"""64-bit packed-word layouts for the GPU queue family (paper Figs. 2 & 3).

The paper's central architectural move (Lemma III.5) is packing every piece of
concurrently-mutated shared state into a single 64-bit word so that native
single-width atomics (FAA / CAS) suffice where wCQ needed CAS2.  This module
defines those layouts and pure bit-twiddling helpers.  All values are Python
ints masked to 64 bits; the simulated atomic memory stores them in numpy
uint64 arrays.

Layouts
-------
Entry word (Fig. 2)  — one per ring slot::

    [ cycle : CYCLE_BITS | safe : 1 | enq : 1 | index : IDX_BITS ]

  ``index`` is a payload index, ``IDX_BOT`` (empty) or ``IDX_BOTC`` (consumed).
  ``cycle`` is the reduced-width cycle tag of Lemmas III.2 / III.6; its width
  is configurable so the property tests can probe the soundness boundary
  (live skew < R/2).

Global Head/Tail word (Fig. 3)::

    [ cnt : CNT_BITS | thridx : TID_BITS ]

  ``thridx`` is the helper thread id of the in-flight SLOWFAA phase-2 round,
  or ``NULL_TID``.

Local head/tail word (Fig. 3, per-thread record)::

    [ lcnt : LCNT_BITS | seq : SEQ_BITS | inc : 1 | fin : 1 ]

Request / result / note words — per-thread slow-path records, all seq-tagged
so stale helpers fail their CASes (the publication discipline of § III-C-c).
"""

from __future__ import annotations

from dataclasses import dataclass

MASK64 = (1 << 64) - 1

# ---------------------------------------------------------------------------
# Entry word
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EntryFormat:
    """Bit layout for a ring-slot entry word."""

    idx_bits: int = 32
    cycle_bits: int = 30  # reduced-width cycle tag (Lemma III.2 / III.6)

    @property
    def idx_mask(self) -> int:
        return (1 << self.idx_bits) - 1

    @property
    def cycle_mask(self) -> int:
        return (1 << self.cycle_bits) - 1

    @property
    def cycle_range(self) -> int:
        """R = 2^{b_c}."""
        return 1 << self.cycle_bits

    # Field offsets:  [cycle | safe | enq | idx]
    @property
    def enq_shift(self) -> int:
        return self.idx_bits

    @property
    def safe_shift(self) -> int:
        return self.idx_bits + 1

    @property
    def cycle_shift(self) -> int:
        return self.idx_bits + 2

    @property
    def idx_bot(self) -> int:
        """⊥ — empty slot."""
        return self.idx_mask

    @property
    def idx_botc(self) -> int:
        """⊥_c — consumed slot."""
        return self.idx_mask - 1

    def pack(self, cycle: int, safe: int, enq: int, idx: int) -> int:
        assert 0 <= idx <= self.idx_mask
        return (
            ((cycle & self.cycle_mask) << self.cycle_shift)
            | ((safe & 1) << self.safe_shift)
            | ((enq & 1) << self.enq_shift)
            | idx
        ) & MASK64

    def cycle(self, word: int) -> int:
        return (word >> self.cycle_shift) & self.cycle_mask

    def safe(self, word: int) -> int:
        return (word >> self.safe_shift) & 1

    def enq(self, word: int) -> int:
        return (word >> self.enq_shift) & 1

    def idx(self, word: int) -> int:
        return word & self.idx_mask

    def is_empty_idx(self, word: int) -> bool:
        return self.idx(word) in (self.idx_bot, self.idx_botc)

    def with_idx(self, word: int, idx: int) -> int:
        """Replace the index field, preserving the other packed fields
        (the CONSUME primitive of § III-B-c builds on this)."""
        return ((word & ~self.idx_mask) | (idx & self.idx_mask)) & MASK64

    def cycle_lt(self, a: int, b: int) -> bool:
        """Modular ``a < b`` on reduced-width cycle tags (Lemma III.6):
        b is newer than a  iff  0 < (b - a) mod R < R/2."""
        d = (b - a) & self.cycle_mask
        return 0 < d < (self.cycle_range >> 1)

    def cycle_eq(self, a: int, b: int) -> bool:
        return (a & self.cycle_mask) == (b & self.cycle_mask)


# ---------------------------------------------------------------------------
# Global Head/Tail word  (cnt | thridx)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GlobalFormat:
    tid_bits: int = 16

    @property
    def tid_mask(self) -> int:
        return (1 << self.tid_bits) - 1

    @property
    def null_tid(self) -> int:
        return self.tid_mask

    @property
    def cnt_mask(self) -> int:
        return (1 << (64 - self.tid_bits)) - 1

    def pack(self, cnt: int, thridx: int) -> int:
        return (((cnt & self.cnt_mask) << self.tid_bits) | (thridx & self.tid_mask)) & MASK64

    def cnt(self, word: int) -> int:
        return (word >> self.tid_bits) & self.cnt_mask

    def thridx(self, word: int) -> int:
        return word & self.tid_mask


# ---------------------------------------------------------------------------
# Local head/tail word  (lcnt | seq | inc | fin)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LocalFormat:
    seq_bits: int = 16
    lcnt_bits: int = 46

    @property
    def seq_mask(self) -> int:
        return (1 << self.seq_bits) - 1

    @property
    def lcnt_mask(self) -> int:
        return (1 << self.lcnt_bits) - 1

    def pack(self, lcnt: int, seq: int, inc: int, fin: int) -> int:
        return (
            ((lcnt & self.lcnt_mask) << (self.seq_bits + 2))
            | ((seq & self.seq_mask) << 2)
            | ((inc & 1) << 1)
            | (fin & 1)
        ) & MASK64

    def lcnt(self, word: int) -> int:
        return (word >> (self.seq_bits + 2)) & self.lcnt_mask

    def seq(self, word: int) -> int:
        return (word >> 2) & self.seq_mask

    def inc(self, word: int) -> int:
        return (word >> 1) & 1

    def fin(self, word: int) -> int:
        return word & 1


# ---------------------------------------------------------------------------
# Request / result / note words (per-thread slow-path record)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RequestFormat:
    """Request word: [ value : 32 | seq : 16 | pending : 1 | isenq : 1 ]."""

    seq_bits: int = 16
    val_bits: int = 32

    @property
    def seq_mask(self) -> int:
        return (1 << self.seq_bits) - 1

    @property
    def val_mask(self) -> int:
        return (1 << self.val_bits) - 1

    def pack(self, value: int, seq: int, pending: int, isenq: int) -> int:
        return (
            ((value & self.val_mask) << (self.seq_bits + 2))
            | ((seq & self.seq_mask) << 2)
            | ((pending & 1) << 1)
            | (isenq & 1)
        ) & MASK64

    def value(self, word: int) -> int:
        return (word >> (self.seq_bits + 2)) & self.val_mask

    def seq(self, word: int) -> int:
        return (word >> 2) & self.seq_mask

    def pending(self, word: int) -> int:
        return (word >> 1) & 1

    def isenq(self, word: int) -> int:
        return word & 1


@dataclass(frozen=True)
class ResultFormat:
    """Result word: [ value : 32 | seq : 16 | done : 1 | empty : 1 ]."""

    seq_bits: int = 16
    val_bits: int = 32

    @property
    def seq_mask(self) -> int:
        return (1 << self.seq_bits) - 1

    @property
    def val_mask(self) -> int:
        return (1 << self.val_bits) - 1

    def pack(self, value: int, seq: int, done: int, empty: int) -> int:
        return (
            ((value & self.val_mask) << (self.seq_bits + 2))
            | ((seq & self.seq_mask) << 2)
            | ((done & 1) << 1)
            | (empty & 1)
        ) & MASK64

    def value(self, word: int) -> int:
        return (word >> (self.seq_bits + 2)) & self.val_mask

    def seq(self, word: int) -> int:
        return (word >> 2) & self.seq_mask

    def done(self, word: int) -> int:
        return (word >> 1) & 1

    def empty(self, word: int) -> int:
        return word & 1


@dataclass(frozen=True)
class NoteFormat:
    """Note word (Lemma III.8): [ cycle : 47 | seq : 16 | valid : 1 ].

    ``cycle`` here is the *unreduced* per-request round cycle: the note is
    private to one request record, so it does not need the reduced-width
    treatment of the shared entry words.
    """

    seq_bits: int = 16

    @property
    def seq_mask(self) -> int:
        return (1 << self.seq_bits) - 1

    def pack(self, cycle: int, seq: int, valid: int) -> int:
        return (((cycle & ((1 << 47) - 1)) << (self.seq_bits + 1))
                | ((seq & self.seq_mask) << 1) | (valid & 1)) & MASK64

    def cycle(self, word: int) -> int:
        return (word >> (self.seq_bits + 1)) & ((1 << 47) - 1)

    def seq(self, word: int) -> int:
        return (word >> 1) & self.seq_mask

    def valid(self, word: int) -> int:
        return word & 1


# Default singletons used across the queue family.
ENTRY = EntryFormat()
GLOBAL = GlobalFormat()
LOCAL = LocalFormat()
REQ = RequestFormat()
RES = ResultFormat()
NOTE = NoteFormat()
