"""repro.runtime — the queue-backed task-parallel execution engine
(DESIGN.md § 4).

Two faces over the same queue core:

* **sim face** — ``TaskFabric`` (sharded MPMC rings, wave-affinity
  placement, work stealing, priority lanes) driven by ``TaskRuntime``
  persistent workers under the adversarial interleaving scheduler;
* **JAX face** — ``RoundRunner`` / ``PriorityRoundRunner`` (deterministic
  rounds over the Pallas ring/heap, running on the fused device-resident
  megaround engine ``fusedrounds.FusedRounds`` by default with host sync
  only at quiescence), ``MeshRoundRunner`` (the FIFO megaround under
  shard_map, DESIGN.md § 2.3), and ``PriorityMeshRoundRunner`` (the
  sharded G-PQ megaround — strict or k-relaxed pop order, DESIGN.md § 6).
"""

from .executor import Arrival, ExecutorConfig, Handler, TaskRuntime
from .fusedrounds import FusedPriorityRounds, FusedRounds
from .meshrounds import (FusedMeshRounds, FusedPriorityMeshRounds,
                         MeshRoundRunner, PriorityMeshRoundRunner)
from .rounds import (HeapState, PriorityRoundRunner, RingState, RoundRunner,
                     heap_init, mesh_task_round, ring_init)
from .taskpool import (FabricMetrics, HostTaskPool, PriorityFabric,
                       TaskFabric, TaskRecord, TaskSpec)

__all__ = [
    "Arrival", "ExecutorConfig", "FabricMetrics", "FusedMeshRounds",
    "FusedPriorityMeshRounds", "FusedPriorityRounds", "FusedRounds",
    "Handler", "HostTaskPool", "HeapState", "MeshRoundRunner",
    "PriorityFabric", "PriorityMeshRoundRunner", "PriorityRoundRunner",
    "RingState", "RoundRunner", "TaskFabric", "TaskRecord", "TaskSpec",
    "TaskRuntime", "heap_init", "mesh_task_round", "ring_init",
]
