"""Dense-wave compaction invariants (DESIGN.md § 4.4):

* ``wave_compact`` (Pallas segmented scan) and ``compact_planes`` (pure-jnp
  ``associative_scan`` twin) both match a numpy cumsum oracle over random /
  all-inactive / full masks, one and two planes, single- and multi-block
  shapes, and both report the TRUE popcount even when lanes clamp;
* compacted lanes land in exactly the row-major ticket order ``wavefaa``
  ranks promise, so the dense wave and the sparse scatter address the same
  slots;
* ``compact_width`` implements the engagement rule (off / auto / forced,
  bound clamp, nlanes==0);
* birth-round stamps survive a compacted wave: span planes are
  bit-identical with compaction forced on vs off on every engine, and the
  four engines themselves stay fused/legacy bit-identical with the
  dense-wave path engaged.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.jaxcompat import make_mesh
from repro.kernels import LANES, compact_planes, compact_width, wave_compact
from repro.kernels.wavefaa import wavefaa
from repro.obs.spans import Spans
from repro.runtime import (MeshRoundRunner, PriorityMeshRoundRunner,
                           PriorityRoundRunner, RoundRunner)


def _oracle(mask, planes, width):
    """Numpy reference: exclusive-cumsum ranks in row-major order, drop
    lanes past ``width``, TRUE (unclamped) popcount."""
    m = np.asarray(mask) > 0
    rank = np.cumsum(m) - m
    dense = [np.zeros(width, np.int32) for _ in planes]
    for d, p in zip(dense, planes):
        keep = m & (rank < width)
        d[rank[keep]] = np.asarray(p)[keep]
    return dense, int(m.sum())


@pytest.mark.parametrize("n", [256, 1024, 2500])
@pytest.mark.parametrize("density", [0.0, 0.3, 1.0])
@pytest.mark.parametrize("nplanes", [1, 2])
def test_compact_matches_cumsum_oracle(n, density, nplanes):
    rng = np.random.default_rng(n * 7 + nplanes)
    mask = (rng.random(n) < density).astype(np.int32)
    planes = [rng.integers(1, 1 << 20, n).astype(np.int32)
              for _ in range(nplanes)]
    for width in (max(n // 8, 8), n):          # clamping and full widths
        dref, cref = _oracle(mask, planes, width)
        dj, cj = compact_planes(jnp.asarray(mask),
                                tuple(jnp.asarray(p) for p in planes),
                                width=width)
        dk, ck = wave_compact(jnp.asarray(mask),
                              tuple(jnp.asarray(p) for p in planes),
                              width=width, interpret=True)
        assert int(cj) == cref and int(ck) == cref   # TRUE popcount
        for a, b, c in zip(dref, dj, dk):
            np.testing.assert_array_equal(a, np.asarray(b))
            np.testing.assert_array_equal(a, np.asarray(c))


def test_compact_multiblock_matches_twin():
    # > one grid step for the Pallas kernel (block = LANES lanes)
    n = 3 * LANES + 137
    rng = np.random.default_rng(9)
    mask = (rng.random(n) < 0.15).astype(np.int32)
    plane = rng.integers(1, 1 << 20, n).astype(np.int32)
    width = 512
    (dj,), cj = compact_planes(jnp.asarray(mask), (jnp.asarray(plane),),
                               width=width)
    (dk,), ck = wave_compact(jnp.asarray(mask), (jnp.asarray(plane),),
                             width=width, interpret=True)
    np.testing.assert_array_equal(np.asarray(dj), np.asarray(dk))
    assert int(cj) == int(ck) == int(mask.sum())


def test_compact_order_matches_wavefaa_ranks():
    # the dense wave's lane i must hold the value whose wavefaa ticket is
    # base + i — row-major ticket order is the shared contract
    n = 2048
    rng = np.random.default_rng(3)
    mask = (rng.random(n) < 0.4).astype(np.int32)
    vals = rng.integers(1, 1 << 20, n).astype(np.int32)
    base = 1000
    tickets, _ = wavefaa(jnp.asarray(mask), jnp.array([base], jnp.int32),
                         interpret=True)
    (dense,), count = compact_planes(jnp.asarray(mask), (jnp.asarray(vals),),
                                     width=n)
    sparse = np.zeros(n, np.int32)
    tk = np.asarray(tickets)
    sparse[tk[mask > 0] - base] = vals[mask > 0]
    np.testing.assert_array_equal(np.asarray(dense), sparse)
    assert int(count) == int(mask.sum())


def test_compact_width_rule():
    assert compact_width(100, 64, False) is None       # forced off
    assert compact_width(0, 64) is None                # no lanes
    assert compact_width(100, 64) == 64                # auto: engages, clamps
    assert compact_width(32, 64) is None               # auto: already narrow
    assert compact_width(32, 64, True) == 32           # forced on
    assert compact_width(3, 0, True) == 1              # floor at one lane


def _tree_step():
    def step(acc, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        cm = (valid & (vals < 32))[:, None]
        return acc, cv, cm
    return step


def _pri_step():
    def step(acc, keys, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        ck = (cv * 7919) % 1000
        cm = (valid & (vals < 32))[:, None]
        return acc, ck, cv, cm
    return step


def _runs(make, priority=False):
    out = []
    for compact in (False, True):
        r = make(compact)
        acc, st = (r.run([7919 % 1000], [1], acc=jnp.zeros(80, jnp.int32))
                   if priority
                   else r.run([1], acc=jnp.zeros(80, jnp.int32)))
        stats = {k: v for k, v in r.stats.items()
                 if k not in ("fused", "host_syncs")}
        out.append((np.asarray(acc), stats, r))
    return out


def test_chip_fifo_compact_bit_identical():
    off, on = _runs(lambda c: RoundRunner(
        _tree_step(), capacity_log2=8, batch=16, interpret=True, compact=c))
    np.testing.assert_array_equal(off[0], on[0])
    assert off[1] == on[1]


def test_chip_priority_compact_bit_identical():
    off, on = _runs(lambda c: PriorityRoundRunner(
        _pri_step(), capacity_log2=8, batch=16, interpret=True, compact=c),
        priority=True)
    np.testing.assert_array_equal(off[0], on[0])
    assert off[1] == on[1]


def test_mesh_fifo_compact_bit_identical():
    mesh = make_mesh((1,), ("data",))
    off, on = _runs(lambda c: MeshRoundRunner(
        _tree_step(), mesh=mesh, capacity_log2=8, batch=16, compact=c,
        combine=lambda a: a.sum(0)))
    np.testing.assert_array_equal(off[0], on[0])
    assert off[1] == on[1]


@pytest.mark.parametrize("relaxed", [True, False])
def test_mesh_priority_compact_bit_identical(relaxed):
    mesh = make_mesh((1,), ("data",))
    off, on = _runs(lambda c: PriorityMeshRoundRunner(
        _pri_step(), mesh=mesh, capacity_log2=8, batch=16, relaxed=relaxed,
        compact=c, combine=lambda a: a.sum(0)), priority=True)
    np.testing.assert_array_equal(off[0], on[0])
    assert off[1] == on[1]


def _span_snap(sp):
    return (np.asarray(sp.hist).tolist(), np.asarray(sp.max_wait).tolist(),
            int(np.asarray(sp.total).sum()))


def test_spans_survive_compacted_wave_chip():
    # birth stamps thread the compacted enqueue: identical wait histograms
    snaps = []
    for compact in (False, True):
        sp = Spans(classes=1, engine="rounds")
        r = RoundRunner(_tree_step(), capacity_log2=8, batch=16,
                        interpret=True, compact=compact, spans=sp)
        r.run([1], acc=jnp.zeros(80, jnp.int32))
        snaps.append(_span_snap(sp))
    assert snaps[0] == snaps[1]
    assert snaps[0][2] > 0


@pytest.mark.parametrize("relaxed", [True, False])
def test_spans_survive_compacted_wave_mesh_priority(relaxed):
    mesh = make_mesh((1,), ("data",))
    snaps = []
    for compact in (False, True):
        sp = Spans(classes=1, engine="pmesh")
        r = PriorityMeshRoundRunner(_pri_step(), mesh=mesh, capacity_log2=8,
                                    batch=16, relaxed=relaxed,
                                    compact=compact, spans=sp,
                                    combine=lambda a: a.sum(0))
        r.run([7919 % 1000], [1], acc=jnp.zeros(80, jnp.int32))
        snaps.append(_span_snap(sp))
    assert snaps[0] == snaps[1]
    assert snaps[0][2] > 0
