"""Priority policies — how a task's class/deadline becomes a G-PQ key
(DESIGN.md § 5.4).

A policy maps ``(priority class, optional absolute deadline, now, …)`` to
the integer min-key the fabric's heaps order by (smaller = served first).
Three policies cover the strict-lanes replacement:

* **strict** — ``key = class·STRIDE + arrival-seq``: every class-0 task
  outranks every class-1 task, FIFO within a class.  Exactly the old
  two-lane semantics, including its starvation: sustained class-0 arrivals
  postpone class-1 forever.
* **weighted** — weighted fair queuing by virtual finish time:
  ``key = n_c · (SCALE / w_c)`` for the class's n-th task, so classes share
  throughput ∝ weights.  Starvation-free: every class's keys advance, so
  any pending task is eventually minimal.
* **edf** — earliest deadline first: ``key = deadline`` (absolute, or
  ``now + slack[class]``).  Urgency *ages*: a waiting task's deadline
  stays put while new arrivals take later ones, so class-1 tasks drift
  toward the front instead of re-queuing at fixed rank.  Starvation-free
  with finite slacks.

Policies validate the class range (``0 ≤ priority < classes``) and raise
``ValueError`` otherwise — the fabric does not clamp.
"""

from __future__ import annotations

from typing import Optional, Sequence


class PriorityPolicy:
    """Base: subclasses implement ``key``; ``classes`` bounds the valid
    priority range."""

    name = "abstract"

    def __init__(self, classes: int = 2) -> None:
        self.classes = classes

    def validate(self, priority: int) -> int:
        if not 0 <= priority < self.classes:
            raise ValueError(
                f"priority {priority} out of range [0, {self.classes}) for "
                f"policy {self.name!r}")
        return priority

    def key(self, priority: int, deadline: Optional[int], now: int) -> int:
        raise NotImplementedError


class StrictPolicy(PriorityPolicy):
    """Class-major, FIFO within class — the old strict lanes as a key.

    The default stride (2^25) exceeds the fabric's 24-bit task-id space,
    so the within-class sequence cannot saturate before the task table
    itself overflows; custom strides assert the same headroom because a
    saturated sequence would silently degrade FIFO-within-class to
    arbitrary heap order."""

    name = "strict"

    def __init__(self, classes: int = 2, stride: int = 1 << 25) -> None:
        super().__init__(classes)
        self.stride = stride
        self._seq = 0

    def key(self, priority: int, deadline: Optional[int], now: int) -> int:
        self.validate(priority)
        self._seq += 1
        assert self._seq < self.stride, \
            "StrictPolicy sequence saturated: FIFO-within-class would break"
        return priority * self.stride + self._seq


class WeightedPolicy(PriorityPolicy):
    """Weighted fair queuing (start-time fair queuing flavour): each class
    carries a virtual-finish clock advanced by ``scale / weight`` per task,
    clamped below by real time — so an idle class accrues no banked credit,
    a backlogged class shares throughput ∝ its weight, and every class's
    keys advance (starvation-free)."""

    name = "weighted"

    def __init__(self, weights: Sequence[int] = (4, 1),
                 scale: int = 64) -> None:
        super().__init__(len(weights))
        assert all(w > 0 for w in weights)
        self.weights = tuple(weights)
        self.scale = scale
        self._finish = [0] * len(weights)

    def key(self, priority: int, deadline: Optional[int], now: int) -> int:
        self.validate(priority)
        start = max(self._finish[priority], now)
        step = -(-self.scale // self.weights[priority])
        self._finish[priority] = start + step
        return self._finish[priority]


class EDFPolicy(PriorityPolicy):
    """Earliest deadline first; per-class default slacks when a task
    carries no absolute deadline."""

    name = "edf"

    def __init__(self, slack: Sequence[int] = (0, 512)) -> None:
        super().__init__(len(slack))
        self.slack = tuple(slack)

    def key(self, priority: int, deadline: Optional[int], now: int) -> int:
        self.validate(priority)
        if deadline is not None:
            return deadline
        return now + self.slack[priority]


POLICIES = {"strict": StrictPolicy, "weighted": WeightedPolicy,
            "edf": EDFPolicy}


def make_policy(spec) -> PriorityPolicy:
    """'strict' | 'weighted' | 'edf' | an already-built policy object."""
    if isinstance(spec, PriorityPolicy):
        return spec
    if spec in POLICIES:
        return POLICIES[spec]()
    raise ValueError(f"unknown policy {spec!r}; pick from {list(POLICIES)}")
