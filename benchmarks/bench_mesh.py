"""Mesh round-engine benchmark: legacy host-driven per-round shard_map
dispatch vs the fused device-resident megaround loop (DESIGN.md § 2.3,
BENCH_4).

Workloads (both on ≥2 shards of a forced-host-device CPU mesh):

* ``fanout`` — the geometric spawn tree of bench_rounds, now spread over
  the mesh: every round each shard claims its rebalanced share of the
  global frontier, steps it, and publishes children with one psum.  Pure
  coordination cost — the mesh engine IS the workload.
* ``bfs``    — ``apps.bfs.bfs_mesh_rounds`` on a road-like grid (long
  diameter → many rounds: the per-round host-sync regime) and a kron-like
  power-law graph.

Multi-device CPU meshes need ``XLA_FLAGS=--xla_force_host_platform_
device_count=N`` set *before* jax initializes, so the sweep runs in a
subprocess (``--inner``) and the parent relays its CSV — same pattern as
tests/test_distqueue.py.  Timings are best-of-``TRIALS`` per mode (the
shared-runner scheduler noise on oversubscribed CPU devices is large);
compilation is excluded by a warmup run.

``--smoke`` is the CI acceptance gate: fused/legacy bit-parity (acc +
planes + head/tail + stats) on both workloads and host_syncs 1 vs
per-round — correctness only, no speedup assertion (CI timing noise).
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

HEADER = ("bench,workload,batch,shards,mode,rounds,items,elapsed_s,"
          "rounds_per_s,items_per_s,host_syncs,drained,"
          "carry_bytes_per_shard")
TRIALS = 3


def _spawn_inner(args, out) -> int:
    """Run this module in a subprocess with the mesh device count forced;
    relay its stdout into ``out``."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (f"{flags} --xla_force_host_platform_device_count="
                        f"{args[args.index('--shards') + 1]}").strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(repo, "src"), env.get("PYTHONPATH"), repo)
        if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_mesh", "--inner"] + args,
        capture_output=True, text=True, cwd=repo, env=env, timeout=1800)
    print(proc.stdout, end="", file=out)
    if proc.returncode != 0:
        print(f"# FAIL: inner benchmark exited {proc.returncode}: "
              f"{proc.stderr[-2000:]}", file=out)
    return proc.returncode


# ---------------------------------------------------------------------------
# inner (subprocess) side — jax only imported here
# ---------------------------------------------------------------------------


def _fanout_step(fanout: int, depth: int):
    import jax.numpy as jnp

    def step(acc, vals, valid):
        acc = acc.at[jnp.clip(vals, 0, depth)].add(valid.astype(jnp.int32))
        cv = jnp.broadcast_to((vals - 1)[:, None],
                              (vals.shape[0], fanout)).astype(jnp.int32)
        cm = (valid & (vals > 0))[:, None]
        return acc, cv, cm
    return step


def _expected_fanout_acc(fanout: int, depth: int, roots: int):
    import numpy as np
    counts = np.zeros(depth + 1, np.int64)
    for d in range(depth, -1, -1):
        counts[d] = roots * fanout ** (depth - d)
    return counts.astype(np.int32)


def _mesh(shards: int):
    import jax
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src"))
    from repro.jaxcompat import make_mesh
    assert len(jax.devices()) >= shards, (
        f"need {shards} devices, have {len(jax.devices())} "
        f"(XLA_FLAGS not set before jax init?)")
    return make_mesh((shards,), ("data",))


def _fanout_runner(mesh, batch: int, *, fused: bool, sharded: bool = False,
                   depth: int = 14, roots: int = 4, sync_every: int = 0):
    import jax.numpy as jnp
    import numpy as np
    from repro.runtime import MeshRoundRunner

    shards = int(mesh.shape["data"])
    peak = roots * 2 ** depth
    cap_log2 = max(int(np.ceil(np.log2(2 * peak))),
                   int(np.ceil(np.log2(4 * batch * shards))))
    runner = MeshRoundRunner(_fanout_step(2, depth), mesh=mesh,
                             capacity_log2=cap_log2, batch=batch,
                             fused=fused, sharded=sharded,
                             sync_every=sync_every,
                             combine=lambda a: a.sum(0))
    seeds = np.full(roots, depth, np.int32)
    acc0 = jnp.zeros(depth + 1, jnp.int32)
    return runner, seeds, acc0


def run_fanout(mesh, batch: int, *, fused: bool, sharded: bool = False,
               depth: int = 14, roots: int = 4, trials: int = TRIALS):
    """Best-of-``trials`` timed fanout run (post-warmup).  Returns
    (row dict, acc, state)."""
    import numpy as np
    runner, seeds, acc0 = _fanout_runner(mesh, batch, fused=fused,
                                         sharded=sharded, depth=depth,
                                         roots=roots)
    acc, st = runner.run(seeds, acc=acc0, max_rounds=1_000_000)  # warmup
    best = None
    for _ in range(trials):
        t0 = time.perf_counter()
        acc, st = runner.run(seeds, acc=acc0, max_rounds=1_000_000)
        el = time.perf_counter() - t0
        best = el if best is None else min(best, el)
    mode = "sharded" if sharded else ("fused" if fused else "legacy")
    row = _row("fanout", batch, int(mesh.shape["data"]), mode,
               runner.stats, best, runner.loop_carry_bytes())
    return row, np.asarray(acc), st


def run_bfs(mesh, batch: int, *, fused: bool, graph: str = "road",
            n: int = 1024, trials: int = TRIALS):
    import numpy as np
    from repro.apps import bfs

    g = (bfs.road_like(n) if graph == "road"
         else bfs.kron_like(n, avg_deg=4, seed=1))
    runner, init_fn = bfs.bfs_mesh_rounds_runner(g, mesh=mesh, batch=batch,
                                                 fused=fused)
    runner.run([0], acc=init_fn(0), max_rounds=1_000_000)        # warmup
    best, dist = None, None
    for _ in range(trials):
        t0 = time.perf_counter()
        dist, _ = runner.run([0], acc=init_fn(0), max_rounds=1_000_000)
        el = time.perf_counter() - t0
        best = el if best is None else min(best, el)
    row = _row(f"bfs_{graph}", batch, int(mesh.shape["data"]),
               "fused" if fused else "legacy", runner.stats, best,
               runner.loop_carry_bytes())
    return row, np.asarray(dist)


def _row(workload: str, batch: int, shards: int, mode: str, stats: dict,
         elapsed: float, carry_bytes: int) -> dict:
    rounds, items = stats["rounds"], stats["processed"]
    return {
        "workload": workload, "batch": batch, "shards": shards,
        "mode": mode,
        "rounds": rounds, "items": items,
        "elapsed_s": round(elapsed, 4),
        "rounds_per_s": round(rounds / max(elapsed, 1e-9), 1),
        "items_per_s": round(items / max(elapsed, 1e-9), 1),
        "host_syncs": stats["host_syncs"], "drained": stats["drained"],
        "carry_bytes_per_shard": carry_bytes,
    }


def _emit(out, row: dict) -> None:
    print(f"mesh,{row['workload']},{row['batch']},{row['shards']},"
          f"{row['mode']},{row['rounds']},{row['items']},{row['elapsed_s']},"
          f"{row['rounds_per_s']},{row['items_per_s']},{row['host_syncs']},"
          f"{row['drained']},{row['carry_bytes_per_shard']}", file=out)


def run_fanout_interleaved(mesh, batch: int, *, depth: int = 14,
                           roots: int = 4, trials: int = TRIALS):
    """Timed fanout sweep over all three modes with trials interleaved
    (min-of-interleaved-trials: shared-runner scheduler drift hits every
    mode equally instead of biasing whichever ran last)."""
    modes = ("legacy", "fused", "sharded")
    rigs, best = {}, {}
    for mode in modes:
        rigs[mode] = _fanout_runner(mesh, batch, fused=mode != "legacy",
                                    sharded=mode == "sharded",
                                    depth=depth, roots=roots)
        runner, seeds, acc0 = rigs[mode]
        runner.run(seeds, acc=acc0, max_rounds=1_000_000)        # warmup
    for _ in range(trials):
        for mode in modes:
            runner, seeds, acc0 = rigs[mode]
            t0 = time.perf_counter()
            runner.run(seeds, acc=acc0, max_rounds=1_000_000)
            el = time.perf_counter() - t0
            best[mode] = min(best.get(mode, el), el)
    return {mode: _row("fanout", batch, int(mesh.shape["data"]), mode,
                       rigs[mode][0].stats, best[mode],
                       rigs[mode][0].loop_carry_bytes())
            for mode in modes}


def inner_main(out, shards: int, batches, bfs_n: int,
               graphs=("road", "kron")) -> None:
    mesh = _mesh(shards)
    print(f"bench,{HEADER.split(',', 1)[1]}", file=out)
    for batch in batches:
        by_mode = run_fanout_interleaved(mesh, batch)
        for row in by_mode.values():
            _emit(out, row)
        speedup = (by_mode["fused"]["rounds_per_s"]
                   / max(by_mode["legacy"]["rounds_per_s"], 1e-9))
        ratio = (by_mode["sharded"]["rounds_per_s"]
                 / max(by_mode["fused"]["rounds_per_s"], 1e-9))
        print(f"# mesh fanout batch={batch} shards={shards}: fused "
              f"{speedup:.1f}x rounds/s, host_syncs "
              f"{by_mode['legacy']['host_syncs']} -> "
              f"{by_mode['fused']['host_syncs']}; sharded rings "
              f"{by_mode['sharded']['carry_bytes_per_shard']} B/shard "
              f"carry vs {by_mode['fused']['carry_bytes_per_shard']} B "
              f"replicated at {ratio:.2f}x fused rounds/s", file=out)
    for graph in graphs:
        for batch in batches:
            for fused in (False, True):
                row, _ = run_bfs(mesh, batch, fused=fused, graph=graph,
                                 n=bfs_n)
                _emit(out, row)


def inner_smoke(out, shards: int) -> bool:
    """Parity gate, run inside the forced-device subprocess."""
    import numpy as np
    from repro.apps import bfs

    mesh = _mesh(shards)
    ok = True
    print("# mesh smoke: fused-vs-legacy parity on "
          f"{shards} shards", file=out)
    print(f"bench,{HEADER.split(',', 1)[1]}", file=out)

    res = {}
    for fused in (False, True):
        row, acc, st = run_fanout(mesh, 32, fused=fused, depth=6, roots=2,
                                  trials=1)
        _emit(out, row)
        res[fused] = (row, acc, st)
    row_l, acc_l, st_l = res[False]
    row_f, acc_f, st_f = res[True]
    if not (np.array_equal(acc_l, acc_f)
            and np.array_equal(acc_l, _expected_fanout_acc(2, 6, 2))):
        print("# FAIL: mesh fanout acc mismatch", file=out)
        ok = False
    planes_eq = all(np.array_equal(np.asarray(a), np.asarray(b))
                    for a, b in zip(st_l[:4], st_f[:4]))
    heads_eq = (int(np.asarray(st_l.head)) == int(np.asarray(st_f.head))
                and int(np.asarray(st_l.tail)) == int(np.asarray(st_f.tail)))
    if not (planes_eq and heads_eq):
        print("# FAIL: mesh fanout ring state mismatch", file=out)
        ok = False
    if not (row_f["host_syncs"] == 1
            and row_l["host_syncs"] == row_l["rounds"]):
        print("# FAIL: mesh fused path did not reduce host syncs", file=out)
        ok = False

    # sharded rings: same results, per-shard carry O(ring/shards)
    row_s, acc_s, _ = run_fanout(mesh, 32, fused=True, sharded=True,
                                 depth=6, roots=2, trials=1)
    _emit(out, row_s)
    if not np.array_equal(acc_s, _expected_fanout_acc(2, 6, 2)):
        print("# FAIL: sharded mesh fanout acc mismatch", file=out)
        ok = False
    if row_s["host_syncs"] != 1:
        print("# FAIL: sharded mesh path did not reduce host syncs",
              file=out)
        ok = False
    if shards > 1 and not (row_s["carry_bytes_per_shard"]
                           < row_f["carry_bytes_per_shard"]):
        print("# FAIL: sharded rings do not shrink per-shard loop carry",
              file=out)
        ok = False

    g = bfs.road_like(256)
    ref = bfs.bfs_reference(g, 0)
    for fused in (False, True):
        row, dist = run_bfs(mesh, 32, fused=fused, n=256, trials=1)
        _emit(out, row)
        if not np.array_equal(dist, ref):
            print(f"# FAIL: mesh bfs fused={fused} distances wrong",
                  file=out)
            ok = False
    print(f"# acceptance: {'PASS' if ok else 'FAIL'}", file=out)
    return ok


# ---------------------------------------------------------------------------
# outer (CSV-relaying) side
# ---------------------------------------------------------------------------


def main(out=sys.stdout, shards: int = 2, batches=(64, 256),
         bfs_n: int = 1024) -> None:
    print("# mesh round engine: legacy per-round shard_map dispatch vs "
          "fused device-resident megarounds", file=out)
    rc = _spawn_inner(["--shards", str(shards),
                       "--batches", ",".join(map(str, batches)),
                       "--bfs-n", str(bfs_n)], out)
    if rc != 0:
        # fail loudly: a silent-empty mesh section must not masquerade as
        # a completed benchmark in the emitted trajectory
        raise RuntimeError(f"mesh benchmark subprocess exited {rc}")


def smoke(out=sys.stdout, shards: int = 2) -> bool:
    rc = _spawn_inner(["--shards", str(shards), "--smoke"], out)
    return rc == 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true",
                    help="run the sweep in-process (expects XLA_FLAGS set)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI parity gate (fast; asserts correctness only)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep (CI-sized)")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--batches", default="64,256")
    ap.add_argument("--bfs-n", type=int, default=1024)
    a = ap.parse_args()
    batches = tuple(int(b) for b in a.batches.split(","))
    if a.quick:
        batches, a.bfs_n = (64,), 512
    if a.inner:
        if a.smoke:
            sys.exit(0 if inner_smoke(sys.stdout, a.shards) else 1)
        inner_main(sys.stdout, a.shards, batches, a.bfs_n)
        sys.exit(0)
    if a.smoke:
        sys.exit(0 if smoke(shards=a.shards) else 1)
    main(shards=a.shards, batches=batches, bfs_n=a.bfs_n)
