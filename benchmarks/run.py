"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Sections: Fig. 4 throughput, Fig. 5 per-op profiling (+ Fig. 1 ablation),
Table IV/Fig. 6 BFS, Fig. 7 ray tracing, kernel micro-benchmarks.
CSV lines go to stdout: ``name,...`` per row.
"""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweeps (CI-sized)")
    ap.add_argument("--section", default=None,
                    choices=["throughput", "profiling", "bfs", "raytrace",
                             "kernels", None])
    args = ap.parse_args()
    from . import (bench_bfs, bench_kernels, bench_profiling,
                   bench_raytrace, bench_throughput)

    kw_thr = dict(threads_list=(8, 32), steps=40_000) if args.quick else {}
    kw_prof = dict(threads_list=(8, 32), steps=40_000) if args.quick else {}
    sections = {
        "throughput": lambda: bench_throughput.main(**kw_thr),
        "profiling": lambda: bench_profiling.main(**kw_prof),
        "bfs": bench_bfs.main,
        "raytrace": bench_raytrace.main,
        "kernels": bench_kernels.main,
    }
    todo = [args.section] if args.section else list(sections)
    for name in todo:
        print(f"# === {name} ===")
        sections[name]()
        sys.stdout.flush()


if __name__ == "__main__":
    main()
