"""Delta-stepping SSSP on the priority mesh rounds (DESIGN.md § 6): the
sharded G-PQ round engine computing exact shortest paths, strict vs
k-relaxed pop order, fused vs legacy sync.

    PYTHONPATH=src python examples/sssp_demo.py

The whole API in one doctest-sized snippet (1-shard mesh — multi-shard
needs ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` exported
before jax initializes; see README "Priority mesh + SSSP quickstart"):

    >>> from repro.apps import sssp
    >>> from repro.apps.bfs import road_like
    >>> g = road_like(64)                      # 8x8 weighted grid
    >>> w = sssp.with_weights(g, max_w=8, seed=1)
    >>> dist, stats = sssp.sssp_mesh_rounds(g, w, 0, shards=1, batch=16)
    >>> bool((dist == sssp.dijkstra_reference(g, w, 0)).all())
    True
    >>> stats["host_syncs"]                    # fused: one sync per run
    1

``REPRO_EXAMPLES_SMOKE=1`` (the CI examples gate) shrinks the graphs.
"""

import os
import time

import numpy as np

from repro.apps import sssp
from repro.apps.bfs import kron_like, road_like

SMOKE = os.environ.get("REPRO_EXAMPLES_SMOKE", "") not in ("", "0")
N = 256 if SMOKE else 1024

for g in (road_like(N), kron_like(N, avg_deg=4, seed=1)):
    w = sssp.with_weights(g, max_w=8, seed=1)
    ref = sssp.dijkstra_reference(g, w, 0)
    rows = []
    for relaxed in (False, True):
        for fused in (False, True):
            t0 = time.perf_counter()
            dist, stats = sssp.sssp_mesh_rounds(
                g, w, 0, shards=1, batch=64, relaxed=relaxed, fused=fused)
            el = time.perf_counter() - t0
            assert np.array_equal(dist, ref), "distances must match Dijkstra"
            rows.append((("relaxed" if relaxed else "strict"),
                         ("fused" if fused else "legacy"), stats, el))
    finite = ref[ref >= 0]
    print(f"{g.name:12s} n={g.n} m={g.m} reachable={len(finite)} "
          f"max_dist={finite.max()}  (all four engine modes exact)")
    for order, mode, stats, el in rows:
        print(f"  {order:7s}/{mode:6s}: rounds={stats['rounds']:3d} "
              f"processed={stats['processed']:5d} "
              f"host_syncs={stats['host_syncs']:3d}  {el*1e3:7.1f}ms")
