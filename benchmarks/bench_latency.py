"""Offered-load latency sweep: per-class sojourn percentiles from the
device span planes on a 2-shard priority mesh (DESIGN.md § 7.6, BENCH_7).

The span layer's payoff benchmark: where bench_obs prices the *overhead*
of span tracing, this section reads the *signal* — queue sojourn time
(enqueue → dequeue, in rounds) as offered load rises.  ``batch`` is the
load knob: each relaxed shard claims up to ``batch`` items per round, so
``offered_load = items / (rounds · batch · shards)`` is the fraction of
claim capacity the workload actually filled; the p50/p95/p99 columns are
the wait distribution the serving layer cares about and ``starved``
counts classes whose max-wait high-water blew past the starvation factor
(``obs.analyze.starvation_flags``).

Workloads (2-shard relaxed priority mesh, forced host devices):

* ``sssp_road`` — delta-stepping SSSP on the weighted road-like grid;
  span rows default to one per shard (is either shard's queue aging
  worse?).
* ``prio_tree`` — synthetic spawn tree with scrambled keys
  ``(payload · 7919) mod 256`` and ``class_of = key // 64`` (4 priority
  classes): the relaxed pop order serves low keys first, so high-key
  classes *should* wait longer — the per-class p99 gradient makes the
  fairness/ordering tradeoff visible.

Multi-device CPU meshes need ``XLA_FLAGS=--xla_force_host_platform_
device_count=N`` before jax initializes, so the sweep runs in a
subprocess (``--inner``) and the parent relays its CSV — the
bench_sssp.py pattern.  ``--smoke`` is the CI gate: span mass equals
processed items, percentiles are ordered, and the per-class rows merge
consistently across shards.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

HEADER = ("bench,workload,batch,shards,classes,rounds,items,elapsed_s,"
          "offered_load,p50_wait,p95_wait,p99_wait,max_wait,worst_class,"
          "starved,dropped_flows")


def _spawn_inner(args, out) -> int:
    """Run this module in a subprocess with the mesh device count forced;
    relay its stdout into ``out``."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (f"{flags} --xla_force_host_platform_device_count="
                        f"{args[args.index('--shards') + 1]}").strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(repo, "src"), env.get("PYTHONPATH"), repo)
        if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_latency", "--inner"] + args,
        capture_output=True, text=True, cwd=repo, env=env, timeout=1800)
    print(proc.stdout, end="", file=out)
    if proc.returncode != 0:
        print(f"# FAIL: inner benchmark exited {proc.returncode}: "
              f"{proc.stderr[-2000:]}", file=out)
    return proc.returncode


# ---------------------------------------------------------------------------
# inner (subprocess) side — jax only imported here
# ---------------------------------------------------------------------------


def _mesh(shards: int):
    import jax
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src"))
    from repro.jaxcompat import make_mesh
    assert len(jax.devices()) >= shards, (
        f"need {shards} devices, have {len(jax.devices())} "
        f"(XLA_FLAGS not set before jax init?)")
    return make_mesh((shards,), ("data",))


def run_sssp_spans(mesh, batch: int, *, n: int = 512, delta: int = 4):
    """One instrumented relaxed-mesh SSSP run; span rows = shards.
    Returns (row, spans, stats)."""
    from repro.apps import bfs, sssp
    from repro.obs import Spans

    shards = int(mesh.shape["data"])
    g = bfs.road_like(n)
    w = sssp.with_weights(g, max_w=8, seed=1)
    sp = Spans(classes=shards, engine="sssp_mesh")
    runner, init_fn = sssp.sssp_mesh_rounds_runner(
        g, w, mesh=mesh, batch=batch, delta=delta, relaxed=True,
        fused=True, spans=sp)
    runner.run([0], [0], acc=init_fn(0), max_rounds=1_000_000)   # warmup
    sp.reset()
    t0 = time.perf_counter()
    runner.run([0], [0], acc=init_fn(0), max_rounds=1_000_000)
    el = time.perf_counter() - t0
    return (_row("sssp_road", batch, shards, sp, runner.stats, el),
            sp, dict(runner.stats))


def run_prio_tree_spans(mesh, batch: int, *, limit: int = 256,
                        roots: int = 4):
    """One instrumented relaxed priority-mesh run over a synthetic spawn
    tree with 4 key-derived priority classes.  Returns (row, spans,
    stats)."""
    import jax.numpy as jnp
    from repro.obs import Spans
    from repro.runtime import PriorityMeshRoundRunner

    shards = int(mesh.shape["data"])

    def tree_step(acc, keys, vals, valid):
        del keys
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        ck = (cv * 7919) % 256
        cm = (valid & (vals < limit))[:, None]
        return acc, ck, cv, cm

    sp = Spans(classes=4, engine="prio_mesh",
               class_of=lambda k: k // 64)
    runner = PriorityMeshRoundRunner(
        tree_step, mesh=mesh, capacity_log2=12, batch=batch, relaxed=True,
        fused=True, combine=lambda a: a.sum(0), spans=sp)
    seeds = [((v * 7919) % 256, v) for v in range(1, roots + 1)]
    acc0 = lambda: jnp.zeros(2 * limit + 8, jnp.int32)  # noqa: E731
    runner.run([k for k, _ in seeds], [v for _, v in seeds], acc=acc0())
    sp.reset()
    t0 = time.perf_counter()
    runner.run([k for k, _ in seeds], [v for _, v in seeds], acc=acc0())
    el = time.perf_counter() - t0
    return (_row("prio_tree", batch, shards, sp, runner.stats, el),
            sp, dict(runner.stats))


def _row(workload: str, batch: int, shards: int, sp, stats: dict,
         elapsed: float) -> dict:
    from repro.obs import max_wait_highwater, starvation_flags
    rounds, items = stats["rounds"], stats["processed"]
    summ = sp.summary()
    hw = max_wait_highwater(summ)
    flags = starvation_flags(summ)
    return {
        "workload": workload, "batch": batch, "shards": shards,
        "classes": summ["classes"], "rounds": rounds, "items": items,
        "elapsed_s": round(elapsed, 4),
        "offered_load": round(items / max(rounds * batch * shards, 1), 4),
        "p50_wait": summ["p50"], "p95_wait": summ["p95"],
        "p99_wait": summ["p99"], "max_wait": hw["high_water"],
        "worst_class": hw["worst_class"],
        "starved": len(flags["starved_classes"]),
        "dropped_flows": sp.dropped_flows,
    }


def _emit(out, row: dict) -> None:
    cells = [row[k] for k in HEADER.split(",")[1:]]
    print("latency," + ",".join("" if c is None else str(c)
                                for c in cells), file=out)


def inner_main(out, shards: int, batches, n: int) -> None:
    mesh = _mesh(shards)
    print(f"bench,{HEADER.split(',', 1)[1]}", file=out)
    for batch in batches:
        row_s, _, _ = run_sssp_spans(mesh, batch, n=n)
        _emit(out, row_s)
        row_p, _, _ = run_prio_tree_spans(mesh, batch)
        _emit(out, row_p)
        print(f"# batch={batch}: sssp p99 wait {row_s['p99_wait']} rounds "
              f"@ load {row_s['offered_load']}, prio_tree p99 "
              f"{row_p['p99_wait']} @ load {row_p['offered_load']} "
              f"(worst class {row_p['worst_class']})", file=out)


def inner_smoke(out, shards: int) -> bool:
    """CI gate: span mass == processed items, ordered percentiles, and a
    populated per-class histogram on both workloads."""
    from repro.obs import bucket_edges, bucket_of
    mesh = _mesh(shards)
    ok = True
    print(f"# latency smoke: span-mass parity + ordered percentiles on "
          f"{shards} shards", file=out)
    print(f"bench,{HEADER.split(',', 1)[1]}", file=out)
    for name, fn in (("sssp_road", lambda: run_sssp_spans(mesh, 32, n=256)),
                     ("prio_tree", lambda: run_prio_tree_spans(
                         mesh, 32, limit=128))):
        row, sp, stats = fn()
        _emit(out, row)
        if sp.total != stats["processed"]:
            print(f"# FAIL: {name} span mass {sp.total} != processed "
                  f"{stats['processed']}", file=out)
            ok = False
        ps = [row["p50_wait"], row["p95_wait"], row["p99_wait"]]
        known = [p for p in ps if p is not None]
        if not known or known != sorted(known):
            print(f"# FAIL: {name} percentiles missing or unordered: {ps}",
                  file=out)
            ok = False
        # p99 is a bucket *upper edge* while max_wait is exact, so compare
        # at bucket granularity: p99's edge cannot exceed the edge of the
        # bucket holding the true maximum
        nb = sp.buckets
        if (row["p99_wait"] is not None
                and row["p99_wait"]
                > int(bucket_edges(nb)[bucket_of(row["max_wait"], nb)])):
            print(f"# FAIL: {name} p99 {row['p99_wait']} beyond max_wait "
                  f"{row['max_wait']}'s bucket", file=out)
            ok = False
    print(f"# acceptance: {'PASS' if ok else 'FAIL'}", file=out)
    return ok


# ---------------------------------------------------------------------------
# outer (CSV-relaying) side
# ---------------------------------------------------------------------------


def main(out=sys.stdout, shards: int = 2, batches=(16, 64, 256),
         n: int = 512) -> None:
    print("# offered-load latency sweep: device span histograms on the "
          "2-shard priority mesh", file=out)
    rc = _spawn_inner(["--shards", str(shards),
                       "--batches", ",".join(map(str, batches)),
                       "--n", str(n)], out)
    if rc != 0:
        raise RuntimeError(f"latency benchmark subprocess exited {rc}")


def smoke(out=sys.stdout, shards: int = 2) -> bool:
    rc = _spawn_inner(["--shards", str(shards), "--smoke"], out)
    return rc == 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true",
                    help="run the sweep in-process (expects XLA_FLAGS set)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI correctness gate (no timing assertion)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep (CI-sized)")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--batches", default="16,64,256")
    ap.add_argument("--n", type=int, default=512)
    a = ap.parse_args()
    batches = tuple(int(b) for b in a.batches.split(","))
    if a.quick:
        batches, a.n = (64,), 256
    if a.inner:
        if a.smoke:
            sys.exit(0 if inner_smoke(sys.stdout, a.shards) else 1)
        inner_main(sys.stdout, a.shards, batches, a.n)
        sys.exit(0)
    if a.smoke:
        sys.exit(0 if smoke(shards=a.shards) else 1)
    main(shards=a.shards, batches=batches, n=a.n)
