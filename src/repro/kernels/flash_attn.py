"""Fused flash attention as a Pallas TPU kernel.

EXPERIMENTS.md § Perf identifies the dominant memory term of the train /
prefill cells as flash logit tiles round-tripping HBM in the XLA-materialized
implementation (`models.layers._flash_attention`).  This kernel is the
TPU-native remedy: the (bq × bk) logits tile, the online-softmax statistics
and the output accumulator live in VMEM scratch; HBM traffic reduces to one
read of Q/K/V and one write of O — arithmetic intensity ≈ bk/2 FLOPs/byte
instead of <1.

Grid: (batch, q_heads, nq, nk) with the k-block dimension innermost
(sequential on TPU), carrying (m, l, acc) scratch across k-blocks.  GQA maps
q-head h to kv-head h // rep in the K/V BlockSpec index maps.  Causal /
sliding-window masks and gemma-style logit soft-capping are computed
in-kernel from block offsets.

VMEM budget per core: q/k/v/o tiles (bq+2·bk+bq)·hd·2B + scratch
(bq·bk·4 + bq·(hd+2)·4) ≈ 1.8 MiB at bq=bk=512, hd=128 — well inside 16 MiB.

Validated in interpret mode against `ref.flash_attention_ref` over
shape/dtype/mask sweeps (tests/test_kernels.py); the framework integration
point is `models.layers` (kernel on TPU backends, XLA path on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 512
DEFAULT_BK = 512


def _flash_kernel(softcap_val, causal, window, scale, bq, bk,
                  q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                   # (bq, hd)
    k = k_ref[0, 0]                                   # (bk, hd)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap_val:
        s = softcap_val * jnp.tanh(s / softcap_val)
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok = ok & (kpos <= qpos)
    if window:
        ok = ok & (kpos > qpos - window)
    s = jnp.where(ok, s, -1e30)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(p.astype(v.dtype), v,
                                          (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap_val", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap_val: float = 0.0, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool = True):
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd) with H % KV == 0.
    Returns (B, H, Sq, hd)."""
    b, h, sq, hd = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    rep = h // kvh
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / (hd ** 0.5)
    kern = functools.partial(_flash_kernel, float(softcap_val), bool(causal),
                             int(window), scale, bq, bk)
    return pl.pallas_call(
        kern,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
