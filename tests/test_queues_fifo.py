"""Device-side FIFO conformance (paper § IV-b) for the full queue family:
exactly-once delivery, no out-of-thin-air tokens, per-producer monotone
sequences — across schedulers and capacities, with the G-WFQ/G-WFQ-YMC slow
paths forced via tiny patience."""

import pytest

from repro.core import QUEUE_CLASSES, run_producer_consumer


CASES = [
    ("glfq", {}),
    ("gwfq", dict(patience=2, help_delay=4)),
    ("gwfq-ymc", dict(patience=2, help_delay=4)),
    ("sfq", {}),
]


@pytest.mark.parametrize("name,kw", CASES, ids=[c[0] for c in CASES])
@pytest.mark.parametrize("policy", ["random", "gang"])
@pytest.mark.parametrize("capacity", [4, 16])
def test_fifo_conformance(name, kw, policy, capacity):
    q = QUEUE_CLASSES[name](capacity=capacity, num_threads=8, **kw)
    sched, sink, rep = run_producer_consumer(
        q, producers=4, consumers=4, ops_per_producer=12,
        policy=policy, seed=1234, max_steps=3_000_000)
    assert rep.ok, f"{name}: {rep.reason}"


@pytest.mark.parametrize("name,kw", CASES, ids=[c[0] for c in CASES])
def test_fifo_many_seeds(name, kw):
    for seed in range(5):
        q = QUEUE_CLASSES[name](capacity=8, num_threads=8, **kw)
        _, _, rep = run_producer_consumer(
            q, producers=4, consumers=4, ops_per_producer=10,
            policy="random", seed=seed, max_steps=3_000_000)
        assert rep.ok, f"{name} seed={seed}: {rep.reason}"


def test_single_thread_sequential():
    """Sequential sanity: FIFO order with one thread."""
    from repro.core import AtomicMemory, Scheduler
    from repro.core.sim import DEQ, ENQ
    for name, kw in CASES:
        q = QUEUE_CLASSES[name](capacity=8, num_threads=1, **kw)
        mem = AtomicMemory()
        q.init(mem)
        sched = Scheduler(mem, policy="rr")
        result = {}

        def body(ctx, tid):
            got = []
            for v in (5, 6, 7):
                ok = yield from q.enqueue(ctx, tid, v)
                assert ok
            for _ in range(3):
                ok, v = yield from q.dequeue(ctx, tid)
                got.append(v)
            ok, v = yield from q.dequeue(ctx, tid)
            result["empty"] = not ok
            result["got"] = got

        sched.spawn(body)
        assert sched.run(500_000)
        assert result["got"] == [5, 6, 7], f"{name}: {result}"
        assert result["empty"], f"{name}: dequeue on empty must report EMPTY"


def test_bounded_capacity_rejects():
    """A full G-LFQ rejects enqueues (bounded memory, § III-B)."""
    from repro.core import AtomicMemory, Scheduler
    q = QUEUE_CLASSES["glfq"](capacity=4, num_threads=1)
    mem = AtomicMemory()
    q.init(mem)
    sched = Scheduler(mem, policy="rr")
    result = {}

    def body(ctx, tid):
        oks = []
        for v in range(8):
            ok = yield from q.enqueue(ctx, tid, v)
            oks.append(ok)
        result["oks"] = oks

    sched.spawn(body)
    assert sched.run(500_000)
    assert result["oks"][:4] == [True] * 4
    assert not all(result["oks"]), "enqueue into a full bounded ring must fail"


@pytest.mark.parametrize("name,kw", [
    ("glfq", {}),
    ("gwfq", dict(patience=4, help_delay=8)),
], ids=["glfq", "gwfq"])
def test_reduced_cycle_tags_sound_across_wraps(name, kw):
    """Lemma III.2 / III.6: with the paper's proof configuration (k ≤ n,
    D = 64) an 8-bit cycle tag (R = 256) is sufficient.  Drive a tiny ring
    (n = 4, 2n = 8 slots) through hundreds of cycle wraps — far beyond the
    tag range — with producers and consumers racing; FIFO conformance must
    hold throughout (modular comparison never confuses live states)."""
    q = QUEUE_CLASSES[name](capacity=4, num_threads=4, cycle_bits=8, **kw)
    _, _, rep = run_producer_consumer(
        q, producers=2, consumers=2, ops_per_producer=1200,
        policy="random", seed=11, max_steps=12_000_000)
    assert rep.ok, rep.reason
    # confirm the run genuinely wrapped the 8-bit tag range (> 256 cycles)
    tail_name = f"{q.tag}_tailG" if name == "gwfq" else f"{q.tag}_tail"
    raw = int(q.mem.array(tail_name)[0])
    tail = (raw >> 16) if name == "gwfq" else raw
    assert tail // q.nslots > 256, f"only {tail // q.nslots} cycles — no wrap"
