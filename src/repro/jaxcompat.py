"""Version-compat shims over the handful of jax APIs that moved between
the 0.4.x line (what the container ships) and the 0.5+/0.6 line (what parts
of the codebase were written against).

Three surfaces are papered over:

* ``current_mesh()`` — the ambient mesh used for sharding hints.  New jax
  exposes ``jax.sharding.get_abstract_mesh()``; old jax keeps the context
  mesh on ``jax._src.mesh.thread_resources.env.physical_mesh``.  Both
  normalize to "an object with ``.axis_names`` and a mapping ``.shape``, or
  ``None`` when no mesh with named axes is ambient" — all call sites only
  ever read those two attributes.
* ``make_mesh(shape, axes)`` — ``axis_types=`` (and ``jax.sharding.AxisType``
  itself) does not exist before 0.5; every mesh here is Auto-typed anyway,
  which is also the old default.
* ``cost_analysis_dict(compiled)`` — ``Compiled.cost_analysis()`` returned a
  one-element list of dicts on old jax and returns the dict directly on new
  jax.
"""

from __future__ import annotations

from typing import Optional

import jax


def current_mesh() -> Optional[object]:
    """The ambient mesh (``with mesh:`` / ``use_mesh`` context), or ``None``
    when no mesh with named axes is active."""
    mesh = None
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        try:
            mesh = get_am()
        except Exception:
            mesh = None
    if mesh is None or not getattr(mesh, "axis_names", None):
        try:  # old jax: the `with Mesh(...)` context manager's thread state
            from jax._src import mesh as mesh_lib
            mesh = mesh_lib.thread_resources.env.physical_mesh
        except Exception:
            return None
    if mesh is None or not getattr(mesh, "axis_names", None):
        return None
    return mesh


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types on every jax version."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def axis_size(axis: str):
    """``jax.lax.axis_size`` (new jax) or a statically-evaluated psum of 1
    over the axis (old jax — the operand is a constant, so no collective is
    actually emitted)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis)
    return jax.lax.psum(1, axis)


def pvary(x, axis: str):
    """Idempotent ``jax.lax.pvary``: promote to axis-varying only if not
    already.  A no-op on jax versions without the varying-manual-axes type
    system (where every shard_map value is already axis-varying)."""
    fn = getattr(jax.lax, "pvary", None)
    if fn is None:
        return x
    try:
        if axis in jax.typeof(x).vma:
            return x
    except AttributeError:
        pass
    return fn(x, (axis,))


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` to a plain dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}
