"""Round-based deterministic task loop on the Pallas ring (DESIGN.md § 4.3).

The sim face (`executor.py`) explores adversarial interleavings; this face is
the *device* execution model: task scheduling advances in jitted rounds, and
within a round every queue operation is ordered by ticket — the batched
analogue of Lemma III.1, with no nondeterminism left.  One round is

    dequeue a batch of task values from the ring (``ring_dequeue``),
    run the user's jitted step function on the batch,
    enqueue the children it emits (``ring_enqueue``) in row-major order.

Head/Tail live on the host between rounds (the round loop is data-dependent:
it stops at quiescence), so tickets are computed exactly and every kernel
invocation uses fixed ``batch``-sized operands — two compilations total.
Because ticket issue is exact, TRYENQ/TRYDEQ never miss: the kernels'
conditional paths are exercised but the ``ok`` flags certify every op, and
the whole run is bit-deterministic (pure integer jnp + host ints, no RNG).

At mesh scope the same round structure runs on ``core.distqueue``:
``mesh_task_round`` composes one enqueue round and one dequeue round inside
shard_map — each chip contributes its spawn/claim masks, one prefix-sum
collective orders the whole mesh's tickets (DESIGN.md § 2.3).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.distqueue import dist_dequeue_round, dist_enqueue_round
from ..kernels.heap_batch import KEY_INF as HEAP_KEY_INF, heap_apply
from ..kernels.ring_slots import ring_dequeue, ring_enqueue

IDX_BOT = 2 ** 31 - 1           # ⊥ (⊥_c = IDX_BOT - 1); payloads must be smaller


class RingState(NamedTuple):
    """Field planes of the 2n-slot ring plus host-side head/tail tickets."""
    cycles: jax.Array
    safes: jax.Array
    enqs: jax.Array
    idxs: jax.Array
    head: int
    tail: int

    @property
    def occupancy(self) -> int:
        return self.tail - self.head


def ring_init(capacity_log2: int) -> RingState:
    """Ring with logical capacity 2^capacity_log2 (2n physical slots).
    Head = Tail = 2n, so first tickets carry cycle 1 over cycle-0 slots."""
    nslots = 2 << capacity_log2
    return RingState(
        cycles=jnp.zeros((nslots,), jnp.int32),
        safes=jnp.ones((nslots,), jnp.int32),
        enqs=jnp.zeros((nslots,), jnp.int32),
        idxs=jnp.full((nslots,), IDX_BOT, jnp.int32),
        head=nslots, tail=nslots,
    )


# StepFn: (acc, vals (B,), valid (B,)) -> (acc, child_vals (B,F), child_mask (B,F))
StepFn = Callable[[Any, jax.Array, jax.Array], Tuple[Any, jax.Array, jax.Array]]


class RoundRunner:
    """Drives ``step_fn`` to quiescence through the Pallas ring."""

    def __init__(self, step_fn: StepFn, *, capacity_log2: int = 10,
                 batch: int = 64, interpret: bool = True) -> None:
        self.step_fn = jax.jit(step_fn)
        self.capacity_log2 = capacity_log2
        self.nslots_log2 = capacity_log2 + 1
        self.capacity = 1 << capacity_log2
        self.batch = batch
        self.interpret = interpret
        self.stats: Dict[str, int] = {}

    def _enq_chunk(self, st: RingState, vals: np.ndarray) -> RingState:
        b, k = self.batch, len(vals)
        assert k <= b
        if st.occupancy + k > self.capacity:
            raise RuntimeError(
                f"ring overflow: occupancy {st.occupancy} + {k} children "
                f"exceeds capacity {self.capacity} (raise capacity_log2 or "
                f"lower the fanout)")
        tickets = np.full(b, -1, np.int32)
        tickets[:k] = st.tail + np.arange(k, dtype=np.int32)
        values = np.full(b, -1, np.int32)
        values[:k] = vals
        cyc, saf, enq, idx, ok = ring_enqueue(
            st.cycles, st.safes, st.enqs, st.idxs,
            jnp.asarray(tickets), jnp.asarray(values),
            jnp.asarray([st.head], jnp.int32).reshape(()),
            nslots_log2=self.nslots_log2, idx_bot=IDX_BOT,
            interpret=self.interpret)
        assert bool(ok[:k].all()), "exact tickets cannot miss"
        return RingState(cyc, saf, enq, idx, st.head, st.tail + k)

    def run(self, initial: np.ndarray, acc: Any = None,
            max_rounds: int = 10_000) -> Tuple[Any, RingState]:
        """Seed the ring with ``initial`` task values, run rounds until the
        ring drains (or max_rounds).  Returns (acc, final ring state)."""
        st = ring_init(self.capacity_log2)
        initial = np.asarray(initial, np.int32)
        for i in range(0, len(initial), self.batch):
            st = self._enq_chunk(st, initial[i:i + self.batch])
        rounds = processed = spawned = 0
        max_occ = st.occupancy
        while st.occupancy > 0 and rounds < max_rounds:
            k = min(self.batch, st.occupancy)
            tickets = np.full(self.batch, -1, np.int32)
            tickets[:k] = st.head + np.arange(k, dtype=np.int32)
            cyc, saf, enq, idx, vals, ok = ring_dequeue(
                st.cycles, st.safes, st.enqs, st.idxs, jnp.asarray(tickets),
                nslots_log2=self.nslots_log2, idx_bot=IDX_BOT,
                interpret=self.interpret)
            assert bool(ok[:k].all()), "exact tickets cannot miss"
            st = RingState(cyc, saf, enq, idx, st.head + k, st.tail)
            acc, cvals, cmask = self.step_fn(acc, vals, ok)
            cv = np.asarray(cvals).reshape(-1)
            cm = np.broadcast_to(np.asarray(cmask).astype(bool),
                                 np.asarray(cvals).shape).reshape(-1)
            children = cv[cm]                      # row-major ⇒ deterministic
            for i in range(0, len(children), self.batch):
                st = self._enq_chunk(st, children[i:i + self.batch])
            rounds += 1
            processed += k
            spawned += len(children)
            max_occ = max(max_occ, st.occupancy)
        self.stats = {"rounds": rounds, "processed": processed,
                      "spawned": spawned, "max_occupancy": max_occ,
                      "drained": int(st.occupancy == 0)}
        return acc, st


# ---------------------------------------------------------------------------
# Priority rounds on the Pallas heap (DESIGN.md § 5.6)
# ---------------------------------------------------------------------------


class HeapState(NamedTuple):
    """Field planes of the device heap plus the host-side size."""
    keys: jax.Array
    vals: jax.Array
    size: int

    @property
    def occupancy(self) -> int:
        return self.size


def heap_init(capacity_log2: int) -> HeapState:
    cap = 1 << capacity_log2
    return HeapState(
        keys=jnp.full((cap,), HEAP_KEY_INF, jnp.int32),
        vals=jnp.full((cap,), -1, jnp.int32),
        size=0,
    )


# PriorityStepFn: (acc, keys (B,), vals (B,), valid (B,))
#   -> (acc, child_keys (B,F), child_vals (B,F), child_mask (B,F))
PriorityStepFn = Callable[
    [Any, jax.Array, jax.Array, jax.Array],
    Tuple[Any, jax.Array, jax.Array, jax.Array]]


class PriorityRoundRunner:
    """``RoundRunner``'s priority twin: drives ``step_fn`` to quiescence
    through the Pallas heap kernel.  One round pops the ``batch`` smallest
    (key, val) pairs (EDF: earliest deadlines), runs the jitted step, and
    inserts the children it emits in row-major order — every kernel batch
    is applied in batch-index order, so the whole run is bit-deterministic
    exactly like the FIFO rounds."""

    def __init__(self, step_fn: PriorityStepFn, *, capacity_log2: int = 10,
                 batch: int = 64, arity_log2: int = 2,
                 interpret: bool = True) -> None:
        self.step_fn = jax.jit(step_fn)
        self.capacity_log2 = capacity_log2
        self.capacity = 1 << capacity_log2
        self.batch = batch
        self.arity_log2 = arity_log2
        self.interpret = interpret
        self.stats: Dict[str, int] = {}

    def _apply(self, st: HeapState, ops: np.ndarray, keys: np.ndarray,
               vals: np.ndarray):
        k, v, size, outk, outv, ok = heap_apply(
            st.keys, st.vals, jnp.asarray(st.size, jnp.int32),
            jnp.asarray(ops), jnp.asarray(keys), jnp.asarray(vals),
            cap_log2=self.capacity_log2, arity_log2=self.arity_log2,
            interpret=self.interpret)
        return HeapState(k, v, int(size)), outk, outv, ok

    def _ins_chunk(self, st: HeapState, ckeys: np.ndarray,
                   cvals: np.ndarray) -> HeapState:
        b, n = self.batch, len(ckeys)
        assert n <= b
        if st.size + n > self.capacity:
            raise RuntimeError(
                f"heap overflow: size {st.size} + {n} children exceeds "
                f"capacity {self.capacity} (raise capacity_log2 or lower "
                f"the fanout)")
        ops = np.full(b, -1, np.int32)
        ops[:n] = 0
        keys = np.full(b, HEAP_KEY_INF, np.int32)
        keys[:n] = ckeys
        vals = np.full(b, -1, np.int32)
        vals[:n] = cvals
        st, _, _, ok = self._apply(st, ops, keys, vals)
        assert bool(ok[:n].all()), "capacity was checked: inserts cannot miss"
        return st

    def run(self, initial_keys: np.ndarray, initial_vals: np.ndarray,
            acc: Any = None, max_rounds: int = 10_000
            ) -> Tuple[Any, HeapState]:
        st = heap_init(self.capacity_log2)
        ik = np.asarray(initial_keys, np.int32)
        iv = np.asarray(initial_vals, np.int32)
        assert ik.shape == iv.shape
        for i in range(0, len(ik), self.batch):
            st = self._ins_chunk(st, ik[i:i + self.batch],
                                 iv[i:i + self.batch])
        rounds = processed = spawned = 0
        max_occ = st.size
        while st.size > 0 and rounds < max_rounds:
            k = min(self.batch, st.size)
            ops = np.full(self.batch, -1, np.int32)
            ops[:k] = 1
            pad = np.full(self.batch, HEAP_KEY_INF, np.int32)
            st, outk, outv, ok = self._apply(st, ops, pad, pad)
            assert bool(ok[:k].all()), "size was checked: pops cannot miss"
            acc, ckeys, cvals, cmask = self.step_fn(acc, outk, outv, ok)
            ck = np.asarray(ckeys).reshape(-1)
            cv = np.asarray(cvals).reshape(-1)
            cm = np.broadcast_to(np.asarray(cmask).astype(bool),
                                 np.asarray(ckeys).shape).reshape(-1)
            children_k, children_v = ck[cm], cv[cm]   # row-major order
            for i in range(0, len(children_k), self.batch):
                st = self._ins_chunk(st, children_k[i:i + self.batch],
                                     children_v[i:i + self.batch])
            rounds += 1
            processed += k
            spawned += len(children_k)
            max_occ = max(max_occ, st.size)
        self.stats = {"rounds": rounds, "processed": processed,
                      "spawned": spawned, "max_occupancy": max_occ,
                      "drained": int(st.size == 0)}
        return acc, st


def mesh_task_round(state, spawn_vals: jax.Array, spawn_mask: jax.Array,
                    claim_mask: jax.Array, axis: str):
    """One mesh-scope task round inside shard_map: publish this chip's
    spawned tasks, then claim up to ``claim_mask.sum()`` tasks for local
    execution.  Returns (state, granted, claimed_vals, claimed_ok).

    Composes ``dist_enqueue_round`` + ``dist_dequeue_round`` — two prefix-sum
    collectives per round, the mesh analogue of a wave's two leader FAAs."""
    state, granted = dist_enqueue_round(state, spawn_vals, spawn_mask, axis)
    state, vals, ok = dist_dequeue_round(state, claim_mask, axis)
    return state, granted, vals, ok
