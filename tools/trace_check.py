"""Telemetry trace validator (CI gate).

Validates the JSONL trace files ``benchmarks/run.py --trace`` emits
against the schema ``repro.obs.export`` declares (the two share
``JSONL_SCHEMA``, so the validator cannot drift from the emitter):

1. the file parses line-by-line as JSON, every line is ``kind``-tagged
   with a known kind, and line 1 is the ``meta`` header carrying a
   ``schema_version`` the validator understands;
2. every line carries its kind's required fields with sane types/shapes
   (per-shard vectors of one consistent width, non-negative counts,
   ``min_key <= max_key`` on non-empty rounds, and **no empty-string
   stand-ins for numeric fields** — absent numbers must be ``null``);
3. round indices are strictly increasing and sync heartbeats are
   monotone in ``rounds`` and ``wall_time``;
4. span-layer lines (schema v2): ``hist`` histograms are ``classes`` ×
   ``buckets`` grids of non-negative ints whose grand total matches
   ``total`` with ordered percentiles, and ``flow`` lifecycles satisfy
   ``birth <= claim``.

Also accepts Chrome trace files (``--chrome``): checks the
``traceEvents`` envelope and the round/counter/sync event phases.

Run: ``python tools/trace_check.py TRACE.jsonl [--chrome TRACE.json]`` —
exits nonzero with a list of violations on failure.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.obs.export import JSONL_SCHEMA, SCHEMA_VERSION  # noqa: E402
from repro.obs.trace import KEY_SENTINEL  # noqa: E402

# fields that are never strings: bench emitters once wrote "" where a
# number was unknown, which silently poisons downstream arithmetic —
# absent numerics must be JSON null (None), so "" is a hard violation
_NUMERIC_FIELDS = {
    "round", "imbalance", "min_key", "max_key", "overflow", "sync",
    "wall_time", "rounds", "host_syncs", "schema_version", "classes",
    "buckets", "total", "p50", "p95", "p99", "birth", "claim", "cls",
    "ref",
}


def _is_count_grid(hist, classes, buckets) -> bool:
    """True when ``hist`` is a ``classes`` × ``buckets`` grid of
    non-negative ints."""
    return (isinstance(hist, list) and len(hist) == classes
            and all(isinstance(row, list) and len(row) == buckets
                    and all(isinstance(x, int) and not isinstance(x, bool)
                            and x >= 0 for x in row)
                    for row in hist))


def check_jsonl(path: str) -> list:
    """Validate one telemetry JSONL file; returns a list of violations."""
    errors = []
    lines = []
    with open(path) as f:
        for i, raw in enumerate(f, 1):
            raw = raw.strip()
            if not raw:
                continue
            try:
                lines.append((i, json.loads(raw)))
            except json.JSONDecodeError as e:
                errors.append(f"{path}:{i}: not JSON: {e}")
    if not lines:
        return errors + [f"{path}: empty trace"]

    # 1. meta header first, known schema version
    _, head = lines[0]
    if head.get("kind") != "meta":
        errors.append(f"{path}:1: first line must be the meta header, "
                      f"got kind={head.get('kind')!r}")
    elif head.get("schema_version") != SCHEMA_VERSION:
        errors.append(f"{path}:1: schema_version "
                      f"{head.get('schema_version')!r} != {SCHEMA_VERSION}")

    # 2. per-kind required fields and shapes
    shard_width = None
    prev_round = None
    prev_sync = None
    for i, d in lines:
        kind = d.get("kind")
        if kind not in JSONL_SCHEMA:
            errors.append(f"{path}:{i}: unknown kind {kind!r}")
            continue
        missing = [k for k in JSONL_SCHEMA[kind] if k not in d]
        if missing:
            errors.append(f"{path}:{i}: {kind} line missing {missing}")
            continue
        empty = [k for k in JSONL_SCHEMA[kind]
                 if k in _NUMERIC_FIELDS and d[k] == ""]
        if empty:
            errors.append(f"{path}:{i}: {kind} line has empty-string "
                          f"stand-ins for numeric fields {empty} "
                          f"(use null)")
            continue
        if kind == "round":
            vecs = {k: d[k] for k in ("pops", "pushes", "occupancy")}
            for name, v in vecs.items():
                if (not isinstance(v, list) or not v
                        or not all(isinstance(x, int) and x >= 0 for x in v)):
                    errors.append(f"{path}:{i}: {name} must be a non-empty "
                                  f"list of ints >= 0, got {v!r}")
            widths = {len(v) for v in vecs.values() if isinstance(v, list)}
            if len(widths) == 1:
                w = widths.pop()
                if shard_width is None:
                    shard_width = w
                elif w != shard_width:
                    errors.append(f"{path}:{i}: shard width {w} != "
                                  f"{shard_width} seen earlier")
            if d["imbalance"] < 0:
                errors.append(f"{path}:{i}: negative imbalance")
            nonempty = d["min_key"] != KEY_SENTINEL
            if nonempty and d["min_key"] > d["max_key"]:
                errors.append(f"{path}:{i}: min_key {d['min_key']} > "
                              f"max_key {d['max_key']} on non-empty round")
            if prev_round is not None and d["round"] <= prev_round:
                errors.append(f"{path}:{i}: round {d['round']} not after "
                              f"{prev_round}")
            prev_round = d["round"]
        elif kind == "sync":
            if prev_sync is not None:
                if d["rounds"] < prev_sync["rounds"]:
                    errors.append(f"{path}:{i}: sync rounds went backwards "
                                  f"({prev_sync['rounds']} -> {d['rounds']})")
                if d["wall_time"] < prev_sync["wall_time"]:
                    errors.append(f"{path}:{i}: sync wall_time went "
                                  f"backwards")
            prev_sync = d
        elif kind == "metrics" and not isinstance(d["metrics"], dict):
            errors.append(f"{path}:{i}: metrics payload must be a dict")
        elif kind == "hist":
            classes, buckets = d["classes"], d["buckets"]
            if not (isinstance(classes, int) and classes > 0
                    and isinstance(buckets, int) and buckets > 0):
                errors.append(f"{path}:{i}: hist classes/buckets must be "
                              f"positive ints, got {classes!r}/{buckets!r}")
                continue
            if (not isinstance(d["bucket_edges"], list)
                    or len(d["bucket_edges"]) != buckets):
                errors.append(f"{path}:{i}: bucket_edges must list "
                              f"{buckets} upper edges")
            if not _is_count_grid(d["hist"], classes, buckets):
                errors.append(f"{path}:{i}: hist must be a {classes}x"
                              f"{buckets} grid of ints >= 0")
                continue
            if not (isinstance(d["max_wait"], list)
                    and len(d["max_wait"]) == classes
                    and all(isinstance(x, int) and x >= 0
                            for x in d["max_wait"])):
                errors.append(f"{path}:{i}: max_wait must be {classes} "
                              f"ints >= 0")
            if d["total"] != sum(sum(row) for row in d["hist"]):
                errors.append(f"{path}:{i}: total {d['total']!r} != sum of "
                              f"hist counts")
            ps = [d[k] for k in ("p50", "p95", "p99")]
            if any(p is not None and not isinstance(p, int) for p in ps):
                errors.append(f"{path}:{i}: percentiles must be ints or "
                              f"null, got {ps!r}")
            else:
                known = [p for p in ps if p is not None]
                if known != sorted(known):
                    errors.append(f"{path}:{i}: percentiles not ordered "
                                  f"(p50 <= p95 <= p99): {ps!r}")
        elif kind == "flow":
            bad = [k for k in ("birth", "claim", "cls", "ref")
                   if not isinstance(d[k], int) or d[k] < 0]
            if bad:
                errors.append(f"{path}:{i}: flow fields {bad} must be "
                              f"ints >= 0")
            elif d["birth"] > d["claim"]:
                errors.append(f"{path}:{i}: flow birth {d['birth']} > "
                              f"claim {d['claim']}")
    return errors


def check_chrome(path: str) -> list:
    """Validate a Chrome trace-event file's envelope and phases."""
    errors = []
    try:
        with open(path) as f:
            trace = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        return [f"{path}: unreadable: {e}"]
    ev = trace.get("traceEvents")
    if not isinstance(ev, list) or not ev:
        return [f"{path}: no traceEvents"]
    meta = trace.get("metadata", {})
    if meta.get("schema_version") != SCHEMA_VERSION:
        errors.append(f"{path}: metadata.schema_version "
                      f"{meta.get('schema_version')!r} != {SCHEMA_VERSION}")
    phases = {e.get("ph") for e in ev}
    for need in ("X", "C"):
        if need not in phases:
            errors.append(f"{path}: no {need!r}-phase events (rounds / "
                          f"counters missing)")
    flow_ids = {"s": set(), "f": set()}
    for i, e in enumerate(ev):
        if "ph" not in e or "pid" not in e:
            errors.append(f"{path}: event {i} missing ph/pid")
        if e.get("ph") in ("X", "C", "i", "s", "f") and "ts" not in e:
            errors.append(f"{path}: event {i} ({e.get('ph')}) missing ts")
        ph = e.get("ph")
        if ph in ("s", "f"):
            if "id" not in e:
                errors.append(f"{path}: event {i} ({ph}) missing flow id")
            else:
                flow_ids[ph].add(e["id"])
            if ph == "f" and e.get("bp") != "e":
                errors.append(f"{path}: event {i} (f) missing bp='e' "
                              f"(flow end must bind to enclosing slice)")
    if flow_ids["s"] != flow_ids["f"]:
        errors.append(f"{path}: unpaired flow ids "
                      f"(s-only {sorted(flow_ids['s'] - flow_ids['f'])}, "
                      f"f-only {sorted(flow_ids['f'] - flow_ids['s'])})")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("jsonl", nargs="*", help="telemetry JSONL file(s)")
    ap.add_argument("--chrome", action="append", default=[],
                    help="Chrome trace-event file(s)")
    args = ap.parse_args(argv)
    if not args.jsonl and not args.chrome:
        ap.error("nothing to check")
    errors = []
    for p in args.jsonl:
        errors += check_jsonl(p)
    for p in args.chrome:
        errors += check_chrome(p)
    for e in errors:
        print(e, file=sys.stderr)
    ok = not errors
    print(f"trace_check: {'OK' if ok else 'FAIL'} "
          f"({len(args.jsonl)} jsonl, {len(args.chrome)} chrome)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
