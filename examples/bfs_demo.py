"""Queue-driven level-synchronous BFS (paper § V-B-a) vs the dense-sweep
baseline, on a road-like and a power-law graph.

    PYTHONPATH=src python examples/bfs_demo.py
"""

import time

import numpy as np

from repro.apps.bfs import bfs_baseline, bfs_queue, bfs_reference, kron_like, road_like

for g in (road_like(4096), kron_like(4096, 16)):
    ref = bfs_reference(g)
    t0 = time.perf_counter(); dq, m = bfs_queue(g, use_kernel=False); tq = time.perf_counter() - t0
    t0 = time.perf_counter(); db, _ = bfs_baseline(g); tb = time.perf_counter() - t0
    assert (dq == ref).all() and (db == ref).all()
    print(f"{g.name:12s} n={g.n} m={g.m} levels={m['levels']:3d} "
          f"queue={tq*1e3:7.1f}ms  baseline={tb*1e3:7.1f}ms  (both correct)")
