"""Priority-linearizability checking for concurrent histories (§ IV machinery
extended to priority semantics, DESIGN.md § 5.3).

History format: ``HistoryEvent`` rows as logged by the scheduler, with

* op 0 (INS)    — ``arg = (key, ident)``, ``ret = True`` on success
                  (failed/FULL inserts are dropped, like FULL enqueues);
* op 1 (DELMIN) — ``ret = (key, ident)``, ``None`` for EMPTY, or ``False``
                  for an abandoned attempt (dropped: it claims nothing).

``ident`` values are globally unique (the § IV-b differentiated-history
token scheme); keys may repeat.

``check_p_linearizable(history, k)`` — the production checker: a
**bad-pattern necessary-condition check** for k-relaxed priority
linearizability ("delete-min returns a key within the k+1 smallest pending
keys at some instant of its interval").  Patterns:

  Q1  an ident deleted but never inserted, inserted twice, or deleted
      twice; or a delete's key disagreeing with its insert's key;
  Q2  delmin(x) returns before ins(x) is invoked;
  Q3  rank violation: for some delmin returning key v, every instant of
      its interval has more than k *provably pending* elements with key
      strictly below v (provably pending at t: the insert returned before
      t and no delete of that ident was invoked by t);
  Q4  a delmin → EMPTY whose whole interval is covered by provably
      pending elements (the priority P5).

Provably-pending undercounts what any real linearization must keep
pending, so a Q3/Q4 hit refutes every linearization: the check is sound.
It is not complete (k-relaxed membership is a search problem — exact
checking generalizes Gibbons–Korach); ``check_p_linearizable_search`` is
the exact Wing–Gong oracle for small histories, and the test suite
cross-validates the two on positive and negative fixtures.

Q3/Q4 run in O(n log n): delmins sorted by returned key share one
min-coverage segment tree over compressed event times, elements entering
as the key threshold passes them.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from ..core.linearizability import CheckResult
from ..core.sim import HistoryEvent
from .gpq import DELMIN, INS

_INF = 1 << 62


class _MinSegTree:
    """Range-add / range-min with lazy propagation over m leaves."""

    def __init__(self, m: int) -> None:
        self.n = 1
        while self.n < max(m, 1):
            self.n *= 2
        self.mn = [0] * (2 * self.n)
        self.lz = [0] * (2 * self.n)

    def _push(self, x: int) -> None:
        if self.lz[x]:
            for c in (2 * x, 2 * x + 1):
                self.lz[c] += self.lz[x]
                self.mn[c] += self.lz[x]
            self.lz[x] = 0

    def add(self, lo: int, hi: int, v: int, x: int = 1, l: int = 0,
            r: Optional[int] = None) -> None:
        """Add v on [lo, hi] inclusive."""
        if r is None:
            r = self.n - 1
        if hi < l or r < lo or lo > hi:
            return
        if lo <= l and r <= hi:
            self.mn[x] += v
            self.lz[x] += v
            return
        self._push(x)
        mid = (l + r) // 2
        self.add(lo, hi, v, 2 * x, l, mid)
        self.add(lo, hi, v, 2 * x + 1, mid + 1, r)
        self.mn[x] = min(self.mn[2 * x], self.mn[2 * x + 1])

    def query(self, lo: int, hi: int, x: int = 1, l: int = 0,
              r: Optional[int] = None) -> int:
        if r is None:
            r = self.n - 1
        if hi < l or r < lo or lo > hi:
            return _INF
        if lo <= l and r <= hi:
            return self.mn[x]
        self._push(x)
        mid = (l + r) // 2
        return min(self.query(lo, hi, 2 * x, l, mid),
                   self.query(lo, hi, 2 * x + 1, mid + 1, r))


def _prepare(history: Sequence[HistoryEvent]) -> List[HistoryEvent]:
    ops = []
    for ev in history:
        if ev.op == INS and ev.ret is not True:
            continue                      # failed/FULL insert: no effect
        if ev.op == DELMIN and ev.ret is False:
            continue                      # abandoned attempt: claims nothing
        ops.append(ev)
    ops.sort(key=lambda e: (e.call, e.end))
    return ops


def check_p_linearizable(history: Sequence[HistoryEvent],
                         k: int = 0) -> CheckResult:
    ops = _prepare(history)
    ins: Dict[int, HistoryEvent] = {}
    dels: Dict[int, HistoryEvent] = {}
    keys: Dict[int, int] = {}
    empties: List[HistoryEvent] = []
    for ev in ops:
        if ev.op == INS:
            key, ident = ev.arg
            if ident in ins:
                return CheckResult(False, f"Q1: ident {ident} inserted twice")
            ins[ident] = ev
            keys[ident] = key
        else:
            if ev.ret is None:
                empties.append(ev)
                continue
            key, ident = ev.ret
            if ident in dels:
                return CheckResult(False, f"Q1: ident {ident} deleted twice")
            dels[ident] = ev
    for ident, d in dels.items():
        if ident not in ins:
            return CheckResult(
                False, f"Q1: ident {ident} deleted, never inserted")
        if keys[ident] != d.ret[0]:
            return CheckResult(
                False, f"Q1: ident {ident} deleted with key {d.ret[0]}, "
                       f"inserted with {keys[ident]}")
        if d.end < ins[ident].call:
            return CheckResult(
                False, f"Q2: delmin({ident}) returned before its insert began")

    # Q3/Q4 — min-coverage over provably-pending intervals.  An element is
    # provably pending on the open interval (ins.end, del.call) — or
    # (ins.end, ∞) if never deleted.  Compress all event times.
    coords = sorted({t for ev in ops for t in (ev.call, ev.end)} | {_INF})
    pos = {t: i for i, t in enumerate(coords)}
    tree = _MinSegTree(len(coords))

    elements = sorted(
        ((keys[ident], ident) for ident in ins), key=lambda p: p[0])
    queries = sorted(
        ((d.ret[0], d) for d in dels.values()), key=lambda p: p[0])

    def interval(ident: int) -> Tuple[int, int]:
        lo = ins[ident].end
        hi = dels[ident].call if ident in dels else _INF
        return lo, hi

    ei = 0
    for v, d in queries:
        while ei < len(elements) and elements[ei][0] < v:
            lo, hi = interval(elements[ei][1])
            # open interval (lo, hi) over discrete distinct event times:
            # covered leaves are those strictly inside.
            a, b = pos[lo] + 1, pos[hi] - 1
            tree.add(a, b, 1)
            ei += 1
        mn = tree.query(pos[d.call], pos[d.end])
        if mn > k:
            _, ident = d.ret
            return CheckResult(
                False,
                f"Q3: delmin returned key {v} (ident {ident}) but every "
                f"instant of [{d.call},{d.end}] has > {k} smaller pending "
                f"keys (min coverage {mn})")
    while ei < len(elements):
        lo, hi = interval(elements[ei][1])
        tree.add(pos[lo] + 1, pos[hi] - 1, 1)
        ei += 1
    for d in empties:
        mn = tree.query(pos[d.call], pos[d.end])
        if mn > 0:
            return CheckResult(
                False,
                f"Q4: EMPTY delmin by proc {d.proc} at [{d.call},{d.end}] "
                f"overlaps no empty instant (min coverage {mn})")
    return CheckResult(
        True, f"priority-linearizable up to relaxation {k} (pattern check)")


def mesh_trace_history(trace, seeds) -> List[HistoryEvent]:
    """Convert a ``PriorityMeshRoundRunner(trace=True)`` recording into a
    checkable history.  ``seeds`` is the run's initial ``[(key, ident)]``
    list; ``trace`` is the runner's per-round list of ``{"pops": (keys
    (S,B), vals (S,B), ok (S,B)), "pushes": (gkeys, gvals, active)}``.

    Timing reflects the engine's linearization structure: rounds are
    totally ordered by the collective schedule; within a round every
    shard's pops share ONE interval (they are concurrent — no
    linearization is forced to keep a same-round sibling pop pending),
    and the publish wave's inserts follow in a later interval of the same
    round.  ``ident`` = the payload word, so payloads must be unique
    across the run (use a spawn-tree workload, not a workload that can
    re-publish a payload).  Feed the result to ``check_p_linearizable``
    with ``k = relaxed.mesh_relaxation_bound(...)``."""
    h: List[HistoryEvent] = []
    for key, ident in seeds:
        h.append(HistoryEvent(proc=0, op=INS, arg=(int(key), int(ident)),
                              ret=True, call=0, end=1))
    for r, rec in enumerate(trace):
        t = 4 * r + 4
        pk, pv, ok = rec["pops"]
        for s in range(pk.shape[0]):
            for lane in range(pk.shape[1]):
                if ok[s, lane]:
                    h.append(HistoryEvent(
                        proc=s, op=DELMIN, arg=None,
                        ret=(int(pk[s, lane]), int(pv[s, lane])),
                        call=t, end=t + 1))
        gk, gv, ga = rec["pushes"]
        for i in range(len(gk)):
            if ga[i]:
                h.append(HistoryEvent(proc=0, op=INS,
                                      arg=(int(gk[i]), int(gv[i])),
                                      ret=True, call=t + 2, end=t + 3))
    return h


# ---------------------------------------------------------------------------
# Exact Wing–Gong search against the k-relaxed priority-queue spec
# (independent oracle for small histories)
# ---------------------------------------------------------------------------


def check_p_linearizable_search(history: Sequence[HistoryEvent], k: int = 0,
                                max_nodes: int = 500_000) -> CheckResult:
    ops = _prepare(history)
    n = len(ops)
    if n == 0:
        return CheckResult(True, "empty history")
    calls = [op.call for op in ops]
    ends = [op.end for op in ops]
    nodes = 0
    seen = set()
    stack: List[Tuple[int, frozenset]] = [(0, frozenset())]
    full_mask = (1 << n) - 1
    while stack:
        mask, pend = stack.pop()
        if mask == full_mask:
            return CheckResult(True, "p-linearizable (search)", nodes)
        key_state = (mask, pend)
        if key_state in seen:
            continue
        seen.add(key_state)
        nodes += 1
        if nodes > max_nodes:
            return CheckResult(False, f"search budget exceeded ({nodes})",
                               nodes)
        min_end = min(ends[i] for i in range(n) if not (mask >> i) & 1)
        for i in range(n):
            if (mask >> i) & 1 or calls[i] > min_end:
                continue
            op = ops[i]
            if op.op == INS:
                stack.append((mask | (1 << i), pend | {op.arg}))
            elif op.ret is None:
                if not pend:
                    stack.append((mask | (1 << i), pend))
            else:
                item = (op.ret[0], op.ret[1])
                if item in pend:
                    # k-relaxed: at most k pending keys strictly below
                    rank = sum(1 for (kk, _) in pend if kk < item[0])
                    if rank <= k:
                        stack.append((mask | (1 << i), pend - {item}))
    return CheckResult(False, "no valid k-relaxed linearization found", nodes)
