"""Task-runtime invariants (DESIGN.md § 4):

* every spawned task executes exactly once — no loss, no duplication —
  under random/gang/rr interleaving, with and without stealing, for all
  four queue algorithms;
* every (lane, shard) ring history is independently linearizable
  (``check_linearizable``), since shards are plain bounded FIFO rings;
* priority lanes actually pre-empt: urgent tasks finish ahead of normal
  ones under a single-consumer drain;
* the JAX round face is bit-deterministic across reruns and processes each
  seeded/spawned value exactly once;
* the mesh-scope round (``mesh_task_round``) grants and claims FIFO at a
  single-device mesh;
* the rewired apps agree with their references;
* the bench_runtime acceptance comparison holds: ≥32 workers under
  power-law costs, sharded+stealing beats the single shared queue on
  throughput and idle-steps.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

from repro.core import QUEUE_CLASSES, check_linearizable
from repro.runtime import (ExecutorConfig, RoundRunner, TaskFabric,
                           TaskRuntime, TaskSpec)

ALGOS = list(QUEUE_CLASSES)


def _tree_runtime(algo, policy, *, steal=True, workers=8, shards=2,
                  depth=4, roots=2, seed=0):
    """Binary-tree spawn workload: roots at depth d, every task spawns two
    children until depth 0 — total roots·(2^(d+1)−1) tasks."""
    def handler(rec):
        d = rec.payload
        if d <= 0:
            return []
        return [TaskSpec(d - 1, cost=1, priority=1),
                TaskSpec(d - 1, cost=1, priority=1)]

    fabric = TaskFabric(algo=algo, shards=shards, capacity_per_shard=128,
                        num_threads=workers + 1, steal=steal)
    rt = TaskRuntime(fabric, handler,
                     ExecutorConfig(workers=workers, policy=policy, seed=seed))
    for _ in range(roots):
        rt.add_task(depth, cost=1)
    metrics = rt.run()
    total = roots * (2 ** (depth + 1) - 1)
    return rt, fabric, metrics, total


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("policy", ["random", "gang", "rr"])
def test_exactly_once_and_linearizable(algo, policy):
    rt, fabric, metrics, total = _tree_runtime(algo, policy, seed=7)
    assert metrics["completed"] == 1.0, "runtime did not reach quiescence"
    ids = [t for t, _ in rt.executed]
    assert len(ids) == total, f"lost tasks: {len(ids)}/{total}"
    assert len(set(ids)) == len(ids), "a task executed twice"
    for key, hist in fabric.shard_history.items():
        res = check_linearizable(hist)
        assert res.ok, f"shard {key} history not linearizable: {res.reason}"


@pytest.mark.parametrize("algo", ["glfq", "sfq"])
def test_exactly_once_without_stealing(algo):
    rt, fabric, metrics, total = _tree_runtime(algo, "random", steal=False,
                                               seed=3)
    assert metrics["completed"] == 1.0
    ids = [t for t, _ in rt.executed]
    assert len(ids) == total and len(set(ids)) == len(ids)
    assert metrics["steals"] == 0


def test_stealing_engages_under_affinity_skew():
    """All arrivals pinned to one shard: workers homed elsewhere must steal
    (and without stealing those tasks would be unreachable for them)."""
    fabric = TaskFabric(algo="glfq", shards=2, capacity_per_shard=128,
                        num_threads=17, steal=True)
    rt = TaskRuntime(fabric, lambda rec: [],
                     ExecutorConfig(workers=16, policy="gang", seed=0))
    for i in range(64):
        rt.add_task(i, cost=4, affinity=0)
    m = rt.run()
    assert m["completed"] == 1.0
    assert m["steals"] > 0
    assert m["steal_rate"] > 0.02


def test_priority_lane_preempts():
    """Single consumer stuck in a long warmup task while both lanes fill:
    on resume it must drain the entire urgent lane first."""
    fabric = TaskFabric(algo="glfq", shards=1, capacity_per_shard=128,
                        num_threads=2, steal=False)
    rt = TaskRuntime(fabric, lambda rec: [],
                     ExecutorConfig(workers=1, policy="rr", seed=0))
    rt.add_task(("warmup", 0), priority=0, cost=2000)
    for i in range(12):
        rt.add_task(("lo", i), priority=1, cost=1)
    for i in range(12):
        rt.add_task(("hi", i), priority=0, cost=1)
    m = rt.run()
    assert m["completed"] == 1.0
    order = [fabric.tasks[t].payload[0] for t, _ in rt.executed
             if fabric.tasks[t].payload[0] != "warmup"]
    assert order[:12] == ["hi"] * 12, order


def test_executor_metrics_shape():
    _, _, m, _ = _tree_runtime("gwfq", "gang", seed=1)
    for key in ("throughput_ops_per_kstep", "idle_steps", "steal_rate",
                "load_imbalance", "worker_imbalance", "tasks_executed",
                "steps_per_op", "stall_steps_per_op"):
        assert key in m, key
    assert m["tasks_executed"] > 0
    assert m["idle_steps"] >= 0


# -- JAX face ----------------------------------------------------------------


def _tree_step():
    import jax.numpy as jnp

    def step(acc, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(
            valid.astype(jnp.int32))
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        cm = (valid & (vals < 8))[:, None]
        return acc, cv, cm
    return step


def test_rounds_exactly_once_and_deterministic():
    jnp = pytest.importorskip("jax.numpy")
    runner = RoundRunner(_tree_step(), capacity_log2=8, batch=16)
    acc, st = runner.run([1], acc=jnp.zeros(32, jnp.int32))
    counts = np.asarray(acc)
    # tasks 1..15 processed exactly once each
    assert counts[1:16].tolist() == [1] * 15
    assert counts[16:].sum() == 0 and counts[0] == 0
    assert runner.stats["drained"] == 1
    assert runner.stats["processed"] == 15
    # bit-determinism across reruns (fresh runner, same inputs)
    runner2 = RoundRunner(_tree_step(), capacity_log2=8, batch=16)
    acc2, st2 = runner2.run([1], acc=jnp.zeros(32, jnp.int32))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(acc2))
    for a, b in zip(st[:4], st2[:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (st.head, st.tail) == (st2.head, st2.tail)
    assert runner.stats == runner2.stats


def test_mesh_task_round_single_device():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.distqueue import dist_queue_init
    from repro.jaxcompat import make_mesh
    from repro.runtime import mesh_task_round

    mesh = make_mesh((1,), ("data",))

    def inner(state, values, emask, want):
        return mesh_task_round(state, values, emask, want, "data")

    # replication checker ON: the psum-gathered rounds keep the replicated
    # planes replicated-typed (no check_rep=False escape hatch)
    f = jax.jit(shard_map(inner, mesh=mesh,
                          in_specs=(P(), P("data"), P("data"), P("data")),
                          out_specs=(P(), P("data"), P("data"), P("data"))))
    state = dist_queue_init(16)
    vals = jnp.asarray([11, 12, 13, 14], jnp.int32)
    ones = jnp.ones(4, jnp.int32)
    state, granted, got, ok = f(state, vals, ones, ones)
    assert bool(granted.all()) and bool(ok.all())
    np.testing.assert_array_equal(np.asarray(got), np.asarray(vals))  # FIFO


# -- rewired consumers --------------------------------------------------------


def test_bfs_runtime_matches_reference():
    from repro.apps import bfs
    g = bfs.kron_like(200, avg_deg=6, seed=2)
    ref = bfs.bfs_reference(g, 0)
    for algo in ("glfq", "sfq"):
        dist, info = bfs.bfs_runtime(g, 0, algo=algo, shards=2, workers=8,
                                     policy="random", seed=5)
        np.testing.assert_array_equal(dist, ref)
        assert info["tasks"] >= int((ref >= 0).sum()) - 1


def test_render_runtime_matches_queue():
    from repro.apps import raytrace
    scene = raytrace.cornell_scene()
    img_q, _ = raytrace.render_queue(scene, w=16, h=16)
    img_r, info = raytrace.render_runtime(scene, w=16, h=16, workers=4,
                                          shards=2, seed=1)
    np.testing.assert_allclose(img_r, img_q, rtol=1e-5, atol=1e-5)
    assert info["rays"] > 0 and info["tasks"] > 0


def test_engine_priority_admission():
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.engine import EngineConfig, Request, ServingEngine
    cfg = get_config("h2o-danube-1.8b").reduced()
    eng = ServingEngine(cfg, init_params(cfg),
                        EngineConfig(max_slots=1, page_size=16, num_pages=8,
                                     max_seq=64))
    rng = np.random.default_rng(0)

    def req(rid, pri):
        return Request(rid=rid,
                       prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                       max_new_tokens=2, priority=pri)

    for rid in range(4):
        assert eng.submit(req(rid, 1))
    for rid in (100, 101):
        assert eng.submit(req(rid, 0))
    m = eng.run(max_ticks=400)
    assert m["completed"] == 6
    # urgent lane admitted first despite arriving last (single slot)
    assert set(eng.admission_log[:2]) == {100, 101}, eng.admission_log


# -- bench acceptance ---------------------------------------------------------


def test_bench_runtime_acceptance_powerlaw():
    """≥32 sim workers, power-law task costs: sharded+stealing strictly
    beats the single shared queue on throughput and idle-steps."""
    from benchmarks.bench_runtime import run_scenario
    single = run_scenario("powerlaw", "glfq", "single", 1, False,
                          workers=32, n_tasks=96)
    fabric = run_scenario("powerlaw", "glfq", "sharded+steal", 4, True,
                          workers=32, n_tasks=96)
    assert fabric["throughput_ops_per_kstep"] > single["throughput_ops_per_kstep"]
    assert fabric["idle_steps"] < single["idle_steps"]
