"""repro.runtime — the queue-backed task-parallel execution engine
(DESIGN.md § 4).

Two faces over the same queue core:

* **sim face** — ``TaskFabric`` (sharded MPMC rings, wave-affinity
  placement, work stealing, priority lanes) driven by ``TaskRuntime``
  persistent workers under the adversarial interleaving scheduler;
* **JAX face** — ``RoundRunner`` / ``PriorityRoundRunner`` (deterministic
  rounds over the Pallas ring/heap, running on the fused device-resident
  megaround engine ``fusedrounds.FusedRounds`` by default with host sync
  only at quiescence) and ``mesh_task_round`` (the same round at mesh
  scope on ``core.distqueue``).
"""

from .executor import Arrival, ExecutorConfig, Handler, TaskRuntime
from .fusedrounds import FusedPriorityRounds, FusedRounds
from .meshrounds import FusedMeshRounds, MeshRoundRunner
from .rounds import (HeapState, PriorityRoundRunner, RingState, RoundRunner,
                     heap_init, mesh_task_round, ring_init)
from .taskpool import (FabricMetrics, HostTaskPool, PriorityFabric,
                       TaskFabric, TaskRecord, TaskSpec)

__all__ = [
    "Arrival", "ExecutorConfig", "FabricMetrics", "FusedMeshRounds",
    "FusedPriorityRounds", "FusedRounds", "Handler", "HostTaskPool",
    "HeapState", "MeshRoundRunner", "PriorityFabric", "PriorityRoundRunner",
    "RingState", "RoundRunner", "TaskFabric", "TaskRecord", "TaskSpec",
    "TaskRuntime", "heap_init", "mesh_task_round", "ring_init",
]
