"""Batched bounded-ring slot operations as Pallas TPU kernels.

These kernels apply a *wave* of fast-path queue operations (paper Alg. 1) to
the ring state in one invocation.  The ring's packed 64-bit entry word is
represented as four parallel int32 field planes (cycle / safe / enq / idx) —
TPU-native layout: 32-bit lanes, single-writer-per-slot semantics guaranteed
by ticket uniqueness (Lemma III.1).

Exact tickets within a batch hit pairwise-distinct slots (any wave spans
< 2n tickets), so the batch needs no serial ordering at all: both kernels
are a single gather → predicate → masked scatter over the field planes,
vectorized across the whole wave.  Lanes whose predicate fails (and inactive
``ticket == -1`` lanes) are routed to an out-of-range index and dropped, so
only installing/consuming lanes touch the planes.  The same vectorized
plane updates are exposed as pure-jnp functions (``enq_planes`` /
``deq_planes``) so the fused round engine can inline them into a jitted
``while_loop`` without a host round-trip.

VMEM budget: the whole ring (4 × 2n × 4 B) plus the op batch live in VMEM;
for n ≤ 64Ki that is ≤ 2 MiB — comfortably inside the 16 MiB/core budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_env import resolve_interpret


def ticket_cycle(tickets, nslots_log2: int):
    """A ticket's ring cycle, wrap-safe: tickets are unsigned mod-2^32
    counters carried in int32, so the cycle is the *logical* right shift
    (an arithmetic shift would smear the sign bit over wrapped tickets)."""
    return jax.lax.shift_right_logical(tickets, nslots_log2)


def cycle_lt(a, b, nslots_log2: int):
    """Wrap-safe cycle comparison a < b (wCQ-style bounded-cycle
    arithmetic).  Cycles live mod 2^(32-log2(2n)), so the wraparound
    difference is computed in *cycle-modulus* space: shift it back into
    ticket space and read the int32 sign.  Valid while live cycles stay
    within half the cycle modulus of each other — guaranteed because a
    ring holds at most two live cycles at once (Lemma III.2)."""
    return ((b - a) << nslots_log2) > 0


def enq_planes(cycles, safes, enqs, idxs, tickets, values, head, *,
               nslots_log2: int, idx_bot: int, active=None):
    """Vectorized TRYENQ install wave over the (2n,) field planes.

    ``tickets``/``values`` are (B,) int32; active tickets must hit
    pairwise-distinct slots (Lemma III.1 — true for any ticket wave
    spanning < 2n).  ``active`` masks live lanes; when ``None`` it defaults
    to ``tickets >= 0`` (the -1-sentinel convention of the chip-level
    engine).  Callers whose tickets may wrap past 2^31 (the mesh queue)
    must pass ``active`` explicitly — all ticket comparisons here are
    wraparound-difference based, so wrapped (negative) tickets behave
    correctly.  ``head`` is a scalar.  One gather per plane, one masked
    scatter per plane — no serial loop.  Returns
    (cycles, safes, enqs, idxs, ok)."""
    nslots = 1 << nslots_log2
    idx_botc = idx_bot - 1
    if active is None:
        active = tickets >= 0
    j = jnp.where(active, tickets & (nslots - 1), 0)
    c = jnp.where(active, ticket_cycle(tickets, nslots_log2), 0)
    e_c, e_s, e_i = cycles[j], safes[j], idxs[j]
    empty = (e_i == idx_bot) | (e_i == idx_botc)
    can = active & cycle_lt(e_c, c, nslots_log2) & empty & (
        (e_s == 1) | ((tickets - head) >= 0))
    w = jnp.where(can, j, nslots)          # failed lanes scatter out of range
    cycles = cycles.at[w].set(c, mode="drop")
    safes = safes.at[w].set(1, mode="drop")
    enqs = enqs.at[w].set(1, mode="drop")
    idxs = idxs.at[w].set(values, mode="drop")
    return cycles, safes, enqs, idxs, can.astype(jnp.int32)


def deq_planes(cycles, safes, enqs, idxs, tickets, *,
               nslots_log2: int, idx_bot: int, active=None):
    """Vectorized TRYDEQ consume wave (same distinct-slot precondition and
    wrap-safe comparisons as ``enq_planes``).
    Returns (cycles, safes, enqs, idxs, values, ok)."""
    nslots = 1 << nslots_log2
    idx_botc = idx_bot - 1
    if active is None:
        active = tickets >= 0
    j = jnp.where(active, tickets & (nslots - 1), 0)
    c = jnp.where(active, ticket_cycle(tickets, nslots_log2), 0)
    e_c, e_s, e_e, e_i = cycles[j], safes[j], enqs[j], idxs[j]
    empty = (e_i == idx_bot) | (e_i == idx_botc)
    hit = active & (e_c == c) & (~empty) & (e_e == 1)
    idxs = idxs.at[jnp.where(hit, j, nslots)].set(idx_botc, mode="drop")
    adv = active & (~hit) & empty & cycle_lt(e_c, c, nslots_log2)
    cycles = cycles.at[jnp.where(adv, j, nslots)].set(c, mode="drop")
    uns = active & (~hit) & (~empty) & cycle_lt(e_c, c, nslots_log2)
    safes = safes.at[jnp.where(uns, j, nslots)].set(0, mode="drop")
    vals = jnp.where(hit, e_i, -1)
    return cycles, safes, enqs, idxs, vals, hit.astype(jnp.int32)


def _enq_kernel(nslots_log2, idx_bot, head_ref, tickets_ref, values_ref,
                cyc_in, saf_in, enq_in, idx_in,
                cyc_ref, saf_ref, enq_ref, idx_ref, ok_ref):
    cyc, saf, enq, idx, ok = enq_planes(
        cyc_in[...][0], saf_in[...][0], enq_in[...][0], idx_in[...][0],
        tickets_ref[...][0], values_ref[...][0], head_ref[0],
        nslots_log2=nslots_log2, idx_bot=idx_bot)
    cyc_ref[...] = cyc[None]
    saf_ref[...] = saf[None]
    enq_ref[...] = enq[None]
    idx_ref[...] = idx[None]
    ok_ref[...] = ok[None]


def _deq_kernel(nslots_log2, idx_bot, tickets_ref,
                cyc_in, saf_in, enq_in, idx_in,
                cyc_ref, saf_ref, enq_ref, idx_ref, val_ref, ok_ref):
    cyc, saf, enq, idx, vals, ok = deq_planes(
        cyc_in[...][0], saf_in[...][0], enq_in[...][0], idx_in[...][0],
        tickets_ref[...][0], nslots_log2=nslots_log2, idx_bot=idx_bot)
    cyc_ref[...] = cyc[None]
    saf_ref[...] = saf[None]
    enq_ref[...] = enq[None]
    idx_ref[...] = idx[None]
    val_ref[...] = vals[None]
    ok_ref[...] = ok[None]


@functools.partial(jax.jit,
                   static_argnames=("nslots_log2", "idx_bot", "interpret"))
def _ring_enqueue_jit(cycles, safes, enqs, idxs, tickets, values, head, *,
                      nslots_log2: int, idx_bot: int, interpret: bool):
    nslots = 1 << nslots_log2
    b = tickets.shape[0]
    kern = functools.partial(_enq_kernel, nslots_log2, idx_bot)
    call = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
        ] + [pl.BlockSpec((1, nslots), lambda i: (0, 0))] * 4,
        out_specs=[pl.BlockSpec((1, nslots), lambda i: (0, 0))] * 4
        + [pl.BlockSpec((1, b), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, nslots), jnp.int32)] * 4
        + [jax.ShapeDtypeStruct((1, b), jnp.int32)],
        interpret=interpret,
    )
    with jax.named_scope("repro.ring_enqueue"):
        outs = call(head.reshape(1), tickets.reshape(1, b),
                    values.reshape(1, b),
                    cycles.reshape(1, nslots), safes.reshape(1, nslots),
                    enqs.reshape(1, nslots), idxs.reshape(1, nslots))
    cyc, saf, enq, idx, ok = outs
    return (cyc.reshape(nslots), saf.reshape(nslots), enq.reshape(nslots),
            idx.reshape(nslots), ok.reshape(b).astype(bool))


def ring_enqueue(cycles, safes, enqs, idxs, tickets, values, head, *,
                 nslots_log2: int, idx_bot: int, interpret=None):
    """Apply a wave of TRYENQ installs (one masked scatter).  All field
    arrays are (2n,) int32; tickets/values are (B,) int32 (ticket -1 =
    inactive).  ``interpret=None`` resolves via REPRO_PALLAS_INTERPRET /
    backend.  Returns (cycles, safes, enqs, idxs, ok)."""
    return _ring_enqueue_jit(cycles, safes, enqs, idxs, tickets, values,
                             head, nslots_log2=nslots_log2, idx_bot=idx_bot,
                             interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("nslots_log2", "idx_bot", "interpret"))
def _ring_dequeue_jit(cycles, safes, enqs, idxs, tickets, *,
                      nslots_log2: int, idx_bot: int, interpret: bool):
    nslots = 1 << nslots_log2
    b = tickets.shape[0]
    kern = functools.partial(_deq_kernel, nslots_log2, idx_bot)
    call = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, b), lambda i: (0, 0))]
        + [pl.BlockSpec((1, nslots), lambda i: (0, 0))] * 4,
        out_specs=[pl.BlockSpec((1, nslots), lambda i: (0, 0))] * 4
        + [pl.BlockSpec((1, b), lambda i: (0, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((1, nslots), jnp.int32)] * 4
        + [jax.ShapeDtypeStruct((1, b), jnp.int32)] * 2,
        interpret=interpret,
    )
    with jax.named_scope("repro.ring_dequeue"):
        outs = call(tickets.reshape(1, b),
                    cycles.reshape(1, nslots), safes.reshape(1, nslots),
                    enqs.reshape(1, nslots), idxs.reshape(1, nslots))
    cyc, saf, enq, idx, val, ok = outs
    return (cyc.reshape(nslots), saf.reshape(nslots), enq.reshape(nslots),
            idx.reshape(nslots), val.reshape(b), ok.reshape(b).astype(bool))


def ring_dequeue(cycles, safes, enqs, idxs, tickets, *,
                 nslots_log2: int, idx_bot: int, interpret=None):
    """Apply a wave of TRYDEQ consumes (one masked scatter).  Returns
    (cycles, safes, enqs, idxs, values, ok)."""
    return _ring_dequeue_jit(cycles, safes, enqs, idxs, tickets,
                             nslots_log2=nslots_log2, idx_bot=idx_bot,
                             interpret=resolve_interpret(interpret))
