"""Continuous-batching serving demo: request queue + KV page ring.

    PYTHONPATH=src python examples/serve_lm.py
"""

import subprocess
import sys

subprocess.run([sys.executable, "-m", "repro.launch.serve",
                "--arch", "h2o-danube-1.8b", "--requests", "8"],
               check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
