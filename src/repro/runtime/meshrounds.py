"""Mesh-fused round engine (DESIGN.md § 2.3): ``FusedRounds``' twin one
level up the hierarchy, running the whole dequeue → step → ticket →
enqueue cycle *device-resident under shard_map*.

PR 3 removed the per-round host sync at chip scope; this module removes it
at mesh scope.  The legacy mesh path (`fused=False`, the ``mesh_task_round``
discipline) dispatches one jitted shard_map call per round and reads
occupancy back on the host every time; ``FusedMeshRounds`` runs up to
``limit`` rounds inside ONE ``lax.while_loop`` *inside* shard_map:

* the distqueue's replicated field planes, head and tail ride in the loop
  carry as device values;
* the claim wave needs NO collective — the cross-shard rebalancing
  schedule (``distqueue.claim_schedule``: the round's budget split evenly,
  so a shard whose step spawned nothing still pulls its share of the
  gathered compact block) is a pure function of the replicated head/tail;
* the publish wave costs exactly ONE psum (``mesh_round_gather``: ticket
  aggregation and compact-block exchange fused into a single collective —
  the ``mesh_ticket_base`` leader-FAA with the payload riding along);
* the loop condition is the replicated occupancy, so every shard exits on
  the same round and the collectives stay in lockstep;
* the host syncs once at global quiescence (or every ``sync_every``
  rounds for a stats heartbeat), exactly like the chip-level engine.

Overflow and truncation follow the ``_FusedEngine`` contract: a flag in
the carry exits the loop and the host driver raises ``RuntimeError`` at
the next sync.

Accumulators are *per-shard*: the step function sees only its shard's
claimed batch, so acc leaves diverge across shards.  ``run`` returns them
stacked with a leading shard axis, reduced by the ``combine`` callable
when one is given (BFS: elementwise min over shards).

Note on the replication checker: the per-round distqueue API passes
``check_rep=True`` (psum-gathered payloads keep the planes
replicated-typed), but ``lax.while_loop`` has no replication rule in this
jax line, so the megaround's shard_map is built with ``check_rep=False``.
Per-shard state bit-identity is asserted by tests instead.

Both engines are bit-identical — same acc leaves, same planes, same
head/tail and stats counters — asserted on tree and BFS workloads.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.distqueue import (DistQueueState, dist_claim_round,
                              dist_publish_round, dist_queue_init)
from ..kernels.ring_slots import enq_planes
from .fusedrounds import IDX_BOT, StepFn, _FusedEngine

__all__ = ["FusedMeshRounds", "MeshRoundRunner"]


class _MeshEngineBase(_FusedEngine):
    """Shared mesh-round machinery: seeding, specs, the one-round body."""

    def __init__(self, step_fn: StepFn, *, mesh, axis: str = "data",
                 capacity_log2: int = 10, batch: int = 64,
                 sync_every: int = 0) -> None:
        self.step_fn = step_fn
        self.mesh = mesh
        self.axis = axis
        self.shards = int(mesh.shape[axis])
        self.capacity_log2 = capacity_log2
        self.capacity = 1 << capacity_log2
        self.nslots_log2 = capacity_log2 + 1
        self.batch = batch
        if batch * self.shards > self.capacity:
            raise ValueError(
                f"mesh batch {batch} x {self.shards} shards exceeds ring "
                f"capacity {self.capacity}")
        self.sync_every = sync_every
        self._reset()

    # -- seeding (host-side, before shard_map: planes are plain jnp) --------
    def _seed(self, state: DistQueueState,
              initial: np.ndarray) -> DistQueueState:
        k = len(initial)
        if k > self.capacity:
            raise RuntimeError(
                f"mesh ring overflow: {k} seed values exceed capacity "
                f"{self.capacity} (raise capacity_log2)")
        if k == 0:
            return state
        base = int(np.int64(np.asarray(state.tail)))
        t = (base + np.arange(k, dtype=np.int64)) % (2 ** 32)
        tickets = jnp.asarray(np.where(t >= 2 ** 31, t - 2 ** 32, t)
                              .astype(np.int32))
        cyc, saf, enq, idx, ok = enq_planes(
            state.cycles, state.safes, state.enqs, state.idxs, tickets,
            jnp.asarray(initial), state.head,
            nslots_log2=self.nslots_log2, idx_bot=IDX_BOT)
        assert bool(np.asarray(ok).all()), "exact tickets cannot miss"
        return DistQueueState(cyc, saf, enq, idx,
                              tail=state.tail + jnp.int32(k),
                              head=state.head)

    # -- one mesh round, shared verbatim by both engines --------------------
    def _round(self, state: DistQueueState, acc):
        """claim (no collective) → step → publish (one psum).  Returns
        (state, acc, k, total, over)."""
        occ = state.tail - state.head
        k = jnp.minimum(occ, jnp.int32(self.shards * self.batch))
        state, vals, ok = dist_claim_round(state, k, self.batch, self.axis)
        acc, cvals, cmask = self.step_fn(acc, vals, ok)
        cm = jnp.broadcast_to(cmask.astype(bool), cvals.shape).reshape(-1)
        cv = cvals.reshape(-1).astype(jnp.int32)
        state, _, total, over = dist_publish_round(
            state, cv, cm.astype(jnp.int32), self.axis,
            capacity=self.capacity)
        return state, acc, k, total, over

    def _initial_carry(self, state: DistQueueState, acc):
        acc = jax.tree_util.tree_map(jnp.asarray, acc)
        occ0 = jnp.int32(np.asarray(state.tail - state.head))
        return state, acc, occ0


class FusedMeshRounds(_MeshEngineBase):
    """The mesh megaround loop: one jitted shard_map call runs up to
    ``limit`` rounds on device; host sync only at quiescence (or every
    ``sync_every`` rounds).  ``run`` mirrors ``FusedRounds.run`` and
    returns (acc, final DistQueueState) where acc carries a leading shard
    axis unless ``combine`` reduces it."""

    def __init__(self, step_fn: StepFn, *, mesh, axis: str = "data",
                 capacity_log2: int = 10, batch: int = 64,
                 sync_every: int = 0,
                 combine: Callable[[Any], Any] = None) -> None:
        super().__init__(step_fn, mesh=mesh, axis=axis,
                         capacity_log2=capacity_log2, batch=batch,
                         sync_every=sync_every)
        self.combine = combine
        # in shard_map, P() = replicated operand, P(axis) = sharded; a bare
        # P serves as a pytree-prefix spec for the whole acc subtree.  acc
        # rides stacked (shards, ...) through P(axis) specs so successive
        # chunk calls (sync_every heartbeats) compose.
        self._megaround = jax.jit(shard_map(
            self._megaround_impl, mesh=self.mesh,
            in_specs=(P(), P(), P(), P(), P(), P(), P(self.axis), P(), P(),
                      P(), P()),
            out_specs=(P(), P(), P(), P(), P(), P(), P(self.axis),
                       P(), P(), P(), P(), P()),
            check_rep=False))   # while_loop has no replication rule

    # -- the jitted megaround: up to `limit` rounds entirely on device ------
    def _megaround_impl(self, cyc, saf, enq, idx, head, tail, acc,
                        processed, spawned, max_occ, limit):
        acc = jax.tree_util.tree_map(lambda x: x[0], acc)

        def body(carry):
            (cyc, saf, enq, idx, head, tail, acc, processed, spawned,
             max_occ, oflow, rounds) = carry
            state = DistQueueState(cyc, saf, enq, idx, tail=tail, head=head)
            state, acc, k, total, over = self._round(state, acc)
            return (state.cycles, state.safes, state.enqs, state.idxs,
                    state.head, state.tail, acc, processed + k,
                    spawned + total,
                    jnp.maximum(max_occ, state.tail - state.head),
                    oflow | over, rounds + 1)

        def cond(carry):
            _, _, _, _, head, tail, _, _, _, _, oflow, rounds = carry
            return (tail - head > 0) & (~oflow) & (rounds < limit)

        carry = (cyc, saf, enq, idx, head, tail, acc, processed, spawned,
                 max_occ, jnp.bool_(False), jnp.int32(0))
        out = jax.lax.while_loop(cond, body, carry)
        acc_stacked = jax.tree_util.tree_map(lambda x: x[None], out[6])
        return (out[0], out[1], out[2], out[3], out[4], out[5], acc_stacked,
                out[7], out[8], out[9], out[10], out[11])

    def run(self, initial: np.ndarray, acc: Any = None,
            max_rounds: int = 10_000) -> Tuple[Any, DistQueueState]:
        self._reset()
        st = self._seed(dist_queue_init(self.capacity),
                        np.asarray(initial, np.int32).reshape(-1))
        st, acc, occ0 = self._initial_carry(st, acc)
        acc = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.shards,) + x.shape),
            acc)
        state = [st.cycles, st.safes, st.enqs, st.idxs, st.head, st.tail,
                 acc, jnp.int32(0), jnp.int32(0), occ0]

        def chunk_fn(limit):
            (state[0], state[1], state[2], state[3], state[4], state[5],
             state[6], state[7], state[8], state[9], oflow, r
             ) = self._megaround(*state, jnp.int32(limit))
            occ = int(np.int32(np.asarray(state[5] - state[4])))  # THE sync
            return (occ, int(r), bool(oflow), int(state[7]), int(state[8]),
                    int(state[9]))

        self._drive(chunk_fn, max_rounds, "mesh ring")
        final = DistQueueState(state[0], state[1], state[2], state[3],
                               tail=state[5], head=state[4])
        acc = state[6]
        if self.combine is not None:
            acc = self.combine(acc)
        return acc, final


class MeshRoundRunner(_MeshEngineBase):
    """Mesh twin of ``RoundRunner``: ``fused=True`` (default) delegates to
    ``FusedMeshRounds``; ``fused=False`` keeps the legacy host-driven loop
    — one jitted shard_map dispatch and one occupancy readback per round
    (the ``mesh_task_round`` pathology PR 3's engine removed at chip
    level), kept for step-debug and as the parity baseline.  Both engines
    are bit-identical."""

    def __init__(self, step_fn: StepFn, *, mesh, axis: str = "data",
                 capacity_log2: int = 10, batch: int = 64,
                 fused: bool = True, sync_every: int = 0,
                 combine: Callable[[Any], Any] = None) -> None:
        super().__init__(step_fn, mesh=mesh, axis=axis,
                         capacity_log2=capacity_log2, batch=batch,
                         sync_every=sync_every)
        self.fused = fused
        self.combine = combine
        if fused:
            self._engine = FusedMeshRounds(
                step_fn, mesh=mesh, axis=axis, capacity_log2=capacity_log2,
                batch=batch, sync_every=sync_every, combine=combine)
        else:
            self._engine = None
            # legacy: acc rides stacked (shards, ...) through P(axis) specs
            self._round_jit = jax.jit(shard_map(
                self._round_impl, mesh=self.mesh,
                in_specs=(P(), P(), P(), P(), P(), P(), P(self.axis)),
                out_specs=(P(), P(), P(), P(), P(), P(), P(self.axis),
                           P(), P(), P()),
                check_rep=False))   # acc diverges per shard (P(axis) io)

    def _round_impl(self, cyc, saf, enq, idx, head, tail, acc):
        acc = jax.tree_util.tree_map(lambda x: x[0], acc)
        state = DistQueueState(cyc, saf, enq, idx, tail=tail, head=head)
        state, acc, k, total, over = self._round(state, acc)
        acc = jax.tree_util.tree_map(lambda x: x[None], acc)
        return (state.cycles, state.safes, state.enqs, state.idxs,
                state.head, state.tail, acc, k, total, over)

    def run(self, initial: np.ndarray, acc: Any = None,
            max_rounds: int = 10_000) -> Tuple[Any, DistQueueState]:
        if self._engine is not None:
            try:
                return self._engine.run(initial, acc, max_rounds)
            finally:
                self.stats = dict(self._engine.stats, fused=1)
                self.sync_log = self._engine.sync_log
        self._reset()
        st = self._seed(dist_queue_init(self.capacity),
                        np.asarray(initial, np.int32).reshape(-1))
        st, acc, occ0 = self._initial_carry(st, acc)
        acc = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.shards,) + x.shape),
            acc)
        state = [st.cycles, st.safes, st.enqs, st.idxs, st.head, st.tail]
        rounds = processed = spawned = 0
        max_occ = occ = int(np.int32(np.asarray(occ0)))
        host_syncs = 0
        overflow = False
        while occ > 0 and rounds < max_rounds:
            (state[0], state[1], state[2], state[3], state[4], state[5],
             acc, k, total, over) = self._round_jit(*state, acc)
            occ = int(np.int32(np.asarray(state[5] - state[4])))
            host_syncs += 1                             # per-round readback
            rounds += 1
            processed += int(k)
            spawned += int(total)
            max_occ = max(max_occ, occ)
            self.sync_log.append({"rounds": rounds, "occupancy": occ})
            if bool(over):
                overflow = True
                break
        self.stats = {"rounds": rounds, "processed": processed,
                      "spawned": spawned, "max_occupancy": max_occ,
                      "drained": int(occ == 0),
                      "host_syncs": host_syncs, "fused": 0}
        if overflow:
            raise RuntimeError(
                f"mesh ring overflow: occupancy {occ} + spawned children "
                f"exceed capacity {self.capacity} at round {rounds} (raise "
                f"capacity_log2 or lower the fanout)")
        if occ > 0:
            raise RuntimeError(
                f"mesh ring round loop truncated at max_rounds={max_rounds} "
                f"with occupancy {occ}: not quiescent (stats['drained']=0)")
        final = DistQueueState(state[0], state[1], state[2], state[3],
                               tail=state[5], head=state[4])
        if self.combine is not None:
            acc = self.combine(acc)
        return acc, final
