"""tools/bench_compare.py: gap-tolerant baselining and serving-row
identity.

The trajectory has a real hole (BENCH_8 was never committed), so the
sentinel must compare the newest snapshot against the latest *existing*
predecessor AND say so — a silent cross-gap baseline reads as "vs n-1"
when it is not.  Serving rows add ``rate``/``tenant`` knobs that name a
configuration: two rows at different offered loads must never be matched
as the same row (a 1.5-req/tick row timed against a 0.5 baseline would
flag a phantom regression every run).
"""

import json
import os
import sys

sys.path.insert(
    0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

from tools.bench_compare import compare, gap_note  # noqa: E402


def _snap(path, bench_id, sections):
    payload = {"bench_id": bench_id, "git_rev": "abc1234",
               "config": {"quick": False, "sections": sorted(sections)},
               "sections": sections}
    with open(path, "w") as f:
        json.dump(payload, f)
    return str(path)


def _serving_row(rate, tenant, ticks_per_s, goodput=0.8):
    return {"bench": "serving", "mode": "device", "shards": 1,
            "rate": rate, "tenant": tenant, "offered_load": rate + 0.1,
            "goodput": goodput, "ticks_per_s": ticks_per_s}


def test_gap_note_names_every_missing_id(tmp_path):
    old = _snap(tmp_path / "BENCH_7.json", 7,
                {"serving": [_serving_row(0.5, 0, 100.0)]})
    new = _snap(tmp_path / "BENCH_10.json", 10,
                {"serving": [_serving_row(0.5, 0, 99.0)]})
    note = gap_note(old, new)
    assert "BENCH_8" in note and "BENCH_9" in note
    assert "latest existing predecessor" in note
    lines, regs = compare(old, new)
    assert any("BENCH_8" in ln for ln in lines)
    assert regs == []          # 1% drift is inside tolerance


def test_consecutive_ids_emit_no_note(tmp_path):
    old = _snap(tmp_path / "BENCH_9.json", 9,
                {"serving": [_serving_row(0.5, 0, 100.0)]})
    new = _snap(tmp_path / "BENCH_10.json", 10,
                {"serving": [_serving_row(0.5, 0, 100.0)]})
    assert gap_note(old, new) is None
    lines, _ = compare(old, new)
    assert not any("NOTE" in ln for ln in lines)


def test_non_bench_paths_emit_no_note(tmp_path):
    old = _snap(tmp_path / "before.json", 1,
                {"serving": [_serving_row(0.5, 0, 100.0)]})
    new = _snap(tmp_path / "after.json", 5,
                {"serving": [_serving_row(0.5, 0, 100.0)]})
    assert gap_note(old, new) is None


def test_regressions_still_flagged_across_a_gap(tmp_path):
    old = _snap(tmp_path / "BENCH_7.json", 7,
                {"serving": [_serving_row(0.5, 0, 100.0)]})
    new = _snap(tmp_path / "BENCH_10.json", 10,
                {"serving": [_serving_row(0.5, 0, 40.0)]})
    _, regs = compare(old, new)
    assert len(regs) == 1 and regs[0]["metric"] == "ticks_per_s"


def test_rate_and_tenant_are_identity_knobs(tmp_path):
    """A high-load row must not be timed against a low-load baseline:
    if ``rate``/``tenant`` fell out of the identity, the 1.5-rate row
    below would match the 0.5 baseline and flag a 90% regression."""
    old = _snap(tmp_path / "BENCH_9.json", 9,
                {"serving": [_serving_row(0.5, 0, 100.0)]})
    new = _snap(tmp_path / "BENCH_10.json", 10,
                {"serving": [_serving_row(0.5, 0, 100.0),
                             _serving_row(1.5, 0, 10.0),
                             _serving_row(0.5, 1, 10.0)]})
    lines, regs = compare(old, new)
    assert regs == [], lines
