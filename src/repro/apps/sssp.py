"""Delta-stepping single-source shortest paths on the priority mesh
rounds (DESIGN.md § 6) — the canonical priority-queue graph workload
(Chen et al.'s concurrent-heap case study, Wang et al.'s relaxed-order
load balancing), run through ``PriorityMeshRoundRunner``.

The queue carries ``(key, payload)`` pairs: the payload packs a tentative
distance claim as ``d·n + v`` (self-contained, like mesh BFS — a shard
can relax a vertex it has never seen), and the key is the delta-stepping
bucket ``d // delta``, so pops drain the lowest-distance buckets first.
The step is asynchronous label-correcting: a claim expands only if its
distance still improves (or matches) the shard's local label, children
are published only for strictly improving relaxations, and per-shard
labels are min-combined at quiescence.  Correctness therefore does NOT
depend on pop order — strict, k-relaxed, or adversarial order all
converge to exact Dijkstra distances (every shortest-path prefix is
claimed somewhere with its true distance and re-published on
improvement); priority order only bounds the *wasted* re-relaxations, so
``delta`` and ``relaxed`` trade queue pressure against round count
exactly as in CPU delta-stepping.

Determinism: the whole run is bit-deterministic for a fixed (graph,
source, mesh, batch, delta, relaxed) configuration — both engines
(``fused=True``/``False``) produce identical labels, heap planes, and
stats, asserted by tests.

Exactness is asserted against the ``dijkstra_reference`` heapq oracle on
road-like and kron-like weighted graphs at 1/2/4 shards.
"""

from __future__ import annotations

import heapq
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .bfs import CSRGraph

BIG = np.iinfo(np.int32).max


def with_weights(g: CSRGraph, max_w: int = 8, seed: int = 0) -> np.ndarray:
    """Integer edge weights in ``[1, max_w]`` aligned with ``g.col_idx``."""
    rng = np.random.default_rng(seed)
    return rng.integers(1, max_w + 1, g.m).astype(np.int32)


def dijkstra_reference(g: CSRGraph, weights: np.ndarray,
                       source: int = 0) -> np.ndarray:
    """Plain heapq Dijkstra oracle; -1 marks unreachable vertices."""
    dist = np.full(g.n, -1, np.int64)
    dist[source] = 0
    pq = [(0, source)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        for k in range(g.row_ptr[u], g.row_ptr[u + 1]):
            v = int(g.col_idx[k])
            nd = d + int(weights[k])
            if dist[v] < 0 or nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (nd, v))
    return dist.astype(np.int32)


def sssp_mesh_rounds_runner(g: CSRGraph, weights: np.ndarray, *, mesh=None,
                            shards: int = None, axis: str = "data",
                            batch: int = 64, delta: int = 4,
                            relaxed: bool = True, fused: bool = True,
                            sync_every: int = 0, capacity_log2: int = None,
                            trace: bool = False, telemetry=None,
                            spans=None, compact=None,
                            split_payload: bool = False):
    """Build the priority-mesh SSSP runner for ``(g, weights)``.  Returns
    ``(runner, init_fn)`` where ``init_fn(source)`` builds the label
    accumulator and the source's seed is ``(key=0, payload=source)`` —
    callers that run SSSP repeatedly (benchmarks) reuse the runner to
    amortize the megaround compilation.

    ``relaxed=True`` pops per-shard local minima under the hint-ordered
    claim schedule (k-relaxed, ``sched.relaxed.mesh_relaxation_bound``);
    ``relaxed=False`` pops exact global bucket order from the replicated
    heap.  Both are exact at quiescence; ``fused`` picks host sync at
    quiescence vs per round (bit-identical engines).

    ``split_payload=True`` switches the queue to the two-plane
    ``(key, payload)`` layout: the payload carries the bare vertex id and
    the exact tentative distance rides the heap's aux rider plane, so
    nothing packs into ``d·n + v`` and the ``(max_d + max_w)·n < 2^31``
    packed cap disappears — only the distances themselves must stay below
    ``2^31``.  Seed with ``runner.run([0], [source], ...,
    initial_aux=[0])``.  Mutually exclusive with ``spans``;
    ``trace``/legacy still work (the aux plane threads the per-round
    state)."""
    from ..jaxcompat import make_mesh
    from ..runtime import PriorityMeshRoundRunner

    n = g.n
    if mesh is None:
        shards = shards or len(jax.devices())
        mesh = make_mesh((shards,), (axis,))
    weights = np.asarray(weights, np.int32)
    assert weights.shape == (g.m,)
    max_w = int(weights.max()) if g.m else 1
    # any finite tentative distance is a real path length ≤ (n-1)·max_w
    max_d = (n - 1) * max_w
    if split_payload:
        # two-plane layout: only the raw distances must fit in int32
        if max_d + max_w >= 2 ** 31:
            raise ValueError(
                f"graph too large even for split payloads: n={n}, "
                f"max_w={max_w} needs (n-1)*max_w + max_w < 2^31")
    elif (max_d + max_w) * n + (n - 1) >= 2 ** 31:
        raise ValueError(
            f"graph too large for packed (d, v) payloads: n={n}, "
            f"max_w={max_w} needs ((n-1)*max_w + max_w)*n + n < 2^31 "
            f"(use split_payload=True for the two-plane layout)")
    if delta < 1:
        raise ValueError(f"delta must be >= 1, got {delta}")
    deg = np.diff(g.row_ptr).astype(np.int64)
    fan = max(int(deg.max()) if n else 0, 1)
    nbr = np.full((n, fan), -1, np.int32)
    wgt = np.zeros((n, fan), np.int32)
    rows = np.repeat(np.arange(n), deg)
    pos = np.arange(g.m) - np.repeat(g.row_ptr[:-1].astype(np.int64), deg)
    nbr[rows, pos] = g.col_idx
    wgt[rows, pos] = weights
    nbr_j = jnp.asarray(nbr)
    wgt_j = jnp.asarray(wgt)

    def _relax(dist, v, d, valid):
        """Shared label-correcting core: claim (v, d) pairs in, winning
        child relaxations ``(dist, ck, wf, ndf, win, shape)`` out."""
        b = v.shape[0]
        # expand unless the local label already beats the claim (labels are
        # real path lengths ≥ the true distance, so a true-distance claim
        # is never stale; ``==`` claims re-expand but spawn only improving
        # children, which keeps the recursion finite)
        fresh = valid & (d <= dist[v])
        dist = dist.at[jnp.where(fresh, v, n)].min(d, mode="drop")
        w = jnp.where(fresh[:, None], nbr_j[v], -1)          # (B, F)
        wc = jnp.clip(w, 0, n - 1)
        nd = d[:, None] + wgt_j[v]
        elig = (w >= 0) & (nd < dist[wc])
        # in-batch winner per target: smallest nd, then row-major order —
        # two scatter-mins, so no packed winner key to overflow
        ef = elig.reshape(-1)
        wf = w.reshape(-1)
        ndf = nd.reshape(-1)
        tgt = jnp.where(ef, wf, n)
        claim_nd = jnp.full((n + 1,), BIG, jnp.int32).at[tgt].min(
            jnp.where(ef, ndf, BIG))
        tie = ef & (claim_nd[tgt] == ndf)
        order = jnp.arange(b * w.shape[1], dtype=jnp.int32)
        claim_ord = jnp.full((n + 1,), BIG, jnp.int32).at[tgt].min(
            jnp.where(tie, order, BIG))
        win = tie & (claim_ord[tgt] == order)
        dist = dist.at[jnp.where(win, wf, n)].min(ndf, mode="drop")
        ck = jnp.where(win, ndf // delta, 0)
        return dist, ck, wf, ndf, win, w.shape

    def step(dist, keys, payloads, valid):
        del keys                                  # bucket only orders pops
        p = jnp.where(valid, payloads, 0)
        dist, ck, wf, ndf, win, shape = _relax(dist, p % n, p // n, valid)
        cv = jnp.where(win, ndf * n + jnp.clip(wf, 0, n - 1), 0)
        return (dist, ck.reshape(shape), cv.reshape(shape),
                win.reshape(shape))

    def step_split(dist, keys, payloads, aux, valid):
        del keys                                  # bucket only orders pops
        v = jnp.where(valid, payloads, 0)         # bare vertex plane
        d = jnp.where(valid, aux, 0)              # exact distance rider
        dist, ck, wf, ndf, win, shape = _relax(dist, v, d, valid)
        cv = jnp.where(win, jnp.clip(wf, 0, n - 1), 0)
        ca = jnp.where(win, ndf, 0)
        return (dist, ck.reshape(shape), cv.reshape(shape),
                ca.reshape(shape), win.reshape(shape))

    def combine(stacked):                        # (shards, n) labels
        m = stacked.min(0)
        return jnp.where(m == BIG, -1, m)

    nshards = int(mesh.shape[axis])
    if capacity_log2 is None:
        per_shard = max(4 * n // max(nshards, 1), 4 * batch, 16)
        capacity_log2 = int(np.ceil(np.log2(per_shard)))
        if not relaxed:
            capacity_log2 = int(np.ceil(np.log2(
                max(4 * n, 4 * batch * nshards, 16))))
    runner = PriorityMeshRoundRunner(step_split if split_payload else step,
                                     mesh=mesh, axis=axis,
                                     capacity_log2=capacity_log2,
                                     batch=batch, relaxed=relaxed,
                                     fused=fused, sync_every=sync_every,
                                     combine=combine, trace=trace,
                                     telemetry=telemetry, spans=spans,
                                     compact=compact, split=split_payload)

    def init_fn(source: int):
        # all labels unvisited (BIG) — the source's 0 arrives via its seed
        # claim (pre-setting it would make that claim non-improving and
        # suppress the very first expansion)
        del source
        return jnp.full((n,), BIG, jnp.int32)

    return runner, init_fn


def sssp_mesh_rounds(g: CSRGraph, weights: np.ndarray, source: int = 0, *,
                     mesh=None, shards: int = None, batch: int = 64,
                     delta: int = 4, relaxed: bool = True,
                     fused: bool = True, sync_every: int = 0,
                     compact=None, split_payload: bool = False,
                     max_rounds: int = 100_000) -> Tuple[np.ndarray, Dict]:
    """Delta-stepping SSSP on the priority mesh engine across ≥1 shards:
    exact Dijkstra distances at quiescence, host sync only at quiescence
    when ``fused=True``.  Returns ``(dist, stats)``."""
    runner, init_fn = sssp_mesh_rounds_runner(
        g, weights, mesh=mesh, shards=shards, batch=batch, delta=delta,
        relaxed=relaxed, fused=fused, sync_every=sync_every,
        compact=compact, split_payload=split_payload)
    kw = {"initial_aux": [0]} if split_payload else {}
    dist, _ = runner.run([0], [source], acc=init_fn(source),
                         max_rounds=max_rounds, **kw)
    return np.asarray(dist), dict(runner.stats)
