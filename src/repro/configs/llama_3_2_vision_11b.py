"""llama-3.2-vision-11b — 40L dense GQA with cross-attention image layers
every 5th layer; patch-embedding frontend stubbed per assignment
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256,
    cross_attn_every=5, n_image_tokens=1601,
    rope_theta=500000.0, fsdp=True,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention, no sub-quadratic mechanism (DESIGN §5)",
)
