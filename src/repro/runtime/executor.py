"""Persistent-worker task executor on the interleaving simulator
(DESIGN.md § 4.2).

Workers are generator threads on ``repro.core.sim.Scheduler`` that loop
dequeue → execute → spawn-children until quiescence, exactly the paper's
persistent-kernel consumer pattern.  Dynamic task spawning goes through the
fabric's OUTSTANDING counter with the increment-children-before-retiring-
the-parent discipline, so a worker that loads OUTSTANDING == 0 holds a sound
termination certificate (Dijkstra–Scholten at counter granularity): every
task is counted from before it becomes visible until after its children are.

Arrival schedules (``at_step``) model open-loop workloads: a source thread
releases tasks into the fabric when the simulated clock reaches each
arrival, spraying them round-robin across shards; the OUTSTANDING counter is
pre-charged with the whole schedule so workers cannot conclude quiescence
between bursts.

Executor metrics extend the § V-C family: ``idle_steps`` (per-thread steps
burned in acquire passes that found no task, the WAIT/op analogue at runtime
scope), ``steal_rate``, and per-shard ``load_imbalance``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

from ..core import AtomicMemory
from ..core.sim import Scheduler
from ..obs.metrics import MetricsRegistry, metric_key
from .taskpool import TaskFabric, TaskRecord, TaskSpec

# A handler executes a task on the host and returns the children to spawn.
Handler = Callable[[TaskRecord], Optional[Iterable[TaskSpec]]]


@dataclass
class ExecutorConfig:
    workers: int = 32
    sources: int = 1                # parallel arrival-release threads
    wave_size: int = 8
    policy: str = "gang"            # random | gang | rr
    seed: int = 0
    max_steps: int = 5_000_000
    backoff_cap: int = 8            # max idle backoff (steps) after an empty scan


@dataclass
class Arrival:
    at_step: int
    spec: TaskSpec
    affinity: Optional[int] = None   # target shard; None = round-robin spray


class TaskRuntime:
    """Owns the memory, the scheduler, the fabric, and the worker fleet for
    one task-parallel run."""

    def __init__(self, fabric: TaskFabric, handler: Handler,
                 cfg: Optional[ExecutorConfig] = None,
                 registry: Optional[MetricsRegistry] = None) -> None:
        self.fabric = fabric
        self.handler = handler
        self.cfg = cfg or ExecutorConfig()
        self.registry = registry
        self.arrivals: List[Arrival] = []
        self.executed: List[Tuple[int, int]] = []   # (task_id, worker tid)
        self.idle_steps = 0
        self.exec_steps = 0
        self.per_worker_executed: Dict[int, int] = {}
        self._sched: Optional[Scheduler] = None

    # -- workload construction ----------------------------------------------

    def add_task(self, payload: Any, *, priority: int = 1, cost: int = 0,
                 at_step: int = 0, affinity: Optional[int] = None,
                 deadline: Optional[int] = None) -> None:
        # Fail fast at workload-construction time: register() would raise
        # the same ValueError, but only mid-simulation inside the source
        # thread, after arbitrary simulated work.
        self.fabric.validate_priority(priority)
        self.fabric.validate_deadline(deadline)
        self.arrivals.append(
            Arrival(at_step, TaskSpec(payload, priority, cost, deadline),
                    affinity))

    # -- thread bodies -------------------------------------------------------

    def _source_body(self, ctx, tid, lane: int = 0):
        """Release scheduled arrivals at their step; OUTSTANDING was
        pre-charged with the full schedule, so no increment here.  With
        ``cfg.sources > 1`` the schedule is striped across that many
        source threads, so one arrival stalled on a full fabric (admission
        backpressure) does not head-of-line-block the rest of the open
        loop."""
        pending = sorted(self.arrivals,
                         key=lambda a: a.at_step)[lane::self.cfg.sources]
        for a in pending:
            while self._sched.step_count < a.at_step:
                yield from ctx.step()
            rec = self.fabric.register(a.spec.payload, a.spec.priority,
                                       a.spec.cost, a.spec.deadline)
            shard = (a.affinity % self.fabric.shards
                     if a.affinity is not None else self.fabric.spray_shard())
            yield from self.fabric.enqueue_task(ctx, tid, rec, shard=shard)

    def _worker_body(self, ctx, tid):
        backoff = 1
        while True:
            t0 = self._sched.threads[tid].steps
            rec = yield from self.fabric.acquire(ctx, tid)
            if rec is None:
                self.idle_steps += self._sched.threads[tid].steps - t0
                out = yield from self.fabric.outstanding(ctx, tid)
                if out == 0:
                    return                      # quiescent: no task anywhere
                for _ in range(backoff):
                    yield from ctx.step()
                self.idle_steps += backoff
                backoff = min(backoff * 2, self.cfg.backoff_cap)
                continue
            backoff = 1
            for _ in range(rec.cost):            # simulated compute
                yield from ctx.step()
            self.exec_steps += rec.cost
            children = self.handler(rec) or ()
            for spec in children:
                yield from self.fabric.spawn(ctx, tid, spec)
            yield from self.fabric.complete(ctx, tid)
            self.executed.append((rec.task_id, tid))
            self.per_worker_executed[tid] = self.per_worker_executed.get(tid, 0) + 1

    # -- run ------------------------------------------------------------------

    def run(self) -> Dict[str, float]:
        cfg = self.cfg
        mem = AtomicMemory()
        sched = Scheduler(mem, wave_size=cfg.wave_size, policy=cfg.policy,
                          seed=cfg.seed)
        self._sched = sched
        self.fabric.init(mem, sched, initial_outstanding=len(self.arrivals))
        if self.arrivals:
            for lane in range(min(cfg.sources, len(self.arrivals))):
                sched.spawn(self._source_body, lane)
        for _ in range(cfg.workers):
            sched.spawn(self._worker_body)
        completed = sched.run(cfg.max_steps)
        m = sched.metrics()
        execd = [n for _, n in sorted(self.per_worker_executed.items())]
        mean_exec = (sum(execd) / len(execd)) if execd else 0.0
        m.update({
            "completed": float(completed),
            "tasks_executed": len(self.executed),
            "idle_steps": self.idle_steps,
            "exec_steps": self.exec_steps,
            "idle_steps_per_task": self.idle_steps / max(len(self.executed), 1),
            "steals": self.fabric.metrics.steals,
            "steal_rate": self.fabric.steal_rate(),
            "enq_retries": self.fabric.metrics.enq_retries,
            "load_imbalance": self.fabric.metrics.load_imbalance(),
            "worker_imbalance": (max(execd) / mean_exec) if mean_exec else 1.0,
        })
        # Starvation metrics (per-class queue waits) when the fabric
        # tracks them — both TaskFabric and PriorityFabric do.
        wait_stats = getattr(self.fabric, "wait_stats", None)
        if wait_stats is not None:
            m.update(wait_stats())
        if self.registry is not None:
            self._publish(m)
        return m

    def _publish(self, m: Dict[str, float]) -> None:
        """Mirror the run's metrics into the shared registry under the
        stable ``runtime.*`` / ``fabric.*`` key scheme (DESIGN.md § 7.2):
        the free-form dict stays the return value, the registry is what
        exporters and benchmarks read."""
        reg = self.registry
        for name in ("tasks_executed", "idle_steps", "exec_steps",
                     "completed"):
            reg.counter(metric_key("runtime", name), m[name])
        for name in ("idle_steps_per_task", "steal_rate", "load_imbalance",
                     "worker_imbalance"):
            reg.gauge(metric_key("runtime", name), m[name])
        for tid, n in sorted(self.per_worker_executed.items()):
            reg.counter(metric_key("runtime", "executed", worker=tid), n)
        self.fabric.metrics.publish(reg)
        for prio, waits in sorted(self.fabric.waits.items()):
            key = metric_key("fabric", "wait", cls=prio)
            for w in waits:
                reg.observe(key, w)

    @property
    def scheduler(self) -> Scheduler:
        assert self._sched is not None, "run() first"
        return self._sched
