"""Architecture registry: --arch <id> resolution."""
from .base import ArchConfig
from .h2o_danube_1_8b import CONFIG as _danube
from .gemma3_4b import CONFIG as _gemma3
from .yi_34b import CONFIG as _yi
from .gemma2_27b import CONFIG as _gemma2
from .llama_3_2_vision_11b import CONFIG as _llamav
from .granite_moe_3b_a800m import CONFIG as _granite
from .deepseek_moe_16b import CONFIG as _dsmoe
from .zamba2_7b import CONFIG as _zamba
from .mamba2_130m import CONFIG as _mamba
from .hubert_xlarge import CONFIG as _hubert

ARCHS = {c.name: c for c in (_danube, _gemma3, _yi, _gemma2, _llamav,
                             _granite, _dsmoe, _zamba, _mamba, _hubert)}


def get_config(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return ARCHS[name[:-6]].reduced()
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)
