"""repro.distributed subpackage."""
