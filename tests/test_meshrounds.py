"""Mesh-fused round engine invariants (DESIGN.md § 2.3):

* ``FusedMeshRounds`` is bit-identical to the legacy host-driven per-round
  shard_map path — same combined acc, same ring planes, same head/tail and
  stats counters — on tree and BFS workloads;
* the fused path syncs the host once at quiescence (``sync_every`` gives a
  periodic heartbeat) where the legacy path syncs every round;
* overflow and ``max_rounds`` truncation raise ``RuntimeError`` from both
  engines;
* ``bfs_mesh_rounds`` computes exact BFS distances via min-combined
  label-correcting;
* the ≥2-shard run (bench_mesh --smoke in a forced-device subprocess)
  holds the same parity plus exact BFS across shards.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.jaxcompat import make_mesh  # noqa: E402
from repro.runtime import MeshRoundRunner  # noqa: E402

STAT_KEYS = ("rounds", "processed", "spawned", "max_occupancy", "drained")


def _mesh1():
    return make_mesh((1,), ("data",))


def _tree_step():
    def step(acc, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        cm = (valid & (vals < 32))[:, None]
        return acc, cv, cm
    return step


def _run_pair(**kw):
    mesh = _mesh1()
    accs, states, stats = [], [], []
    for fused in (True, False):
        r = MeshRoundRunner(_tree_step(), mesh=mesh, capacity_log2=8,
                            batch=16, fused=fused,
                            combine=lambda a: a.sum(0), **kw)
        acc, st = r.run([1], acc=jnp.zeros(80, jnp.int32))
        accs.append(np.asarray(acc))
        states.append(st)
        stats.append(r.stats)
    return accs, states, stats


def test_mesh_fused_matches_legacy_tree():
    accs, states, stats = _run_pair()
    np.testing.assert_array_equal(accs[0], accs[1])
    for a, b in zip(states[0][:4], states[1][:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (int(np.asarray(states[0].head)), int(np.asarray(states[0].tail))) \
        == (int(np.asarray(states[1].head)), int(np.asarray(states[1].tail)))
    for k in STAT_KEYS:
        assert stats[0][k] == stats[1][k], k
    # the headline: host sync only at quiescence vs every round
    assert stats[0]["host_syncs"] == 1
    assert stats[1]["host_syncs"] == stats[1]["rounds"]
    # tasks 1..31 processed exactly once each
    assert accs[0][1:32].tolist() == [1] * 31


def test_mesh_sync_every_heartbeat():
    mesh = _mesh1()
    r = MeshRoundRunner(_tree_step(), mesh=mesh, capacity_log2=8, batch=16,
                        sync_every=2, combine=lambda a: a.sum(0))
    acc, _ = r.run([1], acc=jnp.zeros(80, jnp.int32))
    full = MeshRoundRunner(_tree_step(), mesh=mesh, capacity_log2=8,
                           batch=16, combine=lambda a: a.sum(0))
    acc2, _ = full.run([1], acc=jnp.zeros(80, jnp.int32))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(acc2))
    assert r.stats["host_syncs"] > 1
    assert r.sync_log[-1]["occupancy"] == 0


def test_mesh_bfs_single_shard_exact_and_bit_identical():
    from repro.apps import bfs
    mesh = _mesh1()
    for g in (bfs.road_like(144), bfs.kron_like(200, avg_deg=6, seed=2)):
        ref = bfs.bfs_reference(g, 0)
        res = {}
        for fused in (True, False):
            dist, stats = bfs.bfs_mesh_rounds(g, 0, mesh=mesh, batch=32,
                                              fused=fused)
            np.testing.assert_array_equal(dist, ref)
            res[fused] = stats
        for k in STAT_KEYS:
            assert res[True][k] == res[False][k], (g.name, k)
        assert res[True]["host_syncs"] == 1


def _explode_step():
    def step(acc, vals, valid):
        cv = jnp.broadcast_to(vals[:, None], (vals.shape[0], 4)) + 1
        cm = jnp.broadcast_to(valid[:, None], cv.shape)
        return acc, cv.astype(jnp.int32), cm
    return step


@pytest.mark.parametrize("fused", [True, False])
def test_mesh_overflow_raises(fused):
    r = MeshRoundRunner(_explode_step(), mesh=_mesh1(), capacity_log2=4,
                        batch=8, fused=fused)
    with pytest.raises(RuntimeError, match="mesh ring overflow"):
        r.run(np.arange(8), acc=jnp.int32(0), max_rounds=100)


@pytest.mark.parametrize("fused", [True, False])
def test_mesh_seed_overflow_raises(fused):
    r = MeshRoundRunner(_tree_step(), mesh=_mesh1(), capacity_log2=4,
                        batch=8, fused=fused)
    with pytest.raises(RuntimeError, match="mesh ring overflow"):
        r.run(np.arange(64), acc=jnp.zeros(80, jnp.int32))


def _immortal_step():
    def step(acc, vals, valid):
        return acc, vals[:, None], valid[:, None]     # every task respawns
    return step


@pytest.mark.parametrize("fused", [True, False])
def test_mesh_max_rounds_truncation_raises(fused):
    r = MeshRoundRunner(_immortal_step(), mesh=_mesh1(), capacity_log2=6,
                        batch=8, fused=fused)
    with pytest.raises(RuntimeError, match="not quiescent"):
        r.run([1, 2, 3], acc=jnp.int32(0), max_rounds=5)
    assert r.stats["drained"] == 0
    assert r.stats["rounds"] == 5


def test_mesh_batch_exceeds_capacity_raises():
    with pytest.raises(ValueError, match="exceeds ring capacity"):
        MeshRoundRunner(_tree_step(), mesh=_mesh1(), capacity_log2=4,
                        batch=64)


# -- ≥2-shard acceptance (forced-device subprocess) ---------------------------


def test_bench_mesh_smoke_two_shards():
    """The CI gate: fused/legacy bit-parity + exact BFS on 2 shards."""
    import io
    from benchmarks.bench_mesh import smoke
    buf = io.StringIO()
    assert smoke(buf, shards=2), buf.getvalue()
