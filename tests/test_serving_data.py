"""Serving engine + data pipeline tests: continuous batching completes all
requests exactly once, KV pages are conserved (ring accounting), admission
backpressure engages under page pressure; the data pipeline delivers
deterministic, ordered batches through the bounded ring."""

import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, DataPipeline, HostRing, synth_batch
from repro.models import init_params
from repro.serving.engine import EngineConfig, Request, ServingEngine


def test_host_ring_fifo_and_backpressure():
    r = HostRing(4)
    assert all(r.enqueue(i, timeout=0.05) for i in range(4))
    assert not r.enqueue(99, timeout=0.05)          # full: backpressure
    assert [r.dequeue(timeout=0.05) for _ in range(4)] == [0, 1, 2, 3]
    assert r.dequeue(timeout=0.05) is None           # empty


def test_pipeline_ordered_and_deterministic():
    cfg = get_config("h2o-danube-1.8b").reduced()
    dcfg = DataConfig(seq_len=8, global_batch=2, prefetch=3,
                      num_producer_threads=2)
    steps1 = [(i, b["tokens"].copy()) for i, b in
              DataPipeline(cfg, dcfg, 10).start()]
    steps2 = [(i, b["tokens"].copy()) for i, b in
              DataPipeline(cfg, dcfg, 10).start()]
    assert [i for i, _ in steps1] == list(range(10))
    for (i1, t1), (i2, t2) in zip(steps1, steps2):
        assert i1 == i2
        np.testing.assert_array_equal(t1, t2)       # restart-deterministic


def test_synth_batch_shapes():
    cfg = get_config("llama-3.2-vision-11b").reduced()
    b = synth_batch(cfg, DataConfig(seq_len=8, global_batch=2), 0)
    assert b["tokens"].shape == (2, 8)
    assert b["img"].shape == (2, cfg.n_image_tokens, cfg.d_model)


def _engine(n_requests=6, num_pages=8, max_slots=2):
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(cfg)
    ecfg = EngineConfig(max_slots=max_slots, page_size=16, num_pages=num_pages,
                        max_seq=64, request_ring_capacity=16)
    eng = ServingEngine(cfg, params, ecfg)
    rng = np.random.default_rng(0)
    for rid in range(n_requests):
        req = Request(rid=rid,
                      prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                      max_new_tokens=4)
        assert eng.submit(req)
    return eng


def test_engine_completes_all_requests():
    eng = _engine()
    metrics = eng.run(max_ticks=400)
    assert metrics["completed"] == 6
    assert metrics["admitted"] == 6
    assert metrics["tokens_out"] >= 6 * 4


def test_engine_conserves_pages():
    eng = _engine()
    eng.run(max_ticks=400)
    free = 0
    while eng.free_pages.dequeue(timeout=0.0) is not None:
        free += 1
    assert free == eng.ecfg.num_pages    # every page returned exactly once


def test_engine_page_pressure_backpressure():
    # one page total: requests need 1 page → serialized admission
    eng = _engine(n_requests=4, num_pages=1, max_slots=2)
    metrics = eng.run(max_ticks=800)
    assert metrics["completed"] == 4
    assert metrics["page_stalls"] > 0    # RETRY path engaged
