"""Simulated 64-bit atomic shared memory.

The queue algorithms in this package are written as per-thread state machines
that issue *atomic instructions* against this memory.  Each instruction is
executed indivisibly by the scheduler (`repro.core.sim`), which models the
sequentially-consistent-at-atomic-granularity semantics the paper assumes for
GPU global memory with device-scope atomics.

Primitives match what the paper uses on CDNA2/3 hardware:

* ``load`` / ``store``      — 64-bit atomic load/store,
* ``faa``                   — fetch-and-add (returns the old value),
* ``cas``                   — single-width 64-bit compare-and-swap,
* ``consume``               — the paper's CONSUME: atomically set the entry
                              word's Index field to ⊥_c *without changing the
                              other packed fields* (§ III-B-c),
* ``fetch_or``/``fetch_and``— bit-set/clear RMWs (Enq-bit publication).

The memory also keeps per-array atomic-traffic counters so the benchmarks can
report how many *hot-word* atomics each design issues per successful
operation — the quantity wave-batching (Fig. 1) is designed to reduce.
"""

from __future__ import annotations

from collections import defaultdict
from typing import Dict

import numpy as np

from .packed import MASK64, EntryFormat


class AtomicMemory:
    """Named uint64 arrays with atomic RMW primitives and traffic counters."""

    def __init__(self) -> None:
        self._arrays: Dict[str, np.ndarray] = {}
        self.op_counts: Dict[str, int] = defaultdict(int)       # by primitive
        self.word_traffic: Dict[str, int] = defaultdict(int)    # by array name
        self.rmw_traffic: Dict[str, int] = defaultdict(int)     # RMWs only

    # -- allocation --------------------------------------------------------

    def alloc(self, name: str, size: int, fill: int = 0) -> None:
        if name in self._arrays:
            raise ValueError(f"array {name!r} already allocated")
        self._arrays[name] = np.full(size, np.uint64(fill & MASK64), dtype=np.uint64)

    def free_all(self) -> None:
        self._arrays.clear()

    def array(self, name: str) -> np.ndarray:
        return self._arrays[name]

    # -- primitives ---------------------------------------------------------

    def _count(self, kind: str, name: str) -> None:
        self.op_counts[kind] += 1
        self.word_traffic[name] += 1
        if kind in ("faa", "cas"):
            self.rmw_traffic[name] += 1

    def load(self, name: str, i: int) -> int:
        self._count("load", name)
        return int(self._arrays[name][i])

    def store(self, name: str, i: int, v: int) -> None:
        self._count("store", name)
        self._arrays[name][i] = np.uint64(v & MASK64)

    def faa(self, name: str, i: int, delta: int) -> int:
        """Fetch-and-add; returns the pre-add value.  Wraps mod 2^64."""
        self._count("faa", name)
        a = self._arrays[name]
        old = int(a[i])
        a[i] = np.uint64((old + delta) & MASK64)
        return old

    def cas(self, name: str, i: int, expected: int, desired: int) -> bool:
        self._count("cas", name)
        a = self._arrays[name]
        if int(a[i]) == (expected & MASK64):
            a[i] = np.uint64(desired & MASK64)
            return True
        return False

    def fetch_or(self, name: str, i: int, mask: int) -> int:
        self._count("faa", name)  # counts as one RMW atomic
        a = self._arrays[name]
        old = int(a[i])
        a[i] = np.uint64((old | mask) & MASK64)
        return old

    def fetch_and(self, name: str, i: int, mask: int) -> int:
        self._count("faa", name)
        a = self._arrays[name]
        old = int(a[i])
        a[i] = np.uint64((old & mask) & MASK64)
        return old

    def consume(self, name: str, i: int, fmt: EntryFormat) -> int:
        """CONSUME (§ III-B-c): atomically mark the slot's Index field ⊥_c,
        preserving cycle/safe/enq.  Returns the *old* word (whose Index field
        is the dequeued payload index)."""
        self._count("cas", name)  # single RMW on the slot word
        a = self._arrays[name]
        old = int(a[i])
        a[i] = np.uint64(fmt.with_idx(old, fmt.idx_botc))
        return old

    # -- signed helpers (Threshold is a signed quantity in sCQ) -------------

    @staticmethod
    def to_signed(v: int) -> int:
        return v - (1 << 64) if v >= (1 << 63) else v

    @staticmethod
    def from_signed(v: int) -> int:
        return v & MASK64

    # -- metrics -------------------------------------------------------------

    def reset_counters(self) -> None:
        self.op_counts.clear()
        self.word_traffic.clear()
        self.rmw_traffic.clear()

    def total_atomics(self) -> int:
        return sum(self.op_counts.values())
