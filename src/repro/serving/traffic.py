"""Open-loop traffic generation for the serving harness
(``benchmarks/bench_serving.py``).

Open-loop means arrival times are drawn ahead of time and never react to
service state — the engine falls behind under overload instead of the
generator politely slowing down, which is what makes goodput-vs-offered-
load curves meaningful (a closed loop self-throttles and hides the knee).

The process is bursty power-law on top of a Poisson floor: a baseline
``rate``-requests/tick Poisson stream, plus burst events every
``burst_period`` ticks in expectation whose sizes follow a discrete
Pareto tail ``P(size ≥ s) ∝ s^{-(alpha-1)}`` — the heavy-tailed
fine-grained arrival pattern Wang et al.'s dynamic load-balancing
argument targets (PAPERS.md).  Everything is driven by one
``numpy.random.default_rng(seed)``: the same config always replays the
same trace, so host-pool and device-admission runs see identical
arrivals and their admitted sets are comparable request-for-request.

Tenants round-robin over burst events (a burst is one tenant's flash
crowd, not uniformly smeared), and each arrival flips urgent with
``urgent_frac``.  ``slo_ticks`` defines goodput: a request counts iff it
completes within ``slo_ticks`` engine ticks of submission.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

__all__ = ["Arrival", "TrafficConfig", "generate_trace", "offered_load"]


@dataclasses.dataclass(frozen=True)
class Arrival:
    tick: int              # engine tick at which the request is submitted
    tenant: int
    priority: int          # 0 = urgent admission class
    prompt_len: int
    max_new_tokens: int


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    ticks: int = 200               # arrival horizon (engine ticks)
    rate: float = 0.5              # baseline offered load (requests/tick)
    burst_alpha: float = 2.2       # Pareto tail exponent (>1; lower=heavier)
    burst_period: int = 32         # mean ticks between burst events
    burst_max: int = 8             # burst-size clamp (bounded tails on CPU)
    tenants: int = 1
    urgent_frac: float = 0.25
    prompt_len: Tuple[int, int] = (4, 12)       # inclusive range
    max_new_tokens: Tuple[int, int] = (2, 8)    # inclusive range
    slo_ticks: int = 120           # completion deadline for goodput
    seed: int = 0


def _pareto_size(rng: np.random.Generator, alpha: float, clamp: int) -> int:
    """Discrete Pareto burst size ≥ 1: inverse-CDF of the continuous
    Pareto(alpha-1) tail, floored and clamped."""
    u = rng.random()
    s = int(np.floor((1.0 - u) ** (-1.0 / (alpha - 1.0))))
    return max(1, min(s, clamp))


def generate_trace(tc: TrafficConfig) -> List[Arrival]:
    """The full arrival list, sorted by tick (stable: arrivals within a
    tick keep generation order)."""
    rng = np.random.default_rng(tc.seed)
    out: List[Arrival] = []
    burst_tenant = 0

    def emit(tick: int, tenant: int) -> None:
        pri = 0 if rng.random() < tc.urgent_frac else 1
        plen = int(rng.integers(tc.prompt_len[0], tc.prompt_len[1] + 1))
        newt = int(rng.integers(tc.max_new_tokens[0],
                                tc.max_new_tokens[1] + 1))
        out.append(Arrival(tick, tenant, pri, plen, newt))

    for t in range(tc.ticks):
        for _ in range(int(rng.poisson(tc.rate))):
            emit(t, int(rng.integers(tc.tenants)))
        if rng.random() < 1.0 / tc.burst_period:
            # one tenant's flash crowd; tenants take turns so every lane
            # sees bursts even on short horizons
            for _ in range(_pareto_size(rng, tc.burst_alpha, tc.burst_max)):
                emit(t, burst_tenant)
            burst_tenant = (burst_tenant + 1) % tc.tenants
    out.sort(key=lambda a: a.tick)
    return out


def offered_load(trace: List[Arrival], tc: TrafficConfig) -> float:
    """Realized offered load (requests/tick) of a generated trace."""
    return len(trace) / max(1, tc.ticks)
