"""In-loop trace planes: device-resident per-round telemetry for the fused
engines (DESIGN.md § 7).

The fused megarounds (PRs 3-5) run the whole dequeue → step → publish
cycle inside a jitted ``lax.while_loop`` with host sync only at
quiescence — exactly where the host runtime used to observe per-round
occupancy, claim imbalance, and pop order, it is now blind.  A
``TracePlane`` restores that visibility without re-introducing per-round
host syncs: a fixed-capacity ring of per-round records carried as *extra*
loop state, written by one masked scatter per round (``trace_record``)
and drained on the host at each sync/heartbeat (``drain_plane``).

Layout (all int32, static shapes, while_loop/shard_map compatible).  The
plane is physically TWO packed arrays plus the cursor — recording is two
row scatters per round, and the loop carries three extra leaves, not
nine (the dominant in-loop cost on dispatch-bound backends is per-op
overhead and carry count, not bytes — see the overhead budget in
DESIGN.md § 7.5):

* ``scalars``  (C, 5)    — per-round scalar lanes, columns =
  ``(round, imbalance, min_key, max_key, overflow)``
* ``pershard`` (C, S, 3) — per-shard lanes, last axis =
  ``(pops, pushes, occupancy)``
* ``count``    ()        — total rounds ever recorded (the write cursor;
  ``count > C`` means the oldest records were overwritten — wraparound is
  *flagged at drain*, never an error)

Named accessors (``tp.round``, ``tp.pops``, …) expose the logical
columns; the logical record is:

* ``round``      (C,)    — global round index occupying each slot
* ``pops``       (C, S)  — per-shard items claimed this round
* ``pushes``     (C, S)  — per-shard children published this round
* ``occupancy``  (C, S)  — per-shard occupancy *after* the round
* ``imbalance``  (C,)    — max − min of the round's per-shard pops (the
  claim-schedule imbalance; 0 at one shard)
* ``min_key`` / ``max_key`` (C,) — extrema of the keys popped this round
  (priority engines; the rank-error proxy ``obs.analyze`` consumes) or of
  the payloads claimed (FIFO engines).  ``KEY_SENTINEL``/-``KEY_SENTINEL``
  when the round popped nothing.
* ``overflow``   (C,)    — the round flagged capacity overflow

Chip-level engines record with S = 1.  The planes are pure data: recording
costs a handful of masked ``at[slot].set`` scatters per round and zero
collectives — per-shard quantities recorded at mesh scope are already
replicated values (claim schedules, gathered push counts, psum'd meta), so
every shard writes the identical plane.

``drain_plane`` converts the device arrays into host ``RoundRecord``s
(newest ``min(count - prev_count, C)`` rounds, in round order) and reports
how many records the ring dropped since the previous drain.  ``SyncPoint``
is the unified host-sync heartbeat schema shared by every engine's
``sync_log`` (satellite: fusedrounds and meshrounds used to record
different shapes): dataclass fields plus dict-style access for the
pre-unification callers that indexed ``e["rounds"]``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "KEY_SENTINEL", "RoundRecord", "SyncPoint", "Telemetry", "TracePlane",
    "drain_plane", "masked_min_max", "trace_init", "trace_record",
]

# min_key when a round popped nothing (max_key gets -KEY_SENTINEL): an
# int32 extremum that cannot collide with live keys (heap keys are
# < KEY_INF = 2^30 - 1 and payloads are < IDX_BOT).
KEY_SENTINEL = 2 ** 31 - 1


class TracePlane(NamedTuple):
    """Fixed-capacity device ring of per-round records (see module doc).
    Packed: 3 pytree leaves, 2 row scatters per recorded round."""
    scalars: jax.Array      # (C, 5): round, imbalance, min_key, max_key,
    #                                 overflow
    pershard: jax.Array     # (C, S, 3): pops, pushes, occupancy
    count: jax.Array        # () int32 — total records ever written

    @property
    def capacity(self) -> int:
        return self.scalars.shape[0]

    @property
    def shards(self) -> int:
        return self.pershard.shape[1]

    # logical-column accessors (device or host arrays alike)
    @property
    def round(self):
        return self.scalars[:, 0]

    @property
    def imbalance(self):
        return self.scalars[:, 1]

    @property
    def min_key(self):
        return self.scalars[:, 2]

    @property
    def max_key(self):
        return self.scalars[:, 3]

    @property
    def overflow(self):
        return self.scalars[:, 4]

    @property
    def pops(self):
        return self.pershard[:, :, 0]

    @property
    def pushes(self):
        return self.pershard[:, :, 1]

    @property
    def occupancy(self):
        return self.pershard[:, :, 2]


def trace_init(capacity: int, shards: int = 1) -> TracePlane:
    """Empty plane for ``capacity`` round records over ``shards`` shards."""
    c, s = int(capacity), int(shards)
    if c < 1:
        raise ValueError(f"trace capacity must be >= 1, got {c}")
    if s < 1:
        raise ValueError(f"trace shards must be >= 1, got {s}")
    empty = jnp.asarray([-1, 0, KEY_SENTINEL, -KEY_SENTINEL, 0], jnp.int32)
    return TracePlane(
        scalars=jnp.tile(empty, (c, 1)),
        pershard=jnp.zeros((c, s, 3), jnp.int32),
        count=jnp.int32(0),
    )


def trace_record(tp: TracePlane, round_idx, pops, pushes, occupancy,
                 min_key, max_key, overflow) -> TracePlane:
    """Write one round record at the ring cursor (``count % C``) and bump
    the cursor.  Pure function of traced values — callable inside
    ``lax.while_loop``/``shard_map`` bodies.  ``pops``/``pushes``/
    ``occupancy`` are (S,) vectors ((,) scalars are promoted for S = 1);
    the claim imbalance is derived here so every engine records the same
    definition (max − min per-shard pops)."""
    s = tp.shards
    pops = jnp.broadcast_to(jnp.asarray(pops, jnp.int32).reshape(-1), (s,))
    pushes = jnp.broadcast_to(jnp.asarray(pushes, jnp.int32).reshape(-1),
                              (s,))
    occupancy = jnp.broadcast_to(
        jnp.asarray(occupancy, jnp.int32).reshape(-1), (s,))
    slot = jnp.remainder(tp.count, jnp.int32(tp.capacity))
    row = jnp.stack([
        jnp.asarray(round_idx, jnp.int32).reshape(()),
        jnp.max(pops) - jnp.min(pops),
        jnp.asarray(min_key, jnp.int32).reshape(()),
        jnp.asarray(max_key, jnp.int32).reshape(()),
        jnp.asarray(overflow, jnp.int32).reshape(()),
    ])
    return TracePlane(
        scalars=tp.scalars.at[slot].set(row),
        pershard=tp.pershard.at[slot].set(
            jnp.stack([pops, pushes, occupancy], axis=-1)),
        count=tp.count + 1,
    )


def masked_min_max(keys, valid) -> Tuple[jax.Array, jax.Array]:
    """Extrema of ``keys`` where ``valid`` — the per-round min/max popped
    key (or payload) the plane records; sentinels when nothing popped."""
    valid = jnp.asarray(valid).astype(bool)
    keys = jnp.asarray(keys, jnp.int32)
    mn = jnp.min(jnp.where(valid, keys, KEY_SENTINEL))
    mx = jnp.max(jnp.where(valid, keys, -KEY_SENTINEL))
    return mn, mx


@dataclasses.dataclass
class RoundRecord:
    """One drained per-round record — the host-side face of a plane slot,
    timestamped at drain (in-loop rounds have no host clock: that is the
    point of the fused engines; ``wall_time`` is when the record became
    visible)."""
    engine: str
    round: int
    pops: List[int]
    pushes: List[int]
    occupancy: List[int]
    imbalance: int
    min_key: int
    max_key: int
    overflow: bool
    sync: int            # index of the host sync that drained this record
    wall_time: float     # drain timestamp (time.time())

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RoundRecord":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})


def drain_plane(tp: TracePlane, prev_count: int, *, engine: str = "fused",
                sync: int = 0, wall_time: float = None
                ) -> Tuple[List[RoundRecord], int, int]:
    """Read the plane back and extract the records written since
    ``prev_count``, oldest first.  Returns ``(records, new_count,
    dropped)`` where ``dropped`` counts rounds whose slots were overwritten
    before this drain (ring capacity < rounds between syncs)."""
    cap = tp.capacity
    host = jax.device_get(tp)          # one batched transfer, all leaves
    count = int(host.count)
    fresh = count - int(prev_count)
    if fresh <= 0:
        return [], count, 0
    dropped = max(fresh - cap, 0)
    keep = fresh - dropped
    wall_time = time.time() if wall_time is None else wall_time
    slots = np.arange(count - keep, count) % cap
    sync = int(sync)
    records = [
        RoundRecord(engine=engine, round=r, pops=p, pushes=pu, occupancy=o,
                    imbalance=im, min_key=mn, max_key=mx, overflow=bool(of),
                    sync=sync, wall_time=wall_time)
        for r, p, pu, o, im, mn, mx, of in zip(
            host.round[slots].tolist(), host.pops[slots].tolist(),
            host.pushes[slots].tolist(), host.occupancy[slots].tolist(),
            host.imbalance[slots].tolist(), host.min_key[slots].tolist(),
            host.max_key[slots].tolist(), host.overflow[slots].tolist())]
    return records, count, dropped


@dataclasses.dataclass
class SyncPoint:
    """One host-sync heartbeat — the unified ``sync_log`` entry every
    engine records (fused: one per ``sync_every`` chunk; legacy: one per
    round).  Dict-style access keeps pre-unification callers working."""
    rounds: int
    occupancy: int
    wall_time: float
    host_syncs: int = 0

    def __getitem__(self, key: str):
        return getattr(self, key)

    def get(self, key: str, default=None):
        return getattr(self, key, default)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class Telemetry:
    """Host-side telemetry collector for one engine instance.

    Pass ``telemetry=Telemetry(...)`` to any fused round engine: the
    engine carries a ``TracePlane`` of ``capacity`` records through its
    megaround loop and drains it here at every host sync.  ``records``
    accumulates drained ``RoundRecord``s across ``run`` calls until
    ``reset()``; ``dropped`` counts ring-overwritten rounds (capacity
    smaller than the rounds between two syncs); ``sync_points`` mirrors
    the engine's ``sync_log``.  With ``telemetry=None`` (every engine's
    default) the loop compiles to the exact pre-telemetry carry —
    bit-identity is asserted by tests on all four fused engines.

    ``registry`` (a ``MetricsRegistry``; one is created when not given)
    receives the engine's end-of-drive counters under stable
    ``engine.<stat>`` keys.
    """

    def __init__(self, capacity: int = 1024, *, engine: str = "fused",
                 registry=None) -> None:
        if int(capacity) < 1:
            raise ValueError(f"telemetry capacity must be >= 1, "
                             f"got {capacity}")
        self.capacity = int(capacity)
        self.engine = engine
        if registry is None:
            from .metrics import MetricsRegistry
            registry = MetricsRegistry()
        self.registry = registry
        self.reset()

    def reset(self) -> None:
        self._records: List[RoundRecord] = []
        self._pending: List[Tuple[TracePlane, int, int, float]] = []
        self.sync_points: List[SyncPoint] = []
        self.dropped = 0
        self._count = 0

    def begin_run(self) -> None:
        """Called by the engine at the top of ``run``: a fresh plane means
        a fresh cursor (records of previous runs are kept)."""
        self._count = 0

    @property
    def records(self) -> List[RoundRecord]:
        """Drained ``RoundRecord``s, oldest first.  Materialized lazily:
        ``drain`` only pulls the plane to host (the part that must sit at
        the engine's sync point); formatting rows into records is
        analysis-time work and happens on first access here."""
        if self._pending:
            for host, prev, sync, wall_time in self._pending:
                recs, _, _ = drain_plane(host, prev, engine=self.engine,
                                         sync=sync, wall_time=wall_time)
                self._records.extend(recs)
            self._pending = []
        return self._records

    def drain(self, tp: TracePlane, *, sync: int = 0,
              wall_time: float = None) -> int:
        """Pull the plane to host and account for it; returns the number
        of fresh records (the objects materialize on ``.records``)."""
        host = jax.device_get(tp)
        count = int(host.count)
        fresh = count - self._count
        if fresh <= 0:
            return 0
        dropped = max(fresh - host.capacity, 0)
        self._pending.append(
            (host, self._count, sync,
             time.time() if wall_time is None else wall_time))
        self._count = count
        self.dropped += dropped
        if dropped:
            self.registry.counter(f"{self.engine}.trace_dropped", dropped)
        return fresh - dropped

    def heartbeat(self, point: SyncPoint) -> None:
        self.sync_points.append(point)

    def finish(self, stats: Dict[str, int]) -> None:
        """Absorb the engine's end-of-drive stats into the registry under
        stable ``engine.<stat>`` gauges."""
        for k, v in stats.items():
            self.registry.gauge(f"{self.engine}.{k}", v)
