"""Host-thread twin of G-PQ: a bounded, thread-safe deadline/priority pool
(DESIGN.md § 5.5).

``HostPriorityPool`` is to ``GPQ`` what ``HostRing`` is to G-LFQ — the same
scheduling semantics for real host threads, with a mutex standing in for
the latch and a binary heap for the applied d-ary heap.  Keys are integers,
smaller = more urgent; ties break by admission sequence (FIFO within a
key), so EDF admission is deterministic.  The serving engine's EDF
admission path (§ 3) uses it as the request queue: page-stalled requests
re-enter with their *original* deadline, so they age toward urgency as new
arrivals take later deadlines.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Optional


class HostPriorityPool:
    """Bounded blocking min-priority pool: ``enqueue(item, key=, timeout=)``,
    ``dequeue(timeout=)``, ``peek_key()``, ``empty()``, ``close()``."""

    def __init__(self, capacity: int) -> None:
        self.capacity = capacity
        self._heap: list = []
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self.closed = False
        self.metrics = {"enqueues": 0, "dequeues": 0, "rejects": 0}

    def enqueue(self, item, key: int = 0,
                timeout: Optional[float] = None) -> bool:
        with self._not_full:
            deadline = None if timeout is None else time.time() + timeout
            while len(self._heap) >= self.capacity and not self.closed:
                remaining = None if deadline is None else deadline - time.time()
                if remaining is not None and remaining <= 0:
                    self.metrics["rejects"] += 1
                    return False
                self._not_full.wait(remaining)
            if self.closed:
                return False
            heapq.heappush(self._heap, (key, next(self._seq), item))
            self.metrics["enqueues"] += 1
            self._not_empty.notify()
            return True

    def dequeue(self, timeout: Optional[float] = None):
        with self._not_empty:
            deadline = None if timeout is None else time.time() + timeout
            while not self._heap and not self.closed:
                remaining = None if deadline is None else deadline - time.time()
                if remaining is not None and remaining <= 0:
                    return None
                self._not_empty.wait(remaining)
            if not self._heap:
                return None  # closed and drained
            _, _, item = heapq.heappop(self._heap)
            self.metrics["dequeues"] += 1
            self._not_full.notify()
            return item

    def peek_key(self) -> Optional[int]:
        with self._lock:
            return self._heap[0][0] if self._heap else None

    def empty(self) -> bool:
        with self._lock:
            return not self._heap

    def close(self) -> None:
        with self._lock:
            self.closed = True
            self._not_full.notify_all()
            self._not_empty.notify_all()
