"""Core transformer layers, functional style.

Params are plain dicts of jnp arrays; every constructor has a matching
``*_specs`` function returning the same-structure tree of PartitionSpec for
the production mesh (DP/FSDP over "data"(+"pod"), TP over "model").

All attention variants required by the assigned pool live in one code path:
GQA, sliding windows (per-layer *dynamic* window scalar so heterogeneous
local/global stacks stay inside a single lax.scan), logit soft-capping,
bidirectional (encoder) masks, and cross-attention.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..jaxcompat import current_mesh

Params = Dict[str, jax.Array]

# Axis conventions: activations (batch, seq, d); batch sharded over
# ("pod","data") ≡ "dp"; hidden/heads sharded over "model".
DP = ("pod", "data")  # collapsed to ("data",) on single-pod meshes


def dp_axes(mesh_axes: Tuple[str, ...]):
    return tuple(a for a in DP if a in mesh_axes)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _dense(key, shape, scale_axis: int = 0, dtype=jnp.bfloat16):
    scale = 1.0 / (shape[scale_axis] ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, hd); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attn_params(key, cfg: ArchConfig, cross: bool = False) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    pfx = "c" if cross else ""
    return {
        f"{pfx}wq": _dense(ks[0], (d, h * hd)),
        f"{pfx}wk": _dense(ks[1], (d, kv * hd)),
        f"{pfx}wv": _dense(ks[2], (d, kv * hd)),
        f"{pfx}wo": _dense(ks[3], (h * hd, d)),
    }


def attn_specs(cfg: ArchConfig, cross: bool = False, fsdp_axis=None):
    f = fsdp_axis
    pfx = "c" if cross else ""
    return {
        f"{pfx}wq": P(f, "model"),
        f"{pfx}wk": P(f, "model"),
        f"{pfx}wv": P(f, "model"),
        f"{pfx}wo": P("model", f),
    }


def _mask_bias(q_pos, k_pos, window, causal: bool):
    """Additive mask: causal + optional sliding window (dynamic scalar)."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), jnp.bool_)
    if causal:
        ok = ok & (k_pos[None, :] <= q_pos[:, None])
    # window: 0 = full; else key must be within `window` of the query
    ok = ok & jnp.where(window > 0,
                        k_pos[None, :] > q_pos[:, None] - jnp.maximum(window, 1),
                        True)
    return jnp.where(ok, 0.0, -1e30)


FLASH_BLOCK_Q = 512
FLASH_BLOCK_K = 512
FLASH_MIN_SEQ = 2048  # use the blocked path above this many keys


def _pin(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint iff a mesh with the named axes is ambient
    and every sharded dim divides; no-op otherwise (tests run mesh-less)."""
    mesh = current_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    for i, ax in enumerate(spec):
        if ax is None or ax is P.UNCONSTRAINED:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            if a not in mesh.axis_names:
                return x
            size *= mesh.shape[a]
        if x.shape[i] % size:
            return x
    return jax.lax.with_sharding_constraint(x, spec)


def _blk_logits(static, qb, kb, qpb, kpb, window):
    """One (q-block, k-block) logits tile with scaling, soft-capping and
    causal/window bias.  qb (b,bq,kv,rep,hd); kb (b,bk,kv,hd)."""
    cap, causal, scale = static
    raw = jnp.einsum("bqgrd,bkgd->bgrqk", qb, kb).astype(jnp.float32) * scale
    capped = softcap(raw, cap)
    bias = _mask_bias(qpb, kpb, window, causal)
    return raw, capped + bias[None, None, None, :, :]




def _q_block_spec(kvh: int) -> P:
    """Layout for the per-iteration q block inside the flash scans, aligned
    with `_kv_stack_spec`: when kv-heads divide the TP degree both q and K/V
    shard on the head dim (tiles shrink, no per-tile resharding); otherwise
    shard the query rows."""
    U = P.UNCONSTRAINED
    mesh = current_mesh()
    model = (mesh.shape.get("model", 1)
             if mesh is not None and mesh.axis_names else 1)
    if model > 1 and kvh % model == 0:
        return P(U, U, "model", U, U)
    return P(U, "model", U, U, U)

def _kv_stack_spec(kvh: int) -> P:
    """Layout for the stacked K/V blocks feeding the flash scans: kv-heads
    over "model" when divisible (memory /TP, slices local), else fully
    gathered (one gather per layer — still far better than the per-tile
    re-gathers the partitioner produces if left unpinned)."""
    U = P.UNCONSTRAINED
    mesh = current_mesh()
    model = (mesh.shape.get("model", 1)
             if mesh is not None and mesh.axis_names else 1)
    if model > 1 and kvh % model == 0:
        return P(None, U, None, "model", None)
    return P(None, U, None, None, None)

def _flash_fwd_impl(static, q, k, v, q_pos, k_pos, window):
    b, sq, kvh, rep, hd = q.shape
    sk = k.shape[1]
    bq = min(FLASH_BLOCK_Q, sq)
    bk = min(FLASH_BLOCK_K, sk)
    nq, nk = sq // bq, sk // bk
    qg = jnp.moveaxis(q.reshape(b, nq, bq, kvh, rep, hd), 1, 0)
    kg = jnp.moveaxis(k.reshape(b, nk, bk, kvh, hd), 1, 0)
    vg = jnp.moveaxis(v.reshape(b, nk, bk, kvh, hd), 1, 0)
    # Pin the stacked K/V blocks so the scan slices locally — one gather
    # per layer instead of one per (q-block × k-block) iteration (48% of
    # yi-prefill's collective term); head-sharded when kv divides the TP
    # degree so prefill memory does not regress.
    U = P.UNCONSTRAINED
    kg = _pin(kg, _kv_stack_spec(kvh))
    vg = _pin(vg, _kv_stack_spec(kvh))
    qp = q_pos.reshape(nq, bq)
    kp = k_pos.reshape(nk, bk)

    def q_block(_, inp):
        qb, qpb = inp
        # shard the query block so every logits tile (and its HBM
        # round-trip) shrinks by the TP degree (§Perf hillclimb)
        qb = _pin(qb, _q_block_spec(kvh))

        def k_block(carry, kin):
            m, l, acc = carry
            kb, vb, kpb = kin
            _, s = _blk_logits(static, qb, kb, qpb, kpb, window)
            new_m = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - new_m[..., None])
            corr = jnp.exp(m - new_m)
            l = l * corr + p.sum(axis=-1)
            acc = (acc * corr[..., None]
                   + jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(vb.dtype),
                                vb).astype(jnp.float32))
            return (new_m, l, acc), None

        m0 = jnp.full((b, kvh, rep, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kvh, rep, bq), jnp.float32)
        a0 = jnp.zeros((b, kvh, rep, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_block, (m0, l0, a0), (kg, vg, kp))
        l = jnp.maximum(l, 1e-30)
        out = (acc / l[..., None]).astype(q.dtype)       # (b,kv,rep,bq,hd)
        return None, (out.transpose(0, 3, 1, 2, 4),      # (b,bq,kv,rep,hd)
                      m.transpose(0, 3, 1, 2), l.transpose(0, 3, 1, 2))

    _, (outs, ms, ls) = jax.lax.scan(q_block, None, (qg, qp))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, kvh, rep, hd)
    m = jnp.moveaxis(ms, 0, 1).reshape(b, sq, kvh, rep)
    l = jnp.moveaxis(ls, 0, 1).reshape(b, sq, kvh, rep)
    return out, m, l


import functools as _ft


@_ft.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_core(static, q, k, v, q_pos, k_pos, window):
    out, _, _ = _flash_fwd_impl(static, q, k, v, q_pos, k_pos, window)
    return out


def _flash_core_fwd(static, q, k, v, q_pos, k_pos, window):
    out, m, l = _flash_fwd_impl(static, q, k, v, q_pos, k_pos, window)
    return out, (q, k, v, out, m, l, q_pos, k_pos, window)


def _flash_core_bwd(static, res, dout):
    """Flash backward: recompute the logits tile per (k-block, q-block) pair
    using the saved per-row (m, l) statistics — O(S·blk) memory, never the
    full S² tensor.  Outer scan over k blocks emits (dk, dv) blocks and
    carries the full dq accumulator."""
    cap, causal, scale = static
    q, k, v, out, m, l, q_pos, k_pos, window = res
    b, sq, kvh, rep, hd = q.shape
    sk = k.shape[1]
    bq = min(FLASH_BLOCK_Q, sq)
    bk = min(FLASH_BLOCK_K, sk)
    nq, nk = sq // bq, sk // bk
    d_row = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                              # (b,sq,kv,rep)
    qg = jnp.moveaxis(q.reshape(b, nq, bq, kvh, rep, hd), 1, 0)
    dg = jnp.moveaxis(dout.reshape(b, nq, bq, kvh, rep, hd), 1, 0)
    mg = jnp.moveaxis(m.reshape(b, nq, bq, kvh, rep), 1, 0)
    lg = jnp.moveaxis(l.reshape(b, nq, bq, kvh, rep), 1, 0)
    Dg = jnp.moveaxis(d_row.reshape(b, nq, bq, kvh, rep), 1, 0)
    kg = jnp.moveaxis(k.reshape(b, nk, bk, kvh, hd), 1, 0)
    vg = jnp.moveaxis(v.reshape(b, nk, bk, kvh, hd), 1, 0)
    qp = q_pos.reshape(nq, bq)
    kp = k_pos.reshape(nk, bk)

    dq0 = jnp.zeros((nq, b, bq, kvh, rep, hd), jnp.float32)

    U = P.UNCONSTRAINED

    def k_block2(dq_acc, kin):
        kb, vb, kpb = kin

        def q_block(carry, qin):
            dkb, dvb = carry
            qb, doutb, mb, lb, Db, qpb = qin
            qb = _pin(qb, _q_block_spec(kvh))
            doutb = _pin(doutb, _q_block_spec(kvh))
            raw, s = _blk_logits(static, qb, kb, qpb, kpb, window)
            p = jnp.exp(s - mb.transpose(0, 2, 3, 1)[..., None]) \
                / lb.transpose(0, 2, 3, 1)[..., None]
            doutg = doutb.transpose(0, 2, 3, 1, 4)
            dvb = dvb + jnp.einsum("bgrqk,bgrqd->bkgd", p,
                                   doutg.astype(p.dtype))
            dp = jnp.einsum("bgrqd,bkgd->bgrqk", doutg.astype(vb.dtype), vb)
            ds = p * (dp.astype(jnp.float32)
                      - Db.transpose(0, 2, 3, 1)[..., None])
            if cap:
                ds = ds * (1.0 - jnp.tanh(raw / cap) ** 2)
            ds = ds * scale
            dq_blk = jnp.einsum("bgrqk,bkgd->bqgrd", ds.astype(kb.dtype), kb)
            dkb = dkb + jnp.einsum("bgrqk,bqgrd->bkgd", ds.astype(qb.dtype), qb)
            return (dkb, dvb), dq_blk.astype(jnp.float32)

        z = jnp.zeros((b, bk, kvh, hd), jnp.float32)
        (dkb, dvb), dq_blocks = jax.lax.scan(
            q_block, (z, z), (qg, dg, mg, lg, Dg, qp))
        return dq_acc + dq_blocks, (dkb, dvb)

    dq_all, (dks, dvs) = jax.lax.scan(k_block2, dq0, (kg, vg, kp))
    dq = jnp.moveaxis(dq_all, 0, 1).reshape(b, sq, kvh, rep, hd).astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, sk, kvh, hd).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, sk, kvh, hd).astype(v.dtype)
    return dq, dk, dv, None, None, None


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def _flash_attention(q, k, v, cfg: ArchConfig, q_pos, k_pos, window):
    """Blocked online-softmax attention (flash-style) with a custom VJP that
    recomputes logits tiles in the backward pass: O(S·blk) memory in both
    directions instead of O(S²) saved residuals.  GQA without materializing
    repeated KV: q grouped (b, sq, kv, rep, hd) vs k/v (b, sk, kv, hd).

    On a TPU backend with a *static* window (uniform-pattern inference
    forward), the fused Pallas kernel (`kernels.flash_attn`) takes over:
    tiles never leave VMEM — the remedy for the memory term EXPERIMENTS.md
    § Perf identifies.  The XLA path below remains the differentiable /
    CPU / traced-window implementation; both are validated against the same
    oracle."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    if jax.default_backend() == "tpu" and isinstance(window, int):
        from ..kernels.flash_attn import flash_attention as _pallas_flash
        out = _pallas_flash(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=cfg.causal,
                            window=int(window),
                            softcap_val=float(cfg.attn_softcap),
                            interpret=False)
        return out.transpose(0, 2, 1, 3).reshape(b, sq, h * hd)
    static = (float(cfg.attn_softcap), bool(cfg.causal), 1.0 / (hd ** 0.5))
    out = _flash_core(static, q.reshape(b, sq, kvh, rep, hd), k, v,
                      q_pos, k_pos, window)
    return out.reshape(b, sq, h * hd)


def attention(p: Params, x: jax.Array, cfg: ArchConfig, *,
              positions: jax.Array, window, kv_override=None,
              cache: Optional[Tuple] = None, cross: bool = False):
    """x: (B, S, d).  kv_override: (B, Skv, d) for cross-attention.
    cache: (k, v, cur_len) for decode — k/v (B, Sc, kv, hd).
    Returns (out, new_cache)."""
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    pfx = "c" if cross else ""
    q = (x @ p[f"{pfx}wq"]).reshape(b, s, h, hd)
    src = kv_override if kv_override is not None else x
    k = (src @ p[f"{pfx}wk"]).reshape(b, src.shape[1], kv, hd)
    v = (src @ p[f"{pfx}wv"]).reshape(b, src.shape[1], kv, hd)
    if not cross:
        q = rope(q, positions, cfg.rope_theta)
        kpos = positions if cache is None else positions  # decode: pos of new tok
        k = rope(k, kpos, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        ck, cv, cur = cache
        sc = ck.shape[1]
        # Ring-buffer write at cur % Sc.  Window caches are sized Sc ==
        # window and wrap; full caches have Sc >= max len (mod is a no-op).
        idx = cur % sc
        if b == 1 and s == 1:
            # B=1 long-context decode: the cache is sequence-sharded across
            # the whole mesh.  A dynamic_update_slice at a traced index on a
            # sharded dim makes GSPMD rematerialize the full cache (f32
            # gathers, 43 GB/step at 500k) — a mask-select write is fully
            # shardable elementwise instead (§Perf hillclimb #3).
            sel = (jnp.arange(sc, dtype=jnp.int32) == idx)[None, :, None, None]
            ck = jnp.where(sel, k.astype(ck.dtype), ck)
            cv = jnp.where(sel, v.astype(cv.dtype), cv)
        else:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, idx, 0, 0))
        k, v = ck, cv
        new_cache = (ck, cv, cur + s)
        # Slot j holds the most recent token p ≤ cur with p ≡ j (mod Sc):
        slot = jnp.arange(sc, dtype=jnp.int32)
        kpos = cur - ((cur - slot) % sc)
        bias = _mask_bias(positions, kpos, window, cfg.causal)
        bias = jnp.where(kpos[None, :] >= 0, bias, -1e30)  # unwritten slots
    else:
        k_positions = (jnp.arange(src.shape[1], dtype=jnp.int32)
                       if cross else positions)
        if cross:
            bias = jnp.zeros((s, src.shape[1]), jnp.float32)
        else:
            bias = _mask_bias(positions, k_positions, window, cfg.causal)
    sk = k.shape[1]
    if (cache is None and not cross and sk >= FLASH_MIN_SEQ
            and s % min(FLASH_BLOCK_Q, s) == 0 and sk % min(FLASH_BLOCK_K, sk) == 0):
        # Pin K/V to their inside-flash layout (kv heads over "model" when
        # divisible, else fully gathered) BEFORE the q/k block scans — the
        # partitioner otherwise re-gathers the sequence-sharded K/V on
        # every (q-block × k-block) iteration (§Perf: yi-34b prefill was
        # 1190 s collective-bound from exactly this).
        U = P.UNCONSTRAINED
        mesh = current_mesh()
        model_sz = (mesh.shape.get("model", 1)
                    if mesh is not None and mesh.axis_names else 1)
        kv_axis = "model" if (model_sz > 1 and kv % model_sz == 0) else None
        k = _pin(k, P(U, None, kv_axis, U))
        v = _pin(v, P(U, None, kv_axis, U))
        out = _flash_attention(q, k, v, cfg, positions, k_positions, window)
        return out @ p[f"{pfx}wo"], new_cache
    # dense path (short sequences / decode / cross) — grouped GQA einsums
    # (no materialized kv repeat)
    rep = h // kv
    qg = q.reshape(b, s, kv, rep, hd)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k).astype(jnp.float32)
    if cache is not None and b == 1:
        # B=1 long-context decode: keep the logits sequence-sharded like the
        # cache so attention needs only tiny softmax/value psums instead of
        # f32 all-gathers of the whole cache (§Perf hillclimb #3)
        mesh = current_mesh()
        if mesh is not None and mesh.axis_names:
            logits = _pin(logits, P(None, None, None, None,
                                    tuple(mesh.axis_names)))
    logits = logits / (hd ** 0.5)
    logits = softcap(logits, cfg.attn_softcap)
    logits = logits + bias[None, None, None, :, :]
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", w, v).reshape(b, s, h * hd)
    return out @ p[f"{pfx}wo"], new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_params(key, d: int, ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense(ks[0], (d, ff)),
        "w_up": _dense(ks[1], (d, ff)),
        "w_down": _dense(ks[2], (ff, d), scale_axis=0),
    }


def mlp_specs(fsdp_axis=None):
    f = fsdp_axis
    return {"w_gate": P(f, "model"), "w_up": P(f, "model"),
            "w_down": P("model", f)}


def mlp(p: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
