"""Telemetry overhead benchmark: fused engines with trace planes on vs
off (DESIGN.md § 7.5, BENCH_6 "obs" section).

The trace plane rides the megaround loop as extra carry — a handful of
masked ``at[slot].set`` scatters per round, zero extra collectives, zero
extra host syncs.  This benchmark prices that: each workload runs the
*same* fused runner twice (``telemetry=None`` vs a live ``Telemetry``),
trials interleaved and the per-side minimum reported, and the ``on`` row carries
``overhead_pct`` = the rounds/s cost of recording.  The acceptance gate
(ISSUE 6) is < 5% on ``fanout`` @ batch 64 — the round-dispatch-bound
regime where per-round overhead is most visible.

Workloads:

* ``fanout``    — geometric spawn tree on the chip ``FusedRounds`` engine
  (bench_rounds's workload; shortest rounds, worst case for per-round
  recording cost).
* ``bfs_road``  — road-grid BFS on ``FusedRounds`` (real claim traffic).
* ``sssp_road`` — delta-stepping SSSP on the relaxed priority mesh at one
  shard (the widened 4-word psum meta path, in-process — multi-shard
  overhead is covered by the ``--trace`` emitter's 2-shard run).

Also home to the ``run.py --trace`` emitter (:func:`trace_main`): a
forced-2-device subprocess runs one mesh SSSP with telemetry on, drains
the planes, measures rank error against the declared
``mesh_relaxation_bound`` envelope (exact history from a legacy traced
run + the fused plane's inversion proxy), and writes the JSONL + Chrome
trace files ``tools/trace_check.py`` validates.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
import time

HEADER = ("bench,workload,batch,telemetry,rounds,items,elapsed_s,"
          "rounds_per_s,items_per_s,overhead_pct,records,dropped")
TRIALS = 30     # interleaved on/off; the estimator is the MIN over trials,
                # not the median: shared-host interference is one-sided (it
                # only ever adds time), so the fastest interleaved trial is
                # the highest-fidelity estimate of intrinsic per-run cost —
                # medians on this class of box scatter by ±10pp run-to-run,
                # and 15 draws left the span-overhead pct with ±5pp scatter
                # (the <5% gate needs ~1-2pp resolution, hence 30)
CAPACITY = 1024   # the Telemetry default; covers every workload's round
                  # count here with headroom (in-loop carry cost scales
                  # with plane capacity — benchmark what users get)


def _row(workload: str, batch: int, tel_on, stats: dict,
         elapsed: float, *, overhead_pct=None, records=0,
         dropped=0) -> dict:
    rounds, items = stats["rounds"], stats["processed"]
    return {
        "workload": workload, "batch": batch,
        "telemetry": (tel_on if isinstance(tel_on, str)
                      else ("on" if tel_on else "off")),
        "rounds": rounds, "items": items,
        "elapsed_s": round(elapsed, 4),
        "rounds_per_s": round(rounds / max(elapsed, 1e-9), 1),
        "items_per_s": round(items / max(elapsed, 1e-9), 1),
        # baseline rows carry no overhead measurement: emit JSON null, not
        # "" — trace_check/bench_compare reject empty-string numerics
        "overhead_pct": (None if overhead_pct is None
                         else round(overhead_pct, 2)),
        "records": records, "dropped": dropped,
    }


def _emit(out, row: dict) -> None:
    ov = "" if row["overhead_pct"] is None else row["overhead_pct"]
    print(f"obs,{row['workload']},{row['batch']},{row['telemetry']},"
          f"{row['rounds']},{row['items']},{row['elapsed_s']},"
          f"{row['rounds_per_s']},{row['items_per_s']},"
          f"{ov},{row['records']},{row['dropped']}",
          file=out)


def _measure_pair(make_runner, run_once, batch: int, workload: str,
                  trials: int = TRIALS):
    """Min-of-interleaved-trials for telemetry off vs on (see TRIALS note).
    Both runners are built from the same factory and warmed before timing;
    the ``on`` telemetry is reset per trial so drain cost (the real
    per-sync price) is inside the timed region but record accumulation
    across trials is not."""
    from repro.obs import Telemetry

    tel = Telemetry(CAPACITY, engine=workload)
    runners = {False: make_runner(None), True: make_runner(tel)}
    for r in runners.values():
        run_once(r)                               # warmup/compile
    times = {False: [], True: []}
    stats = {}
    for _ in range(trials):
        for tel_on, runner in runners.items():
            if tel_on:
                tel.reset()
            t0 = time.perf_counter()
            run_once(runner)
            times[tel_on].append(time.perf_counter() - t0)
            stats[tel_on] = dict(runner.stats)
    assert stats[True] == stats[False], (
        f"{workload}: telemetry changed engine stats")
    med = {k: min(v) for k, v in times.items()}
    rps = {k: stats[k]["rounds"] / max(med[k], 1e-9) for k in med}
    overhead = (rps[False] - rps[True]) / max(rps[False], 1e-9) * 100
    assert len(tel.records) + tel.dropped == stats[True]["rounds"], (
        f"{workload}: plane lost rounds")
    return (_row(workload, batch, False, stats[False], med[False]),
            _row(workload, batch, True, stats[True], med[True],
                 overhead_pct=overhead, records=len(tel.records),
                 dropped=tel.dropped))


def _measure_span_pair(make_runner, run_once, batch: int, workload: str,
                       trials: int = TRIALS):
    """Span-layer twin of :func:`_measure_pair`: spans off vs on with the
    same min-of-interleaved-trials estimator.  The ``on`` row's
    ``records`` is the histogram mass (one count per claimed task) and
    ``dropped`` counts flow-ring overwrites (sampling, never an error)."""
    from repro.obs.spans import Spans

    sp = Spans(classes=1, engine=workload)
    runners = {False: make_runner(None), True: make_runner(sp)}
    for r in runners.values():
        run_once(r)                               # warmup/compile
    times = {False: [], True: []}
    stats = {}
    for _ in range(trials):
        for sp_on, runner in runners.items():
            if sp_on:
                sp.reset()
            t0 = time.perf_counter()
            run_once(runner)
            times[sp_on].append(time.perf_counter() - t0)
            stats[sp_on] = dict(runner.stats)
    assert stats[True] == stats[False], (
        f"{workload}: spans changed engine stats")
    best = {k: min(v) for k, v in times.items()}
    rps = {k: stats[k]["rounds"] / max(best[k], 1e-9) for k in best}
    overhead = (rps[False] - rps[True]) / max(rps[False], 1e-9) * 100
    assert sp.total == stats[True]["processed"], (
        f"{workload}: span histogram lost tasks "
        f"({sp.total} != {stats[True]['processed']})")
    return (_row(workload, batch, "span-off", stats[False], best[False]),
            _row(workload, batch, "span-on", stats[True], best[True],
                 overhead_pct=overhead, records=sp.total,
                 dropped=sp.dropped_flows))


def run_fanout_span_pair(batch: int, *, depth: int = 10, roots: int = 4,
                         trials: int = TRIALS):
    import jax.numpy as jnp
    import numpy as np
    from repro.runtime import RoundRunner
    from .bench_rounds import _fanout_step

    peak = roots * 2 ** depth
    capacity_log2 = max(int(np.ceil(np.log2(2 * peak))),
                        int(np.ceil(np.log2(2 * batch))))
    seeds = np.full(roots, depth, np.int32)
    acc0 = jnp.zeros(depth + 1, jnp.int32)

    def make(sp):
        return RoundRunner(_fanout_step(2, depth),
                           capacity_log2=capacity_log2, batch=batch,
                           spans=sp)

    return _measure_span_pair(
        make, lambda r: r.run(seeds, acc=acc0, max_rounds=1_000_000),
        batch, "fanout_spans", trials)


def run_fanout_pair(batch: int, *, depth: int = 10, roots: int = 4,
                    trials: int = TRIALS):
    import jax.numpy as jnp
    import numpy as np
    from repro.runtime import RoundRunner
    from .bench_rounds import _fanout_step

    peak = roots * 2 ** depth
    capacity_log2 = max(int(np.ceil(np.log2(2 * peak))),
                        int(np.ceil(np.log2(2 * batch))))
    seeds = np.full(roots, depth, np.int32)
    acc0 = jnp.zeros(depth + 1, jnp.int32)

    def make(tel):
        return RoundRunner(_fanout_step(2, depth),
                           capacity_log2=capacity_log2, batch=batch,
                           telemetry=tel)

    return _measure_pair(
        make, lambda r: r.run(seeds, acc=acc0, max_rounds=1_000_000),
        batch, "fanout", trials)


def run_bfs_pair(batch: int, *, n: int = 4096, trials: int = TRIALS):
    from repro.apps import bfs

    g = bfs.road_like(n)
    init = {}

    def make(tel):
        runner, init_fn = bfs.bfs_rounds_runner(g, batch=batch,
                                                telemetry=tel)
        init["fn"] = init_fn
        return runner

    return _measure_pair(
        make, lambda r: r.run([0], acc=init["fn"](0), max_rounds=1_000_000),
        batch, "bfs_road", trials)


def run_sssp_pair(batch: int, *, n: int = 1024, delta: int = 4,
                  trials: int = TRIALS):
    from repro.apps import bfs, sssp
    from repro.jaxcompat import make_mesh

    g = bfs.road_like(n)
    w = sssp.with_weights(g, max_w=8, seed=1)
    mesh = make_mesh((1,), ("data",))
    init = {}

    def make(tel):
        runner, init_fn = sssp.sssp_mesh_rounds_runner(
            g, w, mesh=mesh, batch=batch, delta=delta, telemetry=tel)
        init["fn"] = init_fn
        return runner

    return _measure_pair(
        make,
        lambda r: r.run([0], [0], acc=init["fn"](0), max_rounds=1_000_000),
        batch, "sssp_road", trials)


def main(out=sys.stdout, batches=(64, 256), fanout_depth: int = 10,
         bfs_n: int = 4096, sssp_n: int = 1024) -> list:
    """The "obs" sweep: telemetry on-vs-off across the three workloads."""
    print("# telemetry overhead: fused engines with trace planes on vs off",
          file=out)
    print(HEADER, file=out)
    rows = []
    for batch in batches:
        off, on = run_fanout_pair(batch, depth=fanout_depth)
        _emit(out, off)
        _emit(out, on)
        rows += [off, on]
        print(f"# fanout batch={batch}: telemetry costs "
              f"{on['overhead_pct']}% rounds/s "
              f"({on['records']} records, {on['dropped']} dropped)",
              file=out)
    for batch in batches:
        soff, son = run_fanout_span_pair(batch, depth=fanout_depth)
        _emit(out, soff)
        _emit(out, son)
        rows += [soff, son]
        print(f"# fanout batch={batch}: spans cost "
              f"{son['overhead_pct']}% rounds/s "
              f"({son['records']} sojourns, {son['dropped']} flow drops)",
              file=out)
    for batch in batches:
        for pair in (run_bfs_pair(batch, n=bfs_n),
                     run_sssp_pair(batch, n=sssp_n)):
            off, on = pair
            _emit(out, off)
            _emit(out, on)
            rows += [off, on]
    return rows


def smoke(out=sys.stdout) -> bool:
    """CI gate: stats identical with telemetry on/off, plane accounts for
    every round, and the trace files validate."""
    import tempfile

    from repro.obs import write_chrome_trace, write_jsonl
    from repro.obs.trace import Telemetry

    print("# obs smoke: telemetry + span parity + export validation",
          file=out)
    print(HEADER, file=out)
    off, on = run_fanout_pair(32, depth=6, trials=3)
    _emit(out, off)
    _emit(out, on)
    ok = on["rounds"] == off["rounds"] and on["records"] == on["rounds"]
    soff, son = run_fanout_span_pair(32, depth=6, trials=3)
    _emit(out, soff)
    _emit(out, son)
    ok = ok and son["rounds"] == soff["rounds"]
    ok = ok and son["records"] == son["items"]   # one sojourn per task
    # re-run one instrumented pass and validate its export end to end
    from repro.obs.spans import Spans
    from repro.runtime import RoundRunner
    import jax.numpy as jnp
    import numpy as np
    from .bench_rounds import _fanout_step
    tel = Telemetry(CAPACITY, engine="fanout")
    sp = Spans(classes=1, engine="fanout")
    r = RoundRunner(_fanout_step(2, 6), capacity_log2=8, batch=32,
                    telemetry=tel, spans=sp)
    r.run(np.full(2, 6, np.int32), acc=jnp.zeros(7, jnp.int32))
    with tempfile.TemporaryDirectory() as d:
        jl = os.path.join(d, "t.jsonl")
        ch = os.path.join(d, "t.json")
        write_jsonl(jl, tel.records, tel.sync_points,
                    metrics=tel.registry.snapshot(), engine="fanout",
                    spans=sp)
        write_chrome_trace(ch, tel.records, tel.sync_points,
                           engine="fanout", flows=sp.flows)
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        res = subprocess.run(
            [sys.executable, os.path.join(repo, "tools", "trace_check.py"),
             jl, "--chrome", ch], capture_output=True, text=True)
        if res.returncode != 0:
            print(f"# FAIL: trace_check rejected the export: "
                  f"{res.stderr[-1000:]}", file=out)
            ok = False
    print(f"# acceptance: {'PASS' if ok else 'FAIL'}", file=out)
    return ok


# ---------------------------------------------------------------------------
# run.py --trace emitter (forced-device subprocess, bench_mesh pattern)
# ---------------------------------------------------------------------------


def trace_main(out=sys.stdout, *, trace_dir: str = ".", shards: int = 2,
               batch: int = 64, n: int = 512) -> bool:
    """Emit the PR-6 acceptance artifact: one mesh SSSP run's telemetry as
    ``trace_sssp.jsonl`` + ``trace_sssp.json`` (Chrome) under
    ``trace_dir``, validated by ``tools/trace_check.py``."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    trace_dir = os.path.abspath(trace_dir)
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (f"{flags} --xla_force_host_platform_device_count="
                        f"{shards}").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(repo, "src"), env.get("PYTHONPATH"), repo)
        if p)
    os.makedirs(trace_dir, exist_ok=True)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_obs", "--inner-trace",
         "--trace-dir", trace_dir, "--shards", str(shards),
         "--batches", str(batch), "--n", str(n)],
        capture_output=True, text=True, cwd=repo, env=env, timeout=1800)
    print(proc.stdout, end="", file=out)
    if proc.returncode != 0:
        print(f"# FAIL: trace subprocess exited {proc.returncode}: "
              f"{proc.stderr[-2000:]}", file=out)
        return False
    jl = os.path.join(trace_dir, "trace_sssp.jsonl")
    ch = os.path.join(trace_dir, "trace_sssp.json")
    res = subprocess.run(
        [sys.executable, os.path.join(repo, "tools", "trace_check.py"),
         jl, "--chrome", ch], capture_output=True, text=True)
    print(f"# {res.stdout.strip()}", file=out)
    if res.returncode != 0:
        print(f"# FAIL: emitted trace is schema-invalid: "
              f"{res.stderr[-2000:]}", file=out)
        return False
    return True


def inner_trace(out, trace_dir: str, shards: int, batch: int,
                n: int) -> None:
    """Subprocess side of :func:`trace_main` (expects XLA_FLAGS set)."""
    import numpy as np

    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src"))
    import jax
    assert len(jax.devices()) >= shards, (
        f"need {shards} devices, have {len(jax.devices())}")
    from repro.apps import bfs, sssp
    from repro.jaxcompat import make_mesh
    from repro.obs import (Telemetry, rank_error_vs_envelope, write_jsonl,
                           write_chrome_trace)
    from repro.sched import mesh_relaxation_bound

    mesh = make_mesh((shards,), ("data",))
    g = bfs.road_like(n)
    w = sssp.with_weights(g, max_w=8, seed=1)

    # fused run with the trace plane: per-round occupancy / imbalance /
    # key extrema drained at quiescence
    tel = Telemetry(CAPACITY, engine="sssp_mesh")
    runner, init_fn = sssp.sssp_mesh_rounds_runner(
        g, w, mesh=mesh, batch=batch, telemetry=tel)
    dist, _ = runner.run([0], [0], acc=init_fn(0), max_rounds=1_000_000)
    ref = sssp.dijkstra_reference(g, w, 0)
    exact = bool(np.array_equal(np.asarray(dist), ref))

    # legacy traced run: the exact per-pop history for measured rank error
    lruner, linit = sssp.sssp_mesh_rounds_runner(
        g, w, mesh=mesh, batch=batch, fused=False, trace=True)
    lruner.run([0], [0], acc=linit(0), max_rounds=1_000_000)
    history, inserts = [], []
    for rec in lruner.trace:
        pk, _, ok = rec["pops"]
        history.append([int(k) for k, o in
                        zip(pk.reshape(-1), ok.reshape(-1)) if o])
        gk, _, ga = rec["pushes"]
        inserts.append([int(k) for k, a in
                        zip(gk.reshape(-1), ga.reshape(-1)) if a])
    env = mesh_relaxation_bound(shards, batch,
                                lruner.stats["max_occupancy"])
    rank = rank_error_vs_envelope(env, history=history, inserts=inserts,
                                  records=tel.records)

    meta = {"workload": "sssp_road", "shards": shards, "batch": batch,
            "n": g.n, "exact_distances": exact, "rank_error": rank,
            "stats": dict(runner.stats)}
    jl = os.path.join(trace_dir, "trace_sssp.jsonl")
    ch = os.path.join(trace_dir, "trace_sssp.json")
    nl = write_jsonl(jl, tel.records, tel.sync_points,
                     metrics=tel.registry.snapshot(), engine="sssp_mesh",
                     extra_meta=meta)
    ne = write_chrome_trace(ch, tel.records, tel.sync_points,
                            engine="sssp_mesh")
    print(f"# trace: {nl} jsonl lines -> {jl}", file=out)
    print(f"# trace: {ne} chrome events -> {ch}", file=out)
    print(f"# rank error: measured {rank['measured_rank_error']} vs "
          f"declared envelope {rank['envelope']} "
          f"(within={rank['within_envelope']}, "
          f"inversions={rank['key_inversions']}); "
          f"exact_distances={exact}", file=out)
    if not exact or not rank["within_envelope"]:
        raise SystemExit("trace run violated correctness/envelope")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--batches", default="64,256")
    ap.add_argument("--trace", action="store_true",
                    help="emit the validated SSSP trace artifact")
    ap.add_argument("--inner-trace", action="store_true")
    ap.add_argument("--trace-dir", default=".")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--n", type=int, default=512)
    a = ap.parse_args()
    batches = tuple(int(b) for b in a.batches.split(","))
    if a.inner_trace:
        inner_trace(sys.stdout, a.trace_dir, a.shards, batches[0], a.n)
        sys.exit(0)
    if a.trace:
        sys.exit(0 if trace_main(trace_dir=a.trace_dir, shards=a.shards,
                                 batch=batches[0], n=a.n) else 1)
    if a.smoke:
        sys.exit(0 if smoke() else 1)
    if a.quick:
        main(batches=(64,), fanout_depth=8, bfs_n=1024, sssp_n=512)
    else:
        main(batches=batches)
