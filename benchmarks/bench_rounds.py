"""Round-engine benchmark: legacy host-driven rounds vs the fused
device-resident megaround loop (DESIGN.md § 4.3, BENCH_3).

Workloads:

* ``fanout`` — synthetic geometric spawn tree: every task of depth d > 0
  spawns ``FANOUT`` children of depth d-1; per-depth counts accumulate on
  device.  Pure queue/scheduler cost — the round engine IS the workload.
* ``bfs``    — ``apps.bfs.bfs_rounds`` on a road-like grid (long diameter,
  many rounds: the regime where per-round host syncs dominate) and a
  kron-like power-law graph (wide frontier: big enqueue waves).

Rows report rounds/sec, items/sec, and host syncs per run for each engine
at batch ∈ {64, 256, 1024} — kron@1024 included: the one regime where the
sparse fused wave lost to legacy (BENCH_3) is covered again now that the
dense-wave rule (DESIGN.md § 4.4) compacts the child block on device.
Timings exclude compilation (one warmup run per config) and use the
min-of-interleaved-trials estimator: legacy and fused alternate inside
one trial loop and each mode reports its minimum, so drift on a shared
runner hits both sides equally and the min discards one-sided stalls.

``--smoke`` is the CI acceptance gate: it asserts fused/legacy parity
(bit-identical acc + final ring state) on both workloads — including the
forced-compaction fused path (``compact=True``) against both — and
records timings; it does NOT require a speedup (interpret-mode timings
on shared CI runners are too noisy to gate on).
"""

from __future__ import annotations

import argparse
import sys
import time

import jax.numpy as jnp
import numpy as np

HEADER = ("bench,workload,batch,mode,rounds,items,elapsed_s,rounds_per_s,"
          "items_per_s,host_syncs,drained")

TRIALS = 5      # interleaved legacy/fused; min over trials (see module doc)


def _fanout_step(fanout: int, depth: int):
    def step(acc, vals, valid):
        acc = acc.at[jnp.clip(vals, 0, depth)].add(valid.astype(jnp.int32))
        cv = jnp.broadcast_to((vals - 1)[:, None],
                              (vals.shape[0], fanout)).astype(jnp.int32)
        cm = (valid & (vals > 0))[:, None]
        return acc, cv, cm
    return step


def _expected_fanout_acc(fanout: int, depth: int, roots: int) -> np.ndarray:
    counts = np.zeros(depth + 1, np.int64)
    for d in range(depth, -1, -1):
        counts[d] = roots * fanout ** (depth - d)
    return counts.astype(np.int32)


def _fanout_runner(batch: int, *, fused: bool, fanout: int = 2,
                   depth: int = 10, roots: int = 4, sync_every: int = 0,
                   compact=None):
    from repro.runtime import RoundRunner

    peak = roots * fanout ** depth
    capacity_log2 = max(int(np.ceil(np.log2(2 * peak))),
                        int(np.ceil(np.log2(2 * batch))))
    seeds = np.full(roots, depth, np.int32)
    acc0 = jnp.zeros(depth + 1, jnp.int32)
    runner = RoundRunner(_fanout_step(fanout, depth),
                         capacity_log2=capacity_log2, batch=batch,
                         fused=fused, sync_every=sync_every, compact=compact)
    return runner, seeds, acc0


def _interleaved_min(run_fns, trials: int):
    """Time each thunk ``trials`` times, round-robin (legacy and fused
    alternate inside one loop), and return per-thunk (min_elapsed,
    last_result) — the min estimator discards one-sided scheduler noise."""
    best = [None] * len(run_fns)
    last = [None] * len(run_fns)
    for _ in range(max(trials, 1)):
        for i, fn in enumerate(run_fns):
            t0 = time.perf_counter()
            last[i] = fn()
            el = time.perf_counter() - t0
            best[i] = el if best[i] is None else min(best[i], el)
    return list(zip(best, last))


def run_fanout(batch: int, *, fused: bool, fanout: int = 2, depth: int = 10,
               roots: int = 4, sync_every: int = 0, compact=None,
               trials: int = 1):
    """Best-of-``trials`` timed fanout run (post-warmup).  Returns
    (row dict, acc, state)."""
    runner, seeds, acc0 = _fanout_runner(batch, fused=fused, fanout=fanout,
                                         depth=depth, roots=roots,
                                         sync_every=sync_every,
                                         compact=compact)
    runner.run(seeds, acc=acc0, max_rounds=1_000_000)        # warmup/compile
    (elapsed, (acc, st)), = _interleaved_min(
        [lambda: runner.run(seeds, acc=acc0, max_rounds=1_000_000)], trials)
    row = _row("fanout", batch, fused, runner.stats, elapsed)
    return row, np.asarray(acc), st


def run_fanout_pair(batch: int, *, fanout: int = 2, depth: int = 10,
                    roots: int = 4, trials: int = TRIALS):
    """Legacy and fused fanout interleaved trial-by-trial; returns
    ``{mode: row}`` plus the two (acc, state) results for parity checks."""
    built = {}
    for fused in (False, True):
        runner, seeds, acc0 = _fanout_runner(batch, fused=fused,
                                             fanout=fanout, depth=depth,
                                             roots=roots)
        runner.run(seeds, acc=acc0, max_rounds=1_000_000)    # warmup/compile
        built[fused] = (runner, seeds, acc0)
    timed = _interleaved_min(
        [lambda f=f: built[f][0].run(built[f][1], acc=built[f][2],
                                     max_rounds=1_000_000)
         for f in (False, True)], trials)
    rows = {}
    for fused, (elapsed, _) in zip((False, True), timed):
        row = _row("fanout", batch, fused, built[fused][0].stats, elapsed)
        rows[row["mode"]] = row
    return rows


def run_bfs(batch: int, *, fused: bool, graph: str = "road", n: int = 4096,
            sync_every: int = 0, compact=None, trials: int = 1):
    """Best-of-``trials`` timed BFS run (post-warmup, runner reused so the
    timed runs pay no megaround compilation).  Returns (row dict, dist)."""
    from repro.apps import bfs

    g = (bfs.road_like(n) if graph == "road"
         else bfs.kron_like(n, avg_deg=4, seed=1))
    runner, init_fn = bfs.bfs_rounds_runner(g, batch=batch, fused=fused,
                                            sync_every=sync_every,
                                            compact=compact)
    runner.run([0], acc=init_fn(0), max_rounds=1_000_000)    # warmup/compile
    (elapsed, (dist, _)), = _interleaved_min(
        [lambda: runner.run([0], acc=init_fn(0), max_rounds=1_000_000)],
        trials)
    row = _row(f"bfs_{graph}", batch, fused, runner.stats, elapsed)
    return row, np.asarray(dist)


def run_bfs_pair(batch: int, *, graph: str = "road", n: int = 4096,
                 trials: int = TRIALS):
    """Legacy and fused BFS interleaved trial-by-trial on one shared graph;
    returns ``{mode: row}``.  The fused side keeps the default dense-wave
    auto rule, so kron at large batch exercises the compaction kernel."""
    from repro.apps import bfs

    g = (bfs.road_like(n) if graph == "road"
         else bfs.kron_like(n, avg_deg=4, seed=1))
    built = {}
    for fused in (False, True):
        runner, init_fn = bfs.bfs_rounds_runner(g, batch=batch, fused=fused)
        runner.run([0], acc=init_fn(0), max_rounds=1_000_000)    # warmup
        built[fused] = (runner, init_fn)
    timed = _interleaved_min(
        [lambda f=f: built[f][0].run([0], acc=built[f][1](0),
                                     max_rounds=1_000_000)
         for f in (False, True)], trials)
    rows = {}
    for fused, (elapsed, _) in zip((False, True), timed):
        row = _row(f"bfs_{graph}", batch, fused, built[fused][0].stats,
                   elapsed)
        rows[row["mode"]] = row
    return rows


def _row(workload: str, batch: int, fused: bool, stats: dict,
         elapsed: float) -> dict:
    rounds = stats["rounds"]
    items = stats["processed"]
    return {
        "workload": workload, "batch": batch,
        "mode": "fused" if fused else "legacy",
        "rounds": rounds, "items": items,
        "elapsed_s": round(elapsed, 4),
        "rounds_per_s": round(rounds / max(elapsed, 1e-9), 1),
        "items_per_s": round(items / max(elapsed, 1e-9), 1),
        "host_syncs": stats["host_syncs"], "drained": stats["drained"],
    }


def _emit(out, row: dict) -> None:
    print(f"rounds,{row['workload']},{row['batch']},{row['mode']},"
          f"{row['rounds']},{row['items']},{row['elapsed_s']},"
          f"{row['rounds_per_s']},{row['items_per_s']},{row['host_syncs']},"
          f"{row['drained']}", file=out)


def main(out=sys.stdout, batches=(64, 256, 1024), fanout_depth: int = 10,
         bfs_n: int = 4096, graphs=("road", "kron"),
         trials: int = TRIALS) -> list:
    """Full sweep: fanout + BFS, legacy vs fused interleaved, across
    batches (kron@1024 included — the dense-wave regime)."""
    print("# round engine: legacy host-driven vs fused device-resident "
          f"(min of {trials} interleaved trials)", file=out)
    print(f"bench,{HEADER.split(',', 1)[1]}", file=out)
    rows = []
    for batch in batches:
        by_mode = run_fanout_pair(batch, depth=fanout_depth, trials=trials)
        for mode in ("legacy", "fused"):
            _emit(out, by_mode[mode])
            rows.append(by_mode[mode])
        speedup = (by_mode["fused"]["rounds_per_s"]
                   / max(by_mode["legacy"]["rounds_per_s"], 1e-9))
        print(f"# fanout batch={batch}: fused {speedup:.1f}x rounds/s, "
              f"host_syncs {by_mode['legacy']['host_syncs']} -> "
              f"{by_mode['fused']['host_syncs']}", file=out)
    for graph in graphs:
        for batch in batches:
            by_mode = run_bfs_pair(batch, graph=graph, n=bfs_n,
                                   trials=trials)
            for mode in ("legacy", "fused"):
                _emit(out, by_mode[mode])
                rows.append(by_mode[mode])
            speedup = (by_mode["fused"]["rounds_per_s"]
                       / max(by_mode["legacy"]["rounds_per_s"], 1e-9))
            print(f"# bfs_{graph} batch={batch}: fused {speedup:.1f}x "
                  f"rounds/s", file=out)
    return rows


def smoke(out=sys.stdout) -> bool:
    """CI acceptance: fused/legacy bit-parity on both workloads + recorded
    timings.  Speedup is reported, not asserted (CI timing noise)."""
    from repro.apps import bfs

    ok = True
    print("# rounds smoke: fused-vs-legacy parity", file=out)
    print(f"bench,{HEADER.split(',', 1)[1]}", file=out)

    row_l, acc_l, st_l = run_fanout(32, fused=False, depth=6, roots=2)
    row_f, acc_f, st_f = run_fanout(32, fused=True, depth=6, roots=2)
    _emit(out, row_l)
    _emit(out, row_f)
    if not (np.array_equal(acc_l, acc_f)
            and np.array_equal(acc_l, _expected_fanout_acc(2, 6, 2))):
        print("# FAIL: fanout acc mismatch", file=out)
        ok = False
    planes_eq = all(np.array_equal(np.asarray(a), np.asarray(b))
                    for a, b in zip(st_l[:4], st_f[:4]))
    if not (planes_eq and (st_l.head, st_l.tail) == (st_f.head, st_f.tail)):
        print("# FAIL: fanout ring state mismatch", file=out)
        ok = False

    # compaction parity gate: the forced dense-wave fused path must match
    # the sparse fused path and legacy bit-for-bit (acc + ring state)
    row_c, acc_c, st_c = run_fanout(32, fused=True, depth=6, roots=2,
                                    compact=True)
    if not np.array_equal(acc_c, acc_f):
        print("# FAIL: compaction fanout acc mismatch", file=out)
        ok = False
    planes_eq_c = all(np.array_equal(np.asarray(a), np.asarray(b))
                      for a, b in zip(st_c[:4], st_f[:4]))
    if not (planes_eq_c
            and (st_c.head, st_c.tail) == (st_f.head, st_f.tail)):
        print("# FAIL: compaction fanout ring state mismatch", file=out)
        ok = False

    g = bfs.road_like(256)
    ref = bfs.bfs_reference(g, 0)
    bfs_stats = {}
    for fused, compact in ((False, None), (True, None), (True, True)):
        runner, init_fn = bfs.bfs_rounds_runner(g, batch=32, fused=fused,
                                                compact=compact)
        runner.run([0], acc=init_fn(0))                      # warmup
        t0 = time.perf_counter()
        dist, _ = runner.run([0], acc=init_fn(0))
        if compact is None:
            bfs_stats[fused] = runner.stats
            _emit(out, _row("bfs_road", 32, fused, runner.stats,
                            time.perf_counter() - t0))
        if not np.array_equal(np.asarray(dist), ref):
            print(f"# FAIL: bfs fused={fused} compact={compact} "
                  f"distances wrong", file=out)
            ok = False
    if not (bfs_stats[True]["host_syncs"] < bfs_stats[False]["host_syncs"]
            and row_f["host_syncs"] < row_l["host_syncs"]):
        # fused engines sync once at quiescence; legacy syncs every round
        print("# FAIL: fused path did not reduce host syncs", file=out)
        ok = False
    print(f"# acceptance: {'PASS' if ok else 'FAIL'}", file=out)
    return ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI parity gate (fast; asserts correctness only)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep (CI-sized)")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if smoke() else 1)
    if args.quick:
        main(batches=(64, 256), fanout_depth=8, bfs_n=1024)
    else:
        main()
