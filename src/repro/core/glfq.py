"""G-LFQ — the paper's bounded lock-free GPU queue (§ III-B, Algorithm 1).

An sCQ-style bounded ring (2n physical slots, logical capacity n, threshold
empty-test) with the paper's two changes:

1. **Wave-batched ticket reservation** (WAVEFAA, Fig. 1 / Lemma III.1): hot
   Head/Tail counters receive one batched FAA per converged wave instead of
   one per thread.  In the simulator this is the ``ctx.wavefaa`` instruction;
   the scheduler forms the active mask exactly as a ballot would.
2. **Single 64-bit packed slot words** ``(Cycle, Safe, Enq, Index)`` with
   reduced-width cycle tags (Lemma III.2).

Notation follows Algorithm 1.  One deliberate reading of the paper's
line 18 condition ``(E.Safe ∨ Head < t)``: we implement the sCQ original
``Head ≤ t`` (the paper's strict ``<`` appears to be a transcription slip —
with ``<`` an enqueuer would refuse a slot whose matching dequeuer has not
been issued yet when ``Head == t``, needlessly failing; both are safe, only
``≤`` is live.  Flagged here per reproduction policy.)

Initialization follows sCQ: ``Head = Tail = 2n`` so the first tickets carry
cycle 1 while all slots start at cycle 0 (making ``E.Cycle < c`` hold).
"""

from __future__ import annotations

from .atomics import AtomicMemory
from .base import QueueAlgorithm, VAL_MASK
from .packed import EntryFormat
from .sim import Ctx

RETRY = "retry"
SUCCESS = "success"
EMPTY = "empty"

NEG1 = (1 << 64) - 1  # two's-complement -1 for FAA decrements


class GLFQ(QueueAlgorithm):
    name = "glfq"

    def __init__(self, capacity: int, num_threads: int, tag: str = "glfq",
                 prefill: int = 0, cycle_bits: int = 30,
                 max_attempts: int = 0) -> None:
        super().__init__(capacity, num_threads)
        self.tag = tag
        self.prefill = prefill
        self.fmt = EntryFormat(idx_bits=32, cycle_bits=cycle_bits)
        self.nslots = 2 * capacity           # ring of size 2n
        # 0 = unbounded retries (lock-free; termination relies on workload)
        self.max_attempts = max_attempts
        self.s_tail = f"{tag}_tail"
        self.s_head = f"{tag}_head"
        self.s_thresh = f"{tag}_thresh"
        self.s_entries = f"{tag}_entries"

    # -- geometry -------------------------------------------------------------

    def slot(self, t: int) -> int:
        return t % self.nslots

    def cycle(self, t: int) -> int:
        return (t // self.nslots) & self.fmt.cycle_mask

    @property
    def threshold_full(self) -> int:
        return 3 * self.capacity - 1  # sCQ: 3n - 1 for the 2n ring

    def init(self, mem: AtomicMemory) -> None:
        self.mem = mem
        f = self.fmt
        mem.alloc(self.s_tail, 1, fill=self.nslots)   # = 2n
        mem.alloc(self.s_head, 1, fill=self.nslots)
        mem.alloc(self.s_thresh, 1, fill=AtomicMemory.from_signed(-1))
        mem.alloc(self.s_entries, self.nslots, fill=f.pack(0, 1, 0, f.idx_bot))
        if self.prefill:
            assert self.prefill <= self.capacity
            entries = mem.array(self.s_entries)
            for i in range(self.prefill):
                t = self.nslots + i          # tickets 2n .. 2n+prefill-1
                entries[self.slot(t)] = f.pack(self.cycle(t), 1, 1, i)
            mem.array(self.s_tail)[0] = self.nslots + self.prefill
            mem.array(self.s_thresh)[0] = AtomicMemory.from_signed(self.threshold_full)

    # -- Algorithm 1: TRYENQ ----------------------------------------------------

    def _tryenq(self, ctx: Ctx, tid: int, value: int):
        f = self.fmt
        t = yield from ctx.wavefaa(self.s_tail, 0)
        j, c = self.slot(t), self.cycle(t)
        while True:  # sCQ re-reads the entry when its CAS loses a race
            e = yield from ctx.load(self.s_entries, j)
            if not (f.cycle_lt(f.cycle(e), c) and f.is_empty_idx(e)):
                return RETRY
            h = yield from ctx.load(self.s_head, 0)
            if not (f.safe(e) or h <= t):
                return RETRY
            new = f.pack(c, 1, 1, value)
            ok = yield from ctx.cas(self.s_entries, j, e, new)
            if ok:
                # reset Threshold to 3n-1 (Alg. 1 line 20)
                yield from ctx.store(
                    self.s_thresh, 0,
                    AtomicMemory.from_signed(self.threshold_full))
                return SUCCESS
            # CAS lost a race — re-examine the slot with the same ticket

    # -- Algorithm 1: TRYDEQ ------------------------------------------------------

    def _catchup(self, ctx: Ctx, target: int):
        """Catch Tail up to at least ``target`` (Alg. 1 line 43)."""
        while True:
            t = yield from ctx.load(self.s_tail, 0)
            if t >= target:
                return
            ok = yield from ctx.cas(self.s_tail, 0, t, target)
            if ok:
                return

    def _trydeq(self, ctx: Ctx, tid: int):
        f = self.fmt
        thr = yield from ctx.load(self.s_thresh, 0)
        if AtomicMemory.to_signed(thr) < 0:
            return (EMPTY, None)
        h = yield from ctx.wavefaa(self.s_head, 0)
        j, c = self.slot(h), self.cycle(h)
        while True:  # sCQ re-reads on a lost neutralize race: the concurrent
            # change may be the matching install, which we must then consume.
            e = yield from ctx.load(self.s_entries, j)
            if f.cycle_eq(f.cycle(e), c) and not f.is_empty_idx(e) and f.enq(e):
                old = yield from ctx.consume(self.s_entries, j, f)
                return (SUCCESS, f.idx(old))
            # Non-matching slot: neutralize so the matching enqueuer cannot
            # install late (Alg. 1 lines 36-40).
            if f.cycle_lt(f.cycle(e), c):
                if f.is_empty_idx(e):
                    # advance the cycle, keep Safe, leave ⊥
                    new = f.pack(c, f.safe(e), 0, f.idx_bot)
                else:
                    # stale live value: mark unsafe, preserve everything else
                    new = f.pack(f.cycle(e), 0, f.enq(e), f.idx(e))
                ok = yield from ctx.cas(self.s_entries, j, e, new)
                if not ok:
                    continue
            break
        # Empty detection (Alg. 1 lines 42-48).
        t = yield from ctx.load(self.s_tail, 0)
        if t <= h + 1:
            yield from self._catchup(ctx, h + 1)
            yield from ctx.faa(self.s_thresh, 0, NEG1)
            return (EMPTY, None)
        old_thr = yield from ctx.faa(self.s_thresh, 0, NEG1)
        if AtomicMemory.to_signed(old_thr) <= 0:
            return (EMPTY, None)
        return (RETRY, None)

    # -- public ops -----------------------------------------------------------------

    def enqueue(self, ctx: Ctx, tid: int, value: int):
        assert 0 <= value <= VAL_MASK
        attempts = 0
        while True:
            # Bounded-queue full pre-check (logical capacity n).  The check
            # is racy, but over-admission is safe: live slots are never
            # overwritten (install requires an empty index), and with the
            # paper's proof configuration k ≤ n the transient occupancy
            # n + k never exceeds the 2n physical slots.
            t = yield from ctx.load(self.s_tail, 0)
            h = yield from ctx.load(self.s_head, 0)
            if t - h >= self.capacity:
                return False
            r = yield from self._tryenq(ctx, tid, value)
            if r == SUCCESS:
                return True
            attempts += 1
            if self.max_attempts and attempts >= self.max_attempts:
                return False

    def dequeue(self, ctx: Ctx, tid: int):
        attempts = 0
        while True:
            r, v = yield from self._trydeq(ctx, tid)
            if r == SUCCESS:
                return (True, v)
            if r == EMPTY:
                return (False, None)
            attempts += 1
            if self.max_attempts and attempts >= self.max_attempts:
                return (False, None)
