"""Adversarial interleaving simulator for the GPU queue algorithms.

Threads are Python generators that *yield* atomic-instruction requests; the
scheduler executes each request indivisibly against an `AtomicMemory` and
resumes the thread with the result.  This gives a faithful model of
concurrent execution at atomic granularity: any interleaving the scheduler
chooses is an execution the GPU memory system could produce.

Wave semantics
--------------
Threads are grouped into fixed *waves* of ``wave_size`` lanes (AMD wavefront
analogue).  The ``wavefaa`` instruction implements the paper's WAVEFAA
(Alg. 1): when a thread blocks on ``wavefaa(counter)``, the scheduler forms
the *active mask* from all lanes of the same wave that are currently blocked
on a ``wavefaa`` of the same counter, performs **one** fetch-and-add by the
mask's popcount, and resumes each lane with ``base + rank`` where rank is the
lane's prefix rank within the mask — exactly Lemma III.1.  The mask contains
only converged lanes, matching SIMT ballot semantics: in `gang` scheduling
mode lanes of a wave are co-scheduled so they usually arrive together (high
batching occupancy, the regime of Fig. 1); in `random` mode convergence is
emergent and batches are smaller, which only changes *how many* atomics are
issued, never the ticket order (Lemma III.1's observational equivalence — we
property-test this).

Histories & metrics
-------------------
Queue operations bracket themselves with ``op_begin``/``op_end`` events.  The
scheduler records a concurrent history (proc, op, arg, ret, call, end) in the
paper's § IV format for the linearizability checker, and derives the paper's
normalized § V-C metrics:

* ``steps/op``        — state-machine transitions per successful operation
                        (VALU/op analogue),
* ``stall-steps/op``  — transitions spent in attempts that did not commit
                        (failed fast-path rounds, spins, helping) per
                        successful operation (WAIT/op analogue).
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Generator, Iterable, List, Optional, Tuple

from .atomics import AtomicMemory
from .packed import EntryFormat

# Instruction opcodes yielded by thread generators.
LOAD, STORE, FAA, CAS, CONSUME, WAVEFAA, FETCH_OR, FETCH_AND, OP_BEGIN, OP_END, YIELD = (
    "load", "store", "faa", "cas", "consume", "wavefaa", "fetch_or", "fetch_and",
    "op_begin", "op_end", "yield",
)

ENQ, DEQ = 0, 1  # paper § IV history encoding: op=0 ENQ, op=1 DEQ


@dataclass
class HistoryEvent:
    proc: int
    op: int          # 0 = ENQ, 1 = DEQ
    arg: Optional[int]
    ret: Optional[Any]
    call: int        # scheduler step of invocation
    end: int         # scheduler step of response


@dataclass
class ThreadState:
    tid: int
    wave: int
    lane: int
    gen: Generator
    pending: Optional[Tuple] = None   # instruction awaiting execution
    done: bool = False
    steps: int = 0
    cur_op: Optional[Tuple] = None    # (op, arg, call_step, steps_at_begin)
    # Metrics:
    succ_enq: int = 0
    succ_deq: int = 0
    stall_steps: int = 0
    op_steps: int = 0                 # steps inside committed ops


class Ctx:
    """Per-thread instruction issue helper.  All methods are sub-generators —
    queue code uses ``yield from ctx.faa(...)`` etc."""

    def load(self, arr: str, i: int):
        return (yield (LOAD, arr, i))

    def store(self, arr: str, i: int, v: int):
        return (yield (STORE, arr, i, v))

    def faa(self, arr: str, i: int, d: int):
        return (yield (FAA, arr, i, d))

    def cas(self, arr: str, i: int, exp: int, new: int):
        return (yield (CAS, arr, i, exp, new))

    def consume(self, arr: str, i: int, fmt: EntryFormat):
        return (yield (CONSUME, arr, i, fmt))

    def wavefaa(self, arr: str, i: int, d: int = 1):
        """WAVEFAA — returns this lane's ticket (base + prefix rank)."""
        return (yield (WAVEFAA, arr, i, d))

    def fetch_or(self, arr: str, i: int, mask: int):
        return (yield (FETCH_OR, arr, i, mask))

    def fetch_and(self, arr: str, i: int, mask: int):
        return (yield (FETCH_AND, arr, i, mask))

    def op_begin(self, op: int, arg: Optional[int]):
        return (yield (OP_BEGIN, op, arg))

    def op_end(self, ret: Any, success: bool):
        return (yield (OP_END, ret, success))

    def step(self):
        """A pure-compute step (no memory traffic) — lets the scheduler
        preempt between local computations."""
        return (yield (YIELD,))


CTX = Ctx()


class Scheduler:
    """Executes a set of thread generators under a chosen interleaving policy.

    Policies:
      * ``random``  — uniformly random runnable thread each step (adversarial
                      coverage for linearizability checking),
      * ``gang``    — pick a wave, run its lanes round-robin for a burst
                      (SIMT-like; maximizes WAVEFAA batching occupancy),
      * ``rr``      — global round-robin.
    """

    def __init__(
        self,
        mem: AtomicMemory,
        *,
        wave_size: int = 8,
        policy: str = "gang",
        seed: int = 0,
        gang_burst: int = 24,
    ) -> None:
        self.mem = mem
        self.wave_size = wave_size
        self.policy = policy
        self.rng = random.Random(seed)
        self.gang_burst = gang_burst
        self.threads: List[ThreadState] = []
        self.history: List[HistoryEvent] = []
        self.step_count = 0
        self._gang_wave = 0
        self._gang_left = 0
        self._wf_defer = 0  # SIMT-reconvergence defer counter (gang policy)

    # -- thread management ---------------------------------------------------

    def spawn(self, fn: Callable[..., Generator], *args) -> ThreadState:
        tid = len(self.threads)
        wave, lane = divmod(tid, self.wave_size)
        th = ThreadState(tid=tid, wave=wave, lane=lane, gen=fn(CTX, tid, *args))
        self.threads.append(th)
        # Prime the generator to its first instruction.
        self._advance(th, None)
        return th

    def _advance(self, th: ThreadState, send_val) -> None:
        try:
            th.pending = th.gen.send(send_val)
        except StopIteration:
            th.pending = None
            th.done = True

    # -- instruction execution ------------------------------------------------

    def _exec(self, th: ThreadState) -> None:
        ins = th.pending
        th.steps += 1
        self.step_count += 1
        kind = ins[0]
        if kind == WAVEFAA:
            self._exec_wavefaa(th)
            return
        m = self.mem
        if kind == LOAD:
            res = m.load(ins[1], ins[2])
        elif kind == STORE:
            res = m.store(ins[1], ins[2], ins[3])
        elif kind == FAA:
            res = m.faa(ins[1], ins[2], ins[3])
        elif kind == CAS:
            res = m.cas(ins[1], ins[2], ins[3], ins[4])
        elif kind == CONSUME:
            res = m.consume(ins[1], ins[2], ins[3])
        elif kind == FETCH_OR:
            res = m.fetch_or(ins[1], ins[2], ins[3])
        elif kind == FETCH_AND:
            res = m.fetch_and(ins[1], ins[2], ins[3])
        elif kind == OP_BEGIN:
            th.cur_op = (ins[1], ins[2], self.step_count, th.steps)
            res = None
        elif kind == OP_END:
            op, arg, call, steps0 = th.cur_op
            ret, success = ins[1], ins[2]
            self.history.append(
                HistoryEvent(proc=th.tid, op=op, arg=arg, ret=ret,
                             call=call, end=self.step_count)
            )
            used = th.steps - steps0
            if success:
                th.op_steps += used
                if op == ENQ:
                    th.succ_enq += 1
                else:
                    th.succ_deq += 1
            else:
                th.stall_steps += used
            th.cur_op = None
            res = None
        elif kind == YIELD:
            res = None
        else:  # pragma: no cover
            raise ValueError(f"unknown instruction {kind!r}")
        self._advance(th, res)

    def _exec_wavefaa(self, th: ThreadState) -> None:
        """Form the active mask from converged lanes of th's wave and issue a
        single batched FAA (Alg. 1 WAVEFAA)."""
        _, arr, i, d = th.pending
        members = [
            t for t in self.threads
            if (not t.done and t.wave == th.wave and t.pending is not None
                and t.pending[0] == WAVEFAA and t.pending[1] == arr
                and t.pending[2] == i)
        ]
        members.sort(key=lambda t: t.lane)  # prefix rank by lane id
        deltas = [t.pending[3] for t in members]
        count = sum(deltas)
        base = self.mem.faa(arr, i, count)  # ONE atomic for the whole mask
        rank = 0
        for t, delta in zip(members, deltas):
            if t is not th:
                t.steps += 1  # each lane still executes the instruction
                self.step_count += 1
            self._advance(t, base + rank)  # ticket = base + prefix rank
            rank += delta

    # -- scheduling loop -------------------------------------------------------

    def runnable(self) -> List[ThreadState]:
        return [t for t in self.threads if not t.done]

    def _pick(self) -> Optional[ThreadState]:
        live = self.runnable()
        if not live:
            return None
        if self.policy == "random":
            return self.rng.choice(live)
        if self.policy == "rr":
            return live[self.step_count % len(live)]
        # gang: stay on one wave for a burst
        if self._gang_left <= 0:
            waves = sorted({t.wave for t in live})
            self._gang_wave = self.rng.choice(waves)
            self._gang_left = self.gang_burst
        wave_live = [t for t in live if t.wave == self._gang_wave]
        if not wave_live:
            self._gang_left = 0
            return self._pick()
        self._gang_left -= 1
        # SIMT reconvergence: lanes stopped at WAVEFAA wait for the rest of
        # the wave to arrive (a ballot takes whoever is converged); keep
        # advancing the non-arrived lanes first, with a defer budget so a
        # permanently-diverged lane cannot deadlock the wave.
        at_wf = [t for t in wave_live if t.pending and t.pending[0] == WAVEFAA]
        not_wf = [t for t in wave_live if t not in at_wf]
        if at_wf and not_wf and self._wf_defer < 4 * len(wave_live):
            self._wf_defer += 1
            return not_wf[self.step_count % len(not_wf)]
        self._wf_defer = 0
        pool = at_wf if at_wf else wave_live
        return pool[self.step_count % len(pool)]

    def run(self, max_steps: int = 1_000_000) -> bool:
        """Run until all threads finish or the step budget is exhausted.
        Returns True if all threads completed."""
        while self.step_count < max_steps:
            th = self._pick()
            if th is None:
                return True
            self._exec(th)
        return not self.runnable()

    # -- metrics ----------------------------------------------------------------

    def metrics(self) -> Dict[str, float]:
        succ = sum(t.succ_enq + t.succ_deq for t in self.threads)
        stall = sum(t.stall_steps for t in self.threads)
        steps = sum(t.steps for t in self.threads)
        return {
            "successful_ops": succ,
            "total_steps": steps,
            "steps_per_op": steps / max(succ, 1),
            "stall_steps_per_op": stall / max(succ, 1),
            "atomics": self.mem.total_atomics(),
            "atomics_per_op": self.mem.total_atomics() / max(succ, 1),
            "throughput_ops_per_kstep": 1000.0 * succ / max(self.step_count, 1),
        }
