"""End-to-end system test: data pipeline → jitted train step (AdamW) →
async checkpoints → injected node failure → restart from the committed
checkpoint → training completes with a lower loss.  The full stack of
deliverable (b)'s training driver, exercised on a reduced config."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, DataPipeline
from repro.distributed.fault_tolerance import RestartManager
from repro.models import init_params, loss_fn
from repro.optim import adamw


def test_train_restart_end_to_end(tmp_path):
    cfg = get_config("mamba2-130m").reduced()
    dcfg = DataConfig(seq_len=16, global_batch=4, prefetch=4)
    ocfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    num_steps = 20

    params = init_params(cfg)
    state = adamw.init(params)

    @jax.jit
    def jstep(state, tokens, labels):
        p = adamw.cast_params(state.master)
        loss, grads = jax.value_and_grad(loss_fn)(
            p, {"tokens": tokens, "labels": labels}, cfg)
        state, _ = adamw.step(ocfg, state, grads)
        return state, loss

    losses = {}

    def step_fn(state, i):
        from repro.data.pipeline import synth_batch
        b = synth_batch(cfg, dcfg, i % 2)      # two recurring batches:
        state, loss = jstep(state, jnp.asarray(b["tokens"]),
                            jnp.asarray(b["labels"]))  # memorizable signal
        losses[i] = float(loss)
        return state

    ckpt = CheckpointManager(str(tmp_path), async_write=True)
    rm = RestartManager(ckpt, save_every=5, max_restarts=2)
    final_step, state = rm.run(state, step_fn, num_steps=num_steps,
                               inject_fault_at=13)
    assert final_step == num_steps
    assert rm.restarts == 1
    assert losses[num_steps - 2] < losses[0]   # trained through the fault
    assert int(state.step) == num_steps        # optimizer steps preserved


def test_pipeline_feeds_training():
    cfg = get_config("h2o-danube-1.8b").reduced()
    dcfg = DataConfig(seq_len=8, global_batch=2, prefetch=2)
    pipe = DataPipeline(cfg, dcfg, 5).start()
    params = init_params(cfg)
    seen = 0
    for i, batch in pipe:
        loss = loss_fn(params, {k: jnp.asarray(v) for k, v in batch.items()},
                       cfg)
        assert bool(jnp.isfinite(loss))
        seen += 1
    assert seen == 5
