"""The fused-engine core (DESIGN.md § 4.8): ONE loop builder, ONE plane
registry, ONE host driver behind every round engine.

Every fused engine in this repo — chip FIFO, chip priority, mesh FIFO
(replicated or sharded rings), mesh priority (relaxed or strict) — runs
the same shape of computation: a jitted ``lax.while_loop`` whose body is
one *round* (claim → step → publish) over loop-carried queue planes,
with optional trace/span planes riding the carry, chunked by the host
driver at ``sync_every`` and raising on overflow/truncation at the next
sync.  Before this module each engine hand-threaded that shape — four
copies of the carry plumbing, four copies of the chunk driver, two
copies of the legacy per-round loop.  Now an engine is a *configuration*:

* ``_round(qstate, acc, tel=, sp=, births=)`` — the one-round body.
  Contract: returns ``(qstate, acc, k, total, over, telinfo, sp,
  births[, extra...])`` where ``k`` is the round's claim count, ``total``
  the installed-children count (already zeroed when ``over``), ``over``
  the traced overflow flag, and ``telinfo`` a ``(pops, pushes, occs,
  min, max)`` record tuple (``None`` when ``tel`` is off).  Span
  record/tick happen inside the round; trailing ``extra`` entries (the
  legacy trace tuple) are ignored by the fused loop.
* ``_occ_of(qstate)`` — the traced occupancy (the loop condition and the
  ``max_occupancy`` counter read it).
* a ``PlaneRegistry`` describing the loop carry: named plane groups with
  a sharded/replicated flag each, from which the engine derives its
  shard_map specs AND its per-shard loop-carry byte count (the
  O(ring/shards) claim ``benchmarks/bench_mesh.py`` measures).

``fused_loop`` assembles the while_loop from ``_round``/``_occ_of``;
``_run_chunks`` drives the standardized megaround signature
``megaround(qstate, acc, processed, spawned, max_occ, limit, tp, sp,
births)`` chunk by chunk; ``_legacy_loop`` is the shared host-driven
per-round baseline.  Bit-identity rule: the builder performs exactly the
carry updates the hand-rolled loops performed, in pure-functional order,
so an engine moved onto the core is bit-identical to its pre-core twin
(asserted against recorded goldens in ``tests/test_enginecore.py``).

Drain ordering at each host sync is fixed by the driver: trace plane
first (``Telemetry.drain`` → ``heartbeat`` → ``finish``), span plane
second (``Spans.drain`` → ``finish``) — registered once here, never
re-threaded per engine.
"""

from __future__ import annotations

import time
import warnings
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ring_slots import SPAN_ROUND_CAP
from ..obs.spans import Spans, span_init
from ..obs.trace import SyncPoint, Telemetry, trace_init, trace_record

try:  # jax>=0.4.35 moved PartitionSpec construction; keep one import site
    from jax.sharding import PartitionSpec as P
except ImportError:  # pragma: no cover
    from jax.experimental import PartitionSpec as P


def _sds(shape, dtype=jnp.int32):
    """Shape-only leaf for registry declarations (no device allocation)."""
    return jax.ShapeDtypeStruct(shape, dtype)


class PlaneGroup(NamedTuple):
    """One named group of loop-carried leaves (a queue plane set, the
    trace plane, the span plane, a stamp plane...)."""
    name: str
    shapes: Tuple[Tuple[Tuple[int, ...], str], ...]   # ((shape, dtype), ...)
    sharded: bool

    @property
    def nbytes(self) -> int:
        return sum(int(np.prod(s, dtype=np.int64)) * np.dtype(d).itemsize
                   for s, d in self.shapes)


class PlaneRegistry:
    """The loop-carry plane registry: each engine registers its carried
    plane groups ONCE (name + leaves + sharded flag), and the registry
    answers the two questions previously hand-threaded through every
    engine: which shard_map spec each group rides (``spec``/
    ``leaf_specs``), and how many bytes of loop carry a shard actually
    holds (``bytes_per_shard`` — sharded groups divide by the shard
    count, replicated groups don't).  Shapes registered for sharded
    groups are GLOBAL (stacked ``(shards, ...)``), matching what the
    host passes the jitted megaround."""

    def __init__(self, axis: Optional[str] = None) -> None:
        self.axis = axis
        self._groups: Dict[str, PlaneGroup] = {}

    def register(self, name: str, example, *, sharded: bool = False) -> None:
        leaves = jax.tree_util.tree_leaves(example)
        shapes = tuple((tuple(int(d) for d in leaf.shape),
                        jnp.dtype(leaf.dtype).name) for leaf in leaves)
        self._groups[name] = PlaneGroup(name, shapes, sharded)

    @property
    def groups(self) -> Tuple[PlaneGroup, ...]:
        return tuple(self._groups.values())

    def spec(self, name: str):
        """One pytree-prefix spec for the whole group (``P(axis)`` when
        sharded, ``P()`` when replicated)."""
        g = self._groups[name]
        return P(self.axis) if (g.sharded and self.axis) else P()

    def leaf_specs(self, *names: str) -> tuple:
        """Per-leaf specs for groups whose leaves travel as separate
        megaround arguments."""
        out = []
        for nm in names:
            s = self.spec(nm)
            out.extend([s] * len(self._groups[nm].shapes))
        return tuple(out)

    def bytes_per_shard(self, shards: int = 1) -> int:
        total = 0
        for g in self._groups.values():
            total += g.nbytes // shards if g.sharded else g.nbytes
        return total


class EngineEntry(NamedTuple):
    """One row of the engine matrix (``ENGINE_REGISTRY``): enough for the
    parametrized test/bench harnesses to build and drive the runner."""
    name: str
    runner: type
    priority: bool          # PriorityStepFn + run(keys, vals) signature
    mesh: bool              # constructor takes mesh=
    kwargs: Dict[str, Any]  # mode selectors (relaxed=, sharded=, ...)
    spans_ok: bool          # span planes supported in this configuration


ENGINE_REGISTRY: Dict[str, EngineEntry] = {}


def register_engine(name: str, runner: type, *, priority: bool, mesh: bool,
                    kwargs: Optional[Dict[str, Any]] = None,
                    spans_ok: bool = True) -> None:
    """Register a runner configuration in the engine matrix.  New engines
    self-register at import; the parity/telemetry-off test suite and the
    bench harness enumerate the matrix instead of hand-copying per-engine
    cases (tests/conftest.py)."""
    ENGINE_REGISTRY[name] = EngineEntry(name, runner, priority, mesh,
                                        dict(kwargs or {}), spans_ok)


class EngineCore:
    """Shared core of every fused round engine: the while_loop builder
    (``fused_loop``), the chunked host driver (``_run_chunks`` /
    ``_drive``), the legacy per-round baseline (``_legacy_loop``), the
    obs-plane lifecycle (init memoization + drain hooks), and the plane
    registry.  Subclasses configure ``_round`` / ``_occ_of`` / specs.

    Telemetry (DESIGN.md § 7): when constructed with a
    ``repro.obs.Telemetry``, the megaround carries a ``TracePlane`` of
    per-round records as extra loop state; the driver drains it into the
    collector at every host sync (the same sync — telemetry adds zero
    extra syncs).  With ``telemetry=None`` the plane never enters the
    carry and the jitted loop is the exact pre-telemetry graph
    (bit-identity asserted in tests).  Spans ride the same way
    (DESIGN.md § 7.6), with one extra driver duty: the packed
    ``(birth << 1) | 1`` stamp format caps the round clock at 2^30
    (``kernels.ring_slots.SPAN_ROUND_CAP``), so the driver clamps each
    chunk's limit to the cap and raises instead of letting stamps wrap."""

    sync_every: int
    capacity: int
    telemetry: Optional[Telemetry]
    spans: Optional[Spans] = None
    span_round_cap: int = SPAN_ROUND_CAP
    # optional extra loop-exit predicate ``carry -> bool`` (python-level:
    # when None — every engine except the serving admission tick — the
    # built graph is byte-identical to the hookless loop, so the recorded
    # goldens keep holding).  The predicate MUST be replicated across
    # shards: the relaxed round's publish psum is a collective, and a
    # shard exiting early would deadlock the others.
    _extra_cond = None

    def _reset(self) -> None:
        self.stats: Dict[str, int] = {}
        self.sync_log: List[SyncPoint] = []
        if self.telemetry is not None:
            self.telemetry.begin_run()
        if self.spans is not None:
            self.spans.begin_run()

    # -- plane registry ------------------------------------------------------

    @property
    def registry(self) -> PlaneRegistry:
        if getattr(self, "_registry", None) is None:
            self._registry = PlaneRegistry(getattr(self, "axis", None))
        return self._registry

    def _register_obs_planes(self, shards: int = 1, *, stacked: bool = False,
                             births_shape=None,
                             births_sharded: bool = False) -> None:
        """Register the trace/span/births carry groups (empty groups when
        the corresponding collector is off, so specs stay derivable)."""
        reg = self.registry
        reg.register("trace", self._tel_init(shards))
        reg.register("span", self._span_init(shards, stacked=stacked),
                     sharded=stacked)
        births = None
        if self.spans is not None and births_shape is not None:
            births = _sds(births_shape)
        reg.register("births", births, sharded=births_sharded)

    def loop_carry_bytes(self, shards: Optional[int] = None) -> int:
        """Per-shard bytes of registered loop carry (queue planes + obs
        planes; the workload's acc is excluded — it is the caller's
        state, not the engine's).  This is the measured column behind
        the sharded ring's O(ring/shards) claim (bench_mesh)."""
        return self.registry.bytes_per_shard(
            shards if shards is not None else getattr(self, "shards", 1))

    # -- obs plane lifecycle (memoized zero-init, DESIGN.md § 7.5/7.6) -------

    def _tel_init(self, shards: int = 1):
        """Fresh plane for one run (telemetry on), else None.  The zero
        plane is immutable (recording is functional), so one instance is
        memoized and shared across runs — plane init must not show up in
        the per-run overhead budget (DESIGN.md § 7.5)."""
        if self.telemetry is None:
            return None
        key = (self.telemetry.capacity, shards)
        if getattr(self, "_tel_zero_key", None) != key:
            self._tel_zero = trace_init(*key)
            self._tel_zero_key = key
        return self._tel_zero

    def _span_init(self, shards: int = 1, *, stacked: bool = False):
        """Fresh SpanPlane for one run (spans on), else None — memoized
        like ``_tel_init`` (same zero-init budget rule, DESIGN.md § 7.6).
        ``stacked=True`` (the mesh engines) broadcasts a leading shard
        axis for ``P(axis)``-sharded planes; with no ``class_of`` the
        mesh histogram defaults to one row per shard."""
        if self.spans is None:
            return None
        rows = self.spans.classes
        if stacked and self.spans.class_of is None:
            rows = shards
        key = (rows, self.spans.buckets, self.spans.flow_capacity,
               shards if stacked else 0, self.batch)
        if getattr(self, "_span_zero_key", None) != key:
            z = span_init(rows, buckets=self.spans.buckets,
                          flow_capacity=self.spans.flow_capacity,
                          lanes=self.batch)
            if stacked:
                z = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x[None], (shards,) + x.shape),
                    z)
            self._span_zero = z
            self._span_zero_key = key
        return self._span_zero

    def _births_init(self, shape):
        """Fresh zeroed birth-stamp plane (spans on), else None — memoized;
        zero stamps make seed items born at round 0 by construction."""
        if self.spans is None:
            return None
        if getattr(self, "_births_zero_shape", None) != shape:
            self._births_zero = jnp.zeros(shape, jnp.int32)
            self._births_zero_shape = shape
        return self._births_zero

    def _span_cls(self, keys_or_vals, default):
        """Per-lane class row: the collector's ``class_of`` applied to the
        popped keys (priority) / payloads (FIFO), else ``default``."""
        if self.spans is not None and self.spans.class_of is not None:
            return jnp.asarray(self.spans.class_of(keys_or_vals), jnp.int32)
        return default

    def _tel_plane(self):
        """Current TracePlane from the chunk state (``_run_chunks``
        installs the accessor)."""
        raise NotImplementedError

    def _span_plane(self):
        """Current SpanPlane from the chunk state (``_run_chunks``
        installs the accessor)."""
        raise NotImplementedError

    # -- the ONE fused loop builder ------------------------------------------

    def fused_loop(self, round_fn, occ_of, qstate, acc, processed, spawned,
                   max_occ, limit, tp, sp, births):
        """Build and run the jitted megaround ``lax.while_loop`` over one
        engine's round body.  ``round_fn`` follows the ``_round`` contract
        (module docstring); ``occ_of`` maps the queue state to its traced
        occupancy.  Carry layout (and return):

            (qstate, acc, processed, spawned, max_occ, oflow, rounds,
             tp, sp, births)

        ``tp``/``sp``/``births`` slots are ``None`` pytrees when the
        corresponding collector is off, so the default call compiles to
        the exact unobserved graph — every obs branch here is
        python-level.  The counter updates are exactly the hand-rolled
        engines' updates (bit-identity rule, tests/test_enginecore.py)."""
        tel = tp is not None

        def body(carry):
            (qstate, acc, processed, spawned, max_occ, oflow, rounds,
             tp, sp, births) = carry
            r = round_fn(qstate, acc, tel=tel, sp=sp, births=births)
            qstate, acc, k, total, over, telinfo, sp, births = r[:8]
            if tel:
                pops, pushes, occs, mn, mx = telinfo
                tp = trace_record(tp, tp.count, pops, pushes, occs,
                                  mn, mx, over)
            return (qstate, acc, processed + k, spawned + total,
                    jnp.maximum(max_occ, occ_of(qstate)), oflow | over,
                    rounds + 1, tp, sp, births)

        def cond(carry):
            c = ((occ_of(carry[0]) > 0) & (~carry[5])
                 & (carry[6] < limit))
            if self._extra_cond is not None:
                c = c & self._extra_cond(carry)
            return c

        return jax.lax.while_loop(cond, body, (
            qstate, acc, processed, spawned, max_occ, jnp.bool_(False),
            jnp.int32(0), tp, sp, births))

    def _megaround_impl(self, qstate, acc, processed, spawned, max_occ,
                        limit, tp=None, sp=None, births=None):
        """Default megaround: the fused loop over this engine's round.
        Mesh engines wrap this to unstack/restack their ``P(axis)``
        leaves at the shard_map boundary."""
        return self.fused_loop(self._round, self._occ_of, qstate, acc,
                               processed, spawned, max_occ, limit,
                               tp, sp, births)

    # -- host drivers --------------------------------------------------------

    def _run_chunks(self, state, ext, occ_fn, what: str,
                    max_rounds: int) -> None:
        """Drive the standardized megaround to quiescence.  ``state`` =
        ``[qstate, acc, processed, spawned, max_occ]`` (mutated in
        place), ``ext`` = ``[tp, sp, births]``; ``occ_fn(qstate)`` is the
        ONE host-sync readback per chunk."""
        self._tel_plane = lambda: ext[0]
        self._span_plane = lambda: ext[1]

        def chunk_fn(limit):
            out = self._megaround(*state, jnp.int32(limit), *ext)
            state[:] = out[:5]
            oflow, r = out[5], out[6]
            ext[:] = out[7:]
            occ = occ_fn(state[0])              # THE host sync
            return (occ, int(r), bool(oflow), int(state[2]),
                    int(state[3]), int(state[4]))

        self._drive(chunk_fn, max_rounds, what)

    def _drive(self, chunk_fn, max_rounds: int, what: str) -> None:
        """``chunk_fn(limit)`` advances internal state by up to ``limit``
        rounds and returns (occupancy, rounds_delta, overflow, processed,
        spawned, max_occ) — one host sync per call."""
        chunk = self.sync_every if self.sync_every > 0 else max_rounds
        rounds = host_syncs = 0
        while True:
            limit = min(chunk, max_rounds - rounds)
            if self.spans is not None:
                # stamp-time cap enforcement: no round past the cap ever
                # writes a packed birth stamp (the stamps would wrap)
                limit = min(limit, self.span_round_cap - rounds)
            occ, r, oflow, processed, spawned, max_occ = chunk_fn(limit)
            rounds += r
            host_syncs += 1
            now = time.time()
            point = SyncPoint(rounds=rounds, occupancy=occ, wall_time=now,
                              host_syncs=host_syncs)
            self.sync_log.append(point)
            self.stats = {
                "rounds": rounds, "processed": processed, "spawned": spawned,
                "max_occupancy": max_occ, "drained": int(occ == 0),
                "host_syncs": host_syncs,
            }
            if self.telemetry is not None:
                self.telemetry.drain(self._tel_plane(),
                                     sync=host_syncs - 1, wall_time=now)
                self.telemetry.heartbeat(point)
                self.telemetry.finish(self.stats)
            if self.spans is not None:
                self.spans.drain(self._span_plane(), wall_time=now)
                self.spans.finish(self.stats)
            if oflow:
                raise RuntimeError(
                    f"{what} overflow: occupancy {occ} + spawned children "
                    f"exceed capacity {self.capacity} at round {rounds} "
                    f"(raise capacity_log2 or lower the fanout)")
            if occ == 0:
                return
            if self.spans is not None and rounds >= self.span_round_cap:
                raise RuntimeError(
                    f"{what} span round clock reached the packed "
                    f"birth-stamp cap ({self.span_round_cap} rounds) with "
                    f"occupancy {occ}: stamps would wrap the "
                    f"(birth << 1) | 1 flag plane (run without spans or "
                    f"split the run)")
            if rounds >= max_rounds:
                raise RuntimeError(
                    f"{what} round loop truncated at max_rounds="
                    f"{max_rounds} with occupancy {occ}: not quiescent "
                    f"(stats['drained']=0)")

    def _legacy_loop(self, state, acc, round_call, occ0: int, occ_fn,
                     what: str, max_rounds: int, on_round=None):
        """The host-driven per-round baseline (one jitted dispatch + one
        occupancy readback per round, ``host_syncs == rounds``), shared
        by the legacy mesh runners.  ``round_call(state, acc)`` returns
        ``(state, acc, k, total, over, extra)``; ``on_round(extra)``
        fires per round (the priority trace recorder).  Returns
        ``(state, acc)``; raises the engine's overflow/truncation errors
        with its ``what`` wording."""
        rounds = processed = spawned = host_syncs = 0
        occ = max_occ = occ0
        overflow = False
        while occ > 0 and rounds < max_rounds:
            state, acc, k, total, over, extra = round_call(state, acc)
            occ = occ_fn(state)
            host_syncs += 1                     # per-round readback
            rounds += 1
            processed += int(k)
            spawned += int(total)
            max_occ = max(max_occ, occ)
            self.sync_log.append(SyncPoint(
                rounds=rounds, occupancy=occ, wall_time=time.time(),
                host_syncs=host_syncs))
            if on_round is not None:
                on_round(extra)
            if bool(over):
                overflow = True
                break
        self.stats = {"rounds": rounds, "processed": processed,
                      "spawned": spawned, "max_occupancy": max_occ,
                      "drained": int(occ == 0),
                      "host_syncs": host_syncs, "fused": 0}
        if overflow:
            raise RuntimeError(
                f"{what} overflow: occupancy {occ} + spawned children "
                f"exceed capacity {self.capacity} at round {rounds} (raise "
                f"capacity_log2 or lower the fanout)")
        if occ > 0:
            raise RuntimeError(
                f"{what} round loop truncated at max_rounds={max_rounds} "
                f"with occupancy {occ}: not quiescent "
                f"(stats['drained']=0)")
        return state, acc


def deprecated_engine(new_name: str):
    """Class decorator for the legacy ``Fused*`` entry points: identical
    constructor signature and behavior (a subclass), plus a
    ``DeprecationWarning`` naming the core configuration to use."""
    def wrap(cls):
        base = cls.__mro__[1]

        def __init__(self, *args, **kwargs):
            warnings.warn(
                f"{cls.__name__} is deprecated: use {new_name} (the four "
                f"round loops are unified behind runtime.enginecore)",
                DeprecationWarning, stacklevel=2)
            base.__init__(self, *args, **kwargs)

        cls.__init__ = __init__
        return cls
    return wrap
