"""End-to-end training driver (deliverable b): a ~100M-param-class reduced
model for a few hundred steps with checkpoints and an injected node failure
mid-run to demonstrate restart.

    PYTHONPATH=src python examples/train_lm.py
"""

import subprocess
import sys

subprocess.run([sys.executable, "-m", "repro.launch.train",
                "--arch", "mamba2-130m", "--steps", "200",
                "--batch", "4", "--seq", "64",
                "--ckpt-dir", "/tmp/repro_ckpt", "--save-every", "50",
                "--inject-fault-at", "120"],
               check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
