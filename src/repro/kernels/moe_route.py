"""Capacity-bounded MoE dispatch via per-expert ticket reservation — the
paper's wave-batched FAA applied to expert routing (DESIGN.md § 2.1).

Each routed (token, choice) pair must claim a slot in its expert's bounded
ring.  A naive implementation performs one atomic per pair on the expert's
Tail counter; this kernel aggregates per tile: within a (TILE, E) one-hot
block it computes exclusive prefix ranks, and commits **one** per-expert
count update per tile into a VMEM accumulator carried across the sequential
TPU grid — Fig. 1's contention collapse, per expert.  Slots ≥ capacity are
dropped (the bounded ring's RETRY path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 128  # routed pairs per grid step


def _route_kernel(capacity, eids_ref, slots_ref, base_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        base_ref[...] = jnp.zeros_like(base_ref)

    e = eids_ref[...]                                  # (1, TILE) expert ids
    n_e = base_ref.shape[1]
    onehot = (e.reshape(TILE, 1)
              == jax.lax.broadcasted_iota(jnp.int32, (TILE, n_e), 1))
    onehot = onehot.astype(jnp.int32)
    ranks = jnp.cumsum(onehot, axis=0) - onehot        # exclusive, per expert
    base = base_ref[...]                               # (1, E)
    slot = jnp.sum((ranks + base) * onehot, axis=1)    # (TILE,)
    valid = (e[0, :] >= 0) & (slot < capacity)
    slots_ref[...] = jnp.where(valid, slot, -1).reshape(1, TILE)
    # ONE per-expert commit per tile (aggregate-then-commit)
    base_ref[...] = base + jnp.sum(
        jnp.where((e.reshape(TILE, 1) >= 0), onehot, 0), axis=0, keepdims=True)


@functools.partial(jax.jit,
                   static_argnames=("num_experts", "capacity", "interpret"))
def expert_tickets(expert_ids: jax.Array, *, num_experts: int, capacity: int,
                   interpret: bool = True):
    """expert_ids: (N,) int32 (N % 128 == 0, -1 = inactive pair).
    Returns slots (N,) int32: the pair's ring slot in its expert, or -1 when
    the expert's bounded ring is full (dropped token)."""
    n = expert_ids.shape[0]
    assert n % TILE == 0
    blocks = n // TILE
    kern = functools.partial(_route_kernel, capacity)
    slots = pl.pallas_call(
        kern,
        grid=(blocks,),
        in_specs=[pl.BlockSpec((1, TILE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, TILE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((blocks, TILE), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, num_experts), jnp.int32)],
        interpret=interpret,
    )(expert_ids.reshape(blocks, TILE))
    return slots.reshape(n)


def moe_route(gates: jax.Array, k: int, capacity: int, *,
              interpret: bool = True):
    """Full routing: top-k gating (jnp) + kernel-based ticket reservation.
    Matches ref.moe_route_ref.  gates: (T, E) with T*k % 128 == 0."""
    t, e = gates.shape
    top_g, top_e = jax.lax.top_k(gates, k)
    flat = top_e.reshape(t * k).astype(jnp.int32)
    slots = expert_tickets(flat, num_experts=e, capacity=capacity,
                           interpret=interpret)
    dispatch = slots.reshape(t, k)
    ok = dispatch >= 0
    probs = jax.nn.softmax(top_g, axis=-1)
    combine = jnp.where(ok, probs, 0.0)
    return dispatch, top_e, combine
