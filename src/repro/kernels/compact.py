"""Device-side wave compaction — segmented-scan child packing
(DESIGN.md § 4.4, paper § III).

The fused engines build each round's child wave ``batch × max_fanout``
lanes wide and historically scattered the full sparse block.  On power-law
graphs almost every lane is masked out, so the scatter width — and at mesh
scope the collective payload — is O(B·F) for O(n_child) live children:
the one regime where the host-compacted legacy path still won (BENCH_3,
kron at batch 1024).  This module closes it with the classic prefix-sum
stream compaction (Wald'11 ray wavefronts, our ``render_compaction``
baseline), run on device *inside* the jitted loop:

    rank   = exclusive prefix sum of the spawn mask      (the ballot scan)
    dense[rank[i]] = plane[i]   for every active lane i  (one drop-scatter)

Because the ranks are exactly the row-major ticket ranks ``wavefaa``
promises (Lemma III.1's order), the compacted wave installs with
*contiguous* tickets ``tail + [0, n_child)`` — bit-identical planes to the
sparse install, with the scatter width cut to the engine's capacity bound.

Two faces, bit-identical (asserted by tests):

* ``wave_compact`` — the Pallas kernel, mirroring ``wavefaa``: a grid of
  VREG-tiled mask blocks, the in-block ``cumsum`` rank, ONE scalar
  rank-base commit per block into an SMEM accumulator, and a masked
  drop-scatter into a full-width dense output block that persists across
  the (sequential) grid.  Blocks are up to ``BLOCK_LANES`` lanes so huge
  child waves don't pay per-step dispatch overhead.
* ``compact_planes`` — the pure-jnp ``lax.associative_scan`` twin for
  shard_map / while-loop-inlined paths (the mesh engines), exactly like
  ``ring_slots.enq_planes`` twins ``ring_enqueue``.

Both return the TRUE popcount, not the clamped one: a wave whose live
children exceed the compact width necessarily overflows its engine (the
width is the engine's capacity bound — the dense-wave rule, DESIGN.md
§ 4.4), and the true count is what makes the overflow check agree with
the sparse path's, lane drops notwithstanding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_env import resolve_interpret

LANES = 8 * 128          # minimum block: one (8, 128) VREG tile
BLOCK_LANES = 512 * 128  # preferred block for huge waves (64 Ki lanes)


def compact_width(nlanes: int, bound: int, mode=None):
    """The dense-wave rule: the static compact width for an ``nlanes``-wide
    sparse child wave on an engine whose per-round install is bounded by
    ``bound`` live children (its capacity-class limit — any round spawning
    more must overflow).  Returns ``None`` when compaction should not
    engage: ``mode=False`` forces it off, ``mode=None`` (auto) engages
    only when the sparse wave is wider than the bound (otherwise
    compaction cannot shrink anything), ``mode=True`` forces it on with
    ``width = min(nlanes, bound)`` (tests exercise the packed path on
    small shapes this way)."""
    if mode is False or nlanes == 0:
        return None
    w = min(int(nlanes), int(bound))
    if mode is None and int(nlanes) <= w:
        return None
    return max(w, 1)


@functools.partial(jax.jit, static_argnames=("width",))
def compact_planes(mask, planes, *, width: int):
    """Pure-jnp twin of ``wave_compact`` (shard_map/interpret paths).

    ``mask``: (N,) int32/bool spawn mask; ``planes``: tuple of (N,) int32
    value planes sharing the mask.  Returns ``(dense, count)`` where
    ``dense`` is a tuple of (width,) planes holding each input's active
    lanes packed in row-major rank order (rank ≥ width drops; tail lanes
    are zero) and ``count`` is the TRUE popcount — it may exceed
    ``width``, which callers must fold into their overflow check."""
    m = (jnp.asarray(mask) > 0).astype(jnp.int32)
    inc = jax.lax.associative_scan(jnp.add, m)   # inclusive prefix popcount
    rank = inc - m                               # exclusive rank
    idx = jnp.where((m > 0) & (rank < width), rank, width)
    dense = tuple(
        jnp.zeros((width,), jnp.int32).at[idx].set(
            jnp.asarray(p, jnp.int32), mode="drop")
        for p in planes)
    return dense, jnp.sum(m)


def _compact_kernel(width, nplanes, block, mask_ref, *refs):
    plane_refs = refs[:nplanes]
    dense_refs = refs[nplanes:2 * nplanes]
    count_ref = refs[2 * nplanes]
    acc_ref = refs[2 * nplanes + 1]
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        acc_ref[0] = 0
        for d in dense_refs:
            d[...] = jnp.zeros_like(d)

    m = (mask_ref[...] > 0).astype(jnp.int32)        # (rows, 128) block
    flat = m.reshape(1, block)
    rank = jnp.cumsum(flat, axis=1) - flat           # in-block exclusive rank
    base = acc_ref[0]
    # ranks past the dense width drop (the wave must overflow its engine;
    # the true count below keeps that check exact)
    idx = jnp.where(flat > 0, base + rank, width)
    for p, d in zip(plane_refs, dense_refs):
        v = p[...].reshape(1, block)
        d[...] = d[...].at[0, idx[0]].set(v[0], mode="drop")
    # ONE commit per block — the same aggregation step as wavefaa
    acc_ref[0] = base + jnp.sum(m)

    @pl.when(step == pl.num_programs(0) - 1)
    def _fin():
        count_ref[0] = acc_ref[0]


def wave_compact(mask, planes, *, width: int, interpret=None):
    """Ballot-compact ``planes`` by ``mask`` into (width,) dense waves —
    the Pallas face.  Same contract and bit-identical results as
    ``compact_planes`` (rank ≥ width drops, TRUE popcount returned);
    ``interpret=None`` resolves via REPRO_PALLAS_INTERPRET / backend.
    Arbitrary N — the wrapper zero-pads to the block grid."""
    return _wave_compact_jit(mask, tuple(planes), width=int(width),
                             interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def _wave_compact_jit(mask, planes, *, width: int, interpret: bool):
    n = mask.shape[0]
    block = LANES if n <= BLOCK_LANES else BLOCK_LANES
    npad = -(-max(n, 1) // block) * block
    m = (jnp.asarray(mask) > 0).astype(jnp.int32)
    if npad != n:
        m = jnp.zeros((npad,), jnp.int32).at[:n].set(m)
        planes = tuple(jnp.zeros((npad,), jnp.int32).at[:n].set(
            jnp.asarray(p, jnp.int32)) for p in planes)
    else:
        planes = tuple(jnp.asarray(p, jnp.int32) for p in planes)
    blocks, rows = npad // block, block // 128
    wpad = -(-width // 128) * 128               # dense block: 128-lane tiles
    nplanes = len(planes)
    kern = functools.partial(_compact_kernel, width, nplanes, block)
    call = pl.pallas_call(
        kern,
        grid=(blocks,),
        in_specs=[pl.BlockSpec((rows, 128), lambda i: (i, 0))] * (1 + nplanes),
        out_specs=[pl.BlockSpec((1, wpad), lambda i: (0, 0))] * nplanes
        + [pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_shape=[jax.ShapeDtypeStruct((1, wpad), jnp.int32)] * nplanes
        + [jax.ShapeDtypeStruct((1,), jnp.int32)],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )
    with jax.named_scope("repro.wave_compact"):
        outs = call(m.reshape(blocks * rows, 128),
                    *[p.reshape(blocks * rows, 128) for p in planes])
    dense = tuple(o.reshape(wpad)[:width] for o in outs[:nplanes])
    return dense, outs[nplanes][0]
