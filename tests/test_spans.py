"""Span-layer invariants (DESIGN.md § 7.6):

* the device log2 bucket rule bit-matches the host twin (``bucket_of``)
  and ``span_record`` bit-matches a numpy oracle on random claim waves,
  including all-inactive waves (which must not perturb the plane);
* ``spans=None`` compiles each fused engine to the exact unspanned loop —
  spans on vs off is bit-identical on the acc, the queue planes, and
  every stats counter, for all four fused engines;
* the device sojourn histogram bit-matches a host FIFO replay of the
  fused round engine (every task counted once, at its true wait);
* birth stamps survive distqueue ticket wraparound across the int32
  boundary (the ``dist_queue_init(start=...)`` regime);
* per-class rows: ``class_of`` routes sojourns to the right histogram
  row with exact counts;
* export: ``write_jsonl(spans=...)`` round-trips the ``hist``/``flow``
  lines and both emitters pass ``tools/trace_check.py``, which also
  rejects empty-string stand-ins for numeric fields;
* the sojourn analyzers (percentiles, high-water, starvation flags) and
  the legacy-engine rejection contract.
"""

import collections
import os
import subprocess
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.jaxcompat import make_mesh  # noqa: E402
from repro.obs import (  # noqa: E402
    Spans, Telemetry, bucket_edges, bucket_of, max_wait_highwater,
    read_jsonl, sojourn_percentiles, span_init, span_record, span_tick,
    starvation_flags, to_chrome_trace, write_chrome_trace, write_jsonl)
from repro.runtime import (  # noqa: E402
    MeshRoundRunner, PriorityMeshRoundRunner, PriorityRoundRunner,
    RoundRunner)

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _mesh1():
    return make_mesh((1,), ("data",))


def _tree_step():
    def step(acc, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        cm = (valid & (vals < 32))[:, None]
        return acc, cv, cm
    return step


def _pri_step():
    def step(acc, keys, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        ck = jnp.stack([keys + 1, keys + 2], -1).astype(jnp.int32)
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        cm = (valid & (vals < 32))[:, None]
        return acc, ck, cv, cm
    return step


def _pri_mesh_tree_step():
    def step(acc, keys, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        ck = (cv * 7919) % 1000
        cm = (valid & (vals < 32))[:, None]
        return acc, ck, cv, cm
    return step


def _assert_identical(res_off, res_on):
    (acc0, st0, stats0), (acc1, st1, stats1) = res_off, res_on
    np.testing.assert_array_equal(np.asarray(acc0), np.asarray(acc1))
    for a, b in zip(jax.tree.leaves(st0), jax.tree.leaves(st1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert stats0 == stats1


# -- the device bucket rule and span_record vs a numpy oracle -----------------


@pytest.mark.parametrize("buckets", [2, 8, 16])
def test_bucket_rule_device_matches_host(buckets):
    sojourns = np.concatenate([np.arange(200),
                               [2 ** 10, 2 ** 20, 2 ** 30, 2 ** 31 - 1]])
    sp = span_init(1, buckets=buckets, flow_capacity=1,
                   lanes=len(sojourns))
    sp = span_record(sp, np.zeros(len(sojourns), np.int32),
                     sojourns.astype(np.int32),
                     np.ones(len(sojourns), bool),
                     np.arange(len(sojourns), dtype=np.int32))
    want = np.bincount([bucket_of(s, buckets) for s in sojourns],
                       minlength=buckets)
    # lane-major device plane: counts fold across lanes, max-wait is the
    # trailing column
    acc = np.asarray(sp.hist)
    np.testing.assert_array_equal(acc[:, 0, :buckets].sum(0), want)
    assert int(acc[:, 0, buckets].max()) == 2 ** 31 - 1
    # edges bracket their bucket: bucket_of(edge) == that bucket
    for b, e in enumerate(bucket_edges(buckets)):
        assert bucket_of(int(e), buckets) == b


def test_span_record_matches_numpy_oracle_random():
    rng = np.random.default_rng(7)
    k, nb, f, b = 3, 8, 16, 11
    sp = span_init(k, buckets=nb, flow_capacity=f, lanes=b)
    hist = np.zeros((k, nb), np.int64)
    maxw = np.zeros((k,), np.int64)
    flows = []
    rnd = 0
    for _ in range(20):
        cls = rng.integers(0, k, b).astype(np.int32)
        s = rng.integers(0, 300, b).astype(np.int32)
        valid = rng.random(b) < 0.6
        sp = span_record(sp, cls, s, valid, np.arange(b, dtype=np.int32))
        sp = span_tick(sp)
        for c, w, v in zip(cls, s, valid):
            if v:
                hist[c, bucket_of(int(w), nb)] += 1
                maxw[c] = max(maxw[c], int(w))
        # flow ring samples ONE exemplar per recorded round: lane 0's
        # lifecycle, whenever lane 0 claimed (ref is lane index = 0)
        if valid[0]:
            flows.append((rnd - int(s[0]), rnd, int(cls[0]), 0))
        rnd += 1
    acc = np.asarray(sp.hist)
    np.testing.assert_array_equal(acc[..., :nb].sum(0), hist)
    np.testing.assert_array_equal(acc[..., nb].max(0), maxw)
    assert int(sp.fcount) == len(flows)
    assert int(sp.round) == rnd
    # ring keeps the newest min(f, written) exemplars, in write order
    keep = min(len(flows), f)
    kept = flows[len(flows) - keep:]
    got = np.asarray(sp.flows)[
        np.arange(len(flows) - keep, len(flows)) % f]
    np.testing.assert_array_equal(got, np.asarray(kept))


def test_span_record_all_inactive_wave_no_change():
    sp = span_init(2, buckets=8, flow_capacity=4, lanes=2)
    sp = span_record(sp, jnp.array([0, 1]), jnp.array([3, 5]),
                     jnp.array([True, True]), jnp.array([9, 9]))
    before = jax.tree.map(np.asarray, sp)
    sp2 = span_record(sp, jnp.array([0, 1]), jnp.array([7, 7]),
                      jnp.array([False, False]), jnp.array([9, 9]))
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(sp2)):
        np.testing.assert_array_equal(a, np.asarray(b))


# -- spans=None bit-identity on all four fused engines ------------------------


def _run_engine(name, sp, mesh):
    if name == "rounds":
        r = RoundRunner(_tree_step(), capacity_log2=8, batch=16, spans=sp)
        acc, st = r.run([1], acc=jnp.zeros(80, jnp.int32))
    elif name == "prounds":
        r = PriorityRoundRunner(_pri_step(), capacity_log2=8, batch=16,
                                spans=sp)
        acc, st = r.run([5], [1], acc=jnp.zeros(80, jnp.int32))
    elif name == "mesh":
        r = MeshRoundRunner(_tree_step(), mesh=mesh, capacity_log2=8,
                            batch=16, combine=lambda a: a.sum(0), spans=sp)
        acc, st = r.run([1], acc=jnp.zeros(80, jnp.int32))
    else:
        r = PriorityMeshRoundRunner(_pri_mesh_tree_step(), mesh=mesh,
                                    capacity_log2=8, batch=16,
                                    relaxed=(name == "pmesh-relaxed"),
                                    combine=lambda a: a.sum(0), spans=sp)
        acc, st = r.run([7919 % 1000], [1], acc=jnp.zeros(80, jnp.int32))
    return (acc, st, dict(r.stats))


@pytest.mark.parametrize("name", ["rounds", "prounds", "mesh",
                                  "pmesh-relaxed", "pmesh-strict"])
def test_spans_off_bit_identical(name):
    mesh = _mesh1()
    off = _run_engine(name, None, mesh)
    sp = Spans(classes=1, engine=name)
    on = _run_engine(name, sp, mesh)
    _assert_identical(off, on)
    assert sp.total == on[2]["processed"]   # one sojourn per task
    assert sp.percentile(0.99) is not None
    # the body is claim → step → publish, so no child turns around in the
    # round it was born: every non-seed waits >= 1 round, and the engine
    # final round always claims something (quiescence) — histogram mass
    # beyond bucket 0 is guaranteed on a multi-round tree
    assert on[2]["rounds"] > 1
    assert int(sp.hist[:, 1:].sum()) > 0


# -- device histogram vs host FIFO replay -------------------------------------


def test_fused_rounds_histogram_matches_host_replay():
    batch = 16
    sp = Spans(classes=1, engine="rounds")
    r = RoundRunner(_tree_step(), capacity_log2=8, batch=batch, spans=sp)
    r.run([1], acc=jnp.zeros(80, jnp.int32))
    # host replay of the FIFO megaround: claim min(batch, size) oldest,
    # record sojourn, append children (vals < 32 spawn 2v, 2v+1) at birth
    # round = the claiming round
    q = collections.deque([(1, 0)])
    hist = np.zeros((1, sp.buckets), np.int64)
    maxw = np.zeros((1,), np.int64)
    rnd = 0
    while q:
        wave = [q.popleft() for _ in range(min(batch, len(q)))]
        for v, born in wave:
            s = rnd - born
            hist[0, bucket_of(s, sp.buckets)] += 1
            maxw[0] = max(maxw[0], s)
        for v, _ in wave:
            if v < 32:
                q.append((2 * v, rnd))
                q.append((2 * v + 1, rnd))
        rnd += 1
    assert r.stats["rounds"] == rnd
    np.testing.assert_array_equal(sp.hist, hist)
    np.testing.assert_array_equal(sp.max_wait, maxw)


def test_priority_class_rows_exact():
    # batch=1 over two inert seeds: key 3 (class 0) pops in round 0 with
    # sojourn 0, key 100 (class 1) pops in round 1 with sojourn 1
    def inert(acc, keys, vals, valid):
        z = jnp.zeros((keys.shape[0], 1), jnp.int32)
        return acc + valid.sum(), z, z, z.astype(bool)

    sp = Spans(classes=2, engine="pr", class_of=lambda k: k // 64)
    r = PriorityRoundRunner(inert, capacity_log2=4, batch=1, spans=sp)
    r.run([3, 100], [7, 8], acc=jnp.int32(0))
    np.testing.assert_array_equal(
        sp.hist, [[1] + [0] * (sp.buckets - 1),
                  [0, 1] + [0] * (sp.buckets - 2)])
    np.testing.assert_array_equal(sp.max_wait, [0, 1])
    assert [(f["birth"], f["claim"], f["cls"]) for f in sp.flows] == \
        [(0, 0, 0), (0, 1, 1)]


# -- ticket wraparound across the int32 boundary ------------------------------


def test_birth_stamps_survive_ticket_wraparound():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core.distqueue import (dist_claim_round, dist_publish_round,
                                      dist_queue_init)
    mesh = _mesh1()
    cap = 64                      # n2 = 128 physical slots
    state = dist_queue_init(cap, start=(2 ** 31 - 128))
    births = jnp.zeros((128,), jnp.int32)
    b = 48

    def inner(state, births):
        vals = jnp.arange(b, dtype=jnp.int32) + 100
        mask = jnp.ones((b,), jnp.int32)
        bouts = []
        # round 1's tickets cross 2**31 (tail starts 128 below, round 0
        # advances it 48): stamps must read back across the wrap
        for r in range(2):
            pr = dist_publish_round(state, vals, mask, "data", capacity=cap,
                                    births=births,
                                    birth_round=jnp.int32(r + 5))
            state, births = pr[0], pr[4]
            cr = dist_claim_round(state, jnp.int32(b), b, "data",
                                  births=births)
            state, ok, bout = cr[0], cr[2], cr[3]
            bouts.append((ok, bout))
        return bouts[0] + bouts[1]

    f = jax.jit(shard_map(inner, mesh=mesh,
                          in_specs=(P(), P()),
                          out_specs=(P(), P(), P(), P()),
                          check_rep=False))
    ok0, b0, ok1, b1 = f(state, births)
    assert bool(np.asarray(ok0).all()) and bool(np.asarray(ok1).all())
    np.testing.assert_array_equal(np.asarray(b0), np.full(b, 5))
    np.testing.assert_array_equal(np.asarray(b1), np.full(b, 6))


# -- 2-shard forced-device parity + merge -------------------------------------


_TWO_SHARD_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys; sys.path.insert(0, {src!r})
import numpy as np
import jax, jax.numpy as jnp
from repro.jaxcompat import make_mesh
from repro.obs import Spans
from repro.runtime import MeshRoundRunner, PriorityMeshRoundRunner

mesh = make_mesh((2,), ("data",))

def tree_step(acc, vals, valid):
    acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
    cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
    cm = (valid & (vals < 32))[:, None]
    return acc, cv, cm

def pri_step(acc, keys, vals, valid):
    acc, cv, cm = tree_step(acc, vals, valid)
    ck = (cv * 7919) % 1000
    return acc, ck, cv, cm

def check(mk_runner, run_args, engine):
    out = []
    for sp in (None, Spans(classes=2, engine=engine)):
        r = mk_runner(sp)
        acc, st = r.run(*run_args, acc=jnp.zeros(80, jnp.int32))
        out.append((np.asarray(acc), jax.tree.leaves(st), dict(r.stats)))
    np.testing.assert_array_equal(out[0][0], out[1][0])
    for a, b in zip(out[0][1], out[1][1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert out[0][2] == out[1][2]
    # the sharded planes merged at drain: mass == processed, 2 rows
    assert sp.total == out[1][2]["processed"], engine
    assert sp.hist.shape[0] == 2, engine
    return sp

sp = check(lambda sp: MeshRoundRunner(
    tree_step, mesh=mesh, capacity_log2=8, batch=16,
    combine=lambda a: a.sum(0), spans=sp), ([1],), "mesh")
assert all(r.sum() > 0 for r in sp.hist)     # both shards claimed work

for relaxed in (True, False):
    check(lambda sp: PriorityMeshRoundRunner(
        pri_step, mesh=mesh, capacity_log2=8, batch=16, relaxed=relaxed,
        combine=lambda a: a.sum(0), spans=sp),
        ([7919 % 1000], [1]), "pmesh")
print("TWO_SHARD_SPANS_OK")
"""


def test_two_shard_mesh_spans_bit_identical():
    """Forced-device acceptance: spans on vs off is bit-identical on the
    mesh engines at 2 shards, and the sharded span planes merge to
    exactly one sojourn per processed task (the strict mode's local-slice
    recording must not double-count the replicated heap)."""
    src = os.path.join(REPO, "src")
    res = subprocess.run(
        [sys.executable, "-c", _TWO_SHARD_SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "TWO_SHARD_SPANS_OK" in res.stdout


# -- export / trace_check -----------------------------------------------------


def test_span_export_roundtrip_and_trace_check(tmp_path):
    tel = Telemetry(256, engine="rounds")
    sp = Spans(classes=1, engine="rounds")
    r = RoundRunner(_tree_step(), capacity_log2=8, batch=16,
                    telemetry=tel, spans=sp)
    r.run([1], acc=jnp.zeros(80, jnp.int32))
    path = str(tmp_path / "trace.jsonl")
    n = write_jsonl(path, tel.records, tel.sync_points,
                    metrics=tel.registry.snapshot(), engine="rounds",
                    spans=sp)
    assert n == 1 + len(tel.records) + len(tel.sync_points) + 1 \
        + 1 + len(sp.flows)
    back = read_jsonl(path)
    want = dict(sp.summary())
    want["engine"] = "rounds"
    assert back["hist"] == want
    assert back["flows"] == [{"engine": "rounds", **f} for f in sp.flows]
    # chrome flow events: one s/f pair per sampled lifecycle
    trace = to_chrome_trace(tel.records, tel.sync_points, engine="rounds",
                            flows=sp.flows)
    sev = [e for e in trace["traceEvents"] if e["ph"] == "s"]
    fev = [e for e in trace["traceEvents"] if e["ph"] == "f"]
    assert len(sev) == len(fev) == len(sp.flows)
    assert all(e["bp"] == "e" for e in fev)
    chrome = str(tmp_path / "trace.json")
    write_chrome_trace(chrome, tel.records, tel.sync_points,
                       engine="rounds", flows=sp.flows)
    tool = os.path.join(REPO, "tools", "trace_check.py")
    ok = subprocess.run([sys.executable, tool, path, "--chrome", chrome],
                        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stderr


def test_trace_check_rejects_empty_string_numerics(tmp_path):
    tel = Telemetry(256, engine="rounds")
    sp = Spans(classes=1, engine="rounds")
    r = RoundRunner(_tree_step(), capacity_log2=8, batch=16,
                    telemetry=tel, spans=sp)
    r.run([1], acc=jnp.zeros(80, jnp.int32))
    good = str(tmp_path / "good.jsonl")
    write_jsonl(good, tel.records, tel.sync_points, engine="rounds",
                spans=sp)
    tool = os.path.join(REPO, "tools", "trace_check.py")
    import json
    lines = [json.loads(ln) for ln in open(good)]
    # "" where a number belongs (the bench_obs overhead_pct pathology)
    for field, kind in (("total", "hist"), ("birth", "flow")):
        bad = str(tmp_path / f"bad_{field}.jsonl")
        with open(bad, "w") as f:
            for d in lines:
                d = dict(d)
                if d["kind"] == kind:
                    d[field] = ""
                f.write(json.dumps(d) + "\n")
        res = subprocess.run([sys.executable, tool, bad],
                             capture_output=True, text=True)
        assert res.returncode == 1 and "empty-string" in res.stderr, field
    # a hist line whose counts disagree with total is also rejected
    bad = str(tmp_path / "bad_sum.jsonl")
    with open(bad, "w") as f:
        for d in lines:
            d = dict(d)
            if d["kind"] == "hist":
                d["total"] = d["total"] + 1
            f.write(json.dumps(d) + "\n")
    res = subprocess.run([sys.executable, tool, bad],
                         capture_output=True, text=True)
    assert res.returncode == 1 and "sum" in res.stderr


# -- analyzers ----------------------------------------------------------------


def _summary(hist, maxw):
    hist = np.asarray(hist)
    return {"classes": hist.shape[0], "buckets": hist.shape[1],
            "bucket_edges": bucket_edges(hist.shape[1]).tolist(),
            "hist": hist.tolist(), "max_wait": list(maxw),
            "total": int(hist.sum()), "p50": None, "p95": None, "p99": None}


def test_sojourn_percentiles_from_summary():
    # class 0: 10 sojourns in bucket 1 (edge 1); class 1: 1 in bucket 3;
    # CDF(bucket 1) = 10/11 < 0.95, so p95 spills into the last bucket
    s = _summary([[0, 10, 0, 0], [0, 0, 0, 1]], [1, 7])
    assert sojourn_percentiles(s) == {"p50": 1, "p95": 7, "p99": 7}
    assert sojourn_percentiles(s, cls=1) == {"p50": 7, "p95": 7, "p99": 7}
    assert sojourn_percentiles(_summary(np.zeros((1, 4)), [0])) == \
        {"p50": None, "p95": None, "p99": None}


def test_max_wait_highwater_and_starvation():
    s = _summary([[50, 50, 0, 0], [0, 0, 0, 2]], [1, 900])
    hw = max_wait_highwater(s)
    assert hw == {"per_class": [1, 900], "worst_class": 1,
                  "high_water": 900}
    fl = starvation_flags(s, factor=8.0)
    assert fl["starved_classes"] == [1]          # 900 > 8 * p50(=1)
    assert fl["per_class"][0]["starved"] is False
    # fabric cross-check compares direction only (class 0 = urgent)
    agree = starvation_flags(
        s, wait_stats={"urgent_max_wait": 10.0, "normal_max_wait": 5000.0})
    assert agree["fabric"]["agrees"] is True
    disagree = starvation_flags(
        s, wait_stats={"urgent_max_wait": 5000.0, "normal_max_wait": 10.0})
    assert disagree["fabric"]["agrees"] is False


# -- API contracts ------------------------------------------------------------


def test_legacy_engines_reject_spans():
    sp = Spans(classes=1)
    with pytest.raises(ValueError, match="fused"):
        RoundRunner(_tree_step(), fused=False, spans=sp)
    with pytest.raises(ValueError, match="fused"):
        PriorityRoundRunner(_pri_step(), fused=False, spans=sp)
    with pytest.raises(ValueError, match="fused"):
        MeshRoundRunner(_tree_step(), mesh=_mesh1(), fused=False,
                        combine=lambda a: a.sum(0), spans=sp)
    with pytest.raises(ValueError, match="fused"):
        PriorityMeshRoundRunner(_pri_mesh_tree_step(), mesh=_mesh1(),
                                fused=False, combine=lambda a: a.sum(0),
                                spans=sp)


def test_spans_validation_and_multi_run_banking():
    with pytest.raises(ValueError, match="classes"):
        Spans(classes=0)
    with pytest.raises(ValueError, match="buckets"):
        Spans(buckets=1)
    with pytest.raises(ValueError, match="flow_capacity"):
        Spans(flow_capacity=0)
    sp = Spans(classes=1, engine="rounds")
    r = RoundRunner(_tree_step(), capacity_log2=8, batch=16, spans=sp)
    r.run([1], acc=jnp.zeros(80, jnp.int32))
    one = sp.total
    r.run([1], acc=jnp.zeros(80, jnp.int32))   # second run banks the first
    assert sp.total == 2 * one
    assert sp.registry.get("rounds.sojourn_p99") is not None
