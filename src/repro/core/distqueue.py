"""The distributed (mesh-level) bounded FIFO queue — the paper's design
carried above the chip (DESIGN.md § 2.3).

Aggregation hierarchy: lane → block (Pallas wavefaa, one counter update) →
chip → mesh (this module: one exclusive-prefix-sum collective hands every
chip a contiguous ticket block).  The ring state (packed field planes) is
replicated per shard and advanced by the deterministic per-round ticket
order, so every chip holds an identical view after each round — FIFO and
linearizability hold by construction: rounds are totally ordered by the
collective schedule, and within a round tickets order operations exactly as
per-thread FAA would (Lemma III.1 applied at mesh scope).

API (pure-functional, jit/shard_map-compatible):

    state = dist_queue_init(capacity)
    state, granted = dist_enqueue_round(state, values, mask, axis="data")
    state, vals, ok = dist_dequeue_round(state, want, axis="data")

Each round costs exactly one psum (ticket aggregation); payload exchange
uses all_gather of the round's compact blocks — the batched analogue of the
paper's single leader atomic per wave.

Note: the ring planes come back *deterministically identical* on every
shard, but shard_map's replication checker cannot infer that through the
gathered-scan; wrap calls with ``shard_map(..., check_rep=False)`` and
out_spec the state as ``P()`` (see tests/test_distqueue.py).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..distributed.collectives import mesh_ticket_base
from ..jaxcompat import axis_size as _axis_size, pvary as _pvary

IDX_BOT = jnp.int32(2 ** 31 - 1)
IDX_BOTC = jnp.int32(2 ** 31 - 2)


class DistQueueState(NamedTuple):
    """Replicated ring state (per-shard identical by construction)."""
    cycles: jax.Array   # (2n,) int32
    safes: jax.Array    # (2n,) int32
    idxs: jax.Array     # (2n,) int32 — payload or ⊥ / ⊥_c
    tail: jax.Array     # () int32
    head: jax.Array     # () int32


def dist_queue_init(capacity: int) -> DistQueueState:
    n2 = 2 * capacity
    return DistQueueState(
        cycles=jnp.zeros((n2,), jnp.int32),
        safes=jnp.ones((n2,), jnp.int32),
        idxs=jnp.full((n2,), IDX_BOT),
        tail=jnp.int32(n2),
        head=jnp.int32(n2),
    )


def _apply_enqueue(state: DistQueueState, tickets, values, head_now):
    n2 = state.cycles.shape[0]

    def body(st, tv):
        cyc, saf, idx = st
        t, v = tv
        j = jnp.where(t >= 0, t % n2, 0)
        c = jnp.where(t >= 0, t // n2, 0)
        empty = (idx[j] == IDX_BOT) | (idx[j] == IDX_BOTC)
        can = (t >= 0) & (cyc[j] < c) & empty & ((saf[j] == 1) | (head_now <= t))
        cyc = cyc.at[j].set(jnp.where(can, c, cyc[j]))
        saf = saf.at[j].set(jnp.where(can, 1, saf[j]))
        idx = idx.at[j].set(jnp.where(can, v, idx[j]))
        return (cyc, saf, idx), can

    (cyc, saf, idx), ok = jax.lax.scan(
        body, (state.cycles, state.safes, state.idxs), (tickets, values))
    return cyc, saf, idx, ok


def dist_enqueue_round(state: DistQueueState, values: jax.Array,
                       mask: jax.Array, axis: str):
    """One enqueue round inside shard_map.  values/mask: (B,) local requests.
    Returns (new_state, granted mask (B,))."""
    b = values.shape[0]
    count = jnp.sum(mask.astype(jnp.int32))
    base, total = mesh_ticket_base(count, axis)
    # local tickets: base + exclusive prefix rank (the wavefaa rule)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - mask.astype(jnp.int32)
    tickets = jnp.where(mask > 0, state.tail + base + rank, -1)
    # gather the round's compact blocks so every shard applies every op
    all_tickets = jax.lax.all_gather(tickets, axis).reshape(-1)
    all_values = jax.lax.all_gather(values, axis).reshape(-1)
    order = jnp.argsort(jnp.where(all_tickets >= 0, all_tickets, 2 ** 30))
    # promote the replicated ring planes to device-varying so the scan
    # carry types match the (axis-varying) gathered tickets
    state = state._replace(
        cycles=_pvary(state.cycles, axis),
        safes=_pvary(state.safes, axis),
        idxs=_pvary(state.idxs, axis))
    cyc, saf, idx, ok_sorted = _apply_enqueue(
        state, all_tickets[order], all_values[order],
        _pvary(state.head, axis))
    inv = jnp.argsort(order)
    ok_all = ok_sorted[inv]
    n = _axis_size(axis)
    me = jax.lax.axis_index(axis)
    ok_local = ok_all.reshape(n, b)[me]
    new_state = state._replace(cycles=cyc, safes=saf, idxs=idx,
                               tail=state.tail + total)
    return new_state, ok_local & (mask > 0)


def dist_dequeue_round(state: DistQueueState, want: jax.Array, axis: str):
    """One dequeue round.  want: (B,) local request mask.
    Returns (new_state, values (B,), ok (B,))."""
    b = want.shape[0]
    n2 = state.cycles.shape[0]
    count = jnp.sum(want.astype(jnp.int32))
    base, total = mesh_ticket_base(count, axis)
    rank = jnp.cumsum(want.astype(jnp.int32)) - want.astype(jnp.int32)
    tickets = jnp.where(want > 0, state.head + base + rank, -1)
    all_tickets = jax.lax.all_gather(tickets, axis).reshape(-1)
    order = jnp.argsort(jnp.where(all_tickets >= 0, all_tickets, 2 ** 30))
    ts = all_tickets[order]
    state = state._replace(
        cycles=_pvary(state.cycles, axis),
        safes=_pvary(state.safes, axis),
        idxs=_pvary(state.idxs, axis))

    def body(st, t):
        cyc, saf, idx = st
        j = jnp.where(t >= 0, t % n2, 0)
        c = jnp.where(t >= 0, t // n2, 0)
        empty = (idx[j] == IDX_BOT) | (idx[j] == IDX_BOTC)
        hit = (t >= 0) & (cyc[j] == c) & (~empty)
        val = jnp.where(hit, idx[j], -1)
        idx = idx.at[j].set(jnp.where(hit, IDX_BOTC, idx[j]))
        adv = (t >= 0) & (~hit) & empty & (cyc[j] < c)
        cyc = cyc.at[j].set(jnp.where(adv, c, cyc[j]))
        uns = (t >= 0) & (~hit) & (~empty) & (cyc[j] < c)
        saf = saf.at[j].set(jnp.where(uns, 0, saf[j]))
        return (cyc, saf, idx), (val, hit)

    (cyc, saf, idx), (vals_sorted, ok_sorted) = jax.lax.scan(
        body, (state.cycles, state.safes, state.idxs), ts)
    inv = jnp.argsort(order)
    vals_all = vals_sorted[inv]
    ok_all = ok_sorted[inv]
    n = _axis_size(axis)
    me = jax.lax.axis_index(axis)
    new_state = state._replace(cycles=cyc, safes=saf, idxs=idx,
                               head=state.head + total)
    return (new_state, vals_all.reshape(n, b)[me],
            ok_all.reshape(n, b)[me] & (want > 0))
