"""Batched d-ary heap operations as a Pallas TPU kernel (DESIGN.md § 5.6).

The device face of G-PQ, mirroring ``ring_slots.py``: the heap's packed
node words are unpacked into two parallel int32 field planes (key / val —
TPU-native 32-bit lanes) living in VMEM, and one kernel invocation applies
a *ticket-ordered batch* of operations — the wave's announce-ring drain
plus its delete-mins — in batch-index order, which is the linearization
order (the deterministic analogue of the latch-combined drain).

Each op is ``(opcode, key, val)``: opcode 0 = INSERT (sift-up, rejected
when full), 1 = DELETE-MIN (root out, last node sifts down, rejected when
empty), anything else = inactive lane padding.  Sifts are fixed-trip
``fori_loop``s over the heap's static depth with a moving flag — no
data-dependent control flow, so the kernel compiles to straight-line TPU
code.  The heap size rides in SMEM alongside the op batch.

``heap_planes`` is the pure-jnp twin of the kernel — the same masked
batched sift expressed as ``lax.scan``/``fori_loop`` plane updates, so the
mesh engine can inline heap batches into a jitted ``while_loop`` *under
shard_map* exactly as the FIFO engine inlines ``ring_slots.enq_planes``.
Both faces are bit-identical (asserted by differential tests), and both
honor inactive (``OP_NOP``) lanes, which is what makes *partial waves*
work: ``heap_pop_count`` pops a traced-count prefix of a fixed-width
batch, ``heap_insert_masked`` installs a masked subset — the claim and
publish waves of the priority mesh rounds (DESIGN.md § 6).

VMEM budget: 2 planes × 2^cap_log2 × 4 B plus the batch — a 64Ki-node
heap costs 512 KiB, comfortably inside the 16 MiB/core budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_env import resolve_interpret

KEY_INF = 2 ** 31 - 1    # empty-slot / inactive-lane key sentinel

OP_INSERT, OP_DELMIN, OP_NOP = 0, 1, -1


def _heap_kernel(cap_log2, arity_log2, size_ref, ops_ref, okeys_ref,
                 ovals_ref, keys_in, vals_in, keys_ref, vals_ref,
                 outk_ref, outv_ref, ok_ref, size_out_ref):
    cap = 1 << cap_log2
    d = 1 << arity_log2
    # static depth: levels needed to cover cap nodes with arity d
    max_depth = -(-cap_log2 // arity_log2) + 1
    keys_ref[...] = keys_in[...]
    vals_ref[...] = vals_in[...]
    outk_ref[...] = jnp.full_like(outk_ref, KEY_INF)
    outv_ref[...] = jnp.full_like(outv_ref, -1)
    ok_ref[...] = jnp.zeros_like(ok_ref)
    b = ops_ref.shape[1]

    def body(i, size):
        op = ops_ref[0, i]
        key = okeys_ref[0, i]
        val = ovals_ref[0, i]

        # ---- INSERT: hole starts at `size`, parents move down ----------
        do_ins = (op == OP_INSERT) & (size < cap)

        def up(_, carry):
            j, moving = carry
            p = jnp.where(j > 0, (j - 1) >> arity_log2, 0)
            pk = keys_ref[0, p]
            cond = moving & (j > 0) & (pk > key)
            jc = jnp.where(cond, j, 0)          # clamp for the masked store
            keys_ref[0, jc] = jnp.where(cond, pk, keys_ref[0, jc])
            vals_ref[0, jc] = jnp.where(cond, vals_ref[0, p], vals_ref[0, jc])
            return (jnp.where(cond, p, j), moving & cond)

        j0 = jnp.where(do_ins, size, 0)
        jf, _ = jax.lax.fori_loop(0, max_depth, up, (j0, do_ins))
        keys_ref[0, jf] = jnp.where(do_ins, key, keys_ref[0, jf])
        vals_ref[0, jf] = jnp.where(do_ins, val, vals_ref[0, jf])

        # ---- DELETE-MIN: root out, last node sifts down into the hole --
        do_pop = (op == OP_DELMIN) & (size > 0)
        outk_ref[0, i] = jnp.where(do_pop, keys_ref[0, 0], KEY_INF)
        outv_ref[0, i] = jnp.where(do_pop, vals_ref[0, 0], -1)
        nsize = jnp.where(do_pop, size - 1, size)
        lpos = jnp.where(do_pop & (size > 0), size - 1, 0)
        lk = keys_ref[0, lpos]
        lv = vals_ref[0, lpos]

        def down(_, carry):
            j, moving = carry
            base = (j << arity_log2) + 1

            def child(c, acc):
                bk, bj = acc
                cj = base + c
                in_r = cj < nsize
                ck = jnp.where(in_r, keys_ref[0, jnp.where(in_r, cj, 0)],
                               KEY_INF)
                better = ck < bk
                return (jnp.where(better, ck, bk), jnp.where(better, cj, bj))

            bk, bj = jax.lax.fori_loop(0, d, child, (KEY_INF, -1))
            cond = moving & (bj >= 0) & (bk < lk)
            jc = jnp.where(cond, j, 0)
            keys_ref[0, jc] = jnp.where(cond, bk, keys_ref[0, jc])
            vals_ref[0, jc] = jnp.where(
                cond, vals_ref[0, jnp.where(cond, bj, 0)], vals_ref[0, jc])
            return (jnp.where(cond, bj, j), moving & cond)

        moving0 = do_pop & (nsize > 0)
        jf2, _ = jax.lax.fori_loop(0, max_depth, down, (0, moving0))
        place = jnp.where(moving0, jf2, 0)
        keys_ref[0, place] = jnp.where(moving0, lk, keys_ref[0, place])
        vals_ref[0, place] = jnp.where(moving0, lv, vals_ref[0, place])
        # scrub the vacated tail slot so stale keys can't resurface
        keys_ref[0, lpos] = jnp.where(do_pop, KEY_INF, keys_ref[0, lpos])
        vals_ref[0, lpos] = jnp.where(do_pop, -1, vals_ref[0, lpos])

        ok_ref[0, i] = (do_ins | do_pop).astype(jnp.int32)
        return jnp.where(do_ins, size + 1, nsize)

    final = jax.lax.fori_loop(0, b, body, size_ref[0])
    size_out_ref[0, 0] = final


def heap_apply(keys, vals, size, ops, opkeys, opvals, *, cap_log2: int,
               arity_log2: int = 2, interpret=None):
    """Apply a batch of heap ops in batch order.  ``keys``/``vals`` are
    (cap,) int32 planes (empty slots KEY_INF / -1); ``size`` a scalar
    int32; ``ops``/``opkeys``/``opvals`` are (B,) int32.
    ``interpret=None`` resolves via REPRO_PALLAS_INTERPRET / backend.
    Returns ``(keys, vals, new_size, out_keys, out_vals, ok)`` where
    ``out_*[i]`` carry delete-min results and ``ok[i]`` certifies op i
    applied."""
    return _heap_apply_jit(keys, vals, size, ops, opkeys, opvals,
                           cap_log2=cap_log2, arity_log2=arity_log2,
                           interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("cap_log2", "arity_log2", "interpret"))
def _heap_apply_jit(keys, vals, size, ops, opkeys, opvals, *, cap_log2: int,
                    arity_log2: int, interpret: bool):
    cap = 1 << cap_log2
    b = ops.shape[0]
    kern = functools.partial(_heap_kernel, cap_log2, arity_log2)
    call = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
        ] + [pl.BlockSpec((1, cap), lambda i: (0, 0))] * 2,
        out_specs=[pl.BlockSpec((1, cap), lambda i: (0, 0))] * 2
        + [pl.BlockSpec((1, b), lambda i: (0, 0))] * 3
        + [pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, cap), jnp.int32)] * 2
        + [jax.ShapeDtypeStruct((1, b), jnp.int32)] * 3
        + [jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )
    with jax.named_scope("repro.heap_apply"):
        outs = call(size.reshape(1), ops.reshape(1, b), opkeys.reshape(1, b),
                    opvals.reshape(1, b), keys.reshape(1, cap),
                    vals.reshape(1, cap))
    k, v, outk, outv, ok, nsize = outs
    return (k.reshape(cap), v.reshape(cap), nsize.reshape(())[()],
            outk.reshape(b), outv.reshape(b), ok.reshape(b).astype(bool))


# ---------------------------------------------------------------------------
# pure-jnp plane face — the shard_map/while_loop-inlinable twin of the kernel
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cap_log2", "arity_log2"))
def heap_planes(keys, vals, size, ops, opkeys, opvals, *, cap_log2: int,
                arity_log2: int = 2, rider=None, oprider=None):
    """Apply a batch of heap ops in batch order — pure jnp, no Pallas.

    Same contract and bit-identical results as ``heap_apply`` (the batch
    is the linearization order; ``OP_NOP`` lanes are inert), but expressed
    as a ``lax.scan`` over the batch with fixed-trip sift loops, so it can
    be inlined into a jitted ``lax.while_loop`` under ``shard_map`` — the
    mesh analogue of ``ring_slots.enq_planes``/``deq_planes``.  All inputs
    may be traced (``size`` and the op vectors included); only the shapes
    are static.  Returns ``(keys, vals, new_size, out_keys, out_vals,
    ok)`` with ``out_*[i]`` carrying delete-min results.

    ``rider`` is an optional second (cap,) value plane that moves in
    lockstep with ``vals`` through every sift — the span layer's
    birth-stamp plane (DESIGN.md § 7.6).  ``oprider`` supplies the rider
    value installed by INSERT lanes (scalar or (B,); ignored on pops).
    With a rider the return tuple grows to ``(..., ok, rider, out_rider)``;
    without one the op sequence — and therefore the result — is exactly
    the single-plane version's."""
    cap = 1 << cap_log2
    d = 1 << arity_log2
    max_depth = -(-cap_log2 // arity_log2) + 1
    size = jnp.asarray(size, jnp.int32)
    ops = ops.astype(jnp.int32)
    # generalize over a tuple of value planes: the heap's ordering lives
    # entirely in `keys`; every value plane just mirrors the moves
    if rider is None:
        vplanes = (vals,)
        opvals_t = (opvals.astype(jnp.int32),)
    else:
        opr = jnp.zeros_like(ops) if oprider is None else jnp.broadcast_to(
            jnp.asarray(oprider, jnp.int32), ops.shape)
        vplanes = (vals, rider)
        opvals_t = (opvals.astype(jnp.int32), opr)

    def one(carry, opkv):
        keys, vs, size = carry
        op, key, ovals = opkv

        # ---- INSERT: hole starts at `size`, parents move down ----------
        do_ins = (op == OP_INSERT) & (size < cap)

        def up(_, c):
            keys, vs, j, moving = c
            p = jnp.where(j > 0, (j - 1) >> arity_log2, 0)
            pk = keys[p]
            cond = moving & (j > 0) & (pk > key)
            jc = jnp.where(cond, j, cap)        # failed lanes drop
            keys = keys.at[jc].set(pk, mode="drop")
            vs = tuple(v.at[jc].set(v[p], mode="drop") for v in vs)
            return (keys, vs, jnp.where(cond, p, j), moving & cond)

        j0 = jnp.where(do_ins, size, 0)
        keys, vs, jf, _ = jax.lax.fori_loop(
            0, max_depth, up, (keys, vs, j0, do_ins))
        ins_at = jnp.where(do_ins, jf, cap)
        keys = keys.at[ins_at].set(key, mode="drop")
        vs = tuple(v.at[ins_at].set(ov, mode="drop")
                   for v, ov in zip(vs, ovals))

        # ---- DELETE-MIN: root out, last node sifts down into the hole --
        do_pop = (op == OP_DELMIN) & (size > 0)
        outk = jnp.where(do_pop, keys[0], KEY_INF)
        outs = tuple(jnp.where(do_pop, v[0], -1) for v in vs)
        nsize = jnp.where(do_pop, size - 1, size)
        lpos = jnp.where(do_pop & (size > 0), size - 1, 0)
        lk = keys[lpos]
        lvs = tuple(v[lpos] for v in vs)

        def down(_, c):
            keys, vs, j, moving = c
            base = (j << arity_log2) + 1

            def child(cc, acc):
                bk, bj = acc
                cj = base + cc
                in_r = cj < nsize
                ck = jnp.where(in_r, keys[jnp.where(in_r, cj, 0)], KEY_INF)
                better = ck < bk
                return (jnp.where(better, ck, bk), jnp.where(better, cj, bj))

            bk, bj = jax.lax.fori_loop(
                0, d, child, (jnp.int32(KEY_INF), jnp.int32(-1)))
            cond = moving & (bj >= 0) & (bk < lk)
            jc = jnp.where(cond, j, cap)
            bsrc = jnp.where(cond, bj, 0)
            keys = keys.at[jc].set(bk, mode="drop")
            vs = tuple(v.at[jc].set(v[bsrc], mode="drop") for v in vs)
            return (keys, vs, jnp.where(cond, bj, j), moving & cond)

        moving0 = do_pop & (nsize > 0)
        keys, vs, jf2, _ = jax.lax.fori_loop(
            0, max_depth, down, (keys, vs, jnp.int32(0), moving0))
        place = jnp.where(moving0, jf2, cap)
        keys = keys.at[place].set(lk, mode="drop")
        vs = tuple(v.at[place].set(lv, mode="drop")
                   for v, lv in zip(vs, lvs))
        # scrub the vacated tail slot so stale keys can't resurface
        scrub = jnp.where(do_pop, lpos, cap)
        keys = keys.at[scrub].set(KEY_INF, mode="drop")
        vs = tuple(v.at[scrub].set(-1, mode="drop") for v in vs)

        ok = (do_ins | do_pop).astype(jnp.int32)
        new_size = jnp.where(do_ins, size + 1, nsize)
        return (keys, vs, new_size), (outk, outs, ok)

    (keys, vplanes, size), (outk, outvs, ok) = jax.lax.scan(
        one, (keys, vplanes, size),
        (ops, opkeys.astype(jnp.int32), opvals_t))
    if rider is None:
        return keys, vplanes[0], size, outk, outvs[0], ok.astype(bool)
    return (keys, vplanes[0], size, outk, outvs[0], ok.astype(bool),
            vplanes[1], outvs[1])


def heap_pop_count(keys, vals, size, count, *, batch: int, cap_log2: int,
                   arity_log2: int = 2, rider=None):
    """Pop the ``count`` smallest (key, val) pairs through a fixed-width
    masked wave: lanes ``>= count`` are ``OP_NOP`` padding, so ``count``
    may be traced (the mesh claim schedule's per-shard share).  Returns
    the ``heap_planes`` tuple; ``ok[i] = i < min(count, size)``.  An
    optional ``rider`` plane passes through (the popped rider values land
    in the appended ``out_rider``)."""
    lane = jnp.arange(batch, dtype=jnp.int32)
    ops = jnp.where(lane < jnp.asarray(count, jnp.int32), OP_DELMIN, OP_NOP)
    pad = jnp.full((batch,), KEY_INF, jnp.int32)
    return heap_planes(keys, vals, size, ops, pad, pad,
                       cap_log2=cap_log2, arity_log2=arity_log2, rider=rider)


def heap_insert_masked(keys, vals, size, inkeys, invals, mask, *,
                       cap_log2: int, arity_log2: int = 2, rider=None,
                       oprider=None):
    """Install the masked subset of a fixed-width (key, val) wave in lane
    order (masked-out lanes are ``OP_NOP``) — the publish wave of the
    priority mesh rounds, where each shard keeps only its sprayed share of
    the gathered children.  Returns the ``heap_planes`` tuple.  An
    optional ``rider`` plane installs ``oprider`` (scalar or (B,)) on
    applied lanes — the span layer's birth stamps."""
    ops = jnp.where(mask, OP_INSERT, OP_NOP)
    return heap_planes(keys, vals, size, ops, inkeys, invals,
                       cap_log2=cap_log2, arity_log2=arity_log2,
                       rider=rider, oprider=oprider)
