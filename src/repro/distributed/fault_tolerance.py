"""Fault tolerance: checkpoint/restart, straggler mitigation, elasticity.

Designed for 1000+ node fleets where *something* is always failing:

* **Restart manager** — wraps the train loop: periodic async checkpoints
  (atomic commit via `checkpoint.manager`), exception-driven restart from
  the latest committed step, bounded retry budget.  Restore-with-remesh
  means a restart may come back on a *different* device count (elastic).
* **Straggler detection** — per-step heartbeat durations; a pod whose step
  time exceeds ``threshold × median`` of its trailing window is flagged.
  The mitigation hook re-plans the data sharding so the slow pod receives a
  smaller micro-batch share (documented plan object — the actual reshard is
  a new jit with the updated batch pspecs).
* **Elastic re-mesh plan** — given survivors, picks the largest (data,
  model) grid consistent with the TP degree and emits the parameter
  re-sharding plan executed by `CheckpointManager.restore(shardings=...)`.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax

from ..checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class StragglerReport:
    step: int
    pod: int
    step_time: float
    median_time: float
    ratio: float


class StragglerDetector:
    """Deadline-based slow-pod detection over per-pod heartbeats."""

    def __init__(self, n_pods: int, *, window: int = 16,
                 threshold: float = 1.5) -> None:
        self.n_pods = n_pods
        self.window = window
        self.threshold = threshold
        self._hist: List[Deque[float]] = [deque(maxlen=window)
                                          for _ in range(n_pods)]
        self.reports: List[StragglerReport] = []

    def heartbeat(self, step: int, pod: int, step_time: float) -> Optional[StragglerReport]:
        self._hist[pod].append(step_time)
        times = sorted(t for h in self._hist for t in h)
        if len(times) < self.n_pods * 2:
            return None
        med = times[len(times) // 2]
        if med > 0 and step_time > self.threshold * med:
            rep = StragglerReport(step, pod, step_time, med,
                                  step_time / med)
            self.reports.append(rep)
            return rep
        return None

    def mitigation_plan(self, rep: StragglerReport) -> Dict:
        """Shift batch share away from the slow pod proportionally to its
        slowdown (bounded at 50%)."""
        share = max(0.5, 1.0 / rep.ratio)
        shares = [1.0] * self.n_pods
        shares[rep.pod] = share
        total = sum(shares)
        return {"kind": "rebalance_batch",
                "pod_shares": [s / total for s in shares],
                "reason": dataclasses.asdict(rep)}


def elastic_mesh_plan(n_devices: int, *, tp: int = 16) -> Dict:
    """Largest (data, model) grid for the surviving device count; TP degree
    is kept (params resharded only along data) unless fewer than tp devices
    survive."""
    tp = min(tp, n_devices)
    while n_devices % tp:
        tp //= 2
    return {"data": n_devices // tp, "model": tp}


class RestartManager:
    """Run a step function with periodic checkpoints and crash-restart.

    ``step_fn(state, step_idx) -> state`` may raise; on failure the manager
    restores the latest committed checkpoint and resumes, up to
    ``max_restarts``.  Simulated-fault injection (`inject_fault_at`) lets the
    test suite exercise the full restart path deterministically.
    """

    def __init__(self, ckpt: CheckpointManager, *, save_every: int = 10,
                 max_restarts: int = 3) -> None:
        self.ckpt = ckpt
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, state, step_fn: Callable, *, num_steps: int,
            start_step: int = 0,
            inject_fault_at: Optional[int] = None):
        step = start_step
        faults_left = 1 if inject_fault_at is not None else 0
        while step < num_steps:
            try:
                if faults_left and step == inject_fault_at:
                    faults_left = 0
                    raise RuntimeError("injected node failure")
                state = step_fn(state, step)
                step += 1
                if step % self.save_every == 0 or step == num_steps:
                    self.ckpt.save(step, state)
            except Exception:  # noqa: BLE001 — restart path
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = start_step
                    continue
                step, state = self.ckpt.restore(state, latest)
        self.ckpt.wait()
        return step, state
