"""The LM: one functional transformer covering the whole assigned pool.

Families: dense (GQA / SWA / alternating local-global / soft-capping), MoE
(fine-grained + shared experts), SSM (Mamba2 SSD), hybrid (Mamba2 + shared
attention block), VLM (periodic cross-attention, stubbed patch frontend),
audio encoder (stubbed frame frontend).

Execution paths:

* ``forward`` / ``loss_fn`` — training & encoder inference: **one flat
  lax.scan over layers** with per-layer scanned flag arrays (window /
  is_cross / use_shared), keeping the HLO a single layer body regardless of
  depth — critical for the 80-compile dry-run matrix on one CPU core.
* ``prefill`` — scan that additionally emits per-layer KV (uniform cache).
* ``decode_step`` — python-unrolled layers with per-layer ring caches sized
  to each layer's attention window (local layers keep O(window) KV at 500k
  context; SSM layers keep O(1) state) — the sub-quadratic decode paths of
  DESIGN.md § 5.

Params are dicts; ``param_specs`` mirrors the tree with PartitionSpec
(TP over "model", optional FSDP over "data", replicated across "pod").
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..jaxcompat import current_mesh
from .layers import (attention, attn_params, attn_specs, mlp, mlp_params,
                     mlp_specs, rms_norm, softcap, _dense)
from .moe import moe_forward, moe_params, moe_specs
from .ssm import ssm_forward, ssm_params, ssm_specs

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------


def _layer_params(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Params = {"ln1": jnp.zeros((d,), jnp.bfloat16)}
    if cfg.family in ("ssm", "hybrid"):
        p.update(ssm_params(ks[0], cfg))
        return p
    p.update(attn_params(ks[0], cfg))
    p["ln2"] = jnp.zeros((d,), jnp.bfloat16)
    if cfg.family == "moe":
        p.update(moe_params(ks[1], cfg))
    else:
        p.update(mlp_params(ks[1], d, cfg.d_ff))
    if cfg.family == "vlm":
        p.update(attn_params(ks[2], cfg, cross=True))
        p["cln"] = jnp.zeros((d,), jnp.bfloat16)
    return p


def _layer_specs(cfg: ArchConfig, f) -> Params:
    sp: Params = {"ln1": P(None)}
    if cfg.family in ("ssm", "hybrid"):
        sp.update(ssm_specs(cfg, f))
        return sp
    sp.update(attn_specs(cfg))
    sp["ln2"] = P(None)
    if cfg.family == "moe":
        sp.update(moe_specs(cfg, f))
    else:
        sp.update(mlp_specs(f))
    if cfg.family == "vlm":
        sp.update(attn_specs(cfg, cross=True, fsdp_axis=f))
        sp["cln"] = P(None)
    # FSDP-shard the attention/mlp matrices' non-model axis
    if f is not None:
        for k in ("wq", "wk", "wv", "cwq", "cwk", "cwv", "w_gate", "w_up"):
            if k in sp:
                sp[k] = P(f, "model")
        for k in ("wo", "cwo", "w_down"):
            if k in sp:
                sp[k] = P("model", f)
    return sp


def _shared_block_params(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    p = {"ln1": jnp.zeros((d,), jnp.bfloat16),
         "ln2": jnp.zeros((d,), jnp.bfloat16)}
    p.update(attn_params(ks[0], cfg))
    p.update(mlp_params(ks[1], d, cfg.d_ff))
    return p


def init_params(cfg: ArchConfig, key=None) -> Params:
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, cfg.n_layers + 4)
    layers = [_layer_params(ks[i], cfg) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    params: Params = {
        "embed": _dense(ks[-1], (cfg.vocab, cfg.d_model)),
        "lm_head": _dense(ks[-2], (cfg.d_model, cfg.vocab)),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.bfloat16),
        "layers": stacked,
    }
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        params["shared_attn"] = _shared_block_params(ks[-3], cfg)
    return params


def param_specs(cfg: ArchConfig, *, fsdp: Optional[bool] = None) -> Params:
    f = "data" if (cfg.fsdp if fsdp is None else fsdp) else None
    lsp = _layer_specs(cfg, f)
    specs: Params = {
        "embed": P("model", f),        # vocab-parallel embedding
        "lm_head": P(f, "model"),
        "final_norm": P(None),
        "layers": jax.tree.map(lambda s: P(None, *s), lsp,
                               is_leaf=lambda s: isinstance(s, P)),
    }
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        ssp = {"ln1": P(None), "ln2": P(None)}
        ssp.update(attn_specs(cfg, fsdp_axis=f))
        ssp.update(mlp_specs(f))
        specs["shared_attn"] = ssp
    return specs


def layer_flags(cfg: ArchConfig) -> Dict[str, jax.Array]:
    """Per-layer scanned flag arrays (static content, dynamic inside scan)."""
    L = cfg.n_layers
    window = jnp.array([cfg.window_for_layer(i) for i in range(L)], jnp.int32)
    is_cross = jnp.array(
        [1 if (cfg.cross_attn_every and (i + 1) % cfg.cross_attn_every == 0)
         else 0 for i in range(L)], jnp.int32)
    use_shared = jnp.array(
        [1 if (cfg.shared_attn_every and (i + 1) % cfg.shared_attn_every == 0)
         else 0 for i in range(L)], jnp.int32)
    return {"window": window, "is_cross": is_cross, "use_shared": use_shared}


# ---------------------------------------------------------------------------
# flat-scan forward (train / encode)
# ---------------------------------------------------------------------------


def _dense_layer(p, x, cfg, positions, window, is_cross, img):
    if cfg.family == "vlm":
        def self_branch(args):
            p_, h_ = args
            out, _ = attention(p_, rms_norm(h_, p_["ln1"]), cfg,
                               positions=positions, window=window)
            return out

        def cross_branch(args):
            p_, h_ = args
            out, _ = attention(p_, rms_norm(h_, p_["cln"]), cfg,
                               positions=positions, window=window,
                               kv_override=img, cross=True)
            return out

        a = jax.lax.cond(is_cross > 0, cross_branch, self_branch, (p, x))
    else:
        a, _ = attention(p, rms_norm(x, p["ln1"]), cfg,
                         positions=positions, window=window)
    h = x + a
    inner = rms_norm(h, p["ln2"])
    if cfg.family == "moe":
        return h + moe_forward(p, inner, cfg)
    return h + mlp(p, inner)


def _ssm_layer(p, x, cfg, shared, positions, use_shared):
    out, _ = ssm_forward(p, rms_norm(x, p["ln1"]), cfg)
    h = x + out
    if cfg.family == "hybrid" and shared is not None:
        def with_attn(h_):
            a, _ = attention(shared, rms_norm(h_, shared["ln1"]), cfg,
                             positions=positions, window=0)
            g = h_ + a
            return g + mlp(shared, rms_norm(g, shared["ln2"]))

        h = jax.lax.cond(use_shared > 0, with_attn, lambda h_: h_, h)
    return h


def _seq_shard(x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Megatron sequence parallelism: between layers the residual stream is
    sharded over "model" along the sequence axis (batch stays on the DP
    axes — leaving it unconstrained lets GSPMD un-shard the batch at the
    vocabulary projection, which costs ~15 GiB/device at yi-34b scale), so
    the per-layer saved activations of the remat'd scan shrink by the TP
    degree.  GSPMD derives the all-gather/reduce-scatter pairs around
    attention/MLP automatically.  Only applied when the dry-run sets
    cfg.seq_parallel (mesh context present; seq divisible)."""
    if not cfg.seq_parallel or x.ndim != 3:
        return x
    mesh = current_mesh()
    if mesh is None or "model" not in (mesh.axis_names or ()):
        return x
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return jax.lax.with_sharding_constraint(x, P(dp, "model", None))


def forward(params: Params, tokens: jax.Array, cfg: ArchConfig, *,
            img: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None) -> jax.Array:
    """tokens (B, S) int32 — or, for the audio frontend, ``frames``
    (B, S, d) pre-embedded.  Returns logits (B, S, V)."""
    if cfg.audio_frontend:
        x = frames.astype(jnp.bfloat16)
        b, s, _ = x.shape
    else:
        x = params["embed"][tokens] * jnp.sqrt(float(cfg.d_model)).astype(jnp.bfloat16)
        b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    flags = layer_flags(cfg)
    shared = params.get("shared_attn")
    x = _seq_shard(x, cfg)

    def body(h, xs):
        lp, fl = xs
        if cfg.family in ("ssm", "hybrid"):
            h = _ssm_layer(lp, h, cfg, shared, positions, fl["use_shared"])
        else:
            h = _dense_layer(lp, h, cfg, positions, fl["window"],
                             fl["is_cross"], img)
        return _seq_shard(h, cfg), None

    layer_fn = body
    if cfg.remat:
        # full remat: only the layer-boundary residual stream is saved —
        # the minimum for a scanned stack; everything else is recomputed.
        layer_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(layer_fn, x, (params["layers"], flags))
    x = rms_norm(x, params["final_norm"])
    logits = x @ params["lm_head"]
    logits = _seq_shard(logits, cfg)
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


def loss_fn(params: Params, batch: Dict[str, jax.Array],
            cfg: ArchConfig) -> jax.Array:
    logits = forward(params, batch.get("tokens"), cfg,
                     img=batch.get("img"), frames=batch.get("frames"))
    logits = _seq_shard(logits, cfg)
    labels = batch["labels"]
    # cross-entropy without a full log_softmax materialization:
    # nll = logsumexp(logits) - logits[label]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    mask = (labels >= 0).astype(jnp.float32)
    mask = _seq_shard(mask[..., None], cfg)[..., 0] if cfg.seq_parallel else mask
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# prefill (emit uniform KV caches) and decode (per-layer ring caches)
# ---------------------------------------------------------------------------


def prefill(params: Params, tokens: jax.Array, cfg: ArchConfig, *,
            img: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None):
    """Forward over the prompt, returning (last-token logits, cache).
    Attention layers emit (K, V) stacked (L, B, S, kv, hd); SSM layers emit
    their final states."""
    if cfg.audio_frontend:
        x = frames.astype(jnp.bfloat16)
        b, s, _ = x.shape
    else:
        x = params["embed"][tokens] * jnp.sqrt(float(cfg.d_model)).astype(jnp.bfloat16)
        b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    flags = layer_flags(cfg)
    shared = params.get("shared_attn")
    from .layers import rope  # local import to avoid cycle noise

    def body(h, xs):
        lp, fl = xs
        if cfg.family in ("ssm", "hybrid"):
            inner = rms_norm(h, lp["ln1"])
            out, st = ssm_forward(lp, inner, cfg)
            h = h + out
            aux = {"ssm": st[1], "conv": st[0]}
            if cfg.family == "hybrid" and shared is not None:
                def with_attn(h_):
                    a, _ = attention(shared, rms_norm(h_, shared["ln1"]), cfg,
                                     positions=positions, window=0)
                    g = h_ + a
                    return g + mlp(shared, rms_norm(g, shared["ln2"]))
                h = jax.lax.cond(fl["use_shared"] > 0, with_attn,
                                 lambda h_: h_, h)
                # shared-attn KV recomputed at decode prefill boundary; emit
                # the block input so decode can rebuild (uniform aux shape)
            return h, aux
        # attention families: emit roped K / V
        inner = rms_norm(h, lp["ln1"])
        kv = cfg.n_kv_heads
        k = (inner @ lp["wk"]).reshape(b, s, kv, cfg.hd)
        v = (inner @ lp["wv"]).reshape(b, s, kv, cfg.hd)
        k = rope(k, positions, cfg.rope_theta)
        h = _dense_layer(lp, h, cfg, positions, fl["window"],
                         fl["is_cross"], img)
        return h, {"k": k, "v": v}

    x, caches = jax.lax.scan(body, x, (params["layers"], flags))
    x = rms_norm(x[:, -1:, :], params["final_norm"])
    logits = softcap((x @ params["lm_head"]).astype(jnp.float32),
                     cfg.final_softcap)
    return logits, caches


def init_decode_cache(cfg: ArchConfig, batch: int, max_seq: int,
                      dtype=jnp.bfloat16) -> List:
    """Per-layer ring caches: local layers O(window), global layers O(S),
    SSM layers O(1) state; the hybrid's shared block caches O(S) per
    invocation."""
    cache: List = []
    for i in range(cfg.n_layers):
        if cfg.family in ("ssm", "hybrid"):
            entry = {
                "conv": jnp.zeros((batch, cfg.ssm_conv - 1,
                                   cfg.d_inner + 2 * cfg.ssm_state), dtype),
                "ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim,
                                  cfg.ssm_state), jnp.float32),
            }
            if (cfg.family == "hybrid" and cfg.shared_attn_every
                    and (i + 1) % cfg.shared_attn_every == 0):
                entry["k"] = jnp.zeros((batch, max_seq, cfg.n_kv_heads,
                                        cfg.hd), dtype)
                entry["v"] = jnp.zeros((batch, max_seq, cfg.n_kv_heads,
                                        cfg.hd), dtype)
            cache.append(entry)
        else:
            w = cfg.window_for_layer(i)
            sc = min(w, max_seq) if w else max_seq
            cache.append({
                "k": jnp.zeros((batch, sc, cfg.n_kv_heads, cfg.hd), dtype),
                "v": jnp.zeros((batch, sc, cfg.n_kv_heads, cfg.hd), dtype),
            })
    return cache


def decode_step(params: Params, cache: List, token: jax.Array,
                cur: jax.Array, cfg: ArchConfig, *,
                img: Optional[jax.Array] = None):
    """One decode step.  token (B, 1) int32; cur () int32 current length.
    Returns (logits (B, 1, V), new_cache)."""
    x = params["embed"][token] * jnp.sqrt(float(cfg.d_model)).astype(jnp.bfloat16)
    positions = cur[None].astype(jnp.int32)
    is_cross = [bool(cfg.cross_attn_every and (i + 1) % cfg.cross_attn_every == 0)
                for i in range(cfg.n_layers)]  # static (unrolled decode)
    shared = params.get("shared_attn")
    new_cache: List = []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        c = cache[i]
        if cfg.family in ("ssm", "hybrid"):
            inner = rms_norm(x, lp["ln1"])
            out, st = ssm_forward(lp, inner, cfg, state=(c["conv"], c["ssm"]))
            x = x + out
            nc = {"conv": st[0], "ssm": st[1]}
            if "k" in c:  # hybrid shared-attn invocation
                a, kvc = attention(shared, rms_norm(x, shared["ln1"]), cfg,
                                   positions=positions, window=0,
                                   cache=(c["k"], c["v"], cur))
                g = x + a
                x = g + mlp(shared, rms_norm(g, shared["ln2"]))
                nc["k"], nc["v"] = kvc[0], kvc[1]
            new_cache.append(nc)
            continue
        w = int(cfg.window_for_layer(i))
        if cfg.family == "vlm" and is_cross[i]:
            a, _ = attention(lp, rms_norm(x, lp["cln"]), cfg,
                             positions=positions, window=w,
                             kv_override=img, cross=True)
            x = x + a
            new_cache.append(c)
        else:
            a, kvc = attention(lp, rms_norm(x, lp["ln1"]), cfg,
                               positions=positions, window=w,
                               cache=(c["k"], c["v"], cur))
            x = x + a
            new_cache.append({"k": kvc[0], "v": kvc[1]})
        inner = rms_norm(x, lp["ln2"])
        if cfg.family == "moe":
            x = x + moe_forward(lp, inner, cfg)
        else:
            x = x + mlp(lp, inner)
    x = rms_norm(x, params["final_norm"])
    logits = softcap((x @ params["lm_head"]).astype(jnp.float32),
                     cfg.final_softcap)
    return logits, new_cache
