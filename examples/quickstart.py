"""Quickstart: the paper's queues in 60 seconds.

Runs each queue (G-LFQ, G-WFQ, G-WFQ-YMC, SFQ) through a concurrent
producer/consumer workload under the adversarial scheduler, checks FIFO
conformance (§ IV-b) and linearizability (§ IV-a), and prints the paper's
per-op metrics.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import QUEUE_CLASSES, check_linearizable, run_producer_consumer

for name, cls in QUEUE_CLASSES.items():
    kw = dict(patience=2, help_delay=4) if name.startswith("gwfq") else {}
    q = cls(capacity=16, num_threads=8, **kw)
    sched, sink, rep = run_producer_consumer(
        q, producers=4, consumers=4, ops_per_producer=20,
        policy="gang", seed=0)
    lin = check_linearizable(sched.history)
    m = sched.metrics()
    print(f"{name:10s} fifo={'PASS' if rep.ok else 'FAIL'} "
          f"linearizable={'PASS' if lin.ok else 'FAIL'}  "
          f"steps/op={m['steps_per_op']:.1f} "
          f"stall-steps/op={m['stall_steps_per_op']:.1f} "
          f"atomics/op={m['atomics_per_op']:.2f}")
