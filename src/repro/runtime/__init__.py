"""repro.runtime — the queue-backed task-parallel execution engine
(DESIGN.md § 4).

Two faces over the same queue core:

* **sim face** — ``TaskFabric`` (sharded MPMC rings, wave-affinity
  placement, work stealing, priority lanes) driven by ``TaskRuntime``
  persistent workers under the adversarial interleaving scheduler;
* **JAX face** — ``RoundRunner`` / ``PriorityRoundRunner`` (deterministic
  rounds over the Pallas ring/heap, running on the fused device-resident
  megaround engine ``fusedrounds.RingEngine`` by default with host sync
  only at quiescence), ``MeshRoundRunner`` (the FIFO megaround under
  shard_map — replicated or per-shard rings, DESIGN.md § 2.3), and
  ``PriorityMeshRoundRunner`` (the sharded G-PQ megaround — strict or
  k-relaxed pop order, DESIGN.md § 6).

All fused engines are configurations of ``enginecore.EngineCore``
(DESIGN.md § 4.8): one jitted while_loop builder, one plane registry, one
host driver.  ``ENGINE_REGISTRY`` enumerates the runner matrix; the
``Fused*`` names are deprecated shims kept for one release.
"""

from .enginecore import (ENGINE_REGISTRY, EngineCore, EngineEntry,
                         PlaneGroup, PlaneRegistry, register_engine)
from .executor import Arrival, ExecutorConfig, Handler, TaskRuntime
from .fusedrounds import (FusedPriorityRounds, FusedRounds, HeapEngine,
                          RingEngine)
from .meshrounds import (FusedMeshRounds, FusedPriorityMeshRounds,
                         MeshHeapEngine, MeshRingEngine, MeshRoundRunner,
                         PriorityMeshRoundRunner, ShardedMeshRingEngine)
from .rounds import (HeapState, PriorityRoundRunner, RingState, RoundRunner,
                     heap_init, mesh_task_round, ring_init)
from .taskpool import (FabricMetrics, HostTaskPool, PriorityFabric,
                       TaskFabric, TaskRecord, TaskSpec)

__all__ = [
    "Arrival", "ENGINE_REGISTRY", "EngineCore", "EngineEntry",
    "ExecutorConfig", "FabricMetrics", "FusedMeshRounds",
    "FusedPriorityMeshRounds", "FusedPriorityRounds", "FusedRounds",
    "Handler", "HeapEngine", "HostTaskPool", "HeapState", "MeshHeapEngine",
    "MeshRingEngine", "MeshRoundRunner", "PlaneGroup", "PlaneRegistry",
    "PriorityFabric", "PriorityMeshRoundRunner", "PriorityRoundRunner",
    "RingEngine", "RingState", "RoundRunner", "ShardedMeshRingEngine",
    "TaskFabric", "TaskRecord", "TaskSpec", "TaskRuntime", "heap_init",
    "mesh_task_round", "register_engine", "ring_init",
]
