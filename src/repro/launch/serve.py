"""Serving driver: continuous-batching engine over the queue substrate.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --requests 8 --prompt-len 6 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from ..configs import get_config
from ..models import init_params
from ..serving.engine import EngineConfig, Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--pages", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg)
    eng = ServingEngine(cfg, params, EngineConfig(
        max_slots=args.slots, num_pages=args.pages, page_size=32,
        max_seq=max(64, args.prompt_len + args.max_new + 1)))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        ok = eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))
        print(f"submit {rid}: {'ok' if ok else 'ring full'}")
    metrics = eng.run(max_ticks=2000)
    dt = time.time() - t0
    print(f"\ncompleted {metrics['completed']}/{args.requests} requests, "
          f"{metrics['tokens_out']} tokens in {dt:.1f}s "
          f"({metrics['tokens_out']/dt:.1f} tok/s)")
    print(f"decode steps: {metrics['decode_steps']}  "
          f"page stalls (ring RETRY path): {metrics['page_stalls']}")


if __name__ == "__main__":
    main()
