"""Priority mesh rounds + delta-stepping SSSP invariants (DESIGN.md § 6):

* ``heap_planes`` (the pure-jnp masked batched sift) is bit-identical to
  the ``heap_apply`` Pallas kernel, including partial (``OP_NOP``-padded)
  waves via ``heap_pop_count`` / ``heap_insert_masked``;
* ``PriorityMeshRoundRunner`` fused-vs-legacy bit-identity (acc, heap
  planes, stats) in both orderings, on the spawn-tree workload and on
  SSSP;
* SSSP distances are exact vs the Dijkstra oracle (1 shard in-process;
  2 and 4 shards via the bench_sssp --smoke forced-device subprocess);
* overflow, seed overflow, and ``max_rounds`` truncation raise
  ``RuntimeError`` from both engines on the priority plane;
* recorded pop histories are priority-linearizable at the declared
  relaxation: ``k = 0`` at 1 shard (both orderings — one heap pops in
  exact min-key order), ``mesh_relaxation_bound`` at 2 shards (inside
  the smoke subprocess);
* ``priority_claim_schedule`` follows the hint-ordered even-split
  contract.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.jaxcompat import make_mesh  # noqa: E402
from repro.kernels.heap_batch import (  # noqa: E402
    KEY_INF, OP_DELMIN, OP_INSERT, OP_NOP, heap_apply, heap_insert_masked,
    heap_planes, heap_pop_count)
from repro.runtime import PriorityMeshRoundRunner  # noqa: E402
from repro.sched import (check_p_linearizable, mesh_relaxation_bound,  # noqa: E402
                         mesh_trace_history)

STAT_KEYS = ("rounds", "processed", "spawned", "max_occupancy", "drained")


def _mesh1():
    return make_mesh((1,), ("data",))


# -- heap_planes: the pure-jnp twin of the Pallas kernel ----------------------


def test_heap_planes_bit_identical_to_kernel():
    rng = np.random.default_rng(0)
    cap_log2, arity_log2 = 5, 2
    cap = 1 << cap_log2
    for trial in range(8):
        k1 = jnp.full((cap,), KEY_INF, jnp.int32)
        v1 = jnp.full((cap,), -1, jnp.int32)
        s1 = jnp.int32(0)
        k2, v2, s2 = k1, v1, s1
        for _ in range(5):
            b = int(rng.integers(1, 12))
            ops = jnp.asarray(
                rng.choice([OP_INSERT, OP_DELMIN, OP_NOP], b).astype(np.int32))
            opk = jnp.asarray(rng.integers(0, 1000, b).astype(np.int32))
            opv = jnp.asarray(rng.integers(0, 1000, b).astype(np.int32))
            k1, v1, s1, outk1, outv1, ok1 = heap_apply(
                k1, v1, s1, ops, opk, opv, cap_log2=cap_log2,
                arity_log2=arity_log2)
            k2, v2, s2, outk2, outv2, ok2 = heap_planes(
                k2, v2, s2, ops, opk, opv, cap_log2=cap_log2,
                arity_log2=arity_log2)
            np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
            np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
            assert int(s1) == int(s2)
            np.testing.assert_array_equal(np.asarray(outk1), np.asarray(outk2))
            np.testing.assert_array_equal(np.asarray(outv1), np.asarray(outv2))
            np.testing.assert_array_equal(np.asarray(ok1), np.asarray(ok2))


def test_heap_partial_waves_drain_sorted():
    """Masked pop/insert wrappers: traced-count pops return the smallest
    keys in order; NOP lanes are inert."""
    rng = np.random.default_rng(1)
    cap_log2 = 6
    cap = 1 << cap_log2
    keys = jnp.full((cap,), KEY_INF, jnp.int32)
    vals = jnp.full((cap,), -1, jnp.int32)
    size = jnp.int32(0)
    ik = rng.permutation(40).astype(np.int32)
    mask = np.ones(40, bool)
    mask[[3, 17]] = False                      # masked-out lanes stay out
    keys, vals, size, _, _, ok = heap_insert_masked(
        keys, vals, size, jnp.asarray(ik), jnp.asarray(ik),
        jnp.asarray(mask), cap_log2=cap_log2)
    assert int(size) == 38
    np.testing.assert_array_equal(np.asarray(ok), mask)
    keys, vals, size, outk, outv, ok = heap_pop_count(
        keys, vals, size, 38, batch=48, cap_log2=cap_log2)
    expect = sorted(ik[mask].tolist())
    assert np.asarray(outk)[:38].tolist() == expect
    assert np.asarray(outv)[:38].tolist() == expect
    assert int(size) == 0
    assert not bool(np.asarray(ok)[38:].any())


# -- priority mesh rounds: fused vs legacy parity -----------------------------


def _tree_step(limit=32):
    def step(acc, keys, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        ck = (cv * 7919) % 1000                # scrambled keys
        cm = (valid & (vals < limit))[:, None]
        return acc, ck, cv, cm
    return step


@pytest.mark.parametrize("relaxed", [True, False])
def test_priority_mesh_fused_matches_legacy_tree(relaxed):
    mesh = _mesh1()
    accs, finals, stats = [], [], []
    for fused in (True, False):
        r = PriorityMeshRoundRunner(_tree_step(), mesh=mesh, capacity_log2=8,
                                    batch=16, relaxed=relaxed, fused=fused,
                                    combine=lambda a: a.sum(0))
        acc, st = r.run([7919 % 1000], [1], acc=jnp.zeros(80, jnp.int32))
        accs.append(np.asarray(acc))
        finals.append(st)
        stats.append(r.stats)
    np.testing.assert_array_equal(accs[0], accs[1])
    for a, b in zip(finals[0][:2], finals[1][:2]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in STAT_KEYS:
        assert stats[0][k] == stats[1][k], k
    # the headline: host sync only at quiescence vs every round
    assert stats[0]["host_syncs"] == 1
    assert stats[1]["host_syncs"] == stats[1]["rounds"]
    # tasks 1..63 processed exactly once each
    assert accs[0][1:64].tolist() == [1] * 63


def test_priority_mesh_sync_every_heartbeat():
    mesh = _mesh1()
    r = PriorityMeshRoundRunner(_tree_step(), mesh=mesh, capacity_log2=8,
                                batch=16, sync_every=2,
                                combine=lambda a: a.sum(0))
    acc, _ = r.run([0], [1], acc=jnp.zeros(80, jnp.int32))
    full = PriorityMeshRoundRunner(_tree_step(), mesh=mesh, capacity_log2=8,
                                   batch=16, combine=lambda a: a.sum(0))
    acc2, _ = full.run([0], [1], acc=jnp.zeros(80, jnp.int32))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(acc2))
    assert r.stats["host_syncs"] > 1
    assert r.sync_log[-1]["occupancy"] == 0


# -- overflow / truncation on the priority plane ------------------------------


def _explode_step():
    def step(acc, keys, vals, valid):
        cv = jnp.broadcast_to(vals[:, None], (vals.shape[0], 4)) + 1
        cm = jnp.broadcast_to(valid[:, None], cv.shape)
        return acc, cv.astype(jnp.int32), cv.astype(jnp.int32), cm
    return step


@pytest.mark.parametrize("relaxed", [True, False])
@pytest.mark.parametrize("fused", [True, False])
def test_priority_mesh_overflow_raises(relaxed, fused):
    r = PriorityMeshRoundRunner(_explode_step(), mesh=_mesh1(),
                                capacity_log2=4, batch=8, relaxed=relaxed,
                                fused=fused)
    with pytest.raises(RuntimeError, match="mesh heap overflow"):
        r.run(np.arange(8), np.arange(8), acc=jnp.int32(0), max_rounds=100)


@pytest.mark.parametrize("fused", [True, False])
def test_priority_mesh_seed_overflow_raises(fused):
    r = PriorityMeshRoundRunner(_tree_step(), mesh=_mesh1(), capacity_log2=4,
                                batch=8, fused=fused)
    with pytest.raises(RuntimeError, match="mesh heap overflow"):
        r.run(np.arange(64), np.arange(64), acc=jnp.zeros(80, jnp.int32))


def _immortal_step():
    def step(acc, keys, vals, valid):
        return (acc, keys[:, None], vals[:, None],
                valid[:, None])               # every task respawns
    return step


@pytest.mark.parametrize("relaxed", [True, False])
@pytest.mark.parametrize("fused", [True, False])
def test_priority_mesh_truncation_raises(relaxed, fused):
    r = PriorityMeshRoundRunner(_immortal_step(), mesh=_mesh1(),
                                capacity_log2=6, batch=8, relaxed=relaxed,
                                fused=fused)
    with pytest.raises(RuntimeError, match="not quiescent"):
        r.run([1, 2, 3], [1, 2, 3], acc=jnp.int32(0), max_rounds=5)
    assert r.stats["drained"] == 0
    assert r.stats["rounds"] == 5


def test_priority_mesh_batch_exceeds_capacity_raises():
    with pytest.raises(ValueError, match="exceeds per-shard heap capacity"):
        PriorityMeshRoundRunner(_tree_step(), mesh=_mesh1(), capacity_log2=4,
                                batch=64)


def test_trace_requires_legacy_engine():
    with pytest.raises(ValueError, match="fused=False"):
        PriorityMeshRoundRunner(_tree_step(), mesh=_mesh1(), trace=True)


# -- relaxation semantics -----------------------------------------------------


@pytest.mark.parametrize("relaxed", [True, False])
def test_single_shard_pop_history_is_exact(relaxed):
    """At 1 shard both orderings pop one heap in global min-key order:
    the recorded history must be priority-linearizable at k = 0."""
    r = PriorityMeshRoundRunner(_tree_step(limit=64), mesh=_mesh1(),
                                capacity_log2=8, batch=8, relaxed=relaxed,
                                fused=False, trace=True,
                                combine=lambda a: a.sum(0))
    seeds = [(7919 % 1000, 1)]
    acc, _ = r.run([k for k, _ in seeds], [v for _, v in seeds],
                   acc=jnp.zeros(200, jnp.int32))
    assert np.asarray(acc)[1:128].tolist() == [1] * 127
    hist = mesh_trace_history(r.trace, seeds)
    res = check_p_linearizable(hist, 0)
    assert res.ok, res.reason
    assert mesh_relaxation_bound(1, 8, r.stats["max_occupancy"]) == 0


def test_mesh_relaxation_bound_shape():
    assert mesh_relaxation_bound(1, 64, 10_000) == 0
    assert mesh_relaxation_bound(1, 64, 10_000, lazy=3) == 3
    # the chip-level envelope stacks under the mesh term
    assert (mesh_relaxation_bound(2, 64, 1000, rings=4, num_threads=8)
            == 2 * 3 * 8 + 1 * (500 + 64))
    # monotone in every mesh argument
    assert (mesh_relaxation_bound(4, 64, 1000)
            > mesh_relaxation_bound(2, 64, 1000))
    assert (mesh_relaxation_bound(2, 128, 1000)
            > mesh_relaxation_bound(2, 64, 1000))


def test_priority_claim_schedule_hint_ordered():
    from repro.core.distqueue import priority_claim_schedule
    # remainder goes to the lowest-key shards, shares clamp to local size
    counts = np.asarray(priority_claim_schedule(
        7, 3, 4, jnp.asarray([50, 10, 99]), jnp.asarray([5, 5, 5])))
    # hint order: shard1 (10), shard0 (50), shard2 (99); 7 = 2·3 + 1, so
    # the one remainder unit lands on shard1, the lowest-key shard
    assert counts.tolist() == [2, 3, 2]
    # an empty shard cannot donate; its share is clamped away
    counts = np.asarray(priority_claim_schedule(
        8, 2, 8, jnp.asarray([1, 2 ** 31 - 1]), jnp.asarray([8, 0])))
    assert counts.tolist() == [4, 0]
    # budget never exceeds batch per shard
    counts = np.asarray(priority_claim_schedule(
        100, 2, 8, jnp.asarray([1, 2]), jnp.asarray([50, 50])))
    assert counts.tolist() == [8, 8]


# -- SSSP: exact vs Dijkstra, fused/legacy bit-identity -----------------------


@pytest.mark.parametrize("relaxed", [True, False])
def test_sssp_single_shard_exact_and_bit_identical(relaxed):
    from repro.apps import bfs, sssp
    mesh = _mesh1()
    for g in (bfs.road_like(144), bfs.kron_like(200, avg_deg=6, seed=2)):
        w = sssp.with_weights(g, max_w=8, seed=1)
        ref = sssp.dijkstra_reference(g, w, 0)
        res = {}
        for fused in (True, False):
            dist, stats = sssp.sssp_mesh_rounds(g, w, 0, mesh=mesh, batch=32,
                                                relaxed=relaxed, fused=fused)
            np.testing.assert_array_equal(dist, ref)
            res[fused] = stats
        for k in STAT_KEYS:
            assert res[True][k] == res[False][k], (g.name, k)
        assert res[True]["host_syncs"] == 1


def test_sssp_delta_sweep_stays_exact():
    """Bucket width trades rounds for re-relaxations, never exactness."""
    from repro.apps import bfs, sssp
    mesh = _mesh1()
    g = bfs.road_like(100)
    w = sssp.with_weights(g, max_w=6, seed=3)
    ref = sssp.dijkstra_reference(g, w, 0)
    for delta in (1, 4, 16):
        dist, stats = sssp.sssp_mesh_rounds(g, w, 0, mesh=mesh, batch=16,
                                            delta=delta)
        np.testing.assert_array_equal(dist, ref)
        assert stats["drained"] == 1


def test_sssp_payload_packing_guard():
    from repro.apps import bfs, sssp
    g = bfs.road_like(144)
    w = np.full(g.m, 2 ** 20, np.int32)       # forces (d·n + v) past int32
    with pytest.raises(ValueError, match="packed"):
        sssp.sssp_mesh_rounds_runner(g, w, mesh=_mesh1())


@pytest.mark.parametrize("relaxed", [True, False])
def test_sssp_split_payload_parity(relaxed):
    """Two-plane (key, payload) mode: the aux rider carries the exact
    distance, everything stays exact and fused/legacy/compact
    bit-identical."""
    from repro.apps import bfs, sssp
    mesh = _mesh1()
    g = bfs.kron_like(150, avg_deg=5, seed=2)
    w = sssp.with_weights(g, max_w=8, seed=1)
    ref = sssp.dijkstra_reference(g, w, 0)
    res = {}
    for fused in (True, False):
        for compact in (None, True):
            dist, stats = sssp.sssp_mesh_rounds(
                g, w, 0, mesh=mesh, batch=32, relaxed=relaxed, fused=fused,
                compact=compact, split_payload=True)
            np.testing.assert_array_equal(dist, ref)
            res[(fused, compact)] = stats
    for k in STAT_KEYS:
        vals = {v[k] for v in res.values()}
        assert len(vals) == 1, (k, res)


def test_sssp_split_payload_lifts_packed_cap():
    """Cap-boundary regression: a graph whose (d·n + v) packing overflows
    int32 trips the packed ValueError but runs exact in split mode —
    only the raw distances must fit."""
    from repro.apps import bfs, sssp
    g = bfs.road_like(49)
    w = np.full(g.m, 10 ** 6, np.int32)       # max_d ≈ 48e6: packed ≫ 2^31
    assert (((g.n - 1) * 10 ** 6 + 10 ** 6) * g.n + g.n - 1) >= 2 ** 31
    assert ((g.n - 1) * 10 ** 6 + 10 ** 6) < 2 ** 31
    with pytest.raises(ValueError, match="packed"):
        sssp.sssp_mesh_rounds_runner(g, w, mesh=_mesh1())
    ref = sssp.dijkstra_reference(g, w, 0)
    dist, stats = sssp.sssp_mesh_rounds(g, w, 0, mesh=_mesh1(), batch=16,
                                        split_payload=True)
    np.testing.assert_array_equal(dist, ref)
    assert stats["drained"] == 1


# -- ≥2-shard acceptance (forced-device subprocess) ---------------------------


@pytest.mark.parametrize("shards", [2, 4])
def test_bench_sssp_smoke_multi_shard(shards):
    """The CI gate: fused/legacy bit-parity, exact Dijkstra distances, and
    the declared relaxation envelope on a forced-device mesh."""
    import io
    from benchmarks.bench_sssp import smoke
    buf = io.StringIO()
    assert smoke(buf, shards=shards), buf.getvalue()
