import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove every (architecture × input shape × mesh) cell
lowers AND compiles under the production meshes, and extract the roofline
inputs from the compiled artifact.

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init) — do not move it.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --list

Results accumulate in ``dryrun_results.json`` (incremental: completed cells
are skipped on re-runs; --force recomputes).
"""

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from ..configs import SHAPES, get_config, list_archs          # noqa: E402
from ..jaxcompat import cost_analysis_dict                     # noqa: E402
from ..models import param_specs                               # noqa: E402
from . import steps as S                                       # noqa: E402
from .hlo_analysis import analyze_hlo_text                     # noqa: E402
from .mesh import make_production_mesh                         # noqa: E402

RESULTS_PATH = os.environ.get("DRYRUN_RESULTS", "dryrun_results.json")


def _jsonable(d):
    out = {}
    for k, v in (d or {}).items():
        try:
            out[str(k)] = float(v)
        except (TypeError, ValueError):
            out[str(k)] = str(v)
    return out


def lower_cell(arch: str, shape: str, mesh, *, opt_overrides=None):
    """Build + lower + compile one cell.  Returns (compiled, lowered)."""
    import dataclasses
    cfg = get_config(arch)
    kind = SHAPES[shape]["kind"]
    if kind in ("train", "prefill") and SHAPES[shape]["seq_len"] % 16 == 0:
        # sequence-parallel residual stream (Megatron SP) under the mesh
        cfg = dataclasses.replace(cfg, seq_parallel=True)
    if opt_overrides:
        cfg = dataclasses.replace(cfg, **opt_overrides)
    pspecs = param_specs(cfg)

    with jax.set_mesh(mesh):
        if kind == "train":
            st_struct = S.state_struct(cfg)
            st_specs = S.sanitize_pspecs(S.state_pspecs(cfg), st_struct, mesh)
            step = S.make_train_step(cfg, pspecs=st_specs.master)
            b_struct = S.batch_struct(cfg, shape)
            b_specs = S.sanitize_pspecs(S.batch_pspecs(cfg, shape, mesh),
                                        b_struct, mesh)
            lowered = jax.jit(
                step,
                in_shardings=(st_specs, b_specs),
                out_shardings=(st_specs, None),
            ).lower(st_struct, b_struct)
        elif kind == "prefill":
            step = S.make_prefill_step(cfg)
            p_struct = S.params_struct(cfg)
            p_specs = S.sanitize_pspecs(pspecs, p_struct, mesh)
            b = dict(S.batch_struct(cfg, shape))
            b.pop("labels")
            bp = dict(S.batch_pspecs(cfg, shape, mesh))
            bp.pop("labels")
            bp = S.sanitize_pspecs(bp, b, mesh)
            lowered = jax.jit(
                step, in_shardings=(p_specs, bp),
            ).lower(p_struct, b)
        else:  # decode
            step = S.make_serve_step(cfg)
            sh = SHAPES[shape]
            bsz = sh["global_batch"]
            tok = jax.ShapeDtypeStruct((bsz, 1), jnp.int32)
            cur = jax.ShapeDtypeStruct((), jnp.int32)
            p_struct = S.params_struct(cfg)
            c_struct = S.cache_struct(cfg, shape)
            args = [p_struct, c_struct, tok, cur]
            in_sh = [S.sanitize_pspecs(pspecs, p_struct, mesh),
                     S.sanitize_pspecs(S.cache_pspecs(cfg, shape, mesh),
                                       c_struct, mesh),
                     S.token_pspecs(cfg, shape, mesh), P()]
            if cfg.family == "vlm":
                img = jax.ShapeDtypeStruct(
                    (bsz, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
                args.append(img)
                in_sh.append(S.sanitize_pspecs(
                    S.batch_pspecs(cfg, shape, mesh)["img"], img, mesh))
            lowered = jax.jit(step, in_shardings=tuple(in_sh)).lower(*args)
        compiled = lowered.compile()
    return compiled, lowered


def run_cell(arch: str, shape: str, mesh_kind: str, results: dict,
             force: bool = False) -> dict:
    key = f"{arch}|{shape}|{mesh_kind}"
    cfg = get_config(arch)
    if shape in cfg.skip_shapes:
        rec = {"status": "skipped", "reason": cfg.skip_reason}
        results[key] = rec
        return rec
    if key in results and results[key].get("status") == "ok" and not force:
        return results[key]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    try:
        compiled, lowered = lower_cell(arch, shape, mesh)
        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        hlo = analyze_hlo_text(compiled.as_text())
        rec = {
            "status": "ok",
            "seconds": round(time.time() - t0, 1),
            "ndev": mesh.size,
            "memory": {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
            },
            "cost_analysis": {
                "flops": float(cost.get("flops", -1.0)),
                "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
            },
            "hlo": {
                "flops_per_dev": hlo.flops,
                "bytes_per_dev": hlo.bytes,
                "collective_bytes_per_dev": hlo.collective_bytes,
                "by_collective": _jsonable(hlo.by_collective),
                "dot_count": hlo.dot_count,
                "warnings": hlo.warnings[:20],
            },
            "model_flops_note": {
                "params": cfg.param_count(),
                "active_params": cfg.active_param_count(),
            },
        }
        print(f"[ok] {key}: {rec['seconds']}s  "
              f"hlo_flops/dev={hlo.flops:.3e}  coll/dev={hlo.collective_bytes:.3e}  "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec = {"status": "error", "seconds": round(time.time() - t0, 1),
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
        print(f"[ERROR] {key}: {type(e).__name__}: {str(e)[:200]}")
    results[key] = rec
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default=None, choices=["pod", "multipod", None])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=RESULTS_PATH)
    args = ap.parse_args()

    if args.list:
        for a in list_archs():
            cfg = get_config(a)
            print(f"{a:26s} {cfg.family:7s} params={cfg.param_count()/1e9:7.2f}B "
                  f"skips={','.join(cfg.skip_shapes) or '-'}")
        return

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["pod", "multipod"]
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                run_cell(arch, shape, mk, results, force=args.force)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    ok = sum(1 for r in results.values() if r.get("status") == "ok")
    sk = sum(1 for r in results.values() if r.get("status") == "skipped")
    err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"\n=== dry-run summary: {ok} ok, {sk} skipped, {err} errors "
          f"(of {len(results)} cells) -> {args.out}")


if __name__ == "__main__":
    main()
