"""Linearizability checking for concurrent FIFO-queue histories (paper § IV).

The paper logs device histories ``(proc, op, arg, ret, call, end)`` and checks
them with Porcupine's FIFO model.  Porcupine is a Go library; this module
provides the same check in Python, two ways:

* ``check_linearizable`` — the production checker: the **complete
  bad-pattern characterization** of queue linearizability for differentiated
  histories (all values distinct — guaranteed by the § IV-b token scheme),
  following Bouajjani–Emmi–Enea–Hamza.  A history is linearizable w.r.t. the
  FIFO queue iff none of the following patterns occur:

    P1  a value is dequeued but never enqueued, or dequeued/enqueued twice;
    P2  deq(x) returns before enq(x) is invoked;
    P3  FIFO inversion: enq(x) precedes enq(y) (returns before invocation)
        and deq(y) precedes deq(x);
    P4  enq(x) precedes enq(y), y is dequeued but x never is;
    P5  a deq→EMPTY whose whole interval is covered by values that are
        provably inside the queue (enq returned before, deq not yet invoked).

  This runs in O(n log n) and scales to the benchmark-sized histories.

* ``check_linearizable_search`` — a direct Wing–Gong search with
  Horn–Kroening-style memoization (what Porcupine executes), kept as an
  independent oracle: the test suite cross-validates both checkers on small
  histories, including hand-built non-linearizable ones.

Histories use the § IV conventions: op 0 = ENQ (arg = value, ret = True on
success), op 1 = DEQ (ret = value, or None for EMPTY).  Failed (FULL)
enqueues have no visible effect and are dropped before checking.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .sim import DEQ, ENQ, HistoryEvent


@dataclass
class CheckResult:
    ok: bool
    reason: str = ""
    nodes: int = 0


def _prepare(history: Sequence[HistoryEvent]) -> List[HistoryEvent]:
    ops = []
    for ev in history:
        if ev.op == ENQ and ev.ret is not True:
            continue  # failed/FULL enqueue: no effect
        ops.append(ev)
    ops.sort(key=lambda e: (e.call, e.end))
    return ops


# ---------------------------------------------------------------------------
# Complete pattern-based checker (distinct values)
# ---------------------------------------------------------------------------


def check_linearizable(history: Sequence[HistoryEvent]) -> CheckResult:
    ops = _prepare(history)
    enq: Dict[int, HistoryEvent] = {}
    deq: Dict[int, HistoryEvent] = {}
    empties: List[HistoryEvent] = []
    for ev in ops:
        if ev.op == ENQ:
            if ev.arg in enq:
                return CheckResult(False, f"P1: value {ev.arg} enqueued twice")
            enq[ev.arg] = ev
        else:
            if ev.ret is None:
                empties.append(ev)
                continue
            if ev.ret in deq:
                return CheckResult(False, f"P1: value {ev.ret} dequeued twice")
            deq[ev.ret] = ev
    for v, d in deq.items():
        e = enq.get(v)
        if e is None:
            return CheckResult(False, f"P1: value {v} dequeued, never enqueued")
        if d.end < e.call:
            return CheckResult(False, f"P2: deq({v}) returned before enq({v}) began")

    # P4: some unmatched x strictly precedes a matched (dequeued) y.
    unmatched = [v for v in enq if v not in deq]
    if unmatched:
        m = min(enq[v].end for v in unmatched)
        for y, ey in enq.items():
            if y in deq and ey.call > m:
                x = next(v for v in unmatched if enq[v].end < ey.call)
                return CheckResult(
                    False, f"P4: enq({x}) precedes enq({y}); {y} dequeued, {x} never")

    # P3: enqEnd(x) < enqCall(y)  ∧  deqEnd(y) < deqCall(x), both matched.
    matched = sorted(deq.keys(), key=lambda v: enq[v].end)
    enq_ends = [enq[v].end for v in matched]
    # prefix max (top-2, to exclude self) of deq(x).call over enq-end order
    best: List[Tuple[Tuple[int, Optional[int]], Tuple[int, Optional[int]]]] = []
    b1: Tuple[int, Optional[int]] = (-1, None)
    b2: Tuple[int, Optional[int]] = (-1, None)
    for v in matched:
        c = deq[v].call
        if c > b1[0]:
            b1, b2 = (c, v), b1
        elif c > b2[0]:
            b2 = (c, v)
        best.append((b1, b2))
    for y in matched:
        k = bisect.bisect_left(enq_ends, enq[y].call)  # x with enqEnd < enqCall(y)
        if k == 0:
            continue
        (c1, x1), (c2, x2) = best[k - 1]
        cand = (c1, x1) if x1 != y else (c2, x2)
        if cand[1] is not None and cand[0] > deq[y].end:
            return CheckResult(
                False,
                f"P3: enq({cand[1]}) precedes enq({y}) but deq({y}) precedes deq({cand[1]})")

    # P5: every EMPTY needs an uncovered instant in its interval.
    blocks: List[Tuple[int, int]] = []  # open intervals (enqEnd, deqCall/∞)
    INF = 1 << 62
    for v, e in enq.items():
        lo = e.end
        hi = deq[v].call if v in deq else INF
        if hi > lo:
            blocks.append((lo, hi))
    blocks.sort()
    merged: List[Tuple[int, int]] = []
    for lo, hi in blocks:
        if merged and lo <= merged[-1][1]:  # open intervals: touching ⇒ escapable
            if lo < merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], hi))
            else:
                merged.append((lo, hi))
        else:
            merged.append((lo, hi))
    starts = [b[0] for b in merged]
    for ev in empties:
        # find an instant t ∈ [call, end] outside all open blocks
        k = bisect.bisect_right(starts, ev.call) - 1
        t = ev.call
        covered = True
        while t <= ev.end:
            # is t strictly inside some block?
            while k + 1 < len(merged) and merged[k + 1][0] < t:
                k += 1
            if k >= 0 and merged[k][0] < t < merged[k][1]:
                t = merged[k][1]  # jump to the block's end (escapable boundary)
                continue
            covered = False
            break
        if covered:
            return CheckResult(
                False, f"P5: EMPTY dequeue by proc {ev.proc} at [{ev.call},{ev.end}] "
                       f"overlaps no empty instant")
    return CheckResult(True, "linearizable (complete pattern check)")


# ---------------------------------------------------------------------------
# Wing–Gong / Horn–Kroening search (independent oracle for small histories)
# ---------------------------------------------------------------------------


def check_linearizable_search(history: Sequence[HistoryEvent],
                              max_nodes: int = 500_000) -> CheckResult:
    ops = _prepare(history)
    n = len(ops)
    if n == 0:
        return CheckResult(True, "empty history")
    calls = [op.call for op in ops]
    ends = [op.end for op in ops]
    nodes = 0
    seen = set()
    stack: List[Tuple[int, Tuple[int, ...]]] = [(0, tuple())]
    full_mask = (1 << n) - 1
    while stack:
        mask, q = stack.pop()
        if mask == full_mask:
            return CheckResult(True, "linearizable (search)", nodes)
        key = (mask, q)
        if key in seen:
            continue
        seen.add(key)
        nodes += 1
        if nodes > max_nodes:
            return CheckResult(False, f"search budget exceeded ({nodes} nodes)", nodes)
        min_end = min(ends[i] for i in range(n) if not (mask >> i) & 1)
        for i in range(n):
            if (mask >> i) & 1 or calls[i] > min_end:
                continue
            op = ops[i]
            if op.op == ENQ:
                stack.append((mask | (1 << i), q + (op.arg,)))
            elif op.ret is None:
                if not q:
                    stack.append((mask | (1 << i), q))
            elif q and q[0] == op.ret:
                stack.append((mask | (1 << i), q[1:]))
    return CheckResult(False, "no valid linearization found", nodes)


# Back-compat alias used by benchmarks for very large histories: the pattern
# checker IS complete, so the "screen" is simply the checker itself.
def fast_violation_screen(history: Sequence[HistoryEvent]) -> CheckResult:
    return check_linearizable(history)
