"""repro.sched — G-PQ: wave-batched linearizable priority scheduling
(DESIGN.md § 5).

The priority companion to the FIFO queue core: a bounded concurrent
min-priority queue built from the paper's primitives (WAVEFAA ticket
batching into an announce ring, packed 64-bit node words, latch-combined
d-ary applied heap), a k-relaxed multi-ring variant with a quantitative
relaxation bound, the priority-semantics history checker, scheduling
policies (strict / weighted / EDF) for the runtime's ``PriorityFabric``,
and the host-thread twin used by the serving engine's EDF admission.
"""

from .gpq import DELMIN, GPQ, INS, NODE, NodeFormat
from .hostpq import HostPriorityPool
from .plinearizability import (check_p_linearizable,
                               check_p_linearizable_search,
                               mesh_trace_history)
from .policy import (EDFPolicy, POLICIES, PriorityPolicy, StrictPolicy,
                     WeightedPolicy, make_policy)
from .relaxed import RelaxedGPQ, mesh_relaxation_bound

__all__ = [
    "DELMIN", "EDFPolicy", "GPQ", "HostPriorityPool", "INS", "NODE",
    "NodeFormat", "POLICIES", "PriorityPolicy", "RelaxedGPQ", "StrictPolicy",
    "WeightedPolicy", "check_p_linearizable", "check_p_linearizable_search",
    "make_policy", "mesh_relaxation_bound", "mesh_trace_history",
]
