"""Task-runtime benchmark: single shared queue vs. sharded fabric vs.
sharded fabric + work stealing, across arrival scenarios (DESIGN.md § 4.6).

Three open-loop scenarios, each executed by ≥32 persistent sim workers:

* ``uniform``   — tasks arrive evenly spaced, uniform small costs, sprayed
                  round-robin across shards (the balanced regime: isolates
                  pure queue-contention cost),
* ``powerlaw``  — all tasks arrive up front with Pareto-tailed costs (the
                  heavy-tail regime: a few giant tasks, load imbalance from
                  cost skew),
* ``bursty``    — periodic bursts land on a *single rotating shard* each
                  (wave-affinity arrivals: placement skew, the regime work
                  stealing exists for).

The headline comparison (acceptance): under power-law costs the
sharded+stealing fabric must beat the single shared queue on both
``throughput_ops_per_kstep`` (higher) and ``idle_steps`` (lower).  The
no-steal sharded column is the placement-oracle upper bound: when arrivals
are already balanced it can edge out stealing (steal scans add consumers to
hot rings) — the fabric's win over `single` comes from de-contending the
rings, stealing's role is robustness to skew (`bursty`; and without it,
skewed placement can starve outright when no worker's wave covers a shard).

CSV columns: scenario, queue, config, workers, tasks,
throughput_ops_per_kstep, idle_steps, idle_per_task, steals, steal_rate,
load_imbalance, total_steps.
"""

from __future__ import annotations

import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime import ExecutorConfig, TaskFabric, TaskRuntime

CONFIGS: Tuple[Tuple[str, int, bool], ...] = (
    ("single", 1, False),
    ("sharded", 4, False),
    ("sharded+steal", 4, True),
)


def _build(scenario: str, rt: TaskRuntime, shards: int, n_tasks: int,
           seed: int) -> None:
    rng = np.random.default_rng(seed)
    if scenario == "uniform":
        for i in range(n_tasks):
            rt.add_task(i, cost=int(rng.integers(1, 9)), at_step=i * 40)
    elif scenario == "powerlaw":
        costs = np.minimum((rng.pareto(1.2, n_tasks) * 4 + 1).astype(int), 64)
        for i in range(n_tasks):
            rt.add_task(i, cost=int(costs[i]))
    elif scenario == "bursty":
        bursts = max(n_tasks // 32, 1)
        for b in range(bursts):
            for k in range(32):
                rt.add_task(b * 32 + k, cost=int(rng.integers(32, 129)),
                            at_step=b * 3000, affinity=b)
    else:
        raise ValueError(f"unknown scenario {scenario!r}")


def run_scenario(scenario: str, algo: str, config: str, shards: int,
                 steal: bool, *, workers: int = 32, n_tasks: int = 256,
                 seed: int = 0, policy: str = "gang") -> Dict[str, float]:
    fabric = TaskFabric(algo=algo, shards=shards,
                        capacity_per_shard=max(2 * n_tasks // shards, 64),
                        num_threads=workers + 1, steal=steal)
    rt = TaskRuntime(fabric, lambda rec: [],
                     ExecutorConfig(workers=workers, policy=policy, seed=seed))
    _build(scenario, rt, shards, n_tasks, seed)
    m = rt.run()
    m["tasks"] = len(rt.executed)
    return m


def main(out=sys.stdout, *, workers: int = 32, n_tasks: int = 256,
         algos=("glfq", "gwfq", "gwfq-ymc", "sfq"),
         scenarios=("uniform", "powerlaw", "bursty"),
         seed: int = 0) -> List[Dict]:
    print("bench,scenario,queue,config,workers,tasks,"
          "throughput_ops_per_kstep,idle_steps,idle_per_task,steals,"
          "steal_rate,load_imbalance,total_steps", file=out)
    rows: List[Dict] = []
    for scenario in scenarios:
        for algo in algos:
            for config, shards, steal in CONFIGS:
                m = run_scenario(scenario, algo, config, shards, steal,
                                 workers=workers, n_tasks=n_tasks, seed=seed)
                row = {
                    "bench": "runtime", "scenario": scenario, "queue": algo,
                    "config": config, "workers": workers,
                    "tasks": int(m["tasks"]),
                    "throughput_ops_per_kstep":
                        round(m["throughput_ops_per_kstep"], 3),
                    "idle_steps": int(m["idle_steps"]),
                    "idle_per_task": round(m["idle_steps_per_task"], 2),
                    "steals": int(m["steals"]),
                    "steal_rate": round(m["steal_rate"], 3),
                    "load_imbalance": round(m["load_imbalance"], 3),
                    "total_steps": int(m["total_steps"]),
                }
                rows.append(row)
                print("runtime,{scenario},{queue},{config},{workers},{tasks},"
                      "{throughput_ops_per_kstep},{idle_steps},"
                      "{idle_per_task},{steals},{steal_rate},"
                      "{load_imbalance},{total_steps}".format(**row), file=out)
                out.flush()
    # headline acceptance summary for the default algorithm
    for algo in algos[:1]:
        base = next(r for r in rows if r["scenario"] == "powerlaw"
                    and r["queue"] == algo and r["config"] == "single")
        st = next(r for r in rows if r["scenario"] == "powerlaw"
                  and r["queue"] == algo and r["config"] == "sharded+steal")
        verdict = (st["throughput_ops_per_kstep"]
                   > base["throughput_ops_per_kstep"]
                   and st["idle_steps"] < base["idle_steps"])
        print(f"# powerlaw/{algo}: sharded+steal thr "
              f"{st['throughput_ops_per_kstep']} vs single "
              f"{base['throughput_ops_per_kstep']}, idle {st['idle_steps']} "
              f"vs {base['idle_steps']} -> "
              f"{'PASS' if verdict else 'FAIL'}", file=out)
    return rows


if __name__ == "__main__":
    main()
