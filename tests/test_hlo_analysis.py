"""HLO analyzer validation: trip-count extraction and FLOP accounting on a
known scanned workload (the probe that motivated the analyzer: XLA's
cost_analysis counts while bodies once)."""

import jax
import jax.numpy as jnp

from repro.jaxcompat import cost_analysis_dict, make_mesh
from repro.launch.hlo_analysis import analyze_hlo_text


def test_scan_trip_count_multiplies_flops():
    L, M, B = 7, 128, 32

    def step(w, xs):
        def body(c, x):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, xs[0], xs, length=L)
        return c.sum()

    w = jax.ShapeDtypeStruct((M, M), jnp.float32)
    xs = jax.ShapeDtypeStruct((L, B, M), jnp.float32)
    compiled = jax.jit(jax.grad(step)).lower(w, xs).compile()
    c = analyze_hlo_text(compiled.as_text())
    assert not c.warnings, c.warnings
    # fwd: L×(2·B·M·M); bwd ≈ 2× more (dgrad + wgrad)
    fwd = L * 2 * B * M * M
    assert c.flops >= 2.5 * fwd, (c.flops, fwd)
    assert c.flops <= 4.0 * fwd, (c.flops, fwd)
    # cost_analysis counts the body once — the analyzer must exceed it
    assert c.flops > float(cost_analysis_dict(compiled)["flops"]) * (L - 1) / 2


def test_collectives_counted():
    import numpy as np
    mesh = make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def f(x):
        return jax.lax.psum(x, "data")

    g = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P()))
    compiled = g.lower(jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile()
    c = analyze_hlo_text(compiled.as_text())
    assert c.collective_bytes >= 0  # single device may elide the collective


def test_shape_parsing():
    from repro.launch.hlo_analysis import shape_bytes, shape_elems
    assert shape_bytes("f32[2,3]{1,0}") == 24
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(f32[2]{0}, s32[])") == 12
    assert shape_elems("pred[8,8]") == 64
