"""Task-runtime benchmark: single shared queue vs. sharded fabric vs.
sharded fabric + work stealing, across arrival scenarios (DESIGN.md § 4.7),
plus the priority-policy comparison on the G-PQ fabric (DESIGN.md § 5.7).

Three open-loop scenarios, each executed by ≥32 persistent sim workers:

* ``uniform``   — tasks arrive evenly spaced, uniform small costs, sprayed
                  round-robin across shards (the balanced regime: isolates
                  pure queue-contention cost),
* ``powerlaw``  — all tasks arrive up front with Pareto-tailed costs (the
                  heavy-tail regime: a few giant tasks, load imbalance from
                  cost skew),
* ``bursty``    — periodic bursts land on a *single rotating shard* each
                  (wave-affinity arrivals: placement skew, the regime work
                  stealing exists for).

The headline comparison (acceptance): under power-law costs the
sharded+stealing fabric must beat the single shared queue on both
``throughput_ops_per_kstep`` (higher) and ``idle_steps`` (lower).  The
no-steal sharded column is the placement-oracle upper bound: when arrivals
are already balanced it can edge out stealing (steal scans add consumers to
hot rings) — the fabric's win over `single` comes from de-contending the
rings, stealing's role is robustness to skew (`bursty`; and without it,
skewed placement can starve outright when no worker's wave covers a shard).

CSV columns: scenario, queue, config, workers, tasks,
throughput_ops_per_kstep, idle_steps, idle_per_task, steals, steal_rate,
load_imbalance, total_steps.
"""

from __future__ import annotations

import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime import (ExecutorConfig, PriorityFabric, TaskFabric,
                           TaskRuntime)

CONFIGS: Tuple[Tuple[str, int, bool], ...] = (
    ("single", 1, False),
    ("sharded", 4, False),
    ("sharded+steal", 4, True),
)

POLICIES: Tuple[str, ...] = ("strict", "weighted", "edf")


def _build(scenario: str, rt: TaskRuntime, shards: int, n_tasks: int,
           seed: int) -> None:
    rng = np.random.default_rng(seed)
    if scenario == "uniform":
        for i in range(n_tasks):
            rt.add_task(i, cost=int(rng.integers(1, 9)), at_step=i * 40)
    elif scenario == "powerlaw":
        costs = np.minimum((rng.pareto(1.2, n_tasks) * 4 + 1).astype(int), 64)
        for i in range(n_tasks):
            rt.add_task(i, cost=int(costs[i]))
    elif scenario == "bursty":
        bursts = max(n_tasks // 32, 1)
        for b in range(bursts):
            for k in range(32):
                rt.add_task(b * 32 + k, cost=int(rng.integers(32, 129)),
                            at_step=b * 3000, affinity=b)
    else:
        raise ValueError(f"unknown scenario {scenario!r}")


def run_scenario(scenario: str, algo: str, config: str, shards: int,
                 steal: bool, *, workers: int = 32, n_tasks: int = 256,
                 seed: int = 0, policy: str = "gang") -> Dict[str, float]:
    fabric = TaskFabric(algo=algo, shards=shards,
                        capacity_per_shard=max(2 * n_tasks // shards, 64),
                        num_threads=workers + 1, steal=steal)
    rt = TaskRuntime(fabric, lambda rec: [],
                     ExecutorConfig(workers=workers, policy=policy, seed=seed))
    _build(scenario, rt, shards, n_tasks, seed)
    m = rt.run()
    m["tasks"] = len(rt.executed)
    return m


def fifo_acceptance(single: Dict, fab: Dict) -> Tuple[bool, str]:
    """Headline FIFO-fabric criterion: sharded+steal must beat the single
    shared queue on throughput and idle steps (powerlaw costs)."""
    ok = (fab["throughput_ops_per_kstep"] > single["throughput_ops_per_kstep"]
          and fab["idle_steps"] < single["idle_steps"])
    msg = (f"sharded+steal thr {fab['throughput_ops_per_kstep']:.3f} vs "
           f"single {single['throughput_ops_per_kstep']:.3f}, idle "
           f"{int(fab['idle_steps'])} vs {int(single['idle_steps'])} -> "
           f"{'PASS' if ok else 'FAIL'}")
    return ok, msg


def priority_acceptance(strict: Dict, row: Dict) -> Tuple[bool, str]:
    """Headline G-PQ criterion: a starvation-free policy must match or
    beat strict on throughput with strictly lower normal-class max wait."""
    ok = (row["throughput_ops_per_kstep"]
          >= strict["throughput_ops_per_kstep"]
          and row["normal_max_wait"] < strict["normal_max_wait"])
    msg = (f"thr {row['throughput_ops_per_kstep']:.3f} vs strict "
           f"{strict['throughput_ops_per_kstep']:.3f}, normal max wait "
           f"{int(row['normal_max_wait'])} vs "
           f"{int(strict['normal_max_wait'])} -> "
           f"{'PASS' if ok else 'FAIL'}")
    return ok, msg


def _make_policy(name: str):
    """Bench-tuned policy instances: weighted 6:1 shares; EDF with zero
    urgent slack and a 4096-step normal slack (≈ the urgent inter-burst
    horizon, so normal tasks age to the front within a few bursts)."""
    from repro.sched.policy import EDFPolicy, StrictPolicy, WeightedPolicy
    return {"strict": lambda: StrictPolicy(),
            "weighted": lambda: WeightedPolicy(weights=(6, 1), scale=96),
            "edf": lambda: EDFPolicy(slack=(0, 4096))}[name]()


def run_priority_scenario(policy: str, *, workers: int = 8, sources: int = 8,
                          n_normal: int = 64, bursts: int = 16,
                          burst: int = 8, gap: int = 500, shards: int = 4,
                          capacity_per_shard: int = 16, seed: int = 0,
                          sched_policy: str = "gang") -> Dict[str, float]:
    """Powerlaw + bursty mixed-class workload on the G-PQ PriorityFabric
    (DESIGN.md § 5.7): heavy-tailed *normal* tasks all pending up front,
    *heavy urgent* bursts (think priority prefills) arriving steadily on a
    rotating affinity shard across the whole horizon, released by parallel
    open-loop sources against deliberately tight shard capacity.  A policy
    that starves the normal class (strict) keeps shards full of aged
    normal tasks and serializes the heavy urgent work head-of-line, so
    starvation shows up as *both* a normal-class max-wait blowup and a
    throughput loss (admission backpressure + slot-turnover stalls)."""
    fabric = PriorityFabric(policy=_make_policy(policy), shards=shards,
                            capacity_per_shard=capacity_per_shard,
                            num_threads=workers + sources)
    rt = TaskRuntime(fabric, lambda rec: [],
                     ExecutorConfig(workers=workers, sources=sources,
                                    policy=sched_policy, seed=seed))
    rng = np.random.default_rng(seed)
    costs = np.minimum((rng.pareto(1.2, n_normal) * 8 + 2).astype(int), 48)
    for i in range(n_normal):
        rt.add_task(("n", i), priority=1, cost=int(costs[i]), at_step=0)
    for b in range(bursts):
        for k in range(burst):
            rt.add_task(("u", b * burst + k), priority=0,
                        cost=int(rng.integers(32, 97)),
                        at_step=100 + b * gap, affinity=b % shards)
    m = rt.run()
    m["tasks"] = len(rt.executed)
    return m


def priority_main(out=sys.stdout, *, workers: int = 8, bursts: int = 16,
                  seed: int = 0) -> List[Dict]:
    """Policy comparison rows + acceptance: EDF and weighted must match or
    beat strict on throughput while strictly reducing normal-class
    starvation (max wait)."""
    print("bench,scenario,policy,workers,tasks,throughput_ops_per_kstep,"
          "idle_steps,steals,steal_rate,load_imbalance,normal_max_wait,"
          "normal_p99_wait,urgent_max_wait,urgent_p99_wait,total_steps",
          file=out)
    rows: List[Dict] = []
    for policy in POLICIES:
        m = run_priority_scenario(policy, workers=workers, bursts=bursts,
                                  seed=seed)
        row = {
            "bench": "priority", "scenario": "powerlaw+bursty",
            "policy": policy, "workers": workers, "tasks": int(m["tasks"]),
            "throughput_ops_per_kstep":
                round(m["throughput_ops_per_kstep"], 3),
            "idle_steps": int(m["idle_steps"]),
            "steals": int(m["steals"]),
            "steal_rate": round(m["steal_rate"], 3),
            "load_imbalance": round(m["load_imbalance"], 3),
            "normal_max_wait": int(m["normal_max_wait"]),
            "normal_p99_wait": int(m["normal_p99_wait"]),
            "urgent_max_wait": int(m["urgent_max_wait"]),
            "urgent_p99_wait": int(m["urgent_p99_wait"]),
            "total_steps": int(m["total_steps"]),
        }
        rows.append(row)
        print("priority,{scenario},{policy},{workers},{tasks},"
              "{throughput_ops_per_kstep},{idle_steps},{steals},"
              "{steal_rate},{load_imbalance},{normal_max_wait},"
              "{normal_p99_wait},{urgent_max_wait},{urgent_p99_wait},"
              "{total_steps}".format(**row), file=out)
        out.flush()
    strict = next(r for r in rows if r["policy"] == "strict")
    for policy in ("weighted", "edf"):
        r = next(x for x in rows if x["policy"] == policy)
        _, msg = priority_acceptance(strict, r)
        print(f"# powerlaw+bursty/{policy}: {msg}", file=out)
    return rows


def main(out=sys.stdout, *, workers: int = 32, n_tasks: int = 256,
         algos=("glfq", "gwfq", "gwfq-ymc", "sfq"),
         scenarios=("uniform", "powerlaw", "bursty"),
         seed: int = 0) -> List[Dict]:
    print("bench,scenario,queue,config,workers,tasks,"
          "throughput_ops_per_kstep,idle_steps,idle_per_task,steals,"
          "steal_rate,load_imbalance,total_steps", file=out)
    rows: List[Dict] = []
    for scenario in scenarios:
        for algo in algos:
            for config, shards, steal in CONFIGS:
                m = run_scenario(scenario, algo, config, shards, steal,
                                 workers=workers, n_tasks=n_tasks, seed=seed)
                row = {
                    "bench": "runtime", "scenario": scenario, "queue": algo,
                    "config": config, "workers": workers,
                    "tasks": int(m["tasks"]),
                    "throughput_ops_per_kstep":
                        round(m["throughput_ops_per_kstep"], 3),
                    "idle_steps": int(m["idle_steps"]),
                    "idle_per_task": round(m["idle_steps_per_task"], 2),
                    "steals": int(m["steals"]),
                    "steal_rate": round(m["steal_rate"], 3),
                    "load_imbalance": round(m["load_imbalance"], 3),
                    "total_steps": int(m["total_steps"]),
                }
                rows.append(row)
                print("runtime,{scenario},{queue},{config},{workers},{tasks},"
                      "{throughput_ops_per_kstep},{idle_steps},"
                      "{idle_per_task},{steals},{steal_rate},"
                      "{load_imbalance},{total_steps}".format(**row), file=out)
                out.flush()
    # headline acceptance summary for the default algorithm
    for algo in algos[:1]:
        base = next(r for r in rows if r["scenario"] == "powerlaw"
                    and r["queue"] == algo and r["config"] == "single")
        st = next(r for r in rows if r["scenario"] == "powerlaw"
                  and r["queue"] == algo and r["config"] == "sharded+steal")
        _, msg = fifo_acceptance(base, st)
        print(f"# powerlaw/{algo}: {msg}", file=out)
    return rows


def smoke() -> int:
    """CI-sized acceptance gate: both headline comparisons must PASS.
    Returns a process exit code (0 = all acceptance criteria hold)."""
    failures = 0
    single = run_scenario("powerlaw", "glfq", "single", 1, False,
                          workers=32, n_tasks=96)
    fab = run_scenario("powerlaw", "glfq", "sharded+steal", 4, True,
                       workers=32, n_tasks=96)
    ok, msg = fifo_acceptance(single, fab)
    print(f"# smoke powerlaw/glfq: {msg}")
    failures += not ok
    strict = run_priority_scenario("strict", bursts=12)
    for policy in ("weighted", "edf"):
        m = run_priority_scenario(policy, bursts=12)
        ok, msg = priority_acceptance(strict, m)
        print(f"# smoke powerlaw+bursty/{policy}: {msg}")
        failures += not ok
    return 1 if failures else 0


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="acceptance gate only (exit 1 on FAIL)")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(smoke())
    main()
    priority_main()
