"""Roofline report (deliverable g): derive the three terms per
(architecture × shape × mesh) from the dry-run artifacts.

    compute    = HLO_FLOPs_per_dev / PEAK_FLOPS
    memory     = HLO_bytes_per_dev / HBM_BW
    collective = collective_bytes_per_dev / ICI_BW

HLO quantities come from the trip-count-corrected HLO analyzer (dryrun
stores them in dryrun_results.json).  MODEL_FLOPS = 6·N·D (dense) or
6·N_active·D (MoE) cross-checks the compiled compute; the ratio exposes
remat/recompute overhead (>1 expected: full remat ≈ +fwd, flash backward
re-tiles, attention itself is outside 6·N·D).

Usage:
    PYTHONPATH=src python -m repro.launch.roofline [--results FILE] [--md]
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, Optional

from ..configs import SHAPES, get_config, list_archs

# TPU v5e per-chip targets (assignment constants)
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # B/s
ICI_BW = 50e9           # B/s per link


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    sh = SHAPES[shape]
    n = cfg.active_param_count()
    if sh["kind"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 6.0 * n * tokens
    if sh["kind"] == "prefill":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * sh["global_batch"]


def cell_report(key: str, rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    arch, shape, mesh = key.split("|")
    ndev = rec["ndev"]
    hlo = rec["hlo"]
    compute = hlo["flops_per_dev"] / PEAK_FLOPS
    memory = hlo["bytes_per_dev"] / HBM_BW
    coll = hlo["collective_bytes_per_dev"] / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(arch, shape)
    mf_dev = mf / ndev
    useful_ratio = mf_dev / max(hlo["flops_per_dev"], 1.0)
    # roofline fraction: useful model flops per device over the time the
    # dominant term implies, vs peak
    frac = (mf_dev / PEAK_FLOPS) / max(bound, 1e-30)
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "ndev": ndev,
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant, "model_flops_per_dev": mf_dev,
        "useful_ratio": useful_ratio, "roofline_fraction": frac,
        "temp_gib": rec["memory"]["temp_bytes"] / 2 ** 30,
        "arg_gib": rec["memory"]["argument_bytes"] / 2 ** 30,
        "by_collective": hlo.get("by_collective", {}),
        "warnings": hlo.get("warnings", []),
    }


MITIGATION = {
    "compute": "raise useful-FLOP share: cheaper remat policy / fewer "
               "recomputed tiles / larger per-chip batch",
    "memory": "fuse / shrink materialized intermediates; bf16 residuals; "
              "bigger flash tiles to cut HBM round-trips",
    "collective": "reshard to cut all-gather volume (FSDP prefetch, "
                  "sequence- vs tensor-parallel rebalance); overlap with "
                  "bucketed collectives; int8-compress cross-pod grads",
}


def build_report(results: Dict) -> Dict[str, Dict]:
    out = {}
    for key, rec in sorted(results.items()):
        r = cell_report(key, rec)
        if r is not None:
            out[key] = r
    return out


def to_markdown(report: Dict[str, Dict], results: Dict) -> str:
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) |"
        " dominant | MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key, r in report.items():
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.1%} |")
    for key, rec in sorted(results.items()):
        if rec.get("status") == "skipped":
            a, s, m = key.split("|")
            lines.append(f"| {a} | {s} | {m} | — | — | — | skipped |"
                         f" {rec['reason'][:40]} | — |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results.json")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    with open(args.results) as f:
        results = json.load(f)
    report = build_report(results)
    if args.md:
        print(to_markdown(report, results))
        return
    for key, r in report.items():
        print(f"{key:48s} C={r['compute_s']:.2e} M={r['memory_s']:.2e} "
              f"X={r['collective_s']:.2e} dom={r['dominant']:10s} "
              f"frac={r['roofline_fraction']:6.1%} "
              f"useful={r['useful_ratio']:.2f} temp={r['temp_gib']:.1f}GiB")
        print(f"{'':48s} ↳ {MITIGATION[r['dominant']]}")


if __name__ == "__main__":
    main()
