"""Progress-guarantee tests (paper Theorems III.4 / III.10, empirically).

Wait-freedom cannot be *proved* by testing, but its observable signature
can: under a scheduler that systematically starves a victim thread, a
wait-free queue's victim still completes every operation in a bounded
number of *its own* steps once scheduled (helpers finished its request),
while a merely lock-free design lets the victim's retry count grow with
the interference it observes."""

import pytest

from repro.core import AtomicMemory, QUEUE_CLASSES, Scheduler
from repro.core.base import VAL_MASK
from repro.core.sim import DEQ, ENQ


class StarvingScheduler(Scheduler):
    """Runs the victim (tid 0) only once every ``starve`` steps; everyone
    else round-robins."""

    def __init__(self, *args, starve: int = 64, **kw):
        super().__init__(*args, **kw)
        self.starve = starve
        self._last_victim = 0

    def _pick(self):
        live = self.runnable()
        if not live:
            return None
        victim = next((t for t in live if t.tid == 0), None)
        others = [t for t in live if t.tid != 0]
        due = self.step_count - self._last_victim >= self.starve
        if victim is not None and (due or not others):
            self._last_victim = self.step_count
            return victim
        if others:
            return others[self.step_count % len(others)]
        return victim


def _run_starved(name: str, kw, ops: int = 30, starve: int = 64):
    q = QUEUE_CLASSES[name](capacity=64, num_threads=8, **kw)
    mem = AtomicMemory()
    q.init(mem)
    sched = StarvingScheduler(mem, wave_size=8, policy="rr", starve=starve)
    done = {"victim": False}

    def victim(ctx, tid):
        for k in range(ops):
            v = (1 << 20) | k
            yield from ctx.op_begin(ENQ, v)
            ok = yield from q.enqueue(ctx, tid, v)
            yield from ctx.op_end(ok, ok)
            yield from ctx.op_begin(DEQ, None)
            ok, _ = yield from q.dequeue(ctx, tid)
            yield from ctx.op_end(None, ok)
        done["victim"] = True

    def antagonist(ctx, tid):
        k = 0
        while not done["victim"]:
            v = ((tid << 16) | (k & 0xFFFF)) & VAL_MASK
            yield from ctx.op_begin(ENQ, v)
            ok = yield from q.enqueue(ctx, tid, v)
            yield from ctx.op_end(ok, ok)
            yield from ctx.op_begin(DEQ, None)
            ok, _ = yield from q.dequeue(ctx, tid)
            yield from ctx.op_end(None, ok)
            k += 1

    sched.spawn(victim)
    for _ in range(7):
        sched.spawn(antagonist)
    sched.run(3_000_000)
    vic = sched.threads[0]
    return done["victim"], vic.steps / max(ops * 2, 1)


@pytest.mark.parametrize("name,kw", [
    ("gwfq", dict(patience=4, help_delay=8)),
    ("gwfq-ymc", dict(patience=4, help_delay=8)),
])
def test_wait_free_starved_victim_completes(name, kw):
    """The wait-free designs must let a 64×-starved victim finish: after
    patience, its published request is completed by helpers (Theorem III.10
    under the residency/fairness assumption), in bounded own-steps."""
    finished, steps_per_op = _run_starved(name, kw)
    assert finished, f"{name}: starved victim never completed"
    assert steps_per_op < 400, f"{name}: victim steps/op {steps_per_op:.0f}"


def test_lock_free_victim_starves():
    """The separation the paper is about, demonstrated: G-LFQ is lock-free
    (Theorem III.4) but NOT wait-free — under systematic starvation its
    victim's tickets are always stale by the time it re-reads the slot, so
    it retries forever while the system as a whole keeps completing ops.
    (This test documents expected behavior; if it ever "fails" because the
    victim finished, the scheduler has become too gentle.)"""
    finished, steps_per_op = _run_starved("glfq", {}, ops=10)
    assert not finished, "starved G-LFQ victim unexpectedly completed"
    assert steps_per_op > 400  # unbounded retries, no helping
