"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated against
(tests sweep shapes/dtypes and assert_allclose kernel-vs-ref).  They are also
used directly by the pure-JAX fallback paths on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wavefaa_ref(active: jax.Array, counter: jax.Array):
    """Wave-batched ticket reservation (paper Alg. 1 WAVEFAA, Lemma III.1).

    active : (N,) int32/bool — the ballot mask (1 = lane requests a ticket)
    counter: (1,)  int32     — the shared FAA counter

    Returns (tickets, new_counter): tickets[i] = counter + (exclusive prefix
    popcount of active up to lane i) for active lanes, -1 for inactive lanes;
    new_counter = counter + popcount(active).  This is exactly the ticket
    order per-thread FAA would produce (observational equivalence).
    """
    a = active.astype(jnp.int32)
    rank = jnp.cumsum(a) - a  # exclusive prefix rank within the mask
    tickets = jnp.where(a > 0, counter[0] + rank, -1).astype(jnp.int32)
    return tickets, counter + jnp.sum(a, dtype=jnp.int32)


def ring_enqueue_ref(cycles, safes, enqs, idxs, tickets, values, head,
                     nslots_log2: int, idx_bot: int):
    """Batched G-LFQ fast-path installs (paper Alg. 1 TRYENQ, lines 15-24).

    The ring state is four parallel int32 field arrays (cycle, safe, enq,
    idx) of length 2n = 1 << nslots_log2.  ``tickets`` is a batch of unique
    tickets (wavefaa output; -1 = inactive).  Installs are applied in ticket
    order — the linearization order.  Returns updated fields + success mask.
    """
    nslots = 1 << nslots_log2
    idx_botc = idx_bot - 1

    def body(state, tv):
        cyc, saf, enq, idx = state
        t, v = tv
        j = jnp.where(t >= 0, t & (nslots - 1), 0)
        c = jnp.where(t >= 0, jax.lax.shift_right_logical(t, nslots_log2), 0)
        e_c, e_s, e_i = cyc[j], saf[j], idx[j]
        empty = (e_i == idx_bot) | (e_i == idx_botc)
        # wrap-safe comparisons (cycle-modulus difference), like ring_slots
        can = (t >= 0) & (((c - e_c) << nslots_log2) > 0) & empty & (
            (e_s == 1) | ((t - head[0]) >= 0))
        cyc = cyc.at[j].set(jnp.where(can, c, cyc[j]))
        saf = saf.at[j].set(jnp.where(can, 1, saf[j]))
        enq = enq.at[j].set(jnp.where(can, 1, enq[j]))
        idx = idx.at[j].set(jnp.where(can, v, idx[j]))
        return (cyc, saf, enq, idx), can

    (cycles, safes, enqs, idxs), ok = jax.lax.scan(
        body, (cycles, safes, enqs, idxs), (tickets, values))
    return cycles, safes, enqs, idxs, ok


def ring_dequeue_ref(cycles, safes, enqs, idxs, tickets,
                     nslots_log2: int, idx_bot: int):
    """Batched G-LFQ fast-path consumes (paper Alg. 1 TRYDEQ match branch):
    for each ticket, if the slot's cycle matches and holds a visible value,
    CONSUME it (index := ⊥_c); non-matching empty slots are ⊥-advanced.
    Returns updated fields, dequeued values (-1 on miss), success mask."""
    nslots = 1 << nslots_log2
    idx_botc = idx_bot - 1

    def body(state, t):
        cyc, saf, enq, idx = state
        j = jnp.where(t >= 0, t & (nslots - 1), 0)
        c = jnp.where(t >= 0, jax.lax.shift_right_logical(t, nslots_log2), 0)
        e_c, e_i, e_e = cyc[j], idx[j], enq[j]
        empty = (e_i == idx_bot) | (e_i == idx_botc)
        hit = (t >= 0) & (e_c == c) & (~empty) & (e_e == 1)
        # consume
        idx = idx.at[j].set(jnp.where(hit, idx_botc, e_i))
        # ⊥-advance stale empty slots (neutralize); wrap-safe compare
        adv = (t >= 0) & (~hit) & empty & (((c - e_c) << nslots_log2) > 0)
        cyc = cyc.at[j].set(jnp.where(adv, c, cyc[j]))
        # mark stale live slots unsafe
        uns = (t >= 0) & (~hit) & (~empty) & (((c - e_c) << nslots_log2) > 0)
        saf = saf.at[j].set(jnp.where(uns, 0, saf[j]))
        val = jnp.where(hit, e_i, -1)
        return (cyc, saf, enq, idx), (val, hit)

    (cycles, safes, enqs, idxs), (vals, ok) = jax.lax.scan(
        body, (cycles, safes, enqs, idxs), tickets)
    return cycles, safes, enqs, idxs, vals, ok


def frontier_expand_ref(row_ptr, col_idx, frontier, frontier_len, visited,
                        max_out: int):
    """Level-synchronous BFS frontier expansion (paper § V-B-a).

    For every vertex in the frontier (padded with -1), scan its CSR
    neighbors; unvisited neighbors are marked and enqueued into the next
    frontier with queue-style ticket reservation (aggregate-then-commit —
    each accepted neighbor takes ticket = running popcount).  Returns
    (next_frontier (max_out, padded -1), next_len, visited')."""
    n = visited.shape[0]

    def vbody(state, u):
        visited, out, cnt = state

        def ebody(k, st):
            visited, out, cnt = st
            v = col_idx[k]
            fresh = visited[v] == 0
            visited = visited.at[v].set(1)
            out = out.at[jnp.where(fresh, cnt, max_out - 1)].set(
                jnp.where(fresh, v, out[jnp.minimum(cnt, max_out - 1)]))
            cnt = cnt + fresh.astype(jnp.int32)
            return visited, out, cnt

        valid = u >= 0
        start = jnp.where(valid, row_ptr[jnp.maximum(u, 0)], 0)
        stop = jnp.where(valid, row_ptr[jnp.maximum(u, 0) + 1], 0)
        visited, out, cnt = jax.lax.fori_loop(start, stop, ebody,
                                              (visited, out, cnt))
        return (visited, out, cnt), None

    out0 = jnp.full((max_out,), -1, dtype=jnp.int32)
    (visited, out, cnt), _ = jax.lax.scan(
        vbody, (visited, out0, jnp.int32(0)), frontier)
    return out, cnt, visited


def moe_route_ref(gates: jax.Array, k: int, capacity: int):
    """Capacity-bounded top-k MoE dispatch via per-expert ticket reservation.

    gates: (T, E) router logits.  Each token claims a ring ticket in each of
    its top-k experts; tokens beyond an expert's capacity are dropped (the
    RETRY path of the bounded ring).  Ticket order = token order, exactly
    what a per-token FAA on the expert's Tail would produce.

    Returns (dispatch (T, k) slot-or--1, expert_idx (T, k), combine (T, k)).
    """
    T, E = gates.shape
    top_g, top_e = jax.lax.top_k(gates, k)          # (T, k)
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(T * k, E)
    ranks = jnp.cumsum(flat, axis=0) - flat          # exclusive prefix per expert
    slot = jnp.sum(ranks * flat, axis=-1).reshape(T, k)
    ok = slot < capacity
    dispatch = jnp.where(ok, slot, -1)
    probs = jax.nn.softmax(top_g, axis=-1)
    combine = jnp.where(ok, probs, 0.0)
    return dispatch, top_e, combine


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap_val=0.0):
    """Oracle for kernels.flash_attn: plain masked softmax attention.
    q (B,H,Sq,hd); k/v (B,KV,Sk,hd)."""
    b, h, sq, hd = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    rep = h // kvh
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) / (hd ** 0.5)
    if softcap_val:
        s = softcap_val * jnp.tanh(s / softcap_val)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok = ok & (kpos <= qpos)
    if window:
        ok = ok & (kpos > qpos - window)
    s = jnp.where(ok[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v)
