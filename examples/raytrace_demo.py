"""Tile-based wavefront ray tracing with per-tile queues (paper § V-B-b) vs
stream compaction; writes out.ppm of the queue-rendered image.

    PYTHONPATH=src python examples/raytrace_demo.py
"""

import numpy as np

from repro.apps.raytrace import complex_scene, render_compaction, render_queue

scene = complex_scene()
img_q, mq = render_queue(scene, 96, 96, 4, 4)
img_c, mc = render_compaction(scene, 96, 96)
print(f"queue: {mq['rays']} rays in {mq['waves']} waves; "
      f"compaction: {mc['rays']} rays; images match: "
      f"{np.allclose(img_q, img_c, atol=1e-4)}")
with open("out.ppm", "wb") as f:
    f.write(b"P6\n96 96\n255\n")
    f.write((np.clip(img_q, 0, 1) * 255).astype(np.uint8).tobytes())
print("wrote out.ppm")
