"""Scheduler wave semantics: SIMT reconvergence batches WAVEFAA (Fig. 1),
and batching changes only the atomic count — never the ticket order
(Lemma III.1's observational equivalence, measured end to end)."""

from repro.core import AtomicMemory, QUEUE_CLASSES, Scheduler
from repro.core.base import VAL_MASK
from repro.core.sim import DEQ, ENQ


def _run_balanced(policy: str, threads: int = 64, steps: int = 60_000):
    q = QUEUE_CLASSES["glfq"](capacity=128, num_threads=threads)
    mem = AtomicMemory()
    q.init(mem)
    sched = Scheduler(mem, wave_size=8, policy=policy, seed=0)

    def worker(ctx, tid):
        k = 0
        while True:
            v = ((tid << 16) | (k & 0xFFFF)) & VAL_MASK
            yield from ctx.op_begin(ENQ, v)
            ok = yield from q.enqueue(ctx, tid, v)
            yield from ctx.op_end(ok, ok)
            yield from ctx.op_begin(DEQ, None)
            ok, o = yield from q.dequeue(ctx, tid)
            yield from ctx.op_end(o if ok else None, ok)
            k += 1

    for _ in range(threads):
        sched.spawn(worker)
    sched.run(steps)
    m = sched.metrics()
    hot = (mem.rmw_traffic.get("glfq_tail", 0)
           + mem.rmw_traffic.get("glfq_head", 0))
    return hot / max(m["successful_ops"], 1), sched


def test_wave_batching_reduces_hot_rmws():
    """Gang scheduling (reconvergent waves) must get within 2× of the ideal
    1/wave_size hot-word RMWs per op; random scheduling must not."""
    gang, _ = _run_balanced("gang")
    rand, _ = _run_balanced("random")
    assert gang < 0.25, f"gang batching ineffective: {gang:.3f} RMWs/op"
    assert rand > 2 * gang, f"no batching advantage: {gang:.3f} vs {rand:.3f}"


def test_batched_runs_stay_linearizable():
    """Lemma III.1 end-to-end: maximal batching must not perturb queue
    semantics."""
    from repro.core import check_linearizable, run_producer_consumer
    q = QUEUE_CLASSES["glfq"](capacity=16, num_threads=8)
    sched, _, rep = run_producer_consumer(
        q, producers=4, consumers=4, ops_per_producer=15,
        policy="gang", seed=3)
    assert rep.ok, rep.reason
    assert check_linearizable(sched.history).ok


def test_wavefaa_defer_cannot_deadlock():
    """A permanently diverged lane (never calls WAVEFAA) must not stall its
    wave: the defer budget forces progress."""
    mem = AtomicMemory()
    mem.alloc("ctr", 1)
    sched = Scheduler(mem, wave_size=4, policy="gang", seed=0)
    got = []

    def spinner(ctx, tid):
        while True:
            yield from ctx.step()

    def claimer(ctx, tid):
        t = yield from ctx.wavefaa("ctr", 0)
        got.append(t)

    sched.spawn(spinner)
    for _ in range(3):
        sched.spawn(claimer)
    sched.run(5_000)
    assert sorted(got) == [0, 1, 2]
