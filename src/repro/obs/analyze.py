"""Trace analysis: timelines and rank-error measurement (DESIGN.md § 7.4).

The payoff of the trace planes: the relaxed mesh engines *declare* a
worst-case rank-error envelope (``sched.relaxed.mesh_relaxation_bound``,
the paper's k-relaxation bound specialised to the shard/batch geometry)
— this module *measures* the error an actual run incurred and compares.

Two measurement levels:

* :func:`measured_rank_error` — exact, from a legacy-engine pop trace
  (``PriorityMeshRoundRunner(trace=True, fused=False)``): a pop's rank
  error is the number of strictly smaller keys popped in later rounds
  (items it "jumped over"); the run's error is the max over pops.
* :func:`key_inversions` — a proxy computable from the fused engines'
  drained planes alone (no per-item history): the worst inversion depth
  ``max_key[r] − min_key[r']`` over round pairs ``r < r'`` where an
  earlier round popped a key larger than a later round's minimum.  Zero
  inversions ⇒ zero rank error; the proxy is in key units, not ranks, so
  it bounds *which rounds* violated order, not by how many items.

:func:`rank_error_vs_envelope` packages either measurement against the
declared bound for export/plotting (the acceptance artifact of PR 6).

The span layer (DESIGN.md § 7.6) adds the *latency* face of the same
question: :func:`sojourn_percentiles` reads p50/p95/p99 sojourn out of an
exported ``Spans.summary()`` histogram, :func:`max_wait_highwater` names
the worst-served class, and :func:`starvation_flags` turns the per-class
max-wait high-waters into starvation verdicts — cross-checkable against
the sim fabric's host-side ``wait_stats()`` accounting.
"""

from __future__ import annotations

import bisect
import heapq
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .trace import KEY_SENTINEL, RoundRecord

__all__ = [
    "imbalance_timeline", "key_inversions", "max_wait_highwater",
    "measured_rank_error", "occupancy_timeline", "rank_error_vs_envelope",
    "sojourn_percentiles", "starvation_flags",
]


def occupancy_timeline(records: Sequence[RoundRecord]
                       ) -> List[Tuple[int, List[int]]]:
    """``[(round, [per-shard occupancy])]`` in round order."""
    return [(r.round, list(r.occupancy))
            for r in sorted(records, key=lambda r: r.round)]


def imbalance_timeline(records: Sequence[RoundRecord]
                       ) -> List[Tuple[int, int]]:
    """``[(round, claim imbalance)]`` in round order (max − min per-shard
    pops; the claim_schedule fairness signal)."""
    return [(r.round, r.imbalance)
            for r in sorted(records, key=lambda r: r.round)]


def measured_rank_error(history: Sequence[Sequence[int]],
                        inserts: Optional[Sequence[Sequence[int]]] = None
                        ) -> int:
    """Exact rank error from a per-round pop-key history
    (``history[r]`` = keys popped in round ``r``; the shape a
    ``PriorityMeshRoundRunner(trace=True)`` recording flattens to).  A
    pop of key ``k`` in round ``r`` has rank error = number of *queued*
    keys strictly smaller than ``k`` it overtook — smaller keys popped in
    rounds > ``r`` that were already inserted before round ``r``.
    Returns the max over all pops — directly comparable to the declared
    k-relaxation bound.

    ``inserts[r]`` = keys published in round ``r`` (visible to pops of
    rounds > ``r``); pops with no matching insert are seeds, present from
    the start.  Without ``inserts`` every key is treated as present from
    round 0 — an *upper bound* that also charges a pop for smaller keys
    that did not exist yet (spawn-tree workloads can generate children
    smaller than long-popped parents; only pass ``inserts=None`` when
    keys are monotone over spawn edges, e.g. delta-stepping buckets)."""
    # match each pop to its insert round: FIFO per key value (equal keys
    # are interchangeable), unmatched pops are seeds (round -1)
    ins_q: Dict[int, List[int]] = {}
    ins_pos: Dict[int, int] = {}
    if inserts is not None:
        for r, keys in enumerate(inserts):
            for k in keys:
                ins_q.setdefault(k, []).append(r)
    pops: List[Tuple[int, int, int]] = []        # (round, key, insert round)
    for r, keys in enumerate(history):
        for k in keys:
            q = ins_q.get(k)
            p = ins_pos.get(k, 0)
            ins = -1
            if q is not None and p < len(q):
                ins, ins_pos[k] = q[p], p + 1
            pops.append((r, k, ins))
    # backward over rounds: ``active`` holds the sorted keys of pops from
    # later rounds still eligible at the current round (insert < r); as r
    # decreases, late-inserted items retire from eligibility exactly once
    worst = 0
    by_round: Dict[int, List[Tuple[int, int, int]]] = {}
    for p in pops:
        by_round.setdefault(p[0], []).append(p)
    active: List[int] = []                       # sorted keys, ins < r
    retire: List[Tuple[int, int]] = []           # (-ins, key) heap order
    for r in sorted(by_round, reverse=True):
        while retire and -retire[0][0] >= r:
            _, k = heapq.heappop(retire)
            del active[bisect.bisect_left(active, k)]
        for _, k, _ in by_round[r]:
            worst = max(worst, bisect.bisect_left(active, k))
        for _, k, ins in by_round[r]:
            bisect.insort(active, k)
            heapq.heappush(retire, (-ins, k))
    return worst


def key_inversions(records: Sequence[RoundRecord]
                   ) -> List[Dict[str, int]]:
    """Plane-level inversion proxy: rounds whose max popped key exceeds a
    *later* round's min popped key (order violation visible from extrema
    alone).  Returns ``[{round, later_round, depth}]`` with ``depth`` in
    key units; empty list ⇒ the trace is consistent with zero rank
    error."""
    recs = [r for r in sorted(records, key=lambda r: r.round)
            if r.min_key != KEY_SENTINEL]    # skip empty rounds
    out: List[Dict[str, int]] = []
    # running max of max_key over earlier rounds; report each later round
    # whose min undercuts it
    best_round, run_max = -1, -KEY_SENTINEL
    for r in recs:
        if r.min_key < run_max:
            out.append({"round": r.round, "later_round": best_round,
                        "depth": run_max - r.min_key})
        if r.max_key > run_max:
            run_max, best_round = r.max_key, r.round
    # normalise field names: "round" = the earlier offender, "later_round"
    # = where the smaller key surfaced
    for o in out:
        o["round"], o["later_round"] = o["later_round"], o["round"]
    return out


def rank_error_vs_envelope(envelope: int, *,
                           history: Optional[Sequence[Sequence[int]]] = None,
                           inserts: Optional[Sequence[Sequence[int]]] = None,
                           records: Optional[Sequence[RoundRecord]] = None
                           ) -> Dict[str, Any]:
    """Measured rank error against the declared ``mesh_relaxation_bound``
    envelope.  Pass ``history`` (exact, legacy trace; ``inserts`` refines
    it — see :func:`measured_rank_error`) and/or ``records`` (fused-plane
    inversion proxy); the result is export-ready."""
    out: Dict[str, Any] = {"envelope": int(envelope)}
    if history is not None:
        err = measured_rank_error(history, inserts)
        out["measured_rank_error"] = err
        out["within_envelope"] = err <= envelope
        out["slack"] = int(envelope) - err
    if records is not None:
        inv = key_inversions(records)
        out["key_inversions"] = len(inv)
        out["max_inversion_depth"] = max((i["depth"] for i in inv),
                                         default=0)
    if history is None and records is None:
        raise ValueError("need history and/or records to measure")
    return out


# ---------------------------------------------------------------------------
# span / sojourn analysis (DESIGN.md § 7.6)
# ---------------------------------------------------------------------------


def sojourn_percentiles(summary: Dict[str, Any],
                        qs: Sequence[float] = (0.5, 0.95, 0.99),
                        cls: Optional[int] = None) -> Dict[str, Optional[int]]:
    """Sojourn percentiles (in rounds) from an exported ``Spans.summary()``
    dict — the host twin of ``Spans.percentile`` for post-hoc analysis of
    a jsonl "hist" record.  Log2 buckets resolve to their *upper* edge
    (pessimistic: the reported pNN never understates the true quantile).
    ``cls`` restricts to one histogram row; default aggregates all
    classes.  Empty histograms yield ``None`` per quantile."""
    hist = summary["hist"]
    edges = summary["bucket_edges"]
    rows = [hist[cls]] if cls is not None else list(hist)
    agg = [sum(col) for col in zip(*rows)] if rows else []
    total = sum(agg)
    out: Dict[str, Optional[int]] = {}
    for q in qs:
        name = f"p{round(q * 100)}"
        if total == 0:
            out[name] = None
            continue
        target, c = q * total, 0
        for b, n in enumerate(agg):
            c += n
            if c >= target:
                out[name] = int(edges[b])
                break
    return out


def max_wait_highwater(summary: Dict[str, Any]) -> Dict[str, Any]:
    """Per-class max-wait high-water from ``Spans.summary()``: the device
    scatter-max kept the worst sojourn each class ever saw; this names the
    worst-served class (ties → lowest class index)."""
    mw = [int(w) for w in summary["max_wait"]]
    worst = max(range(len(mw)), key=lambda c: mw[c]) if mw else None
    return {"per_class": mw, "worst_class": worst,
            "high_water": max(mw, default=0)}


def starvation_flags(summary: Dict[str, Any], *, factor: float = 8.0,
                     wait_stats: Optional[Dict[str, float]] = None
                     ) -> Dict[str, Any]:
    """Starvation verdicts from the span histograms: a class is flagged
    when its max-wait high-water exceeds ``factor`` × the all-class median
    sojourn — some class waits far beyond typical service while the
    engine keeps processing.  With ``wait_stats`` (a fabric
    ``wait_stats()`` dict from the sim runtime, DESIGN.md § 5.4) the
    device-side verdict is cross-checked against the host-side
    accounting: both sides classify class 0 as urgent and classes ≥ 1 as
    normal, and ``fabric["agrees"]`` reports whether they point the same
    way on *which lane waits longer* — the scales differ (scheduler steps
    vs engine rounds), so only the direction is comparable."""
    p50 = sojourn_percentiles(summary, qs=(0.5,))["p50"]
    mw = [int(w) for w in summary["max_wait"]]
    threshold = factor * max(p50 or 0, 1)
    flags = [w > threshold for w in mw]
    out: Dict[str, Any] = {
        "p50": p50, "factor": factor, "threshold": threshold,
        "per_class": [{"cls": c, "max_wait": w, "starved": bool(f)}
                      for c, (w, f) in enumerate(zip(mw, flags))],
        "starved_classes": [c for c, f in enumerate(flags) if f],
    }
    if wait_stats is not None:
        span_urgent = mw[0] if mw else 0
        span_normal = max(mw[1:], default=0)
        fab_urgent = float(wait_stats.get("urgent_max_wait", 0.0))
        fab_normal = float(wait_stats.get("normal_max_wait", 0.0))
        out["fabric"] = {
            "urgent_max_wait": fab_urgent, "normal_max_wait": fab_normal,
            "span_urgent_max": span_urgent, "span_normal_max": span_normal,
            "agrees": (span_normal >= span_urgent)
                      == (fab_normal >= fab_urgent),
        }
    return out
