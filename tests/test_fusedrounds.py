"""Fused round engine invariants (DESIGN.md § 4.3):

* the fused megaround loop is bit-identical to the legacy per-round loop —
  same acc, same field planes, same head/tail / heap size, same stats
  counters — on tree, BFS, and raytrace workloads;
* the fused path syncs the host once at quiescence (``sync_every`` gives a
  periodic heartbeat), where the legacy path syncs every round;
* overflow (ring and heap) and ``max_rounds`` truncation raise
  ``RuntimeError`` from both engines — truncation cannot be mistaken for
  quiescence;
* ``wavefaa`` edge cases: all-inactive mask and the multi-block SMEM
  carry of the in-loop ticket source;
* ``REPRO_PALLAS_INTERPRET`` resolves interpret/compiled mode for every
  kernel entry point without a code change.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.runtime import PriorityRoundRunner, RoundRunner  # noqa: E402

STAT_KEYS = ("rounds", "processed", "spawned", "max_occupancy", "drained")


def _tree_step():
    def step(acc, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        cm = (valid & (vals < 32))[:, None]
        return acc, cv, cm
    return step


def _run_pair(**kw):
    accs, states, stats = [], [], []
    for fused in (True, False):
        r = RoundRunner(_tree_step(), capacity_log2=8, batch=16,
                        fused=fused, **kw)
        acc, st = r.run([1], acc=jnp.zeros(80, jnp.int32))
        accs.append(np.asarray(acc))
        states.append(st)
        stats.append(r.stats)
    return accs, states, stats


def test_fused_matches_legacy_tree():
    accs, states, stats = _run_pair()
    np.testing.assert_array_equal(accs[0], accs[1])
    for a, b in zip(states[0][:4], states[1][:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (states[0].head, states[0].tail) == (states[1].head,
                                                states[1].tail)
    for k in STAT_KEYS:
        assert stats[0][k] == stats[1][k], k
    # the headline: host sync only at quiescence vs every round
    assert stats[0]["host_syncs"] == 1
    assert stats[1]["host_syncs"] > stats[1]["rounds"]


def test_fused_sync_every_heartbeat():
    r = RoundRunner(_tree_step(), capacity_log2=8, batch=16, sync_every=2)
    acc, _ = r.run([1], acc=jnp.zeros(80, jnp.int32))
    full = RoundRunner(_tree_step(), capacity_log2=8, batch=16)
    acc2, _ = full.run([1], acc=jnp.zeros(80, jnp.int32))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(acc2))
    assert r.stats["host_syncs"] > 1
    assert r.sync_log[-1]["occupancy"] == 0
    assert [e["rounds"] for e in r.sync_log] == \
        sorted(e["rounds"] for e in r.sync_log)


def test_fused_bfs_bit_identical_and_exact():
    from repro.apps import bfs
    for g in (bfs.kron_like(300, avg_deg=6, seed=2), bfs.road_like(256)):
        ref = bfs.bfs_reference(g, 0)
        dist_f, stats_f = bfs.bfs_rounds(g, 0, batch=32, fused=True)
        dist_l, stats_l = bfs.bfs_rounds(g, 0, batch=32, fused=False)
        np.testing.assert_array_equal(dist_f, ref)
        np.testing.assert_array_equal(dist_l, ref)
        for k in STAT_KEYS:
            assert stats_f[k] == stats_l[k], (g.name, k)
        assert stats_f["host_syncs"] < stats_l["host_syncs"]


def test_fused_raytrace_bit_identical_to_legacy_and_queue():
    from repro.apps import raytrace
    scene = raytrace.cornell_scene()
    img_q, _ = raytrace.render_queue(scene, w=16, h=16)
    img_f, info_f = raytrace.render_rounds(scene, w=16, h=16, batch=64,
                                           fused=True)
    img_l, info_l = raytrace.render_rounds(scene, w=16, h=16, batch=64,
                                           fused=False)
    np.testing.assert_array_equal(img_f, img_l)          # bit-identical
    np.testing.assert_allclose(img_f, img_q, rtol=1e-5, atol=1e-5)
    assert info_f["rays"] == info_l["rays"] > 0
    assert info_f["host_syncs"] == 1


def _pri_step():
    def step(acc, keys, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        ck = jnp.stack([keys + 1, keys + 2], -1).astype(jnp.int32)
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        cm = (valid & (vals < 32))[:, None]
        return acc, ck, cv, cm
    return step


def test_fused_priority_matches_legacy():
    accs, sizes, stats = [], [], []
    for fused in (True, False):
        r = PriorityRoundRunner(_pri_step(), capacity_log2=8, batch=16,
                                fused=fused)
        acc, st = r.run([5], [1], acc=jnp.zeros(80, jnp.int32))
        accs.append(np.asarray(acc))
        sizes.append(st.size)
        stats.append(r.stats)
        if fused:
            keys_f, vals_f = np.asarray(st.keys), np.asarray(st.vals)
        else:
            np.testing.assert_array_equal(keys_f, np.asarray(st.keys))
            np.testing.assert_array_equal(vals_f, np.asarray(st.vals))
    np.testing.assert_array_equal(accs[0], accs[1])
    assert sizes[0] == sizes[1]
    for k in STAT_KEYS:
        assert stats[0][k] == stats[1][k], k
    assert stats[0]["host_syncs"] == 1 < stats[1]["host_syncs"]


# -- error paths --------------------------------------------------------------


def _explode_step():
    def step(acc, vals, valid):
        cv = jnp.broadcast_to(vals[:, None], (vals.shape[0], 4)) + 1
        cm = jnp.broadcast_to(valid[:, None], cv.shape)
        return acc, cv.astype(jnp.int32), cm
    return step


@pytest.mark.parametrize("fused", [True, False])
def test_ring_overflow_raises(fused):
    r = RoundRunner(_explode_step(), capacity_log2=4, batch=8, fused=fused)
    with pytest.raises(RuntimeError, match="ring overflow"):
        r.run(np.arange(8), acc=jnp.int32(0), max_rounds=100)


@pytest.mark.parametrize("fused", [True, False])
def test_ring_seed_overflow_raises(fused):
    r = RoundRunner(_tree_step(), capacity_log2=4, batch=8, fused=fused)
    with pytest.raises(RuntimeError, match="ring overflow"):
        r.run(np.arange(64), acc=jnp.zeros(80, jnp.int32))


@pytest.mark.parametrize("fused", [True, False])
def test_failed_run_does_not_keep_stale_stats(fused):
    """A run that dies before its first sync must not republish the
    previous successful run's stats."""
    r = RoundRunner(_tree_step(), capacity_log2=4, batch=8, fused=fused)
    r.run([40], acc=jnp.zeros(80, jnp.int32))          # drains instantly
    assert r.stats["drained"] == 1
    with pytest.raises(RuntimeError, match="ring overflow"):
        r.run(np.arange(64), acc=jnp.zeros(80, jnp.int32))
    assert "drained" not in r.stats                    # reset, not stale


def _pri_explode_step():
    def step(acc, keys, vals, valid):
        ck = jnp.broadcast_to(keys[:, None], (keys.shape[0], 4)) + 1
        cv = jnp.broadcast_to(vals[:, None], ck.shape) + 1
        cm = jnp.broadcast_to(valid[:, None], ck.shape)
        return acc, ck.astype(jnp.int32), cv.astype(jnp.int32), cm
    return step


@pytest.mark.parametrize("fused", [True, False])
def test_heap_overflow_raises(fused):
    r = PriorityRoundRunner(_pri_explode_step(), capacity_log2=4, batch=8,
                            fused=fused)
    with pytest.raises(RuntimeError, match="heap overflow"):
        r.run(np.arange(8), np.arange(8), acc=jnp.int32(0), max_rounds=100)


def _immortal_step():
    def step(acc, vals, valid):
        return acc, vals[:, None], valid[:, None]     # every task respawns
    return step


@pytest.mark.parametrize("fused", [True, False])
def test_max_rounds_truncation_raises(fused):
    r = RoundRunner(_immortal_step(), capacity_log2=6, batch=8, fused=fused)
    with pytest.raises(RuntimeError, match="not quiescent"):
        r.run([1, 2, 3], acc=jnp.int32(0), max_rounds=5)
    assert r.stats["drained"] == 0
    assert r.stats["rounds"] == 5


def _pri_immortal_step():
    def step(acc, keys, vals, valid):
        return acc, keys[:, None], vals[:, None], valid[:, None]
    return step


@pytest.mark.parametrize("fused", [True, False])
def test_priority_max_rounds_truncation_raises(fused):
    r = PriorityRoundRunner(_pri_immortal_step(), capacity_log2=6, batch=8,
                            fused=fused)
    with pytest.raises(RuntimeError, match="not quiescent"):
        r.run([1, 2], [1, 2], acc=jnp.int32(0), max_rounds=5)
    assert r.stats["drained"] == 0


# -- wavefaa edge cases -------------------------------------------------------


def test_wavefaa_all_inactive():
    from repro.kernels import wavefaa
    tickets, newctr = wavefaa(jnp.zeros(2048, jnp.int32),
                              jnp.array([123], jnp.int32))
    assert int(newctr[0]) == 123                       # counter untouched
    assert (np.asarray(tickets) == -1).all()


def test_wavefaa_multiblock_smem_carry():
    """The SMEM accumulator must carry the running count across grid
    blocks: lane ranks in block k start at the popcount of blocks < k."""
    from repro.kernels import LANES, wavefaa
    blocks = 3
    active = np.zeros(blocks * LANES, np.int32)
    active[5] = active[LANES + 7] = active[2 * LANES + 11] = 1
    active[LANES - 1] = 1                              # block-boundary lane
    tickets, newctr = wavefaa(jnp.asarray(active), jnp.array([50], jnp.int32))
    t = np.asarray(tickets)
    got = t[active > 0]
    np.testing.assert_array_equal(np.sort(got), np.arange(50, 54))
    assert int(newctr[0]) == 54
    assert t[5] == 50 and t[LANES - 1] == 51           # in-lane order
    assert t[LANES + 7] == 52 and t[2 * LANES + 11] == 53
    assert (t[active == 0] == -1).all()


# -- REPRO_PALLAS_INTERPRET override ------------------------------------------


def test_env_interpret_override(monkeypatch):
    from repro.kernels.pallas_env import env_interpret, resolve_interpret
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert env_interpret() is None
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert env_interpret() is True
    assert resolve_interpret(None) is True
    assert resolve_interpret(False) is False           # explicit flag wins
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "compiled")
    assert env_interpret() is False
    assert resolve_interpret(None) is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "banana")
    with pytest.raises(ValueError, match="REPRO_PALLAS_INTERPRET"):
        env_interpret()


def test_env_interpret_reaches_kernels(monkeypatch):
    """With the env forcing interpret mode on CPU, every entry point still
    routes and agrees with the oracle — the flag is plumbed end to end."""
    from repro.kernels import ref, ring_enqueue
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "interpret")
    nsl2, bot = 5, (1 << 31) - 1
    nslots = 1 << nsl2
    cyc = jnp.zeros(nslots, jnp.int32)
    saf = jnp.ones(nslots, jnp.int32)
    enq = jnp.zeros(nslots, jnp.int32)
    idx = jnp.full(nslots, bot, jnp.int32)
    tickets = jnp.arange(nslots, nslots + 8, dtype=jnp.int32)
    values = jnp.arange(8, dtype=jnp.int32)
    head = jnp.array([nslots], jnp.int32)
    out = ring_enqueue(cyc, saf, enq, idx, tickets, values, head,
                       nslots_log2=nsl2, idx_bot=bot)
    want = ref.ring_enqueue_ref(cyc, saf, enq, idx, tickets, values, head,
                                nsl2, bot)
    for a, b in zip(out, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- bench acceptance ---------------------------------------------------------


def test_bench_rounds_smoke_parity():
    """The CI gate: fused/legacy bit-parity on fanout + BFS workloads."""
    import io
    from benchmarks.bench_rounds import smoke
    buf = io.StringIO()
    assert smoke(buf), buf.getvalue()
