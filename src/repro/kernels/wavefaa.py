"""WAVEFAA as a Pallas TPU kernel — vectorized aggregate-then-commit ticket
reservation (paper Alg. 1 / Fig. 1, adapted per DESIGN.md § 2.1).

On the GPU a wavefront ballots, one leader FAAs by the popcount, and lanes
add their prefix rank.  On TPU the "wave" is a VMEM-resident block of request
lanes: the kernel computes the in-block exclusive prefix rank on the VREG
lane grid and commits **one** scalar counter update per block into an SMEM
accumulator that carries across the (sequential) TPU grid — the same
aggregation hierarchy, one level up.

Block shape: (8, 128) int32 lanes per grid step — one VREG tile.  The mask
is reshaped (N,) → (N/1024, 8, 128) by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_env import resolve_interpret

LANES = 8 * 128  # one (8, 128) VREG tile per grid step


def _wavefaa_kernel(counter_ref, active_ref, tickets_ref, newctr_ref, acc_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        acc_ref[0] = counter_ref[0]

    a = active_ref[...].astype(jnp.int32)           # (8, 128) block
    flat = a.reshape(1, LANES)
    rank = jnp.cumsum(flat, axis=1) - flat          # exclusive prefix rank
    base = acc_ref[0]
    t = jnp.where(flat > 0, base + rank, -1)
    tickets_ref[...] = t.reshape(a.shape)
    # ONE commit per block — the leader FAA of Alg. 1
    acc_ref[0] = base + jnp.sum(a)

    @pl.when(step == pl.num_programs(0) - 1)
    def _fin():
        newctr_ref[0] = acc_ref[0]


def wavefaa(active: jax.Array, counter: jax.Array, *, interpret=None):
    """active: (N,) int32/bool with N % 1024 == 0; counter: (1,) int32.
    ``interpret=None`` resolves via REPRO_PALLAS_INTERPRET / backend.
    Returns (tickets (N,) int32, new_counter (1,) int32)."""
    return _wavefaa_jit(active, counter,
                        interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _wavefaa_jit(active: jax.Array, counter: jax.Array, *, interpret: bool):
    n = active.shape[0]
    assert n % LANES == 0, f"N={n} must be a multiple of {LANES}"
    blocks = n // LANES
    a = active.astype(jnp.int32).reshape(blocks * 8, 128)
    ctr = counter.astype(jnp.int32).reshape(1)
    call = pl.pallas_call(
        _wavefaa_kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((8, 128), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((8, 128), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((blocks * 8, 128), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )
    with jax.named_scope("repro.wavefaa"):
        tickets, newctr = call(ctr, a)
    return tickets.reshape(n), newctr
