"""Batched bounded-ring slot operations as Pallas TPU kernels.

These kernels apply a *wave* of fast-path queue operations (paper Alg. 1) to
the ring state in one invocation.  The ring's packed 64-bit entry word is
represented as four parallel int32 field planes (cycle / safe / enq / idx) —
TPU-native layout: 32-bit lanes, single-writer-per-slot semantics guaranteed
by ticket uniqueness (Lemma III.1), applied in ticket order, which *is* the
linearization order.

VMEM budget: the whole ring (4 × 2n × 4 B) plus the op batch live in VMEM;
for n ≤ 64Ki that is ≤ 2 MiB — comfortably inside the 16 MiB/core budget.
The field planes are aliased input→output so the update is in-place.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _enq_kernel(nslots_log2, idx_bot, head_ref, tickets_ref, values_ref,
                cyc_in, saf_in, enq_in, idx_in,
                cyc_ref, saf_ref, enq_ref, idx_ref, ok_ref):
    nslots = 1 << nslots_log2
    idx_botc = idx_bot - 1
    cyc_ref[...] = cyc_in[...]
    saf_ref[...] = saf_in[...]
    enq_ref[...] = enq_in[...]
    idx_ref[...] = idx_in[...]
    ok_ref[...] = jnp.zeros_like(ok_ref)
    head = head_ref[0]
    b = tickets_ref.shape[1]

    def body(i, _):
        t = tickets_ref[0, i]
        v = values_ref[0, i]
        j = jnp.where(t >= 0, t & (nslots - 1), 0)
        c = jnp.where(t >= 0, t >> nslots_log2, 0)
        e_c, e_s, e_i = cyc_ref[0, j], saf_ref[0, j], idx_ref[0, j]
        empty = (e_i == idx_bot) | (e_i == idx_botc)
        can = (t >= 0) & (e_c < c) & empty & ((e_s == 1) | (head <= t))
        cyc_ref[0, j] = jnp.where(can, c, e_c)
        saf_ref[0, j] = jnp.where(can, 1, e_s)
        enq_ref[0, j] = jnp.where(can, 1, enq_ref[0, j])
        idx_ref[0, j] = jnp.where(can, v, e_i)
        ok_ref[0, i] = can.astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, b, body, 0)


def _deq_kernel(nslots_log2, idx_bot, tickets_ref,
                cyc_in, saf_in, enq_in, idx_in,
                cyc_ref, saf_ref, enq_ref, idx_ref, val_ref, ok_ref):
    nslots = 1 << nslots_log2
    idx_botc = idx_bot - 1
    cyc_ref[...] = cyc_in[...]
    saf_ref[...] = saf_in[...]
    enq_ref[...] = enq_in[...]
    idx_ref[...] = idx_in[...]
    val_ref[...] = jnp.full_like(val_ref, -1)
    ok_ref[...] = jnp.zeros_like(ok_ref)
    b = tickets_ref.shape[1]

    def body(i, _):
        t = tickets_ref[0, i]
        j = jnp.where(t >= 0, t & (nslots - 1), 0)
        c = jnp.where(t >= 0, t >> nslots_log2, 0)
        e_c, e_s, e_e, e_i = (cyc_ref[0, j], saf_ref[0, j],
                              enq_ref[0, j], idx_ref[0, j])
        empty = (e_i == idx_bot) | (e_i == idx_botc)
        hit = (t >= 0) & (e_c == c) & (~empty) & (e_e == 1)
        idx_ref[0, j] = jnp.where(hit, idx_botc, e_i)     # CONSUME
        adv = (t >= 0) & (~hit) & empty & (e_c < c)
        cyc_ref[0, j] = jnp.where(adv, c, e_c)            # ⊥-advance
        uns = (t >= 0) & (~hit) & (~empty) & (e_c < c)
        saf_ref[0, j] = jnp.where(uns, 0, e_s)            # mark unsafe
        val_ref[0, i] = jnp.where(hit, e_i, -1)
        ok_ref[0, i] = hit.astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, b, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("nslots_log2", "idx_bot", "interpret"))
def ring_enqueue(cycles, safes, enqs, idxs, tickets, values, head, *,
                 nslots_log2: int, idx_bot: int, interpret: bool = True):
    """Apply a batch of TRYENQ installs in ticket order.  All field arrays
    are (2n,) int32; tickets/values are (B,) int32 (ticket -1 = inactive).
    Returns (cycles, safes, enqs, idxs, ok)."""
    nslots = 1 << nslots_log2
    b = tickets.shape[0]
    kern = functools.partial(_enq_kernel, nslots_log2, idx_bot)
    outs = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
        ] + [pl.BlockSpec((1, nslots), lambda i: (0, 0))] * 4,
        out_specs=[pl.BlockSpec((1, nslots), lambda i: (0, 0))] * 4
        + [pl.BlockSpec((1, b), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, nslots), jnp.int32)] * 4
        + [jax.ShapeDtypeStruct((1, b), jnp.int32)],
        interpret=interpret,
    )(head.reshape(1), tickets.reshape(1, b), values.reshape(1, b),
      cycles.reshape(1, nslots), safes.reshape(1, nslots),
      enqs.reshape(1, nslots), idxs.reshape(1, nslots))
    cyc, saf, enq, idx, ok = outs
    return (cyc.reshape(nslots), saf.reshape(nslots), enq.reshape(nslots),
            idx.reshape(nslots), ok.reshape(b).astype(bool))


@functools.partial(jax.jit,
                   static_argnames=("nslots_log2", "idx_bot", "interpret"))
def ring_dequeue(cycles, safes, enqs, idxs, tickets, *,
                 nslots_log2: int, idx_bot: int, interpret: bool = True):
    """Apply a batch of TRYDEQ consumes in ticket order.  Returns
    (cycles, safes, enqs, idxs, values, ok)."""
    nslots = 1 << nslots_log2
    b = tickets.shape[0]
    kern = functools.partial(_deq_kernel, nslots_log2, idx_bot)
    outs = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, b), lambda i: (0, 0))]
        + [pl.BlockSpec((1, nslots), lambda i: (0, 0))] * 4,
        out_specs=[pl.BlockSpec((1, nslots), lambda i: (0, 0))] * 4
        + [pl.BlockSpec((1, b), lambda i: (0, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((1, nslots), jnp.int32)] * 4
        + [jax.ShapeDtypeStruct((1, b), jnp.int32)] * 2,
        interpret=interpret,
    )(tickets.reshape(1, b),
      cycles.reshape(1, nslots), safes.reshape(1, nslots),
      enqs.reshape(1, nslots), idxs.reshape(1, nslots))
    cyc, saf, enq, idx, val, ok = outs
    return (cyc.reshape(nslots), saf.reshape(nslots), enq.reshape(nslots),
            idx.reshape(nslots), val.reshape(b), ok.reshape(b).astype(bool))
