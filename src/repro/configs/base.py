"""Architecture config system.

One ``ArchConfig`` per assigned architecture (exact public-literature values
in the per-arch modules).  ``reduced()`` yields a same-family micro config
for CPU smoke tests; the full configs are exercised only via the dry-run
(ShapeDtypeStruct lowering, no allocation).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

# Input-shape grid shared by all LM-family architectures (assignment spec).
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                   # 0 for attention-free
    n_kv_heads: int
    d_ff: int                      # dense FFN width (expert width for MoE)
    vocab: int
    head_dim: int = 0              # 0 → d_model // n_heads

    # attention variants
    sliding_window: int = 0        # 0 = full attention
    # per-layer window pattern: e.g. ("local",)*5 + ("global",) repeating.
    # Empty = uniform (all sliding_window if set, else all global).
    layer_pattern: Tuple[str, ...] = ()
    attn_softcap: float = 0.0      # gemma2 logit soft-capping
    final_softcap: float = 0.0
    causal: bool = True            # False = encoder-only (hubert)
    rope_theta: float = 10000.0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    # hybrid: every k-th layer also applies the shared attention block
    shared_attn_every: int = 0

    # VLM: every k-th layer is cross-attention to image embeddings
    cross_attn_every: int = 0
    n_image_tokens: int = 1601     # stubbed patch-embedding frontend
    # audio: stubbed frame-embedding frontend (encoder input is frames)
    audio_frontend: bool = False

    # distribution hints
    fsdp: bool = False             # shard params/optimizer over the data axis
    remat: bool = True
    # Megatron-style sequence-parallel residual stream (activations sharded
    # over "model" between layers); enabled by the dry-run for train/prefill.
    seq_parallel: bool = False

    # which assigned shapes are runnable (DESIGN.md § 5 skip rules)
    skip_shapes: Tuple[str, ...] = ()
    skip_reason: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    def window_for_layer(self, i: int) -> int:
        """Effective attention window of layer i (0 = full)."""
        if not self.layer_pattern:
            return self.sliding_window
        kind = self.layer_pattern[i % len(self.layer_pattern)]
        return self.sliding_window if kind == "local" else 0

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, v = self.d_model, self.vocab
        n = 2 * v * d  # embed + untied head
        per_layer = 0
        if self.family in ("dense", "vlm", "audio", "moe"):
            attn = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd \
                + self.n_heads * self.hd * d
            per_layer += attn + 2 * d
        if self.family in ("dense", "vlm", "audio"):
            per_layer += 3 * d * self.d_ff
        if self.family == "moe":
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * self.d_ff
            per_layer += self.n_shared_experts * 3 * d * self.d_ff
        if self.family in ("ssm", "hybrid"):
            di, st, nh = self.d_inner, self.ssm_state, self.ssm_nheads
            per_layer += d * (2 * di + 2 * st + nh)  # in_proj (g=1)
            per_layer += self.ssm_conv * (di + 2 * st) + 2 * nh + di
            per_layer += di * d + d  # out_proj + norm
        n += self.n_layers * per_layer
        if self.family == "vlm" and self.cross_attn_every:
            ncross = self.n_layers // self.cross_attn_every
            n += ncross * (2 * (d * self.n_heads * self.hd
                                + d * self.n_kv_heads * self.hd) + d)
        if self.family == "hybrid" and self.shared_attn_every:
            attn = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd \
                + self.n_heads * self.hd * d
            n += attn + 3 * d * self.d_ff + 2 * d  # one shared attn+MLP block
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        inactive = self.n_layers * (self.n_experts - self.top_k) * 3 * d * self.d_ff
        return full - inactive

    def reduced(self) -> "ArchConfig":
        """Same-family micro config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if not self.layer_pattern
                         else len(self.layer_pattern)),
            d_model=128,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32 if self.n_heads else 0,
            d_ff=256,
            vocab=512,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm_state else 64,
            n_image_tokens=16,
            shared_attn_every=min(self.shared_attn_every, 2),
            cross_attn_every=min(self.cross_attn_every, 2),
            fsdp=False,
        )
