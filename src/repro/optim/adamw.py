"""AdamW with mixed precision, global-norm clipping and cosine schedule.

State: fp32 master weights + fp32 (m, v); the model computes in bf16.
Sharding of every state leaf follows the parameter's PartitionSpec, so with
FSDP configs the optimizer state is ZeRO-3-sharded over the data axis for
free.  Pure functional: ``init`` / ``step``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    master: Any   # fp32 params
    m: Any
    v: Any
    step: jax.Array


def init(params: Any) -> OptState:
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(master=master,
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def step(cfg: AdamWConfig, state: OptState, grads: Any) -> Tuple[OptState, Dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    t = state.step + 1
    lr = schedule(cfg, t)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads)

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        return p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)

    new_master = jax.tree.map(upd, state.master, new_m, new_v)
    return (OptState(new_master, new_m, new_v, t),
            {"grad_norm": gnorm, "lr": lr})


def cast_params(master: Any) -> Any:
    """bf16 working copy (integer leaves kept as-is)."""
    return jax.tree.map(
        lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p,
        master)
