"""G-PQ — wave-batched bounded concurrent priority queue (DESIGN.md § 5.1).

The FIFO family reserves *slots* with one WAVEFAA per converged wave; a
priority queue cannot pre-assign slots (the position of a key is data-
dependent), so G-PQ batches at a different point: inserts reserve a ticket
in a bounded **announce ring** with one WAVEFAA per converged wave and
publish a packed 64-bit ``(epoch, valid, key, idx)`` node word into their
ticket's slot — single-writer per (slot, epoch) by Lemma III.1 ticket
uniqueness, exactly the ring-slot discipline of the FIFO queues.  The
**applied heap** (a d-ary min-heap over the same packed node words) is
advanced by whichever consumer holds the heap latch: before popping it
*drains* the announce ring in ticket order, applying the whole batch of
announced inserts under one latch acquisition — flat combining, the
consumer-side analogue of wave batching.

Linearization points:

* ``insert`` — the WAVEFAA ticket reservation (the announce install is
  completed before the operation returns, so every insert that returned is
  visible to any later drain);
* ``delete_min`` — the drain's read of the announce tail under the latch:
  the pop returns the minimum over every insert ticketed before that read
  minus those already popped, i.e. a minimal pending key (0-relaxed);
* ``delete_min → EMPTY`` — the same tail read, at which point the applied
  heap was empty and every announced ticket was drained.

A ``lazy`` parameter weakens the drain: backlogs of at most ``lazy``
announced-but-unapplied inserts may be skipped before a pop, so a returned
key may ignore up to ``lazy`` smaller pending inserts — the per-ring
relaxation used by ``relaxed.RelaxedGPQ`` (strict G-PQ is ``lazy=0``).

A per-queue **min-hint** word publishes a lower-bound estimate of the
current minimum key: inserts CAS-min it down before returning; a pop
raises it (single CAS attempt; losing the race leaves the hint stale-low,
which is always safe — consumers use hints only to order scans, never to
skip correctness work).  ``PriorityFabric`` orders shard scans by hint, so
work stealing takes the highest-priority shard first.

Histories are bracketed with ``op_begin``/``op_end`` using the § IV event
format extended to priority semantics: op 0 = INS with ``arg=(key, ident)``,
op 1 = DELMIN with ``ret=(key, ident)`` (or None for EMPTY); see
``sched.plinearizability`` for the checker.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.atomics import AtomicMemory
from ..core.sim import Ctx

# History op codes (aliases of the § IV ENQ/DEQ slots: 0 = insert,
# 1 = delete-min).
INS, DELMIN = 0, 1

NEG1 = (1 << 64) - 1  # two's-complement -1 for FAA decrements
MASK64 = NEG1


@dataclass(frozen=True)
class NodeFormat:
    """Packed 64-bit heap/announce node word (Lemma III.2 style):

        [ epoch : EPOCH_BITS | valid : 1 | key : KEY_BITS | idx : IDX_BITS ]

    ``epoch`` versions an announce slot across ring wrap-arounds (the
    reduced-width cycle tag of the FIFO rings, applied to the announce
    ring); ``valid`` flags an installed-but-undrained announce; ``key`` is
    the priority (smaller = more urgent); ``idx`` the payload index."""

    epoch_bits: int = 12
    key_bits: int = 27
    idx_bits: int = 24

    @property
    def epoch_mask(self) -> int:
        return (1 << self.epoch_bits) - 1

    @property
    def key_mask(self) -> int:
        return (1 << self.key_bits) - 1

    @property
    def idx_mask(self) -> int:
        return (1 << self.idx_bits) - 1

    @property
    def key_inf(self) -> int:
        """Hint value for "no pending key" (an unreachable key)."""
        return self.key_mask

    @property
    def valid_shift(self) -> int:
        return self.key_bits + self.idx_bits

    @property
    def epoch_shift(self) -> int:
        return self.valid_shift + 1

    def pack(self, epoch: int, valid: int, key: int, idx: int) -> int:
        assert 0 <= key <= self.key_mask and 0 <= idx <= self.idx_mask
        return (((epoch & self.epoch_mask) << self.epoch_shift)
                | ((valid & 1) << self.valid_shift)
                | ((key & self.key_mask) << self.idx_bits)
                | (idx & self.idx_mask)) & MASK64

    def epoch(self, word: int) -> int:
        return (word >> self.epoch_shift) & self.epoch_mask

    def valid(self, word: int) -> int:
        return (word >> self.valid_shift) & 1

    def key(self, word: int) -> int:
        return (word >> self.idx_bits) & self.key_mask

    def idx(self, word: int) -> int:
        return word & self.idx_mask


NODE = NodeFormat()


class GPQ:
    """Bounded concurrent min-priority queue: wave-batched announce ring +
    latch-combined d-ary applied heap.

    Public generator API (driven by ``core.sim.Scheduler``):

    * ``insert(ctx, tid, key, idx)`` → bool (False = full),
    * ``delete_min(ctx, tid)`` → (True, (key, idx)) or (False, None) EMPTY,
    * ``peek_hint(ctx, tid)`` → current min-key hint (scan ordering only).

    The unbracketed internals (``reserve``/``announce_install``/
    ``pop_once``) are reused by ``RelaxedGPQ``, which does its own history
    bracketing across rings.
    """

    name = "gpq"

    def __init__(self, capacity: int, num_threads: int, tag: str = "gpq",
                 *, arity: int = 4, lazy: int = 0,
                 fmt: NodeFormat = NODE) -> None:
        assert arity >= 2
        self.capacity = capacity
        self.num_threads = num_threads
        self.tag = tag
        self.arity = arity
        self.lazy = lazy
        self.fmt = fmt
        # Announce ring sized so a full queue of live-but-undrained inserts
        # can never wrap onto an unconsumed slot (insert would otherwise
        # have to block on a drain that might never come).
        self.announce_slots = capacity + num_threads
        # Heap headroom for the transient count overshoot of concurrent
        # reservations (each backs off, but holds a slot meanwhile).
        self.heap_slots = capacity + num_threads
        self.mem: AtomicMemory | None = None
        self.s_lock = f"{tag}_lock"
        self.s_atail = f"{tag}_atail"
        self.s_ahead = f"{tag}_ahead"
        self.s_ann = f"{tag}_ann"
        self.s_heap = f"{tag}_heap"
        self.s_size = f"{tag}_size"
        self.s_count = f"{tag}_count"
        self.s_hint = f"{tag}_hint"

    def init(self, mem: AtomicMemory) -> None:
        self.mem = mem
        f = self.fmt
        mem.alloc(self.s_lock, 1, fill=0)
        mem.alloc(self.s_atail, 1, fill=0)
        mem.alloc(self.s_ahead, 1, fill=0)
        mem.alloc(self.s_ann, self.announce_slots, fill=f.pack(0, 0, 0, 0))
        mem.alloc(self.s_heap, self.heap_slots, fill=0)
        mem.alloc(self.s_size, 1, fill=0)
        mem.alloc(self.s_count, 1, fill=0)
        mem.alloc(self.s_hint, 1, fill=f.key_inf)

    # -- unbracketed internals (shared with RelaxedGPQ) ----------------------

    def reserve(self, ctx: Ctx, tid: int):
        """Capacity reservation on the pending-element counter.  Returns
        True if a slot was reserved (must be paid back by a pop or an
        unreserve on failure)."""
        old = yield from ctx.faa(self.s_count, 0, 1)
        if old >= self.capacity:
            yield from ctx.faa(self.s_count, 0, NEG1)
            return False
        return True

    def announce_install(self, ctx: Ctx, tid: int, key: int, idx: int):
        """WAVEFAA ticket + packed node install + hint publication.  The
        caller must hold a successful ``reserve``."""
        f = self.fmt
        t = yield from ctx.wavefaa(self.s_atail, 0)
        j = t % self.announce_slots
        e = (t // self.announce_slots + 1) & f.epoch_mask
        prev_e = (e - 1) & f.epoch_mask
        while True:
            w = yield from ctx.load(self.s_ann, j)
            if f.valid(w) == 0 and f.epoch(w) == prev_e:
                break
            yield from ctx.step()      # previous epoch not yet drained
        yield from ctx.store(self.s_ann, j, f.pack(e, 1, key, idx))
        # Publish a min-key lower bound before returning: every *completed*
        # insert is hinted, so hint-ordered scans see it.
        while True:
            h = yield from ctx.load(self.s_hint, 0)
            if key >= h:
                break
            ok = yield from ctx.cas(self.s_hint, 0, h, key)
            if ok:
                break
        return t

    def _heap_sift_up(self, ctx: Ctx, pos: int, word: int):
        f, d = self.fmt, self.arity
        key = f.key(word)
        j = pos
        while j > 0:
            p = (j - 1) // d
            pw = yield from ctx.load(self.s_heap, p)
            if f.key(pw) <= key:
                break
            yield from ctx.store(self.s_heap, j, pw)
            j = p
        yield from ctx.store(self.s_heap, j, word)

    def _heap_sift_down(self, ctx: Ctx, size: int, word: int):
        f, d = self.fmt, self.arity
        key = f.key(word)
        j = 0
        while True:
            base = j * d + 1
            if base >= size:
                break
            best_k, best_j, best_w = None, -1, 0
            for c in range(base, min(base + d, size)):
                cw = yield from ctx.load(self.s_heap, c)
                ck = f.key(cw)
                if best_k is None or ck < best_k:
                    best_k, best_j, best_w = ck, c, cw
            if best_k is None or best_k >= key:
                break
            yield from ctx.store(self.s_heap, j, best_w)
            j = best_j
        yield from ctx.store(self.s_heap, j, word)

    def _drain(self, ctx: Ctx, *, force: bool):
        """Apply announced inserts to the heap in ticket order (latch held).
        With ``lazy > 0`` and ``force=False``, backlogs of at most ``lazy``
        are deferred."""
        f = self.fmt
        tail = yield from ctx.load(self.s_atail, 0)
        head = yield from ctx.load(self.s_ahead, 0)
        if tail == head:
            return head, 0, tail
        if not force and (tail - head) <= self.lazy:
            return head, tail - head, tail
        size = yield from ctx.load(self.s_size, 0)
        for h in range(head, tail):
            j = h % self.announce_slots
            e = (h // self.announce_slots + 1) & f.epoch_mask
            while True:
                w = yield from ctx.load(self.s_ann, j)
                if f.valid(w) and f.epoch(w) == e:
                    break
                yield from ctx.step()  # ticket reserved, install in flight
            yield from ctx.store(self.s_ann, j, f.pack(e, 0, 0, 0))
            yield from self._heap_sift_up(ctx, size, w)
            size += 1
        yield from ctx.store(self.s_size, 0, size)
        yield from ctx.store(self.s_ahead, 0, tail)
        return tail, 0, tail

    def pop_once(self, ctx: Ctx, tid: int):
        """One latch acquisition: drain, then pop the applied minimum.
        Returns (key, idx) or None (nothing applied and nothing announced).
        Does NOT touch the pending counter or the history."""
        f = self.fmt
        while True:
            ok = yield from ctx.cas(self.s_lock, 0, 0, 1)
            if ok:
                break
            yield from ctx.step()
        size = yield from ctx.load(self.s_size, 0)
        force = size == 0          # never report EMPTY past undrained work
        head, backlog, tail_seen = yield from self._drain(ctx, force=force)
        size = yield from ctx.load(self.s_size, 0)
        if size == 0:
            # Fully drained and empty: publish the EMPTY hint — after
            # re-scanning tickets announced since the drain's tail read,
            # so the raise cannot erase a fresh insert's publication.
            new_hint = f.key_inf
            tail_now = yield from ctx.load(self.s_atail, 0)
            for t in range(tail_seen, tail_now):
                j = t % self.announce_slots
                e = (t // self.announce_slots + 1) & f.epoch_mask
                w = yield from ctx.load(self.s_ann, j)
                if f.valid(w) and f.epoch(w) == e:
                    new_hint = min(new_hint, f.key(w))
            h = yield from ctx.load(self.s_hint, 0)
            if h != new_hint:
                yield from ctx.cas(self.s_hint, 0, h, new_hint)
            yield from ctx.store(self.s_lock, 0, 0)
            return None
        root = yield from ctx.load(self.s_heap, 0)
        last = yield from ctx.load(self.s_heap, size - 1)
        size -= 1
        yield from ctx.store(self.s_size, 0, size)
        if size > 0:
            yield from self._heap_sift_down(ctx, size, last)
        # Recompute the ring's min-key estimate: the applied root, min'd
        # with every announced key the drain did not apply — the (≤ lazy)
        # skipped backlog plus any announce ticketed after the drain's
        # tail read (whose publication this raise could otherwise erase).
        # Published with a single CAS: if a racing insert's lower CAS-min
        # lands between our load and CAS, our CAS fails and the lower
        # value sticks.  A mid-install slot (ticket reserved, word not yet
        # stored) can still slip a narrow window — its key is unreadable
        # here and its own CAS-min may load our pre-raise value — so the
        # hint is a *scan-ordering heuristic*, never a correctness input:
        # consumers scan every shard/ring regardless, pops always drain
        # before popping or declaring EMPTY, and the relaxed envelope
        # (relaxed.py) already charges sibling-ring publication races.
        new_hint = f.key_inf
        if size > 0:
            nw = yield from ctx.load(self.s_heap, 0)
            new_hint = f.key(nw)
        tail_now = yield from ctx.load(self.s_atail, 0)
        for t in list(range(head, head + backlog)) + list(range(tail_seen,
                                                                tail_now)):
            j = t % self.announce_slots
            e = (t // self.announce_slots + 1) & f.epoch_mask
            w = yield from ctx.load(self.s_ann, j)
            if f.valid(w) and f.epoch(w) == e:
                new_hint = min(new_hint, f.key(w))
        h = yield from ctx.load(self.s_hint, 0)
        yield from ctx.cas(self.s_hint, 0, h, new_hint)
        yield from ctx.store(self.s_lock, 0, 0)
        return (f.key(root), f.idx(root))

    def unreserve(self, ctx: Ctx, tid: int):
        yield from ctx.faa(self.s_count, 0, NEG1)

    # -- bracketed public operations -----------------------------------------

    def insert(self, ctx: Ctx, tid: int, key: int, idx: int):
        assert 0 <= key < self.fmt.key_inf, "key out of NodeFormat range"
        assert 0 <= idx <= self.fmt.idx_mask
        yield from ctx.op_begin(INS, (key, idx))
        ok = yield from self.reserve(ctx, tid)
        if not ok:
            yield from ctx.op_end(False, False)
            return False
        yield from self.announce_install(ctx, tid, key, idx)
        yield from ctx.op_end(True, True)
        return True

    def delete_min(self, ctx: Ctx, tid: int):
        yield from ctx.op_begin(DELMIN, None)
        c = yield from ctx.load(self.s_count, 0)
        if c == 0:
            yield from ctx.op_end(None, True)
            return (False, None)
        got = yield from self.pop_once(ctx, tid)
        if got is None:
            # Nothing announced at the drain's tail read: every element in
            # ``count`` was an insert that had not completed — EMPTY is a
            # valid linearization at that read.
            yield from ctx.op_end(None, True)
            return (False, None)
        yield from ctx.faa(self.s_count, 0, NEG1)
        yield from ctx.op_end(got, True)
        return (True, got)

    def peek_hint(self, ctx: Ctx, tid: int):
        h = yield from ctx.load(self.s_hint, 0)
        return h
