"""Task-runtime demo: the sharded work-stealing fabric and the
deterministic JAX round scheduler on one spawning workload (DESIGN.md § 4).

A binary tree of tasks (every task spawns two children until depth 0) runs
three ways: single shared queue, sharded fabric, sharded fabric with work
stealing — then the same task graph executes as jitted rounds through the
Pallas ring.

    PYTHONPATH=src python examples/runtime_demo.py
"""

import jax.numpy as jnp

from repro.runtime import (ExecutorConfig, RoundRunner, TaskFabric,
                           TaskRuntime, TaskSpec)

DEPTH, ROOTS, WORKERS = 5, 4, 32
TOTAL = ROOTS * (2 ** (DEPTH + 1) - 1)


def handler(rec):
    d = rec.payload
    return [TaskSpec(d - 1, cost=2), TaskSpec(d - 1, cost=2)] if d > 0 else []


print(f"spawning tree: {ROOTS} roots x depth {DEPTH} = {TOTAL} tasks, "
      f"{WORKERS} persistent workers\n")
for label, shards, steal in (("single queue", 1, False),
                             ("sharded x4", 4, False),
                             ("sharded x4 + steal", 4, True)):
    fabric = TaskFabric(algo="glfq", shards=shards, capacity_per_shard=256,
                        num_threads=WORKERS + 1, steal=steal)
    rt = TaskRuntime(fabric, handler,
                     ExecutorConfig(workers=WORKERS, policy="gang", seed=0))
    for _ in range(ROOTS):
        rt.add_task(DEPTH, cost=2)
    m = rt.run()
    assert len(rt.executed) == TOTAL
    print(f"{label:20s} thr={m['throughput_ops_per_kstep']:6.2f} ops/kstep  "
          f"idle={m['idle_steps']:7.0f}  steal_rate={m['steal_rate']:.2f}  "
          f"imbalance={m['load_imbalance']:.2f}")

# -- the same tree as deterministic jitted rounds on the Pallas ring ---------


def step(acc, vals, valid):
    """Task value = remaining depth: d spawns two copies of d-1."""
    acc = acc + valid.sum()
    children = jnp.stack([vals - 1, vals - 1], -1).astype(jnp.int32)
    mask = (valid & (vals > 0))[:, None]
    return acc, children, mask


runner = RoundRunner(step, capacity_log2=10, batch=64)
acc, _ = runner.run([DEPTH] * ROOTS, acc=jnp.int32(0))
assert int(acc) == TOTAL
print(f"\nround scheduler (Pallas ring): {int(acc)} tasks in "
      f"{runner.stats['rounds']} rounds, max occupancy "
      f"{runner.stats['max_occupancy']}, drained={bool(runner.stats['drained'])}")
