"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json results.json]

Sections: Fig. 4 throughput, Fig. 5 per-op profiling (+ Fig. 1 ablation),
Table IV/Fig. 6 BFS, Fig. 7 ray tracing, kernel micro-benchmarks, and the
task-runtime fabric comparison (bench_runtime).

CSV lines go to stdout: ``name,...`` per row.  With ``--json`` the same
rows are parsed into ``{section: [row dicts]}`` and written to the given
path (``-`` = stdout) — the machine-readable trajectory format.
"""

import argparse
import io
import json
import sys


class _Tee(io.TextIOBase):
    """Forward writes to stdout while keeping a copy for CSV parsing."""

    def __init__(self) -> None:
        self.buf = io.StringIO()

    def write(self, s: str) -> int:
        sys.stdout.write(s)
        return self.buf.write(s)

    def flush(self) -> None:
        sys.stdout.flush()


def _parse_csv(text: str):
    """Parse a section's output: every bench header leads with the literal
    cell ``bench`` (possibly mid-section — sub-tables need no separator);
    later comma lines are rows under the current header (numbers coerced);
    ``#`` lines are commentary."""
    rows, header = [], None
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split(",")
        if parts[0] == "bench" or header is None:
            header = parts
            continue
        row = {}
        for k, v in zip(header, parts):
            try:
                row[k] = int(v)
            except ValueError:
                try:
                    row[k] = float(v)
                except ValueError:
                    row[k] = v
        rows.append(row)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweeps (CI-sized)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also emit {section: [rows]} JSON to PATH ('-' = stdout)")
    ap.add_argument("--section", default=None,
                    choices=["throughput", "profiling", "bfs", "raytrace",
                             "kernels", "runtime", None])
    args = ap.parse_args()
    from . import (bench_bfs, bench_kernels, bench_profiling,
                   bench_raytrace, bench_runtime, bench_throughput)

    kw_thr = dict(threads_list=(8, 32), steps=40_000) if args.quick else {}
    kw_prof = dict(threads_list=(8, 32), steps=40_000) if args.quick else {}
    kw_rt = (dict(algos=("glfq",), n_tasks=96) if args.quick
             else dict(algos=("glfq", "gwfq", "gwfq-ymc", "sfq")))
    sections = {
        "throughput": lambda out: bench_throughput.main(out, **kw_thr),
        "profiling": lambda out: bench_profiling.main(out, **kw_prof),
        "bfs": lambda out: bench_bfs.main(out),
        "raytrace": lambda out: bench_raytrace.main(out),
        "kernels": lambda out: bench_kernels.main(out),
        "runtime": lambda out: bench_runtime.main(out, **kw_rt),
    }
    todo = [args.section] if args.section else list(sections)
    if args.json and args.json != "-":
        with open(args.json, "a"):     # fail on an unwritable path up front,
            pass                       # not after the whole sweep has run
    results = {}
    for name in todo:
        print(f"# === {name} ===")
        tee = _Tee()
        sections[name](tee)
        results[name] = _parse_csv(tee.buf.getvalue())
        sys.stdout.flush()
    if args.json:
        payload = json.dumps(results, indent=1, sort_keys=True)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w") as f:
                f.write(payload + "\n")
            print(f"# json -> {args.json}")


if __name__ == "__main__":
    main()
