"""Paper Table IV + Fig. 6 — level-synchronous BFS: queue-driven frontiers
vs the Gunrock-style dense-sweep baseline, over nine synthetic graphs
matched to the Table IV families (road / kron / hollywood / delaunay /
osm)."""

from __future__ import annotations

import sys
import time

from repro.apps.bfs import (bfs_baseline, bfs_queue, bfs_reference,
                            delaunay_like, kron_like, road_like)


def graphs():
    return [
        road_like(1024), road_like(4096), road_like(16384),
        kron_like(1024, 16), kron_like(4096, 24),
        delaunay_like(1024, 6), delaunay_like(4096, 6),
        kron_like(2048, 48),       # hollywood-like (dense power-law)
        road_like(9216),           # osm-like
    ]


def _time(fn, *args, reps: int = 3, **kw):
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


def main(out=sys.stdout) -> None:
    print("bench,graph,n,m,levels,queue_ms,baseline_ms,rel_vs_baseline,"
          "correct", file=out)
    for g in graphs():
        ref = bfs_reference(g)
        tq, (dq, mq) = _time(bfs_queue, g, use_kernel=False)
        tb, (db, _) = _time(bfs_baseline, g)
        ok = bool((dq == ref).all() and (db == ref).all())
        print(f"fig6_bfs,{g.name},{g.n},{g.m},{mq['levels']},"
              f"{tq*1e3:.2f},{tb*1e3:.2f},{tb/max(tq,1e-9):.2f},{ok}",
              file=out)


if __name__ == "__main__":
    main()
