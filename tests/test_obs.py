"""Observability layer invariants (DESIGN.md § 7):

* trace-plane ring semantics: one record per round, wraparound overwrites
  oldest-first and is *reported* at drain (never an error);
* ``telemetry=None`` compiles each fused engine to the exact
  pre-telemetry loop — telemetry on vs off is bit-identical on the acc,
  the queue planes, and every stats counter, for all four fused engines;
* drained records agree with the engine's own stats (pops sum to
  ``processed``, rounds are contiguous, occupancy ends at 0);
* export roundtrip: JSONL write → read reproduces every field; the Chrome
  trace and JSONL both satisfy ``tools/trace_check.py``;
* the metrics registry enforces kinds and stable keys; the analyzers
  measure rank error / inversions the paper's envelope is compared to.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.jaxcompat import make_mesh  # noqa: E402
from repro.obs import (  # noqa: E402
    KEY_SENTINEL, MetricsRegistry, RoundRecord, SyncPoint, Telemetry,
    drain_plane, key_inversions, measured_rank_error, metric_key,
    rank_error_vs_envelope, read_jsonl, to_chrome_trace, trace_init,
    trace_record, write_chrome_trace, write_jsonl)
from repro.runtime import (  # noqa: E402
    MeshRoundRunner, PriorityMeshRoundRunner, PriorityRoundRunner,
    RoundRunner)

STAT_KEYS = ("rounds", "processed", "spawned", "max_occupancy", "drained")

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _mesh1():
    return make_mesh((1,), ("data",))


# -- trace plane ring ---------------------------------------------------------


def test_trace_plane_wraparound_reports_dropped():
    tp = trace_init(4, shards=2)
    for r in range(6):
        tp = trace_record(tp, r, jnp.array([r, r + 1]), jnp.array([0, 1]),
                          jnp.array([5, 6]), r * 10, r * 10 + 5, False)
    recs, count, dropped = drain_plane(tp, 0, engine="t", sync=3,
                                       wall_time=1.5)
    assert count == 6 and dropped == 2          # rounds 0-1 overwritten
    assert [r.round for r in recs] == [2, 3, 4, 5]
    assert recs[0].pops == [2, 3] and recs[0].imbalance == 1
    assert recs[-1].min_key == 50 and recs[-1].max_key == 55
    assert all(r.sync == 3 and r.wall_time == 1.5 for r in recs)
    # a second drain from the same cursor sees nothing new
    assert drain_plane(tp, count) == ([], 6, 0)


def test_trace_plane_scalar_promotion_and_empty_round():
    tp = trace_init(2)                           # S = 1, scalars promoted
    tp = trace_record(tp, 0, 3, 1, 7, KEY_SENTINEL, -KEY_SENTINEL, False)
    recs, _, dropped = drain_plane(tp, 0)
    assert dropped == 0
    assert recs[0].pops == [3] and recs[0].occupancy == [7]
    assert recs[0].min_key == KEY_SENTINEL      # empty-round sentinels kept
    assert recs[0].imbalance == 0


def test_telemetry_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        Telemetry(0)
    with pytest.raises(ValueError, match="capacity"):
        trace_init(0)


def test_sync_point_dict_compat():
    p = SyncPoint(rounds=4, occupancy=0, wall_time=2.0, host_syncs=1)
    assert p["rounds"] == 4 and p["occupancy"] == 0
    assert p.get("host_syncs") == 1 and p.get("missing", -1) == -1
    assert p.to_dict() == {"rounds": 4, "occupancy": 0, "wall_time": 2.0,
                           "host_syncs": 1}


# -- telemetry-off bit-identity on all four fused engines ---------------------


def _tree_step():
    def step(acc, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        cm = (valid & (vals < 32))[:, None]
        return acc, cv, cm
    return step


def _pri_step():
    def step(acc, keys, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        ck = jnp.stack([keys + 1, keys + 2], -1).astype(jnp.int32)
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        cm = (valid & (vals < 32))[:, None]
        return acc, ck, cv, cm
    return step


def _assert_identical(res_off, res_on):
    (acc0, st0, stats0), (acc1, st1, stats1) = res_off, res_on
    np.testing.assert_array_equal(np.asarray(acc0), np.asarray(acc1))
    for a, b in zip(jax.tree.leaves(st0), jax.tree.leaves(st1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert stats0 == stats1


def _check_records(tel, stats, shards=1):
    recs = tel.records
    assert [r.round for r in recs] == list(range(stats["rounds"]))
    assert sum(sum(r.pops) for r in recs) == stats["processed"]
    assert sum(sum(r.pushes) for r in recs) == stats["spawned"]
    assert all(len(r.pops) == shards for r in recs)
    assert max(max(r.occupancy) for r in recs) <= stats["max_occupancy"]
    assert sum(recs[-1].occupancy) == 0          # quiescent final round
    assert not any(r.overflow for r in recs)
    assert tel.dropped == 0
    # finish() published the stats as engine-scoped gauges
    assert tel.registry.get(f"{tel.engine}.rounds") == stats["rounds"]


def test_engine_matrix_telemetry_off_bit_identical(engine_case):
    """One test over the whole engine matrix (tests/conftest.py): for
    every registered runner configuration, telemetry on vs off is
    bit-identical on acc, queue planes, and stats, and the drained
    records agree with the stats counters."""
    out = []
    for tel in (None, Telemetry(256, engine=engine_case.name)):
        r = engine_case.build(telemetry=tel)
        out.append(engine_case.run(r))
    _assert_identical(out[0], out[1])
    _check_records(r.telemetry, out[1][2], shards=1)
    if engine_case.entry.priority:
        # priority planes record popped-*key* extrema
        keyed = [x for x in r.telemetry.records
                 if x.min_key != KEY_SENTINEL]
        assert keyed and all(x.min_key <= x.max_key for x in keyed)


def _pri_mesh_tree_step():
    def step(acc, keys, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        ck = (cv * 7919) % 1000
        cm = (valid & (vals < 32))[:, None]
        return acc, ck, cv, cm
    return step


def test_telemetry_tiny_capacity_drops_not_raises():
    tel = Telemetry(4, engine="rounds")
    r = RoundRunner(_tree_step(), capacity_log2=8, batch=16, telemetry=tel)
    r.run([1], acc=jnp.zeros(80, jnp.int32))
    assert r.stats["rounds"] > 4
    assert len(tel.records) == 4                 # newest 4 survive
    assert tel.dropped == r.stats["rounds"] - 4
    assert [x.round for x in tel.records] == \
        list(range(r.stats["rounds"] - 4, r.stats["rounds"]))
    assert tel.registry.get("rounds.trace_dropped") == tel.dropped


def test_telemetry_sync_every_heartbeats_and_multi_run():
    tel = Telemetry(256, engine="rounds")
    r = RoundRunner(_tree_step(), capacity_log2=8, batch=16, sync_every=2,
                    telemetry=tel)
    r.run([1], acc=jnp.zeros(80, jnp.int32))
    assert len(tel.sync_points) == r.stats["host_syncs"] > 1
    assert [p.rounds for p in tel.sync_points] == \
        sorted(p.rounds for p in tel.sync_points)
    assert tel.sync_points[-1].occupancy == 0
    syncs = {x.sync for x in tel.records}
    assert len(syncs) > 1                        # drained across heartbeats
    n1 = len(tel.records)
    r.run([1], acc=jnp.zeros(80, jnp.int32))     # records accumulate
    assert len(tel.records) == 2 * n1
    assert [x.round for x in tel.records[n1:]] == \
        [x.round for x in tel.records[:n1]]


def test_legacy_engines_reject_telemetry():
    with pytest.raises(ValueError, match="fused"):
        RoundRunner(_tree_step(), fused=False, telemetry=Telemetry())
    with pytest.raises(ValueError, match="fused"):
        PriorityRoundRunner(_pri_step(), fused=False, telemetry=Telemetry())


_TWO_SHARD_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys; sys.path.insert(0, {src!r})
import numpy as np
import jax, jax.numpy as jnp
from repro.jaxcompat import make_mesh
from repro.obs import Telemetry
from repro.runtime import MeshRoundRunner, PriorityMeshRoundRunner

mesh = make_mesh((2,), ("data",))

def tree_step(acc, vals, valid):
    acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
    cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
    cm = (valid & (vals < 32))[:, None]
    return acc, cv, cm

def pri_step(acc, keys, vals, valid):
    acc, cv, cm = tree_step(acc, vals, valid)
    ck = (cv * 7919) % 1000
    return acc, ck, cv, cm

out = []
for tel in (None, Telemetry(256, engine="mesh")):
    r = MeshRoundRunner(tree_step, mesh=mesh, capacity_log2=8, batch=16,
                        combine=lambda a: a.sum(0), telemetry=tel)
    acc, st = r.run([1], acc=jnp.zeros(80, jnp.int32))
    out.append((np.asarray(acc), jax.tree.leaves(st), dict(r.stats)))
np.testing.assert_array_equal(out[0][0], out[1][0])
for a, b in zip(out[0][1], out[1][1]):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
assert out[0][2] == out[1][2]
recs = r.telemetry.records
assert all(len(x.pops) == 2 for x in recs)       # per-shard columns
assert sum(sum(x.pops) for x in recs) == r.stats["processed"]
assert any(x.imbalance > 0 for x in recs)        # odd claims split unevenly

for relaxed in (True, False):
    out = []
    for tel in (None, Telemetry(256, engine="pmesh")):
        r = PriorityMeshRoundRunner(pri_step, mesh=mesh, capacity_log2=8,
                                    batch=16, relaxed=relaxed,
                                    combine=lambda a: a.sum(0),
                                    telemetry=tel)
        acc, st = r.run([7919 % 1000], [1], acc=jnp.zeros(80, jnp.int32))
        out.append((np.asarray(acc), jax.tree.leaves(st), dict(r.stats)))
    np.testing.assert_array_equal(out[0][0], out[1][0])
    for a, b in zip(out[0][1], out[1][1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert out[0][2] == out[1][2]
    recs = r.telemetry.records
    assert sum(sum(x.pops) for x in recs) == r.stats["processed"]
    assert all(len(x.pops) == 2 for x in recs)
print("TWO_SHARD_TELEMETRY_OK")
"""


def test_two_shard_mesh_telemetry_bit_identical():
    """Forced-device acceptance: telemetry on vs off is bit-identical on
    both mesh engines at 2 shards, with real per-shard record columns."""
    src = os.path.join(REPO, "src")
    res = subprocess.run(
        [sys.executable, "-c", _TWO_SHARD_SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "TWO_SHARD_TELEMETRY_OK" in res.stdout


# -- export / validation ------------------------------------------------------


def _demo_telemetry():
    tel = Telemetry(256, engine="rounds")
    r = RoundRunner(_tree_step(), capacity_log2=8, batch=16, telemetry=tel)
    r.run([1], acc=jnp.zeros(80, jnp.int32))
    return tel


def test_jsonl_roundtrip_exact(tmp_path):
    tel = _demo_telemetry()
    path = str(tmp_path / "trace.jsonl")
    n = write_jsonl(path, tel.records, tel.sync_points,
                    metrics=tel.registry.snapshot(), engine="rounds",
                    extra_meta={"workload": "tree"})
    assert n == 1 + len(tel.records) + len(tel.sync_points) + 1
    back = read_jsonl(path)
    assert back["meta"]["schema_version"] == 2
    assert back["meta"]["workload"] == "tree"
    assert back["records"] == tel.records        # dataclass field equality
    assert back["syncs"] == tel.sync_points
    assert back["metrics"] == tel.registry.snapshot()


def test_chrome_trace_structure(tmp_path):
    tel = _demo_telemetry()
    trace = to_chrome_trace(tel.records, tel.sync_points, engine="rounds")
    xev = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    cev = [e for e in trace["traceEvents"] if e["ph"] == "C"]
    iev = [e for e in trace["traceEvents"] if e["ph"] == "i"]
    assert len(xev) == len(tel.records)
    assert len(cev) == 2 * len(tel.records)      # occupancy + imbalance
    assert len(iev) == len(tel.sync_points)
    assert trace["metadata"]["time_base"] == "round-index"
    path = str(tmp_path / "trace.json")
    assert write_chrome_trace(path, tel.records, tel.sync_points) \
        == len(trace["traceEvents"])


def test_trace_check_tool_accepts_and_rejects(tmp_path):
    tel = _demo_telemetry()
    good = str(tmp_path / "good.jsonl")
    chrome = str(tmp_path / "good.json")
    write_jsonl(good, tel.records, tel.sync_points,
                metrics=tel.registry.snapshot(), engine="rounds")
    write_chrome_trace(chrome, tel.records, tel.sync_points)
    tool = os.path.join(REPO, "tools", "trace_check.py")
    ok = subprocess.run([sys.executable, tool, good, "--chrome", chrome],
                        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stderr
    # corrupt a required field -> nonzero exit naming the line
    lines = open(good).read().splitlines()
    bad = str(tmp_path / "bad.jsonl")
    with open(bad, "w") as f:
        for ln in lines:
            f.write(ln.replace('"pops"', '"poops"') + "\n")
    res = subprocess.run([sys.executable, tool, bad],
                         capture_output=True, text=True)
    assert res.returncode == 1 and "pops" in res.stderr


# -- metrics registry ---------------------------------------------------------


def test_metric_key_stable_scheme():
    assert metric_key("fabric", "deq", shard=1, lane=0) == \
        "fabric.deq[lane=0,shard=1]"             # labels sorted
    assert metric_key("serving", "admitted") == "serving.admitted"


def test_registry_kinds_enforced_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("a.n", 2)
    reg.counter("a.n", 3)
    reg.gauge("a.g", 7)
    for v in (1, 2, 100):
        reg.observe("a.h", v)
    with pytest.raises(ValueError, match="counter"):
        reg.gauge("a.n", 1)
    with pytest.raises(ValueError, match="histogram"):
        reg.counter("a.h")
    snap = reg.snapshot()
    assert snap["a.n"] == 5 and snap["a.g"] == 7
    assert snap["a.h"]["count"] == 3 and snap["a.h"]["max"] == 100
    assert reg.filtered("a").keys() == snap.keys()
    other = MetricsRegistry()
    other.counter("a.n", 10)
    reg.merge(other)
    assert reg.get("a.n") == 15


def test_executor_publishes_stable_keys():
    from repro.runtime.executor import ExecutorConfig, TaskRuntime
    from repro.runtime.taskpool import TaskFabric
    reg = MetricsRegistry()
    fab = TaskFabric(shards=2, lanes=2, capacity_per_shard=64,
                     num_threads=16)
    rt = TaskRuntime(fab, lambda rec: None, ExecutorConfig(workers=8),
                     registry=reg)
    for i in range(12):
        rt.add_task(i, priority=i % 2)
    m = rt.run()
    snap = reg.snapshot()
    assert snap["runtime.tasks_executed"] == 12 == m["tasks_executed"]
    deq = reg.filtered("fabric")
    assert sum(v for k, v in deq.items() if k.startswith("fabric.deq[")) == 12
    assert snap["fabric.wait[cls=0]"]["count"] > 0


# -- analyzers ----------------------------------------------------------------


def test_measured_rank_error_exact():
    assert measured_rank_error([[1], [2], [3]]) == 0
    # 9 popped in round 0 jumps over 3, 1, 2 popped later -> rank error 3
    assert measured_rank_error([[5, 9], [3], [1, 2]]) == 3
    assert measured_rank_error([]) == 0


def test_key_inversions_proxy():
    def rec(rnd, mn, mx):
        return RoundRecord(engine="e", round=rnd, pops=[1], pushes=[0],
                           occupancy=[0], imbalance=0, min_key=mn,
                           max_key=mx, overflow=False, sync=0, wall_time=0.0)
    ordered = [rec(0, 1, 4), rec(1, 5, 9), rec(2, KEY_SENTINEL,
                                               -KEY_SENTINEL)]
    assert key_inversions(ordered) == []         # empty round skipped
    inv = key_inversions([rec(0, 1, 9), rec(1, 5, 6)])
    assert inv == [{"round": 0, "later_round": 1, "depth": 4}]


def test_rank_error_vs_envelope():
    out = rank_error_vs_envelope(5, history=[[5, 9], [3], [1, 2]])
    assert out == {"envelope": 5, "measured_rank_error": 3,
                   "within_envelope": True, "slack": 2}
    with pytest.raises(ValueError):
        rank_error_vs_envelope(5)


def test_mesh_relaxed_within_declared_envelope():
    """Acceptance shape: a relaxed priority-mesh run's measured rank error
    stays within the declared ``mesh_relaxation_bound`` (at one shard the
    engine pops global minima, so the exact trace must show error <=
    envelope)."""
    from repro.sched.relaxed import mesh_relaxation_bound
    mesh = _mesh1()
    r = PriorityMeshRoundRunner(_pri_mesh_tree_step(), mesh=mesh,
                                capacity_log2=8, batch=16, relaxed=True,
                                fused=False, trace=True,
                                combine=lambda a: a.sum(0))
    r.run([7919 % 1000], [1], acc=jnp.zeros(80, jnp.int32))
    history, inserts = [], []
    for rec in r.trace:
        pk, _, ok = rec["pops"]
        history.append([int(k) for k, o in
                        zip(pk.reshape(-1), ok.reshape(-1)) if o])
        gk, _, ga = rec["pushes"]
        inserts.append([int(k) for k, a in
                        zip(gk.reshape(-1), ga.reshape(-1)) if a])
    env = mesh_relaxation_bound(1, 16, r.stats["max_occupancy"])
    out = rank_error_vs_envelope(env, history=history, inserts=inserts)
    assert out == {"envelope": 0, "measured_rank_error": 0,
                   "within_envelope": True, "slack": 0}
