"""Error-feedback int8 gradient compression for cross-pod all-reduce.

At 2+ pods the inter-pod links are the scarcest bandwidth (DESIGN.md § 6);
compressing the gradient payload 4× (f32→int8 with per-block scales) before
the "pod"-axis psum and carrying the quantization error forward (EF-SGD
style) keeps convergence while cutting the cross-pod collective term.

Pure functions over pytrees; the error-feedback buffers live in the train
state of the compressed-DP engine (`distributed.collectives`).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    n = x.size
    pad = (-n) % BLOCK
    flat = jnp.concatenate([x.reshape(-1), jnp.zeros((pad,), x.dtype)])
    return flat.reshape(-1, BLOCK), n


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """f32 → (int8 codes, per-block f32 scales)."""
    blocks, _ = _pad_to_block(g.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, shape, dtype=jnp.float32) -> jax.Array:
    n = 1
    for d in shape:
        n *= d
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(shape).astype(dtype)


def compress_with_feedback(g: jax.Array, err: jax.Array):
    """Error-feedback compression: quantize (g + carried error), return the
    dequantized payload and the new residual."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize(corrected)
    deq = dequantize(q, scale, g.shape)
    new_err = corrected - deq
    return deq.astype(g.dtype), new_err


def tree_compress_with_feedback(grads: Any, errs: Any):
    pairs = jax.tree.map(compress_with_feedback, grads, errs)
    deq = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_errs = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_errs


def init_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio() -> float:
    """Payload bytes ratio vs f32: int8 codes + one f32 scale per block."""
    return (BLOCK * 1 + 4) / (BLOCK * 4)
