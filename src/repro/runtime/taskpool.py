"""Sharded MPMC task fabric over the simulated queue algorithms (DESIGN.md § 4.1).

The fabric is the runtime's work-distribution layer: K independent bounded
rings ("shards") per priority lane, each shard any algorithm from
``repro.core.QUEUE_CLASSES``.  Task payloads are arbitrary Python objects
held in a host-side task table; the rings carry only the 31-bit task ids —
exactly the paper's index-indirection discipline ("move indices, not
payloads") applied at runtime scope.

Placement policy (the two halves of the paper's load-balancing story):

* **wave-affinity enqueue** — a thread spawns children onto the shard owned
  by its *wave* (``wave % K``), so a converged wave's ticket reservations hit
  one hot ring (maximal WAVEFAA batching) and child tasks stay near their
  producer.  External arrivals are sprayed round-robin instead.
* **work-stealing dequeue** — a consumer drains its home shard first; when
  the home ring reports EMPTY it scans the other shards in ring order and
  steals.  Disable with ``steal=False`` to measure the imbalance this
  repairs.

Priority lanes are strict: lane 0 (urgent) is scanned across all shards
before lane 1 ever is.

Every ring operation is bracketed with ``op_begin``/``op_end`` so the
scheduler's § IV history machinery sees the fabric traffic, and each event
is also filed into a per-(lane, shard) history so ``check_linearizable`` can
certify every shard independently (task ids are globally unique, hence the
histories are differentiated).

``HostTaskPool`` at the bottom is the same fabric for *real* host threads
(sharded ``HostRing``s + stealing + lanes) — the serving engine's admission
queue.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from ..core import QUEUE_CLASSES
from ..core.base import VAL_MASK
from ..core.sim import Ctx, DEQ, ENQ, HistoryEvent, Scheduler
from ..data.pipeline import HostRing
from ..obs.metrics import MetricsRegistry, metric_key
from ..sched.gpq import GPQ
from ..sched.policy import make_policy

OUTSTANDING = "rt_outstanding"   # quiescence counter (tasks queued or running)
HINTS = "rt_hints"               # per-ring occupancy hints (poll gating)
NEG1 = (1 << 64) - 1             # two's-complement -1 for FAA decrements


@dataclass
class TaskSpec:
    """What a handler returns to spawn a child task."""
    payload: Any
    priority: int = 1            # 0 = urgent class, 1 = normal class
    cost: int = 0                # simulated compute steps to execute
    deadline: Optional[int] = None   # absolute step deadline (EDF policies)


@dataclass
class TaskRecord:
    task_id: int
    payload: Any
    priority: int
    cost: int
    deadline: Optional[int] = None
    key: int = 0                 # policy-computed scheduling key (G-PQ min-key)
    enq_step: int = -1           # step of the successful queue install
    exec_step: int = -1          # step a worker acquired it for execution


@dataclass
class FabricMetrics:
    enqueues: int = 0
    dequeues: int = 0
    steals: int = 0              # successful dequeues off a non-home shard
    steal_scans: int = 0         # shards probed beyond home
    empty_scans: int = 0         # full acquire passes that found nothing
    enq_retries: int = 0         # backpressure retries (all shards full)
    per_shard_deq: Dict[Tuple[int, int], int] = field(default_factory=dict)

    def load_imbalance(self) -> float:
        """max/mean successful dequeues across shards (1.0 = perfectly even)."""
        counts = list(self.per_shard_deq.values())
        if not counts or sum(counts) == 0:
            return 1.0
        mean = sum(counts) / len(counts)
        return max(counts) / mean if mean else 1.0

    def publish(self, registry: MetricsRegistry, *,
                subsystem: str = "fabric") -> None:
        """Write this snapshot into ``registry`` under the stable
        ``fabric.*`` key scheme (DESIGN.md § 7.2): scalar totals as
        counters, ``load_imbalance`` as a gauge, and the per-(lane, shard)
        dequeue counts as ``fabric.deq[lane=L,shard=S]`` — replacing the
        ``(lane, shard)``-tuple-keyed dict consumers used to reach into."""
        for name in ("enqueues", "dequeues", "steals", "steal_scans",
                     "empty_scans", "enq_retries"):
            registry.counter(metric_key(subsystem, name), getattr(self, name))
        registry.gauge(metric_key(subsystem, "load_imbalance"),
                       self.load_imbalance())
        for (lane, shard), n in sorted(self.per_shard_deq.items()):
            registry.counter(metric_key(subsystem, "deq",
                                        lane=lane, shard=shard), n)


class _FabricBase:
    """State and lifecycle shared by ``TaskFabric`` and ``PriorityFabric``:
    placement helpers (wave-affinity homes + round-robin spray), the host
    task table, dynamic-spawn / OUTSTANDING quiescence accounting, and the
    per-class queue-wait (starvation) metrics.  Subclasses supply
    ``register``, ``enqueue_task``, ``acquire``, and ``validate_priority``."""

    def __init__(self, *, shards: int, wave_size: int) -> None:
        self.shards = shards
        self.wave_size = wave_size
        self.tasks: List[TaskRecord] = []
        self.metrics = FabricMetrics()
        self.waits: Dict[int, List[int]] = {}   # priority class -> queue waits
        self.sched: Optional[Scheduler] = None
        self._rr = itertools.count()          # round-robin arrival spray

    def validate_priority(self, priority: int) -> int:
        raise NotImplementedError

    def validate_deadline(self, deadline: Optional[int]) -> Optional[int]:
        """Fabrics with bounded key encodings override this; the lane
        fabric ignores deadlines."""
        return deadline

    # -- placement -----------------------------------------------------------

    def home_shard(self, tid: int) -> int:
        """Wave-affinity: all lanes of a wave share one home shard."""
        return (tid // self.wave_size) % self.shards

    def spray_shard(self) -> int:
        """Round-robin placement for external arrivals."""
        return next(self._rr) % self.shards

    # -- wait (starvation) accounting ----------------------------------------

    def _record_install(self, rec: TaskRecord) -> None:
        self.metrics.enqueues += 1
        if rec.enq_step < 0:
            rec.enq_step = self.sched.step_count

    def _record_acquire(self, rec: TaskRecord) -> None:
        rec.exec_step = self.sched.step_count
        if rec.enq_step >= 0:
            self.waits.setdefault(rec.priority, []).append(
                rec.exec_step - rec.enq_step)

    # -- spawn / quiescence (generator ops) ----------------------------------

    def spawn(self, ctx: Ctx, tid: int, spec: TaskSpec,
              shard: Optional[int] = None):
        """Register + account + enqueue a dynamically spawned task.  The
        OUTSTANDING increment happens *before* the install so the counter
        can never read zero while this task is invisible to consumers."""
        rec = self.register(spec.payload, spec.priority, spec.cost,
                            spec.deadline)
        yield from ctx.faa(OUTSTANDING, 0, 1)
        yield from self.enqueue_task(ctx, tid, rec, shard)
        return rec

    def complete(self, ctx: Ctx, tid: int):
        """Retire a task (decrement OUTSTANDING).  Call only after all of the
        task's children were spawned — spawn-before-complete is what makes
        the zero-read a sound quiescence certificate."""
        yield from ctx.faa(OUTSTANDING, 0, NEG1)

    def outstanding(self, ctx: Ctx, tid: int):
        v = yield from ctx.load(OUTSTANDING, 0)
        return v

    # -- reporting -----------------------------------------------------------

    def steal_rate(self) -> float:
        return self.metrics.steals / max(self.metrics.dequeues, 1)

    def wait_stats(self) -> Dict[str, float]:
        return _wait_stats(self.waits)


class TaskFabric(_FabricBase):
    """K shards × L priority lanes of bounded rings + the host task table."""

    def __init__(self, *, algo: str = "glfq", shards: int = 4, lanes: int = 2,
                 capacity_per_shard: int = 256, num_threads: int = 32,
                 wave_size: int = 8, steal: bool = True,
                 queue_kw: Optional[dict] = None) -> None:
        if algo not in QUEUE_CLASSES:
            raise ValueError(f"unknown algo {algo!r}; pick from {list(QUEUE_CLASSES)}")
        super().__init__(shards=shards, wave_size=wave_size)
        self.algo = algo
        self.lanes = lanes
        self.capacity_per_shard = capacity_per_shard
        self.steal = steal
        qcls = QUEUE_CLASSES[algo]
        kw = dict(queue_kw or {})
        self.rings = {
            (lane, s): qcls(capacity_per_shard, num_threads,
                            tag=f"rt_{algo}_l{lane}s{s}", **kw)
            for lane in range(lanes) for s in range(shards)
        }
        self.shard_history: Dict[Tuple[int, int], List[HistoryEvent]] = {
            key: [] for key in self.rings
        }

    # -- lifecycle -----------------------------------------------------------

    def validate_priority(self, priority: int) -> int:
        if not 0 <= priority < self.lanes:
            raise ValueError(
                f"priority {priority} out of range [0, {self.lanes}) — "
                f"lanes are not clamped; pick a valid lane")
        return priority

    def init(self, mem, sched: Scheduler, initial_outstanding: int = 0) -> None:
        self.sched = sched
        for ring in self.rings.values():
            ring.init(mem)
        mem.alloc(OUTSTANDING, 1, fill=initial_outstanding)
        # Occupancy hints gate idle polling: a consumer only issues a real
        # dequeue against a ring whose hint is nonzero.  This is the
        # persistent-kernel analogue of sCQ's Threshold — without it, idle
        # workers hammer EMPTY dequeues, which on ticket-based designs
        # (G-WFQ-YMC's FAA head) burn unbounded tickets while the queue
        # sits empty.  The hint is conservative (incremented *after* a
        # successful install, decremented after a successful take), so a
        # skipped poll never hides a task for longer than one scan.
        mem.alloc(HINTS, self.lanes * self.shards, fill=0)

    def register(self, payload: Any, priority: int = 1, cost: int = 0,
                 deadline: Optional[int] = None) -> TaskRecord:
        self.validate_priority(priority)
        tid = len(self.tasks)
        assert tid <= VAL_MASK, "task table exceeded the 31-bit id space"
        rec = TaskRecord(tid, payload, priority, cost, deadline)
        self.tasks.append(rec)
        return rec

    # -- history plumbing ----------------------------------------------------

    def _file(self, lane: int, shard: int) -> None:
        # op_end just appended the event to the global history; cross-file it
        # under the ring it actually targeted for per-shard checking.
        if self.sched is not None and self.sched.history:
            self.shard_history[(lane, shard)].append(self.sched.history[-1])

    # -- generator ops (driven by the Scheduler) ------------------------------

    def enqueue_task(self, ctx: Ctx, tid: int, rec: TaskRecord,
                     shard: Optional[int] = None):
        """Place a task id onto its lane, home shard first, overflowing to
        the other shards, retrying (with backoff) under full backpressure.
        Never drops: returns only after the id is installed."""
        lane = rec.priority
        home = self.home_shard(tid) if shard is None else shard
        while True:
            for k in range(self.shards):
                s = (home + k) % self.shards
                ring = self.rings[(lane, s)]
                yield from ctx.op_begin(ENQ, rec.task_id)
                ok = yield from ring.enqueue(ctx, tid, rec.task_id)
                yield from ctx.op_end(ok, ok)
                self._file(lane, s)
                if ok:
                    yield from ctx.faa(HINTS, lane * self.shards + s, 1)
                    self._record_install(rec)
                    return s
            self.metrics.enq_retries += 1
            yield from ctx.step()      # every shard full: back off and retry

    def acquire(self, ctx: Ctx, tid: int):
        """Dequeue one task: urgent lane first, home shard first, stealing
        from sibling shards when enabled.  Returns a TaskRecord or None."""
        home = self.home_shard(tid)
        scan = self.shards if self.steal else 1
        for lane in range(self.lanes):
            for k in range(scan):
                s = (home + k) % self.shards
                hint = yield from ctx.load(HINTS, lane * self.shards + s)
                if hint == 0:
                    continue                  # poll gate: ring almost surely empty
                ring = self.rings[(lane, s)]
                yield from ctx.op_begin(DEQ, None)
                ok, v = yield from ring.dequeue(ctx, tid)
                yield from ctx.op_end(v if ok else None, ok)
                self._file(lane, s)
                if k > 0:
                    self.metrics.steal_scans += 1
                if ok:
                    yield from ctx.faa(HINTS, lane * self.shards + s, NEG1)
                    self.metrics.dequeues += 1
                    key = (lane, s)
                    self.metrics.per_shard_deq[key] = (
                        self.metrics.per_shard_deq.get(key, 0) + 1)
                    if k > 0:
                        self.metrics.steals += 1
                    rec = self.tasks[v]
                    self._record_acquire(rec)
                    return rec
        self.metrics.empty_scans += 1
        return None


def _wait_stats(waits: Dict[int, List[int]]) -> Dict[str, float]:
    """Queue-wait starvation metrics by class (0 = urgent, ≥1 = normal)."""
    def pct(xs: List[int], q: float) -> float:
        if not xs:
            return 0.0
        ys = sorted(xs)
        return float(ys[min(len(ys) - 1, int(q * len(ys)))])

    urgent = waits.get(0, [])
    normal = [w for cls, xs in waits.items() if cls != 0 for w in xs]
    return {
        "urgent_max_wait": float(max(urgent, default=0)),
        "urgent_p99_wait": pct(urgent, 0.99),
        "normal_max_wait": float(max(normal, default=0)),
        "normal_p99_wait": pct(normal, 0.99),
        "normal_mean_wait": (sum(normal) / len(normal)) if normal else 0.0,
    }


# ---------------------------------------------------------------------------
# Priority fabric (DESIGN.md § 5.4): policy-keyed G-PQ shards
# ---------------------------------------------------------------------------


class PriorityFabric(_FabricBase):
    """K shards of G-PQ min-heaps + the host task table — the priority
    replacement for ``TaskFabric``'s strict lanes.  Drop-in for
    ``TaskRuntime``: same generator protocol (``enqueue_task`` /
    ``acquire`` / ``spawn`` / ``complete`` / ``outstanding``).

    A ``PriorityPolicy`` (strict | weighted | edf, ``repro.sched.policy``)
    maps each task's (class, deadline) to the integer min-key the shards
    order by, so lane semantics become a pure key encoding:

    * placement mirrors ``TaskFabric``: wave-affinity homes for spawned
      children, round-robin spray for external arrivals, overflow to
      sibling shards, retry under full backpressure;
    * **stealing is highest-priority-first**: an acquire reads every
      shard's min-key hint and scans shards in ascending-hint order
      (home shard breaks ties), so a steal always goes after the most
      urgent visible work rather than ring order;
    * every shard op is bracketed into the § IV history and filed
      per shard; each shard history is independently checkable with
      ``sched.check_p_linearizable`` at k = 0 (strict shards) or the
      shard's exact lazy bound.

    Starvation accounting: queue waits (install → acquire, in scheduler
    steps) are recorded per class; ``wait_stats()`` feeds the § V-C
    starvation metrics (max / p99 wait per class).
    """

    def __init__(self, *, policy="edf", shards: int = 4,
                 capacity_per_shard: int = 256, num_threads: int = 32,
                 wave_size: int = 8, steal: bool = True, arity: int = 4,
                 lazy: int = 0) -> None:
        super().__init__(shards=shards, wave_size=wave_size)
        self.policy = make_policy(policy)
        self.capacity_per_shard = capacity_per_shard
        self.steal = steal
        self.lazy = lazy
        self.pqs = {
            s: GPQ(capacity_per_shard, num_threads,
                   tag=f"pf_{self.policy.name}_s{s}", arity=arity, lazy=lazy)
            for s in range(shards)
        }
        self.shard_history: Dict[int, List[HistoryEvent]] = {
            s: [] for s in range(shards)
        }

    # -- lifecycle -----------------------------------------------------------

    def init(self, mem, sched: Scheduler, initial_outstanding: int = 0) -> None:
        self.sched = sched
        for pq in self.pqs.values():
            pq.init(mem)
        mem.alloc(OUTSTANDING, 1, fill=initial_outstanding)

    def validate_priority(self, priority: int) -> int:
        return self.policy.validate(priority)

    def validate_deadline(self, deadline: Optional[int]) -> Optional[int]:
        fmt = next(iter(self.pqs.values())).fmt
        if deadline is not None and not 0 <= deadline < fmt.key_inf:
            raise ValueError(
                f"deadline {deadline} outside the node key range "
                f"[0, {fmt.key_inf})")
        return deadline

    def register(self, payload: Any, priority: int = 1, cost: int = 0,
                 deadline: Optional[int] = None) -> TaskRecord:
        self.validate_deadline(deadline)
        now = self.sched.step_count if self.sched is not None else 0
        key = self.policy.key(priority, deadline, now)  # validates the class
        tid = len(self.tasks)
        fmt = next(iter(self.pqs.values())).fmt
        if tid > fmt.idx_mask:
            raise ValueError("task table exceeded the node idx space")
        if not 0 <= key < fmt.key_inf:
            raise ValueError(f"policy key {key} exceeds the node key range "
                             f"[0, {fmt.key_inf})")
        rec = TaskRecord(tid, payload, priority, cost, deadline, key=key)
        self.tasks.append(rec)
        return rec

    def _file(self, shard: int) -> None:
        if self.sched is not None and self.sched.history:
            self.shard_history[shard].append(self.sched.history[-1])

    # -- generator ops -------------------------------------------------------

    def enqueue_task(self, ctx: Ctx, tid: int, rec: TaskRecord,
                     shard: Optional[int] = None):
        home = self.home_shard(tid) if shard is None else shard
        backoff = 1
        while True:
            for k in range(self.shards):
                s = (home + k) % self.shards
                ok = yield from self.pqs[s].insert(ctx, tid, rec.key,
                                                   rec.task_id)
                self._file(s)
                if ok:
                    self._record_install(rec)
                    return s
            self.metrics.enq_retries += 1
            # every shard full: exponential backoff so admission
            # backpressure does not burn steps hammering full heaps
            for _ in range(backoff):
                yield from ctx.step()
            backoff = min(backoff * 2, 64)

    def acquire(self, ctx: Ctx, tid: int):
        """Pop one task, most-urgent-visible shard first: scan order is
        ascending min-key hint (steal-highest-priority-first), home shard
        breaking ties."""
        home = self.home_shard(tid)
        if self.steal and self.shards > 1:
            order = []
            for s, pq in self.pqs.items():
                h = yield from pq.peek_hint(ctx, tid)
                order.append((h, (s - home) % self.shards, s))
            order.sort()
            scan = [s for _, _, s in order]
        else:
            scan = [home]
        for rank, s in enumerate(scan):
            ok, got = yield from self.pqs[s].delete_min(ctx, tid)
            self._file(s)
            if rank > 0:
                self.metrics.steal_scans += 1
            if ok:
                _, idx = got
                self.metrics.dequeues += 1
                self.metrics.per_shard_deq[(0, s)] = (
                    self.metrics.per_shard_deq.get((0, s), 0) + 1)
                if s != home:
                    self.metrics.steals += 1
                rec = self.tasks[idx]
                self._record_acquire(rec)
                return rec
        self.metrics.empty_scans += 1
        return None


# ---------------------------------------------------------------------------
# Host-thread twin (serving admission)
# ---------------------------------------------------------------------------


class HostTaskPool:
    """The same sharded/laned/stealing fabric for real host threads, built
    from ``HostRing``s (DESIGN.md § 4.5).  API mirrors ``HostRing`` so it
    drops into the serving engine: ``enqueue(item, timeout=, priority=)``,
    ``dequeue(timeout=, affinity=)``, ``empty()``.

    ``dequeue`` scans lane 0 across every shard before lane 1 (strict
    priority), starting from the caller's affinity shard and stealing in
    ring order."""

    def __init__(self, capacity: int, *, shards: int = 2, lanes: int = 2) -> None:
        self.shards = shards
        self.lanes = lanes
        per = max(1, -(-capacity // shards))
        self.rings = {(lane, s): HostRing(per)
                      for lane in range(lanes) for s in range(shards)}
        self.capacity = per * shards
        self.metrics = {"enqueues": 0, "dequeues": 0, "steals": 0,
                        "rejects": 0}
        self._rr = itertools.count()

    def enqueue(self, item, timeout: Optional[float] = None,
                priority: int = 1) -> bool:
        lane = min(max(priority, 0), self.lanes - 1)
        home = next(self._rr) % self.shards
        for k in range(self.shards):
            s = (home + k) % self.shards
            # only the last candidate shard gets the blocking timeout;
            # earlier ones are polled so overflow can migrate
            t = timeout if k == self.shards - 1 else 0.0
            if self.rings[(lane, s)].enqueue(item, timeout=t):
                self.metrics["enqueues"] += 1
                return True
        self.metrics["rejects"] += 1
        return False

    def _scan(self, home: int):
        for lane in range(self.lanes):
            for k in range(self.shards):
                s = (home + k) % self.shards
                item = self.rings[(lane, s)].dequeue(timeout=0.0)
                if item is not None:
                    self.metrics["dequeues"] += 1
                    self.metrics["steals"] += int(k > 0)
                    return item
        return None

    def dequeue(self, timeout: Optional[float] = None, affinity: int = 0):
        """Non-blocking priority scan; with a timeout, keep re-scanning all
        lanes/shards until the deadline so a late urgent arrival on any ring
        is seen (strict lane order is preserved on every scan)."""
        import time as _time
        home = affinity % self.shards
        item = self._scan(home)
        if item is not None or not timeout:
            return item
        deadline = _time.time() + timeout
        while _time.time() < deadline:
            _time.sleep(min(0.002, timeout))
            item = self._scan(home)
            if item is not None:
                return item
        return None

    def empty(self) -> bool:
        return all(r.empty() for r in self.rings.values())

    def close(self) -> None:
        for r in self.rings.values():
            r.close()
