"""BFS frontier expansion as a Pallas TPU kernel (paper § V-B-a).

The level-synchronous BFS dequeues the current frontier, scans CSR
neighbors, marks unvisited vertices and enqueues them into the next
frontier.  The next-frontier enqueue is queue-style ticket reservation: each
accepted vertex takes ticket = running count (one logical FAA per accepted
vertex, batched per frontier vertex — the wave-batched discipline).

The kernel walks the frontier sequentially (grid=(1,), fori_loop) with the
visited bitmap and output frontier resident in VMEM; the CSR neighbor lists
are streamed via dynamic slices.  VMEM budget: visited (n int32) + frontier
buffers; n ≤ 1M fits in 4 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _frontier_kernel(max_out, row_ptr_ref, col_idx_ref, frontier_ref,
                     visited_in, out_ref, visited_ref, count_ref):
    visited_ref[...] = visited_in[...]
    out_ref[...] = jnp.full_like(out_ref, -1)
    count_ref[0] = 0
    f = frontier_ref.shape[1]

    def vbody(i, _):
        u = frontier_ref[0, i]
        valid = u >= 0
        uu = jnp.maximum(u, 0)
        start = jnp.where(valid, row_ptr_ref[0, uu], 0)
        stop = jnp.where(valid, row_ptr_ref[0, uu + 1], 0)

        def ebody(k, _):
            v = col_idx_ref[0, k]
            fresh = visited_ref[0, v] == 0
            visited_ref[0, v] = 1
            cnt = count_ref[0]
            # ticket reservation: accepted vertex takes slot = cnt
            pos = jnp.where(fresh, jnp.minimum(cnt, max_out - 1), max_out - 1)
            old = out_ref[0, pos]
            out_ref[0, pos] = jnp.where(fresh, v, old)
            count_ref[0] = cnt + fresh.astype(jnp.int32)
            return 0

        jax.lax.fori_loop(start, stop, ebody, 0)
        return 0

    jax.lax.fori_loop(0, f, vbody, 0)


@functools.partial(jax.jit, static_argnames=("max_out", "interpret"))
def frontier_expand(row_ptr, col_idx, frontier, visited, *, max_out: int,
                    interpret: bool = True):
    """row_ptr: (n+1,), col_idx: (E,), frontier: (F,) padded with -1,
    visited: (n,) int32 bitmap.  Returns (next_frontier (max_out,),
    count (1,), visited')."""
    n = visited.shape[0]
    f = frontier.shape[0]
    e = col_idx.shape[0]
    kern = functools.partial(_frontier_kernel, max_out)
    out, vis, cnt = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, n + 1), lambda i: (0, 0)),
            pl.BlockSpec((1, e), lambda i: (0, 0)),
            pl.BlockSpec((1, f), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, max_out), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, max_out), jnp.int32),
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(row_ptr.reshape(1, n + 1), col_idx.reshape(1, e),
      frontier.reshape(1, f), visited.reshape(1, n))
    return out.reshape(max_out), cnt, vis.reshape(n)
