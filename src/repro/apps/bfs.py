"""Level-synchronous BFS with queue-managed frontiers (paper § V-B-a).

Two implementations over CSR graphs:

* ``bfs_queue`` — the paper's design: two frontier queues alternate across
  levels; frontier expansion is the Pallas ``frontier_expand`` kernel whose
  next-frontier enqueue is ticket reservation (aggregate-then-commit).
* ``bfs_baseline`` — the Gunrock-style stand-in: dense boolean frontier
  masks with a segment-sum sweep over all vertices per level (no queue) —
  the comparison baseline for benchmarks/bench_bfs.py.

Synthetic graph generators mirror the Table IV families: road-like (low
degree, high diameter), kron/social-like (power-law), delaunay-like
(constant degree).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops


@dataclass
class CSRGraph:
    row_ptr: np.ndarray  # (n+1,) int32
    col_idx: np.ndarray  # (m,) int32
    name: str = "g"

    @property
    def n(self) -> int:
        return len(self.row_ptr) - 1

    @property
    def m(self) -> int:
        return len(self.col_idx)


def road_like(n: int, seed: int = 0) -> CSRGraph:
    """Grid-ish graph: low avg degree, long diameter (road_usa family)."""
    side = int(np.sqrt(n))
    n = side * side
    rows, cols = [], []
    for v in range(n):
        r, c = divmod(v, side)
        for dr, dc in ((0, 1), (1, 0), (0, -1), (-1, 0)):
            rr, cc = r + dr, c + dc
            if 0 <= rr < side and 0 <= cc < side:
                rows.append(v)
                cols.append(rr * side + cc)
    return _to_csr(n, rows, cols, f"road_{n}")


def kron_like(n: int, avg_deg: int = 16, seed: int = 0) -> CSRGraph:
    """Power-law graph (kron_g500 / hollywood family)."""
    rng = np.random.default_rng(seed)
    m = n * avg_deg
    # preferential-attachment-ish: sample endpoints from a zipf-weighted pool
    w = 1.0 / np.arange(1, n + 1) ** 0.6
    p = w / w.sum()
    src = rng.choice(n, m, p=p)
    dst = rng.choice(n, m, p=p)
    keep = src != dst
    return _to_csr(n, src[keep], dst[keep], f"kron_{n}")


def delaunay_like(n: int, deg: int = 6, seed: int = 0) -> CSRGraph:
    """Constant-degree random graph (delaunay family)."""
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, n * deg)
    return _to_csr(n, src, dst, f"delaunay_{n}")


def _to_csr(n: int, rows, cols, name: str) -> CSRGraph:
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    order = np.argsort(rows, kind="stable")
    rows, cols = rows[order], cols[order]
    row_ptr = np.zeros(n + 1, np.int32)
    np.add.at(row_ptr, rows + 1, 1)
    row_ptr = np.cumsum(row_ptr).astype(np.int32)
    return CSRGraph(row_ptr, cols.astype(np.int32), name)


# ---------------------------------------------------------------------------


def bfs_queue(g: CSRGraph, source: int = 0, *, use_kernel: bool = True
              ) -> Tuple[np.ndarray, Dict]:
    """Queue-driven BFS: alternate two frontier queues across levels."""
    n = g.n
    row_ptr = jnp.asarray(g.row_ptr)
    col_idx = jnp.asarray(g.col_idx)
    visited = jnp.zeros(n, jnp.int32).at[source].set(1)
    dist = np.full(n, -1, np.int32)
    dist[source] = 0
    frontier = jnp.full(max(n, 16), -1, jnp.int32).at[0].set(source)
    level, edges_scanned = 0, 0
    flen = 1
    while flen > 0:
        nxt, cnt, visited = ops.frontier_expand(
            row_ptr, col_idx, frontier, visited, max_out=max(n, 16),
            use_kernel=use_kernel)
        flen = int(cnt[0])
        level += 1
        f_np = np.asarray(nxt[:flen])
        edges_scanned += int(np.sum(g.row_ptr[np.asarray(frontier[frontier >= 0]) + 1]
                                    - g.row_ptr[np.asarray(frontier[frontier >= 0])]))
        dist[f_np] = level
        frontier = nxt
    return dist, {"levels": level, "edges_scanned": edges_scanned}


def bfs_baseline(g: CSRGraph, source: int = 0) -> Tuple[np.ndarray, Dict]:
    """Gunrock-style dense sweep: per level, scatter frontier over all edges
    with a boolean mask (no queue, no compaction)."""
    n = g.n
    row_ptr, col_idx = g.row_ptr, g.col_idx
    # edge source vector
    src = np.repeat(np.arange(n, dtype=np.int32),
                    np.diff(row_ptr).astype(np.int64))
    src_j = jnp.asarray(src)
    col_j = jnp.asarray(col_idx)
    front = jnp.zeros(n, jnp.bool_).at[source].set(True)
    visited = front
    dist = np.full(n, -1, np.int32)
    dist[source] = 0
    level = 0

    @jax.jit
    def sweep(front, visited):
        active = front[src_j]
        touched = jnp.zeros(n, jnp.bool_).at[col_j].max(active)
        new = touched & (~visited)
        return new, visited | new

    while bool(front.any()):
        front, visited = sweep(front, visited)
        level += 1
        newly = np.asarray(front)
        dist[newly & (dist == -1)] = level
        if not newly.any():
            break
    return dist, {"levels": level}


def bfs_runtime(g: CSRGraph, source: int = 0, *, algo: str = "glfq",
                shards: int = 4, workers: int = 16, steal: bool = True,
                policy: str = "gang", seed: int = 0
                ) -> Tuple[np.ndarray, Dict]:
    """Task-runtime BFS: frontier expansion as dynamically spawned tasks on
    the sharded fabric (DESIGN.md § 4.5).

    One task = relax one vertex; its handler scans the adjacency list
    (simulated cost = degree, so power-law graphs yield power-law task
    costs) and spawns a child for every neighbour whose tentative distance
    improves (the handler runs atomically between simulator instructions —
    the host stand-in for an atomic min on the distance array).  Unlike
    ``bfs_queue`` there is no level barrier: the fabric's interleaving may
    discover a vertex via a long path first, and the asynchronous relaxation
    re-spawns it when a shorter path arrives — distances are exact at
    quiescence (monotone label-correcting, Wang et al.'s dynamic
    load-balancing discipline), while the *fabric* still executes every
    spawned task exactly once."""
    from ..runtime import ExecutorConfig, TaskFabric, TaskRuntime, TaskSpec

    dist = np.full(g.n, -1, np.int32)
    dist[source] = 0
    edges_scanned = 0

    def handler(rec):
        nonlocal edges_scanned
        v = rec.payload
        dv = int(dist[v])
        lo, hi = int(g.row_ptr[v]), int(g.row_ptr[v + 1])
        edges_scanned += hi - lo
        children = []
        for w in g.col_idx[lo:hi]:
            w = int(w)
            nd = dv + 1
            if dist[w] < 0 or nd < dist[w]:   # atomic relax (host = one step)
                dist[w] = nd
                deg_w = int(g.row_ptr[w + 1]) - int(g.row_ptr[w])
                children.append(TaskSpec(w, cost=max(deg_w, 1)))
        return children

    fabric = TaskFabric(algo=algo, shards=shards,
                        capacity_per_shard=max(2 * g.n // max(shards, 1), 64),
                        num_threads=workers + 1, steal=steal)
    rt = TaskRuntime(fabric, handler,
                     ExecutorConfig(workers=workers, policy=policy, seed=seed,
                                    max_steps=50_000_000))
    rt.add_task(source,
                cost=max(int(g.row_ptr[source + 1]) - int(g.row_ptr[source]), 1))
    metrics = rt.run()
    info = {"tasks": len(rt.executed), "edges_scanned": edges_scanned,
            "steal_rate": metrics["steal_rate"],
            "idle_steps": metrics["idle_steps"],
            "load_imbalance": metrics["load_imbalance"],
            "throughput_ops_per_kstep": metrics["throughput_ops_per_kstep"]}
    return dist, info


def bfs_rounds_runner(g: CSRGraph, *, batch: int = 64, fused: bool = True,
                      interpret=None, sync_every: int = 0):
    """Build the round-engine BFS runner for ``g`` (see ``bfs_rounds``).
    Returns ``(runner, init_fn)`` where ``init_fn(source)`` produces the
    distance accumulator — callers that run BFS repeatedly (benchmarks)
    reuse the runner to amortize the megaround compilation."""
    from ..runtime import RoundRunner

    n = g.n
    deg = np.diff(g.row_ptr).astype(np.int64)
    fan = max(int(deg.max()) if n else 0, 1)
    nbr = np.full((n, fan), -1, np.int32)
    rows = np.repeat(np.arange(n), deg)
    pos = np.arange(g.m) - np.repeat(g.row_ptr[:-1].astype(np.int64), deg)
    nbr[rows, pos] = g.col_idx
    nbr_j = jnp.asarray(nbr)
    big = np.iinfo(np.int32).max

    def step(dist, vals, valid):
        v = jnp.where(valid, vals, 0)
        dv = jnp.where(valid, dist[v], 0)
        w = jnp.where(valid[:, None], nbr_j[v], -1)          # (B, F)
        wc = jnp.clip(w, 0, n - 1)
        eligible = (w >= 0) & (dist[wc] < 0)
        b, f = w.shape
        wf = w.reshape(-1)
        elig_f = eligible.reshape(-1)
        tgt = jnp.where(elig_f, wf, n)                       # n = trash slot
        order = jnp.arange(b * f, dtype=jnp.int32)
        claim = jnp.full((n + 1,), big, jnp.int32).at[tgt].min(order)
        win = elig_f & (claim[tgt] == order)                 # first parent
        ndist = jnp.repeat(dv + 1, f)
        dist = dist.at[jnp.where(win, wf, n)].set(ndist, mode="drop")
        return dist, wc, win.reshape(b, f)

    capacity_log2 = max(int(np.ceil(np.log2(max(n + 1, 2 * batch)))), 4)
    runner = RoundRunner(step, capacity_log2=capacity_log2, batch=batch,
                         fused=fused, interpret=interpret,
                         sync_every=sync_every)

    def init_fn(source: int):
        return jnp.full((n,), -1, jnp.int32).at[source].set(0)

    return runner, init_fn


def bfs_rounds(g: CSRGraph, source: int = 0, *, batch: int = 64,
               fused: bool = True, interpret=None, sync_every: int = 0,
               max_rounds: int = 100_000) -> Tuple[np.ndarray, Dict]:
    """BFS on the deterministic round engine (DESIGN.md § 4.3): the ring
    carries vertex ids, one jitted step relaxes a batch of vertices against
    a dense padded adjacency table and spawns the neighbours it newly
    claims.  Within a batch, a vertex reached by several parents goes to
    the row-major-first parent (a scatter-min claim) — the batched analogue
    of the sequential queue's first-visit rule, so distances are exact.

    ``fused=True`` (default) runs the whole loop device-resident with host
    sync only at quiescence; ``fused=False`` is the legacy per-round path.
    Both are bit-identical."""
    runner, init_fn = bfs_rounds_runner(g, batch=batch, fused=fused,
                                        interpret=interpret,
                                        sync_every=sync_every)
    dist, _ = runner.run([source], acc=init_fn(source),
                         max_rounds=max_rounds)
    return np.asarray(dist), dict(runner.stats)


def bfs_reference(g: CSRGraph, source: int = 0) -> np.ndarray:
    """Plain numpy BFS oracle."""
    from collections import deque
    dist = np.full(g.n, -1, np.int32)
    dist[source] = 0
    dq = deque([source])
    while dq:
        u = dq.popleft()
        for k in range(g.row_ptr[u], g.row_ptr[u + 1]):
            v = g.col_idx[k]
            if dist[v] < 0:
                dist[v] = dist[u] + 1
                dq.append(v)
    return dist
