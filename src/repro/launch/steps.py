"""Step builders: train_step / prefill_step / serve_step with their
sharding specs for any (architecture × input shape × mesh) cell.

Sharding strategy (DESIGN.md § 6): DP over ("pod","data"); Megatron TP over
"model" (head/ff/vocab-sharded per `models.param_specs`); FSDP (ZeRO-3 param
+ optimizer-state sharding over "data") for the large configs; decode caches
batch-sharded when the batch covers the DP axes, else sequence-sharded over
every mesh axis (long_500k, batch 1).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import SHAPES, ArchConfig
from ..models import (decode_step, init_decode_cache, init_params, loss_fn,
                      param_specs, prefill)
from ..optim import adamw
from .mesh import dp_axes, dp_size

# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def _dp(mesh) -> Tuple[str, ...]:
    return dp_axes(mesh)


def sanitize_pspecs(spec_tree, struct_tree, mesh):
    """Drop shardings whose mesh-axis product does not divide the dimension
    (explicit in_shardings require exact divisibility: e.g. a 50280-entry
    vocab cannot be 16-way sharded; granite's 40 experts cannot split over
    16 — those fall back to replication and the roofline shows the cost)."""
    def fix(spec, st):
        if not isinstance(spec, P):
            return spec
        dims = st.shape
        new = []
        for i, ax in enumerate(spec):
            if ax is None or i >= len(dims):
                new.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            new.append(ax if dims[i] % size == 0 else None)
        return P(*new)

    return jax.tree.map(fix, spec_tree, struct_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_struct(cfg: ArchConfig, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.audio_frontend:
        out["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.family == "vlm":
        out["img"] = jax.ShapeDtypeStruct((b, cfg.n_image_tokens, cfg.d_model),
                                          jnp.bfloat16)
    return out


def batch_pspecs(cfg: ArchConfig, shape_name: str, mesh) -> Dict[str, P]:
    dp = _dp(mesh)
    sh = SHAPES[shape_name]
    b = sh["global_batch"]
    bs = dp if b % max(dp_size(mesh), 1) == 0 else ()
    out: Dict[str, P] = {}
    if cfg.audio_frontend:
        out["frames"] = P(bs, None, None)
    else:
        out["tokens"] = P(bs, None)
    out["labels"] = P(bs, None)
    if cfg.family == "vlm":
        out["img"] = P(bs, None, None)
    return out


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, opt_cfg: Optional[adamw.AdamWConfig] = None,
                    pspecs=None):
    opt_cfg = opt_cfg or adamw.AdamWConfig()

    def train_step(state: adamw.OptState, batch: Dict[str, jax.Array]):
        params = adamw.cast_params(state.master)
        if pspecs is not None:
            # pin the bf16 working copy to the FSDP/TP layout so GSPMD
            # all-gathers per layer inside the scan (ZeRO-3), instead of
            # materializing the full unsharded parameter stacks
            params = jax.lax.with_sharding_constraint(params, pspecs)
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        if pspecs is not None:
            grads = jax.lax.with_sharding_constraint(grads, pspecs)
        new_state, metrics = adamw.step(opt_cfg, state, grads)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step


def state_struct(cfg: ArchConfig) -> adamw.OptState:
    """Optimizer-state ShapeDtypeStructs via eval_shape (no allocation)."""
    def build():
        return adamw.init(init_params(cfg))
    return jax.eval_shape(build)


def state_pspecs(cfg: ArchConfig) -> adamw.OptState:
    specs = param_specs(cfg)
    return adamw.OptState(master=specs,
                          m=jax.tree.map(lambda s: s, specs),
                          v=jax.tree.map(lambda s: s, specs),
                          step=P())


# ---------------------------------------------------------------------------
# serve steps (prefill + decode)
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return prefill(params, batch.get("tokens"), cfg,
                       img=batch.get("img"), frames=batch.get("frames"))
    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, cache, token, cur, img=None):
        return decode_step(params, cache, token, cur, cfg, img=img)
    return serve_step


def cache_struct(cfg: ArchConfig, shape_name: str):
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    return jax.eval_shape(lambda: init_decode_cache(cfg, b, s))


def cache_pspecs(cfg: ArchConfig, shape_name: str, mesh):
    """Batch-sharded when possible; else sequence-sharded over all axes."""
    sh = SHAPES[shape_name]
    b = sh["global_batch"]
    dp = _dp(mesh)
    batch_ok = b % max(dp_size(mesh), 1) == 0
    model = mesh.shape.get("model", 1)
    all_axes = tuple(mesh.axis_names)

    def kv_spec(ndim: int) -> P:
        # (B, S, kv, hd).  Never shard S: the decode ring-buffer write is a
        # dynamic_update_slice at a traced index, and GSPMD handles a DUS
        # on a sharded dim by fully rematerializing the cache every step
        # (§Perf hillclimb #3: gemma3 long_500k spent 44 GB/step on it).
        # Prefer kv-heads on "model"; else head_dim over as many axes as
        # divide it; else leave replicated (small caches only).
        if batch_ok:
            if cfg.n_kv_heads and cfg.n_kv_heads % model == 0:
                return P(dp, None, "model", None)
            if cfg.hd % model == 0:
                return P(dp, None, None, "model")
            return P(dp, None, None, None)
        # batch == 1 (long-context): sequence-sharded over the whole mesh;
        # the mask-select cache write keeps every step's collective tiny
        return P(None, all_axes, None, None)

    def entry_specs(entry):
        sp = {}
        for k, v in entry.items():
            if k in ("k", "v"):
                sp[k] = kv_spec(len(v.shape))
            elif k == "ssm":  # (B, nh, hd, st)
                nh = cfg.ssm_nheads
                if batch_ok:
                    sp[k] = (P(dp, "model", None, None)
                             if nh % model == 0 else P(dp, None, None, None))
                else:
                    sp[k] = (P(None, "model", None, None)
                             if nh % model == 0 else P(None, None, None, None))
            else:  # conv state (B, K-1, C)
                sp[k] = P(dp, None, None) if batch_ok else P(None, None, None)
        return sp

    struct = cache_struct(cfg, shape_name)
    return [entry_specs(e) for e in struct]


def token_pspecs(cfg: ArchConfig, shape_name: str, mesh):
    sh = SHAPES[shape_name]
    b = sh["global_batch"]
    dp = _dp(mesh)
    bs = dp if b % max(dp_size(mesh), 1) == 0 else ()
    return P(bs, None)


def params_struct(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params(cfg))
