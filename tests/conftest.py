"""Shared fixtures: the fused-engine matrix.

``ENGINE_REGISTRY`` (repro.runtime.enginecore) enumerates every runner
configuration — FIFO/priority x single-host/mesh x replicated/sharded.
The ``engine_case`` fixture parametrizes a test over the whole matrix so
engine-generic invariants (telemetry-off bit-identity, drain/stat
agreement, deprecation coverage) are written once instead of copy-pasted
per engine.  New engines self-register at import and are picked up here
with zero test edits.
"""

import os
import sys

import pytest

sys.path.insert(
    0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.jaxcompat import make_mesh  # noqa: E402
from repro.runtime import ENGINE_REGISTRY  # noqa: E402
import repro.serving.admission  # noqa: E402,F401  registers "serving" row


def _fifo_fanout_step():
    def step(acc, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        cm = (valid & (vals < 32))[:, None]
        return acc, cv, cm
    return step


def _priority_fanout_step():
    def step(acc, keys, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        ck = (cv * 7919) % 1000
        cm = (valid & (vals < 32))[:, None]
        return acc, ck, cv, cm
    return step


class EngineCase:
    """One engine-matrix row bound to the standard fanout workload.

    ``build(**obs)`` constructs the runner (telemetry=/spans= pass
    through); ``run(runner)`` drives it to quiescence and returns
    ``(acc, final_state, stats)``.  Mesh rows run on a 1-device mesh with
    ``combine=sum-over-shards`` so acc shapes match the host rows.
    """

    def __init__(self, entry):
        self.entry = entry
        self.name = entry.name

    def build(self, **obs):
        kw = dict(self.entry.kwargs, capacity_log2=8, batch=16, **obs)
        if self.entry.mesh:
            kw["mesh"] = make_mesh((1,), ("data",))
            kw["combine"] = lambda a: a.sum(0)
        step = (_priority_fanout_step() if self.entry.priority
                else _fifo_fanout_step())
        return self.entry.runner(step, **kw)

    def run(self, runner):
        acc0 = jnp.zeros(80, jnp.int32)
        if self.entry.priority:
            acc, st = runner.run([7919 % 1000], [1], acc=acc0)
        else:
            acc, st = runner.run([1], acc=acc0)
        return acc, st, dict(runner.stats)


class ServingEngineCase(EngineCase):
    """The serving-admission row: a tick-driven persistent engine with no
    constructor step_fn (the admission decision IS its step).  Driven
    here as ONE admission tick with unconstrained budgets, so it drains
    to quiescence like the other rows; acc is the admitted-index order
    (deterministic EDF at one shard), final state the heap planes."""

    def build(self, **obs):
        kw = dict(self.entry.kwargs, capacity_log2=8, batch=16,
                  table_log2=6, mesh=make_mesh((1,), ("data",)), **obs)
        return self.entry.runner(**kw)

    def run(self, runner):
        admitted = runner.tick([17, 5, 9, 13, 29, 3], [0, 1, 2, 3, 4, 5],
                               slots=16, pages=16, need=[1] * 6)
        return (jnp.asarray(admitted, jnp.int32), runner._state[0],
                dict(runner.stats))


@pytest.fixture(params=sorted(ENGINE_REGISTRY), ids=str)
def engine_case(request):
    entry = ENGINE_REGISTRY[request.param]
    cls = ServingEngineCase if request.param == "serving" else EngineCase
    return cls(entry)
