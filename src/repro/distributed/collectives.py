"""shard_map-level collectives: the distributed queue's aggregation
primitives and the compressed/overlapped data-parallel gradient sync.

These are the TPU-idiomatic renderings of the paper's coordination patterns
(DESIGN.md § 2.3): contention aggregation becomes an exclusive prefix sum
over the mesh axis (one collective round ≡ one wave-batched FAA), and the
cross-pod gradient all-reduce supports int8 error-feedback compression and
bucketed issue so communication overlaps the remaining backward compute.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import compression
from ..jaxcompat import axis_size as _axis_size


# ---------------------------------------------------------------------------
# hierarchical ticket aggregation (the cross-chip WAVEFAA)
# ---------------------------------------------------------------------------


def mesh_ticket_base(count: jax.Array, axis: str) -> Tuple[jax.Array, jax.Array]:
    """Inside shard_map: every participant contributes its request count;
    returns (exclusive prefix over the axis = this shard's ticket base,
    total).  One collective round hands out globally unique, ordered ticket
    blocks — the paper's leader-FAA one level up the hierarchy."""
    idx = jax.lax.axis_index(axis)
    n = _axis_size(axis)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (n,), 0) == idx)
    contrib = jnp.where(onehot, count, 0)
    sums = jax.lax.psum(contrib, axis)              # (n,) per-shard counts
    base = jnp.sum(jnp.where(jax.lax.broadcasted_iota(jnp.int32, (n,), 0) < idx,
                             sums, 0))
    return base, jnp.sum(sums)


def mesh_round_gather(blocks, axis: str):
    """Replicated gather of per-shard compact blocks in ONE psum.

    ``blocks`` is a tuple of (B_i,) int32 arrays (one round's local op
    payloads — values, masks, …).  Every shard scatters its concatenated
    blocks into its row of an (n, ΣB_i) zero buffer and the buffer is
    psum-reduced: each row has exactly one contributor, so the reduction is
    a bit-exact integer gather, and — unlike ``all_gather``, whose output
    the shard_map replication checker types as device-varying — the psum
    output is *replicated-typed*.  This is what lets the distqueue round
    state keep its ``P()`` out_spec with the checker on (no
    ``check_rep=False``).  Returns (n, B_i)-shaped arrays, one per block.
    Per-shard counts/ticket bases fall out of the gathered masks (a cumsum),
    so one call subsumes ``mesh_ticket_base`` + payload exchange — the whole
    round costs this single collective."""
    n = _axis_size(axis)
    me = jax.lax.axis_index(axis)
    widths = [int(b.shape[-1]) for b in blocks]
    row = jnp.concatenate([b.astype(jnp.int32) for b in blocks])
    buf = jnp.zeros((n, sum(widths)), jnp.int32).at[me].set(row)
    out = jax.lax.psum(buf, axis)
    split, off = [], 0
    for w in widths:
        split.append(out[:, off:off + w])
        off += w
    return tuple(split)


# ---------------------------------------------------------------------------
# compressed / bucketed gradient all-reduce (cross-pod DP)
# ---------------------------------------------------------------------------


def allreduce_mean(x: jax.Array, axis: str) -> jax.Array:
    return jax.lax.pmean(x, axis)


def allreduce_compressed(g: jax.Array, err: jax.Array, axis: str):
    """Error-feedback int8 all-reduce: quantize locally, mean-reduce the
    dequantized payload (the wire format is int8 + per-block scales — XLA
    reduces the dequantized f32 here; payload accounting uses
    ``compression.compression_ratio``), return (reduced, new_err)."""
    deq, new_err = compression.compress_with_feedback(g, err)
    return jax.lax.pmean(deq, axis), new_err


def tree_allreduce_compressed(grads: Any, errs: Any, axis: str):
    out = jax.tree.map(lambda g, e: allreduce_compressed(g, e, axis),
                       grads, errs)
    red = jax.tree.map(lambda p: p[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    new = jax.tree.map(lambda p: p[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return red, new


def bucketed_psum(leaves, axis: str, bucket_bytes: int = 1 << 25):
    """Issue psums in buckets (≈32 MiB) so each starts as soon as its
    gradients are ready — compute/communication overlap on the backward
    pass.  Returns reduced leaves in the original order."""
    order = sorted(range(len(leaves)), key=lambda i: leaves[i].size)
    out = [None] * len(leaves)
    bucket, bucket_sz = [], 0
    for i in order:
        bucket.append(i)
        bucket_sz += leaves[i].size * leaves[i].dtype.itemsize
        if bucket_sz >= bucket_bytes:
            red = jax.lax.psum(tuple(leaves[j] for j in bucket), axis)
            for j, r in zip(bucket, red):
                out[j] = r
            bucket, bucket_sz = [], 0
    if bucket:
        red = jax.lax.psum(tuple(leaves[j] for j in bucket), axis)
        for j, r in zip(bucket, red):
            out[j] = r
    return out
