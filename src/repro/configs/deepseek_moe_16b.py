"""deepseek-moe-16b — 28L fine-grained MoE: 2 shared + 64 routed top-6
[arXiv:2401.06066; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=102400,
    n_experts=64, n_shared_experts=2, top_k=6,
    rope_theta=10000.0, fsdp=True,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention, no sub-quadratic mechanism (DESIGN §5)",
)
