"""repro.data subpackage."""
