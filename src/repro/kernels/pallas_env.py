"""Interpret/compiled mode resolution for every Pallas entry point.

All kernel wrappers accept ``interpret=None`` meaning "resolve from the
environment": the ``REPRO_PALLAS_INTERPRET`` variable forces interpret
(``1/true/on/interpret``) or compiled (``0/false/off/compiled``) mode
without a code change; unset, the default is interpret everywhere except
on a real TPU backend.  Explicit ``interpret=True/False`` arguments always
win — the override only fills the ``None`` default, so tests that pin a
mode stay pinned.

The variable is read at call time (not import time), so a test can set it
with ``monkeypatch.setenv`` — but note the kernel wrappers are jitted with
``interpret`` static, so each mode compiles (and caches) separately.
"""

from __future__ import annotations

import os

import jax

ENV_VAR = "REPRO_PALLAS_INTERPRET"

_TRUE = frozenset({"1", "true", "yes", "on", "interpret"})
_FALSE = frozenset({"0", "false", "no", "off", "compile", "compiled"})


def env_interpret() -> bool | None:
    """The ``REPRO_PALLAS_INTERPRET`` override, or None when unset."""
    raw = os.environ.get(ENV_VAR)
    if raw is None:
        return None
    val = raw.strip().lower()
    if val in _TRUE:
        return True
    if val in _FALSE:
        return False
    raise ValueError(
        f"{ENV_VAR}={raw!r}: expected one of {sorted(_TRUE | _FALSE)}")


def resolve_interpret(flag: bool | None = None) -> bool:
    """Resolve an ``interpret`` argument: explicit flag > env var > backend
    default (interpret everywhere but TPU)."""
    if flag is not None:
        return bool(flag)
    env = env_interpret()
    if env is not None:
        return env
    return jax.default_backend() != "tpu"
