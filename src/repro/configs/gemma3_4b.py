"""gemma3-4b — 34L dense GQA, 5:1 local:global interleaving, 128k context
[hf:google/gemma-3-*-pt; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab=262144,
    sliding_window=1024,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    rope_theta=1000000.0,
)
