"""Chip-local fused round engines (DESIGN.md § 4.3) as configurations of
the engine core (DESIGN.md § 4.8).

The legacy round loop (``rounds.py``) pays a host↔device round-trip per
round: head/tail live as host ints, tickets are ``np.arange`` math, every
enqueue chunk is its own ``pallas_call`` dispatch, and each round blocks on
an ``ok`` readback.  The fused engines run the whole dequeue → step →
ticket → enqueue cycle inside ONE jitted ``lax.while_loop``
(``enginecore.fused_loop``):

* head/tail (ring) and size (heap) are device scalars in the loop carry;
* the dequeue wave is the vectorized ``ring_dequeue`` scatter kernel;
* child tickets come from the ``wavefaa`` kernel over the spawn mask — the
  in-loop leader-FAA of paper Alg. 1 — instead of host ticket math;
* the enqueue wave installs ALL children in one vectorized scatter (the
  legacy path chunks them into ``batch``-sized dispatches);
* the host syncs only at quiescence, or every ``sync_every`` rounds when
  the caller wants a stats heartbeat.

Overflow and ``max_rounds`` truncation cannot raise from traced code, so
the loop carries an overflow flag, exits early, and the host driver raises
``RuntimeError`` at the next sync — callers see the same errors as the
legacy path, one sync later.

Bit-determinism: within a round the fused engine issues exactly the
tickets the legacy loop issues (wavefaa ranks = row-major compaction
order, Lemma III.1), applies them through the same vectorized plane
updates, and calls the same jitted ``step_fn`` on the same operands — so
acc, field planes, head/tail, and stats counters are bit-identical to the
legacy loop (tests assert this on BFS, raytrace, and tree workloads).
Each engine here contributes only its ``_round`` body and plane
registrations; the loop carry, chunk driver, and obs-plane lifecycle live
in ``enginecore``.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.compact import compact_width, wave_compact
from ..kernels.heap_batch import (KEY_INF as HEAP_KEY_INF, OP_DELMIN,
                                  OP_INSERT, OP_NOP, heap_apply, heap_planes)
from ..kernels.pallas_env import resolve_interpret
from ..kernels.ring_slots import (deq_planes, enq_planes, ring_dequeue,
                                  ring_enqueue)
from ..kernels.wavefaa import LANES, wavefaa
from ..obs.spans import Spans, span_record, span_tick
from ..obs.trace import Telemetry, masked_min_max
from .enginecore import EngineCore, _sds, deprecated_engine

IDX_BOT = 2 ** 31 - 1           # ⊥ (⊥_c = IDX_BOT - 1); payloads must be smaller


class RingState(NamedTuple):
    """Field planes of the 2n-slot ring plus host-side head/tail tickets."""
    cycles: jax.Array
    safes: jax.Array
    enqs: jax.Array
    idxs: jax.Array
    head: int
    tail: int

    @property
    def occupancy(self) -> int:
        return self.tail - self.head


def ring_init(capacity_log2: int) -> RingState:
    """Ring with logical capacity 2^capacity_log2 (2n physical slots).
    Head = Tail = 2n, so first tickets carry cycle 1 over cycle-0 slots."""
    nslots = 2 << capacity_log2
    return RingState(
        cycles=jnp.zeros((nslots,), jnp.int32),
        safes=jnp.ones((nslots,), jnp.int32),
        enqs=jnp.zeros((nslots,), jnp.int32),
        idxs=jnp.full((nslots,), IDX_BOT, jnp.int32),
        head=nslots, tail=nslots,
    )


class HeapState(NamedTuple):
    """Field planes of the device heap plus the host-side size."""
    keys: jax.Array
    vals: jax.Array
    size: int

    @property
    def occupancy(self) -> int:
        return self.size


def heap_init(capacity_log2: int) -> HeapState:
    cap = 1 << capacity_log2
    return HeapState(
        keys=jnp.full((cap,), HEAP_KEY_INF, jnp.int32),
        vals=jnp.full((cap,), -1, jnp.int32),
        size=0,
    )


# StepFn: (acc, vals (B,), valid (B,)) -> (acc, child_vals (B,F), child_mask (B,F))
StepFn = Callable[[Any, jax.Array, jax.Array], Tuple[Any, jax.Array, jax.Array]]

# PriorityStepFn: (acc, keys (B,), vals (B,), valid (B,))
#   -> (acc, child_keys (B,F), child_vals (B,F), child_mask (B,F))
PriorityStepFn = Callable[
    [Any, jax.Array, jax.Array, jax.Array],
    Tuple[Any, jax.Array, jax.Array, jax.Array]]


def _pad_lanes(mask: jax.Array) -> jax.Array:
    """Pad a flat (N,) int32 spawn mask up to a LANES multiple for wavefaa."""
    n = mask.shape[0]
    npad = -(-n // LANES) * LANES
    if npad == n:
        return mask
    return jnp.zeros((npad,), jnp.int32).at[:n].set(mask)


class RingEngine(EngineCore):
    """The FIFO megaround configuration: chip ring planes + device
    head/tail scalars under the core's fused loop.  Same contract as the
    legacy ``RoundRunner.run`` (exact tickets, row-major child order,
    quiescence), with host sync only at quiescence or every
    ``sync_every`` rounds (0 = quiescence only)."""

    def __init__(self, step_fn: StepFn, *, capacity_log2: int = 10,
                 batch: int = 64, interpret=None, sync_every: int = 0,
                 telemetry: Optional[Telemetry] = None,
                 spans: Optional[Spans] = None, compact=None) -> None:
        self.step_fn = jax.jit(step_fn)
        self.capacity_log2 = capacity_log2
        self.nslots_log2 = capacity_log2 + 1
        self.capacity = 1 << capacity_log2
        self.batch = batch
        if batch > self.capacity:
            raise ValueError(f"batch {batch} exceeds ring capacity "
                             f"{self.capacity}")
        self.interpret = resolve_interpret(interpret)
        self.sync_every = sync_every
        self.telemetry = telemetry
        self.spans = spans
        self.compact = compact
        self._reset()
        nslots = 2 << capacity_log2
        self.registry.register("ring", (_sds((nslots,)),) * 4
                               + (_sds(()), _sds(())))    # planes + head/tail
        # births stays None: FIFO stamps pack into the enq-flag plane
        self._register_obs_planes()
        self._megaround = jax.jit(self._megaround_impl)

    @staticmethod
    def _occ_of(q):
        return q.tail - q.head

    def _round(self, st, acc, tel=False, sp=None, births=None):
        batch, capacity = self.batch, self.capacity
        nslots_log2, interp = self.nslots_log2, self.interpret
        sps = sp is not None
        lane = jnp.arange(batch, dtype=jnp.int32)
        cyc, saf, enq, idx, head, tail = st
        k = jnp.minimum(jnp.int32(batch), tail - head)
        dtickets = jnp.where(lane < k, head + lane, -1)
        if sps:
            # span path inlines the pure-jnp twin of the dequeue kernel
            # in packed-flag mode: the birth stamp lives in the high
            # bits of the enq-flag plane, so it rides the flag
            # gather/scatter the round already pays for — zero extra
            # ops, zero extra carry (every scatter here copies its
            # whole plane per round, so a separate stamp plane costs
            # real microseconds; measured in DESIGN.md § 7.6)
            cyc, saf, enq, idx, vals, okw, bout = deq_planes(
                cyc, saf, enq, idx, dtickets, nslots_log2=nslots_log2,
                idx_bot=IDX_BOT, birth_packed=True)
            ok = okw.astype(bool)
        else:
            cyc, saf, enq, idx, vals, ok = ring_dequeue(
                cyc, saf, enq, idx, dtickets, nslots_log2=nslots_log2,
                idx_bot=IDX_BOT, interpret=interp)
        head = head + k
        acc, cvals, cmask = self.step_fn(acc, vals, ok)
        cm = jnp.broadcast_to(cmask.astype(bool), cvals.shape).reshape(-1)
        cv = cvals.reshape(-1).astype(jnp.int32)
        # dense-wave rule (DESIGN.md § 4.4): compact the sparse child
        # wave down to the capacity bound before installing — the
        # decision is static (trace-time) so exactly one path compiles
        wdth = compact_width(cv.shape[0], capacity, self.compact)
        if wdth is None:
            # in-loop leader FAA: child tickets from the spawn-mask ballot
            etickets, newctr = wavefaa(_pad_lanes(cm.astype(jnp.int32)),
                                       jnp.reshape(tail, (1,)),
                                       interpret=interp)
            etickets = etickets[:cv.shape[0]]
            n_child = newctr[0] - tail
            over = (tail + n_child - head) > capacity
            etickets = jnp.where(over, -1, etickets)  # suppress install
        else:
            # compaction subsumes the ballot: the dense wave IS the
            # children in wavefaa rank order, so tickets are the
            # contiguous run tail + [0, n_child) — bit-identical
            # (ticket, value) scatters to the sparse install
            (cv,), n_child = wave_compact(cm.astype(jnp.int32), (cv,),
                                          width=wdth, interpret=interp)
            over = (tail + n_child - head) > capacity
            lane_w = jnp.arange(wdth, dtype=jnp.int32)
            etickets = jnp.where((lane_w < n_child) & ~over,
                                 tail + lane_w, -1)
        if sps:
            cyc, saf, enq, idx, _ = enq_planes(
                cyc, saf, enq, idx, etickets, cv, head,
                nslots_log2=nslots_log2, idx_bot=IDX_BOT,
                birth_round=sp.round)
        else:
            cyc, saf, enq, idx, _ = ring_enqueue(
                cyc, saf, enq, idx, etickets, cv, head,
                nslots_log2=nslots_log2, idx_bot=IDX_BOT, interpret=interp)
        tail = jnp.where(over, tail, tail + n_child)
        total = jnp.where(over, 0, n_child)
        telinfo = None
        if tel:
            mn, mx = masked_min_max(vals, ok)      # FIFO: payload extrema
            telinfo = (k, total, tail - head, mn, mx)
        if sps:
            cls = self._span_cls(vals, jnp.zeros_like(vals))
            sp = span_record(sp, cls, sp.round - bout, ok, vals)
            sp = span_tick(sp)
        return (RingState(cyc, saf, enq, idx, head, tail), acc, k, total,
                over, telinfo, sp, births)

    def _seed(self, st: RingState, initial: np.ndarray) -> RingState:
        n = len(initial)
        if n > self.capacity:
            raise RuntimeError(
                f"ring overflow: {n} seed values exceed capacity "
                f"{self.capacity} (raise capacity_log2)")
        if n == 0:
            return st
        tickets = jnp.asarray(st.tail + np.arange(n, dtype=np.int64),
                              jnp.int32)
        cyc, saf, enq, idx, ok = ring_enqueue(
            st.cycles, st.safes, st.enqs, st.idxs, tickets,
            jnp.asarray(initial), jnp.asarray(st.head, jnp.int32),
            nslots_log2=self.nslots_log2, idx_bot=IDX_BOT,
            interpret=self.interpret)
        assert bool(ok.all()), "exact tickets cannot miss"
        return RingState(cyc, saf, enq, idx, st.head, st.tail + n)

    def run(self, initial: np.ndarray, acc: Any = None,
            max_rounds: int = 10_000) -> Tuple[Any, RingState]:
        """Seed the ring and run megarounds to quiescence.  Sync contract:
        the host blocks exactly once per ``sync_every`` chunk (once total
        when ``sync_every=0``) on the occupancy readback; ``stats`` and
        ``sync_log`` are populated at each sync.  Determinism: the run is
        bit-deterministic — identical tickets, planes, acc, and stats to
        the legacy per-round engine.  Raises ``RuntimeError`` on ring
        overflow or ``max_rounds`` truncation (at the sync *after* the
        flagged round, so stats reflect the partial run).  Returns
        ``(acc, final RingState)``."""
        self._reset()
        st = self._seed(ring_init(self.capacity_log2),
                        np.asarray(initial, np.int32).reshape(-1))
        acc = jax.tree_util.tree_map(jnp.asarray, acc)
        q = RingState(st.cycles, st.safes, st.enqs, st.idxs,
                      jnp.int32(st.head), jnp.int32(st.tail))
        state = [q, acc, jnp.int32(0), jnp.int32(0),    # processed/spawned
                 jnp.int32(st.tail - st.head)]          # max_occ
        # obs state: [TracePlane, SpanPlane, births] — None slots are empty
        # pytrees, so the all-None call is the exact unspanned graph.  The
        # FIFO ring keeps births=None: its stamps pack into the enq-flag
        # plane (seeds installed by the kernel carry flag 1 ⇔ birth 0)
        ext = [self._tel_init(), self._span_init(), None]
        self._run_chunks(state, ext, lambda q: int(q.tail - q.head),
                         "ring", max_rounds)
        q, acc = state[0], state[1]
        planes = (q.cycles, q.safes, q.enqs, q.idxs)
        if self.spans is not None:
            # strip packed birth stamps: the enq-flag plane is bit-identical
            # to the unspanned run's once reduced back to its low bit
            planes = (planes[0], planes[1], planes[2] & 1, planes[3])
        return acc, RingState(*planes, int(q.head), int(q.tail))


class HeapEngine(EngineCore):
    """``RingEngine``'s priority configuration: chains ``heap_apply`` pop
    and insert batches under the core's fused loop with the heap size as a
    device scalar.  The pad/op vectors are loop-invariant constants (hoisted
    by XLA), and children insert as one masked batch in row-major order —
    identical heap evolution to the legacy chunked inserts."""

    def __init__(self, step_fn: PriorityStepFn, *, capacity_log2: int = 10,
                 batch: int = 64, arity_log2: int = 2, interpret=None,
                 sync_every: int = 0,
                 telemetry: Optional[Telemetry] = None,
                 spans: Optional[Spans] = None, compact=None) -> None:
        self.step_fn = jax.jit(step_fn)
        self.capacity_log2 = capacity_log2
        self.capacity = 1 << capacity_log2
        self.batch = batch
        if batch > self.capacity:
            raise ValueError(f"batch {batch} exceeds heap capacity "
                             f"{self.capacity}")
        self.arity_log2 = arity_log2
        self.interpret = resolve_interpret(interpret)
        self.sync_every = sync_every
        self.telemetry = telemetry
        self.spans = spans
        self.compact = compact
        self._reset()
        cap = self.capacity
        self.registry.register("heap", (_sds((cap,)), _sds((cap,)),
                                        _sds(())))       # keys/vals + size
        self._register_obs_planes(births_shape=(cap,))
        self._megaround = jax.jit(self._megaround_impl)

    @staticmethod
    def _occ_of(q):
        return q.size

    def _round(self, st, acc, tel=False, sp=None, births=None):
        batch, capacity = self.batch, self.capacity
        cap_log2, arity_log2 = self.capacity_log2, self.arity_log2
        interp = self.interpret
        sps = sp is not None
        lane = jnp.arange(batch, dtype=jnp.int32)
        pad = jnp.full((batch,), HEAP_KEY_INF, jnp.int32)
        keys, vals, size = st
        k = jnp.minimum(jnp.int32(batch), size)
        pop_ops = jnp.where(lane < k, OP_DELMIN, OP_NOP)
        if sps:
            # span path inlines the rider-capable pure-jnp heap twin
            # (bit-identical heap evolution to the kernel; the rider
            # plane carries the birth stamps through every sift)
            (keys, vals, size, outk, outv, ok, births,
             bout) = heap_planes(
                keys, vals, size, pop_ops, pad, pad, cap_log2=cap_log2,
                arity_log2=arity_log2, rider=births)
        else:
            keys, vals, size, outk, outv, ok = heap_apply(
                keys, vals, size, pop_ops, pad, pad, cap_log2=cap_log2,
                arity_log2=arity_log2, interpret=interp)
        acc, ckeys, cvals, cmask = self.step_fn(acc, outk, outv, ok)
        cm = jnp.broadcast_to(cmask.astype(bool), ckeys.shape).reshape(-1)
        ckf = ckeys.reshape(-1).astype(jnp.int32)
        cvf = cvals.reshape(-1).astype(jnp.int32)
        # dense-wave rule (DESIGN.md § 4.4): compact before the insert
        # batch — the dense wave preserves row-major lane order, so the
        # masked insert sequence (hence the heap evolution) is
        # bit-identical to the sparse one
        wdth = compact_width(ckf.shape[0], capacity, self.compact)
        if wdth is None:
            n_child = cm.sum(dtype=jnp.int32)
            over = size + n_child > capacity
            ins_ops = jnp.where(cm & ~over, OP_INSERT, OP_NOP)
        else:
            (ckf, cvf), n_child = wave_compact(
                cm.astype(jnp.int32), (ckf, cvf), width=wdth,
                interpret=interp)
            over = size + n_child > capacity
            lane_w = jnp.arange(wdth, dtype=jnp.int32)
            ins_ops = jnp.where((lane_w < n_child) & ~over,
                                OP_INSERT, OP_NOP)
        if sps:
            keys, vals, size, _, _, _, births, _ = heap_planes(
                keys, vals, size, ins_ops, ckf, cvf, cap_log2=cap_log2,
                arity_log2=arity_log2, rider=births, oprider=sp.round)
        else:
            keys, vals, size, _, _, _ = heap_apply(
                keys, vals, size, ins_ops, ckf, cvf, cap_log2=cap_log2,
                arity_log2=arity_log2, interpret=interp)
        total = jnp.where(over, 0, n_child)
        telinfo = None
        if tel:
            mn, mx = masked_min_max(outk, ok)      # popped-key extrema
            telinfo = (k, total, size, mn, mx)
        if sps:
            cls = self._span_cls(outk, jnp.zeros_like(outk))
            sp = span_record(sp, cls, sp.round - bout, ok, outv)
            sp = span_tick(sp)
        return (HeapState(keys, vals, size), acc, k, total, over, telinfo,
                sp, births)

    def _seed(self, st: HeapState, ik: np.ndarray,
              iv: np.ndarray) -> HeapState:
        n = len(ik)
        if st.size + n > self.capacity:
            raise RuntimeError(
                f"heap overflow: {n} seed values exceed capacity "
                f"{self.capacity} (raise capacity_log2)")
        if n == 0:
            return st
        ops = jnp.full((n,), OP_INSERT, jnp.int32)
        keys, vals, size, _, _, ok = heap_apply(
            st.keys, st.vals, jnp.asarray(st.size, jnp.int32), ops,
            jnp.asarray(ik), jnp.asarray(iv), cap_log2=self.capacity_log2,
            arity_log2=self.arity_log2, interpret=self.interpret)
        assert bool(ok.all()), "capacity was checked: inserts cannot miss"
        return HeapState(keys, vals, int(size))

    def run(self, initial_keys: np.ndarray, initial_vals: np.ndarray,
            acc: Any = None, max_rounds: int = 10_000
            ) -> Tuple[Any, HeapState]:
        """Seed the heap and run priority megarounds to quiescence.  Same
        sync/determinism contract as ``RingEngine.run`` (one host sync
        per chunk, bit-identical to the legacy engine, RuntimeError on
        heap overflow/truncation at the next sync), with pops in exact
        min-key order within each round.  Returns ``(acc, HeapState)``."""
        self._reset()
        ik = np.asarray(initial_keys, np.int32).reshape(-1)
        iv = np.asarray(initial_vals, np.int32).reshape(-1)
        assert ik.shape == iv.shape
        st = self._seed(heap_init(self.capacity_log2), ik, iv)
        acc = jax.tree_util.tree_map(jnp.asarray, acc)
        q = HeapState(st.keys, st.vals, jnp.asarray(st.size, jnp.int32))
        state = [q, acc, jnp.int32(0), jnp.int32(0),    # processed/spawned
                 jnp.int32(st.size)]                    # max_occ
        ext = [self._tel_init(), self._span_init(),
               self._births_init((self.capacity,))]
        self._run_chunks(state, ext, lambda q: int(q.size),
                         "heap", max_rounds)
        q = state[0]
        return state[1], HeapState(q.keys, q.vals, int(q.size))


@deprecated_engine("RingEngine")
class FusedRounds(RingEngine):
    """Deprecated alias of :class:`RingEngine` (same constructor and run
    contract; emits ``DeprecationWarning``)."""


@deprecated_engine("HeapEngine")
class FusedPriorityRounds(HeapEngine):
    """Deprecated alias of :class:`HeapEngine` (same constructor and run
    contract; emits ``DeprecationWarning``)."""
