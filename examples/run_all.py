"""Run the documented example scripts end-to-end so the quickstarts in
README.md cannot rot — the CI examples gate.

    PYTHONPATH=src python examples/run_all.py [--smoke]

``--smoke`` exports ``REPRO_EXAMPLES_SMOKE=1`` (examples that honor it
shrink their problem sizes) and enforces a per-example timeout.  The
serving/training examples (``serve_lm.py``, ``train_lm.py``) are excluded
here — they spin up the model zoo and take minutes; CI exercises that
path through the launch tests instead.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

EXAMPLES = (
    "quickstart.py",
    "runtime_demo.py",
    "bfs_demo.py",
    "raytrace_demo.py",
    "priority_demo.py",
    "sssp_demo.py",
)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smoke sizes + per-example timeout (CI)")
    ap.add_argument("--timeout", type=int, default=600,
                    help="per-example timeout in seconds (smoke mode)")
    args = ap.parse_args()
    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(here)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(repo, "src"), env.get("PYTHONPATH")) if p)
    if args.smoke:
        env["REPRO_EXAMPLES_SMOKE"] = "1"
    failed = []
    for name in EXAMPLES:
        t0 = time.perf_counter()
        try:
            proc = subprocess.run(
                [sys.executable, os.path.join(here, name)], env=env,
                cwd=repo, capture_output=True, text=True,
                timeout=args.timeout if args.smoke else None)
            rc = proc.returncode
            tail = (proc.stdout + proc.stderr).strip().splitlines()[-3:]
        except subprocess.TimeoutExpired:
            rc, tail = -1, [f"timed out after {args.timeout}s"]
        el = time.perf_counter() - t0
        status = "ok" if rc == 0 else "FAIL"
        print(f"[{status}] {name:20s} {el:6.1f}s")
        if rc != 0:
            failed.append(name)
            for line in tail:
                print(f"       {line}")
    if failed:
        print(f"examples gate: {len(failed)} failed: {', '.join(failed)}")
        return 1
    print(f"examples gate: all {len(EXAMPLES)} examples ran clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
