"""Paper Fig. 7 — tile-based wavefront ray tracing: per-tile queue
scheduling vs the stream-compaction baseline, on the Complex and Cornell
scenes.  Reports MRays/s and relative throughput."""

from __future__ import annotations

import sys
import time

import numpy as np

from repro.apps.raytrace import (complex_scene, cornell_scene,
                                 render_compaction, render_queue)


def _time(fn, *args, reps: int = 2, **kw):
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


def main(out=sys.stdout, *, size: int = 64) -> None:
    print("bench,scene,method,mrays_per_s,rays,rel_vs_compaction,img_match",
          file=out)
    for scene in (complex_scene(), cornell_scene()):
        tc, (ic, mc) = _time(render_compaction, scene, size, size)
        tq, (iq, mq) = _time(render_queue, scene, size, size, 4, 4)
        match = bool(np.allclose(iq, ic, atol=1e-4))
        mr_c = mc["rays"] / tc / 1e6
        mr_q = mq["rays"] / tq / 1e6
        print(f"fig7_rt,{scene.name},compaction,{mr_c:.3f},{mc['rays']},1.00,"
              f"{match}", file=out)
        print(f"fig7_rt,{scene.name},queue,{mr_q:.3f},{mq['rays']},"
              f"{mr_q/max(mr_c,1e-9):.2f},{match}", file=out)


if __name__ == "__main__":
    main()
