"""Unified host metrics registry (DESIGN.md § 7.2).

Before this module every subsystem kept its own ad-hoc stats surface:
``_FusedEngine.stats`` dicts, ``TaskRuntime.run()``'s free-form metrics
dict, ``FabricMetrics.per_shard_deq`` keyed by ``(lane, shard)`` tuples,
``ServingEngine.metrics`` + ``admission_log`` — and benchmarks
string-matched whichever shape they happened to know.  The registry puts
them behind one schema:

* **counter** — monotically accumulating int (``host_syncs``, steals,
  admissions).
* **gauge** — last-written value (occupancy, load imbalance).
* **histogram** — stream summary (count/sum/min/max + fixed quantiles via
  a bounded reservoir) for latency-like observations (wait times,
  sync-to-sync round deltas).

Keys are flat strings built by :func:`metric_key`:
``<subsystem>.<name>[label=value,...]`` with labels sorted — e.g.
``fabric.deq[lane=0,shard=1]`` or ``serving.admitted`` — so per-shard
snapshots have *stable* names benchmarks and the trace exporter can rely
on.  ``snapshot()`` returns plain ``{key: number-or-dict}`` suitable for
JSONL export.
"""

from __future__ import annotations

import bisect
from typing import Any, Dict, List, Mapping, Optional, Tuple, Union

__all__ = ["Histogram", "MetricsRegistry", "metric_key"]


def metric_key(subsystem: str, name: str, **labels) -> str:
    """Canonical flat metric key: ``subsystem.name[k=v,...]`` (labels
    sorted; no-label keys omit the brackets)."""
    base = f"{subsystem}.{name}" if subsystem else name
    if not labels:
        return base
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{base}[{inner}]"


class Histogram:
    """Bounded-reservoir stream summary: exact count/sum/min/max, and
    quantiles over the most recent ``max_samples`` observations."""

    def __init__(self, max_samples: int = 4096) -> None:
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._samples: List[float] = []
        self._max_samples = int(max_samples)

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        if len(self._samples) >= self._max_samples:
            self._samples.pop(0)
        self._samples.append(v)

    def quantile(self, q: float) -> Optional[float]:
        if not self._samples:
            return None
        xs = sorted(self._samples)
        idx = min(int(q * len(xs)), len(xs) - 1)
        return xs[idx]

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None

    def to_dict(self) -> Dict[str, Any]:
        return {"count": self.count, "sum": self.sum, "min": self.min,
                "max": self.max, "mean": self.mean,
                "p50": self.quantile(0.50), "p99": self.quantile(0.99)}


class MetricsRegistry:
    """One process-local registry instance per engine/runtime/benchmark.

    Kinds are enforced per key: re-using ``fabric.deq[shard=0]`` as both a
    counter and a gauge raises — catching exactly the free-form drift this
    registry exists to remove.
    """

    def __init__(self) -> None:
        self._counters: Dict[str, Union[int, float]] = {}
        self._gauges: Dict[str, Union[int, float]] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- writes ---------------------------------------------------------------

    def counter(self, key: str, delta: Union[int, float] = 1) -> None:
        self._check_kind(key, self._counters)
        self._counters[key] = self._counters.get(key, 0) + delta

    def gauge(self, key: str, value: Union[int, float]) -> None:
        self._check_kind(key, self._gauges)
        self._gauges[key] = value

    def observe(self, key: str, value: Union[int, float]) -> None:
        self._check_kind(key, self._histograms)
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram()
        h.observe(value)

    def _check_kind(self, key: str, own: Mapping[str, Any]) -> None:
        for kind, table in (("counter", self._counters),
                            ("gauge", self._gauges),
                            ("histogram", self._histograms)):
            if table is not own and key in table:
                raise ValueError(
                    f"metric key {key!r} already registered as a {kind}")

    # -- reads ----------------------------------------------------------------

    def get(self, key: str, default=None):
        if key in self._counters:
            return self._counters[key]
        if key in self._gauges:
            return self._gauges[key]
        if key in self._histograms:
            return self._histograms[key]
        return default

    def keys(self) -> List[str]:
        return sorted(set(self._counters) | set(self._gauges)
                      | set(self._histograms))

    def snapshot(self) -> Dict[str, Any]:
        """Flat ``{key: value}`` view (histograms as summary dicts) —
        JSON-serialisable, the shape ``obs.export`` emits."""
        out: Dict[str, Any] = {}
        out.update(self._counters)
        out.update(self._gauges)
        for k, h in self._histograms.items():
            out[k] = h.to_dict()
        return dict(sorted(out.items()))

    def filtered(self, prefix: str) -> Dict[str, Any]:
        """Snapshot restricted to keys under ``prefix`` (subsystem view)."""
        return {k: v for k, v in self.snapshot().items()
                if k == prefix or k.startswith(prefix + ".")
                or k.startswith(prefix + "[")}

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold another registry in (counters add, gauges overwrite,
        histogram samples re-observe) — lets per-engine registries roll up
        into one run-level view before export."""
        for k, v in other._counters.items():
            self.counter(k, v)
        for k, v in other._gauges.items():
            self.gauge(k, v)
        for k, h in other._histograms.items():
            for s in h._samples:
                self.observe(k, s)
