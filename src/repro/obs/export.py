"""Trace export: JSONL and Chrome trace-event emitters (DESIGN.md § 7.3).

Two output formats from the same drained telemetry:

* **JSONL** — one self-describing JSON object per line, ``kind``-tagged
  (``round`` | ``sync`` | ``metrics`` | ``meta`` | ``hist`` | ``flow``),
  the format ``tools/trace_check.py`` validates and ``obs.analyze``
  re-parses.
* **Chrome trace-event** — a ``{"traceEvents": [...]}`` file loadable in
  Perfetto / chrome://tracing.  In-loop rounds carry no host timestamps
  (device residency is the point), so the tick axis is the **round
  index** scaled by ``us_per_round``: each round becomes a complete
  ("X") event on the engine track and each per-shard occupancy series a
  counter ("C") track; host syncs are instant ("i") events carrying
  their wall-clock in args.

Schema v2 adds the span layer (DESIGN.md § 7.6): ``hist`` lines carry a
``Spans.summary()`` sojourn histogram, ``flow`` lines carry sampled
ticket lifecycles (birth round → claim round), and the Chrome emitter
renders each sampled ticket as a flow-event pair — an "s" (start) at its
enqueue round bound to an "f" (finish, ``bp: "e"``) at its dequeue round
under one flow id, so Perfetto draws the arrow across the round track.

The roundtrip contract (asserted in tests): ``read_jsonl(write_jsonl(
records, syncs, metrics))`` reproduces every record field exactly.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from .trace import RoundRecord, SyncPoint

__all__ = [
    "read_jsonl", "to_chrome_trace", "write_chrome_trace", "write_jsonl",
]

SCHEMA_VERSION = 2

# required fields per JSONL record kind — shared with tools/trace_check.py
JSONL_SCHEMA: Dict[str, Tuple[str, ...]] = {
    "meta": ("kind", "schema_version"),
    "round": ("kind", "engine", "round", "pops", "pushes", "occupancy",
              "imbalance", "min_key", "max_key", "overflow", "sync",
              "wall_time"),
    "sync": ("kind", "engine", "rounds", "occupancy", "wall_time",
             "host_syncs"),
    "metrics": ("kind", "metrics"),
    "hist": ("kind", "engine", "classes", "buckets", "bucket_edges",
             "hist", "max_wait", "total", "p50", "p95", "p99"),
    "flow": ("kind", "engine", "birth", "claim", "cls", "ref"),
}


def _round_line(r: RoundRecord) -> Dict[str, Any]:
    d = r.to_dict()
    d["kind"] = "round"
    return d


def _sync_line(s: SyncPoint, engine: str) -> Dict[str, Any]:
    d = s.to_dict()
    d["kind"] = "sync"
    d["engine"] = engine
    return d


def write_jsonl(path: str, records: Sequence[RoundRecord],
                syncs: Sequence[SyncPoint] = (), *,
                metrics: Optional[Dict[str, Any]] = None,
                engine: str = "fused",
                spans: Optional[Any] = None,
                extra_meta: Optional[Dict[str, Any]] = None) -> int:
    """Emit a telemetry JSONL file; returns the number of lines written.
    Line 1 is always the ``meta`` header (schema version + run info).
    ``spans`` (a drained ``obs.spans.Spans`` collector) appends one
    ``hist`` line (the sojourn histogram summary) plus one ``flow`` line
    per sampled ticket lifecycle."""
    lines: List[Dict[str, Any]] = []
    meta: Dict[str, Any] = {"kind": "meta", "schema_version": SCHEMA_VERSION,
                            "engine": engine}
    if extra_meta:
        meta.update(extra_meta)
    lines.append(meta)
    lines.extend(_round_line(r) for r in records)
    lines.extend(_sync_line(s, engine) for s in syncs)
    if spans is not None:
        hist = dict(spans.summary())
        hist["kind"] = "hist"
        hist["engine"] = engine
        lines.append(hist)
        for fl in spans.flows:
            lines.append({"kind": "flow", "engine": engine, **fl})
    if metrics is not None:
        lines.append({"kind": "metrics", "metrics": metrics})
    with open(path, "w") as f:
        for ln in lines:
            f.write(json.dumps(ln) + "\n")
    return len(lines)


def read_jsonl(path: str) -> Dict[str, Any]:
    """Re-parse a telemetry JSONL file into ``{"meta": dict, "records":
    [RoundRecord], "syncs": [SyncPoint], "metrics": dict, "hist": dict,
    "flows": [dict]}`` (``hist``/``flows`` empty when the file carries no
    span layer)."""
    meta: Dict[str, Any] = {}
    records: List[RoundRecord] = []
    syncs: List[SyncPoint] = []
    metrics: Dict[str, Any] = {}
    hist: Dict[str, Any] = {}
    flows: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            kind = d.get("kind")
            if kind == "meta":
                meta = d
            elif kind == "round":
                d = dict(d)
                d.pop("kind")
                records.append(RoundRecord.from_dict(d))
            elif kind == "sync":
                syncs.append(SyncPoint(
                    rounds=d["rounds"], occupancy=d["occupancy"],
                    wall_time=d["wall_time"],
                    host_syncs=d.get("host_syncs", 0)))
            elif kind == "metrics":
                metrics = d.get("metrics", {})
            elif kind == "hist":
                hist = {k: v for k, v in d.items() if k != "kind"}
            elif kind == "flow":
                flows.append({k: v for k, v in d.items() if k != "kind"})
            else:
                raise ValueError(f"unknown JSONL record kind {kind!r}")
    return {"meta": meta, "records": records, "syncs": syncs,
            "metrics": metrics, "hist": hist, "flows": flows}


def to_chrome_trace(records: Sequence[RoundRecord],
                    syncs: Sequence[SyncPoint] = (), *,
                    engine: str = "fused",
                    us_per_round: float = 10.0,
                    flows: Sequence[Dict[str, Any]] = ()) -> Dict[str, Any]:
    """Build a Chrome trace-event dict (see module doc for the time-base
    convention).  pid 1 = the engine; tid 1 = the round track, tid
    100 + s = shard s's occupancy counter track.  ``flows`` (sampled
    ticket lifecycles from ``Spans.flows``) render as enqueue→dequeue
    flow-event pairs on the round track."""
    ev: List[Dict[str, Any]] = [
        {"ph": "M", "pid": 1, "name": "process_name",
         "args": {"name": f"repro:{engine}"}},
        {"ph": "M", "pid": 1, "tid": 1, "name": "thread_name",
         "args": {"name": "rounds"}},
    ]
    for r in records:
        ts = r.round * us_per_round
        ev.append({
            "ph": "X", "pid": 1, "tid": 1, "name": f"round {r.round}",
            "cat": "round", "ts": ts, "dur": us_per_round,
            "args": {"round": r.round, "pops": r.pops, "pushes": r.pushes,
                     "occupancy": r.occupancy, "imbalance": r.imbalance,
                     "min_key": r.min_key, "max_key": r.max_key,
                     "overflow": r.overflow, "sync": r.sync},
        })
        ev.append({
            "ph": "C", "pid": 1, "tid": 1, "name": "occupancy",
            "cat": "occupancy", "ts": ts,
            "args": {f"shard{s}": o for s, o in enumerate(r.occupancy)},
        })
        ev.append({
            "ph": "C", "pid": 1, "tid": 1, "name": "imbalance",
            "cat": "imbalance", "ts": ts, "args": {"pops": r.imbalance},
        })
    for i, s in enumerate(syncs):
        ev.append({
            "ph": "i", "pid": 1, "tid": 1, "name": f"sync {i}",
            "cat": "sync", "s": "p", "ts": s.rounds * us_per_round,
            "args": {"rounds": s.rounds, "occupancy": s.occupancy,
                     "wall_time": s.wall_time,
                     "host_syncs": s.host_syncs},
        })
    for i, fl in enumerate(flows):
        args = {"birth": fl["birth"], "claim": fl["claim"],
                "cls": fl["cls"], "ref": fl["ref"],
                "sojourn": fl["claim"] - fl["birth"]}
        ev.append({
            "ph": "s", "pid": 1, "tid": 1, "id": i,
            "name": f"span cls{fl['cls']}", "cat": "span",
            "ts": fl["birth"] * us_per_round, "args": args,
        })
        ev.append({
            "ph": "f", "pid": 1, "tid": 1, "id": i, "bp": "e",
            "name": f"span cls{fl['cls']}", "cat": "span",
            "ts": fl["claim"] * us_per_round, "args": args,
        })
    return {"traceEvents": ev,
            "displayTimeUnit": "ms",
            "metadata": {"engine": engine, "us_per_round": us_per_round,
                         "schema_version": SCHEMA_VERSION,
                         "time_base": "round-index"}}


def write_chrome_trace(path: str, records: Sequence[RoundRecord],
                       syncs: Sequence[SyncPoint] = (), *,
                       engine: str = "fused",
                       us_per_round: float = 10.0,
                       flows: Sequence[Dict[str, Any]] = ()) -> int:
    """Write the Perfetto-loadable trace file; returns the event count."""
    trace = to_chrome_trace(records, syncs, engine=engine,
                            us_per_round=us_per_round, flows=flows)
    with open(path, "w") as f:
        json.dump(trace, f)
    return len(trace["traceEvents"])
