"""Bench-trajectory regression sentinel (non-blocking CI report).

Diffs the newest ``BENCH_<n>.json`` at the repo root against its
predecessor (or any two snapshots given explicitly): rows are matched per
section on their *identity* fields (workload, mode, batch, shards, … —
everything that names a configuration rather than measures it) and each
shared throughput metric (``*_per_s``) is compared.

Noise discipline: single-snapshot timings on shared CI hosts scatter by
about ±10 percentage points even though each row is already a
min/median of interleaved trials, and consecutive snapshots cannot be
interleaved with each other at all.  So the sentinel only *flags* drops
beyond ``--tolerance`` (default 25%, comfortably past the observed
scatter) and stays **non-blocking** by default — it prints a report and
exits 0 so CI surfaces the warning without failing the build; a drop
that persists across several snapshots is the actionable signal.
``--strict`` turns flagged regressions into a nonzero exit for local
bisection.

Sections absent from the immediate predecessor fall back per-section to
the most recent older snapshot that carries them (sweeps come and go
between PRs — e.g. the ``rounds`` section skips from BENCH_3 to BENCH_8),
so no section silently loses its baseline just because the previous
snapshot dropped it.  Trajectory *ids* may also have holes (a snapshot
that was never committed): the default pair is always the two newest
files that exist, and the report leads with a NOTE naming the missing
ids so a cross-gap baseline is never silent.

Run: ``python tools/bench_compare.py [OLD.json NEW.json]``
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# structural numerics that name a configuration (everything str-valued is
# identity automatically; numbers default to "measurement")
_IDENTITY_NUMERIC = {
    "batch", "shards", "delta", "threads", "capacity", "capacity_log2",
    "lanes", "n", "classes", "depth", "roots", "bursts", "steps",
    "workers", "tasks", "n_tasks", "rate", "tenant", "tenants",
}
# measured-but-not-throughput fields: never part of identity, never gated
# (offered_load is *realized* load — it measures the trace, the ``rate``
# knob names it; goodput/latency are deterministic replays gated by the
# serving bench's own acceptance line, not by cross-snapshot timing)
_INFORMATIONAL = {
    "elapsed_s", "overhead_pct", "rounds", "items", "records", "dropped",
    "dropped_flows", "host_syncs", "drained", "offered_load", "p50_wait",
    "p95_wait", "p99_wait", "max_wait", "worst_class", "starved",
    "goodput", "p50_lat", "p99_lat", "slo_ticks", "submitted", "admitted",
    "completed", "ticks",
}


def _identity(row: dict):
    return tuple(sorted(
        (k, v) for k, v in row.items()
        if isinstance(v, str) or k in _IDENTITY_NUMERIC))


def _metrics(row: dict) -> dict:
    """Higher-is-better throughput metrics of a row (the gated subset)."""
    return {k: v for k, v in row.items()
            if (k.endswith("_per_s") or k.endswith("_per_kstep"))
            and isinstance(v, (int, float))}


def _snapshots():
    """All repo-root BENCH_<n>.json paths as sorted (id, path) pairs."""
    snaps = []
    for p in glob.glob(os.path.join(REPO_ROOT, "BENCH_*.json")):
        m = re.match(r"BENCH_(\d+)\.json$", os.path.basename(p))
        if m:
            snaps.append((int(m.group(1)), p))
    snaps.sort()
    return snaps


def latest_pair():
    """The two newest BENCH_<n>.json paths (old, new); None when fewer
    than two exist."""
    snaps = _snapshots()
    return (snaps[-2][1], snaps[-1][1]) if len(snaps) >= 2 else None


def gap_note(old_path: str, new_path: str):
    """A report line naming any trajectory ids missing between the two
    snapshots (e.g. BENCH_8 was never committed, so BENCH_9 baselines
    against BENCH_7) — or ``None`` when the ids are consecutive or not
    BENCH_<n>-shaped.  Comparing across a gap is fine; doing it silently
    is not: the reader must know the baseline is older than n-1."""
    ids = []
    for p in (old_path, new_path):
        m = re.match(r"BENCH_(\d+)\.json$", os.path.basename(p))
        ids.append(int(m.group(1)) if m else None)
    if ids[0] is None or ids[1] is None or ids[1] - ids[0] <= 1:
        return None
    missing = ", ".join(f"BENCH_{n}" for n in range(ids[0] + 1, ids[1]))
    return (f"  NOTE: {missing} missing from the trajectory — comparing "
            f"BENCH_{ids[1]} against BENCH_{ids[0]}, its latest existing "
            f"predecessor")


def _compare_section(sec, old_rows, new_rows, tolerance, lines,
                     regressions, src=""):
    old_by_id = {_identity(r): r for r in old_rows}
    matched = flagged = 0
    for r in new_rows:
        o = old_by_id.get(_identity(r))
        if o is None:
            continue
        for metric, nv in _metrics(r).items():
            ov = o.get(metric)
            if not isinstance(ov, (int, float)) or ov <= 0:
                continue
            matched += 1
            delta = nv / ov - 1.0
            if delta < -tolerance:
                flagged += 1
                ident = {k: v for k, v in r.items()
                         if isinstance(v, str) or k in _IDENTITY_NUMERIC}
                reg = {"section": sec, "metric": metric, "old": ov,
                       "new": nv, "delta_pct": round(delta * 100, 1),
                       "row": ident}
                regressions.append(reg)
                lines.append(
                    f"  REGRESSION {sec}: {metric} {ov} -> {nv} "
                    f"({reg['delta_pct']:+.1f}%) at {ident}")
    lines.append(f"  {sec}: {matched} metric(s) compared, "
                 f"{flagged} flagged{src}")


def compare(old_path: str, new_path: str, *, tolerance: float = 0.25,
            history=()):
    """Compare two trajectory snapshots.  ``history`` is an ordered
    (newest-first) list of older snapshot paths: a section present in the
    new snapshot but missing from the old one falls back to the most
    recent history snapshot that carries it.  Returns ``(report_lines,
    regressions)`` where ``regressions`` is the flagged subset."""
    with open(old_path) as f:
        old = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    lines = [f"bench_compare: {os.path.basename(old_path)} "
             f"(rev {old.get('git_rev', '?')}) -> "
             f"{os.path.basename(new_path)} (rev {new.get('git_rev', '?')}), "
             f"tolerance {tolerance:.0%}"]
    note = gap_note(old_path, new_path)
    if note:
        lines.append(note)
    regressions = []
    shared = sorted(set(old["sections"]) & set(new["sections"]))
    only_old = sorted(set(old["sections"]) - set(new["sections"]))
    missing = sorted(set(new["sections"]) - set(old["sections"]))
    if only_old:
        lines.append(f"  sections only in the old snapshot (skipped): "
                     f"{', '.join(only_old)}")
    if old.get("config", {}).get("quick") != new.get("config", {}).get("quick"):
        lines.append("  WARNING: quick-mode mismatch between snapshots — "
                     "sweep sizes differ, deltas are not comparable")
    for sec in shared:
        _compare_section(sec, old["sections"][sec], new["sections"][sec],
                         tolerance, lines, regressions)
    # per-section fallback: a section the predecessor dropped still gets
    # the most recent baseline that carries it (e.g. rounds: BENCH_3 -> 8)
    for sec in missing:
        fell_back = False
        for hp in history:
            if os.path.abspath(hp) in (os.path.abspath(old_path),
                                       os.path.abspath(new_path)):
                continue
            try:
                with open(hp) as f:
                    hist = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            if sec in hist.get("sections", {}):
                _compare_section(
                    sec, hist["sections"][sec], new["sections"][sec],
                    tolerance, lines, regressions,
                    src=f" (baseline: {os.path.basename(hp)})")
                fell_back = True
                break
        if not fell_back:
            lines.append(f"  {sec}: new section, no earlier baseline")
    if not shared and not missing:
        lines.append("  no shared sections — nothing compared")
    lines.append(f"bench_compare: {'REGRESSIONS FLAGGED' if regressions else 'OK'} "
                 f"({len(regressions)} flagged)")
    return lines, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("snapshots", nargs="*", metavar="JSON",
                    help="OLD.json NEW.json (default: two newest "
                         "BENCH_<n>.json at the repo root)")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="fractional drop beyond which a metric is "
                         "flagged (default 0.25 — past CI timing noise)")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero when regressions are flagged "
                         "(default: non-blocking report)")
    args = ap.parse_args(argv)
    if len(args.snapshots) == 2:
        pair = tuple(args.snapshots)
    elif not args.snapshots:
        pair = latest_pair()
        if pair is None:
            print("bench_compare: fewer than two BENCH_<n>.json snapshots "
                  "— nothing to compare")
            return 0
    else:
        ap.error("give exactly two snapshot paths, or none for the two "
                 "newest BENCH_<n>.json")
    history = [p for _, p in reversed(_snapshots())]   # newest first
    lines, regressions = compare(pair[0], pair[1],
                                 tolerance=args.tolerance,
                                 history=history)
    print("\n".join(lines))
    return 1 if (args.strict and regressions) else 0


if __name__ == "__main__":
    sys.exit(main())
