"""Observability layer (DESIGN.md § 7): device-resident trace planes for
the fused engines, a unified host metrics registry, and trace exporters.

* :mod:`repro.obs.trace` — ``TracePlane`` in-loop ring + ``Telemetry``
  host driver + the unified ``SyncPoint`` heartbeat schema
* :mod:`repro.obs.metrics` — ``MetricsRegistry`` (counters / gauges /
  histograms behind stable ``metric_key`` names)
* :mod:`repro.obs.export` — JSONL + Chrome trace-event emitters
* :mod:`repro.obs.analyze` — occupancy/imbalance timelines, measured
  rank error vs the declared ``mesh_relaxation_bound`` envelope,
  sojourn percentiles + starvation flags from span histograms
* :mod:`repro.obs.spans` — ``SpanPlane`` in-loop sojourn histograms +
  the ``Spans`` host collector (per-ticket birth→claim wait tracking)
"""

from .analyze import (imbalance_timeline, key_inversions,
                      max_wait_highwater, measured_rank_error,
                      occupancy_timeline, rank_error_vs_envelope,
                      sojourn_percentiles, starvation_flags)
from .export import (read_jsonl, to_chrome_trace, write_chrome_trace,
                     write_jsonl)
from .metrics import Histogram, MetricsRegistry, metric_key
from .spans import (SpanPlane, Spans, bucket_edges, bucket_of, span_init,
                    span_record, span_tick)
from .trace import (KEY_SENTINEL, RoundRecord, SyncPoint, Telemetry,
                    TracePlane, drain_plane, masked_min_max, trace_init,
                    trace_record)

__all__ = [
    "KEY_SENTINEL", "Histogram", "MetricsRegistry", "RoundRecord",
    "SpanPlane", "Spans", "SyncPoint", "Telemetry", "TracePlane",
    "bucket_edges", "bucket_of", "drain_plane", "imbalance_timeline",
    "key_inversions", "masked_min_max", "max_wait_highwater",
    "measured_rank_error", "metric_key", "occupancy_timeline",
    "rank_error_vs_envelope", "read_jsonl", "sojourn_percentiles",
    "span_init", "span_record", "span_tick", "starvation_flags",
    "to_chrome_trace", "trace_init", "trace_record", "write_chrome_trace",
    "write_jsonl",
]
