"""G-WFQ — the paper's bounded wait-free GPU queue (§ III-C, Algorithm 2).

Fast path = G-LFQ's wave-batched ring, bounded by compile-time *patience*
constants.  After patience is exhausted, the operation publishes a fixed
per-thread request record and enters the cooperative slow path, where peers
help it to completion.  Every ``HELP_DELAY`` (= the paper's D) own operations
each thread inspects one peer record and drives whatever request it finds —
helper identity/kind is immaterial (see ``_maybe_help``).

Single-word shared state (Lemma III.5)
--------------------------------------
* global Head/Tail words pack ``(cnt, ThrIdx)`` (Fig. 3),
* per-thread local head/tail words pack ``(lcnt, seq, INC, FIN)``,
* request/result/note words are seq-tagged so stale helpers always fail
  their CASes (§ III-C-c publication discipline),
* ring entries pack ``(Cycle, Safe, Enq, Index)`` (Fig. 2).

Where wCQ publishes a *pointer to the request record* in the ring slot via
CAS2, G-WFQ stores an **owner tag** in the Index field of a not-yet-visible
entry (``Enq = 0``): any thread that encounters the pending entry can look up
the owner's request record and finalize it.  The Enq-bit 0→1 update makes the
entry visible to fast-path dequeues and does not move the linearization point
(§ III-C-e).

Round protocol and its invariants (validated by the linearizability tests):

1. **One increment per round** (Lemma III.7).  Enqueue rounds obtain their
   ticket through SLOWFAA (Algorithm 2): helpers race the global CAS
   ``⟨c, NULL⟩ → ⟨c+1, h⟩``; the winner's phase-2 record names the owner,
   seq and ticket, and the ticket is recorded into the owner's local word by
   a seq/INC-guarded CAS — exactly one increment and one record per round.
   Dequeue rounds perform exactly one FAA each (see point 5).
2. **The entry word is the round's commit object.**  A slow enqueue round
   succeeds iff the entry at (slot, cycle) reaches the visible state
   ``(c, *, 1, v)`` (or its consumed successor), and fails iff the entry
   reaches a state from which that is unreachable (⊥ at cycle c, or a newer
   cycle).  Both verdict states are permanent, so helpers cannot disagree.
3. **done-before-visible**: whoever flips Enq 0→1 must first CAS the owner's
   result word to *done* — hence any consumed/recycled entry implies the
   request was completed, which is what lets a late helper distinguish
   "succeeded then recycled" from "never installed" (no duplicate installs).
4. **Stale-slot exclusion** (Lemma III.8): a round failure is noted in the
   owner's Note word (as the failed *ticket*); later helpers for the same
   request skip the ruled-out slot and proceed directly to the next round.
5. **Head tickets are never dropped.**  A cooperative-CAS increment of Head
   can orphan a ticket (the increment lands, but the request completes
   through an earlier round before the ticket is recorded).  An unexercised
   *tail* ticket is benign — its slot simply stays empty and the matching
   dequeuer neutralizes it — but an unexercised *head* ticket strands any
   value later installed at its slot (nobody else will ever visit it).
   wCQ closes this with CAS2 (counter and helper state move together);
   with single-width atomics we instead keep Algorithm 2's SLOWFAA for
   Tail, and give Head rounds a claim discipline: the request's local-head
   INC bit is the round claim, the claim winner performs exactly one FAA,
   exercises the ticket's slot to a terminal state itself, and is the only
   thread allowed to deliver into the result word — so every consumed value
   has exactly one recipient and the delivering CAS cannot fail.  This
   deviation from Algorithm 2 is recorded in DESIGN.md § 1.
"""

from __future__ import annotations

from .atomics import AtomicMemory
from .base import QueueAlgorithm, VAL_MASK
from .glfq import NEG1, RETRY, SUCCESS, EMPTY
from .packed import (EntryFormat, GlobalFormat, LocalFormat, NoteFormat,
                     RequestFormat, ResultFormat)
from .sim import Ctx

G = GlobalFormat()
L = LocalFormat()
RQ = RequestFormat()
RS = ResultFormat()
NT = NoteFormat()

OWNER_TAG_BIT = 1 << 31  # Index-field bit marking "pending entry, Index = owner tid"

DONE = "done"
ROUND_FAILED = "round_failed"
STALE = "stale"
WAITING = "waiting"


class GWFQ(QueueAlgorithm):
    name = "gwfq"

    def __init__(self, capacity: int, num_threads: int, tag: str = "gwfq",
                 prefill: int = 0, cycle_bits: int = 30,
                 patience: int = 8, help_delay: int = 64,
                 helper_round_budget: int = 64) -> None:
        super().__init__(capacity, num_threads)
        assert num_threads < G.null_tid
        self.tag = tag
        self.prefill = prefill
        self.fmt = EntryFormat(idx_bits=32, cycle_bits=cycle_bits)
        self.nslots = 2 * capacity
        self.patience = patience
        self.help_delay = help_delay
        self.helper_round_budget = helper_round_budget
        t = tag
        self.s_tail, self.s_head = f"{t}_tailG", f"{t}_headG"
        self.s_thresh, self.s_entries = f"{t}_thresh", f"{t}_entries"
        self.s_req, self.s_res = f"{t}_req", f"{t}_res"
        self.s_localT, self.s_localH = f"{t}_localT", f"{t}_localH"
        self.s_noteq = f"{t}_note"
        self.s_phase2 = f"{t}_phase2"
        # thread-local (not shared-memory) bookkeeping
        self._seq = [0] * num_threads
        self._opct = [0] * num_threads
        self._peer = [(i + 1) % max(num_threads, 1) for i in range(num_threads)]

    # -- geometry ---------------------------------------------------------------

    def slot(self, t: int) -> int:
        return t % self.nslots

    def cycle(self, t: int) -> int:
        return (t // self.nslots) & self.fmt.cycle_mask

    @property
    def threshold_full(self) -> int:
        return 3 * self.capacity - 1

    def init(self, mem: AtomicMemory) -> None:
        self.mem = mem
        f = self.fmt
        nt = self.num_threads
        mem.alloc(self.s_tail, 1, fill=G.pack(self.nslots, G.null_tid))
        mem.alloc(self.s_head, 1, fill=G.pack(self.nslots, G.null_tid))
        mem.alloc(self.s_thresh, 1, fill=AtomicMemory.from_signed(-1))
        mem.alloc(self.s_entries, self.nslots, fill=f.pack(0, 1, 0, f.idx_bot))
        mem.alloc(self.s_req, nt)
        mem.alloc(self.s_res, nt)
        mem.alloc(self.s_localT, nt)
        mem.alloc(self.s_localH, nt)
        mem.alloc(self.s_noteq, nt)
        mem.alloc(self.s_phase2, nt)
        if self.prefill:
            assert self.prefill <= self.capacity
            entries = mem.array(self.s_entries)
            for i in range(self.prefill):
                t = self.nslots + i
                entries[self.slot(t)] = f.pack(self.cycle(t), 1, 1, i)
            mem.array(self.s_tail)[0] = G.pack(self.nslots + self.prefill, G.null_tid)
            mem.array(self.s_thresh)[0] = AtomicMemory.from_signed(self.threshold_full)

    # -- phase-2 record: [ticket:31 | owner:12 | seq:16 | pad] -----------------

    @staticmethod
    def _p2_pack(ticket: int, owner: int, seq: int) -> int:
        return (((ticket & ((1 << 31) - 1)) << 28)
                | ((owner & 0xFFF) << 16) | (seq & 0xFFFF))

    @staticmethod
    def _p2_unpack(word: int):
        return (word >> 28) & ((1 << 31) - 1), (word >> 16) & 0xFFF, word & 0xFFFF

    # ==========================================================================
    # Fast path (identical structure to G-LFQ, over packed global words)
    # ==========================================================================

    def _gfaa(self, ctx: Ctx, name: str):
        """Wave-batched FAA of the counter field of a packed global word.
        The counter occupies the high bits, so adding (count << tid_bits)
        never perturbs ThrIdx."""
        w = yield from ctx.wavefaa(name, 0, 1 << G.tid_bits)
        return G.cnt(w)

    def _gcnt(self, ctx: Ctx, name: str):
        w = yield from ctx.load(name, 0)
        return G.cnt(w)

    def _tryenq_fast(self, ctx: Ctx, tid: int, value: int):
        f = self.fmt
        t = yield from self._gfaa(ctx, self.s_tail)
        j, c = self.slot(t), self.cycle(t)
        while True:  # re-read on lost CAS races (sCQ discipline)
            e = yield from ctx.load(self.s_entries, j)
            if not (f.cycle_lt(f.cycle(e), c) and f.is_empty_idx(e)):
                return RETRY
            h = yield from self._gcnt(ctx, self.s_head)
            if not (f.safe(e) or h <= t):
                return RETRY
            ok = yield from ctx.cas(self.s_entries, j, e, f.pack(c, 1, 1, value))
            if ok:
                yield from ctx.store(self.s_thresh, 0,
                                     AtomicMemory.from_signed(self.threshold_full))
                return SUCCESS

    def _trydeq_fast(self, ctx: Ctx, tid: int):
        f = self.fmt
        thr = yield from ctx.load(self.s_thresh, 0)
        if AtomicMemory.to_signed(thr) < 0:
            return (EMPTY, None)
        t_h = yield from self._gfaa(ctx, self.s_head)
        r, v = yield from self._exercise_head_ticket(ctx, t_h)
        return (r, v)

    def _exercise_head_ticket(self, ctx: Ctx, t_h: int):
        """Drive head ticket ``t_h``'s slot to a terminal state and return
        (SUCCESS, v) | (RETRY, None) | (EMPTY, None).  RETRY/EMPTY follow the
        fast-path accounting (threshold decrement / tail catch-up).  The
        caller owns the ticket exclusively (fast path: its own FAA; slow
        path: the request's round claim), so a consumed value always has a
        recipient."""
        f = self.fmt
        j, c = self.slot(t_h), self.cycle(t_h)
        while True:  # re-read on lost CAS races (sCQ discipline)
            e = yield from ctx.load(self.s_entries, j)
            if f.cycle_eq(f.cycle(e), c) and not f.is_empty_idx(e):
                if f.enq(e) == 0:
                    # pending slow enqueue: finalize it, then consume
                    yield from self._complete_pending(ctx, j, e)
                    continue
                old = yield from ctx.consume(self.s_entries, j, f)
                v = f.idx(old)
                if v == f.idx_botc:
                    continue  # lost a consume race; re-read
                return (SUCCESS, v)
            if f.cycle_lt(f.cycle(e), c):
                if f.is_empty_idx(e):
                    new = f.pack(c, f.safe(e), 0, f.idx_bot)
                else:
                    new = f.pack(f.cycle(e), 0, f.enq(e), f.idx(e))
                ok = yield from ctx.cas(self.s_entries, j, e, new)
                if not ok:
                    continue
            break
        t = yield from self._gcnt(ctx, self.s_tail)
        if t <= t_h + 1:
            yield from self._catchup(ctx, t_h + 1)
            yield from ctx.faa(self.s_thresh, 0, NEG1)
            return (EMPTY, None)
        old_thr = yield from ctx.faa(self.s_thresh, 0, NEG1)
        if AtomicMemory.to_signed(old_thr) <= 0:
            return (EMPTY, None)
        return (RETRY, None)

    def _catchup(self, ctx: Ctx, target: int):
        while True:
            g = yield from ctx.load(self.s_tail, 0)
            if G.cnt(g) >= target:
                return
            ok = yield from ctx.cas(self.s_tail, 0, g, G.pack(target, G.thridx(g)))
            if ok:
                return

    # ==========================================================================
    # Pending-entry finalization (owner-tagged invisible entries)
    # ==========================================================================

    def _complete_pending(self, ctx: Ctx, j: int, e: int):
        """Finalize a pending (Enq=0, owner-tagged) entry: ensure the owner's
        result word is *done* first, then flip Enq (done-before-visible)."""
        f = self.fmt
        tag = f.idx(e)
        if not (tag & OWNER_TAG_BIT):
            return
        o = tag & 0xFFFF
        rq = yield from ctx.load(self.s_req, o)
        if not (RQ.pending(rq) and RQ.isenq(rq)):
            # request gone ⟹ this pending entry never delivered (a delivered
            # entry is flipped before its request retires) — roll it back so
            # dequeuers are not blocked by unreachable garbage.
            yield from ctx.cas(self.s_entries, j, e,
                               f.pack(f.cycle(e), f.safe(e), 0, f.idx_bot))
            return
        s, v = RQ.seq(rq), RQ.value(rq)
        r = yield from ctx.load(self.s_res, o)
        if RS.seq(r) != s:
            return  # torn republish window; caller re-reads
        if not RS.done(r):
            yield from ctx.cas(self.s_res, o, r, RS.pack(v, s, 1, 0))
        # Gate the visibility flip on a *re-read* of the result word: flip
        # only when this request's result is done-with-value (not FULL).
        r2 = yield from ctx.load(self.s_res, o)
        if RS.seq(r2) != s or not RS.done(r2):
            return
        if RS.empty(r2):
            # zombie pending entry of a FULL-resolved request: roll back
            yield from ctx.cas(self.s_entries, j, e,
                               f.pack(f.cycle(e), f.safe(e), 0, f.idx_bot))
            return
        # flip Enq 0→1, substituting the real value for the owner tag
        yield from ctx.cas(self.s_entries, j, e, f.pack(f.cycle(e), f.safe(e), 1, v))
        # the flip commits a delivery: reset Threshold exactly as the fast
        # path does after its install CAS (Alg. 1 line 20) — without this a
        # slow enqueue can leave the threshold negative and strand its value
        yield from ctx.store(self.s_thresh, 0,
                             AtomicMemory.from_signed(self.threshold_full))

    # ==========================================================================
    # SLOWFAA (Algorithm 2) — cooperative Tail increment, one per round
    # ==========================================================================

    def _slowfaa_tail(self, ctx: Ctx, helper: int, o: int, s: int):
        """Advance the owner's enqueue round: returns ('ticket', t) once the
        round's ticket is recorded in the owner's local-tail word, or
        ('fin'|'stale', _).  A ticket whose record CAS loses (the round
        already resolved) is dropped — benign for Tail (see point 5)."""
        while True:
            lw = yield from ctx.load(self.s_localT, o)
            if L.seq(lw) != s:
                return (STALE, None)
            if L.fin(lw):
                return (DONE, None)
            if L.inc(lw):
                # INC set ⟺ a round is live with ticket lcnt.  Rounds are
                # strictly serialized: records require INC == 0, and INC is
                # cleared only after the round's permanent-verdict failure.
                return ("ticket", L.lcnt(lw))
            g = yield from ctx.load(self.s_tail, 0)
            c, u = G.cnt(g), G.thridx(g)
            if u != G.null_tid:
                # phase-2 in flight: helper u's record names owner, seq, ticket
                p2 = yield from ctx.load(self.s_phase2, u)
                t0, o2, s2 = self._p2_unpack(p2)
                lw2 = yield from ctx.load(self.s_localT, o2)
                if (L.seq(lw2) == s2 and not L.fin(lw2) and not L.inc(lw2)
                        and L.lcnt(lw2) < t0):
                    yield from ctx.cas(self.s_localT, o2, lw2,
                                       L.pack(t0, s2, 1, 0))
                yield from ctx.cas(self.s_tail, 0, g, G.pack(c, G.null_tid))
                continue
            # publish our phase-2 record, then race for the increment
            yield from ctx.store(self.s_phase2, helper, self._p2_pack(c, o, s))
            won = yield from ctx.cas(self.s_tail, 0, g, G.pack(c + 1, helper))
            if won:
                lw2 = yield from ctx.load(self.s_localT, o)
                if (L.seq(lw2) == s and not L.fin(lw2) and not L.inc(lw2)
                        and L.lcnt(lw2) < c):
                    yield from ctx.cas(self.s_localT, o, lw2, L.pack(c, s, 1, 0))
                # clear ThrIdx (loop: fast-path FAAs may bump the counter)
                while True:
                    g2 = yield from ctx.load(self.s_tail, 0)
                    if G.thridx(g2) != helper:
                        break
                    ok = yield from ctx.cas(self.s_tail, 0, g2,
                                            G.pack(G.cnt(g2), G.null_tid))
                    if ok:
                        break
            # loop: the top re-reads the local word

    # ==========================================================================
    # Slow-path round actions (TRYENQSLOW / TRYDEQSLOW, § III-C-d)
    # ==========================================================================

    def _note_failed(self, ctx: Ctx, o: int, s: int, ticket: int):
        """Advance Note to this round's failed ticket (Lemma III.8), then
        clear INC so the next round can start.  Permanence of the entry-word
        verdict guarantees no late install can revive the noted round, so the
        note→clear order is race-free."""
        while True:
            nw = yield from ctx.load(self.s_noteq, o)
            if NT.seq(nw) != s:
                return
            if NT.valid(nw) and NT.cycle(nw) >= ticket:
                break
            ok = yield from ctx.cas(self.s_noteq, o, nw, NT.pack(ticket, s, 1))
            if ok:
                break
        lw = yield from ctx.load(self.s_localT, o)
        if L.seq(lw) == s and L.inc(lw) and not L.fin(lw) and L.lcnt(lw) == ticket:
            yield from ctx.cas(self.s_localT, o, lw, L.pack(ticket, s, 0, 0))

    def _noted(self, ctx: Ctx, o: int, s: int, ticket: int):
        nw = yield from ctx.load(self.s_noteq, o)
        return NT.seq(nw) == s and NT.valid(nw) and NT.cycle(nw) >= ticket

    def _set_fin(self, ctx: Ctx, o: int, s: int, which_head: int):
        l_name = self.s_localH if which_head else self.s_localT
        while True:
            lw = yield from ctx.load(l_name, o)
            if L.seq(lw) != s or L.fin(lw):
                return
            ok = yield from ctx.cas(l_name, o, lw, L.pack(L.lcnt(lw), s, 0, 1))
            if ok:
                return

    def _try_res_done(self, ctx: Ctx, o: int, s: int, value: int, empty: int):
        r = yield from ctx.load(self.s_res, o)
        if RS.seq(r) == s and not RS.done(r):
            ok = yield from ctx.cas(self.s_res, o, r, RS.pack(value, s, 1, empty))
            return ok
        return False

    def _res_done(self, ctx: Ctx, o: int, s: int):
        r = yield from ctx.load(self.s_res, o)
        return (RS.seq(r) == s and RS.done(r), r)

    def _enq_round(self, ctx: Ctx, o: int, s: int, v: int, t: int):
        """One slow-enqueue round for ticket t.  Returns DONE, ROUND_FAILED,
        or WAITING (slot transiently undecided: stale live value)."""
        f = self.fmt
        j, c = self.slot(t), self.cycle(t)
        tag = OWNER_TAG_BIT | o
        while True:
            done, _ = yield from self._res_done(ctx, o, s)
            if done:
                yield from self._set_fin(ctx, o, s, 0)
                return DONE
            if (yield from self._noted(ctx, o, s, t)):
                yield from self._note_failed(ctx, o, s, t)  # ensure INC clear
                return ROUND_FAILED
            e = yield from ctx.load(self.s_entries, j)
            ec, ei = f.cycle(e), f.idx(e)
            if f.cycle_eq(ec, c):
                if ei == tag:
                    # ours, pending: done-before-visible, then flip
                    yield from self._complete_pending(ctx, j, e)
                    continue
                if ei == v and f.enq(e):
                    # ours, visible (flip already happened)
                    yield from self._try_res_done(ctx, o, s, v, 0)
                    yield from self._set_fin(ctx, o, s, 0)
                    yield from ctx.store(self.s_thresh, 0,
                                         AtomicMemory.from_signed(self.threshold_full))
                    return DONE
                if ei == f.idx_botc:
                    # ours, already consumed ⇒ done-before-visible implies the
                    # result word is (or is about to be) done — loop to top.
                    yield from ctx.step()
                    continue
                # ⊥ at our cycle (dequeuer neutralized the slot): permanent fail
                yield from self._note_failed(ctx, o, s, t)
                return ROUND_FAILED
            if f.cycle_lt(c, ec):
                # newer cycle: permanent fail (res-done already checked above)
                yield from self._note_failed(ctx, o, s, t)
                return ROUND_FAILED
            # older cycle
            if f.is_empty_idx(e):
                h = yield from self._gcnt(ctx, self.s_head)
                if f.safe(e) or h <= t:
                    # install invisible owner-tagged entry
                    yield from ctx.cas(self.s_entries, j, e, f.pack(c, 1, 0, tag))
                    continue
                # unreachable for us (unsafe ∧ matching dequeuer passed):
                # neutralize to our cycle so the verdict becomes permanent
                yield from ctx.cas(self.s_entries, j, e,
                                   f.pack(c, f.safe(e), 0, f.idx_bot))
                continue
            # stale live value: wait for its consumption (bounded by the
            # FULL accounting at the driver level)
            return WAITING

    # ==========================================================================
    # Slow-path drivers
    # ==========================================================================

    def _drive_enq(self, ctx: Ctx, helper: int, o: int, s: int, v: int,
                   budget: int):
        """Drive enqueue request (o, s) toward completion.  Returns True if
        resolved, False if budget exhausted."""
        for _ in range(budget):
            rq = yield from ctx.load(self.s_req, o)
            if RQ.seq(rq) != s or not RQ.pending(rq):
                return True  # request gone (completed & reclaimed)
            done, _ = yield from self._res_done(ctx, o, s)
            if done:
                yield from self._set_fin(ctx, o, s, 0)
                return True
            # FULL resolution (conservative: slow-path skew inflates Tail)
            tl = yield from self._gcnt(ctx, self.s_tail)
            hd = yield from self._gcnt(ctx, self.s_head)
            if tl - hd >= self.capacity + self.num_threads:
                yield from self._try_res_done(ctx, o, s, 0, 1)  # FULL
                yield from self._set_fin(ctx, o, s, 0)
                return True
            st, t = yield from self._slowfaa_tail(ctx, helper, o, s)
            if st in (STALE, DONE):
                return True
            r = yield from self._enq_round(ctx, o, s, v, t)
            if r == DONE:
                return True
            yield from ctx.step()
        return False

    def _drive_deq(self, ctx: Ctx, helper: int, o: int, s: int, budget: int):
        """Drive dequeue request (o, s).  Rounds are serialized through the
        request's local-head INC bit: the claim winner is the only thread
        that may FAA Head, exercise the ticket, and deliver — so every
        consumed value has exactly one recipient and the delivering res-CAS
        cannot fail.  Returns True when the request is resolved."""
        for _ in range(budget):
            rq = yield from ctx.load(self.s_req, o)
            if RQ.seq(rq) != s or not RQ.pending(rq):
                return True  # request gone (completed & reclaimed)
            done, _ = yield from self._res_done(ctx, o, s)
            if done:
                yield from self._set_fin(ctx, o, s, 1)
                return True
            lw = yield from ctx.load(self.s_localH, o)
            if L.seq(lw) != s or L.fin(lw):
                return True
            if L.inc(lw):
                # a round is in flight under another claimer — wait
                yield from ctx.step()
                continue
            won = yield from ctx.cas(self.s_localH, o, lw,
                                     L.pack(L.lcnt(lw), s, 1, 0))
            if not won:
                continue
            # we hold the round claim: resolve EMPTY or run one ticket
            thr = yield from ctx.load(self.s_thresh, 0)
            if AtomicMemory.to_signed(thr) < 0:
                yield from self._try_res_done(ctx, o, s, 0, 1)  # EMPTY
                yield from self._set_fin(ctx, o, s, 1)
                return True
            t_h = yield from self._gfaa(ctx, self.s_head)
            r, v = yield from self._exercise_head_ticket(ctx, t_h)
            if r == SUCCESS:
                yield from self._try_res_done(ctx, o, s, v, 0)
                yield from self._set_fin(ctx, o, s, 1)
                return True
            if r == EMPTY:
                yield from self._try_res_done(ctx, o, s, 0, 1)
                yield from self._set_fin(ctx, o, s, 1)
                return True
            # RETRY: release the round claim
            lw2 = yield from ctx.load(self.s_localH, o)
            if L.seq(lw2) == s and L.inc(lw2) and not L.fin(lw2):
                yield from ctx.cas(self.s_localH, o, lw2,
                                   L.pack(t_h, s, 0, 0))
            yield from ctx.step()
        return False

    def _maybe_help(self, ctx: Ctx, tid: int):
        """Every HELP_DELAY own-operations, inspect one peer record (the
        paper's help-delay D) and drive whichever request it holds.  Any
        thread may help either kind: dequeue delivery goes through the
        request's round claim and result word (never to the helper), and
        enqueue rounds commit on the entry word — helper identity is
        immaterial.  (A per-kind split with a shared counter silently
        starves one kind under alternating workloads — found by the
        starvation test, kept here as a warning.)"""
        self._opct[tid] += 1
        if self.num_threads <= 1 or self._opct[tid] % self.help_delay:
            return
        p = self._peer[tid]
        self._peer[tid] = (p + 1) % self.num_threads
        if p == tid:
            p = (p + 1) % self.num_threads
            self._peer[tid] = (p + 1) % self.num_threads
            if p == tid:
                return
        rq = yield from ctx.load(self.s_req, p)
        if RQ.pending(rq):
            if RQ.isenq(rq):
                yield from self._drive_enq(ctx, tid, p, RQ.seq(rq),
                                           RQ.value(rq),
                                           self.helper_round_budget)
            else:
                yield from self._drive_deq(ctx, tid, p, RQ.seq(rq),
                                           self.helper_round_budget)

    # ==========================================================================
    # Public operations
    # ==========================================================================

    def _publish(self, ctx: Ctx, tid: int, isenq: int, v: int):
        """Publication discipline (§ III-C-c): payload words first, request
        word (seq+pending) last."""
        self._seq[tid] = (self._seq[tid] + 1) & RQ.seq_mask
        s = self._seq[tid]
        yield from ctx.store(self.s_res, tid, RS.pack(0, s, 0, 0))
        yield from ctx.store(self.s_noteq, tid, NT.pack(0, s, 0))
        l_name = self.s_localT if isenq else self.s_localH
        yield from ctx.store(l_name, tid, L.pack(0, s, 0, 0))
        yield from ctx.store(self.s_req, tid, RQ.pack(v, s, 1, isenq))
        return s

    def _retire(self, ctx: Ctx, tid: int, s: int, isenq: int, v: int):
        yield from ctx.store(self.s_req, tid, RQ.pack(v, s, 0, isenq))

    def enqueue(self, ctx: Ctx, tid: int, value: int):
        assert 0 <= value <= VAL_MASK
        yield from self._maybe_help(ctx, tid)
        for _ in range(self.patience):
            t = yield from self._gcnt(ctx, self.s_tail)
            h = yield from self._gcnt(ctx, self.s_head)
            if t - h >= self.capacity:
                return False
            r = yield from self._tryenq_fast(ctx, tid, value)
            if r == SUCCESS:
                return True
        # slow path
        s = yield from self._publish(ctx, tid, 1, value)
        while True:
            resolved = yield from self._drive_enq(ctx, tid, tid, s, value, 1 << 30)
            if resolved:
                break
        _, r = yield from self._res_done(ctx, tid, s)
        yield from self._retire(ctx, tid, s, 1, value)
        return not RS.empty(r)

    def dequeue(self, ctx: Ctx, tid: int):
        yield from self._maybe_help(ctx, tid)
        for _ in range(self.patience):
            r, v = yield from self._trydeq_fast(ctx, tid)
            if r == SUCCESS:
                return (True, v)
            if r == EMPTY:
                return (False, None)
        s = yield from self._publish(ctx, tid, 0, 0)
        while True:
            resolved = yield from self._drive_deq(ctx, tid, tid, s, 1 << 30)
            if resolved:
                break
        _, r = yield from self._res_done(ctx, tid, s)
        yield from self._retire(ctx, tid, s, 0, 0)
        if RS.empty(r):
            return (False, None)
        return (True, RS.value(r))
