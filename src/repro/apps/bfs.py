"""Level-synchronous BFS with queue-managed frontiers (paper § V-B-a).

Two implementations over CSR graphs:

* ``bfs_queue`` — the paper's design: two frontier queues alternate across
  levels; frontier expansion is the Pallas ``frontier_expand`` kernel whose
  next-frontier enqueue is ticket reservation (aggregate-then-commit).
* ``bfs_baseline`` — the Gunrock-style stand-in: dense boolean frontier
  masks with a segment-sum sweep over all vertices per level (no queue) —
  the comparison baseline for benchmarks/bench_bfs.py.

Synthetic graph generators mirror the Table IV families: road-like (low
degree, high diameter), kron/social-like (power-law), delaunay-like
(constant degree).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops


@dataclass
class CSRGraph:
    row_ptr: np.ndarray  # (n+1,) int32
    col_idx: np.ndarray  # (m,) int32
    name: str = "g"

    @property
    def n(self) -> int:
        return len(self.row_ptr) - 1

    @property
    def m(self) -> int:
        return len(self.col_idx)


def road_like(n: int, seed: int = 0) -> CSRGraph:
    """Grid-ish graph: low avg degree, long diameter (road_usa family)."""
    side = int(np.sqrt(n))
    n = side * side
    rows, cols = [], []
    for v in range(n):
        r, c = divmod(v, side)
        for dr, dc in ((0, 1), (1, 0), (0, -1), (-1, 0)):
            rr, cc = r + dr, c + dc
            if 0 <= rr < side and 0 <= cc < side:
                rows.append(v)
                cols.append(rr * side + cc)
    return _to_csr(n, rows, cols, f"road_{n}")


def kron_like(n: int, avg_deg: int = 16, seed: int = 0) -> CSRGraph:
    """Power-law graph (kron_g500 / hollywood family)."""
    rng = np.random.default_rng(seed)
    m = n * avg_deg
    # preferential-attachment-ish: sample endpoints from a zipf-weighted pool
    w = 1.0 / np.arange(1, n + 1) ** 0.6
    p = w / w.sum()
    src = rng.choice(n, m, p=p)
    dst = rng.choice(n, m, p=p)
    keep = src != dst
    return _to_csr(n, src[keep], dst[keep], f"kron_{n}")


def delaunay_like(n: int, deg: int = 6, seed: int = 0) -> CSRGraph:
    """Constant-degree random graph (delaunay family)."""
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, n * deg)
    return _to_csr(n, src, dst, f"delaunay_{n}")


def _to_csr(n: int, rows, cols, name: str) -> CSRGraph:
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    order = np.argsort(rows, kind="stable")
    rows, cols = rows[order], cols[order]
    row_ptr = np.zeros(n + 1, np.int32)
    np.add.at(row_ptr, rows + 1, 1)
    row_ptr = np.cumsum(row_ptr).astype(np.int32)
    return CSRGraph(row_ptr, cols.astype(np.int32), name)


# ---------------------------------------------------------------------------


def bfs_queue(g: CSRGraph, source: int = 0, *, use_kernel: bool = True
              ) -> Tuple[np.ndarray, Dict]:
    """Queue-driven BFS: alternate two frontier queues across levels."""
    n = g.n
    row_ptr = jnp.asarray(g.row_ptr)
    col_idx = jnp.asarray(g.col_idx)
    visited = jnp.zeros(n, jnp.int32).at[source].set(1)
    dist = np.full(n, -1, np.int32)
    dist[source] = 0
    frontier = jnp.full(max(n, 16), -1, jnp.int32).at[0].set(source)
    level, edges_scanned = 0, 0
    flen = 1
    while flen > 0:
        nxt, cnt, visited = ops.frontier_expand(
            row_ptr, col_idx, frontier, visited, max_out=max(n, 16),
            use_kernel=use_kernel)
        flen = int(cnt[0])
        level += 1
        f_np = np.asarray(nxt[:flen])
        edges_scanned += int(np.sum(g.row_ptr[np.asarray(frontier[frontier >= 0]) + 1]
                                    - g.row_ptr[np.asarray(frontier[frontier >= 0])]))
        dist[f_np] = level
        frontier = nxt
    return dist, {"levels": level, "edges_scanned": edges_scanned}


def bfs_baseline(g: CSRGraph, source: int = 0) -> Tuple[np.ndarray, Dict]:
    """Gunrock-style dense sweep: per level, scatter frontier over all edges
    with a boolean mask (no queue, no compaction)."""
    n = g.n
    row_ptr, col_idx = g.row_ptr, g.col_idx
    # edge source vector
    src = np.repeat(np.arange(n, dtype=np.int32),
                    np.diff(row_ptr).astype(np.int64))
    src_j = jnp.asarray(src)
    col_j = jnp.asarray(col_idx)
    front = jnp.zeros(n, jnp.bool_).at[source].set(True)
    visited = front
    dist = np.full(n, -1, np.int32)
    dist[source] = 0
    level = 0

    @jax.jit
    def sweep(front, visited):
        active = front[src_j]
        touched = jnp.zeros(n, jnp.bool_).at[col_j].max(active)
        new = touched & (~visited)
        return new, visited | new

    while bool(front.any()):
        front, visited = sweep(front, visited)
        level += 1
        newly = np.asarray(front)
        dist[newly & (dist == -1)] = level
        if not newly.any():
            break
    return dist, {"levels": level}


def bfs_runtime(g: CSRGraph, source: int = 0, *, algo: str = "glfq",
                shards: int = 4, workers: int = 16, steal: bool = True,
                policy: str = "gang", seed: int = 0
                ) -> Tuple[np.ndarray, Dict]:
    """Task-runtime BFS: frontier expansion as dynamically spawned tasks on
    the sharded fabric (DESIGN.md § 4.6).

    One task = relax one vertex; its handler scans the adjacency list
    (simulated cost = degree, so power-law graphs yield power-law task
    costs) and spawns a child for every neighbour whose tentative distance
    improves (the handler runs atomically between simulator instructions —
    the host stand-in for an atomic min on the distance array).  Unlike
    ``bfs_queue`` there is no level barrier: the fabric's interleaving may
    discover a vertex via a long path first, and the asynchronous relaxation
    re-spawns it when a shorter path arrives — distances are exact at
    quiescence (monotone label-correcting, Wang et al.'s dynamic
    load-balancing discipline), while the *fabric* still executes every
    spawned task exactly once."""
    from ..runtime import ExecutorConfig, TaskFabric, TaskRuntime, TaskSpec

    dist = np.full(g.n, -1, np.int32)
    dist[source] = 0
    edges_scanned = 0

    def handler(rec):
        nonlocal edges_scanned
        v = rec.payload
        dv = int(dist[v])
        lo, hi = int(g.row_ptr[v]), int(g.row_ptr[v + 1])
        edges_scanned += hi - lo
        children = []
        for w in g.col_idx[lo:hi]:
            w = int(w)
            nd = dv + 1
            if dist[w] < 0 or nd < dist[w]:   # atomic relax (host = one step)
                dist[w] = nd
                deg_w = int(g.row_ptr[w + 1]) - int(g.row_ptr[w])
                children.append(TaskSpec(w, cost=max(deg_w, 1)))
        return children

    fabric = TaskFabric(algo=algo, shards=shards,
                        capacity_per_shard=max(2 * g.n // max(shards, 1), 64),
                        num_threads=workers + 1, steal=steal)
    rt = TaskRuntime(fabric, handler,
                     ExecutorConfig(workers=workers, policy=policy, seed=seed,
                                    max_steps=50_000_000))
    rt.add_task(source,
                cost=max(int(g.row_ptr[source + 1]) - int(g.row_ptr[source]), 1))
    metrics = rt.run()
    info = {"tasks": len(rt.executed), "edges_scanned": edges_scanned,
            "steal_rate": metrics["steal_rate"],
            "idle_steps": metrics["idle_steps"],
            "load_imbalance": metrics["load_imbalance"],
            "throughput_ops_per_kstep": metrics["throughput_ops_per_kstep"]}
    return dist, info


def bfs_rounds_runner(g: CSRGraph, *, batch: int = 64, fused: bool = True,
                      interpret=None, sync_every: int = 0, telemetry=None,
                      compact=None):
    """Build the round-engine BFS runner for ``g`` (see ``bfs_rounds``).
    Returns ``(runner, init_fn)`` where ``init_fn(source)`` produces the
    distance accumulator — callers that run BFS repeatedly (benchmarks)
    reuse the runner to amortize the megaround compilation."""
    from ..runtime import RoundRunner

    n = g.n
    deg = np.diff(g.row_ptr).astype(np.int64)
    fan = max(int(deg.max()) if n else 0, 1)
    nbr = np.full((n, fan), -1, np.int32)
    rows = np.repeat(np.arange(n), deg)
    pos = np.arange(g.m) - np.repeat(g.row_ptr[:-1].astype(np.int64), deg)
    nbr[rows, pos] = g.col_idx
    nbr_j = jnp.asarray(nbr)
    big = np.iinfo(np.int32).max

    def step(dist, vals, valid):
        v = jnp.where(valid, vals, 0)
        dv = jnp.where(valid, dist[v], 0)
        w = jnp.where(valid[:, None], nbr_j[v], -1)          # (B, F)
        wc = jnp.clip(w, 0, n - 1)
        eligible = (w >= 0) & (dist[wc] < 0)
        b, f = w.shape
        wf = w.reshape(-1)
        elig_f = eligible.reshape(-1)
        tgt = jnp.where(elig_f, wf, n)                       # n = trash slot
        order = jnp.arange(b * f, dtype=jnp.int32)
        claim = jnp.full((n + 1,), big, jnp.int32).at[tgt].min(order)
        win = elig_f & (claim[tgt] == order)                 # first parent
        ndist = jnp.repeat(dv + 1, f)
        dist = dist.at[jnp.where(win, wf, n)].set(ndist, mode="drop")
        return dist, wc, win.reshape(b, f)

    capacity_log2 = max(int(np.ceil(np.log2(max(n + 1, 2 * batch)))), 4)
    runner = RoundRunner(step, capacity_log2=capacity_log2, batch=batch,
                         fused=fused, interpret=interpret,
                         sync_every=sync_every, telemetry=telemetry,
                         compact=compact)

    def init_fn(source: int):
        return jnp.full((n,), -1, jnp.int32).at[source].set(0)

    return runner, init_fn


def bfs_rounds(g: CSRGraph, source: int = 0, *, batch: int = 64,
               fused: bool = True, interpret=None, sync_every: int = 0,
               max_rounds: int = 100_000) -> Tuple[np.ndarray, Dict]:
    """BFS on the deterministic round engine (DESIGN.md § 4.3): the ring
    carries vertex ids, one jitted step relaxes a batch of vertices against
    a dense padded adjacency table and spawns the neighbours it newly
    claims.  Within a batch, a vertex reached by several parents goes to
    the row-major-first parent (a scatter-min claim) — the batched analogue
    of the sequential queue's first-visit rule, so distances are exact.

    ``fused=True`` (default) runs the whole loop device-resident with host
    sync only at quiescence; ``fused=False`` is the legacy per-round path.
    Both are bit-identical."""
    runner, init_fn = bfs_rounds_runner(g, batch=batch, fused=fused,
                                        interpret=interpret,
                                        sync_every=sync_every)
    dist, _ = runner.run([source], acc=init_fn(source),
                         max_rounds=max_rounds)
    return np.asarray(dist), dict(runner.stats)


def bfs_mesh_rounds_runner(g: CSRGraph, *, mesh=None, shards: int = None,
                           axis: str = "data", batch: int = 64,
                           fused: bool = True, sync_every: int = 0,
                           capacity_log2: int = None, telemetry=None,
                           compact=None):
    """Build the *mesh*-scope BFS runner (DESIGN.md § 2.3): frontier
    vertices flow through the replicated distqueue, each shard steps its
    claimed slice of the round, and children publish back with one psum
    per round.  Returns ``(runner, seeds, init_fn)``.

    The queue payload packs ``(distance, vertex)`` as ``d·n + v`` so a
    claim is self-contained — a shard can relax a vertex it has never seen
    (its local label array is stale for vertices other shards claimed).
    The step is asynchronous label-correcting: a claim expands only if its
    distance improves the shard's local label, and per-shard labels are
    min-combined at quiescence, which converges to exact BFS distances
    (every shortest-path prefix is claimed *somewhere* with its true
    distance and re-published on improvement).  Returns
    ``(runner, init_fn)`` where ``init_fn(source)`` builds the label
    accumulator."""
    from ..jaxcompat import make_mesh
    from ..runtime import MeshRoundRunner

    n = g.n
    if mesh is None:
        shards = shards or len(jax.devices())
        mesh = make_mesh((shards,), (axis,))
    nshards = int(mesh.shape[axis])
    if n * (n + 2) >= 2 ** 31:
        raise ValueError(f"graph too large for packed (d, v) payloads: "
                         f"n={n} needs n*(n+2) < 2^31")
    deg = np.diff(g.row_ptr).astype(np.int64)
    fan = max(int(deg.max()) if n else 0, 1)
    # the in-batch winner key is nd·(batch·fan) + order, nd ≤ n
    if (n + 1) * batch * fan >= 2 ** 31:
        raise ValueError(f"batch {batch} x max degree {fan} too wide for "
                         f"int32 winner keys on n={n}: needs "
                         f"(n+1)*batch*fan < 2^31")
    nbr = np.full((n, fan), -1, np.int32)
    rows = np.repeat(np.arange(n), deg)
    pos = np.arange(g.m) - np.repeat(g.row_ptr[:-1].astype(np.int64), deg)
    nbr[rows, pos] = g.col_idx
    nbr_j = jnp.asarray(nbr)
    big = np.iinfo(np.int32).max

    def step(dist, vals, valid):
        b = vals.shape[0]
        v = jnp.where(valid, vals % n, 0)
        d = jnp.where(valid, vals // n, 0)
        # expand unless the local label already beats the claim (labels are
        # real path lengths ≥ the true distance, so a true-distance claim
        # is never stale; ``==`` claims re-expand but spawn only improving
        # children, which keeps the recursion finite)
        fresh = valid & (d <= dist[v])
        dist = dist.at[jnp.where(fresh, v, n)].min(d, mode="drop")
        w = jnp.where(fresh[:, None], nbr_j[v], -1)    # (B, F)
        wc = jnp.clip(w, 0, n - 1)
        nd = jnp.broadcast_to((d + 1)[:, None], w.shape)
        elig = (w >= 0) & (nd < dist[wc])
        # in-batch winner per target: smallest nd, then row-major order
        bf = b * w.shape[1]
        order = jnp.arange(bf, dtype=jnp.int32)
        key = nd.reshape(-1) * bf + order
        ef, wf, ndf = elig.reshape(-1), w.reshape(-1), nd.reshape(-1)
        tgt = jnp.where(ef, wf, n)
        claim = jnp.full((n + 1,), big, jnp.int32).at[tgt].min(
            jnp.where(ef, key, big))
        win = ef & (claim[tgt] == key)
        dist = dist.at[jnp.where(win, wf, n)].min(ndf, mode="drop")
        cv = jnp.where(win, ndf * n + jnp.clip(wf, 0, n - 1), 0)
        return dist, cv.reshape(w.shape), win.reshape(w.shape)

    def combine(stacked):                              # (shards, n) labels
        m = stacked.min(0)
        return jnp.where(m == big, -1, m)

    if capacity_log2 is None:
        capacity_log2 = max(
            int(np.ceil(np.log2(max(2 * n * nshards, 4 * batch * nshards)))),
            4)
    runner = MeshRoundRunner(step, mesh=mesh, axis=axis,
                             capacity_log2=capacity_log2, batch=batch,
                             fused=fused, sync_every=sync_every,
                             combine=combine, telemetry=telemetry,
                             compact=compact)

    def init_fn(source: int):
        # all labels unvisited (BIG) — the source's 0 arrives via its seed
        # claim (pre-setting it would make that claim non-improving and
        # suppress the very first expansion)
        del source
        return jnp.full((n,), big, jnp.int32)

    return runner, init_fn


def bfs_mesh_rounds(g: CSRGraph, source: int = 0, *, mesh=None,
                    shards: int = None, batch: int = 64, fused: bool = True,
                    sync_every: int = 0, max_rounds: int = 100_000
                    ) -> Tuple[np.ndarray, Dict]:
    """BFS on the mesh-fused round engine across ≥1 shards: exact distances
    at quiescence, host sync only at quiescence when ``fused=True``."""
    runner, init_fn = bfs_mesh_rounds_runner(g, mesh=mesh, shards=shards,
                                             batch=batch, fused=fused,
                                             sync_every=sync_every)
    dist, _ = runner.run([source], acc=init_fn(source),
                         max_rounds=max_rounds)
    return np.asarray(dist), dict(runner.stats)


def bfs_reference(g: CSRGraph, source: int = 0) -> np.ndarray:
    """Plain numpy BFS oracle."""
    from collections import deque
    dist = np.full(g.n, -1, np.int32)
    dist[source] = 0
    dq = deque([source])
    while dq:
        u = dq.popleft()
        for k in range(g.row_ptr[u], g.row_ptr[u + 1]):
            v = g.col_idx[k]
            if dist[v] < 0:
                dist[v] = dist[u] + 1
                dq.append(v)
    return dist
