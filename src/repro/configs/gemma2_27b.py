"""gemma2-27b — 46L dense GQA, alternating local/global attention with logit
soft-capping [arXiv:2408.00118; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab=256000,
    sliding_window=4096, layer_pattern=("local", "global"),
    attn_softcap=50.0, final_softcap=30.0,
    rope_theta=10000.0, fsdp=True,
)
