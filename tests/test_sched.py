"""G-PQ priority scheduling subsystem invariants (DESIGN.md § 5):

* strict G-PQ histories are priority-linearizable at k = 0 under all three
  sim schedules; the k-relaxed multi-ring variant stays within its
  declared quantitative bound (exact ``lazy`` at R = 1, the windowed-
  interference envelope otherwise) — and demonstrably *is* relaxed (a
  deterministic multi-ring run violates k = 0);
* the priority-semantics checker accepts positive fixtures and rejects
  each bad pattern (Q1–Q4), agreeing with the exact Wing–Gong search
  oracle on machine-generated histories from every schedule;
* the Pallas heap kernel matches a host heap oracle op-for-op, and
  ``PriorityRoundRunner`` is bit-deterministic and exactly-once;
* ``PriorityFabric`` executes every task exactly once under every policy
  and schedule, with per-shard histories passing the checker, and steals
  highest-priority-first;
* starvation-freedom: under sustained urgent arrivals the weighted and
  EDF policies complete normal-class tasks within a bounded step horizon
  while the strict policy starves them past it (asserted as such), and
  the bench acceptance holds — EDF/weighted throughput ≥ strict with
  strictly lower normal-class max wait;
* ``TaskFabric.register`` / the policies raise ``ValueError`` on
  out-of-range priorities instead of clamping;
* the serving engine's EDF admission ages waiting normal requests toward
  urgency instead of starving them behind an urgent flood.
"""

import os
import random
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

from repro.core import AtomicMemory
from repro.core.sim import Scheduler, HistoryEvent
from repro.sched import (GPQ, RelaxedGPQ, check_p_linearizable,
                         check_p_linearizable_search)
from repro.sched.gpq import DELMIN, INS

SCHEDULES = ["random", "gang", "rr"]


def _run_pq(pq, policy, seed, *, n_threads=12, ops=8, wave=4, p_ins=0.55,
            key_range=50):
    mem = AtomicMemory()
    sched = Scheduler(mem, wave_size=wave, policy=policy, seed=seed)
    pq.init(mem)

    def body(ctx, tid):
        rng = random.Random(seed * 1009 + tid)
        for k in range(ops):
            if rng.random() < p_ins:
                yield from pq.insert(ctx, tid, rng.randrange(key_range),
                                     tid * 1000 + k)
            else:
                yield from pq.delete_min(ctx, tid)

    for _ in range(n_threads):
        sched.spawn(body)
    assert sched.run(2_000_000), "simulation did not finish"
    return sched.history


def _min_passing_k(history, cap=200):
    k = 0
    while k <= cap:
        if check_p_linearizable(history, k=k).ok:
            return k
        k += 1
    return cap + 1


# -- strict G-PQ: 0-relaxed under every schedule ------------------------------


@pytest.mark.parametrize("policy", SCHEDULES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_gpq_strictly_p_linearizable(policy, seed):
    h = _run_pq(GPQ(64, 13, tag=f"g_{policy}_{seed}"), policy, seed)
    res = check_p_linearizable(h, k=0)
    assert res.ok, res.reason


@pytest.mark.parametrize("policy", SCHEDULES)
def test_gpq_agrees_with_search_oracle(policy):
    for seed in range(4):
        h = _run_pq(GPQ(8, 5, tag=f"gs_{policy}_{seed}"), policy, seed,
                    n_threads=4, ops=3)
        assert check_p_linearizable_search(h, k=0).ok
        assert check_p_linearizable(h, k=0).ok


# -- relaxed variant: quantitative bound --------------------------------------


@pytest.mark.parametrize("policy", SCHEDULES)
@pytest.mark.parametrize("lazy", [0, 3])
def test_relaxed_single_ring_exact_lazy_bound(policy, lazy):
    for seed in range(3):
        pq = RelaxedGPQ(64, 13, tag=f"r1_{lazy}_{policy}_{seed}", rings=1,
                        lazy=lazy)
        h = _run_pq(pq, policy, seed)
        assert pq.relaxation_bound() == lazy
        k = _min_passing_k(h)
        assert k <= lazy, f"observed rank error {k} exceeds exact bound {lazy}"


@pytest.mark.parametrize("policy", SCHEDULES)
@pytest.mark.parametrize("rings,lazy", [(3, 2), (4, 0)])
def test_relaxed_multi_ring_within_envelope(policy, rings, lazy):
    for seed in range(3):
        pq = RelaxedGPQ(64, 13, tag=f"rm_{rings}_{lazy}_{policy}_{seed}",
                        rings=rings, lazy=lazy)
        h = _run_pq(pq, policy, seed)
        res = check_p_linearizable(h, k=pq.relaxation_bound())
        assert res.ok, res.reason


def test_relaxed_multi_ring_actually_relaxes():
    """A deterministic multi-ring run whose history is NOT 0-relaxed —
    the relaxation is real, not a vacuous bound."""
    violated = False
    for seed in range(6):
        pq = RelaxedGPQ(64, 13, tag=f"rv_{seed}", rings=4, lazy=2)
        h = _run_pq(pq, "random", seed)
        if not check_p_linearizable(h, k=0).ok:
            violated = True
            break
    assert violated, "no k=0 violation in 6 seeded multi-ring runs"


# -- checker fixtures ---------------------------------------------------------


def _ev(proc, op, arg, ret, call, end):
    return HistoryEvent(proc=proc, op=op, arg=arg, ret=ret, call=call, end=end)


def test_checker_positive_fixtures():
    # sequential: ins(5), ins(3), delmin->3, delmin->5, delmin->EMPTY
    h = [
        _ev(0, INS, (5, 100), True, 1, 2),
        _ev(0, INS, (3, 101), True, 3, 4),
        _ev(0, DELMIN, None, (3, 101), 5, 6),
        _ev(0, DELMIN, None, (5, 100), 7, 8),
        _ev(0, DELMIN, None, None, 9, 10),
    ]
    assert check_p_linearizable(h, k=0).ok
    assert check_p_linearizable_search(h, k=0).ok
    # concurrent: delmin overlapping both inserts may take either element
    h = [
        _ev(0, INS, (5, 100), True, 1, 10),
        _ev(1, INS, (3, 101), True, 2, 9),
        _ev(2, DELMIN, None, (5, 100), 3, 8),
    ]
    assert check_p_linearizable(h, k=0).ok
    assert check_p_linearizable_search(h, k=0).ok
    # EMPTY before any insert completes
    h = [
        _ev(0, DELMIN, None, None, 1, 4),
        _ev(1, INS, (7, 100), True, 2, 6),
    ]
    assert check_p_linearizable(h, k=0).ok
    assert check_p_linearizable_search(h, k=0).ok


def test_checker_negative_fixtures():
    # Q3: delmin returns 9 while 3 is pending throughout — fails k=0,
    # passes k=1 (exactly one smaller pending key).
    h = [
        _ev(0, INS, (3, 100), True, 1, 2),
        _ev(0, INS, (9, 101), True, 3, 4),
        _ev(1, DELMIN, None, (9, 101), 5, 6),
    ]
    assert not check_p_linearizable(h, k=0).ok
    assert not check_p_linearizable_search(h, k=0).ok
    assert check_p_linearizable(h, k=1).ok
    assert check_p_linearizable_search(h, k=1).ok
    # Q4: EMPTY while an element is provably pending
    h = [
        _ev(0, INS, (3, 100), True, 1, 2),
        _ev(1, DELMIN, None, None, 3, 4),
    ]
    assert not check_p_linearizable(h, k=0).ok
    assert not check_p_linearizable_search(h, k=0).ok
    # Q1: dequeued twice
    h = [
        _ev(0, INS, (3, 100), True, 1, 2),
        _ev(0, DELMIN, None, (3, 100), 3, 4),
        _ev(1, DELMIN, None, (3, 100), 5, 6),
    ]
    assert not check_p_linearizable(h, k=0).ok
    # Q1: never inserted
    h = [_ev(0, DELMIN, None, (3, 100), 1, 2)]
    assert not check_p_linearizable(h, k=0).ok
    # Q2: delete returns before its insert begins
    h = [
        _ev(0, DELMIN, None, (3, 100), 1, 2),
        _ev(1, INS, (3, 100), True, 5, 6),
    ]
    assert not check_p_linearizable(h, k=0).ok


@pytest.mark.parametrize("policy", SCHEDULES)
def test_checker_cross_validation_per_schedule(policy):
    """Pattern checker and exact search agree on small machine-generated
    histories from each schedule, at k = 0 and k = 2."""
    for seed in range(5):
        pq = RelaxedGPQ(16, 5, tag=f"cv_{policy}_{seed}", rings=2, lazy=1)
        h = _run_pq(pq, policy, seed, n_threads=4, ops=3, key_range=10)
        for k in (0, 2, 8):
            pat = check_p_linearizable(h, k=k)
            exact = check_p_linearizable_search(h, k=k, max_nodes=400_000)
            if exact.ok:
                # pattern check is a necessary condition: must accept
                assert pat.ok, (seed, k, pat.reason)
            if not pat.ok:
                # pattern violations are sound: exact search must reject
                assert not exact.ok, (seed, k, pat.reason)


# -- Pallas heap kernel + priority rounds -------------------------------------


def test_heap_apply_matches_host_oracle():
    jnp = pytest.importorskip("jax.numpy")
    import heapq
    from repro.kernels.heap_batch import KEY_INF, heap_apply
    rng = random.Random(7)
    for arity_log2 in (1, 2):
        keys = jnp.full((64,), KEY_INF, jnp.int32)
        vals = jnp.full((64,), -1, jnp.int32)
        size = jnp.asarray(0, jnp.int32)
        oracle = []
        for _ in range(6):
            ops, ks, vs = [], [], []
            for _ in range(8):
                r = rng.random()
                if r < 0.55:
                    ops.append(0); ks.append(rng.randrange(100))
                    vs.append(rng.randrange(1000))
                elif r < 0.9:
                    ops.append(1); ks.append(KEY_INF); vs.append(-1)
                else:
                    ops.append(-1); ks.append(KEY_INF); vs.append(-1)
            keys, vals, size, outk, outv, ok = heap_apply(
                keys, vals, size, jnp.asarray(ops, jnp.int32),
                jnp.asarray(ks, jnp.int32), jnp.asarray(vs, jnp.int32),
                cap_log2=6, arity_log2=arity_log2)
            size = jnp.asarray(int(size), jnp.int32)
            for i, op in enumerate(ops):
                if op == 0:
                    assert bool(ok[i])
                    heapq.heappush(oracle, ks[i])
                elif op == 1 and oracle:
                    assert bool(ok[i])
                    assert int(outk[i]) == heapq.heappop(oracle)
                else:
                    assert not bool(ok[i])
            assert int(size) == len(oracle)


def test_priority_rounds_exactly_once_and_deterministic():
    jnp = pytest.importorskip("jax.numpy")
    from repro.runtime import PriorityRoundRunner

    def step(acc, keys, vals, valid):
        acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
        ck = jnp.stack([keys + 1, keys + 1], -1).astype(jnp.int32)
        cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
        cm = (valid & (vals < 8))[:, None]
        return acc, ck, cv, cm

    r1 = PriorityRoundRunner(step, capacity_log2=8, batch=16)
    acc1, st1 = r1.run([5], [1], acc=jnp.zeros(64, jnp.int32))
    counts = np.asarray(acc1)
    assert counts[1:16].tolist() == [1] * 15      # exactly once
    assert counts[0] == 0 and counts[16:].sum() == 0
    assert r1.stats["drained"] == 1 and r1.stats["processed"] == 15
    r2 = PriorityRoundRunner(step, capacity_log2=8, batch=16)
    acc2, st2 = r2.run([5], [1], acc=jnp.zeros(64, jnp.int32))
    np.testing.assert_array_equal(counts, np.asarray(acc2))
    np.testing.assert_array_equal(np.asarray(st1.keys), np.asarray(st2.keys))
    assert st1.size == st2.size and r1.stats == r2.stats


def test_priority_rounds_pop_in_key_order():
    jnp = pytest.importorskip("jax.numpy")
    from repro.runtime import PriorityRoundRunner

    def step(acc, keys, vals, valid):
        buf, n = acc
        pos = jnp.where(valid,
                        n + jnp.cumsum(valid.astype(jnp.int32)) - 1,
                        buf.shape[0] - 1)          # invalid lanes -> trash slot
        buf = buf.at[pos].set(jnp.where(valid, keys, buf[pos]))
        n = n + valid.sum(dtype=jnp.int32)
        z = jnp.zeros_like(keys)[:, None]
        return (buf, n), z, z, jnp.zeros_like(z, bool)

    runner = PriorityRoundRunner(step, capacity_log2=6, batch=8)
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 100, 24).astype(np.int32)
    (buf, n), _ = runner.run(keys, np.arange(24),
                             acc=(jnp.zeros(25, jnp.int32), jnp.int32(0)))
    assert int(n) == 24
    popped = np.asarray(buf)[:24]
    np.testing.assert_array_equal(popped, np.sort(keys))  # EDF order


# -- PriorityFabric -----------------------------------------------------------


def _tree_priority_runtime(policy, sched_policy, *, workers=8, shards=2,
                           depth=4, roots=2, seed=0):
    from repro.runtime import ExecutorConfig, PriorityFabric, TaskRuntime, TaskSpec

    def handler(rec):
        d = rec.payload
        if d <= 0:
            return []
        return [TaskSpec(d - 1, cost=1, priority=1),
                TaskSpec(d - 1, cost=1, priority=1)]

    fabric = PriorityFabric(policy=policy, shards=shards,
                            capacity_per_shard=128, num_threads=workers + 1)
    rt = TaskRuntime(fabric, handler,
                     ExecutorConfig(workers=workers, policy=sched_policy,
                                    seed=seed))
    for _ in range(roots):
        rt.add_task(depth, cost=1)
    metrics = rt.run()
    total = roots * (2 ** (depth + 1) - 1)
    return rt, fabric, metrics, total


@pytest.mark.parametrize("policy", ["strict", "weighted", "edf"])
@pytest.mark.parametrize("sched_policy", SCHEDULES)
def test_priority_fabric_exactly_once_and_p_linearizable(policy, sched_policy):
    rt, fabric, metrics, total = _tree_priority_runtime(policy, sched_policy,
                                                        seed=7)
    assert metrics["completed"] == 1.0, "runtime did not reach quiescence"
    ids = [t for t, _ in rt.executed]
    assert len(ids) == total and len(set(ids)) == len(ids)
    for shard, hist in fabric.shard_history.items():
        res = check_p_linearizable(hist, k=0)   # strict shards: k = 0
        assert res.ok, f"shard {shard}: {res.reason}"


def test_priority_fabric_steals_highest_priority_first():
    """Urgent work pinned to a non-home shard: a worker's acquire must
    take it (by hint order) before the normal work on its own home
    shard."""
    from repro.runtime import ExecutorConfig, PriorityFabric, TaskRuntime

    fabric = PriorityFabric(policy="strict", shards=2, capacity_per_shard=64,
                            num_threads=2)
    rt = TaskRuntime(fabric, lambda rec: [],
                     ExecutorConfig(workers=1, policy="rr", seed=0))
    # worker 1's home shard is 0 (wave 0): normal tasks there, urgent on 1
    rt.add_task(("warm",), priority=0, cost=800, affinity=0)
    for i in range(6):
        rt.add_task(("n", i), priority=1, cost=1, at_step=10, affinity=0)
    for i in range(6):
        rt.add_task(("u", i), priority=0, cost=1, at_step=10, affinity=1)
    m = rt.run()
    assert m["completed"] == 1.0
    order = [fabric.tasks[t].payload[0] for t, _ in rt.executed
             if fabric.tasks[t].payload[0] != "warm"]
    assert order[:6] == ["u"] * 6, order
    assert m["steals"] > 0


def test_register_rejects_out_of_range_priority():
    from repro.runtime import PriorityFabric, TaskFabric

    fabric = TaskFabric(algo="glfq", shards=1, lanes=2, num_threads=2)
    with pytest.raises(ValueError):
        fabric.register("x", priority=2)
    with pytest.raises(ValueError):
        fabric.register("x", priority=-1)
    fabric.register("x", priority=1)   # in range: fine
    pfabric = PriorityFabric(policy="edf", shards=1, num_threads=2)
    with pytest.raises(ValueError):
        pfabric.register("x", priority=5)


# -- starvation-freedom + bench acceptance ------------------------------------


def test_starvation_freedom_and_bench_acceptance():
    """Sustained urgent arrivals (the bench's powerlaw+bursty scenario):
    weighted and EDF complete every normal task within a bounded wait
    horizon; strict is *documented as starving* and asserted as such
    (normal waits past the horizon).  Simultaneously the bench acceptance:
    EDF/weighted throughput ≥ strict with strictly lower normal max
    wait."""
    from benchmarks.bench_runtime import run_priority_scenario

    horizon = 25_000
    res = {p: run_priority_scenario(p, bursts=12)
           for p in ("strict", "weighted", "edf")}
    for p, m in res.items():
        assert m["completed"] == 1.0, f"{p} did not quiesce"
        assert m["tasks"] == 64 + 12 * 8
    for p in ("weighted", "edf"):
        assert res[p]["normal_max_wait"] < horizon, \
            f"{p} normal wait {res[p]['normal_max_wait']} exceeds horizon"
    # strict starves: normal waits blow past the bounded horizon
    assert res["strict"]["normal_max_wait"] > horizon
    for p in ("weighted", "edf"):
        assert (res[p]["throughput_ops_per_kstep"]
                >= res["strict"]["throughput_ops_per_kstep"])
        assert res[p]["normal_max_wait"] < res["strict"]["normal_max_wait"]


# -- serving engine EDF admission --------------------------------------------


def _mini_engine(admission, normal_slack=8):
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving.engine import EngineConfig, ServingEngine
    cfg = get_config("h2o-danube-1.8b").reduced()
    eng = ServingEngine(cfg, init_params(cfg),
                        EngineConfig(max_slots=1, page_size=16, num_pages=8,
                                     max_seq=64, request_ring_capacity=64,
                                     admission=admission,
                                     normal_slack=normal_slack))
    return cfg, eng


def test_engine_edf_admission_ages_normal_requests():
    """A waiting normal request outranks urgent arrivals once its slack is
    consumed: with slack 8, the normal request admits ahead of the urgent
    tail — under strict lanes it would be dead last."""
    from repro.serving.engine import Request
    cfg, eng = _mini_engine("edf", normal_slack=8)
    rng = np.random.default_rng(0)

    def req(rid, pri):
        return Request(rid=rid,
                       prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                       max_new_tokens=1, priority=pri)

    assert eng.submit(req(500, 1))          # normal first: deadline 1+8
    for rid in range(16):
        assert eng.submit(req(rid, 0))      # urgent flood: deadlines 2..17
    m = eng.run(max_ticks=600)
    assert m["completed"] == 17
    pos = eng.admission_log.index(500)
    assert pos < 12, (pos, eng.admission_log)   # aged ahead of the tail
    assert pos >= 4, (pos, eng.admission_log)   # but urgent head went first


def test_engine_lanes_mode_still_strict():
    from repro.serving.engine import Request
    cfg, eng = _mini_engine("lanes")
    rng = np.random.default_rng(0)

    def req(rid, pri):
        return Request(rid=rid,
                       prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
                       max_new_tokens=1, priority=pri)

    assert eng.submit(req(500, 1))
    for rid in range(6):
        assert eng.submit(req(rid, 0))
    m = eng.run(max_ticks=400)
    assert m["completed"] == 7
    assert eng.admission_log[-1] == 500     # strict lanes: normal starved
