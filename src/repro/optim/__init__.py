"""Optimizer substrate: AdamW (mixed precision, ZeRO-sharded via param
specs), schedules, and gradient compression (distributed/compression)."""
from . import adamw
from .adamw import AdamWConfig, OptState, cast_params, global_norm

__all__ = ["adamw", "AdamWConfig", "OptState", "cast_params", "global_norm"]
