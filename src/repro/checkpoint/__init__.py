"""repro.checkpoint subpackage."""
