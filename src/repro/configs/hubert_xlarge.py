"""hubert-xlarge — 48L encoder-only transformer (w2v2 arch); framewise
frontend stubbed per assignment [arXiv:2106.07447; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504,
    causal=False, audio_frontend=True, fsdp=True,
    skip_shapes=("decode_32k", "long_500k"),
    skip_reason="encoder-only: no decode step (DESIGN §5)",
)
