"""Pallas TPU kernels for the queue framework's compute hot spots.

Each kernel has a pure-jnp oracle in ``ref.py``; ``ops.py`` exposes the
jit'd public API with a kernel/oracle switch.  Kernels run compiled on TPU
and in interpret mode on CPU (how the test suite validates them)."""

from . import ops, ref
from .compact import compact_planes, compact_width, wave_compact
from .frontier import frontier_expand
from .heap_batch import heap_apply
from .moe_route import expert_tickets, moe_route
from .pallas_env import ENV_VAR as PALLAS_INTERPRET_ENV, resolve_interpret
from .ring_slots import deq_planes, enq_planes, ring_dequeue, ring_enqueue
from .wavefaa import LANES, wavefaa

__all__ = ["ops", "ref", "wavefaa", "LANES", "ring_enqueue", "ring_dequeue",
           "enq_planes", "deq_planes", "frontier_expand", "expert_tickets",
           "heap_apply", "moe_route", "resolve_interpret",
           "PALLAS_INTERPRET_ENV", "wave_compact", "compact_planes",
           "compact_width"]
