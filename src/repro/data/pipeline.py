"""Queue-fed data pipeline: host-side producers feed device steps through a
G-LFQ-style bounded ring.

The producer/consumer decoupling is exactly the paper's use case: shard
readers (producers) enqueue ready batches; the training loop (consumer)
dequeues; the bounded ring provides backpressure (threshold-style full/empty
detection).  On the host the ring is a thread-safe Python port of the same
packed-state design, sized ``prefetch`` deep.

Synthetic data: deterministic per-(shard, step) token batches so restarts
resume mid-epoch bit-identically from (epoch, step) in the checkpoint.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.base import ArchConfig


@dataclass
class DataConfig:
    seq_len: int = 256
    global_batch: int = 8
    seed: int = 0
    prefetch: int = 4
    num_producer_threads: int = 2


class HostRing:
    """Bounded MPMC ring (host port of the G-LFQ discipline: tickets from a
    monotone counter, slots matched by cycle; mutex-per-op stands in for the
    64-bit atomics)."""

    def __init__(self, capacity: int) -> None:
        self.capacity = capacity
        self._slots = [None] * capacity
        self._cycle = [0] * capacity
        self._tail = 0
        self._head = 0
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self.closed = False

    def enqueue(self, item, timeout: Optional[float] = None) -> bool:
        with self._not_full:
            deadline = None if timeout is None else time.time() + timeout
            while self._tail - self._head >= self.capacity and not self.closed:
                remaining = None if deadline is None else deadline - time.time()
                if remaining is not None and remaining <= 0:
                    return False
                self._not_full.wait(remaining)
            if self.closed:
                return False
            t = self._tail
            self._tail += 1
            self._slots[t % self.capacity] = item
            self._cycle[t % self.capacity] = t // self.capacity + 1
            self._not_empty.notify()
            return True

    def dequeue(self, timeout: Optional[float] = None):
        with self._not_empty:
            deadline = None if timeout is None else time.time() + timeout
            while self._tail <= self._head and not self.closed:
                remaining = None if deadline is None else deadline - time.time()
                if remaining is not None and remaining <= 0:
                    return None
                self._not_empty.wait(remaining)
            if self._tail <= self._head:
                return None  # closed and drained
            h = self._head
            self._head += 1
            item = self._slots[h % self.capacity]
            self._slots[h % self.capacity] = None
            self._not_full.notify()
            return item

    def close(self) -> None:
        with self._lock:
            self.closed = True
            self._not_full.notify_all()
            self._not_empty.notify_all()

    def empty(self) -> bool:
        with self._lock:
            return self._tail <= self._head


def synth_batch(cfg: ArchConfig, dcfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Deterministic synthetic batch for (cfg, step)."""
    rng = np.random.default_rng((dcfg.seed << 20) ^ step)
    b, s = dcfg.global_batch, dcfg.seq_len
    out: Dict[str, np.ndarray] = {}
    if cfg.audio_frontend:
        out["frames"] = rng.standard_normal((b, s, cfg.d_model)).astype(np.float32)
    else:
        out["tokens"] = rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)
    out["labels"] = rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)
    if cfg.family == "vlm":
        out["img"] = rng.standard_normal(
            (b, cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    return out


class DataPipeline:
    """Producer threads → HostRing → iterator of ready batches."""

    def __init__(self, cfg: ArchConfig, dcfg: DataConfig,
                 num_steps: int) -> None:
        self.cfg, self.dcfg = cfg, dcfg
        self.num_steps = num_steps
        self.ring = HostRing(dcfg.prefetch)
        self._threads = []
        self._next = 0
        self._produced = threading.Lock()

    def _producer(self, worker: int) -> None:
        while True:
            with self._produced:
                step = self._next
                if step >= self.num_steps:
                    break
                self._next += 1
            batch = synth_batch(self.cfg, self.dcfg, step)
            if not self.ring.enqueue((step, batch)):
                break
        # last worker out closes the ring
        if all(not t.is_alive() or t is threading.current_thread()
               for t in self._threads):
            self.ring.close()

    def start(self) -> "DataPipeline":
        for i in range(self.dcfg.num_producer_threads):
            t = threading.Thread(target=self._producer, args=(i,), daemon=True)
            self._threads.append(t)
            t.start()
        return self

    def __iter__(self) -> Iterator:
        got = 0
        pending = {}
        expect = 0
        while got < self.num_steps:
            item = self.ring.dequeue(timeout=30.0)
            if item is None:
                break
            step, batch = item
            pending[step] = batch
            # deliver in order (producers may race)
            while expect in pending:
                yield expect, pending.pop(expect)
                expect += 1
                got += 1
        self.ring.close()
