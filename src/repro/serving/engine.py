"""Continuous-batching serving engine built on the paper's bounded rings.

Two queue roles (DESIGN.md § 3):

* **request queue** — incoming generation requests land in a deadline-keyed
  ``HostPriorityPool`` (EDF admission, DESIGN.md § 5.5): a request's key is
  its admission sequence number plus a per-class slack (urgent = 0), so
  urgent requests pre-empt and waiting or page-stalled requests *age toward
  urgency* — a stalled normal request keeps its original deadline while new
  arrivals take later ones, so it drifts to the front instead of re-queuing
  at fixed rank.  ``admission="lanes"`` keeps the legacy strict two-lane
  ``HostTaskPool`` (urgent lane drained first, stalled requests parked
  engine-side), which starves normal traffic under sustained urgent load.
* **KV page allocator** — the KV cache is paged; free page indices live in a
  bounded ring and are claimed by *ticket reservation* exactly like the
  paper's index indirection (enqueue of a released page, dequeue of a free
  one).  Near-empty = memory pressure, the split-benchmark regime where
  G-WFQ's graceful degradation matters.

The decode loop itself is a jitted serve_step over a fixed slot batch; this
module owns admission, page accounting, completion, and metrics.

Simplification (documented): all slots advance on one shared timeline (a
single ``cur`` index) — a late-admitted slot's earlier cache positions hold
zero K/V, which its queries may attend to.  Scheduling/queueing semantics
(what the tests assert) are exact; the production path would carry per-slot
position vectors.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..data.pipeline import HostRing
from ..models import decode_step, init_decode_cache
from ..obs.metrics import MetricsRegistry, metric_key
from ..runtime import HostTaskPool
from ..sched import HostPriorityPool
from ..sched.policy import make_policy
from .admission import DEADLINE_KEY_CAP, ServingMeshEngine


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int32
    max_new_tokens: int
    priority: int = 1            # 0 = urgent admission class
    deadline: Optional[int] = None   # EDF key; assigned at submit if unset
    tenant: int = 0              # policy lane (EngineConfig.tenant_policies)
    out: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    pages: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    submit_tick: int = -1        # engine tick at submit; -1 = pre-engine
    admit_tick: int = -1         # engine tick at slot admission
    finish_tick: int = -1        # engine tick at completion


@dataclasses.dataclass
class EngineConfig:
    max_slots: int = 4           # concurrent decode slots
    page_size: int = 64          # tokens per KV page
    num_pages: int = 64          # total page budget
    max_seq: int = 256
    request_ring_capacity: int = 16
    request_shards: int = 2      # HostTaskPool shards per lane (lanes mode)
    admission: str = "edf"       # "edf" | "lanes" (legacy) | "device" (mesh)
    normal_slack: int = 64       # EDF slack for non-urgent admission classes
    # multi-tenant policy lanes: one sched.policy spec per tenant
    # ("strict" | "weighted" | "edf" | a PriorityPolicy); None keeps the
    # single-lane inline EDF stamping (bit-compatible with the pre-tenant
    # engine — the policy object path quantizes through make_policy)
    tenants: int = 1
    tenant_policies: Optional[tuple] = None
    # device admission (ServingMeshEngine) sizing
    device_capacity_log2: int = 8
    device_batch: int = 8
    device_table_log2: int = 8
    device_shards: int = 1


class ServingEngine:
    """Synchronous continuous batching over the reduced configs (CPU) —
    structure identical to the production path."""

    def __init__(self, cfg: ArchConfig, params, ecfg: EngineConfig,
                 registry: Optional[MetricsRegistry] = None) -> None:
        self.cfg, self.params, self.ecfg = cfg, params, ecfg
        self.registry = registry
        self._device = None
        if ecfg.admission == "edf":
            self.requests = HostPriorityPool(ecfg.request_ring_capacity)
        elif ecfg.admission == "lanes":
            self.requests = HostTaskPool(ecfg.request_ring_capacity,
                                         shards=ecfg.request_shards, lanes=2)
        elif ecfg.admission == "device":
            # device-resident EDF: pending requests live as (deadline |
            # idx) heap entries on the priority mesh; one engine tick is
            # one admission megaround (DESIGN.md § 5.5)
            self.requests = None
            if ecfg.device_shards > len(jax.devices()):
                raise ValueError(
                    f"device_shards={ecfg.device_shards} exceeds the "
                    f"{len(jax.devices())} visible devices")
            from jax.sharding import Mesh
            mesh = Mesh(np.array(jax.devices()[:ecfg.device_shards]),
                        ("data",))
            self._device = ServingMeshEngine(
                mesh=mesh, capacity_log2=ecfg.device_capacity_log2,
                batch=ecfg.device_batch,
                table_log2=ecfg.device_table_log2)
            self._table: List[Optional[Request]] = \
                [None] * (1 << ecfg.device_table_log2)
            self._free_idx = list(range(1 << ecfg.device_table_log2))
            self._pending: List[tuple] = []    # (key, idx, need) per submit
            self._dev_spawned = 0              # stall-tick detection baseline
        else:
            raise ValueError(f"unknown admission mode {ecfg.admission!r}")
        self._policies = None
        if ecfg.tenant_policies is not None:
            if len(ecfg.tenant_policies) != ecfg.tenants:
                raise ValueError(
                    f"{len(ecfg.tenant_policies)} tenant_policies for "
                    f"{ecfg.tenants} tenants")
            self._policies = [make_policy(p) for p in ecfg.tenant_policies]
        self._seq = 0                      # admission sequence (EDF now-clock)
        self._seq_lock = threading.Lock()  # submit() is client-thread-callable
        self.stalled: List[Request] = []   # page-stalled, awaiting re-admission
        self.admission_log: List[int] = []
        # free-page ring (index indirection: pages move as indices)
        self.free_pages = HostRing(ecfg.num_pages)
        for p in range(ecfg.num_pages):
            assert self.free_pages.enqueue(p, timeout=0.1)
        self.slots: List[Optional[Request]] = [None] * ecfg.max_slots
        self.cache = init_decode_cache(cfg, ecfg.max_slots, ecfg.max_seq)
        self.cur = np.zeros(ecfg.max_slots, np.int32)
        self.tokens = np.zeros((ecfg.max_slots, 1), np.int32)
        self.metrics = {"admitted": 0, "completed": 0, "decode_steps": 0,
                        "page_stalls": 0, "tokens_out": 0}
        self.tick = 0                      # engine ticks; the wait clock
        self._step = jax.jit(
            lambda p, c, t, cur: decode_step(p, c, t, cur, cfg))

    def _count(self, name: str, delta: int = 1) -> None:
        """Bump a metric in the legacy dict and, when a registry is wired,
        mirror it as a ``serving.*`` counter (stable key scheme,
        DESIGN.md § 7.2) — both surfaces always agree."""
        self.metrics[name] += delta
        if self.registry is not None:
            self.registry.counter(metric_key("serving", name), delta)

    # -- client API ------------------------------------------------------------

    def submit(self, req: Request, timeout: float = 1.0) -> bool:
        if req.submit_tick < 0:
            req.submit_tick = self.tick    # racy int read is fine: ±1 tick
        if self.ecfg.admission == "lanes":
            return self.requests.enqueue(req, timeout=timeout,
                                         priority=req.priority)
        if not 0 <= req.tenant < self.ecfg.tenants:
            raise ValueError(f"tenant {req.tenant} out of range "
                             f"[0, {self.ecfg.tenants})")
        with self._seq_lock:
            self._seq += 1
            seq = self._seq
            if self._policies is not None:
                # tenant lane: the lane's policy maps (class, deadline,
                # now) to the EDF key; policy clocks are per tenant, so
                # lanes interleave by key, not by arrival
                req.deadline = self._policies[req.tenant].key(
                    req.priority, req.deadline, seq)
            elif req.deadline is None:
                slack = 0 if req.priority == 0 else self.ecfg.normal_slack
                req.deadline = seq + slack
        if not 0 <= req.deadline < DEADLINE_KEY_CAP:
            # stamp-time cap (PR 9 contract): a wrapped deadline key would
            # silently invert EDF order in the heap planes
            raise ValueError(
                f"deadline {req.deadline} outside [0, {DEADLINE_KEY_CAP}): "
                f"keys past the 2^30 round-clock cap would wrap — rebase "
                f"the deadline clock")
        if self.ecfg.admission == "device":
            with self._seq_lock:
                if not self._free_idx:
                    return False           # table full = pool full
                idx = self._free_idx.pop()
                self._table[idx] = req
                need = self._pages_needed(
                    len(req.prompt) + req.max_new_tokens)
                self._pending.append((req.deadline, idx, need))
            return True
        return self.requests.enqueue(req, key=req.deadline, timeout=timeout)

    # -- scheduler -------------------------------------------------------------

    def _pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.ecfg.page_size)

    def _next_candidate(self) -> Optional[Request]:
        if self.ecfg.admission != "edf":
            # lanes mode: engine-side stalled requests retry first (fixed
            # rank — the § 5.5 inversion baseline)
            return (self.stalled.pop(0) if self.stalled
                    else self.requests.dequeue(timeout=0.0))
        # EDF: self.stalled only holds pool-full overflow; merge it back
        # by deadline so it cannot jump requests with earlier deadlines
        if self.stalled:
            self.stalled.sort(key=lambda r: r.deadline)
            pk = self.requests.peek_key()
            if pk is None or self.stalled[0].deadline <= pk:
                return self.stalled.pop(0)
        req = self.requests.dequeue(timeout=0.0)
        if req is None and self.stalled:
            return self.stalled.pop(0)
        return req

    def _install(self, req: Request, s: int, pages: List[int]) -> None:
        """Shared slot-install bookkeeping: metrics, wait histogram,
        tenant counter, prefill."""
        req.slot, req.pages = s, pages
        req.admit_tick = self.tick
        self.slots[s] = req
        self.admission_log.append(req.rid)
        self._count("admitted")
        if self.registry is not None and self.ecfg.tenants > 1:
            self.registry.counter(
                metric_key("serving", "admitted", tenant=req.tenant))
        if self.registry is not None and req.submit_tick >= 0:
            # request-level sojourn: ticks from submit to admission,
            # per admission class — the serving-layer twin of the
            # engines' device span histograms (DESIGN.md § 7.6)
            self.registry.observe(
                metric_key("serving", "wait", cls=req.priority),
                self.tick - req.submit_tick)
        # prefill (token-by-token through decode_step for simplicity;
        # slot-local so other slots keep decoding)
        self.cur[s] = 0
        for tok in req.prompt:
            self.tokens[s, 0] = tok
            self._decode_once(active_slot=s)

    def _try_admit_device(self) -> None:
        """One admission megaround on the priority mesh: install the
        buffered arrivals as (deadline | idx·retry) heap entries, give
        the tick the free slot/page budgets, admit the EDF prefix the
        device returns.  Page-stalled requests stay heap-resident at
        their original deadline (the § 5.5 aging guarantee)."""
        free_slots = [s for s in range(self.ecfg.max_slots)
                      if self.slots[s] is None]
        if not free_slots:
            return
        if not self._pending and self._device.occupancy() == 0:
            return
        held = sum(len(r.pages) for r in self.slots if r is not None)
        with self._seq_lock:
            pending, self._pending = self._pending, []
        admitted = self._device.tick(
            [k for k, _, _ in pending], [i for _, i, _ in pending],
            slots=len(free_slots), pages=self.ecfg.num_pages - held,
            need=[n for _, _, n in pending])
        spawned = self._device.stats["spawned"]
        if spawned > self._dev_spawned:
            # ≥1 request republished = this tick hit its budget wall
            # (one stall event per stalled tick, like the host path's
            # one stall per _try_admit call)
            self._count("page_stalls")
        self._dev_spawned = spawned
        for idx in admitted:
            req = self._table[idx]
            self._table[idx] = None
            self._free_idx.append(idx)
            need = self._pages_needed(len(req.prompt) + req.max_new_tokens)
            pages = []
            for _ in range(need):
                p = self.free_pages.dequeue(timeout=0.0)
                assert p is not None, "device admission fits the page budget"
                pages.append(p)
            self._install(req, free_slots.pop(0), pages)

    def _try_admit(self) -> None:
        if self.ecfg.admission == "device":
            self._try_admit_device()
            return
        for s in range(self.ecfg.max_slots):
            if self.slots[s] is not None:
                continue
            req = self._next_candidate()
            if req is None:
                return
            need = self._pages_needed(len(req.prompt) + req.max_new_tokens)
            pages = []
            for _ in range(need):
                p = self.free_pages.dequeue(timeout=0.0)
                if p is None:
                    break
                pages.append(p)
            if len(pages) < need:
                # not enough pages: release and requeue (RETRY path)
                for p in pages:
                    self.free_pages.enqueue(p, timeout=0.1)
                self._count("page_stalls")
                if self.ecfg.admission == "edf":
                    # re-enter the pool at the *original* deadline: newer
                    # arrivals take later keys, so the stalled request ages
                    # toward urgency instead of re-queuing at fixed rank.
                    # Non-blocking: this thread is the pool's only
                    # consumer, so waiting on a full pool would deadlock
                    # the decode loop for the whole timeout
                    if not self.requests.enqueue(req, key=req.deadline,
                                                 timeout=0.0):
                        self.stalled.append(req)   # pool full: never drop
                else:
                    # lanes mode: park engine-side, retried ahead of the
                    # pool next tick (fixed priority — the starvation the
                    # EDF path removes)
                    self.stalled.append(req)
                return
            self._install(req, s, pages)

    def _decode_once(self, active_slot: Optional[int] = None) -> np.ndarray:
        tok = jnp.asarray(self.tokens)
        # all slots share one jitted step; cur is per-slot — use max and mask
        cur = jnp.int32(int(self.cur.max()))
        logits, new_cache = self._step(self.params, self.cache, tok, cur)
        self.cache = new_cache
        self._count("decode_steps")
        if active_slot is not None:
            self.cur[active_slot] += 1
        else:
            for s, r in enumerate(self.slots):
                if r is not None:
                    self.cur[s] += 1
        return np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))

    def wait_percentiles(self) -> Dict[int, Dict[str, Optional[float]]]:
        """Per-class request wait percentiles ``{cls: {p50, p99, max,
        count}}`` read back from the registry's ``serving.wait[cls=...]``
        histograms (empty without a registry)."""
        out: Dict[int, Dict[str, Optional[float]]] = {}
        if self.registry is None:
            return out
        for key in self.registry.keys():
            if not key.startswith("serving.wait["):
                continue
            h = self.registry.get(key)
            cls = int(key[key.index("cls=") + 4:-1])
            out[cls] = {"p50": h.quantile(0.50), "p99": h.quantile(0.99),
                        "max": h.max, "count": h.count}
        return out

    def step(self) -> None:
        """One engine tick: admit, decode, complete."""
        self.tick += 1
        self._try_admit()
        if self.registry is not None:
            # pressure gauges: free-page ring occupancy (near-empty = the
            # split-benchmark memory-pressure regime) and busy decode slots
            self.registry.gauge(metric_key("serving", "free_pages"),
                                self.ecfg.num_pages
                                - sum(len(r.pages) for r in self.slots
                                      if r is not None))
            self.registry.gauge(metric_key("serving", "active_slots"),
                                sum(r is not None for r in self.slots))
        if not any(self.slots):
            return
        nxt = self._decode_once()
        for s, req in enumerate(self.slots):
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            self._count("tokens_out")
            if len(req.out) >= req.max_new_tokens:
                req.done = True
                req.finish_tick = self.tick
                for p in req.pages:          # release pages (enqueue indices)
                    self.free_pages.enqueue(p, timeout=0.1)
                self.slots[s] = None
                self._count("completed")

    def _queue_empty(self) -> bool:
        if self.ecfg.admission == "device":
            return not self._pending and self._device.occupancy() == 0
        return self.requests.empty()

    def run(self, max_ticks: int = 1000) -> Dict[str, int]:
        for _ in range(max_ticks):
            self.step()
            if (not any(self.slots) and not self.stalled
                    and self._queue_empty()):
                break
        return dict(self.metrics)
