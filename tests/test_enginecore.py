"""EngineCore refactor invariants (DESIGN.md § 4.8).

The four fused engines are now thin configurations over one while_loop
builder and one plane registry.  This suite pins the refactor to the
pre-refactor engines with golden digests captured from the last commit
before the unification:

* every engine is bit-identical to its pre-refactor twin on fixed-seed
  runs — stats counters, acc leaves, queue planes, drained trace and
  span banks (1-shard in-process, 2-shard in a forced-device
  subprocess);
* the sharded FIFO mesh ring is *exact* against the replicated baseline
  (combined acc + processed/spawned totals; per-shard plane layout
  legitimately differs under fullest-first claim order) while its
  per-shard loop carry shrinks O(ring/shards);
* the packed ``(birth << 1) | 1`` span stamp cap is enforced at stamp
  time — concrete rounds raise ``ValueError`` in ``enq_planes``, traced
  rounds raise ``RuntimeError`` from the driver clamp;
* the deprecated ``Fused*`` entry points warn and still run.
"""

import hashlib
import json
import os
import subprocess
import sys

import numpy as np
import pytest

sys.path.insert(
    0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.jaxcompat import make_mesh  # noqa: E402
from repro.kernels.ring_slots import SPAN_ROUND_CAP, enq_planes  # noqa: E402
from repro.obs import Spans, Telemetry  # noqa: E402
from repro.runtime import (  # noqa: E402
    ENGINE_REGISTRY, FusedMeshRounds, FusedPriorityMeshRounds,
    FusedPriorityRounds, FusedRounds, MeshRoundRunner, PlaneRegistry,
    PriorityMeshRoundRunner, PriorityRoundRunner, RoundRunner)
from repro.runtime.fusedrounds import IDX_BOT  # noqa: E402

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

STATS = ("rounds", "processed", "spawned", "max_occupancy", "drained",
         "host_syncs")

# digests of the pre-refactor engines on the fixed workloads below
# (sha256[:16] over raw int32 bytes; see _digest/_tel_digest)
GOLDEN = {
    "fifo_fanout": {
        "stats": [7, 63, 62, 32, 1, 1], "acc": "b8d77df0675e0603",
        "planes": "1a0afe86d6513a2a", "head_tail": [575, 575],
        "tel": "cb3aae309ae1f69f", "spans": "b5f891af2ff7334a"},
    "heap_sssp": {
        "stats": [10, 124, 122, 46, 1, 1], "acc": "17210d10068cbe8b",
        "planes": "3e13f886f2e96c70", "size": 0,
        "tel": "ef6805304552b52a", "spans": "bbf1586fce097a87"},
    "mesh_fanout": {
        "stats": [7, 63, 62, 32, 1, 1], "acc": "b8d77df0675e0603",
        "planes": "1a0afe86d6513a2a", "head_tail": [575, 575],
        "tel": "cb3aae309ae1f69f"},
    "mesh_bfs": {"stats": [23, 144, 143, 12, 1, 1],
                 "dist": "c8795c4f65942e14"},
    "pmesh_relaxed": {
        "stats": [19, 260, 258, 128, 1, 1], "acc": "cd729cf83f33eed5",
        "planes": "c5830eb454bd1761", "tel": "c24a2c5171ec130e"},
    "pmesh_strict": {
        "stats": [19, 260, 258, 128, 1, 1], "acc": "cd729cf83f33eed5",
        "planes": "c5830eb454bd1761", "tel": "c24a2c5171ec130e"},
    # the serving admission tick (PR 10): page-constrained EDF admission
    # over 4 ticks; admitted order is exact EDF at one shard
    "serving": {
        "stats": [4, 20, 12, 6, 1, 4], "ticks": 4,
        "admitted": [1, 3, 7, 2, 6, 5, 4, 0],
        "planes": "d70650fb443f714a", "hist": "256ab85ea28951cc",
        "tel": "55a5a0cd9cee8fb0"},
}

GOLDEN_2SHARD = {
    "mesh_fanout_2": {
        "stats": [6, 63, 62, 32, 1, 1], "acc": "b8d77df0675e0603",
        "planes": "1a0afe86d6513a2a", "head_tail": [575, 575],
        "tel": "01bcb5be848e8028"},
    "mesh_bfs_2": {"stats": [23, 287, 286, 24, 1, 1],
                   "dist": "c8795c4f65942e14"},
    "pmesh_relaxed_2": {
        "stats": [12, 260, 258, 88, 1, 1], "acc": "cd729cf83f33eed5",
        "planes": "c822643452639513", "tel": "bd8f8645639ba8bc"},
    "pmesh_strict_2": {
        "stats": [12, 260, 258, 110, 1, 1], "acc": "cd729cf83f33eed5",
        "planes": "c5830eb454bd1761", "tel": "2455cb0b0971fae9"},
    # 2-shard serving: same admitted SET as 1-shard (order legitimately
    # relaxes within the mesh envelope), same conservation totals
    "serving_2": {
        "stats": [4, 20, 12, 6, 1, 4], "ticks": 4,
        "admitted": [2, 1, 7, 3, 6, 4, 5, 0],
        "planes": "6ddad96eb514c320", "hist": "385db6ed17cface3",
        "tel": "12c1f9a6ce0747a2"},
}


def _digest(*arrays):
    h = hashlib.sha256()
    for a in arrays:
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()[:16]


def _tel_digest(tel):
    rows = []
    for r in tel.records:
        rows.append((r.round, r.imbalance, r.min_key, r.max_key,
                     int(r.overflow), tuple(r.pops), tuple(r.pushes),
                     tuple(r.occupancy)))
    return hashlib.sha256(repr(rows).encode()).hexdigest()[:16]


def _stat_tuple(st):
    return [int(st[k]) for k in STATS]


def _tree_step(acc, vals, valid):
    acc = acc.at[jnp.where(valid, vals, 0)].add(valid.astype(jnp.int32))
    cv = jnp.stack([vals * 2, vals * 2 + 1], -1).astype(jnp.int32)
    cm = (valid & (vals < 32))[:, None]
    return acc, cv, cm


def _pri_step(acc, keys, vals, valid):
    acc = acc.at[jnp.where(valid, vals % 97, 0)].add(valid.astype(jnp.int32))
    ck = jnp.stack([keys + 3, keys + 7], -1).astype(jnp.int32)
    cv = jnp.stack([vals * 2 + 1, vals * 2 + 2], -1).astype(jnp.int32)
    cm = (valid & (keys < 24))[:, None]
    return acc, ck, cv, cm


def _pri_mesh_step(acc, keys, vals, valid):
    acc = acc.at[jnp.where(valid, vals % 89, 0)].add(valid.astype(jnp.int32))
    ck = jnp.stack([keys + 2, keys + 5], -1).astype(jnp.int32)
    cv = jnp.stack([(vals * 7919) % 1000, (vals * 104729) % 1000],
                   -1).astype(jnp.int32)
    cm = (valid & (keys < 20))[:, None]
    return acc, ck, cv, cm


def _mesh1():
    return make_mesh((1,), ("data",))


def _serving_scenario(mesh):
    """Fixed serving-admission scenario for the golden rows: 8 requests,
    page-constrained ticks so the stall/re-entry path engages, drained
    over however many ticks it takes.  Returns the digest dict."""
    from repro.serving.admission import ServingMeshEngine
    tel = Telemetry(capacity=256)
    e = ServingMeshEngine(mesh=mesh, capacity_log2=6, batch=8,
                          table_log2=6, pop_log=128, telemetry=tel)
    e.begin()
    admitted = list(e.tick([60, 10, 30, 20, 50, 40, 35, 25],
                           [0, 1, 2, 3, 4, 5, 6, 7],
                           slots=4, pages=5, need=[2] * 8))
    ticks = 1
    while e.occupancy() > 0 and ticks < 12:
        admitted += e.tick([], [], slots=4, pages=4)
        ticks += 1
    assert e.occupancy() == 0, "scenario must drain"
    hist = e.pop_history()
    return {"stats": _stat_tuple(e.stats), "ticks": ticks,
            "admitted": admitted,
            "planes": _digest(e._state[0][0], e._state[0][1]),
            "hist": _digest(np.asarray(hist, np.int32)),
            "tel": _tel_digest(tel)}


# -- bit-identity vs the pre-refactor engines ---------------------------------


def test_chip_fifo_matches_prerefactor_golden():
    g = GOLDEN["fifo_fanout"]
    tel, sp = Telemetry(capacity=256), Spans(classes=1, buckets=8)
    r = RoundRunner(_tree_step, capacity_log2=8, batch=16, telemetry=tel,
                    spans=sp)
    acc, st = r.run([1], acc=jnp.zeros(80, jnp.int32))
    assert _stat_tuple(r.stats) == g["stats"]
    assert _digest(acc) == g["acc"]
    assert _digest(*st[:4]) == g["planes"]
    assert [int(st.head), int(st.tail)] == g["head_tail"]
    assert _tel_digest(tel) == g["tel"]
    assert _digest(sp.hist, sp.max_wait) == g["spans"]
    # plain run (no obs planes in the carry): same digests
    r2 = RoundRunner(_tree_step, capacity_log2=8, batch=16)
    acc2, st2 = r2.run([1], acc=jnp.zeros(80, jnp.int32))
    assert _stat_tuple(r2.stats) == g["stats"]
    assert _digest(acc2) == g["acc"] and _digest(*st2[:4]) == g["planes"]


def test_chip_heap_matches_prerefactor_golden():
    g = GOLDEN["heap_sssp"]
    tel, sp = Telemetry(capacity=256), Spans(classes=1, buckets=8)
    r = PriorityRoundRunner(_pri_step, capacity_log2=9, batch=16,
                            telemetry=tel, spans=sp)
    acc, st = r.run([5, 1], [1, 2], acc=jnp.zeros(97, jnp.int32))
    assert _stat_tuple(r.stats) == g["stats"]
    assert _digest(acc) == g["acc"]
    assert _digest(st.keys, st.vals) == g["planes"]
    assert int(st.size) == g["size"]
    assert _tel_digest(tel) == g["tel"]
    assert _digest(sp.hist, sp.max_wait) == g["spans"]


def test_mesh_engines_match_prerefactor_goldens_1shard():
    mesh = _mesh1()
    g = GOLDEN["mesh_fanout"]
    tel = Telemetry(capacity=256)
    r = MeshRoundRunner(_tree_step, mesh=mesh, capacity_log2=8, batch=16,
                        combine=lambda a: a.sum(0), telemetry=tel)
    acc, st = r.run([1], acc=jnp.zeros(80, jnp.int32))
    assert _stat_tuple(r.stats) == g["stats"]
    assert _digest(acc) == g["acc"] and _digest(*st[:4]) == g["planes"]
    assert [int(np.asarray(st.head)),
            int(np.asarray(st.tail))] == g["head_tail"]
    assert _tel_digest(tel) == g["tel"]

    for relaxed, key in ((True, "pmesh_relaxed"), (False, "pmesh_strict")):
        g = GOLDEN[key]
        tel = Telemetry(capacity=512)
        r = PriorityMeshRoundRunner(_pri_mesh_step, mesh=mesh,
                                    capacity_log2=10, batch=16,
                                    relaxed=relaxed,
                                    combine=lambda a: a.sum(0),
                                    telemetry=tel)
        acc, st = r.run([3, 1], [7, 11], acc=jnp.zeros(89, jnp.int32))
        assert _stat_tuple(r.stats) == g["stats"], key
        assert _digest(acc) == g["acc"], key
        assert _digest(st.keys, st.vals) == g["planes"], key
        assert _tel_digest(tel) == g["tel"], key

    from repro.apps import bfs
    g = GOLDEN["mesh_bfs"]
    graph = bfs.road_like(144)
    dist, stats = bfs.bfs_mesh_rounds(graph, 0, mesh=mesh, batch=32)
    assert _stat_tuple(stats) == g["stats"]
    assert _digest(dist) == g["dist"]
    assert np.array_equal(dist, bfs.bfs_reference(graph, 0))


def test_serving_admission_matches_golden_1shard():
    g = GOLDEN["serving"]
    got = _serving_scenario(_mesh1())
    assert got == g
    # the 1-shard admitted order is the exact EDF order of the scenario's
    # deadline keys — pin the semantic, not just the digest
    assert got["admitted"] == [1, 3, 7, 2, 6, 5, 4, 0]


def _forced_device_env(n: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH"), REPO)
        if p)
    return env


def test_mesh_engines_match_prerefactor_goldens_2shard():
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--golden2"],
        capture_output=True, text=True, cwd=REPO,
        env=_forced_device_env(2), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got == GOLDEN_2SHARD


def _golden2_worker():
    """Re-derive the 2-shard goldens in a forced-device subprocess."""
    mesh = make_mesh((2,), ("data",))
    out = {}
    tel = Telemetry(capacity=256)
    r = MeshRoundRunner(_tree_step, mesh=mesh, capacity_log2=8, batch=16,
                        combine=lambda a: a.sum(0), telemetry=tel)
    acc, st = r.run([1], acc=jnp.zeros(80, jnp.int32))
    out["mesh_fanout_2"] = {
        "stats": _stat_tuple(r.stats), "acc": _digest(acc),
        "planes": _digest(*st[:4]),
        "head_tail": [int(np.asarray(st.head)), int(np.asarray(st.tail))],
        "tel": _tel_digest(tel)}
    from repro.apps import bfs
    g = bfs.road_like(144)
    dist, stats = bfs.bfs_mesh_rounds(g, 0, mesh=mesh, batch=32)
    out["mesh_bfs_2"] = {"stats": _stat_tuple(stats),
                         "dist": _digest(dist)}
    for relaxed in (True, False):
        tel = Telemetry(capacity=512)
        r = PriorityMeshRoundRunner(_pri_mesh_step, mesh=mesh,
                                    capacity_log2=10, batch=16,
                                    relaxed=relaxed,
                                    combine=lambda a: a.sum(0),
                                    telemetry=tel)
        acc, st = r.run([3, 1], [7, 11], acc=jnp.zeros(89, jnp.int32))
        out["pmesh_%s_2" % ("relaxed" if relaxed else "strict")] = {
            "stats": _stat_tuple(r.stats), "acc": _digest(acc),
            "planes": _digest(st.keys, st.vals), "tel": _tel_digest(tel)}
    out["serving_2"] = _serving_scenario(mesh)
    print(json.dumps(out))


# -- sharded FIFO mesh ring: exactness + O(ring/shards) carry -----------------


def test_sharded_ring_exact_and_carry_shrinks_1_2_4_shards():
    """Per-shard ring planes: combined results exact vs the replicated
    baseline at 1/2/4 shards, per-shard loop-carry bytes strictly
    shrinking as shards double (the replicated engine stays O(ring))."""
    carries = {}
    for n in (1, 2, 4):
        out = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--sharded-worker"],
            capture_output=True, text=True, cwd=REPO,
            env=_forced_device_env(n), timeout=900)
        assert out.returncode == 0, (n, out.stderr[-3000:])
        got = json.loads(out.stdout.strip().splitlines()[-1])
        assert got["acc_repl"] == got["acc_sharded"], n
        assert got["totals_repl"] == got["totals_sharded"], n
        assert got["carry_repl"] == carries.get("repl",
                                                got["carry_repl"])
        carries["repl"] = got["carry_repl"]
        carries[n] = got["carry_sharded"]
    assert carries[2] < carries[1] and carries[4] < carries[2]
    assert carries["repl"] == carries[1]


def _sharded_worker():
    mesh = make_mesh((len(jax.devices()),), ("data",))
    out = {}
    for sharded in (False, True):
        r = MeshRoundRunner(_tree_step, mesh=mesh, capacity_log2=8,
                            batch=16, sharded=sharded,
                            combine=lambda a: a.sum(0))
        acc, q = r.run([1], acc=jnp.zeros(80, jnp.int32), max_rounds=200)
        tag = "sharded" if sharded else "repl"
        out["acc_" + tag] = np.asarray(acc).tolist()
        out["totals_" + tag] = [int(r.stats["processed"]),
                                int(r.stats["spawned"])]
        out["carry_" + tag] = r.loop_carry_bytes()
    print(json.dumps(out))


def test_sharded_ring_rejects_spans():
    with pytest.raises(ValueError, match="replicated mesh engine"):
        MeshRoundRunner(_tree_step, mesh=_mesh1(), capacity_log2=8,
                        batch=16, sharded=True,
                        spans=Spans(classes=1, buckets=8))


def test_sharded_ring_requires_fused():
    with pytest.raises(ValueError, match="fused=True"):
        MeshRoundRunner(_tree_step, mesh=_mesh1(), capacity_log2=8,
                        batch=16, sharded=True, fused=False)


# -- plane registry accounting ------------------------------------------------


def test_plane_registry_bytes_per_shard():
    reg = PlaneRegistry()
    reg.register("ring", (jax.ShapeDtypeStruct((1024,), jnp.int32),) * 4,
                 sharded=True)
    reg.register("tickets", (jax.ShapeDtypeStruct((4,), jnp.int32),) * 2)
    full = 4 * 1024 * 4 + 2 * 4 * 4
    assert reg.bytes_per_shard(1) == full
    # sharded groups divide by shards; replicated groups do not
    assert reg.bytes_per_shard(4) == 4 * 256 * 4 + 2 * 4 * 4


def test_engine_registry_covers_the_matrix():
    import repro.serving.admission  # noqa: F401  registers "serving"
    assert {"rounds", "prounds", "mesh", "mesh-sharded", "pmesh-relaxed",
            "pmesh-strict", "serving"} <= set(ENGINE_REGISTRY)
    assert not ENGINE_REGISTRY["mesh-sharded"].spans_ok
    assert ENGINE_REGISTRY["mesh-sharded"].kwargs == {"sharded": True}
    assert ENGINE_REGISTRY["serving"].priority
    assert ENGINE_REGISTRY["serving"].mesh


# -- span round-clock cap enforced at stamp time ------------------------------


def test_enq_planes_rejects_birth_round_at_cap():
    n = 8
    planes = [jnp.zeros(2 * n, jnp.int32) for _ in range(3)]
    idxs = jnp.full(2 * n, IDX_BOT, jnp.int32)
    with pytest.raises(ValueError, match="birth-stamp cap"):
        enq_planes(planes[0], planes[1], planes[2], idxs,
                   jnp.arange(4, dtype=jnp.int32),
                   jnp.arange(4, dtype=jnp.int32), jnp.int32(0),
                   nslots_log2=4, idx_bot=IDX_BOT,
                   birth_round=SPAN_ROUND_CAP)
    # one under the cap stamps fine
    enq_planes(planes[0], planes[1], planes[2], idxs,
               jnp.arange(4, dtype=jnp.int32),
               jnp.arange(4, dtype=jnp.int32), jnp.int32(0),
               nslots_log2=4, idx_bot=IDX_BOT,
               birth_round=SPAN_ROUND_CAP - 1)


def test_driver_raises_before_span_stamps_wrap():
    r = RoundRunner(_tree_step, capacity_log2=8, batch=16,
                    spans=Spans(classes=1, buckets=8))
    r._engine.span_round_cap = 4          # the fanout needs 7 rounds
    with pytest.raises(RuntimeError, match="span round clock"):
        r.run([1], acc=jnp.zeros(80, jnp.int32))
    # without spans the same cap is irrelevant: no stamps, no raise
    r2 = RoundRunner(_tree_step, capacity_log2=8, batch=16)
    r2._engine.span_round_cap = 4
    acc, _ = r2.run([1], acc=jnp.zeros(80, jnp.int32))
    assert int(np.asarray(acc).sum()) == 63


# -- deprecated entry points --------------------------------------------------


def test_deprecated_fused_names_warn_and_run():
    mesh = _mesh1()
    with pytest.warns(DeprecationWarning, match="FusedRounds .* RingEngine"):
        e = FusedRounds(_tree_step, capacity_log2=8, batch=16)
    acc, _ = e.run([1], acc=jnp.zeros(80, jnp.int32))
    assert _digest(acc) == GOLDEN["fifo_fanout"]["acc"]
    with pytest.warns(DeprecationWarning, match="HeapEngine"):
        FusedPriorityRounds(_pri_step, capacity_log2=9, batch=16)
    with pytest.warns(DeprecationWarning, match="MeshRingEngine"):
        e = FusedMeshRounds(_tree_step, mesh=mesh, capacity_log2=8,
                            batch=16, combine=lambda a: a.sum(0))
    acc, _ = e.run([1], acc=jnp.zeros(80, jnp.int32))
    assert _digest(acc) == GOLDEN["mesh_fanout"]["acc"]
    with pytest.warns(DeprecationWarning, match="MeshHeapEngine"):
        FusedPriorityMeshRounds(_pri_mesh_step, mesh=mesh,
                                capacity_log2=10, batch=16)


if __name__ == "__main__":
    if "--golden2" in sys.argv:
        _golden2_worker()
    elif "--sharded-worker" in sys.argv:
        _sharded_worker()
