"""repro.apps subpackage."""
