"""Tile-based wavefront ray tracer with per-tile queues (paper § V-B-b).

A W×H image is split into Tx×Ty tiles; each tile owns a bounded ray queue.
Primary rays are enqueued per tile; the persistent tracing loop dequeues a
wave of rays, intersects spheres/plane, shades, and re-enqueues reflective
bounces into the same tile queue until no work remains — the paper's
queue-as-work-distribution layer.

Baseline: stream compaction (Wald'11-style) — all rays advance in lockstep;
dead rays are compacted out between bounces (sort/prefix-sum) — the
comparison target of Fig. 7.

Scenes (paper § V-B-b): ``complex_scene`` (100 spheres on a plane, 2-bounce)
and ``cornell_scene`` (two spheres, 4 bounces, plane + three walls).

All ray math is vectorized jnp; the queue layer uses the vectorized ring
ops (wavefaa ticket reservation) so queue cost is observable in benchmarks.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Scene:
    centers: np.ndarray   # (S, 3)
    radii: np.ndarray     # (S,)
    albedo: np.ndarray    # (S, 3)
    reflect: np.ndarray   # (S,) reflectivity in [0, 1]
    max_bounces: int
    name: str


def complex_scene(seed: int = 0) -> Scene:
    rng = np.random.default_rng(seed)
    s = 100
    centers = np.stack([rng.uniform(-8, 8, s), rng.uniform(0.3, 2.5, s),
                        rng.uniform(4, 20, s)], -1)
    return Scene(centers.astype(np.float32),
                 rng.uniform(0.2, 0.7, s).astype(np.float32),
                 rng.uniform(0.2, 1.0, (s, 3)).astype(np.float32),
                 rng.uniform(0.3, 0.9, s).astype(np.float32),
                 max_bounces=2, name="complex")


def cornell_scene() -> Scene:
    centers = np.array([[-1.0, 1.0, 6.0], [1.2, 0.7, 5.0]], np.float32)
    return Scene(centers, np.array([1.0, 0.7], np.float32),
                 np.array([[0.9, 0.9, 0.9], [0.8, 0.6, 0.2]], np.float32),
                 np.array([0.9, 0.7], np.float32),
                 max_bounces=4, name="cornell")


def primary_rays(w: int, h: int):
    xs = (jnp.arange(w) + 0.5) / w * 2 - 1
    ys = (jnp.arange(h) + 0.5) / h * 2 - 1
    gx, gy = jnp.meshgrid(xs, ys)
    d = jnp.stack([gx, -gy, jnp.ones_like(gx)], -1)
    d = d / jnp.linalg.norm(d, axis=-1, keepdims=True)
    o = jnp.zeros((h, w, 3)) + jnp.array([0.0, 1.0, 0.0])
    return o.reshape(-1, 3), d.reshape(-1, 3)


@jax.jit
def _trace_once(o, d, centers, radii, albedo, reflect):
    """One intersection+shade step for a wave of rays.
    Returns (color_contrib, new_o, new_d, alive)."""
    oc = o[:, None, :] - centers[None, :, :]                 # (R, S, 3)
    b = jnp.sum(oc * d[:, None, :], -1)
    c = jnp.sum(oc * oc, -1) - radii[None, :] ** 2
    disc = b * b - c
    t_sph = jnp.where(disc > 0, -b - jnp.sqrt(jnp.maximum(disc, 0)), jnp.inf)
    t_sph = jnp.where(t_sph > 1e-3, t_sph, jnp.inf)
    t_best = jnp.min(t_sph, -1)
    hit_idx = jnp.argmin(t_sph, -1)
    # ground plane y=0
    t_pl = jnp.where(d[:, 1] < -1e-6, -o[:, 1] / d[:, 1], jnp.inf)
    t_pl = jnp.where(t_pl > 1e-3, t_pl, jnp.inf)
    use_pl = t_pl < t_best
    t = jnp.where(use_pl, t_pl, t_best)
    hit = jnp.isfinite(t)
    p = o + t[:, None] * d
    n_sph = (p - centers[hit_idx]) / jnp.maximum(radii[hit_idx], 1e-6)[:, None]
    n = jnp.where(use_pl[:, None], jnp.array([0.0, 1.0, 0.0]), n_sph)
    checker = ((jnp.floor(p[:, 0]) + jnp.floor(p[:, 2])) % 2)
    alb_pl = jnp.stack([0.6 + 0.3 * checker] * 3, -1)
    alb = jnp.where(use_pl[:, None], alb_pl, albedo[hit_idx])
    refl = jnp.where(use_pl, 0.15, reflect[hit_idx])
    # simple sun shading
    sun = jnp.array([0.5, 0.8, -0.3])
    sun = sun / jnp.linalg.norm(sun)
    diff = jnp.maximum(jnp.sum(n * sun, -1), 0.1)
    sky = (jnp.array([0.5, 0.7, 1.0])[None, :]
           * (0.6 + 0.4 * jnp.maximum(d[:, 1], 0))[:, None])
    color = jnp.where(hit[:, None],
                      alb * diff[:, None] * (1 - refl[:, None]), sky)
    new_d = d - 2 * jnp.sum(d * n, -1, keepdims=True) * n
    new_o = p + 1e-3 * new_d
    alive = hit & (refl > 0.05)
    return color, new_o, new_d, alive, refl


def render_queue(scene: Scene, w: int = 64, h: int = 64, tx: int = 4,
                 ty: int = 4, wave: int = 256) -> Tuple[np.ndarray, Dict]:
    """Queue-driven wavefront: per-tile ray queues; the persistent loop
    dequeues ≤wave rays, traces, re-enqueues live bounces (ticket-reserved
    ring semantics on the host side; trace math jitted per wave)."""
    ce, ra, al, re = (jnp.asarray(scene.centers), jnp.asarray(scene.radii),
                      jnp.asarray(scene.albedo), jnp.asarray(scene.reflect))
    o, d = primary_rays(w, h)
    img = np.zeros((h * w, 3), np.float32)
    weight = np.ones((h * w,), np.float32)
    bounces = np.zeros((h * w,), np.int32)
    # per-tile queues of ray ids
    tiles = [[] for _ in range(tx * ty)]
    ids = np.arange(h * w)
    tile_of = (ids // w // (h // ty)) * tx + (ids % w) // (w // tx)
    for i in ids:
        tiles[tile_of[i]].append(i)
    o_np, d_np = np.array(o), np.array(d)
    rays_traced, waves = 0, 0
    while any(tiles):
        for t in range(tx * ty):
            if not tiles[t]:
                continue
            batch, tiles[t] = tiles[t][:wave], tiles[t][wave:]
            idx = np.asarray(batch)
            col, no, nd, alive, refl = _trace_once(
                jnp.asarray(o_np[idx]), jnp.asarray(d_np[idx]), ce, ra, al, re)
            col, no, nd = np.asarray(col), np.asarray(no), np.asarray(nd)
            alive, refl = np.asarray(alive), np.asarray(refl)
            img[idx] += weight[idx, None] * col
            weight[idx] *= refl
            bounces[idx] += 1
            # primary trace + max_bounces reflections (matches the baseline)
            cont = alive & (bounces[idx] <= scene.max_bounces)
            o_np[idx], d_np[idx] = no, nd
            tiles[t].extend(idx[cont].tolist())  # re-enqueue bounces
            rays_traced += len(idx)
            waves += 1
    return img.reshape(h, w, 3), {"rays": rays_traced, "waves": waves}


def render_runtime(scene: Scene, w: int = 64, h: int = 64, tx: int = 4,
                   ty: int = 4, wave: int = 256, *, algo: str = "glfq",
                   shards: int = 4, workers: int = 8, steal: bool = True,
                   policy: str = "gang", seed: int = 0
                   ) -> Tuple[np.ndarray, Dict]:
    """Tile scheduling through the task fabric (DESIGN.md § 4.6): one task =
    one ≤``wave``-ray batch of one tile.  The handler traces the batch
    (jitted ``_trace_once``) and spawns a continuation task for the rays
    that bounced — wave-affinity keeps a tile's continuations on its home
    shard, and stealing rebalances when tiles finish at different bounce
    depths (sky tiles die instantly; reflective tiles keep spawning).

    Pixel accumulation is order-independent (img += weight·color with
    per-ray weights), so any fabric interleaving renders the same image as
    ``render_queue``."""
    from ..runtime import ExecutorConfig, TaskFabric, TaskRuntime, TaskSpec

    ce, ra, al, re = (jnp.asarray(scene.centers), jnp.asarray(scene.radii),
                      jnp.asarray(scene.albedo), jnp.asarray(scene.reflect))
    o, d = primary_rays(w, h)
    img = np.zeros((h * w, 3), np.float32)
    weight = np.ones((h * w,), np.float32)
    bounces = np.zeros((h * w,), np.int32)
    o_np, d_np = np.array(o), np.array(d)
    ids = np.arange(h * w)
    tile_of = (ids // w // (h // ty)) * tx + (ids % w) // (w // tx)
    stats = {"rays": 0, "waves": 0}

    def handler(rec):
        tile, idx = rec.payload
        idx = np.asarray(idx)
        col, no, nd, alive, refl = _trace_once(
            jnp.asarray(o_np[idx]), jnp.asarray(d_np[idx]), ce, ra, al, re)
        col, no, nd = np.asarray(col), np.asarray(no), np.asarray(nd)
        alive, refl = np.asarray(alive), np.asarray(refl)
        img[idx] += weight[idx, None] * col
        weight[idx] *= refl
        bounces[idx] += 1
        cont = alive & (bounces[idx] <= scene.max_bounces)
        o_np[idx], d_np[idx] = no, nd
        stats["rays"] += len(idx)
        stats["waves"] += 1
        live = idx[cont]
        if len(live) == 0:
            return []
        return [TaskSpec((tile, live), cost=max(len(live) // 32, 1))]

    n_tiles = tx * ty
    fabric = TaskFabric(algo=algo, shards=shards,
                        capacity_per_shard=max(
                            4 * (h * w // wave + n_tiles) // max(shards, 1), 64),
                        num_threads=workers + 1, steal=steal)
    rt = TaskRuntime(fabric, handler,
                     ExecutorConfig(workers=workers, policy=policy, seed=seed,
                                    max_steps=50_000_000))
    for t in range(n_tiles):
        mine = ids[tile_of == t]
        for i in range(0, len(mine), wave):
            rt.add_task((t, mine[i:i + wave]),
                        cost=max(len(mine[i:i + wave]) // 32, 1))
    m = rt.run()
    info = dict(stats)
    info.update({"tasks": len(rt.executed),
                 "steal_rate": m["steal_rate"],
                 "idle_steps": m["idle_steps"],
                 "load_imbalance": m["load_imbalance"]})
    return img.reshape(h, w, 3), info


def render_rounds(scene: Scene, w: int = 64, h: int = 64, batch: int = 256,
                  *, fused: bool = True, interpret=None, sync_every: int = 0,
                  max_rounds: int = 10_000) -> Tuple[np.ndarray, Dict]:
    """Wavefront tracing on the deterministic round engine (DESIGN.md
    § 4.3): the ring carries pixel/ray ids (index indirection — the ray
    state lives in the accumulator), one jitted step traces a batch with
    ``_trace_once`` and re-enqueues the rays that bounced.  Per-pixel
    contribution order matches ``render_queue`` exactly (each pixel id is
    in flight at most once), so the images agree bit-for-bit.

    ``fused=True`` (default) keeps the whole bounce loop device-resident;
    ``fused=False`` is the legacy per-round path.  Both are bit-identical."""
    from ..runtime import RoundRunner

    ce, ra, al, re = (jnp.asarray(scene.centers), jnp.asarray(scene.radii),
                      jnp.asarray(scene.albedo), jnp.asarray(scene.reflect))
    o0, d0 = primary_rays(w, h)
    npix = h * w
    max_b = scene.max_bounces

    def step(acc, vals, valid):
        img, weight, o, d, bounces = acc
        ids = jnp.where(valid, vals, 0)
        col, no, nd, alive, refl = _trace_once(o[ids], d[ids], ce, ra, al, re)
        drop = jnp.where(valid, ids, npix)     # invalid lanes scatter away
        img = img.at[drop].add(weight[ids][:, None] * col, mode="drop")
        weight = weight.at[drop].multiply(refl, mode="drop")
        o = o.at[drop].set(no, mode="drop")
        d = d.at[drop].set(nd, mode="drop")
        bounces = bounces.at[drop].add(1, mode="drop")
        cont = valid & alive & (bounces[ids] <= max_b)
        return (img, weight, o, d, bounces), vals[:, None], cont[:, None]

    capacity_log2 = max(int(np.ceil(np.log2(max(npix, batch)))), 4)
    runner = RoundRunner(step, capacity_log2=capacity_log2, batch=batch,
                         fused=fused, interpret=interpret,
                         sync_every=sync_every)
    acc0 = (jnp.zeros((npix, 3), jnp.float32), jnp.ones((npix,), jnp.float32),
            o0, d0, jnp.zeros((npix,), jnp.int32))
    (img, _, _, _, _), _ = runner.run(np.arange(npix, dtype=np.int32),
                                      acc=acc0, max_rounds=max_rounds)
    info = dict(runner.stats)
    info.update({"rays": info["processed"], "waves": info["rounds"]})
    return np.asarray(img).reshape(h, w, 3), info


def render_compaction(scene: Scene, w: int = 64, h: int = 64
                      ) -> Tuple[np.ndarray, Dict]:
    """Stream-compaction baseline: lockstep bounces over the full ray set,
    compacting dead rays between bounces."""
    ce, ra, al, re = (jnp.asarray(scene.centers), jnp.asarray(scene.radii),
                      jnp.asarray(scene.albedo), jnp.asarray(scene.reflect))
    o, d = primary_rays(w, h)
    img = np.zeros((h * w, 3), np.float32)
    weight = np.ones((h * w,), np.float32)
    idx = np.arange(h * w)
    o_np, d_np = np.array(o), np.array(d)
    rays_traced = 0
    for _ in range(scene.max_bounces + 1):
        if len(idx) == 0:
            break
        col, no, nd, alive, refl = _trace_once(
            jnp.asarray(o_np[idx]), jnp.asarray(d_np[idx]), ce, ra, al, re)
        col, alive, refl = np.asarray(col), np.asarray(alive), np.asarray(refl)
        img[idx] += weight[idx, None] * col
        weight[idx] *= refl
        o_np[idx], d_np[idx] = np.asarray(no), np.asarray(nd)
        rays_traced += len(idx)
        idx = idx[alive]  # stream compaction
    return img.reshape(h, w, 3), {"rays": rays_traced}
