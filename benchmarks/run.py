"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json results.json]

Sections: Fig. 4 throughput, Fig. 5 per-op profiling (+ Fig. 1 ablation),
Table IV/Fig. 6 BFS, Fig. 7 ray tracing, kernel micro-benchmarks, the
task-runtime fabric comparison (bench_runtime), the G-PQ priority policy
comparison (bench_runtime.priority_main), the round/mesh megaround
engines (bench_rounds, bench_mesh), priority-mesh SSSP (bench_sssp), the
telemetry overhead sweep (bench_obs), the offered-load latency sweep
reading per-class sojourn percentiles off the device span planes
(bench_latency), and the open-loop serving harness comparing host-pool
vs device-resident EDF admission on goodput and tail latency
(bench_serving).

``--trace [DIR]`` emits the observability artifact instead of (or before)
the sweep: a 2-shard mesh SSSP run's telemetry as ``trace_sssp.jsonl`` +
``trace_sssp.json`` (Chrome trace) with per-round occupancy, claim
imbalance, and measured rank error vs the declared relaxation envelope —
schema-validated by ``tools/trace_check.py`` before the driver exits 0.

CSV lines go to stdout: ``name,...`` per row.  With ``--json`` the same
rows are parsed into ``{section: [row dicts]}`` and written to the given
path (``-`` = stdout) — the machine-readable trajectory format.

``--emit-trajectory`` additionally writes ``BENCH_<n>.json`` at the repo
root (n auto-increments over existing ``BENCH_*.json``): the scheduling
perf trajectory — throughput / idle / steal / imbalance / starvation rows
plus config and git-rev metadata — one snapshot per PR, so regressions
are visible across the series.
"""

import argparse
import glob
import io
import json
import os
import re
import subprocess
import sys


class _Tee(io.TextIOBase):
    """Forward writes to stdout while keeping a copy for CSV parsing."""

    def __init__(self) -> None:
        self.buf = io.StringIO()

    def write(self, s: str) -> int:
        sys.stdout.write(s)
        return self.buf.write(s)

    def flush(self) -> None:
        sys.stdout.flush()


def _parse_csv(text: str):
    """Parse a section's output: every bench header leads with the literal
    cell ``bench`` (possibly mid-section — sub-tables need no separator);
    later comma lines are rows under the current header (numbers coerced);
    ``#`` lines are commentary."""
    rows, header = [], None
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split(",")
        if parts[0] == "bench" or header is None:
            header = parts
            continue
        row = {}
        for k, v in zip(header, parts):
            if v == "":
                row[k] = None     # absent numeric -> JSON null, never ""
                continue
            try:
                row[k] = int(v)
            except ValueError:
                try:
                    row[k] = float(v)
                except ValueError:
                    row[k] = v
        rows.append(row)
    return rows


REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Trajectory rows keep only scheduling-relevant metrics; everything else in
# a row (configs, counts) rides along untouched.
_TRAJECTORY_SECTIONS = ("runtime", "priority", "rounds", "mesh", "sssp",
                        "obs", "latency", "profiling", "serving")


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _next_bench_id() -> int:
    ids = [int(m.group(1)) for p in glob.glob(os.path.join(REPO_ROOT, "BENCH_*.json"))
           if (m := re.match(r"BENCH_(\d+)\.json$", os.path.basename(p)))]
    return max(ids, default=1) + 1


def emit_trajectory(results: dict, *, quick: bool, bench_id=None) -> str:
    """Write BENCH_<n>.json at the repo root: the perf-trajectory snapshot
    (scheduling sections + config + git rev)."""
    n = _next_bench_id() if bench_id is None else int(bench_id)
    sections = {k: v for k, v in results.items() if k in _TRAJECTORY_SECTIONS}
    if not sections:
        raise ValueError(
            f"no scheduling sections in results (need one of "
            f"{_TRAJECTORY_SECTIONS}); refusing to burn trajectory id {n} "
            f"on a heterogeneous snapshot")
    payload = {
        "bench_id": n,
        "git_rev": _git_rev(),
        "config": {"quick": quick,
                   "sections": sorted(results)},
        "sections": sections,
    }
    path = os.path.join(REPO_ROOT, f"BENCH_{n}.json")
    with open(path, "w") as f:
        f.write(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    print(f"# trajectory -> {path}")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweeps (CI-sized)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also emit {section: [rows]} JSON to PATH ('-' = stdout)")
    ap.add_argument("--section", default=None,
                    help="comma-separated subset of: throughput, profiling, "
                         "bfs, raytrace, kernels, runtime, priority, rounds, "
                         "mesh, sssp, obs, latency, serving")
    ap.add_argument("--trace", nargs="?", const=".", default=None,
                    metavar="DIR",
                    help="emit the telemetry artifact into DIR (default .): "
                         "a 2-shard mesh SSSP run's JSONL + Chrome trace "
                         "with per-round occupancy, claim imbalance, and "
                         "measured rank error vs the declared envelope, "
                         "validated by tools/trace_check.py")
    ap.add_argument("--emit-trajectory", nargs="?", const="auto",
                    default=None, metavar="N",
                    help="write BENCH_<n>.json at the repo root (n "
                         "auto-increments unless given)")
    args = ap.parse_args()
    if args.emit_trajectory not in (None, "auto"):
        try:                       # validate before the sweep, not after
            args.emit_trajectory = int(args.emit_trajectory)
        except ValueError:
            ap.error(f"--emit-trajectory expects an integer, got "
                     f"{args.emit_trajectory!r}")
    from . import (bench_bfs, bench_kernels, bench_latency, bench_mesh,
                   bench_obs, bench_profiling, bench_raytrace, bench_rounds,
                   bench_runtime, bench_serving, bench_sssp,
                   bench_throughput)

    if args.trace is not None:
        if not bench_obs.trace_main(trace_dir=args.trace,
                                    shards=2, n=256 if args.quick else 512):
            sys.exit(1)
        if args.section is None and args.emit_trajectory is None:
            return                       # --trace alone: artifact only

    kw_thr = dict(threads_list=(8, 32), steps=40_000) if args.quick else {}
    kw_prof = dict(threads_list=(8, 32), steps=40_000) if args.quick else {}
    kw_rt = (dict(algos=("glfq",), n_tasks=96) if args.quick
             else dict(algos=("glfq", "gwfq", "gwfq-ymc", "sfq")))
    kw_pri = dict(bursts=12) if args.quick else {}
    kw_rnd = (dict(batches=(64, 256), fanout_depth=8, bfs_n=1024)
              if args.quick else {})
    kw_mesh = dict(batches=(64,), bfs_n=512) if args.quick else {}
    kw_sssp = dict(batches=(64,), n=512) if args.quick else {}
    kw_obs = (dict(batches=(64,), fanout_depth=8, bfs_n=1024, sssp_n=256)
              if args.quick else {})
    kw_lat = dict(batches=(16, 64), n=256) if args.quick else {}
    kw_srv = (dict(rates=(0.5, 2.5), ticks=80, trials=2)
              if args.quick else {})
    sections = {
        "throughput": lambda out: bench_throughput.main(out, **kw_thr),
        "profiling": lambda out: bench_profiling.main(out, **kw_prof),
        "bfs": lambda out: bench_bfs.main(out),
        "raytrace": lambda out: bench_raytrace.main(out),
        "kernels": lambda out: bench_kernels.main(out),
        "runtime": lambda out: bench_runtime.main(out, **kw_rt),
        "priority": lambda out: bench_runtime.priority_main(out, **kw_pri),
        "rounds": lambda out: bench_rounds.main(out, **kw_rnd),
        "mesh": lambda out: bench_mesh.main(out, **kw_mesh),
        "sssp": lambda out: bench_sssp.main(out, **kw_sssp),
        "obs": lambda out: bench_obs.main(out, **kw_obs),
        "latency": lambda out: bench_latency.main(out, **kw_lat),
        "serving": lambda out: bench_serving.main(out, **kw_srv),
    }
    if args.section:
        todo = [s.strip() for s in args.section.split(",") if s.strip()]
        unknown = [s for s in todo if s not in sections]
        if unknown:
            ap.error(f"unknown section(s) {unknown}; pick from {list(sections)}")
    else:
        todo = list(sections)
    if (args.emit_trajectory is not None
            and not any(s in _TRAJECTORY_SECTIONS for s in todo)):
        ap.error(f"--emit-trajectory needs at least one scheduling section "
                 f"({', '.join(_TRAJECTORY_SECTIONS)}) in the run")
    if args.json and args.json != "-":
        with open(args.json, "a"):     # fail on an unwritable path up front,
            pass                       # not after the whole sweep has run
    results = {}
    for name in todo:
        print(f"# === {name} ===")
        tee = _Tee()
        sections[name](tee)
        results[name] = _parse_csv(tee.buf.getvalue())
        sys.stdout.flush()
    if args.json:
        payload = json.dumps(results, indent=1, sort_keys=True)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w") as f:
                f.write(payload + "\n")
            print(f"# json -> {args.json}")
    if args.emit_trajectory is not None:
        emit_trajectory(results, quick=args.quick,
                        bench_id=None if args.emit_trajectory == "auto"
                        else args.emit_trajectory)


if __name__ == "__main__":
    main()
