"""Mesh round engines (DESIGN.md § 2.3, § 6): ``enginecore.EngineCore``
configurations one level up the hierarchy, running the whole
dequeue → step → ticket → enqueue cycle *device-resident under
shard_map*.

Three engines over two queue planes:

* ``MeshRingEngine`` — the FIFO megaround over the *replicated* ring
  (``core.distqueue.DistQueueState``): every shard carries the full
  O(ring) plane set, the claim wave is collective-free (the rebalancing
  schedule is a pure function of the replicated head/tail), and the
  publish wave costs exactly ONE psum (``mesh_round_gather``).  Kept as
  the bit-identity parity baseline for the sharded plane.
* ``ShardedMeshRingEngine`` — the same megaround over *per-shard* ring
  planes (``DistShardedQueueState``): each shard owns one
  2·(capacity/shards)-slot local ring while the (S,) head/tail ticket
  vectors stay replicated, so the loop-carry memory drops from O(ring)
  to O(ring/shards) per shard (the ``benchmarks/bench_mesh.py`` column).
  The claim schedule drains the fullest rings first
  (``dist_sharded_claim_round``); children spray round-robin by global
  publish rank with ONE ``mesh_round_gather`` meta-word psum per round
  (``dist_sharded_publish_round``), mirroring the relaxed priority
  plane's ``dist_priority_publish_round`` discipline.
* ``MeshHeapEngine`` — the priority megaround (claim → pop-min → step →
  push) over the ``core.distqueue`` priority plane, in two orderings:
  ``relaxed=True`` (per-shard local heaps, hint-ordered even-split
  claim schedule, k-relaxed delete-min — envelope in
  ``sched.relaxed.mesh_relaxation_bound``) and ``relaxed=False`` (one
  replicated heap popped in exact global min-key order).

All three are thin configurations of the fused-engine core
(DESIGN.md § 4.8): the round bodies follow the standardized ``_round``
contract, ``EngineCore.fused_loop`` builds the one jitted
``lax.while_loop``, ``_run_chunks``/``_drive`` own the host sync +
overflow/truncation contract, and each engine's loop carry is declared
once in its ``PlaneRegistry`` — the registry derives both the shard_map
specs and the measured per-shard carry bytes.  The mesh layer adds only
the shard_map boundary: ``_megaround_impl`` overrides unstack the
``P(axis)``-sharded leaves (stacked ``(1, ...)`` per shard) around the
core loop and restack them on the way out.

``MeshRoundRunner`` / ``PriorityMeshRoundRunner`` are the runner faces:
``fused=True`` (default) delegates to the engines above; ``fused=False``
keeps the legacy host-driven loop — one jitted shard_map dispatch and
one occupancy readback per round (``EngineCore._legacy_loop``) — for
step-debug, as the parity baseline, and (priority only) as the history
recorder for ``sched.plinearizability``.  Fused and legacy are
bit-identical on the replicated planes; the sharded ring is exact
against the replicated baseline on totals and order-insensitive
accumulators (claim *order* legitimately differs — the schedule is
load-aware, not rank-sliced).

Note on the replication checker: the per-round distqueue API passes
``check_rep=True``, but ``lax.while_loop`` has no replication rule in
this jax line, so every megaround shard_map is built with
``check_rep=False``.  Per-shard state bit-identity is asserted by tests
instead.

Overflow and truncation follow the core contract: a flag in the carry
exits the loop and the host driver raises ``RuntimeError`` at the next
sync.  Accumulators are *per-shard* (each shard steps only its claimed
batch), returned stacked with a leading shard axis unless ``combine``
reduces them (BFS: elementwise min over shards).

``FusedMeshRounds`` / ``FusedPriorityMeshRounds`` are deprecated shims
over ``MeshRingEngine`` / ``MeshHeapEngine``.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.distqueue import (DistHeapState, DistQueueState,
                              DistShardedQueueState, claim_schedule,
                              dist_claim_round, dist_heap_init,
                              dist_priority_publish_compact_round,
                              dist_priority_publish_round,
                              dist_publish_compact_round, dist_publish_round,
                              dist_queue_init, dist_sharded_claim_round,
                              dist_sharded_publish_round,
                              dist_sharded_queue_init,
                              priority_claim_schedule)
from ..kernels.compact import compact_width
from ..kernels.heap_batch import (KEY_INF as HEAP_KEY_INF, heap_insert_masked,
                                  heap_pop_count)
from ..kernels.ring_slots import enq_planes
from ..obs.spans import Spans, span_record, span_tick
from ..obs.trace import Telemetry, masked_min_max
from .enginecore import (EngineCore, _sds, deprecated_engine,
                         register_engine)
from .fusedrounds import IDX_BOT, PriorityStepFn, StepFn

__all__ = ["FusedMeshRounds", "FusedPriorityMeshRounds", "MeshHeapEngine",
           "MeshRingEngine", "MeshRoundRunner", "PriorityMeshRoundRunner",
           "ShardedMeshRingEngine"]


def _unstack(x):
    return jax.tree_util.tree_map(lambda a: a[0], x)


def _restack(x):
    return jax.tree_util.tree_map(lambda a: a[None], x)


class _MeshFifoBase(EngineCore):
    """Shared FIFO-mesh scaffolding: constructor fields, capacity
    validation, and the host-side acc broadcast."""

    def __init__(self, step_fn: StepFn, *, mesh, axis: str = "data",
                 capacity_log2: int = 10, batch: int = 64,
                 sync_every: int = 0,
                 combine: Callable[[Any], Any] = None,
                 telemetry: Optional[Telemetry] = None,
                 spans: Optional[Spans] = None, compact=None) -> None:
        self.step_fn = step_fn
        self.mesh = mesh
        self.axis = axis
        self.shards = int(mesh.shape[axis])
        self.capacity_log2 = capacity_log2
        self.capacity = 1 << capacity_log2
        self.nslots_log2 = capacity_log2 + 1
        self.batch = batch
        if batch * self.shards > self.capacity:
            raise ValueError(
                f"mesh batch {batch} x {self.shards} shards exceeds ring "
                f"capacity {self.capacity}")
        self.sync_every = sync_every
        self.combine = combine
        self.telemetry = telemetry
        self.spans = spans
        self.compact = compact
        self._reset()

    def _initial_carry(self, state, acc):
        acc = jax.tree_util.tree_map(jnp.asarray, acc)
        acc = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.shards,) + x.shape),
            acc)
        return state, acc

    def _finish(self, state):
        acc = state[1]
        if self.combine is not None:
            acc = self.combine(acc)
        return acc, state[0]


class MeshRingEngine(_MeshFifoBase):
    """The replicated-ring FIFO megaround: one jitted shard_map call runs
    up to ``limit`` rounds on device; host sync only at quiescence (or
    every ``sync_every`` rounds).  ``run`` mirrors ``RingEngine.run`` and
    returns (acc, final ``DistQueueState``) where acc carries a leading
    shard axis unless ``combine`` reduces it."""

    def __init__(self, step_fn: StepFn, *, mesh, axis: str = "data",
                 capacity_log2: int = 10, batch: int = 64,
                 sync_every: int = 0,
                 combine: Callable[[Any], Any] = None,
                 telemetry: Optional[Telemetry] = None,
                 spans: Optional[Spans] = None, compact=None) -> None:
        super().__init__(step_fn, mesh=mesh, axis=axis,
                         capacity_log2=capacity_log2, batch=batch,
                         sync_every=sync_every, combine=combine,
                         telemetry=telemetry, spans=spans, compact=compact)
        n2 = 2 << capacity_log2
        reg = self.registry
        reg.register("ring", (_sds((n2,)),) * 4 + (_sds(()), _sds(())))
        self._register_obs_planes(self.shards, stacked=True,
                                  births_shape=(n2,))
        # in shard_map, P() = replicated, P(axis) = sharded; a bare spec
        # serves as a pytree-prefix for a whole subtree (the qstate
        # NamedTuple, the acc tree).  acc rides stacked (shards, ...) so
        # successive chunk calls (sync_every heartbeats) compose.  The
        # trailing (tp, sp, births) slots always exist in the specs: None
        # is a valid pytree leaf-set for any spec, and the all-None call
        # compiles to the exact unobserved graph.  The TracePlane is
        # replicated (every record field derives from replicated values);
        # the SpanPlane is sharded (each shard records its own claims);
        # the births plane mirrors the ring field planes — replicated.
        obs = (reg.spec("trace"), reg.spec("span"), reg.spec("births"))
        in_specs = (reg.spec("ring"), P(self.axis),
                    P(), P(), P(), P()) + obs
        out_specs = (reg.spec("ring"), P(self.axis),
                     P(), P(), P(), P(), P()) + obs
        self._megaround = jax.jit(shard_map(
            self._megaround_impl, mesh=self.mesh,
            in_specs=in_specs, out_specs=out_specs,
            check_rep=False))   # while_loop has no replication rule

    # -- seeding (host-side, before shard_map: planes are plain jnp) --------
    def _seed(self, state: DistQueueState,
              initial: np.ndarray) -> DistQueueState:
        k = len(initial)
        if k > self.capacity:
            raise RuntimeError(
                f"mesh ring overflow: {k} seed values exceed capacity "
                f"{self.capacity} (raise capacity_log2)")
        if k == 0:
            return state
        base = int(np.int64(np.asarray(state.tail)))
        t = (base + np.arange(k, dtype=np.int64)) % (2 ** 32)
        tickets = jnp.asarray(np.where(t >= 2 ** 31, t - 2 ** 32, t)
                              .astype(np.int32))
        cyc, saf, enq, idx, ok = enq_planes(
            state.cycles, state.safes, state.enqs, state.idxs, tickets,
            jnp.asarray(initial), state.head,
            nslots_log2=self.nslots_log2, idx_bot=IDX_BOT)
        assert bool(np.asarray(ok).all()), "exact tickets cannot miss"
        return DistQueueState(cyc, saf, enq, idx,
                              tail=state.tail + jnp.int32(k),
                              head=state.head)

    @staticmethod
    def _occ_of(q: DistQueueState):
        return q.tail - q.head

    # -- one mesh round (the standardized ``_round`` contract) --------------
    def _round(self, state: DistQueueState, acc, tel: bool = False,
               sp=None, births=None):
        """claim (no collective) → step → publish (one psum).  Telemetry
        record fields all derive from already-replicated values — zero
        extra collectives.  With ``sp`` the claim reads birth stamps, the
        publish stamps ``sp.round`` into the replicated births plane, and
        each shard records its own local claims into its sharded
        SpanPlane row (DESIGN.md § 7.6)."""
        sps = sp is not None
        occ = state.tail - state.head
        k = jnp.minimum(occ, jnp.int32(self.shards * self.batch))
        cr = dist_claim_round(state, k, self.batch, self.axis,
                              with_grid=tel, births=births)
        state, vals, ok = cr[0], cr[1], cr[2]
        i = 3
        if tel:
            gvals, gok = cr[i]
            i += 1
        if sps:
            bout = cr[i]
        acc, cvals, cmask = self.step_fn(acc, vals, ok)
        cm = jnp.broadcast_to(cmask.astype(bool), cvals.shape).reshape(-1)
        cv = cvals.reshape(-1).astype(jnp.int32)
        # dense-wave rule (DESIGN.md § 4.4): each shard compacts its child
        # block to the capacity bound before the exchange — same single
        # psum, O(width) instead of O(B·F) payload, bit-identical planes.
        # The decision is static (trace-time): exactly one path compiles.
        wdth = compact_width(cv.shape[0], self.capacity, self.compact)
        if wdth is None:
            pr = dist_publish_round(
                state, cv, cm.astype(jnp.int32), self.axis,
                capacity=self.capacity, with_counts=tel, births=births,
                birth_round=sp.round if sps else None)
        else:
            pr = dist_publish_compact_round(
                state, cv, cm.astype(jnp.int32), self.axis,
                capacity=self.capacity, width=wdth, with_counts=tel,
                births=births, birth_round=sp.round if sps else None)
        state, _, total, over = pr[0], pr[1], pr[2], pr[3]
        j = 4
        telinfo = None
        if tel:
            pushes = pr[j]
            j += 1
            cs_active, _ = claim_schedule(k, self.shards, self.batch)
            pops = cs_active.reshape(self.shards, self.batch).sum(
                1, dtype=jnp.int32)
            mn, mx = masked_min_max(gvals, gok)   # FIFO: payload extrema
            occs = jnp.broadcast_to(state.tail - state.head,
                                    (self.shards,))   # replicated ring
            telinfo = (pops, pushes, occs, mn, mx)
        if sps:
            births = pr[j]
            me = jax.lax.axis_index(self.axis)
            cls = self._span_cls(vals, jnp.full_like(vals, me))
            sp = span_record(sp, cls, sp.round - bout, ok, vals)
            sp = span_tick(sp)
        return state, acc, k, total, over, telinfo, sp, births

    # -- shard_map boundary: unstack/restack the P(axis) leaves -------------
    def _megaround_impl(self, qstate, acc, processed, spawned, max_occ,
                        limit, tp=None, sp=None, births=None):
        acc = _unstack(acc)
        sps = sp is not None
        if sps:   # sharded SpanPlane arrives stacked (1, ...) per shard
            sp = _unstack(sp)
        out = super()._megaround_impl(qstate, acc, processed, spawned,
                                      max_occ, limit, tp, sp, births)
        sp_out = _restack(out[8]) if sps else out[8]
        return (out[0], _restack(out[1])) + out[2:8] + (sp_out, out[9])

    def run(self, initial: np.ndarray, acc: Any = None,
            max_rounds: int = 10_000) -> Tuple[Any, DistQueueState]:
        """Seed the replicated ring and run mesh megarounds to global
        quiescence.  Sync contract: one host block per ``sync_every``
        chunk (once total when 0) on the replicated occupancy; all other
        coordination stays on device (one psum per round).  Determinism:
        bit-identical to the legacy per-round path — same acc leaves,
        planes, head/tail, stats.  Raises ``RuntimeError`` on ring
        overflow or truncation at the next sync."""
        self._reset()
        st = self._seed(dist_queue_init(self.capacity),
                        np.asarray(initial, np.int32).reshape(-1))
        st, acc = self._initial_carry(st, acc)
        occ0 = jnp.int32(np.asarray(st.tail - st.head))
        state = [st, acc, jnp.int32(0), jnp.int32(0), occ0]
        ext = [self._tel_init(self.shards),
               self._span_init(self.shards, stacked=True),
               self._births_init((2 << self.capacity_log2,))]
        self._run_chunks(
            state, ext,
            lambda q: int(np.int32(np.asarray(q.tail - q.head))),
            "mesh ring", max_rounds)
        return self._finish(state)


class ShardedMeshRingEngine(_MeshFifoBase):
    """The per-shard-ring FIFO megaround (DESIGN.md § 2.3): each shard
    loop-carries ONE 2·(capacity/shards)-slot local ring plus the (S,)
    replicated ticket vectors — O(ring/shards) carry bytes per shard
    (``loop_carry_bytes``, measured in bench_mesh) versus the replicated
    engine's O(ring).  The claim schedule is load-aware
    (fullest-rings-first, collective-free); the publish sprays children
    round-robin by global rank in ONE meta-word psum.  Exact against the
    replicated baseline on totals and order-insensitive accumulators;
    claim *order* differs by design, so plane bit-identity is not a
    contract here.  Spans are unsupported: the local rings keep no
    replicated birth-stamp rider."""

    def __init__(self, step_fn: StepFn, *, mesh, axis: str = "data",
                 capacity_log2: int = 10, batch: int = 64,
                 sync_every: int = 0,
                 combine: Callable[[Any], Any] = None,
                 telemetry: Optional[Telemetry] = None,
                 spans: Optional[Spans] = None, compact=None) -> None:
        if spans is not None:
            raise ValueError(
                "sharded ring planes keep no replicated birth-stamp "
                "rider: spans needs the replicated mesh engine "
                "(sharded=False)")
        super().__init__(step_fn, mesh=mesh, axis=axis,
                         capacity_log2=capacity_log2, batch=batch,
                         sync_every=sync_every, combine=combine,
                         telemetry=telemetry, spans=spans, compact=compact)
        self.local_capacity = self.capacity // self.shards
        self.lslots_log2 = (capacity_log2
                            - (self.shards.bit_length() - 1)) + 1
        n2 = 2 * self.local_capacity
        reg = self.registry
        # global (stacked) shapes; the registry divides sharded groups by
        # the shard count in bytes_per_shard — the O(ring/shards) claim
        reg.register("ring", (_sds((self.shards, n2)),) * 4, sharded=True)
        reg.register("tickets", (_sds((self.shards,)), _sds((self.shards,))))
        self._register_obs_planes(self.shards, stacked=True)
        qspec = DistShardedQueueState(
            *((reg.spec("ring"),) * 4),
            tails=reg.spec("tickets"), heads=reg.spec("tickets"))
        obs = (reg.spec("trace"), reg.spec("span"), reg.spec("births"))
        in_specs = (qspec, P(self.axis), P(), P(), P(), P()) + obs
        out_specs = (qspec, P(self.axis), P(), P(), P(), P(), P()) + obs
        self._megaround = jax.jit(shard_map(
            self._megaround_impl, mesh=self.mesh,
            in_specs=in_specs, out_specs=out_specs,
            check_rep=False))   # while_loop has no replication rule

    # -- seeding: round-robin spray by seed rank into the local rings -------
    def _seed(self, state: DistShardedQueueState,
              initial: np.ndarray) -> DistShardedQueueState:
        k = len(initial)
        if k > self.capacity:
            raise RuntimeError(
                f"sharded mesh ring overflow: {k} seed values exceed "
                f"capacity {self.capacity} (raise capacity_log2)")
        if k == 0:
            return state
        planes = [list(np.asarray(p)) for p in
                  (state.cycles, state.safes, state.enqs, state.idxs)]
        tails = np.asarray(state.tails).copy()
        shard_of = np.arange(k) % self.shards
        for s in range(self.shards):
            vals = initial[shard_of == s]
            c = len(vals)
            if c == 0:
                continue
            t = (np.int64(np.uint32(tails[s]))
                 + np.arange(c, dtype=np.int64)) % (2 ** 32)
            tickets = jnp.asarray(np.where(t >= 2 ** 31, t - 2 ** 32, t)
                                  .astype(np.int32))
            cyc, saf, enq, idx, ok = enq_planes(
                jnp.asarray(planes[0][s]), jnp.asarray(planes[1][s]),
                jnp.asarray(planes[2][s]), jnp.asarray(planes[3][s]),
                tickets, jnp.asarray(vals), state.heads[s],
                nslots_log2=self.lslots_log2, idx_bot=IDX_BOT)
            assert bool(np.asarray(ok).all()), "exact tickets cannot miss"
            for p, new in zip(planes, (cyc, saf, enq, idx)):
                p[s] = np.asarray(new)
            tails[s] = np.int32(np.int64(tails[s]) + c)
        return DistShardedQueueState(
            *(jnp.asarray(np.stack(p)) for p in planes),
            tails=jnp.asarray(tails), heads=state.heads)

    @staticmethod
    def _occ_of(q: DistShardedQueueState):
        return jnp.sum(q.tails - q.heads)

    # -- one sharded round (the standardized ``_round`` contract) -----------
    def _round(self, state: DistShardedQueueState, acc, tel: bool = False,
               sp=None, births=None):
        """claim (no collective: load-aware schedule over the replicated
        (S,) occupancies) → step → publish (ONE psum: child blocks +
        count/extrema meta words).  The local claim extrema are NOT
        replicated, so with telemetry on they ride the publish psum as
        ``pop_meta`` words — one-collective-per-round still holds."""
        planes = (state.cycles, state.safes, state.enqs, state.idxs)
        planes, heads, vals, ok, counts = dist_sharded_claim_round(
            planes, state.heads, state.tails, self.batch, self.axis,
            nslots_log2=self.lslots_log2)
        acc, cvals, cmask = self.step_fn(acc, vals, ok)
        cm = jnp.broadcast_to(cmask.astype(bool), cvals.shape).reshape(-1)
        cv = cvals.reshape(-1).astype(jnp.int32)
        pop_meta = masked_min_max(vals, ok) if tel else None
        # dense-wave bound: a round spawning more than the GLOBAL capacity
        # must overflow some local ring, where both paths install nothing
        wdth = compact_width(cv.shape[0], self.capacity, self.compact)
        res = dist_sharded_publish_round(
            planes, heads, state.tails, cv, cm.astype(jnp.int32),
            self.axis, nslots_log2=self.lslots_log2,
            local_capacity=self.local_capacity, width=wdth,
            pop_meta=pop_meta)
        planes, tails, total, over = res[0], res[1], res[2], res[3]
        state = DistShardedQueueState(*planes, tails=tails, heads=heads)
        telinfo = None
        if tel:
            assigned, mins, maxs = res[4], res[5], res[6]
            telinfo = (counts, assigned, tails - heads,
                       jnp.min(mins), jnp.max(maxs))
        return state, acc, jnp.sum(counts), total, over, telinfo, sp, births

    # -- shard_map boundary: unstack/restack the P(axis) plane leaves -------
    def _megaround_impl(self, qstate, acc, processed, spawned, max_occ,
                        limit, tp=None, sp=None, births=None):
        qstate = qstate._replace(
            cycles=qstate.cycles[0], safes=qstate.safes[0],
            enqs=qstate.enqs[0], idxs=qstate.idxs[0])
        acc = _unstack(acc)
        out = EngineCore._megaround_impl(
            self, qstate, acc, processed, spawned, max_occ, limit,
            tp, sp, births)
        q = out[0]
        q = q._replace(cycles=q.cycles[None], safes=q.safes[None],
                       enqs=q.enqs[None], idxs=q.idxs[None])
        return (q, _restack(out[1])) + out[2:]

    def run(self, initial: np.ndarray, acc: Any = None,
            max_rounds: int = 10_000) -> Tuple[Any, DistShardedQueueState]:
        """Seed the per-shard rings (round-robin by seed rank) and run to
        global quiescence; same sync/overflow/truncation contract as the
        replicated engine.  Returns (acc, final ``DistShardedQueueState``
        with globally-stacked planes)."""
        self._reset()
        st = self._seed(dist_sharded_queue_init(self.capacity, self.shards),
                        np.asarray(initial, np.int32).reshape(-1))
        st, acc = self._initial_carry(st, acc)
        occ0 = jnp.int32(int(np.asarray(st.tails - st.heads).sum()))
        state = [st, acc, jnp.int32(0), jnp.int32(0), occ0]
        ext = [self._tel_init(self.shards), None, None]
        self._run_chunks(
            state, ext,
            lambda q: int(np.asarray(q.tails - q.heads).sum()),
            "sharded mesh ring", max_rounds)
        return self._finish(state)


class MeshRoundRunner(_MeshFifoBase):
    """Mesh twin of ``RoundRunner``: ``fused=True`` (default) delegates
    to ``MeshRingEngine`` (or ``ShardedMeshRingEngine`` with
    ``sharded=True``); ``fused=False`` keeps the legacy host-driven loop
    — one jitted shard_map dispatch and one occupancy readback per round
    (the ``mesh_task_round`` pathology the fused engines removed), kept
    for step-debug and as the parity baseline.  Fused and legacy are
    bit-identical on the replicated ring."""

    def __init__(self, step_fn: StepFn, *, mesh, axis: str = "data",
                 capacity_log2: int = 10, batch: int = 64,
                 fused: bool = True, sharded: bool = False,
                 sync_every: int = 0,
                 combine: Callable[[Any], Any] = None,
                 telemetry: Optional[Telemetry] = None,
                 spans: Optional[Spans] = None, compact=None) -> None:
        super().__init__(step_fn, mesh=mesh, axis=axis,
                         capacity_log2=capacity_log2, batch=batch,
                         sync_every=sync_every, combine=combine,
                         telemetry=telemetry, spans=spans, compact=compact)
        self.fused = fused
        self.sharded = sharded
        if spans is not None and not fused:
            raise ValueError(
                "span planes are in-loop state: spans needs the fused "
                "engine (fused=True)")
        if sharded and not fused:
            raise ValueError(
                "sharded rings are a fused-engine configuration (the "
                "per-shard planes live in the megaround carry): use "
                "fused=True")
        if fused:
            cls = ShardedMeshRingEngine if sharded else MeshRingEngine
            self._engine = cls(
                step_fn, mesh=mesh, axis=axis, capacity_log2=capacity_log2,
                batch=batch, sync_every=sync_every, combine=combine,
                telemetry=telemetry, spans=spans, compact=compact)
        else:
            self._engine = None
            # legacy: acc rides stacked (shards, ...) through P(axis)
            self._round_jit = jax.jit(shard_map(
                self._legacy_round, mesh=self.mesh,
                in_specs=(P(), P(self.axis)),
                out_specs=(P(), P(self.axis), P(), P(), P()),
                check_rep=False))   # acc diverges per shard (P(axis) io)

    # reuse the replicated engine's round/seed for the legacy baseline
    _seed = MeshRingEngine._seed
    _round = MeshRingEngine._round
    _occ_of = MeshRingEngine._occ_of

    def _legacy_round(self, qstate, acc):
        acc = _unstack(acc)
        qstate, acc, k, total, over = self._round(qstate, acc)[:5]
        return qstate, _restack(acc), k, total, over

    def loop_carry_bytes(self, shards: int = None) -> int:
        # the fused engine owns the plane registry; the legacy loop
        # carries nothing between dispatches (host-resident state)
        if self._engine is not None:
            return self._engine.loop_carry_bytes(shards)
        return super().loop_carry_bytes(shards)

    def run(self, initial: np.ndarray, acc: Any = None,
            max_rounds: int = 10_000) -> Tuple[Any, DistQueueState]:
        """Run to quiescence on the selected engine.  ``fused=True``:
        the megaround contract (host sync only at quiescence /
        ``sync_every``); ``fused=False``: one shard_map dispatch and one
        occupancy readback per round (``host_syncs == rounds``).  Both
        bit-deterministic; both raise on overflow/truncation."""
        if self._engine is not None:
            try:
                return self._engine.run(initial, acc, max_rounds)
            finally:
                self.stats = dict(self._engine.stats, fused=1)
                self.sync_log = self._engine.sync_log
        self._reset()
        st = self._seed(dist_queue_init(self.capacity),
                        np.asarray(initial, np.int32).reshape(-1))
        st, acc = self._initial_carry(st, acc)
        occ0 = int(np.int32(np.asarray(st.tail - st.head)))

        def round_call(q, acc):
            q, acc, k, total, over = self._round_jit(q, acc)
            return q, acc, k, total, over, None

        st, acc = self._legacy_loop(
            st, acc, round_call, occ0,
            lambda q: int(np.int32(np.asarray(q.tail - q.head))),
            "mesh ring", max_rounds)
        if self.combine is not None:
            acc = self.combine(acc)
        return acc, st


# ---------------------------------------------------------------------------
# priority mesh rounds (DESIGN.md § 6)
# ---------------------------------------------------------------------------


class _PriorityMeshBase(EngineCore):
    """Shared priority-mesh machinery: seeding and the one-round bodies.
    ``relaxed=True`` = per-shard local heaps with hint-ordered claim
    rebalancing; ``relaxed=False`` = one replicated heap popped in exact
    global min-key order."""

    def __init__(self, step_fn: PriorityStepFn, *, mesh, axis: str = "data",
                 capacity_log2: int = 10, batch: int = 64,
                 arity_log2: int = 2, relaxed: bool = True,
                 sync_every: int = 0,
                 combine: Callable[[Any], Any] = None,
                 telemetry: Optional[Telemetry] = None,
                 spans: Optional[Spans] = None, compact=None,
                 split: bool = False) -> None:
        self.step_fn = step_fn
        self.telemetry = telemetry
        self.spans = spans
        if split and spans is not None:
            raise ValueError(
                "split payloads ride the heap's rider plane, which spans "
                "already uses for birth stamps: spans and split are "
                "mutually exclusive")
        self.mesh = mesh
        self.axis = axis
        self.shards = int(mesh.shape[axis])
        self.capacity_log2 = capacity_log2
        self.capacity = 1 << capacity_log2
        self.batch = batch
        self.arity_log2 = arity_log2
        self.relaxed = relaxed
        self.compact = compact
        self.split = split
        self.combine = combine
        if relaxed and batch > self.capacity:
            raise ValueError(
                f"batch {batch} exceeds per-shard heap capacity "
                f"{self.capacity}")
        if not relaxed and batch * self.shards > self.capacity:
            raise ValueError(
                f"mesh batch {batch} x {self.shards} shards exceeds heap "
                f"capacity {self.capacity}")
        self.sync_every = sync_every
        self._reset()

    # -- seeding (host-side, before shard_map) ------------------------------
    def _seed(self, ik: np.ndarray, iv: np.ndarray, ia=None):
        """Install the seed (key, val) pairs.  Relaxed mode sprays them
        round-robin by seed rank (``rank % shards``) into the per-shard
        heaps and returns stacked ``(keys (S,cap), vals (S,cap),
        sizes (S,), hints (S,))``; strict mode installs everything into
        the one replicated heap and returns ``(keys, vals, size)``.  In
        split mode ``ia`` carries per-seed aux words installed through
        the rider plane; it trails the return tuple."""
        k = len(ik)
        spl = ia is not None
        if not self.relaxed:
            if k > self.capacity:
                raise RuntimeError(
                    f"mesh heap overflow: {k} seed values exceed capacity "
                    f"{self.capacity} (raise capacity_log2)")
            st = dist_heap_init(self.capacity)
            aux = jnp.zeros((self.capacity,), jnp.int32) if spl else None
            if k == 0:
                return ((st.keys, st.vals, st.size)
                        + ((aux,) if spl else ()))
            out = heap_insert_masked(
                st.keys, st.vals, st.size, jnp.asarray(ik), jnp.asarray(iv),
                jnp.ones((k,), bool), cap_log2=self.capacity_log2,
                arity_log2=self.arity_log2, rider=aux,
                oprider=jnp.asarray(ia) if spl else None)
            keys, vals, size, ok = out[0], out[1], out[2], out[5]
            assert bool(np.asarray(ok).all()), "capacity checked: cannot miss"
            return (keys, vals, size) + ((out[6],) if spl else ())
        shard_of = np.arange(k) % self.shards
        per = [np.flatnonzero(shard_of == s) for s in range(self.shards)]
        worst = max((len(p) for p in per), default=0)
        if worst > self.capacity:
            raise RuntimeError(
                f"mesh heap overflow: {worst} seed values land on one shard, "
                f"exceeding per-shard capacity {self.capacity} (raise "
                f"capacity_log2)")
        keys_l, vals_l, sizes, hints, aux_l = [], [], [], [], []
        for idx in per:
            st = dist_heap_init(self.capacity)
            kk, vv, sz = st.keys, st.vals, st.size
            aa = jnp.zeros((self.capacity,), jnp.int32) if spl else None
            if len(idx):
                out = heap_insert_masked(
                    kk, vv, sz, jnp.asarray(ik[idx]), jnp.asarray(iv[idx]),
                    jnp.ones((len(idx),), bool),
                    cap_log2=self.capacity_log2, arity_log2=self.arity_log2,
                    rider=aa, oprider=jnp.asarray(ia[idx]) if spl else None)
                kk, vv, sz, ok = out[0], out[1], out[2], out[5]
                if spl:
                    aa = out[6]
                assert bool(np.asarray(ok).all())
            keys_l.append(kk)
            vals_l.append(vv)
            sizes.append(int(sz))
            hints.append(int(jnp.min(kk)))
            aux_l.append(aa)
        res = (jnp.stack(keys_l), jnp.stack(vals_l),
               jnp.asarray(sizes, jnp.int32), jnp.asarray(hints, jnp.int32))
        return res + ((jnp.stack(aux_l),) if spl else ())

    def _occ_of(self, q):
        return jnp.sum(q[2]) if self.relaxed else q[2]

    def _round(self, qstate, acc, tel: bool = False, sp=None, births=None):
        body = self._round_relaxed if self.relaxed else self._round_strict
        return body(*qstate, acc, tel=tel, sp=sp, births=births)

    # -- one priority mesh round, relaxed ordering --------------------------
    def _round_relaxed(self, keys, vals, sizes, hints, acc,
                       tel: bool = False, sp=None, births=None):
        """claim (no collective: hint-ordered schedule over replicated
        sizes/hints) → masked pop wave on the local heap → step →
        publish (ONE psum) → masked insert of this shard's sprayed share.
        The popped-key extrema ride the publish psum as widened meta
        words (``pop_meta``), so the one-collective-per-round invariant
        holds with telemetry on.  With ``sp`` the per-shard births plane
        rides the local heap as a rider value plane (DESIGN.md § 7.6).
        The legacy trace tuple trails the standardized 8-tuple."""
        sps = sp is not None
        spl = self.split
        me = jax.lax.axis_index(self.axis)
        counts = priority_claim_schedule(jnp.sum(sizes), self.shards,
                                         self.batch, hints, sizes)
        if sps or spl:
            # the rider plane carries birth stamps (spans) or the split
            # aux words — mutually exclusive by construction
            keys, vals, size, outk, outv, ok, births, bout = heap_pop_count(
                keys, vals, sizes[me], counts[me], batch=self.batch,
                cap_log2=self.capacity_log2, arity_log2=self.arity_log2,
                rider=births)
        else:
            keys, vals, size, outk, outv, ok = heap_pop_count(
                keys, vals, sizes[me], counts[me], batch=self.batch,
                cap_log2=self.capacity_log2, arity_log2=self.arity_log2)
        if spl:
            acc, ckeys, cvals, caux, cmask = self.step_fn(
                acc, outk, outv, bout, ok)
            caf = caux.reshape(-1).astype(jnp.int32)
        else:
            acc, ckeys, cvals, cmask = self.step_fn(acc, outk, outv, ok)
            caf = None
        cm = jnp.broadcast_to(cmask.astype(bool), ckeys.shape).reshape(-1)
        ckf = ckeys.reshape(-1).astype(jnp.int32)
        cvf = cvals.reshape(-1).astype(jnp.int32)
        # local popped-key extrema (telemetry rides the publish psum)
        pop_meta = masked_min_max(outk, ok) if tel else None
        # dense-wave rule (DESIGN.md § 4.4): the relaxed install bound is
        # shards·capacity — any round spawning more must overflow some
        # shard's heap, where both paths install nothing
        wdth = compact_width(ckf.shape[0], self.shards * self.capacity,
                             self.compact)
        if wdth is None:
            res = dist_priority_publish_round(
                ckf, cvf, cm.astype(jnp.int32), jnp.min(keys), size,
                self.axis, pop_meta=pop_meta, aux=caf)
        else:
            res = dist_priority_publish_compact_round(
                ckf, cvf, cm.astype(jnp.int32), jnp.min(keys), size,
                self.axis, width=wdth, pop_meta=pop_meta, aux=caf)
        gk, gv = res[0], res[1]
        i = 2
        if spl:
            gaux = res[i]
            i += 1
        gactive, ranks, total, hints_pop, sizes_pop = res[i:i + 5]
        i += 5
        if tel:
            pop_mins, pop_maxs = res[i], res[i + 1]
        shard_of = jnp.where(gactive, ranks % self.shards, self.shards)
        if wdth is None:
            assigned = (jnp.zeros((self.shards + 1,), jnp.int32)
                        .at[shard_of].add(1))[:self.shards]
        else:
            # ranks are the round-robin prefix 0..total-1, so the
            # scatter-add has the closed form total//n + (s < total%n) —
            # computed from the TRUE total, it stays exact even when a
            # compact block clamped lanes (only possible when over)
            s_ix = jnp.arange(self.shards, dtype=jnp.int32)
            assigned = (total // self.shards
                        + (s_ix < total % self.shards).astype(jnp.int32))
        over = jnp.any(sizes_pop + assigned > self.capacity)
        mine = gactive & (shard_of == me) & ~over
        if sps or spl:
            keys, vals, size, _, _, _, births, _ = heap_insert_masked(
                keys, vals, size, gk, gv, mine,
                cap_log2=self.capacity_log2, arity_log2=self.arity_log2,
                rider=births, oprider=gaux if spl else sp.round)
        else:
            keys, vals, size, _, _, _ = heap_insert_masked(
                keys, vals, size, gk, gv, mine,
                cap_log2=self.capacity_log2, arity_log2=self.arity_log2)
        ckmin = (jnp.full((self.shards + 1,), HEAP_KEY_INF, jnp.int32)
                 .at[shard_of].min(jnp.where(gactive, gk, HEAP_KEY_INF))
                 )[:self.shards]
        hints = jnp.where(over, hints_pop, jnp.minimum(hints_pop, ckmin))
        sizes = jnp.where(over, sizes_pop, sizes_pop + assigned)
        total = jnp.where(over, 0, total)
        telinfo = None
        if tel:
            telinfo = (counts, jnp.where(over, 0, assigned), sizes,
                       jnp.min(pop_mins), jnp.max(pop_maxs))
        if sps:
            cls = self._span_cls(outk, jnp.full_like(outk, me))
            sp = span_record(sp, cls, sp.round - bout, ok, outv)
            sp = span_tick(sp)
        trace = (outk, outv, ok, gk, gv, gactive)
        return ((keys, vals, sizes, hints), acc, jnp.sum(counts), total,
                over, telinfo, sp, births, trace)

    # -- one priority mesh round, strict ordering ---------------------------
    def _round_strict(self, keys, vals, size, acc, tel: bool = False,
                      sp=None, births=None):
        """Every shard applies the identical full-width pop wave to the
        replicated heap (exact global min-key order), steps only its
        ``claim_schedule`` slice, and installs ALL gathered children —
        the planes stay replicated by construction.  The pop wave is
        replicated full-width, so telemetry extrema are free.  With
        ``sp`` every shard computes identical pops/inserts but records
        only its own slice into its sharded SpanPlane, so the host-side
        shard merge counts each task once (DESIGN.md § 7.6).  The legacy
        trace tuple trails the standardized 8-tuple."""
        sps = sp is not None
        spl = self.split
        me = jax.lax.axis_index(self.axis)
        sb = self.shards * self.batch
        k = jnp.minimum(size, jnp.int32(sb))
        if sps or spl:
            keys, vals, size, outk, outv, _, births, outb = heap_pop_count(
                keys, vals, size, k, batch=sb,
                cap_log2=self.capacity_log2, arity_log2=self.arity_log2,
                rider=births)
        else:
            keys, vals, size, outk, outv, _ = heap_pop_count(
                keys, vals, size, k, batch=sb,
                cap_log2=self.capacity_log2, arity_log2=self.arity_log2)
        active, ranks = claim_schedule(k, self.shards, self.batch)
        act_l = active.reshape(self.shards, self.batch)[me]
        rk_l = ranks.reshape(self.shards, self.batch)[me]
        outk_l = jnp.where(act_l, outk[rk_l], HEAP_KEY_INF)
        outv_l = jnp.where(act_l, outv[rk_l], -1)
        if spl:
            outa_l = jnp.where(act_l, outb[rk_l], 0)
            acc, ckeys, cvals, caux, cmask = self.step_fn(
                acc, outk_l, outv_l, outa_l, act_l)
            caf = caux.reshape(-1).astype(jnp.int32)
        else:
            acc, ckeys, cvals, cmask = self.step_fn(acc, outk_l, outv_l,
                                                    act_l)
            caf = None
        cm = jnp.broadcast_to(cmask.astype(bool), ckeys.shape).reshape(-1)
        ckf = ckeys.reshape(-1).astype(jnp.int32)
        cvf = cvals.reshape(-1).astype(jnp.int32)
        # dense-wave rule (DESIGN.md § 4.4): the strict install bound is
        # the replicated heap's capacity
        wdth = compact_width(ckf.shape[0], self.capacity, self.compact)
        if wdth is None:
            res = dist_priority_publish_round(
                ckf, cvf, cm.astype(jnp.int32), jnp.min(keys), size,
                self.axis, aux=caf)
        else:
            res = dist_priority_publish_compact_round(
                ckf, cvf, cm.astype(jnp.int32), jnp.min(keys), size,
                self.axis, width=wdth, aux=caf)
        gk, gv = res[0], res[1]
        i = 2
        if spl:
            gaux = res[i]
            i += 1
        gactive, total = res[i], res[i + 2]
        over = (size + total) > jnp.int32(self.capacity)
        ins = gactive & ~over
        if sps or spl:
            keys, vals, size, _, _, _, births, _ = heap_insert_masked(
                keys, vals, size, gk, gv, ins,
                cap_log2=self.capacity_log2, arity_log2=self.arity_log2,
                rider=births, oprider=gaux if spl else sp.round)
        else:
            keys, vals, size, _, _, _ = heap_insert_masked(
                keys, vals, size, gk, gv, ins,
                cap_log2=self.capacity_log2, arity_log2=self.arity_log2)
        total = jnp.where(over, 0, total)
        telinfo = None
        if tel:
            pops = active.reshape(self.shards, self.batch).sum(
                1, dtype=jnp.int32)
            pushes = (gactive & ~over).reshape(self.shards, -1).sum(
                1, dtype=jnp.int32)         # children by generating shard
            lane = jnp.arange(sb, dtype=jnp.int32)
            mn, mx = masked_min_max(outk, lane < k)
            telinfo = (pops, pushes, jnp.broadcast_to(size, (self.shards,)),
                       mn, mx)
        if sps:
            outb_l = jnp.where(act_l, outb[rk_l], 0)
            cls = self._span_cls(outk_l, jnp.full_like(outk_l, me))
            sp = span_record(sp, cls, sp.round - outb_l, act_l, outv_l)
            sp = span_tick(sp)
        trace = (outk_l, outv_l, act_l, gk, gv, gactive)
        return (DistHeapState(keys, vals, size), acc, k, total, over,
                telinfo, sp, births, trace)

    def _broadcast_acc(self, acc):
        acc = jax.tree_util.tree_map(jnp.asarray, acc)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.shards,) + x.shape),
            acc)

    # -- shard_map boundary, shared by fused and legacy ---------------------
    def _unstack_round_io(self, qstate, births):
        if self.relaxed:
            k, v, sz, h = qstate
            qstate = (k[0], v[0], sz, h)
            if births is not None:
                births = births[0]
        return qstate, births

    def _restack_round_io(self, qstate, births):
        if self.relaxed:
            qstate = (qstate[0][None], qstate[1][None], qstate[2], qstate[3])
            if births is not None:
                births = births[None]
        return qstate, births


class MeshHeapEngine(_PriorityMeshBase):
    """The priority mesh megaround loop: one jitted shard_map call runs
    the whole claim → pop-min → step → push cycle for up to ``limit``
    rounds with the heap planes (per-shard in relaxed mode, replicated in
    strict mode) as loop-carried device state; the host syncs once at
    global quiescence (or every ``sync_every`` rounds).  ``run`` mirrors
    ``HeapEngine.run``: bit-deterministic, raises ``RuntimeError`` on
    heap overflow or ``max_rounds`` truncation at the next sync, and
    returns (acc, final ``DistHeapState``) — acc carries a leading shard
    axis unless ``combine`` reduces it; relaxed-mode final planes are
    stacked ``(shards, cap)``."""

    def __init__(self, step_fn: PriorityStepFn, *, mesh, axis: str = "data",
                 capacity_log2: int = 10, batch: int = 64,
                 arity_log2: int = 2, relaxed: bool = True,
                 sync_every: int = 0,
                 combine: Callable[[Any], Any] = None,
                 telemetry: Optional[Telemetry] = None,
                 spans: Optional[Spans] = None, compact=None,
                 split: bool = False) -> None:
        super().__init__(step_fn, mesh=mesh, axis=axis,
                         capacity_log2=capacity_log2, batch=batch,
                         arity_log2=arity_log2, relaxed=relaxed,
                         sync_every=sync_every, combine=combine,
                         telemetry=telemetry, spans=spans, compact=compact,
                         split=split)
        cap = self.capacity
        reg = self.registry
        # TracePlane rides replicated; the SpanPlane is sharded (each
        # shard records its own pops); the births plane matches its heap —
        # per-shard (sharded) in relaxed mode, replicated in strict mode.
        # Split mode reuses the births slot for the aux rider plane (same
        # shapes and specs).
        if relaxed:
            reg.register("heap",
                         (_sds((self.shards, cap)),) * 2, sharded=True)
            reg.register("sched", (_sds((self.shards,)),) * 2)
            self._register_obs_planes(
                self.shards, stacked=True,
                births_shape=(self.shards, cap), births_sharded=True)
            if split:
                reg.register("births", _sds((self.shards, cap)),
                             sharded=True)
            qspec = ((reg.spec("heap"),) * 2 + (reg.spec("sched"),) * 2)
        else:
            reg.register("heap", (_sds((cap,)), _sds((cap,)), _sds(())))
            self._register_obs_planes(self.shards, stacked=True,
                                      births_shape=(cap,))
            if split:
                reg.register("births", _sds((cap,)))
            qspec = reg.spec("heap")
        obs = (reg.spec("trace"), reg.spec("span"), reg.spec("births"))
        in_specs = (qspec, P(self.axis), P(), P(), P(), P()) + obs
        out_specs = (qspec, P(self.axis), P(), P(), P(), P(), P()) + obs
        self._megaround = jax.jit(shard_map(
            self._megaround_impl, mesh=self.mesh,
            in_specs=in_specs, out_specs=out_specs,
            check_rep=False))   # while_loop has no replication rule

    def _megaround_impl(self, qstate, acc, processed, spawned, max_occ,
                        limit, tp=None, sp=None, births=None):
        qstate, births = self._unstack_round_io(qstate, births)
        acc = _unstack(acc)
        sps = sp is not None
        if sps:   # sharded SpanPlane arrives stacked per shard
            sp = _unstack(sp)
        out = super()._megaround_impl(qstate, acc, processed, spawned,
                                      max_occ, limit, tp, sp, births)
        qstate, births_out = self._restack_round_io(out[0], out[9])
        sp_out = _restack(out[8]) if sps else out[8]
        return (qstate, _restack(out[1])) + out[2:8] + (sp_out, births_out)

    def run(self, initial_keys: np.ndarray, initial_vals: np.ndarray,
            acc: Any = None, max_rounds: int = 10_000,
            initial_aux: np.ndarray = None) -> Tuple[Any, DistHeapState]:
        """Seed the heap planes (relaxed: round-robin spray by seed rank;
        strict: one replicated heap) and run priority megarounds to
        global quiescence.  Sync contract: one host block per
        ``sync_every`` chunk (once total when 0); one psum per round on
        device.  Determinism: bit-identical to the legacy per-round
        path.  Raises ``RuntimeError`` on heap overflow or truncation at
        the next sync.  In split mode ``initial_aux`` seeds the per-item
        aux words (zeros when None)."""
        self._reset()
        ik = np.asarray(initial_keys, np.int32).reshape(-1)
        iv = np.asarray(initial_vals, np.int32).reshape(-1)
        assert ik.shape == iv.shape
        spl = self.split
        if spl:
            ia = (np.zeros_like(ik) if initial_aux is None
                  else np.asarray(initial_aux, np.int32).reshape(-1))
            assert ia.shape == ik.shape
        else:
            ia = None
        acc = self._broadcast_acc(acc)
        seeded = self._seed(ik, iv, ia)
        if self.relaxed:
            qstate = seeded[:4]
            occ0 = jnp.int32(int(np.asarray(qstate[2]).sum()))
            births0 = (seeded[4] if spl
                       else self._births_init((self.shards, self.capacity)))
        else:
            qstate = DistHeapState(*seeded[:3])
            occ0 = jnp.asarray(qstate.size, jnp.int32)
            births0 = (seeded[3] if spl
                       else self._births_init((self.capacity,)))
        state = [qstate, acc, jnp.int32(0), jnp.int32(0), occ0]
        ext = [self._tel_init(self.shards),
               self._span_init(self.shards, stacked=True), births0]

        def occ_fn(q):
            return (int(np.asarray(q[2]).sum()) if self.relaxed
                    else int(np.asarray(q[2])))

        self._run_chunks(state, ext, occ_fn, "mesh heap", max_rounds)
        q = state[0]
        final = DistHeapState(q[0], q[1], q[2])
        acc = state[1]
        if self.combine is not None:
            acc = self.combine(acc)
        return acc, final


class PriorityMeshRoundRunner(_PriorityMeshBase):
    """Mesh twin of ``PriorityRoundRunner``: ``fused=True`` (default)
    delegates to ``MeshHeapEngine`` (host sync only at global
    quiescence); ``fused=False`` keeps the legacy host-driven loop — one
    jitted shard_map dispatch and one occupancy readback per round — for
    step-debug, as the parity baseline, and as the history recorder
    (``trace=True``, legacy only: per round the popped (key, val, ok)
    batches per shard and the gathered published children, the raw
    material for ``sched.plinearizability`` checking).  Both engines are
    bit-identical: same acc leaves, same heap planes, same sizes/hints
    and stats counters."""

    def __init__(self, step_fn: PriorityStepFn, *, mesh, axis: str = "data",
                 capacity_log2: int = 10, batch: int = 64,
                 arity_log2: int = 2, relaxed: bool = True,
                 fused: bool = True, sync_every: int = 0,
                 combine: Callable[[Any], Any] = None,
                 trace: bool = False,
                 telemetry: Optional[Telemetry] = None,
                 spans: Optional[Spans] = None, compact=None,
                 split: bool = False) -> None:
        super().__init__(step_fn, mesh=mesh, axis=axis,
                         capacity_log2=capacity_log2, batch=batch,
                         arity_log2=arity_log2, relaxed=relaxed,
                         sync_every=sync_every, combine=combine,
                         telemetry=telemetry, spans=spans, compact=compact,
                         split=split)
        self.fused = fused
        if trace and fused:
            raise ValueError("trace recording needs the per-round host "
                             "boundary: use fused=False")
        if spans is not None and not fused:
            raise ValueError(
                "span planes are in-loop state: spans needs the fused "
                "engine (fused=True)")
        self.trace_enabled = trace
        self.trace = []
        if fused:
            self._engine = MeshHeapEngine(
                step_fn, mesh=mesh, axis=axis, capacity_log2=capacity_log2,
                batch=batch, arity_log2=arity_log2, relaxed=relaxed,
                sync_every=sync_every, combine=combine, telemetry=telemetry,
                spans=spans, compact=compact, split=split)
            return
        self._engine = None
        sp = P(self.axis)
        hp = sp if relaxed else P()
        qspec = (hp, hp, P(), P()) if relaxed else P()
        bspec = hp if (split and relaxed) else P()
        in_specs = (qspec, bspec, sp)
        out_core = (qspec, bspec, sp, P(), P(), P())
        # trace arrays ride in the jit outputs only when recording — the
        # untraced legacy baseline must not pay per-round materialization
        # the fused engine never pays
        out_specs = out_core + ((sp, sp, sp, P(), P(), P())
                                if trace else ())
        self._round_jit = jax.jit(shard_map(
            self._legacy_round, mesh=self.mesh, in_specs=in_specs,
            out_specs=out_specs, check_rep=False))

    def _legacy_round(self, qstate, births, acc):
        qstate, births = self._unstack_round_io(qstate, births)
        acc = _unstack(acc)
        r = self._round(qstate, acc, births=births)
        qstate, acc, k, total, over, _, _, births = r[:8]
        qstate, births = self._restack_round_io(qstate, births)
        out = (qstate, births, _restack(acc), k, total, over)
        if self.trace_enabled:
            outk, outv, ok, gk, gv, gactive = r[8]
            out = out + (outk[None], outv[None], ok[None], gk, gv, gactive)
        return out

    def loop_carry_bytes(self, shards: int = None) -> int:
        if self._engine is not None:
            return self._engine.loop_carry_bytes(shards)
        return super().loop_carry_bytes(shards)

    def run(self, initial_keys: np.ndarray, initial_vals: np.ndarray,
            acc: Any = None, max_rounds: int = 10_000,
            initial_aux: np.ndarray = None) -> Tuple[Any, DistHeapState]:
        """Run to quiescence on the selected engine.  ``fused=True``:
        ``MeshHeapEngine.run`` contract (host sync only at quiescence /
        ``sync_every``); ``fused=False``: one dispatch and one occupancy
        readback per round (``host_syncs == rounds``), appending
        per-round pop/push records to ``self.trace`` when ``trace=True``.
        Both bit-deterministic and identical to each other; both raise on
        overflow/truncation.  In split mode ``initial_aux`` seeds the
        per-item aux words (zeros when None)."""
        if self._engine is not None:
            try:
                return self._engine.run(initial_keys, initial_vals, acc,
                                        max_rounds,
                                        initial_aux=initial_aux)
            finally:
                self.stats = dict(self._engine.stats, fused=1)
                self.sync_log = self._engine.sync_log
        self._reset()
        self.trace = []
        ik = np.asarray(initial_keys, np.int32).reshape(-1)
        iv = np.asarray(initial_vals, np.int32).reshape(-1)
        assert ik.shape == iv.shape
        spl = self.split
        if spl:
            ia = (np.zeros_like(ik) if initial_aux is None
                  else np.asarray(initial_aux, np.int32).reshape(-1))
            assert ia.shape == ik.shape
        else:
            ia = None
        acc = self._broadcast_acc(acc)
        seeded = self._seed(ik, iv, ia)
        if self.relaxed:
            qstate = seeded[:4]
            births = seeded[4] if spl else None
            occ0 = int(np.asarray(qstate[2]).sum())
        else:
            qstate = DistHeapState(*seeded[:3])
            births = seeded[3] if spl else None
            occ0 = int(np.asarray(qstate.size))

        def round_call(st, acc):
            out = self._round_jit(st[0], st[1], acc)
            q, b, acc, k, total, over = out[:6]
            return ((q, b), acc, k, total, over,
                    out[6:] if self.trace_enabled else None)

        def occ_fn(st):
            return (int(np.asarray(st[0][2]).sum()) if self.relaxed
                    else int(np.asarray(st[0][2])))

        def on_round(tr):
            if tr is None:
                return
            outk, outv, ok, gk, gv, gactive = tr
            self.trace.append({
                "pops": (np.asarray(outk), np.asarray(outv),
                         np.asarray(ok)),
                "pushes": (np.asarray(gk), np.asarray(gv),
                           np.asarray(gactive)),
            })

        st, acc = self._legacy_loop(
            (qstate, births), acc, round_call, occ0, occ_fn,
            "mesh heap", max_rounds, on_round=on_round)
        q = st[0]
        final = DistHeapState(q[0], q[1], q[2])
        if self.combine is not None:
            acc = self.combine(acc)
        return acc, final


@deprecated_engine("MeshRingEngine")
class FusedMeshRounds(MeshRingEngine):
    """Deprecated alias for ``MeshRingEngine`` (the replicated FIFO mesh
    megaround as an ``enginecore`` configuration)."""


@deprecated_engine("MeshHeapEngine")
class FusedPriorityMeshRounds(MeshHeapEngine):
    """Deprecated alias for ``MeshHeapEngine`` (the priority mesh
    megaround as an ``enginecore`` configuration)."""


# engine-matrix rows (tests/conftest.py parametrizes over these)
register_engine("mesh", MeshRoundRunner, priority=False, mesh=True)
register_engine("mesh-sharded", MeshRoundRunner, priority=False, mesh=True,
                kwargs={"sharded": True}, spans_ok=False)
register_engine("pmesh-relaxed", PriorityMeshRoundRunner, priority=True,
                mesh=True, kwargs={"relaxed": True})
register_engine("pmesh-strict", PriorityMeshRoundRunner, priority=True,
                mesh=True, kwargs={"relaxed": False})
