"""Production meshes.

Functions (never module-level constants) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to build these on CPU.
"""

from __future__ import annotations

import jax

from ..jaxcompat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips over ("data", "model").
    Multi-pod: 2×16×16 = 512 chips over ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Tiny mesh over the locally visible devices (tests / examples)."""
    n = jax.device_count()
    data = max(n // model, 1)
    return make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (pod+data when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s
