"""Mamba2 — SSD (state-space duality) layer, chunked matmul form.

The SSD algorithm splits the sequence into chunks: within a chunk the
recurrence is computed in its quadratic "attention-like" matmul form (MXU
friendly), and chunk boundary states are propagated with a short scan —
O(S·state) work with matmul arithmetic intensity, which is the TPU-native
reading of the paper's duality.

Decode keeps O(1) state per layer: (conv_state (B, d_conv-1, d_conv_in),
ssm_state (B, nh, hd, state)).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..jaxcompat import current_mesh
from .layers import _dense, _pin, rms_norm

CHUNK = 256

U = P.UNCONSTRAINED


def _ssd_axis(nh: int, ck: int):
    """Shard axis for the per-chunk SSD tensors: prefer the head dim (zamba:
    112 % 16 == 0), else the intra-chunk time dim (mamba2: nh=24 does not
    divide) — without a pin the (b, ck, ck, nh) decay/gate chain is fully
    replicated per device (§Perf: 6% of zamba-train bytes per tensor)."""
    mesh = current_mesh()
    model = (mesh.shape.get("model", 1)
             if mesh is not None and mesh.axis_names else 1)
    if model <= 1:
        return None
    if nh % model == 0:
        return "head"
    if ck % model == 0:
        return "time"
    return None


def ssm_params(key, cfg: ArchConfig) -> Dict:
    d, di, st, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    conv_in = di + 2 * st  # x, B, C share the conv (n_groups = 1)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _dense(ks[0], (d, 2 * di + 2 * st + nh)),
        "conv_w": _dense(ks[1], (cfg.ssm_conv, conv_in)),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "ssm_norm": jnp.zeros((di,), jnp.bfloat16),
        "out_proj": _dense(ks[2], (di, d)),
    }


def ssm_specs(cfg: ArchConfig, fsdp_axis=None):
    f = fsdp_axis
    return {
        "in_proj": P(f, "model"),
        "conv_w": P(None, "model"),
        "A_log": P(None), "D": P(None), "dt_bias": P(None),
        "ssm_norm": P("model"),
        "out_proj": P("model", f),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * st]
    dt = zxbcdt[..., di + di + 2 * st:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv.  xbc (B, S, C), w (K, C).
    Returns (out, new_state (B, K-1, C))."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, A, B, C, init_state):
    """Chunked SSD.  x (b, s, nh, hd); dt (b, s, nh); A (nh,);
    B, C (b, s, st); init_state (b, nh, hd, st).
    Returns (y (b, s, nh, hd), final_state).

    One lax.scan over chunks: the intra-chunk quadratic (matmul) form uses
    O(b·ck²·nh) transient memory for a single chunk only, and the
    inter-chunk state recurrence rides the same scan carry."""
    b, s, nh, hd = x.shape
    st = B.shape[-1]
    ck = min(CHUNK, s)
    nc = s // ck
    negA = -jnp.exp(A)                                       # (nh,) < 0
    xc = jnp.moveaxis(x.reshape(b, nc, ck, nh, hd), 1, 0)    # (nc,b,ck,nh,hd)
    dtc = jnp.moveaxis(dt.reshape(b, nc, ck, nh), 1, 0)
    Bc = jnp.moveaxis(B.reshape(b, nc, ck, st), 1, 0)
    Cc = jnp.moveaxis(C.reshape(b, nc, ck, st), 1, 0)
    mask = jnp.tril(jnp.ones((ck, ck), bool))

    ssd_ax = _ssd_axis(nh, ck)

    def chunk_body(h, inp):
        xk, dk, Bk, Ck = inp              # (b,ck,nh,hd) (b,ck,nh) (b,ck,st) ×2
        if ssd_ax == "head":
            xk = _pin(xk, P(U, None, "model", U))
            dk = _pin(dk, P(U, None, "model"))
        dA = dk * negA[None, None, :]                        # (b,ck,nh) ≤ 0
        seg = jnp.cumsum(dA, axis=1)                         # (b,ck,nh)
        # intra-chunk:  y[t] = Σ_{u≤t} C_t·B_u exp(seg_t-seg_u) dt_u x_u
        gate = seg[:, :, None, :] - seg[:, None, :, :]       # (b,t,u,nh)
        gate = jnp.where(mask[None, :, :, None], gate, -jnp.inf)
        if ssd_ax == "head":
            gate = _pin(gate, P(U, None, None, "model"))
        elif ssd_ax == "time":
            gate = _pin(gate, P(U, "model", None, None))
        cb = jnp.einsum("bts,bus->btu", Ck, Bk)              # (b,t,u)
        w = cb[..., None] * jnp.exp(gate)                    # (b,t,u,nh)
        y_intra = jnp.einsum("btuh,buh,buhd->bthd",
                             w.astype(xk.dtype), dk.astype(xk.dtype), xk)
        # inter-chunk:  y[t] += exp(seg_t) · C_t · h_in
        y_inter = jnp.einsum("bts,bhds,bth->bthd",
                             Ck.astype(jnp.float32), h,
                             jnp.exp(seg).astype(jnp.float32)).astype(xk.dtype)
        # state update: h' = exp(seg_last)·h + Σ_u exp(seg_last-seg_u) dt_u B_u x_u
        decay_last = jnp.exp(seg[:, -1:, :] - seg)           # (b,ck,nh)
        contrib = jnp.einsum("buh,buh,buhd,bus->bhds",
                             decay_last.astype(jnp.float32),
                             dk.astype(jnp.float32),
                             xk.astype(jnp.float32),
                             Bk.astype(jnp.float32))
        h = h * jnp.exp(jnp.sum(dA, axis=1))[:, :, None, None] + contrib
        return h, y_intra + y_inter

    h_final, ys = jax.lax.scan(chunk_body, init_state.astype(jnp.float32),
                               (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, nh, hd)
    return y, h_final


def ssm_forward(p: Dict, x: jax.Array, cfg: ArchConfig, *,
                state: Optional[Tuple] = None):
    """x (B, S, d).  state = (conv_state, ssm_state) for decode.
    Returns (out (B, S, d), new_state)."""
    b, s, d = x.shape
    di, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    conv_state = state[0] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], conv_state)
    xs = xbc[..., :di].reshape(b, s, nh, hd)
    B = xbc[..., di:di + st]
    C = xbc[..., di + st:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])      # (b, s, nh)
    init = (state[1] if state is not None
            else jnp.zeros((b, nh, hd, st), jnp.float32))
    if s == 1:
        # decode: single recurrence step
        dA = jnp.exp(dt[:, 0, :] * (-jnp.exp(p["A_log"]))[None, :])  # (b,nh)
        h = init * dA[:, :, None, None] + jnp.einsum(
            "bh,bhd,bs->bhds", dt[:, 0].astype(jnp.float32),
            xs[:, 0].astype(jnp.float32), B[:, 0].astype(jnp.float32))
        y = jnp.einsum("bs,bhds->bhd", C[:, 0].astype(jnp.float32),
                       h).astype(x.dtype).reshape(b, 1, nh, hd)
        final = h
    else:
        y, final = ssd_chunked(xs, dt, p["A_log"], B, C, init)
    y = y + xs * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"])
    out = y @ p["out_proj"]
    new_state = (new_conv, final)
    return out, new_state
