"""Device-resident serving admission (DESIGN.md § 5.5): EDF admission as
priority mesh megarounds.

``ServingMeshEngine`` is a tick-driven configuration of the § 6 relaxed
``MeshHeapEngine``: pending generation requests live *device-resident* as
``(deadline-key | payload)`` heap entries in the per-shard priority
planes, and one serving tick is one megaround call — claim → pop-min →
admission step → publish — that pops requests in (locally exact, mesh
k-relaxed) EDF order and admits the maximal deadline-ordered prefix that
fits the tick's slot and KV-page budgets.  The admission decision *is*
the engine's ``PriorityStepFn``:

* pops arrive per shard in ascending key order (``heap_pop_count`` pops
  the local minimum repeatedly), so prefix-fit = stop-at-first-stall,
  exactly the host pool's ``_try_admit`` contract;
* a request that does not fit is republished as a *child* at its
  ORIGINAL deadline key — the paper's enqueue-wave re-entry — so it ages
  toward urgency while newer arrivals take later keys (the § 5.5
  guarantee the host path already provides);
* any republication marks the (replicated) ``stalled`` flag; the fused
  loop's ``_extra_cond`` hook exits the megaround at the end of that
  round, ending the tick.  Between ticks the heap planes stay resident
  on device; the host only inserts new arrivals, refreshes budgets, and
  reads back the admitted index log.

Payload packing: ``val = retry · table + idx`` where ``idx`` names the
host-side request-table row and ``retry`` counts re-entries, so every
heap residence of a request is a *unique* ident — required by
``sched.plinearizability.mesh_trace_history``'s differentiated-history
scheme, and what lets ``pop_history()`` feed ``check_p_linearizable``
within the declared ``sched.relaxed.mesh_relaxation_bound`` envelope.

Budgets (slots and pages) partition per shard, remainder to low shards:
at one shard admission is *exact* EDF (bit-agreement with the host pool
asserted in tests); at S > 1 shards the admitted set may legitimately
relax within the mesh envelope, like every other relaxed pop.

Deadline keys are capped at ``DEADLINE_KEY_CAP`` (= the packed span
stamp's 2^30 round-clock cap, ``kernels.ring_slots.SPAN_ROUND_CAP``):
a key at or past the cap raises ``ValueError`` at stamp time — silent
wraparound would invert EDF order (PR 9's cap contract, asserted in
``tests/test_serving_admission.py``).
"""

from __future__ import annotations

import time
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.heap_batch import (KEY_INF as HEAP_KEY_INF,
                                  heap_insert_masked)
from ..kernels.ring_slots import SPAN_ROUND_CAP
from ..obs.trace import SyncPoint
from ..runtime.enginecore import register_engine
from ..runtime.meshrounds import MeshHeapEngine

__all__ = ["DEADLINE_KEY_CAP", "ServingMeshEngine"]

# deadline keys share the packed birth-stamp round clock's cap: one
# stamp-time contract for every monotone clock in the system
DEADLINE_KEY_CAP = SPAN_ROUND_CAP


def _check_deadline_keys(keys: np.ndarray) -> None:
    if keys.size == 0:
        return
    lo, hi = int(keys.min()), int(keys.max())
    if lo < 0 or hi >= DEADLINE_KEY_CAP:
        raise ValueError(
            f"deadline key {hi if hi >= DEADLINE_KEY_CAP else lo} outside "
            f"[0, {DEADLINE_KEY_CAP}): keys past the 2^30 round-clock cap "
            f"would wrap and silently invert EDF order — rebase the "
            f"deadline clock (PR 9 stamp-time cap contract)")


class ServingMeshEngine(MeshHeapEngine):
    """Tick-driven EDF admission on the relaxed priority mesh.

    Unlike the drain-to-quiescence engines, serving state is *persistent*:
    ``tick(new_keys, new_idxs, need=, slots=, pages=)`` installs the
    tick's arrivals into the device heap planes, runs ONE megaround call
    (exiting at quiescence or at the first admission stall via the
    ``_extra_cond`` hook), and returns the admitted request indices in
    admission order.  Page-stalled requests remain heap-resident at their
    original deadline key and compete again next tick.

    ``acc`` protocol (all leaves ride the per-shard ``P(axis)`` spec):
    ``need`` (table,) pages-per-request lookup; ``slots``/``pages``
    scalar per-shard budgets; ``adm_idx``/``adm_n`` the admitted log;
    ``stalled`` the replicated loop-exit flag; ``round`` the global round
    clock; optional ``plk``/``plv``/``plr``/``pln`` pop-log planes
    (``pop_log`` > 0) recording every pop for the p-linearizability
    checker."""

    def __init__(self, *, mesh, axis: str = "data",
                 capacity_log2: int = 8, batch: int = 16,
                 arity_log2: int = 2, table_log2: int = 8,
                 pop_log: int = 0, sync_every: int = 0,
                 combine=None, telemetry=None, spans=None,
                 compact=None) -> None:
        self.table = 1 << table_log2
        self.pop_log = int(pop_log)
        super().__init__(self._admission_step, mesh=mesh, axis=axis,
                         capacity_log2=capacity_log2, batch=batch,
                         arity_log2=arity_log2, relaxed=True,
                         sync_every=sync_every, combine=combine,
                         telemetry=telemetry, spans=spans, compact=compact)
        self._state = None          # [qstate, acc, processed, spawned, mx]
        self._ext = None            # [tp, sp, births]
        self._spray = 0             # round-robin insert pointer (persistent)
        self._rounds = 0
        self._host_syncs = 0
        self.admitted_log: List[int] = []

    # -- the admission decision as a PriorityStepFn --------------------------

    def _admission_step(self, acc, keys, vals, valid):
        """Admit the maximal deadline-ordered prefix of this pop wave that
        fits the remaining slot/page budget; republish the rest at their
        original keys with a bumped retry ident."""
        T = jnp.int32(self.table)
        idx = jnp.where(valid, vals % T, 0)
        need = acc["need"][idx]
        lane = jnp.arange(keys.shape[0], dtype=jnp.int32)
        nvalid = jnp.cumsum(valid.astype(jnp.int32))
        pcum = jnp.cumsum(jnp.where(valid, need, 0))
        fits = valid & (pcum <= acc["pages"]) & (nvalid <= acc["slots"])
        # stop at first stall: admission is a deadline-ordered *prefix*,
        # so a request can only be jumped by an earlier deadline
        bad = valid & ~fits
        first_bad = jnp.min(jnp.where(bad, lane, jnp.int32(keys.shape[0])))
        admit = valid & (lane < first_bad)
        rep = valid & ~admit
        acc = dict(acc)
        acc["pages"] = acc["pages"] - jnp.sum(jnp.where(admit, need, 0))
        acc["slots"] = acc["slots"] - jnp.sum(admit.astype(jnp.int32))
        apos = acc["adm_n"] + jnp.cumsum(admit.astype(jnp.int32)) - 1
        apos = jnp.where(admit, apos, jnp.int32(self.table))
        acc["adm_idx"] = acc["adm_idx"].at[apos].set(idx, mode="drop")
        acc["adm_n"] = acc["adm_n"] + jnp.sum(admit.astype(jnp.int32))
        if self.pop_log:
            ppos = acc["pln"] + nvalid - 1
            ppos = jnp.where(valid, ppos, jnp.int32(self.pop_log))
            acc["plk"] = acc["plk"].at[ppos].set(keys, mode="drop")
            acc["plv"] = acc["plv"].at[ppos].set(vals, mode="drop")
            acc["plr"] = acc["plr"].at[ppos].set(
                jnp.broadcast_to(acc["round"], keys.shape), mode="drop")
            acc["pln"] = acc["pln"] + jnp.sum(valid.astype(jnp.int32))
        acc["round"] = acc["round"] + 1
        # re-entry wave: original deadline key, next retry ident
        ck = keys[:, None]
        cv = jnp.where(rep, vals + T, 0)[:, None]
        return acc, ck, cv, rep[:, None]

    # -- stall exit: replicated flag folded after the publish psum -----------

    def _round(self, qstate, acc, tel: bool = False, sp=None, births=None):
        r = super()._round(qstate, acc, tel=tel, sp=sp, births=births)
        acc = dict(r[1])
        # total (the published-children count) is replicated — in this
        # engine every child is a stalled request's re-entry, so the flag
        # stays replicated and all shards exit the loop together
        acc["stalled"] = acc["stalled"] | (r[3] > 0)
        return (r[0], acc) + r[2:]

    def _extra_cond(self, carry):
        return ~carry[1]["stalled"]

    # -- persistent device state ---------------------------------------------

    def _acc_zero(self):
        acc = {
            "need": jnp.zeros((self.table,), jnp.int32),
            "slots": jnp.int32(0), "pages": jnp.int32(0),
            "adm_idx": jnp.zeros((self.table,), jnp.int32),
            "adm_n": jnp.int32(0),
            "stalled": jnp.bool_(False), "round": jnp.int32(0),
        }
        if self.pop_log:
            acc["plk"] = jnp.zeros((self.pop_log,), jnp.int32)
            acc["plv"] = jnp.zeros((self.pop_log,), jnp.int32)
            acc["plr"] = jnp.zeros((self.pop_log,), jnp.int32)
            acc["pln"] = jnp.int32(0)
        return acc

    def begin(self) -> None:
        """(Re)initialize the persistent device planes for a fresh run."""
        self._reset()
        seeded = self._seed(np.zeros(0, np.int32), np.zeros(0, np.int32))
        qstate = seeded[:4]
        self._state = [qstate, self._broadcast_acc(self._acc_zero()),
                       jnp.int32(0), jnp.int32(0), jnp.int32(0)]
        self._ext = [self._tel_init(self.shards),
                     self._span_init(self.shards, stacked=True),
                     self._births_init((self.shards, self.capacity))]
        self._spray = 0
        self._rounds = 0
        self._host_syncs = 0
        self.admitted_log = []
        self.stats = {"rounds": 0, "processed": 0, "spawned": 0,
                      "max_occupancy": 0, "drained": 1, "host_syncs": 0}

    def occupancy(self) -> int:
        if self._state is None:
            return 0
        return int(np.asarray(self._state[0][2]).sum())

    def resident(self) -> List[Tuple[int, int, int]]:
        """Heap-resident ``(key, idx, retry)`` triples (host readback)."""
        if self._state is None:
            return []
        keys = np.asarray(self._state[0][0])
        vals = np.asarray(self._state[0][1])
        out = []
        for s in range(self.shards):
            live = keys[s] != HEAP_KEY_INF
            for k, v in zip(keys[s][live], vals[s][live]):
                out.append((int(k), int(v) % self.table,
                            int(v) // self.table))
        return sorted(out)

    # -- host-side insert into the resident planes ---------------------------

    def _insert(self, ik: np.ndarray, iv: np.ndarray) -> None:
        if len(ik) == 0:
            return
        keys, vals, sizes, hints = self._state[0]
        births = self._ext[2]
        szs = np.asarray(sizes).copy()
        shard_of = (self._spray + np.arange(len(ik))) % self.shards
        self._spray = (self._spray + len(ik)) % self.shards
        keys_l = [keys[s] for s in range(self.shards)]
        vals_l = [vals[s] for s in range(self.shards)]
        births_l = ([births[s] for s in range(self.shards)]
                    if births is not None else None)
        for s in range(self.shards):
            sel = shard_of == s
            c = int(sel.sum())
            if c == 0:
                continue
            if szs[s] + c > self.capacity:
                raise RuntimeError(
                    f"serving heap overflow: {c} arrivals land on shard {s} "
                    f"holding {int(szs[s])} of {self.capacity} (raise "
                    f"capacity_log2 or shed load)")
            rider = births_l[s] if births_l is not None else None
            out = heap_insert_masked(
                keys_l[s], vals_l[s], jnp.int32(int(szs[s])),
                jnp.asarray(ik[sel]), jnp.asarray(iv[sel]),
                jnp.ones((c,), bool), cap_log2=self.capacity_log2,
                arity_log2=self.arity_log2, rider=rider,
                oprider=(jnp.int32(min(self._rounds, self.span_round_cap - 1))
                         if rider is not None else None))
            keys_l[s], vals_l[s] = out[0], out[1]
            szs[s] = int(out[2])
            assert bool(np.asarray(out[5]).all()), "capacity pre-checked"
            if births_l is not None:
                births_l[s] = out[6]
        keys = jnp.stack(keys_l)
        vals = jnp.stack(vals_l)
        hints = jnp.asarray([int(jnp.min(k)) for k in keys_l], jnp.int32)
        self._state[0] = (keys, vals, jnp.asarray(szs, jnp.int32), hints)
        if births_l is not None:
            self._ext[2] = jnp.stack(births_l)

    @staticmethod
    def _split(total: int, shards: int) -> np.ndarray:
        base = total // shards
        return base + (np.arange(shards) < total % shards)

    # -- one serving tick -----------------------------------------------------

    def tick(self, new_keys: Sequence[int], new_idxs: Sequence[int], *,
             slots: int, pages: int, need: Sequence[int] = (),
             max_rounds: int = 256) -> List[int]:
        """Install this tick's arrivals, refresh the budgets, and run one
        megaround (to quiescence or first stall).  Returns the admitted
        request-table indices in admission order.  Unlike ``_drive``,
        occupancy > 0 at exit is NOT an error — stalled requests stay
        device-resident for the next tick."""
        if self._state is None:
            self.begin()
        ik = np.asarray(new_keys, np.int64).reshape(-1)
        iv = np.asarray(new_idxs, np.int64).reshape(-1)
        assert ik.shape == iv.shape
        _check_deadline_keys(ik)
        if iv.size and (iv.min() < 0 or iv.max() >= self.table):
            raise ValueError(
                f"request index outside the {self.table}-row table")
        # arrivals enter as retry-0 idents at their deadline keys
        self._insert(ik.astype(np.int32), iv.astype(np.int32))
        acc = self._state[1]
        accn = {k: np.asarray(v).copy() for k, v in acc.items()}
        if len(need):
            nd = np.asarray(need, np.int32).reshape(-1)
            assert nd.shape == iv.shape
            accn["need"][:, iv] = nd[None, :]
        accn["slots"] = self._split(int(slots), self.shards).astype(np.int32)
        accn["pages"] = self._split(int(pages), self.shards).astype(np.int32)
        accn["stalled"] = np.zeros(self.shards, bool)
        # the admitted log is per-tick (bounded by ``slots`` ≤ table);
        # letting it accumulate would run off the table on long runs
        accn["adm_n"] = np.zeros(self.shards, np.int32)
        self._state[1] = {k: jnp.asarray(v) for k, v in accn.items()}
        # ONE megaround call: the tick's admission wave
        limit = max_rounds
        if self.spans is not None:
            # stamp-time cap (DESIGN.md § 7.6): no round past the cap may
            # write a birth stamp into the heap's rider plane
            if self._rounds >= self.span_round_cap:
                raise RuntimeError(
                    f"serving span round clock reached the birth-stamp cap "
                    f"({self.span_round_cap} rounds): stamps would wrap "
                    f"(run without spans or restart the engine)")
            limit = min(limit, self.span_round_cap - self._rounds)
        out = self._megaround(*self._state, jnp.int32(limit), *self._ext)
        self._state[:] = list(out[:5])
        oflow, r = bool(out[5]), int(out[6])
        self._ext[:] = list(out[7:])
        occ = self.occupancy()                 # THE host sync of the tick
        self._rounds += r
        self._host_syncs += 1
        now = time.time()
        point = SyncPoint(rounds=self._rounds, occupancy=occ, wall_time=now,
                          host_syncs=self._host_syncs)
        self.sync_log.append(point)
        self.stats = {
            "rounds": self._rounds, "processed": int(self._state[2]),
            "spawned": int(self._state[3]),
            "max_occupancy": int(self._state[4]),
            "drained": int(occ == 0), "host_syncs": self._host_syncs,
        }
        if self.telemetry is not None:
            self.telemetry.drain(self._ext[0], sync=self._host_syncs - 1,
                                 wall_time=now)
            self.telemetry.heartbeat(point)
            self.telemetry.finish(self.stats)
        if self.spans is not None:
            self.spans.drain(self._ext[1], wall_time=now)
            self.spans.finish(self.stats)
        if oflow:
            raise RuntimeError(
                f"serving admission overflow: occupancy {occ} + re-entries "
                f"exceed per-shard heap capacity {self.capacity} at round "
                f"{self._rounds} (raise capacity_log2)")
        acc = self._state[1]
        adm_n = np.asarray(acc["adm_n"])
        adm_idx = np.asarray(acc["adm_idx"])
        admitted: List[int] = []
        for s in range(self.shards):
            admitted.extend(int(i) for i in adm_idx[s, :int(adm_n[s])])
        self.admitted_log.extend(admitted)
        return admitted

    # -- history readback for the p-linearizability checker ------------------

    def pop_history(self) -> List[Tuple[int, int, int, int]]:
        """All recorded pops as ``(round, shard, key, val)`` sorted by
        round (requires ``pop_log`` > 0; raises otherwise)."""
        if not self.pop_log:
            raise ValueError("construct with pop_log=N to record pops")
        acc = self._state[1]
        pln = np.asarray(acc["pln"])
        if int(pln.max(initial=0)) > self.pop_log:
            raise RuntimeError(
                f"pop log overflowed ({int(pln.max())} > {self.pop_log}): "
                f"raise pop_log")
        rows = []
        for s in range(self.shards):
            n = int(pln[s])
            plk = np.asarray(acc["plk"][s][:n])
            plv = np.asarray(acc["plv"][s][:n])
            plr = np.asarray(acc["plr"][s][:n])
            rows.extend((int(r), s, int(k), int(v))
                        for r, k, v in zip(plr, plk, plv))
        rows.sort(key=lambda t: (t[0], t[1]))
        return rows


register_engine("serving", ServingMeshEngine, priority=True, mesh=True,
                kwargs={}, spans_ok=True)
