"""Model-zoo smoke + consistency tests on the reduced configs: every
assigned architecture instantiates, runs a train step (finite loss) and a
decode step; flash attention matches dense; chunked SSD matches the naive
recurrence; prefill+decode agrees with teacher-forced forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import (decode_step, forward, init_decode_cache,
                          init_params, loss_fn)

ARCHS = list_archs()


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.audio_frontend:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)), jnp.bfloat16)
        batch["tokens"] = None
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                      jnp.int32)
    if cfg.family == "vlm":
        batch["img"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_image_tokens, cfg.d_model)),
            jnp.bfloat16)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    return batch


def test_all_archs_listed():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_loss_and_grad(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: degenerate grads"


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).causal])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits.
    MoE capacity is made effectively unbounded: token dropping legitimately
    differs between an 8-token forward and 1-token decode steps."""
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    params = init_params(cfg)
    b, s = 1, 8
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    img = None
    if cfg.family == "vlm":
        img = jnp.asarray(rng.standard_normal((b, cfg.n_image_tokens,
                                               cfg.d_model)), jnp.bfloat16)
    full = forward(params, toks, cfg, img=img)              # (b, s, V)
    cache = init_decode_cache(cfg, b, 32)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1],
                                jnp.int32(t), cfg, img=img)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    if cfg.family == "moe":
        # a near-tie router choice may flip under bf16 accumulation-order
        # differences (discontinuous routing): a flipped position diverges
        # wholesale.  Require ≥70% of positions fully close and that the
        # mean deviation stays small.
        close = np.isclose(np.asarray(dec), np.asarray(full),
                           rtol=0.15, atol=0.15)
        pos_close = close.all(axis=-1).mean()
        mean_dev = np.abs(np.asarray(dec) - np.asarray(full)).mean()
        assert pos_close >= 0.7 and mean_dev < 0.2, (pos_close, mean_dev)
    else:
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   rtol=0.15, atol=0.15)


def test_flash_matches_dense():
    import repro.models.layers as LY
    from repro.models.layers import attention
    cfg = get_config("gemma2-27b").reduced()   # softcap + window exercised
    params = init_params(cfg)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    x = (0.2 * jax.random.normal(jax.random.PRNGKey(0),
                                 (2, 2048, cfg.d_model))).astype(jnp.bfloat16)
    pos = jnp.arange(2048, dtype=jnp.int32)

    def f(x_, w):
        out, _ = attention(lp, x_, cfg, positions=pos, window=w)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    for w in (0, 16):
        vf, gf = jax.value_and_grad(f)(x, w)
        orig, LY.FLASH_MIN_SEQ = LY.FLASH_MIN_SEQ, 10 ** 9
        vd, gd = jax.value_and_grad(f)(x, w)
        LY.FLASH_MIN_SEQ = orig
        assert abs(float(vf) - float(vd)) / abs(float(vd)) < 1e-2
        err = float(jnp.max(jnp.abs(gf.astype(jnp.float32)
                                    - gd.astype(jnp.float32))))
        mag = float(jnp.max(jnp.abs(gd.astype(jnp.float32)))) + 1e-9
        assert err / mag < 0.05, f"window={w}: grad mismatch {err} vs {mag}"


def test_ssd_matches_naive_recurrence():
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(0)
    b, s, nh, hd, st = 2, 512, 3, 8, 16
    x = jnp.asarray(rng.normal(size=(b, s, nh, hd)), jnp.float32)
    dt = jnp.asarray(rng.random((b, s, nh)) * 0.5, jnp.float32)
    A = jnp.asarray(rng.random(nh) * 0.5, jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, st)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, st)), jnp.float32)
    y, hf = ssd_chunked(x, dt, A, B, C, jnp.zeros((b, nh, hd, st)))
    h = np.zeros((b, nh, hd, st))
    ys = np.zeros((b, s, nh, hd))
    negA = -np.exp(np.asarray(A))
    for t in range(s):
        dA = np.exp(np.asarray(dt[:, t]) * negA)
        h = h * dA[:, :, None, None] + np.einsum(
            "bh,bhd,bs->bhds", np.asarray(dt[:, t]), np.asarray(x[:, t]),
            np.asarray(B[:, t]))
        ys[:, t] = np.einsum("bs,bhds->bhd", np.asarray(C[:, t]), h)
    np.testing.assert_allclose(np.asarray(y), ys, atol=5e-4)
    np.testing.assert_allclose(np.asarray(hf), h, atol=5e-4)


def test_param_count_matches_init():
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = init_params(cfg)
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / analytic < 0.35, \
            f"{arch}: init {actual} vs analytic {analytic}"


def test_training_reduces_loss():
    """A few AdamW steps on a tiny model reduce the loss on a fixed batch."""
    from repro.optim import adamw
    cfg = get_config("h2o-danube-1.8b").reduced()
    params = init_params(cfg)
    state = adamw.init(params)
    ocfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=1, total_steps=40)
    batch = _batch(cfg, b=4, s=16)

    @jax.jit
    def step(state, batch):
        p = adamw.cast_params(state.master)
        loss, grads = jax.value_and_grad(loss_fn)(p, batch, cfg)
        state, _ = adamw.step(ocfg, state, grads)
        return state, loss

    losses = []
    for _ in range(8):
        state, loss = step(state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, losses
