"""Kernel micro-benchmarks: wall-clock of the jitted kernel entry points
(interpret mode on CPU — structural cost only; the roofline table covers
the TPU-side projection) and of the vectorized/batched queue ops."""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time_call(fn, *args, reps: int = 5, **kw):
    r = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(r)[0])
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(r)[0])
        best = min(best, time.perf_counter() - t0)
    return best


def main(out=sys.stdout) -> None:
    rng = np.random.default_rng(0)
    print("bench,kernel,shape,us_per_call,derived", file=out)
    for n in (1024, 8192):
        a = jnp.asarray((rng.random(n) < 0.4).astype(np.int32))
        c = jnp.array([0], jnp.int32)
        t = _time_call(ops.wavefaa, a, c)
        print(f"kernels,wavefaa,{n},{t*1e6:.1f},tickets/s={n/t:.2e}", file=out)

    nsl2, bot = 8, (1 << 31) - 1
    nslots = 1 << nsl2
    cyc = jnp.zeros(nslots, jnp.int32)
    saf = jnp.ones(nslots, jnp.int32)
    enq = jnp.zeros(nslots, jnp.int32)
    idx = jnp.full(nslots, bot, jnp.int32)
    tk = jnp.arange(nslots, nslots + 128, dtype=jnp.int32)
    vals = jnp.arange(128, dtype=jnp.int32)
    head = jnp.array([nslots], jnp.int32)
    t = _time_call(ops.ring_enqueue, cyc, saf, enq, idx, tk, vals, head,
                   nslots_log2=nsl2, idx_bot=bot)
    print(f"kernels,ring_enqueue,128x{nslots},{t*1e6:.1f},ops/s={128/t:.2e}",
          file=out)

    eids = jnp.asarray(rng.integers(0, 16, 512).astype(np.int32))
    t = _time_call(ops.expert_tickets, eids, num_experts=16, capacity=64)
    print(f"kernels,expert_tickets,512x16,{t*1e6:.1f},pairs/s={512/t:.2e}",
          file=out)

    # dense-wave compaction (DESIGN.md § 4.4): Pallas segmented-scan kernel
    # vs its bit-identical pure-jnp associative_scan twin, sparse-to-dense
    # on a ~10%-occupied child block (the kron wide-wave shape)
    from repro.kernels import compact_planes, wave_compact
    for n, width in ((8192, 1024), (65536, 8192)):
        mask = jnp.asarray((rng.random(n) < 0.1).astype(np.int32))
        plane = jnp.asarray(rng.integers(0, 1 << 20, n).astype(np.int32))
        t = _time_call(wave_compact, mask, (plane,), width=width)
        print(f"kernels,wave_compact,{n}to{width},{t*1e6:.1f},"
              f"lanes/s={n/t:.2e}", file=out)
        t = _time_call(compact_planes, mask, (plane,), width=width)
        print(f"kernels,compact_planes,{n}to{width},{t*1e6:.1f},"
              f"lanes/s={n/t:.2e}", file=out)


if __name__ == "__main__":
    main()
