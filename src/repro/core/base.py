"""Common queue-algorithm interface for the simulated-concurrency layer.

Every queue exposes generator methods (driven by `repro.core.sim.Scheduler`):

* ``enqueue(ctx, tid, value)`` — yields atomic instructions; returns ``True``
  on success, ``False`` if the bounded queue rejected the operation (full).
* ``dequeue(ctx, tid)`` — returns ``(True, value)`` or ``(False, None)`` for
  EMPTY.

Values must fit ``VAL_BITS`` (31 bits here) so they always fit the packed
Index field and never collide with ⊥ / ⊥_c.

The inner rings carry the payload directly in the Index field.  The paper's
outer indirection layer ("moves indices rather than payloads") exists because
real payloads exceed a word; our benchmark payloads are word-sized, so the
payload *is* the index.  `IndexedQueue` reproduces the two-ring indirection
(free-index ring + allocated ring + data array) for completeness and is used
by the application layer.
"""

from __future__ import annotations

from typing import Generator, Optional, Tuple

from .atomics import AtomicMemory
from .sim import Ctx, ENQ, DEQ

VAL_BITS = 31
VAL_MASK = (1 << VAL_BITS) - 1


class QueueAlgorithm:
    """Base class; subclasses allocate arrays in ``init`` and implement the
    generator protocol."""

    name: str = "abstract"

    def __init__(self, capacity: int, num_threads: int) -> None:
        self.capacity = capacity
        self.num_threads = num_threads
        self.mem: Optional[AtomicMemory] = None

    def init(self, mem: AtomicMemory) -> None:
        raise NotImplementedError

    def enqueue(self, ctx: Ctx, tid: int, value: int) -> Generator:
        raise NotImplementedError

    def dequeue(self, ctx: Ctx, tid: int) -> Generator:
        raise NotImplementedError

    # -- benchmark worker bodies (paper § V-A) -------------------------------

    def worker_balanced(self, ctx: Ctx, tid: int, ops: int, val_base: int):
        """Balanced kernel: each thread alternates one enqueue, one dequeue."""
        for k in range(ops):
            v = (val_base + k) & VAL_MASK
            yield from ctx.op_begin(ENQ, v)
            ok = yield from self.enqueue(ctx, tid, v)
            yield from ctx.op_end(ok, ok)
            yield from ctx.op_begin(DEQ, None)
            ok, out = yield from self.dequeue(ctx, tid)
            yield from ctx.op_end(out if ok else None, ok)

    def worker_producer(self, ctx: Ctx, tid: int, ops: int, val_base: int):
        for k in range(ops):
            v = (val_base + k) & VAL_MASK
            yield from ctx.op_begin(ENQ, v)
            ok = yield from self.enqueue(ctx, tid, v)
            yield from ctx.op_end(ok, ok)

    def worker_consumer(self, ctx: Ctx, tid: int, ops: int):
        for _ in range(ops):
            yield from ctx.op_begin(DEQ, None)
            ok, out = yield from self.dequeue(ctx, tid)
            yield from ctx.op_end(out if ok else None, ok)


class IndexedQueue:
    """The paper's outer indirection layer: a data array plus two inner rings
    (free-index ring ``fq`` pre-filled with all indices, allocated ring
    ``aq``).  Enqueue: idx ← fq.deq; data[idx] = v; aq.enq(idx).
    Dequeue: idx ← aq.deq; v = data[idx]; fq.enq(idx)."""

    def __init__(self, ring_cls, capacity: int, num_threads: int, **kw) -> None:
        self.capacity = capacity
        self.aq = ring_cls(capacity, num_threads, tag="aq", **kw)
        self.fq = ring_cls(capacity, num_threads, tag="fq", prefill=capacity, **kw)
        self.data_name = "iq_data"

    def init(self, mem: AtomicMemory) -> None:
        self.mem = mem
        self.aq.init(mem)
        self.fq.init(mem)
        mem.alloc(self.data_name, self.capacity)

    def enqueue(self, ctx: Ctx, tid: int, value: int) -> Generator:
        ok, idx = yield from self.fq.dequeue(ctx, tid)
        if not ok:
            return False  # no free index == queue full
        yield from ctx.store(self.data_name, idx, value)
        ok2 = yield from self.aq.enqueue(ctx, tid, idx)
        assert ok2, "aq can hold every index fq handed out"
        return True

    def dequeue(self, ctx: Ctx, tid: int) -> Generator:
        ok, idx = yield from self.aq.dequeue(ctx, tid)
        if not ok:
            return (False, None)
        v = yield from ctx.load(self.data_name, idx)
        ok2 = yield from self.fq.enqueue(ctx, tid, idx)
        assert ok2
        return (True, v)
