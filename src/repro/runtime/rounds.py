"""Round-based deterministic task loop on the Pallas ring (DESIGN.md § 4.3).

The sim face (`executor.py`) explores adversarial interleavings; this face is
the *device* execution model: task scheduling advances in jitted rounds, and
within a round every queue operation is ordered by ticket — the batched
analogue of Lemma III.1, with no nondeterminism left.  One round is

    dequeue a batch of task values from the ring (``ring_dequeue``),
    run the user's jitted step function on the batch,
    enqueue the children it emits (``ring_enqueue``) in row-major order.

Two execution engines share this contract:

* **fused** (default) — ``fusedrounds.RingEngine``: the whole round cycle
  runs on device inside one jitted ``lax.while_loop`` with head/tail as
  device scalars and ``wavefaa`` as the in-loop child-ticket source; the
  host syncs only at quiescence (or every ``sync_every`` rounds).
* **legacy** (``fused=False``) — one host-driven round per iteration:
  head/tail as host ints, exact ``np.arange`` tickets, one kernel dispatch
  per op wave.  Slower (every round is a host sync) but each round is a
  separate, inspectable step — keep it for adversarial/step-debug use.

Both engines are bit-identical (same acc, same planes, same head/tail —
asserted by tests) and raise ``RuntimeError`` on ring/heap overflow and on
``max_rounds`` truncation, so a non-drained return is impossible to
mistake for quiescence.

At mesh scope the same round structure runs on ``core.distqueue``:
``mesh_task_round`` composes one enqueue round and one dequeue round inside
shard_map — each chip contributes its spawn/claim masks, one collective
hands out the whole mesh's tickets and compact blocks (DESIGN.md § 2.3).
``runtime/meshrounds.py: MeshRoundRunner`` fuses that loop device-resident
(host sync only at global quiescence), exactly as this module's fused
engine does at chip scope.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.distqueue import dist_dequeue_round, dist_enqueue_round
from ..kernels.heap_batch import KEY_INF as HEAP_KEY_INF, heap_apply
from ..kernels.pallas_env import resolve_interpret
from ..kernels.ring_slots import ring_dequeue, ring_enqueue
from .enginecore import register_engine
from .fusedrounds import (IDX_BOT, HeapEngine, HeapState, PriorityStepFn,
                          RingEngine, RingState, StepFn, heap_init,
                          ring_init)

__all__ = [
    "IDX_BOT", "HeapState", "PriorityRoundRunner", "PriorityStepFn",
    "RingState", "RoundRunner", "StepFn", "heap_init", "mesh_task_round",
    "ring_init",
]


class RoundRunner:
    """Drives ``step_fn`` to quiescence through the Pallas ring.

    ``fused=True`` (default) delegates to the device-resident megaround
    loop; ``fused=False`` keeps the legacy host-driven round loop.  Both
    populate ``stats`` with rounds / processed / spawned / max_occupancy /
    drained / host_syncs and raise on overflow or truncation."""

    def __init__(self, step_fn: StepFn, *, capacity_log2: int = 10,
                 batch: int = 64, interpret=None, fused: bool = True,
                 sync_every: int = 0, telemetry=None, spans=None,
                 compact=None) -> None:
        self.step_fn = jax.jit(step_fn)
        self.capacity_log2 = capacity_log2
        self.nslots_log2 = capacity_log2 + 1
        self.capacity = 1 << capacity_log2
        self.batch = batch
        self.interpret = resolve_interpret(interpret)
        self.fused = fused
        self.telemetry = telemetry
        self.spans = spans
        self.stats: Dict[str, int] = {}
        self.sync_log: List[Dict[str, int]] = []
        if telemetry is not None and not fused:
            raise ValueError("trace planes are in-loop state: telemetry "
                             "needs the fused engine (fused=True)")
        if spans is not None and not fused:
            raise ValueError("span planes are in-loop state: spans needs "
                             "the fused engine (fused=True)")
        if fused:
            self._engine = RingEngine(
                step_fn, capacity_log2=capacity_log2, batch=batch,
                interpret=self.interpret, sync_every=sync_every,
                telemetry=telemetry, spans=spans, compact=compact)
        else:
            self._engine = None
            # legacy-path op buffers, reused across rounds (safe because
            # jnp.asarray copies and every kernel call syncs on its ok)
            self._enq_t = np.empty(batch, np.int32)
            self._enq_v = np.empty(batch, np.int32)
            self._deq_t = np.empty(batch, np.int32)

    def _enq_chunk(self, st: RingState, vals: np.ndarray) -> RingState:
        k = len(vals)
        assert k <= self.batch
        if st.occupancy + k > self.capacity:
            raise RuntimeError(
                f"ring overflow: occupancy {st.occupancy} + {k} children "
                f"exceeds capacity {self.capacity} (raise capacity_log2 or "
                f"lower the fanout)")
        self._enq_t.fill(-1)
        self._enq_t[:k] = st.tail + np.arange(k, dtype=np.int32)
        self._enq_v.fill(-1)
        self._enq_v[:k] = vals
        cyc, saf, enq, idx, ok = ring_enqueue(
            st.cycles, st.safes, st.enqs, st.idxs,
            jnp.asarray(self._enq_t), jnp.asarray(self._enq_v),
            jnp.asarray(st.head, jnp.int32),
            nslots_log2=self.nslots_log2, idx_bot=IDX_BOT,
            interpret=self.interpret)
        self._host_syncs += 1
        assert bool(ok[:k].all()), "exact tickets cannot miss"
        return RingState(cyc, saf, enq, idx, st.head, st.tail + k)

    def run(self, initial: np.ndarray, acc: Any = None,
            max_rounds: int = 10_000) -> Tuple[Any, RingState]:
        """Seed the ring with ``initial`` task values, run rounds until the
        ring drains.  Returns (acc, final ring state); raises RuntimeError
        if ``max_rounds`` is hit before quiescence."""
        if self._engine is not None:
            try:
                return self._engine.run(initial, acc, max_rounds)
            finally:
                self.stats = dict(self._engine.stats, fused=1)
                self.sync_log = self._engine.sync_log
        self.stats = {}
        self.sync_log = []
        self._host_syncs = 0
        st = ring_init(self.capacity_log2)
        initial = np.asarray(initial, np.int32)
        for i in range(0, len(initial), self.batch):
            st = self._enq_chunk(st, initial[i:i + self.batch])
        rounds = processed = spawned = 0
        max_occ = st.occupancy
        while st.occupancy > 0 and rounds < max_rounds:
            k = min(self.batch, st.occupancy)
            self._deq_t.fill(-1)
            self._deq_t[:k] = st.head + np.arange(k, dtype=np.int32)
            cyc, saf, enq, idx, vals, ok = ring_dequeue(
                st.cycles, st.safes, st.enqs, st.idxs,
                jnp.asarray(self._deq_t),
                nslots_log2=self.nslots_log2, idx_bot=IDX_BOT,
                interpret=self.interpret)
            self._host_syncs += 1
            assert bool(ok[:k].all()), "exact tickets cannot miss"
            st = RingState(cyc, saf, enq, idx, st.head + k, st.tail)
            acc, cvals, cmask = self.step_fn(acc, vals, ok)
            cv = np.asarray(cvals).reshape(-1)
            cm = np.broadcast_to(np.asarray(cmask).astype(bool),
                                 np.asarray(cvals).shape).reshape(-1)
            self._host_syncs += 1
            children = cv[cm]                      # row-major ⇒ deterministic
            for i in range(0, len(children), self.batch):
                st = self._enq_chunk(st, children[i:i + self.batch])
            rounds += 1
            processed += k
            spawned += len(children)
            max_occ = max(max_occ, st.occupancy)
        self.stats = {"rounds": rounds, "processed": processed,
                      "spawned": spawned, "max_occupancy": max_occ,
                      "drained": int(st.occupancy == 0),
                      "host_syncs": self._host_syncs, "fused": 0}
        if st.occupancy > 0:
            raise RuntimeError(
                f"round loop truncated at max_rounds={max_rounds} with "
                f"occupancy {st.occupancy}: not quiescent "
                f"(stats['drained']=0)")
        return acc, st


# ---------------------------------------------------------------------------
# Priority rounds on the Pallas heap (DESIGN.md § 5.6)
# ---------------------------------------------------------------------------


class PriorityRoundRunner:
    """``RoundRunner``'s priority twin: drives ``step_fn`` to quiescence
    through the Pallas heap kernel.  One round pops the ``batch`` smallest
    (key, val) pairs (EDF: earliest deadlines), runs the jitted step, and
    inserts the children it emits in row-major order — every kernel batch
    is applied in batch-index order, so the whole run is bit-deterministic
    exactly like the FIFO rounds.  ``fused=True`` (default) chains the
    pop/insert batches under one device-resident ``lax.while_loop``."""

    def __init__(self, step_fn: PriorityStepFn, *, capacity_log2: int = 10,
                 batch: int = 64, arity_log2: int = 2, interpret=None,
                 fused: bool = True, sync_every: int = 0,
                 telemetry=None, spans=None, compact=None) -> None:
        self.step_fn = jax.jit(step_fn)
        self.capacity_log2 = capacity_log2
        self.capacity = 1 << capacity_log2
        self.batch = batch
        self.arity_log2 = arity_log2
        self.interpret = resolve_interpret(interpret)
        self.fused = fused
        self.telemetry = telemetry
        self.spans = spans
        self.stats: Dict[str, int] = {}
        self.sync_log: List[Dict[str, int]] = []
        if telemetry is not None and not fused:
            raise ValueError("trace planes are in-loop state: telemetry "
                             "needs the fused engine (fused=True)")
        if spans is not None and not fused:
            raise ValueError("span planes are in-loop state: spans needs "
                             "the fused engine (fused=True)")
        if fused:
            self._engine = HeapEngine(
                step_fn, capacity_log2=capacity_log2, batch=batch,
                arity_log2=arity_log2, interpret=self.interpret,
                sync_every=sync_every, telemetry=telemetry, spans=spans,
                compact=compact)
        else:
            self._engine = None
            # legacy-path op buffers, reused across rounds (safe because
            # jnp.asarray copies and every kernel call syncs on its ok)
            self._ins_ops = np.empty(batch, np.int32)
            self._ins_k = np.empty(batch, np.int32)
            self._ins_v = np.empty(batch, np.int32)
            self._pop_ops = np.empty(batch, np.int32)
            self._pad = jnp.full((batch,), HEAP_KEY_INF, jnp.int32)

    def _apply(self, st: HeapState, ops, keys, vals):
        k, v, size, outk, outv, ok = heap_apply(
            st.keys, st.vals, jnp.asarray(st.size, jnp.int32),
            ops, keys, vals,
            cap_log2=self.capacity_log2, arity_log2=self.arity_log2,
            interpret=self.interpret)
        self._host_syncs += 1
        return HeapState(k, v, int(size)), outk, outv, ok

    def _ins_chunk(self, st: HeapState, ckeys: np.ndarray,
                   cvals: np.ndarray) -> HeapState:
        n = len(ckeys)
        assert n <= self.batch
        if st.size + n > self.capacity:
            raise RuntimeError(
                f"heap overflow: size {st.size} + {n} children exceeds "
                f"capacity {self.capacity} (raise capacity_log2 or lower "
                f"the fanout)")
        self._ins_ops.fill(-1)
        self._ins_ops[:n] = 0
        self._ins_k.fill(HEAP_KEY_INF)
        self._ins_k[:n] = ckeys
        self._ins_v.fill(-1)
        self._ins_v[:n] = cvals
        st, _, _, ok = self._apply(st, jnp.asarray(self._ins_ops),
                                   jnp.asarray(self._ins_k),
                                   jnp.asarray(self._ins_v))
        assert bool(ok[:n].all()), "capacity was checked: inserts cannot miss"
        return st

    def run(self, initial_keys: np.ndarray, initial_vals: np.ndarray,
            acc: Any = None, max_rounds: int = 10_000
            ) -> Tuple[Any, HeapState]:
        if self._engine is not None:
            try:
                return self._engine.run(initial_keys, initial_vals, acc,
                                        max_rounds)
            finally:
                self.stats = dict(self._engine.stats, fused=1)
                self.sync_log = self._engine.sync_log
        self.stats = {}
        self.sync_log = []
        self._host_syncs = 0
        st = heap_init(self.capacity_log2)
        ik = np.asarray(initial_keys, np.int32)
        iv = np.asarray(initial_vals, np.int32)
        assert ik.shape == iv.shape
        for i in range(0, len(ik), self.batch):
            st = self._ins_chunk(st, ik[i:i + self.batch],
                                 iv[i:i + self.batch])
        rounds = processed = spawned = 0
        max_occ = st.size
        while st.size > 0 and rounds < max_rounds:
            k = min(self.batch, st.size)
            self._pop_ops.fill(-1)
            self._pop_ops[:k] = 1
            st, outk, outv, ok = self._apply(st, jnp.asarray(self._pop_ops),
                                             self._pad, self._pad)
            assert bool(ok[:k].all()), "size was checked: pops cannot miss"
            acc, ckeys, cvals, cmask = self.step_fn(acc, outk, outv, ok)
            ck = np.asarray(ckeys).reshape(-1)
            cv = np.asarray(cvals).reshape(-1)
            cm = np.broadcast_to(np.asarray(cmask).astype(bool),
                                 np.asarray(ckeys).shape).reshape(-1)
            self._host_syncs += 1
            children_k, children_v = ck[cm], cv[cm]   # row-major order
            for i in range(0, len(children_k), self.batch):
                st = self._ins_chunk(st, children_k[i:i + self.batch],
                                     children_v[i:i + self.batch])
            rounds += 1
            processed += k
            spawned += len(children_k)
            max_occ = max(max_occ, st.size)
        self.stats = {"rounds": rounds, "processed": processed,
                      "spawned": spawned, "max_occupancy": max_occ,
                      "drained": int(st.size == 0),
                      "host_syncs": self._host_syncs, "fused": 0}
        if st.size > 0:
            raise RuntimeError(
                f"priority round loop truncated at max_rounds={max_rounds} "
                f"with size {st.size}: not quiescent (stats['drained']=0)")
        return acc, st


def mesh_task_round(state, spawn_vals: jax.Array, spawn_mask: jax.Array,
                    claim_mask: jax.Array, axis: str):
    """One mesh-scope task round inside shard_map: publish this chip's
    spawned tasks, then claim up to ``claim_mask.sum()`` tasks for local
    execution.  Returns (state, granted, claimed_vals, claimed_ok).

    Composes ``dist_enqueue_round`` + ``dist_dequeue_round`` — two prefix-sum
    collectives per round, the mesh analogue of a wave's two leader FAAs."""
    state, granted = dist_enqueue_round(state, spawn_vals, spawn_mask, axis)
    state, vals, ok = dist_dequeue_round(state, claim_mask, axis)
    return state, granted, vals, ok


# engine-matrix rows (tests/conftest.py parametrizes over these)
register_engine("rounds", RoundRunner, priority=False, mesh=False)
register_engine("prounds", PriorityRoundRunner, priority=True, mesh=False)
