"""Training driver: data pipeline → jitted train step → async checkpoints →
restart-on-failure.  The end-to-end deliverable (b) entry point.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 50 --batch 4 --seq 64 [--reduced] [--ckpt-dir ckpts]

On this CPU container use --reduced (same code path as production; the full
configs are exercised by the dry-run).  Runs on whatever devices are
visible; add TP with --model-parallel N on real hardware.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint.manager import CheckpointManager
from ..configs import get_config
from ..data.pipeline import DataConfig, synth_batch
from ..distributed.fault_tolerance import RestartManager, StragglerDetector
from ..models import init_params, loss_fn
from ..optim import adamw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--inject-fault-at", type=int, default=None,
                    help="simulate a node failure at this step (demo)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch)
    ocfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=5,
                             total_steps=args.steps)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"devices={jax.device_count()}")

    params = init_params(cfg)
    state = adamw.init(params)

    @jax.jit
    def jstep(state, batch):
        p = adamw.cast_params(state.master)
        loss, grads = jax.value_and_grad(loss_fn)(p, batch, cfg)
        state, metrics = adamw.step(ocfg, state, grads)
        metrics["loss"] = loss
        return state, metrics

    detector = StragglerDetector(n_pods=1)

    def step_fn(state, i):
        t0 = time.time()
        b = synth_batch(cfg, dcfg, i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        state, metrics = jstep(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        detector.heartbeat(i, 0, dt)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  {dt*1e3:.0f}ms")
        return state

    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        rm = RestartManager(ckpt, save_every=args.save_every)
        final, state = rm.run(state, step_fn, num_steps=args.steps,
                              inject_fault_at=args.inject_fault_at)
        print(f"done at step {final} (restarts: {rm.restarts})")
    else:
        for i in range(args.steps):
            state = step_fn(state, i)
        print("done")


if __name__ == "__main__":
    main()
