"""Paper Fig. 5 — per-successful-operation profiling metrics.

rocprofv2's WAIT/op and VALU/op have no CPU analogue (DESIGN.md § 2); the
simulator derives the same normalized quantities:

* steps/op        — state-machine transitions per successful op (VALU/op),
* stall-steps/op  — transitions inside attempts that did not commit
                    (WAIT/op),
* atomics/op      — hot-word atomic traffic per successful op (what
                    wave-batching reduces, Fig. 1).

Also reports the wave-batching ablation: G-LFQ with gang scheduling (high
ballot occupancy) vs random scheduling (batching collapses to per-thread
FAA) — the direct measurement of the Fig. 1 claim.

The sim is deterministic (seeded scheduler), so every per-op column is
bit-stable across runs — ``--smoke`` is the CI gate (sanity invariants on
a tiny sweep) and the full section rides in the ``BENCH_<n>.json``
trajectory where ``tools/bench_compare.py`` can watch it drift."""

from __future__ import annotations

import argparse
import sys

from repro.core import QUEUE_CLASSES
from .bench_throughput import run_balanced, run_split


def main(out=sys.stdout, *, threads_list=(8, 32, 128),
         steps: int = 120_000) -> None:
    print("bench,queue,threads,mode,steps_per_op,stall_steps_per_op,"
          "atomics_per_op", file=out)
    for name, qcls in QUEUE_CLASSES.items():
        for t in threads_list:
            for mode, m in (
                ("balanced", run_balanced(qcls, t, steps)),
                ("p25", run_split(qcls, t, steps, 0.25)),
                ("p50", run_split(qcls, t, steps, 0.50)),
                ("p75", run_split(qcls, t, steps, 0.75)),
            ):
                print(f"fig5,{name},{t},{mode},{m['steps_per_op']:.2f},"
                      f"{m['stall_steps_per_op']:.2f},"
                      f"{m['atomics_per_op']:.2f}", file=out)

    # Fig. 1 ablation: wave batching occupancy (gang) vs none (random)
    from repro.core import AtomicMemory, Scheduler
    from repro.core.base import VAL_MASK
    from repro.core.sim import DEQ, ENQ
    print("bench,queue,threads,policy,hot_word_atomics_per_op", file=out)
    for policy in ("gang", "random"):
        qcls = QUEUE_CLASSES["glfq"]
        t = 64
        q = qcls(capacity=128, num_threads=t)
        mem = AtomicMemory()
        q.init(mem)
        sched = Scheduler(mem, wave_size=8, policy=policy, seed=0)

        def worker(ctx, tid):
            k = 0
            while True:
                v = ((tid << 16) | (k & 0xFFFF)) & VAL_MASK
                yield from ctx.op_begin(ENQ, v)
                ok = yield from q.enqueue(ctx, tid, v)
                yield from ctx.op_end(ok, ok)
                yield from ctx.op_begin(DEQ, None)
                ok, o = yield from q.dequeue(ctx, tid)
                yield from ctx.op_end(o if ok else None, ok)
                k += 1

        for i in range(t):
            sched.spawn(worker)
        sched.run(120_000)
        m = sched.metrics()
        # hot-word atomic RMWs (FAA/CAS on Head/Tail) per successful op —
        # the quantity Fig. 1's wave batching reduces (loads excluded)
        hot = (mem.rmw_traffic.get("glfq_tail", 0)
               + mem.rmw_traffic.get("glfq_head", 0))
        print(f"fig1_ablation,glfq,{t},{policy},"
              f"{hot / max(m['successful_ops'], 1):.3f}", file=out)


def smoke(out=sys.stdout) -> bool:
    """CI gate: per-op metrics exist and respect their invariants on a
    tiny deterministic sweep — every step is at least one transition
    (steps/op ≥ 1), stalled transitions are a subset of all transitions
    (stall ≤ steps), and committed ops touch the hot words (atomics/op
    > 0)."""
    ok = True
    print("# profiling smoke: per-op metric invariants on a tiny sweep",
          file=out)
    print("bench,queue,threads,mode,steps_per_op,stall_steps_per_op,"
          "atomics_per_op", file=out)
    for name, qcls in QUEUE_CLASSES.items():
        m = run_balanced(qcls, 8, 20_000)
        print(f"fig5,{name},8,balanced,{m['steps_per_op']:.2f},"
              f"{m['stall_steps_per_op']:.2f},{m['atomics_per_op']:.2f}",
              file=out)
        if m["steps_per_op"] < 1.0:
            print(f"# FAIL: {name} steps/op {m['steps_per_op']} < 1",
                  file=out)
            ok = False
        if m["stall_steps_per_op"] > m["steps_per_op"]:
            print(f"# FAIL: {name} stall-steps/op exceeds steps/op",
                  file=out)
            ok = False
        if m["atomics_per_op"] <= 0:
            print(f"# FAIL: {name} atomics/op not positive", file=out)
            ok = False
    print(f"# acceptance: {'PASS' if ok else 'FAIL'}", file=out)
    return ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI acceptance gate (tiny deterministic sweep)")
    a = ap.parse_args()
    if a.smoke:
        sys.exit(0 if smoke() else 1)
    main()
