"""Launchers: production meshes, step builders, dry-run, roofline."""
