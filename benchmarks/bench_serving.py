"""Open-loop serving harness: goodput and tail latency vs offered load
(DESIGN.md § 5.5, BENCH_10).

Replays ``repro.serving.traffic``'s bursty power-law arrival traces
through the full ``ServingEngine`` twice per offered-load point — once
with the host-pool EDF admission path and once with device-resident
admission (``ServingMeshEngine`` megarounds) — and reports, per tenant:

* **goodput** — completions within ``slo_ticks`` of submit, per arrival
  tick (the paper-style saturation curve: past the knee, offered load
  rises while goodput flattens);
* **p50/p99 latency** — submit→finish sojourn in engine ticks (the tail
  the EDF aging guarantee protects);
* **ticks_per_s** — wall-clock tick rate, min-of-interleaved-trials (the
  bench-noise discipline: trials interleave across modes so drift hits
  both equally, and the minimum elapsed time is the gate).

The tick clock is logical, so admitted sets, goodput, and latency are
deterministic given a trace — the runs are replayed per trial only to
time them, and the harness asserts the replays agree bit-for-bit.

Multi-device CPU meshes need ``XLA_FLAGS`` set before jax initializes,
so everything runs in a forced-2-device subprocess (``--inner``), the
bench_latency pattern.  ``--smoke`` is the CI gate: host and 1-shard
device admission agree exactly; 2-shard device admission conserves
requests and its relaxed pop order stays inside
``sched.mesh_relaxation_bound``; and the serving telemetry trace
round-trips ``tools/trace_check.py`` cleanly.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import time

HEADER = ("bench,mode,shards,rate,offered_load,tenants,tenant,submitted,"
          "admitted,completed,goodput,slo_ticks,p50_lat,p99_lat,ticks,"
          "elapsed_s,ticks_per_s")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _spawn_inner(args, out) -> int:
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (f"{flags} --xla_force_host_platform_device_count=2"
                        ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH"), REPO)
        if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serving", "--inner"] + args,
        capture_output=True, text=True, cwd=REPO, env=env, timeout=1800)
    print(proc.stdout, end="", file=out)
    if proc.returncode != 0:
        print(f"# FAIL: inner benchmark exited {proc.returncode}: "
              f"{proc.stderr[-2000:]}", file=out)
    return proc.returncode


# ---------------------------------------------------------------------------
# inner (subprocess) side — jax only imported here
# ---------------------------------------------------------------------------

_MODEL = None


def _model():
    global _MODEL
    if _MODEL is None:
        from repro.configs import get_config
        from repro.models import init_params
        cfg = get_config("h2o-danube-1.8b").reduced()
        _MODEL = (cfg, init_params(cfg))
    return _MODEL


def run_serving(mode: str, tc, *, shards: int = 1, max_extra: int = 400):
    """Replay one traffic trace through the engine; returns the metrics
    dict the rows are cut from.  ``mode`` is ``host`` (EDF pool) or
    ``device`` (mesh admission at ``shards``)."""
    import numpy as np

    from repro.serving import (EngineConfig, Request, ServingEngine,
                               generate_trace)
    cfg, params = _model()
    ecfg = EngineConfig(
        max_slots=4, page_size=8, num_pages=16, max_seq=64,
        request_ring_capacity=512,
        admission="device" if mode == "device" else "edf",
        tenants=tc.tenants, device_capacity_log2=9, device_batch=8,
        device_table_log2=9, device_shards=shards)
    eng = ServingEngine(cfg, params, ecfg)
    trace = generate_trace(tc)
    reqs, by_tick = [], {}
    for rid, a in enumerate(trace):
        req = Request(rid=rid,
                      prompt=(np.arange(a.prompt_len) % 17 + 1
                              ).astype(np.int32),
                      max_new_tokens=a.max_new_tokens, priority=a.priority,
                      tenant=a.tenant)
        reqs.append(req)
        by_tick.setdefault(a.tick, []).append(req)
    t0 = time.perf_counter()
    for _ in range(tc.ticks + max_extra):
        for req in by_tick.get(eng.tick, []):
            assert eng.submit(req), "request pool sized for the trace"
        eng.step()
        if (eng.tick > tc.ticks and not any(eng.slots) and not eng.stalled
                and eng._queue_empty()):
            break
    elapsed = time.perf_counter() - t0
    per_tenant = {}
    for t in range(tc.tenants):
        sub = [r for r in reqs if r.tenant == t]
        lats = sorted(r.finish_tick - r.submit_tick for r in sub if r.done)
        good = sum(1 for d in lats if d <= tc.slo_ticks)
        per_tenant[t] = {
            "submitted": len(sub), "completed": len(lats),
            "goodput": round(good / max(1, tc.ticks), 4),
            "p50_lat": lats[len(lats) // 2] if lats else None,
            "p99_lat": lats[min(len(lats) - 1,
                                (99 * len(lats)) // 100)] if lats else None,
        }
    return {
        "mode": mode, "shards": shards, "trace_len": len(trace),
        "admitted": eng.metrics["admitted"],
        "completed": eng.metrics["completed"],
        "admission_log": list(eng.admission_log),
        "decode_steps": eng.metrics["decode_steps"],
        "goodput": round(sum(p["goodput"] for p in per_tenant.values()), 4),
        "ticks": eng.tick, "elapsed_s": elapsed, "per_tenant": per_tenant,
    }


def _emit_rows(out, res, tc, rate: float) -> None:
    base = {
        "mode": res["mode"], "shards": res["shards"], "rate": rate,
        "offered_load": round(res["trace_len"] / tc.ticks, 4),
        "tenants": tc.tenants, "slo_ticks": tc.slo_ticks,
        "ticks": res["ticks"], "elapsed_s": round(res["elapsed_s"], 4),
        "ticks_per_s": round(res["ticks"] / max(res["elapsed_s"], 1e-9), 1),
    }
    rows = [dict(base, tenant=t, **p) for t, p in res["per_tenant"].items()]
    rows.append(dict(base, tenant=-1, submitted=res["trace_len"],
                     admitted=res["admitted"], completed=res["completed"],
                     goodput=res["goodput"], p50_lat=None, p99_lat=None))
    for row in rows:
        cells = [row.get(k) for k in HEADER.split(",")[1:]]
        print("serving," + ",".join("" if c is None else str(c)
                                    for c in cells), file=out)


def _same_replay(a, b) -> bool:
    """The determinism gate: two replays of one (mode, trace) must agree
    on everything but wall time."""
    keys = ("admitted", "completed", "admission_log", "decode_steps",
            "ticks", "per_tenant")
    return all(a[k] == b[k] for k in keys)


def inner_main(out, rates, *, ticks: int, tenants: int, trials: int) -> bool:
    """The sweep: modes x offered loads x tenants, trials interleaved
    across modes, elapsed = min over trials."""
    from repro.serving import TrafficConfig
    print(f"bench,{HEADER.split(',', 1)[1]}", file=out)
    best = {}
    for trial in range(trials):
        for rate in rates:
            tc = TrafficConfig(ticks=ticks, rate=rate, tenants=tenants,
                               seed=10, prompt_len=(2, 6),
                               max_new_tokens=(1, 4), slo_ticks=ticks)
            for mode in ("host", "device"):
                res = run_serving(mode, tc)
                key = (mode, rate)
                if key not in best:
                    best[key] = (res, tc)
                else:
                    prev = best[key][0]
                    assert _same_replay(prev, res), \
                        f"nondeterministic replay for {key}"
                    if res["elapsed_s"] < prev["elapsed_s"]:
                        best[key] = (res, tc)
                print(f"# trial {trial} {mode} rate={rate}: goodput "
                      f"{res['goodput']}, {res['elapsed_s']:.2f}s", file=out)
    for (mode, rate), (res, tc) in sorted(best.items(),
                                          key=lambda kv: (kv[0][1],
                                                          kv[0][0])):
        _emit_rows(out, res, tc, rate)
    top = max(r for _, r in best)
    dev, host = best[("device", top)][0], best[("host", top)][0]
    ok = dev["goodput"] >= host["goodput"]
    print(f"# acceptance: device goodput {dev['goodput']} "
          f"{'>=' if ok else '<'} host goodput {host['goodput']} at "
          f"rate {top}: {'PASS' if ok else 'FAIL'}", file=out)
    return ok


def inner_smoke(out) -> bool:
    """CI gate: exactness at one shard, conservation + relaxation
    envelope at two, and a schema-clean serving telemetry trace."""
    import numpy as np

    from repro.jaxcompat import make_mesh
    from repro.obs import Telemetry, write_jsonl
    from repro.sched import mesh_relaxation_bound
    from repro.serving import ServingMeshEngine, TrafficConfig
    ok = True
    print("# serving smoke: host/device exactness, 2-shard envelope, "
          "trace schema", file=out)
    print(f"bench,{HEADER.split(',', 1)[1]}", file=out)

    # 1. exactness: host pool and 1-shard device admission agree on the
    # admitted requests AND their order (same EDF keys, same prefixes)
    tc = TrafficConfig(ticks=24, rate=0.5, tenants=2, seed=3,
                       prompt_len=(2, 5), max_new_tokens=(1, 3),
                       slo_ticks=24)
    host = run_serving("host", tc)
    dev = run_serving("device", tc)
    for res in (host, dev):
        _emit_rows(out, res, tc, tc.rate)
    if dev["admission_log"] != host["admission_log"]:
        print("# FAIL: 1-shard device admission order diverged from the "
              "host pool", file=out)
        ok = False
    if not (dev["completed"] == host["completed"] == dev["trace_len"]):
        print(f"# FAIL: completions {dev['completed']}/{host['completed']} "
              f"!= submitted {dev['trace_len']}", file=out)
        ok = False
    if dev["goodput"] < host["goodput"]:
        print(f"# FAIL: device goodput {dev['goodput']} < host "
              f"{host['goodput']}", file=out)
        ok = False

    # 2. two-shard envelope: pops of a single stall-free admission tick
    # must order within the declared mesh relaxation bound, and every
    # request is admitted exactly once (conservation)
    eng = ServingMeshEngine(mesh=make_mesh((2,), ("data",)),
                            capacity_log2=6, batch=8, table_log2=6,
                            pop_log=256, telemetry=Telemetry(capacity=512))
    rng = np.random.default_rng(0)
    keys = np.sort(rng.choice(10_000, size=32, replace=False))
    rng.shuffle(keys)
    admitted = eng.tick(keys.tolist(), list(range(32)), slots=32, pages=64,
                        need=[1] * 32)
    if sorted(admitted) != list(range(32)) or eng.occupancy() != 0:
        print(f"# FAIL: 2-shard conservation broken: {sorted(admitted)}",
              file=out)
        ok = False
    k = mesh_relaxation_bound(2, 8, eng.stats["max_occupancy"])
    popped = [kk for _, _, kk, _ in eng.pop_history()]
    depth = max(sum(1 for later in popped[i + 1:] if later < ki)
                for i, ki in enumerate(popped))
    print(f"# 2-shard pop inversion depth {depth} vs envelope k={k}",
          file=out)
    if depth > k:
        print(f"# FAIL: relaxed pop order escaped the envelope "
              f"({depth} > {k})", file=out)
        ok = False

    # 3. the serving trace artifact round-trips the schema validator
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "trace_serving.jsonl")
        write_jsonl(path, eng.telemetry.records, eng.telemetry.sync_points,
                    metrics=dict(eng.stats), engine="serving")
        proc = subprocess.run(
            [sys.executable, os.path.join(REPO, "tools", "trace_check.py"),
             path], capture_output=True, text=True, cwd=REPO, timeout=300)
        print(f"# trace_check: {proc.stdout.strip()}", file=out)
        if proc.returncode != 0:
            print(f"# FAIL: serving trace failed schema validation: "
                  f"{proc.stderr[-1000:]}", file=out)
            ok = False
    print(f"# acceptance: {'PASS' if ok else 'FAIL'}", file=out)
    return ok


# ---------------------------------------------------------------------------
# outer (CSV-relaying) side
# ---------------------------------------------------------------------------


def main(out=sys.stdout, rates=(0.5, 1.5, 3.0), ticks: int = 120,
         tenants: int = 2, trials: int = 3) -> None:
    print("# open-loop serving: goodput + tail latency vs offered load, "
          "host-pool vs device admission", file=out)
    rc = _spawn_inner(["--rates", ",".join(map(str, rates)),
                       "--ticks", str(ticks), "--tenants", str(tenants),
                       "--trials", str(trials)], out)
    if rc != 0:
        raise RuntimeError(f"serving benchmark subprocess exited {rc}")


def smoke(out=sys.stdout) -> bool:
    return _spawn_inner(["--smoke"], out) == 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true",
                    help="run in-process (expects XLA_FLAGS set)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI correctness gate (no timing assertion)")
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep (CI-sized)")
    ap.add_argument("--rates", default="0.5,1.5,3.0")
    ap.add_argument("--ticks", type=int, default=120)
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--trials", type=int, default=3)
    a = ap.parse_args()
    rates = tuple(float(r) for r in a.rates.split(","))
    if a.quick:
        rates, a.ticks, a.trials = (0.5, 2.5), 80, 2
    if a.inner:
        if a.smoke:
            sys.exit(0 if inner_smoke(sys.stdout) else 1)
        sys.exit(0 if inner_main(sys.stdout, rates, ticks=a.ticks,
                                 tenants=a.tenants, trials=a.trials) else 1)
    if a.smoke:
        sys.exit(0 if smoke() else 1)
    main(rates=rates, ticks=a.ticks, tenants=a.tenants, trials=a.trials)
