"""Public jit'd entry points for the kernel package.

Every op takes ``use_kernel`` — True routes through the Pallas kernel
(interpret-mode on CPU, compiled on TPU), False through the pure-jnp oracle
in ``ref.py``.  The test suite asserts both paths agree across shape/dtype
sweeps; the framework calls these wrappers everywhere so the kernel/oracle
switch is a config flag, not a code change.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .frontier import frontier_expand as _frontier_kernel
from .moe_route import expert_tickets as _expert_tickets_kernel
from .moe_route import moe_route as _moe_route_kernel
from .pallas_env import resolve_interpret
from .ring_slots import ring_dequeue as _ring_deq_kernel
from .ring_slots import ring_enqueue as _ring_enq_kernel
from .wavefaa import LANES, wavefaa as _wavefaa_kernel


def _interp() -> bool:
    # REPRO_PALLAS_INTERPRET wins; otherwise interpret everywhere but TPU
    return resolve_interpret(None)


def wavefaa(active, counter, *, use_kernel: bool = True):
    if use_kernel and active.shape[0] % LANES == 0:
        return _wavefaa_kernel(active, counter, interpret=_interp())
    return ref.wavefaa_ref(active, counter)


def ring_enqueue(cycles, safes, enqs, idxs, tickets, values, head, *,
                 nslots_log2: int, idx_bot: int, use_kernel: bool = True):
    if use_kernel:
        return _ring_enq_kernel(cycles, safes, enqs, idxs, tickets, values,
                                head, nslots_log2=nslots_log2,
                                idx_bot=idx_bot, interpret=_interp())
    return ref.ring_enqueue_ref(cycles, safes, enqs, idxs, tickets, values,
                                head, nslots_log2, idx_bot)


def ring_dequeue(cycles, safes, enqs, idxs, tickets, *, nslots_log2: int,
                 idx_bot: int, use_kernel: bool = True):
    if use_kernel:
        return _ring_deq_kernel(cycles, safes, enqs, idxs, tickets,
                                nslots_log2=nslots_log2, idx_bot=idx_bot,
                                interpret=_interp())
    return ref.ring_dequeue_ref(cycles, safes, enqs, idxs, tickets,
                                nslots_log2, idx_bot)


def frontier_expand(row_ptr, col_idx, frontier, visited, *, max_out: int,
                    use_kernel: bool = True):
    if use_kernel:
        return _frontier_kernel(row_ptr, col_idx, frontier, visited,
                                max_out=max_out, interpret=_interp())
    out, cnt, vis = ref.frontier_expand_ref(row_ptr, col_idx, frontier,
                                            None, visited, max_out)
    return out, jnp.reshape(cnt, (1,)), vis


def expert_tickets(expert_ids, *, num_experts: int, capacity: int,
                   use_kernel: bool = True):
    if use_kernel and expert_ids.shape[0] % 128 == 0:
        return _expert_tickets_kernel(expert_ids, num_experts=num_experts,
                                      capacity=capacity, interpret=_interp())
    onehot = jax.nn.one_hot(jnp.maximum(expert_ids, 0), num_experts,
                            dtype=jnp.int32)
    onehot = onehot * (expert_ids >= 0)[:, None]
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.sum(ranks * onehot, axis=-1)
    return jnp.where((expert_ids >= 0) & (slot < capacity), slot, -1)


def moe_route(gates, k: int, capacity: int, *, use_kernel: bool = True):
    if use_kernel and (gates.shape[0] * k) % 128 == 0:
        return _moe_route_kernel(gates, k, capacity, interpret=_interp())
    return ref.moe_route_ref(gates, k, capacity)
