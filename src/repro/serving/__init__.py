"""repro.serving subpackage."""
