"""SFQ — the Scogland–Feng ticketed ring queue (ICPE'15), the paper's GPU
baseline.

Enqueue side: FAA ticket into a fixed ring; each slot carries a *turn*
counter; the producer spins until the slot's turn reaches its cycle (the
blocking interface of the original paper).  A size pre-check provides the
separate non-waiting interface ("for cases where waiting is undesirable").

Dequeue side: CAS-claim on the shared head — deliberately the more
*serialized* side, matching § VI-C-d ("per-operation cost is dominated by the
serialization of its dequeue side"), which is what makes SFQ collapse under
split producer/consumer loads.

Slot word layout: [ turn : 32 | value : 32 ].
"""

from __future__ import annotations

from .base import QueueAlgorithm, VAL_MASK
from .sim import Ctx


def _pack(turn: int, value: int) -> int:
    return ((turn & 0xFFFFFFFF) << 32) | (value & 0xFFFFFFFF)


def _turn(word: int) -> int:
    return (word >> 32) & 0xFFFFFFFF


def _value(word: int) -> int:
    return word & 0xFFFFFFFF


class SFQ(QueueAlgorithm):
    name = "sfq"

    def __init__(self, capacity: int, num_threads: int, tag: str = "sfq",
                 prefill: int = 0, max_spin: int = 4096) -> None:
        super().__init__(capacity, num_threads)
        self.tag = tag
        self.prefill = prefill
        self.max_spin = max_spin
        self.s_tail = f"{tag}_tail"
        self.s_head = f"{tag}_head"
        self.s_slots = f"{tag}_slots"

    def init(self, mem) -> None:
        self.mem = mem
        n = self.capacity
        mem.alloc(self.s_tail, 1, fill=self.prefill)
        mem.alloc(self.s_head, 1, fill=0)
        mem.alloc(self.s_slots, n)
        slots = mem.array(self.s_slots)
        for j in range(n):
            if j < self.prefill:
                slots[j] = _pack(1, j)       # pre-filled with index j
            else:
                slots[j] = _pack(0, 0)       # turn 0 == empty, cycle 0

    # turn protocol: slot j is writable for ticket t (j = t % n) when
    # turn == 2*(t//n); after the write turn becomes 2*(t//n)+1 (readable);
    # after consumption turn becomes 2*(t//n)+2 == writable for next cycle.

    def enqueue(self, ctx: Ctx, tid: int, value: int):
        n = self.capacity
        # Non-waiting interface: reject when full (head read first: head only
        # grows, so tail - head over-approximates the occupancy).
        h = yield from ctx.load(self.s_head, 0)
        t_now = yield from ctx.load(self.s_tail, 0)
        if t_now - h >= n:
            return False
        t = yield from ctx.faa(self.s_tail, 0, 1)
        j = t % n
        want = 2 * (t // n)
        spins = 0
        while True:
            w = yield from ctx.load(self.s_slots, j)
            if _turn(w) == want:
                yield from ctx.store(self.s_slots, j, _pack(want + 1, value & VAL_MASK))
                return True
            # Blocking interface: the ticket cannot be abandoned — spin.
            spins += 1
            yield from ctx.step()
            if spins > self.max_spin:
                # pathological backpressure; keep spinning but let the
                # scheduler's step budget end fixed-duration runs.
                spins = 0

    def dequeue(self, ctx: Ctx, tid: int):
        n = self.capacity
        while True:
            h = yield from ctx.load(self.s_head, 0)
            t = yield from ctx.load(self.s_tail, 0)
            if t <= h:
                return (False, None)  # observed empty (head monotone ⇒ sound)
            j = h % n
            want = 2 * (h // n) + 1
            w = yield from ctx.load(self.s_slots, j)
            turn = _turn(w)
            if turn == want - 1:
                # The head producer holds ticket h but has not published its
                # store yet.  Returning EMPTY here is NOT linearizable when
                # later slots already hold completed enqueues (FIFO blocks
                # them behind h), so the blocking interface spins — this
                # head-of-line wait is exactly the serialization that makes
                # SFQ collapse under asymmetric loads (§ VI-C-d).
                yield from ctx.step()
                continue
            if turn != want:
                continue  # stale head snapshot; retry
            # CAS-claim the head (serialized dequeue side).
            ok = yield from ctx.cas(self.s_head, 0, h, h + 1)
            if not ok:
                continue
            value = _value(w)
            # release the slot for the next cycle
            yield from ctx.store(self.s_slots, j, _pack(want + 1, 0))
            return (True, value)
