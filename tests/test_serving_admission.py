"""Property-based certification of device-resident serving admission
(DESIGN.md § 5.5).

Random tenant mixes, deadlines, and page-stall schedules drive
``ServingMeshEngine`` tick sequences; every run is certified three ways:

* **reference agreement** — at one shard the admitted prefix of every
  tick matches a pure-python EDF reference (sorted-pending,
  stop-at-first-stall, re-entry at the original deadline) in set AND
  order; a 2-shard forced-device subprocess re-certifies the relaxed
  case, where order may legitimately differ but conservation and the
  envelope below still hold;
* **p-linearizability** — the engine's pop log is rebuilt into an
  INS/DELMIN history (arrivals insert before their tick's first round,
  republished stalls re-insert in their round's publish interval) and
  checked by ``sched.check_p_linearizable`` within
  ``sched.mesh_relaxation_bound`` (k = 0 at one shard: *exact* EDF);
* **conservation** — every request is admitted exactly once and the heap
  drains.

The sweep runs under ``hypothesis`` when it is installed (CI's ``[test]``
extra) and falls back to a seeded deterministic sweep of the same
property otherwise — the property function is shared, so both paths
certify identical semantics.

The deadline-key wraparound regressions cover BOTH stamp planes sharing
the 2^30 round clock: the heap deadline plane (``tick``/``submit`` raise
at stamp time) and the packed FIFO birth-stamp plane (``enq_planes``
rejects a wrapped stamp; the serving span clock guard refuses to run a
tick past the cap).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

sys.path.insert(
    0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.sim import HistoryEvent  # noqa: E402
from repro.jaxcompat import make_mesh  # noqa: E402
from repro.kernels.ring_slots import SPAN_ROUND_CAP, enq_planes  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.obs.spans import Spans  # noqa: E402
from repro.runtime.fusedrounds import IDX_BOT  # noqa: E402
from repro.sched import (DELMIN, INS, check_p_linearizable,  # noqa: E402
                         check_p_linearizable_search, mesh_relaxation_bound)
from repro.serving import (DEADLINE_KEY_CAP, EngineConfig,  # noqa: E402
                           Request, ServingEngine, ServingMeshEngine,
                           TrafficConfig, generate_trace)

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BATCH = 4

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # local runs without the [test] extra
    HAVE_HYPOTHESIS = False


# -- shared 1-shard engine (one megaround compile for the whole sweep) --------

_ENGINE = None


def _get_engine():
    global _ENGINE
    if _ENGINE is None:
        _ENGINE = ServingMeshEngine(
            mesh=make_mesh((1,), ("data",)), capacity_log2=6, batch=BATCH,
            table_log2=6, pop_log=2048)
    return _ENGINE


# -- random admission scenarios ----------------------------------------------


def _make_scenario(rng):
    """Random deadlines, page needs, arrival schedule, and per-tick
    slot/page budgets (including zero budgets = pure stall ticks),
    followed by two generous drain ticks so every request is eventually
    admitted and the heap provably empties."""
    n = int(rng.integers(1, 15))
    keys = np.sort(rng.choice(50_000, size=n, replace=False)).astype(int)
    rng.shuffle(keys)
    need = rng.integers(0, 4, size=n).astype(int)
    ticks_n = int(rng.integers(1, 4))
    arrive = rng.integers(0, ticks_n, size=n)
    budgets = [(int(rng.integers(0, 5)), int(rng.integers(0, 9)))
               for _ in range(ticks_n)]
    budgets += [(n, int(3 * n + 1))] * 2       # drain: everything fits
    arrivals = [[] for _ in range(len(budgets))]
    for idx in range(n):
        arrivals[int(arrive[idx])].append((int(keys[idx]), idx))
    return {"n": n, "need": list(need), "arrivals": arrivals,
            "budgets": budgets}


def _reference(scn):
    """Pure-python EDF admission: pending sorted by deadline each tick,
    admit the maximal prefix that fits (stop at the FIRST request that
    exceeds either budget), the rest re-enter at their original keys."""
    pending = []
    per_tick = []
    for t, (slots, pages) in enumerate(scn["budgets"]):
        pending.extend(scn["arrivals"][t])
        pending.sort()
        admitted = []
        for key, idx in pending:
            nd = scn["need"][idx]
            if len(admitted) >= slots or nd > pages:
                break
            admitted.append(idx)
            pages -= nd
        del pending[:len(admitted)]
        per_tick.append(admitted)
    return per_tick, [idx for _, idx in pending]


def _run_device(eng, scn):
    """Drive the scenario's tick sequence; returns per-tick admitted
    lists plus the submission log ``(round-before-tick, key, idx)`` the
    history builder needs."""
    eng.begin()
    subs, per_tick = [], []
    for t, (slots, pages) in enumerate(scn["budgets"]):
        arr = scn["arrivals"][t]
        r0 = eng._rounds
        subs.extend((r0, key, idx) for key, idx in arr)
        adm = eng.tick([k for k, _ in arr], [i for _, i in arr],
                       slots=slots, pages=pages,
                       need=[scn["need"][i] for _, i in arr])
        per_tick.append(adm)
    return per_tick, subs


def _admission_history(subs, pops, resident, table):
    """Rebuild the INS/DELMIN history ``check_p_linearizable`` certifies.

    Timing follows ``mesh_trace_history``'s scheme — round ``r`` pops
    share ``[4r+4, 4r+5]``, its publish wave inserts at ``[4r+6, 4r+7]``
    — and a tick's arrivals insert at ``[4·r0+2, 4·r0+3]`` where ``r0``
    is the global round count before that tick, i.e. before the tick's
    first pop.  A pop's republication is not logged directly but is
    fully inferable: ident ``v`` was republished iff its bumped-retry
    successor ``v + table`` appears in a later pop or stays
    heap-resident (a republished entry has nowhere else to go)."""
    popped = {v for _, _, _, v in pops}
    res = {retry * table + idx for _, idx, retry in resident}
    h = []
    for r0, key, idx in subs:
        t = 4 * r0 + 2
        h.append(HistoryEvent(proc=0, op=INS, arg=(key, idx), ret=True,
                              call=t, end=t + 1))
    for r, s, k, v in pops:
        t = 4 * r + 4
        h.append(HistoryEvent(proc=s, op=DELMIN, arg=None, ret=(k, v),
                              call=t, end=t + 1))
        succ = v + table
        if succ in popped or succ in res:
            h.append(HistoryEvent(proc=s, op=INS, arg=(k, succ), ret=True,
                                  call=t + 2, end=t + 3))
    return h


def _certify(eng, scn, *, exact_order=True):
    """The shared property: reference agreement, conservation, and a
    p-linearizable pop history within the mesh envelope."""
    ref_ticks, ref_left = _reference(scn)
    dev_ticks, subs = _run_device(eng, scn)
    assert ref_left == [], "drain ticks must empty the reference"
    if exact_order:
        assert dev_ticks == ref_ticks, (scn, dev_ticks, ref_ticks)
    # conservation: admitted exactly once each, heap drained
    flat = [i for t in dev_ticks for i in t]
    assert sorted(flat) == list(range(scn["n"])), (scn, dev_ticks)
    assert eng.occupancy() == 0
    # p-linearizability of the pop log within the declared envelope
    k = mesh_relaxation_bound(eng.shards, eng.batch,
                              eng.stats["max_occupancy"])
    if exact_order:
        assert k == 0          # one shard: the check is EXACT EDF
    hist = _admission_history(subs, eng.pop_history(), eng.resident(),
                              eng.table)
    res = check_p_linearizable(hist, k)
    assert res.ok, (res.reason, scn)
    return hist, k


def _property(seed):
    rng = np.random.default_rng(seed)
    _certify(_get_engine(), _make_scenario(rng), exact_order=True)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(st.integers(0, 2**31 - 1))
    def test_admission_property(seed):
        _property(seed)
else:
    @pytest.mark.parametrize("seed", range(8))
    def test_admission_property(seed):
        _property(seed)


def test_admission_history_against_exact_oracle():
    """One small scenario's history re-checked by the Wing–Gong search
    oracle, so the fast pattern check and the exact checker agree on
    serving histories (not just the spawn-tree ones)."""
    rng = np.random.default_rng(7)
    scn = {"n": 4, "need": [1, 3, 1, 2],
           "arrivals": [[(40, 0), (10, 1)], [(20, 2), (30, 3)], []],
           "budgets": [(2, 3), (1, 1), (4, 13)]}
    hist, k = _certify(_get_engine(), scn, exact_order=True)
    del rng
    res = check_p_linearizable_search(hist, k)
    assert res.ok, res.reason


def test_page_stall_reenters_at_original_deadline():
    """The § 5.5 aging guarantee, pinned: a page-stalled request keeps
    its deadline while later arrivals take later keys, so it admits
    FIRST once pages free — not at the back of the line."""
    eng = _get_engine()
    eng.begin()
    assert eng.tick([100], [0], slots=1, pages=1, need=[4]) == []
    assert eng.occupancy() == 1            # stalled, still heap-resident
    # a later (larger-key) arrival cannot jump the aged request
    assert eng.tick([200], [1], slots=2, pages=6, need=[1]) == [0, 1]
    assert eng.occupancy() == 0


# -- 2-shard relaxed certification (forced-device subprocess) -----------------


def _forced_device_env(n):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH"), REPO)
        if p)
    return env


def test_admission_property_2shard():
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--relaxed-worker"],
        capture_output=True, text=True, cwd=REPO,
        env=_forced_device_env(2), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got["ok"] and got["scenarios"] >= 3


def _relaxed_worker():
    """Certify seeded scenarios at 2 shards: admission order may relax
    within the mesh envelope (exact_order=False) but conservation and
    p-linearizability at k = mesh_relaxation_bound must still hold."""
    eng = ServingMeshEngine(mesh=make_mesh((2,), ("data",)),
                            capacity_log2=6, batch=BATCH, table_log2=6,
                            pop_log=2048)
    ks = []
    for seed in (11, 12, 13):
        rng = np.random.default_rng(seed)
        _, k = _certify(eng, _make_scenario(rng), exact_order=False)
        ks.append(k)
    print(json.dumps({"ok": True, "scenarios": len(ks), "k": ks}))


# -- host-pool vs device admission: same admitted requests --------------------


@pytest.fixture(scope="module")
def model():
    cfg = get_config("h2o-danube-1.8b").reduced()
    return cfg, init_params(cfg)


def _drive_engine(model, admission, trace, tc, policies=None):
    cfg, params = model
    ecfg = EngineConfig(max_slots=2, page_size=8, num_pages=8, max_seq=64,
                        request_ring_capacity=64, admission=admission,
                        tenants=tc.tenants, tenant_policies=policies,
                        device_capacity_log2=6, device_batch=BATCH,
                        device_table_log2=6)
    eng = ServingEngine(cfg, params, ecfg)
    by_tick = {}
    reqs = []
    for rid, a in enumerate(trace):
        req = Request(rid=rid, prompt=(np.arange(a.prompt_len) % 17 + 1
                                       ).astype(np.int32),
                      max_new_tokens=a.max_new_tokens, priority=a.priority,
                      tenant=a.tenant)
        reqs.append(req)
        by_tick.setdefault(a.tick, []).append(req)
    horizon = max(by_tick) if by_tick else 0
    for _ in range(500):
        for req in by_tick.get(eng.tick, []):
            assert eng.submit(req)
        eng.step()
        if (eng.tick > horizon and not any(eng.slots) and not eng.stalled
                and eng._queue_empty()):
            break
    return eng, reqs


@pytest.mark.parametrize("policies", [None, ("strict", "weighted")],
                         ids=["inline-edf", "policy-lanes"])
def test_device_admission_matches_host_pool(model, policies):
    """The satellite contract: host-pool and device admission agree on
    the SET of admitted requests — and at one shard on the exact order
    and decode schedule too."""
    tc = TrafficConfig(ticks=30, rate=0.4, tenants=2, seed=3,
                       prompt_len=(2, 5), max_new_tokens=(1, 3))
    trace = generate_trace(tc)
    assert len(trace) >= 6
    host, hreqs = _drive_engine(model, "edf", trace, tc, policies)
    dev, dreqs = _drive_engine(model, "device", trace, tc, policies)
    assert set(dev.admission_log) == set(host.admission_log)
    assert dev.admission_log == host.admission_log       # 1 shard: exact
    assert dev.metrics["completed"] == host.metrics["completed"] == \
        len(trace)
    assert dev.metrics["decode_steps"] == host.metrics["decode_steps"]
    for hr, dr in zip(hreqs, dreqs):
        assert hr.deadline == dr.deadline                # same stamping
        assert (hr.admit_tick, hr.finish_tick) == \
            (dr.admit_tick, dr.finish_tick)
    # page conservation in device mode: all pages back on the free ring
    assert all(s is None for s in dev.slots)
    freed = sum(1 for _ in range(dev.ecfg.num_pages)
                if dev.free_pages.dequeue(timeout=0.0) is not None)
    assert freed == dev.ecfg.num_pages


# -- deadline-key wraparound: raise at stamp time on BOTH planes --------------


def test_deadline_cap_is_the_span_round_cap():
    assert DEADLINE_KEY_CAP == SPAN_ROUND_CAP == 1 << 30


def test_tick_rejects_wrapped_deadline_key():
    eng = _get_engine()
    eng.begin()
    for bad in (DEADLINE_KEY_CAP, DEADLINE_KEY_CAP + 5, -1):
        with pytest.raises(ValueError, match="would wrap"):
            eng.tick([bad], [0], slots=1, pages=1, need=[1])
    # near-cap keys stamp fine and still order exactly
    adm = eng.tick([DEADLINE_KEY_CAP - 2, DEADLINE_KEY_CAP - 5], [0, 1],
                   slots=2, pages=2, need=[1, 1])
    assert adm == [1, 0]


def test_submit_rejects_wrapped_deadline(model):
    cfg, params = model
    for admission in ("edf", "device"):
        eng = ServingEngine(cfg, params, EngineConfig(
            max_slots=2, page_size=8, num_pages=8, max_seq=64,
            admission=admission, device_capacity_log2=6,
            device_batch=BATCH, device_table_log2=6))
        with pytest.raises(ValueError, match="would wrap"):
            eng.submit(Request(rid=0, prompt=np.array([1], np.int32),
                               max_new_tokens=1, deadline=DEADLINE_KEY_CAP))


def test_serving_span_clock_refuses_to_wrap():
    """Heap births plane: once the persistent round clock reaches the
    birth-stamp cap, the next tick raises instead of wrapping stamps."""
    eng = ServingMeshEngine(mesh=make_mesh((1,), ("data",)),
                            capacity_log2=6, batch=BATCH, table_log2=6,
                            spans=Spans(classes=1, buckets=8))
    assert eng.tick([5], [0], slots=1, pages=1, need=[1]) == [0]
    assert eng._rounds >= 1
    eng.span_round_cap = eng._rounds       # clock now AT the cap
    with pytest.raises(RuntimeError, match="birth-stamp cap"):
        eng.tick([6], [1], slots=1, pages=1, need=[1])


def test_fifo_stamp_plane_rejects_deadline_at_cap():
    """Packed FIFO stamp plane: a deadline-magnitude round stamp past the
    shared 2^30 clock is rejected by ``enq_planes`` itself — the same
    cap ``tick``/``submit`` enforce for heap keys."""
    n = 8
    planes = [jnp.zeros(2 * n, jnp.int32) for _ in range(3)]
    idxs = jnp.full(2 * n, IDX_BOT, jnp.int32)
    tickets = jnp.arange(16, 20, dtype=jnp.int32)   # cycle 1 beats cycle 0
    with pytest.raises(ValueError, match="birth-stamp cap"):
        enq_planes(planes[0], planes[1], planes[2], idxs, tickets, tickets,
                   jnp.int32(0), nslots_log2=4, idx_bot=IDX_BOT,
                   birth_round=DEADLINE_KEY_CAP)
    out = enq_planes(planes[0], planes[1], planes[2], idxs, tickets,
                     tickets, jnp.int32(0), nslots_log2=4, idx_bot=IDX_BOT,
                     birth_round=DEADLINE_KEY_CAP - 1)
    assert int(out[4].sum()) == 4          # one under the cap installs


if __name__ == "__main__":
    if "--relaxed-worker" in sys.argv:
        _relaxed_worker()
