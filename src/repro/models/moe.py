"""Mixture-of-Experts layer with ring-ticket dispatch.

Token→expert routing is the paper's bounded-ring admission problem: each
routed (token, choice) pair claims a slot in its expert's capacity-bounded
buffer via ticket reservation; over-capacity pairs take the RETRY path
(dropped, weight zeroed) exactly like a full bounded ring rejects enqueues.
`repro.kernels.moe_route` is the Pallas aggregate-then-commit version of the
same semantics; inside the model graph we use the einsum formulation so XLA
can shard it (experts over "model" = EP), asserting equality in tests.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..jaxcompat import current_mesh
from .layers import _dense

Params = Dict[str, jax.Array]


def _shard_expert_buffers(buf: jax.Array, n_experts: int) -> jax.Array:
    """Pin (g, E, C, d) expert buffers to the mesh: groups over the DP axes,
    experts over "model" when divisible (classic EP) else the capacity dim.
    Without this an indivisible expert count (granite's 40 on a 16-way
    axis) replicates the whole expert GEMM on every chip."""
    mesh = current_mesh()
    if mesh is None or "model" not in (mesh.axis_names or ()):
        return buf
    model = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    gspec = dp if buf.shape[0] > 1 else None
    if model <= 1:
        return jax.lax.with_sharding_constraint(buf, P(gspec, None, None, None))
    if n_experts % model == 0:
        return jax.lax.with_sharding_constraint(buf, P(gspec, "model", None, None))
    if buf.shape[2] % model == 0:
        return jax.lax.with_sharding_constraint(buf, P(gspec, None, "model", None))
    return buf


def moe_params(key, cfg: ArchConfig) -> Params:
    d, fe, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense(ks[0], (d, e), dtype=jnp.float32),
        "e_gate": _dense(ks[1], (e, d, fe)),
        "e_up": _dense(ks[2], (e, d, fe)),
        "e_down": _dense(ks[3], (e, fe, d)),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * fe
        kss = jax.random.split(ks[4], 3)
        p["s_gate"] = _dense(kss[0], (d, fs))
        p["s_up"] = _dense(kss[1], (d, fs))
        p["s_down"] = _dense(kss[2], (fs, d))
    return p


def moe_specs(cfg: ArchConfig, fsdp_axis=None):
    f = fsdp_axis
    sp = {
        "router": P(None, None),
        "e_gate": P("model", f, None),   # EP: experts sharded over "model"
        "e_up": P("model", f, None),
        "e_down": P("model", f, None),
    }
    if cfg.n_shared_experts:
        sp["s_gate"] = P(f, "model")
        sp["s_up"] = P(f, "model")
        sp["s_down"] = P("model", f)
    return sp


def _dp_groups(t: int) -> int:
    """Dispatch group count = the mesh's data-parallel degree (1 off-mesh).
    Group-local dispatch is what EP systems actually do: each DP shard
    ranks and capacity-bounds its own tokens, so the ticket cumsum and the
    (E, C, d) buffers are batch-parallel instead of a global prefix that
    forces every chip through the full global capacity (§Perf hillclimb #1:
    granite's expert GEMMs were 40×262k×d on *every* chip)."""
    mesh = current_mesh()
    if mesh is None or not mesh.axis_names:
        return 1
    g = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            g *= mesh.shape[a]
    # grouping only pays when each group still has a meaningful token count
    # (decode batches are small: capacity padding would dominate)
    return g if g > 1 and t % g == 0 and t // g >= 256 else 1


def moe_forward(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: (B, S, d) → (B, S, d).  Top-k dispatch with group-local per-expert
    capacity C = ceil(T_local·k/E · capacity_factor); over-capacity pairs in
    each group are dropped (the bounded ring's RETRY path, applied at the
    same scope a per-chip expert ring would be)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)
    gates = (xt.astype(jnp.float32) @ p["router"])           # (T, E)
    top_g, top_e = jax.lax.top_k(gates, k)                   # (T, k)
    probs = jax.nn.softmax(top_g, axis=-1)                   # (T, k)

    g = _dp_groups(t)
    tl = t // g                                               # tokens per group
    capacity = int((tl * k) / e * cfg.capacity_factor) + 1
    capacity = -(-capacity // 32) * 32                        # shardable C
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)        # (T, k, E)
    grouped = onehot.reshape(g, tl * k, e)
    # ring-ticket reservation per expert, group-local (batch-parallel):
    ranks = jnp.cumsum(grouped, axis=1) - grouped             # (g, tl·k, E)
    slot = jnp.sum(ranks * grouped, axis=-1).reshape(t, k)    # (T, k)
    keep = slot < capacity                                    # RETRY path: drop
    combine = jnp.where(keep, probs, 0.0)                     # (T, k)

    # Scatter-based dispatch into (g, E, C, d) expert buffers — O(T·k·d).
    # The scatter/gather are vmapped over the group dim so the partitioner
    # can keep them (and the buffers) sharded over the DP axes instead of
    # materializing replicated global-capacity copies.
    e_g = top_e.reshape(g, tl * k)
    s_g = jnp.where(keep, slot, capacity).reshape(g, tl * k)  # C = drop bin
    src_g = jnp.repeat(xt, k, axis=0).reshape(g, tl * k, d).astype(x.dtype)

    def disp(e_i, s_i, src_i):
        buf = jnp.zeros((e, capacity + 1, d), x.dtype)
        return buf.at[e_i, s_i].add(src_i)[:, :capacity]

    xin = jax.vmap(disp)(e_g, s_g, src_g)                     # (g, E, C, d)
    xin = _shard_expert_buffers(xin, e)
    hg = _shard_expert_buffers(
        jnp.einsum("gecd,edf->gecf", xin, p["e_gate"]), e)
    hu = _shard_expert_buffers(
        jnp.einsum("gecd,edf->gecf", xin, p["e_up"]), e)
    hout = jnp.einsum("gecf,efd->gecd", jax.nn.silu(hg) * hu, p["e_down"])
    # For the combine gather, reshard hout from capacity-sharded to
    # d-sharded: the gather output then stays "model"-sharded on d instead
    # of needing a full-width partial-sum all-reduce (76% of this cell's
    # collective volume before this change).
    mesh = current_mesh()
    if (mesh is not None and "model" in (mesh.axis_names or ())
            and d % mesh.shape["model"] == 0):
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        gspec = dp if g > 1 else None
        hout = jax.lax.with_sharding_constraint(
            hout, P(gspec, None, None, "model"))
    else:
        hout = _shard_expert_buffers(hout, e)

    def undisp(h_i, e_i, s_i):
        return h_i[e_i, jnp.minimum(s_i, capacity - 1)]

    gathered = jax.vmap(undisp)(hout, e_g, s_g).reshape(t * k, d)
    gathered = gathered * keep.reshape(t * k, 1).astype(x.dtype)
    yt = jnp.sum(gathered.reshape(t, k, d)
                 * combine[..., None].astype(x.dtype), axis=1)  # (T, d)

    if cfg.n_shared_experts:
        yt = yt + (jax.nn.silu(xt @ p["s_gate"]) * (xt @ p["s_up"])) @ p["s_down"]
    return yt.reshape(b, s, d)
