"""Distributed substrate tests: checkpoint round-trip + atomic commit,
restart-after-fault, straggler detection/mitigation, elastic re-mesh plans,
and error-feedback gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.distributed import compression
from repro.distributed.fault_tolerance import (RestartManager,
                                               StragglerDetector,
                                               elastic_mesh_plan)


def test_checkpoint_roundtrip(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), async_write=False)
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    ckpt.save(7, tree)
    step, restored = ckpt.restore(tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_gc_keeps_latest(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        ckpt.save(s, {"x": jnp.full((2,), s)})
    assert ckpt.list_steps() == [3, 4]


def test_async_checkpoint_commits(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), async_write=True)
    ckpt.save(1, {"x": jnp.zeros((4,))})
    ckpt.wait()
    assert ckpt.latest_step() == 1
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_restart_manager_recovers_from_fault(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), async_write=False)
    calls = {"n": 0}

    def step_fn(state, i):
        calls["n"] += 1
        return {"x": state["x"] + 1}

    rm = RestartManager(ckpt, save_every=5, max_restarts=2)
    final_step, state = rm.run({"x": jnp.zeros(())}, step_fn, num_steps=20,
                               inject_fault_at=12)
    assert final_step == 20
    assert rm.restarts == 1
    # after restart from step 10, steps 10-11 re-run: total value still 20
    assert int(state["x"]) == 20


def test_straggler_detection_and_plan():
    det = StragglerDetector(n_pods=4, threshold=1.5)
    rep = None
    for step in range(20):
        for pod in range(4):
            t = 1.0 if pod != 2 else (3.0 if step > 8 else 1.0)
            r = det.heartbeat(step, pod, t)
            rep = r or rep
    assert rep is not None and rep.pod == 2
    plan = det.mitigation_plan(rep)
    shares = plan["pod_shares"]
    assert shares[2] < min(shares[0], shares[1], shares[3])
    assert abs(sum(shares) - 1.0) < 1e-9


@pytest.mark.parametrize("n,tp,expect", [(512, 16, (32, 16)),
                                         (496, 16, (31, 16)),
                                         (498, 16, (249, 2)),
                                         (8, 16, (1, 8))])
def test_elastic_mesh_plan(n, tp, expect):
    plan = elastic_mesh_plan(n, tp=tp)
    assert (plan["data"], plan["model"]) == expect
    assert plan["data"] * plan["model"] <= n


def test_compression_error_feedback_converges():
    """EF-int8: accumulated quantization error stays bounded and the running
    mean of compressed gradients tracks the true mean."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(1024,)).astype(np.float32))
    err = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        deq, err = compression.compress_with_feedback(g_true, err)
        acc = acc + deq
    drift = float(jnp.max(jnp.abs(acc / 50 - g_true)))
    assert drift < 2e-2, drift
    assert float(jnp.max(jnp.abs(err))) < float(jnp.max(jnp.abs(g_true)))
    assert compression.compression_ratio() < 0.27


def test_quantize_roundtrip_scale():
    x = jnp.asarray(np.linspace(-3, 3, 512).astype(np.float32))
    q, s = compression.quantize(x)
    back = compression.dequantize(q, s, x.shape)
    assert float(jnp.max(jnp.abs(back - x))) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6
