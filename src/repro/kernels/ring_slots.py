"""Batched bounded-ring slot operations as Pallas TPU kernels.

These kernels apply a *wave* of fast-path queue operations (paper Alg. 1) to
the ring state in one invocation.  The ring's packed 64-bit entry word is
represented as four parallel int32 field planes (cycle / safe / enq / idx) —
TPU-native layout: 32-bit lanes, single-writer-per-slot semantics guaranteed
by ticket uniqueness (Lemma III.1).

Exact tickets within a batch hit pairwise-distinct slots (any wave spans
< 2n tickets), so the batch needs no serial ordering at all: both kernels
are a single gather → predicate → masked scatter over the field planes,
vectorized across the whole wave.  Lanes whose predicate fails (and inactive
``ticket == -1`` lanes) are routed to an out-of-range index and dropped, so
only installing/consuming lanes touch the planes.  The same vectorized
plane updates are exposed as pure-jnp functions (``enq_planes`` /
``deq_planes``) so the fused round engine can inline them into a jitted
``while_loop`` without a host round-trip.

VMEM budget: the whole ring (4 × 2n × 4 B) plus the op batch live in VMEM;
for n ≤ 64Ki that is ≤ 2 MiB — comfortably inside the 16 MiB/core budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_env import resolve_interpret

#: Round-clock ceiling of the packed birth-stamp layout: the stamp rides
#: the upper 31 bits of the enq flag word as ``(birth << 1) | 1``, so any
#: round index >= 2^30 would wrap into the sign bit and corrupt both the
#: stamp and the flag's 0/1 semantics.  ``enq_planes`` raises at stamp
#: time (concrete birth rounds) and the engine driver clamps its chunk
#: limits to the cap (traced birth rounds) — stamps never wrap silently.
SPAN_ROUND_CAP = 1 << 30


def ticket_cycle(tickets, nslots_log2: int):
    """A ticket's ring cycle, wrap-safe: tickets are unsigned mod-2^32
    counters carried in int32, so the cycle is the *logical* right shift
    (an arithmetic shift would smear the sign bit over wrapped tickets)."""
    return jax.lax.shift_right_logical(tickets, nslots_log2)


def cycle_lt(a, b, nslots_log2: int):
    """Wrap-safe cycle comparison a < b (wCQ-style bounded-cycle
    arithmetic).  Cycles live mod 2^(32-log2(2n)), so the wraparound
    difference is computed in *cycle-modulus* space: shift it back into
    ticket space and read the int32 sign.  Valid while live cycles stay
    within half the cycle modulus of each other — guaranteed because a
    ring holds at most two live cycles at once (Lemma III.2)."""
    return ((b - a) << nslots_log2) > 0


def enq_planes(cycles, safes, enqs, idxs, tickets, values, head, *,
               nslots_log2: int, idx_bot: int, active=None,
               births=None, birth_round=None):
    """Vectorized TRYENQ install wave over the (2n,) field planes.

    ``tickets``/``values`` are (B,) int32; active tickets must hit
    pairwise-distinct slots (Lemma III.1 — true for any ticket wave
    spanning < 2n).  ``active`` masks live lanes; when ``None`` it defaults
    to ``tickets >= 0`` (the -1-sentinel convention of the chip-level
    engine).  Callers whose tickets may wrap past 2^31 (the mesh queue)
    must pass ``active`` explicitly — all ticket comparisons here are
    wraparound-difference based, so wrapped (negative) tickets behave
    correctly.  ``head`` is a scalar.  One gather per plane, one masked
    scatter per plane — no serial loop.  Returns
    (cycles, safes, enqs, idxs, ok).

    ``births``/``birth_round`` enable the span layer's birth stamps
    (DESIGN.md § 7.6), in one of two layouts:

    * **separate plane** — ``births`` is a (2n,) int32 stamp plane riding
      alongside the field planes; installing lanes reuse the already-
      computed scatter index (one extra masked scatter) and ``births`` is
      appended to the return tuple.
    * **packed flag** (``births=None``, ``birth_round`` given) — the
      install writes ``(birth_round << 1) | 1`` into the ``enqs`` flag
      plane instead of the literal 1.  The flag plane only ever carries
      0/1 semantics (the dequeue tests the low bit and nothing else reads
      it), so the stamp rides the *existing* enq scatter: zero extra ops,
      zero extra loop carry, zero extra plane copies — the layout the
      dispatch-bound chip engine uses.  Seeds installed by the unpacked
      kernel path carry ``enqs == 1`` ⇔ birth round 0, exactly the span
      seed contract; ``enqs & 1`` recovers the unpacked plane bit-exactly.
      The stamp occupies the upper 31 bits, capping the round clock at
      2^30 (``SPAN_ROUND_CAP``, enforced here for concrete rounds and by
      the engine driver for traced ones — never a silent wrap; the
      separate plane keeps full int32 range for the mesh engines).  All other
      plane updates are identical in every mode."""
    nslots = 1 << nslots_log2
    idx_botc = idx_bot - 1
    if active is None:
        active = tickets >= 0
    j = jnp.where(active, tickets & (nslots - 1), 0)
    c = jnp.where(active, ticket_cycle(tickets, nslots_log2), 0)
    e_c, e_s, e_i = cycles[j], safes[j], idxs[j]
    empty = (e_i == idx_bot) | (e_i == idx_botc)
    can = active & cycle_lt(e_c, c, nslots_log2) & empty & (
        (e_s == 1) | ((tickets - head) >= 0))
    w = jnp.where(can, j, nslots)          # failed lanes scatter out of range
    cycles = cycles.at[w].set(c, mode="drop")
    safes = safes.at[w].set(1, mode="drop")
    if births is None and birth_round is not None:
        if not isinstance(birth_round, jax.core.Tracer):
            if int(birth_round) >= SPAN_ROUND_CAP:
                raise ValueError(
                    f"birth_round {int(birth_round)} exceeds the packed "
                    f"birth-stamp cap SPAN_ROUND_CAP={SPAN_ROUND_CAP}: the "
                    f"(birth << 1) | 1 layout caps the round clock at 2^30 "
                    f"(use the separate births plane for longer clocks)")
        flag = (jnp.asarray(birth_round, jnp.int32) << 1) | 1
    else:
        flag = jnp.int32(1)
    enqs = enqs.at[w].set(flag, mode="drop")
    idxs = idxs.at[w].set(values, mode="drop")
    if births is None:
        return cycles, safes, enqs, idxs, can.astype(jnp.int32)
    births = births.at[w].set(jnp.asarray(birth_round, jnp.int32),
                              mode="drop")
    return cycles, safes, enqs, idxs, can.astype(jnp.int32), births


def deq_planes(cycles, safes, enqs, idxs, tickets, *,
               nslots_log2: int, idx_bot: int, active=None, births=None,
               birth_packed: bool = False):
    """Vectorized TRYDEQ consume wave (same distinct-slot precondition and
    wrap-safe comparisons as ``enq_planes``).
    Returns (cycles, safes, enqs, idxs, values, ok).

    ``births`` (the span layer's (2n,) stamp plane) adds a gather of the
    consumed slot's birth round, appended to the return tuple as a (B,)
    vector (-1 on missed lanes).  The stamp plane itself is read-only
    here — stale stamps are overwritten at the slot's next install, so no
    scrub is needed.  With the packed-flag layout (``birth_packed=True``,
    see ``enq_planes``) the birth instead rides the existing enq-flag
    gather — the hit test reads the low bit, the stamp the high bits —
    zero extra ops, and the same (B,) vector is appended."""
    nslots = 1 << nslots_log2
    idx_botc = idx_bot - 1
    if active is None:
        active = tickets >= 0
    j = jnp.where(active, tickets & (nslots - 1), 0)
    c = jnp.where(active, ticket_cycle(tickets, nslots_log2), 0)
    e_c, e_s, e_e, e_i = cycles[j], safes[j], enqs[j], idxs[j]
    empty = (e_i == idx_bot) | (e_i == idx_botc)
    flag = (e_e & 1) if birth_packed else e_e
    hit = active & (e_c == c) & (~empty) & (flag == 1)
    idxs = idxs.at[jnp.where(hit, j, nslots)].set(idx_botc, mode="drop")
    adv = active & (~hit) & empty & cycle_lt(e_c, c, nslots_log2)
    cycles = cycles.at[jnp.where(adv, j, nslots)].set(c, mode="drop")
    uns = active & (~hit) & (~empty) & cycle_lt(e_c, c, nslots_log2)
    safes = safes.at[jnp.where(uns, j, nslots)].set(0, mode="drop")
    vals = jnp.where(hit, e_i, -1)
    if birth_packed:
        bvals = jnp.where(hit, e_e >> 1, -1)
        return cycles, safes, enqs, idxs, vals, hit.astype(jnp.int32), bvals
    if births is None:
        return cycles, safes, enqs, idxs, vals, hit.astype(jnp.int32)
    bvals = jnp.where(hit, births[j], -1)
    return cycles, safes, enqs, idxs, vals, hit.astype(jnp.int32), bvals


def _enq_kernel(nslots_log2, idx_bot, head_ref, tickets_ref, values_ref,
                cyc_in, saf_in, enq_in, idx_in,
                cyc_ref, saf_ref, enq_ref, idx_ref, ok_ref):
    cyc, saf, enq, idx, ok = enq_planes(
        cyc_in[...][0], saf_in[...][0], enq_in[...][0], idx_in[...][0],
        tickets_ref[...][0], values_ref[...][0], head_ref[0],
        nslots_log2=nslots_log2, idx_bot=idx_bot)
    cyc_ref[...] = cyc[None]
    saf_ref[...] = saf[None]
    enq_ref[...] = enq[None]
    idx_ref[...] = idx[None]
    ok_ref[...] = ok[None]


def _deq_kernel(nslots_log2, idx_bot, tickets_ref,
                cyc_in, saf_in, enq_in, idx_in,
                cyc_ref, saf_ref, enq_ref, idx_ref, val_ref, ok_ref):
    cyc, saf, enq, idx, vals, ok = deq_planes(
        cyc_in[...][0], saf_in[...][0], enq_in[...][0], idx_in[...][0],
        tickets_ref[...][0], nslots_log2=nslots_log2, idx_bot=idx_bot)
    cyc_ref[...] = cyc[None]
    saf_ref[...] = saf[None]
    enq_ref[...] = enq[None]
    idx_ref[...] = idx[None]
    val_ref[...] = vals[None]
    ok_ref[...] = ok[None]


@functools.partial(jax.jit,
                   static_argnames=("nslots_log2", "idx_bot", "interpret"))
def _ring_enqueue_jit(cycles, safes, enqs, idxs, tickets, values, head, *,
                      nslots_log2: int, idx_bot: int, interpret: bool):
    nslots = 1 << nslots_log2
    b = tickets.shape[0]
    kern = functools.partial(_enq_kernel, nslots_log2, idx_bot)
    call = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
        ] + [pl.BlockSpec((1, nslots), lambda i: (0, 0))] * 4,
        out_specs=[pl.BlockSpec((1, nslots), lambda i: (0, 0))] * 4
        + [pl.BlockSpec((1, b), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, nslots), jnp.int32)] * 4
        + [jax.ShapeDtypeStruct((1, b), jnp.int32)],
        interpret=interpret,
    )
    with jax.named_scope("repro.ring_enqueue"):
        outs = call(head.reshape(1), tickets.reshape(1, b),
                    values.reshape(1, b),
                    cycles.reshape(1, nslots), safes.reshape(1, nslots),
                    enqs.reshape(1, nslots), idxs.reshape(1, nslots))
    cyc, saf, enq, idx, ok = outs
    return (cyc.reshape(nslots), saf.reshape(nslots), enq.reshape(nslots),
            idx.reshape(nslots), ok.reshape(b).astype(bool))


def ring_enqueue(cycles, safes, enqs, idxs, tickets, values, head, *,
                 nslots_log2: int, idx_bot: int, interpret=None):
    """Apply a wave of TRYENQ installs (one masked scatter).  All field
    arrays are (2n,) int32; tickets/values are (B,) int32 (ticket -1 =
    inactive).  ``interpret=None`` resolves via REPRO_PALLAS_INTERPRET /
    backend.  Returns (cycles, safes, enqs, idxs, ok)."""
    return _ring_enqueue_jit(cycles, safes, enqs, idxs, tickets, values,
                             head, nslots_log2=nslots_log2, idx_bot=idx_bot,
                             interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("nslots_log2", "idx_bot", "interpret"))
def _ring_dequeue_jit(cycles, safes, enqs, idxs, tickets, *,
                      nslots_log2: int, idx_bot: int, interpret: bool):
    nslots = 1 << nslots_log2
    b = tickets.shape[0]
    kern = functools.partial(_deq_kernel, nslots_log2, idx_bot)
    call = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, b), lambda i: (0, 0))]
        + [pl.BlockSpec((1, nslots), lambda i: (0, 0))] * 4,
        out_specs=[pl.BlockSpec((1, nslots), lambda i: (0, 0))] * 4
        + [pl.BlockSpec((1, b), lambda i: (0, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((1, nslots), jnp.int32)] * 4
        + [jax.ShapeDtypeStruct((1, b), jnp.int32)] * 2,
        interpret=interpret,
    )
    with jax.named_scope("repro.ring_dequeue"):
        outs = call(tickets.reshape(1, b),
                    cycles.reshape(1, nslots), safes.reshape(1, nslots),
                    enqs.reshape(1, nslots), idxs.reshape(1, nslots))
    cyc, saf, enq, idx, val, ok = outs
    return (cyc.reshape(nslots), saf.reshape(nslots), enq.reshape(nslots),
            idx.reshape(nslots), val.reshape(b), ok.reshape(b).astype(bool))


def ring_dequeue(cycles, safes, enqs, idxs, tickets, *,
                 nslots_log2: int, idx_bot: int, interpret=None):
    """Apply a wave of TRYDEQ consumes (one masked scatter).  Returns
    (cycles, safes, enqs, idxs, values, ok)."""
    return _ring_dequeue_jit(cycles, safes, enqs, idxs, tickets,
                             nslots_log2=nslots_log2, idx_bot=idx_bot,
                             interpret=resolve_interpret(interpret))
