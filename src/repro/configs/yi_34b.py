"""yi-34b — 60L dense llama-arch GQA [arXiv:2403.04652; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000,
    rope_theta=5000000.0, fsdp=True,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention, no sub-quadratic mechanism (DESIGN §5)",
)
