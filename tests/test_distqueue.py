"""Distributed mesh-level queue: exactly-once + FIFO under shard_map.

The 8-device run needs XLA_FLAGS set before jax initializes, so it executes
in a subprocess (the main test process must keep 1 device for the other
tests)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.distqueue import (dist_dequeue_round, dist_enqueue_round,
                                  dist_queue_init)
from repro.jaxcompat import make_mesh


def test_single_device_semantics():
    mesh = make_mesh((1,), ("data",))
    state = dist_queue_init(16)

    def inner(state, values, emask, want):
        state, granted = dist_enqueue_round(state, values, emask, "data")
        state, vals, ok = dist_dequeue_round(state, want, "data")
        return state, granted, vals, ok

    f = jax.jit(shard_map(inner, mesh=mesh,
                          in_specs=(P(), P("data"), P("data"), P("data")),
                          out_specs=(P(), P("data"), P("data"), P("data")),
                          check_rep=False))
    vals = jnp.asarray([5, 6, 7, 8], jnp.int32)
    ones = jnp.ones(4, jnp.int32)
    state, granted, dv, ok = f(state, vals, ones, ones)
    assert bool(granted.all())
    np.testing.assert_array_equal(np.asarray(dv), np.asarray(vals))  # FIFO
    assert bool(ok.all())


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core.distqueue import (dist_queue_init, dist_enqueue_round,
                                      dist_dequeue_round)
    from repro.jaxcompat import make_mesh

    mesh = make_mesh((8,), ("data",))
    B = 4

    def inner(state, values, emask, want):
        state, granted = dist_enqueue_round(state, values, emask, "data")
        state, vals, ok = dist_dequeue_round(state, want, "data")
        return state, granted, vals, ok

    f = jax.jit(shard_map(inner, mesh=mesh,
                          in_specs=(P(), P("data"), P("data"), P("data")),
                          out_specs=(P(), P("data"), P("data"), P("data")),
                          check_rep=False))
    state = dist_queue_init(64)
    rng = np.random.default_rng(0)
    sent, got = [], []
    for rnd in range(6):
        vals = jnp.asarray(rng.integers(1, 1000, (8 * B,)), jnp.int32) + rnd * 10000
        em = jnp.asarray(rng.random(8 * B) < 0.7, jnp.int32)
        wm = jnp.asarray(rng.random(8 * B) < 0.7, jnp.int32)
        state, granted, dv, ok = f(state, vals, em, wm)
        sent += [int(v) for v, g in zip(vals, granted) if g]
        got += [int(v) for v, o in zip(dv, ok) if o]
    for _ in range(6):
        state, granted, dv, ok = f(state, jnp.zeros(8 * B, jnp.int32),
                                   jnp.zeros(8 * B, jnp.int32),
                                   jnp.ones(8 * B, jnp.int32))
        got += [int(v) for v, o in zip(dv, ok) if o]
    assert got == sent, f"FIFO/exactly-once violated: {{len(sent)}} vs {{len(got)}}"
    print("OK", len(sent))
""")


def test_eight_device_fifo_exactly_once():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = _SUBPROC.format(src=os.path.abspath(src))
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
