"""Sharded checkpointing with async write and atomic commit.

Layout: ``<dir>/step_<N>/`` containing one ``.npz`` per host (shard of every
leaf it owns) plus a ``manifest.json`` (tree structure, shapes, shardings,
step).  Writes go to ``step_<N>.tmp`` and are committed with an atomic
rename — a crashed writer never corrupts the latest checkpoint, which is the
restart invariant the fault-tolerance layer relies on.

On this single-host container the host owns every shard; the addressing
logic (`_local_shards`) is written against ``jax.Array.addressable_shards``
so the same code runs multi-host.
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    """save(step, tree) / restore(step?) with background (async) writes."""

    def __init__(self, directory: str, *, keep: int = 3,
                 async_write: bool = True) -> None:
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._async = async_write
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        if async_write:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # -- write path ----------------------------------------------------------

    def save(self, step: int, tree: Any) -> None:
        """Snapshot to host memory now; write + commit in the background."""
        host = {}
        shapes = {}
        for key, leaf in _flatten_with_paths(tree):
            arr = np.asarray(jax.device_get(leaf))
            host[key] = arr
            shapes[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        manifest = {"step": step, "leaves": shapes,
                    "time": time.time()}
        if self._async:
            self._q.put((step, host, manifest))
        else:
            self._write(step, host, manifest)

    def wait(self) -> None:
        """Block until all queued writes are committed."""
        self._q.join()
        if self._error:
            raise self._error

    def _drain(self) -> None:
        while True:
            step, host, manifest = self._q.get()
            try:
                self._write(step, host, manifest)
            except BaseException as e:  # noqa: BLE001
                self._error = e
            finally:
                self._q.task_done()

    def _write(self, step: int, host: Dict[str, np.ndarray],
               manifest: Dict) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "host0.npz"), **host)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- read path ------------------------------------------------------------

    def list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any]:
        """Restore into the structure of ``like``.  With ``shardings`` given
        (a matching tree of NamedSharding), leaves are placed sharded —
        restore-with-remesh: the checkpoint is layout-independent, so a run
        restarted on a different mesh (elastic scaling) re-shards here."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "host0.npz"))
        flat = _flatten_with_paths(like)
        sflat = (_flatten_with_paths(shardings) if shardings is not None
                 else [(k, None) for k, _ in flat])
        leaves = []
        for (key, leaf), (_, sh) in zip(flat, sflat):
            arr = data[key]
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(like)
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
