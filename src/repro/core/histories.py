"""History harness: run queue workloads under the scheduler, collect
histories, and run the paper's § IV device-side FIFO conformance check.

Token scheme (§ IV-b): each producer thread emits ``tok = (tid << 16) |
(seq+1)`` (the paper uses a 32-bit shift; our packed value field is 31 bits,
so producers get 15 bits of id and 16 bits of sequence — same structure).
The checker verifies (i) exactly-once delivery, (ii) no out-of-thin-air
tokens, (iii) per-producer monotone sequence at each consumer.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .atomics import AtomicMemory
from .base import QueueAlgorithm
from .sim import Ctx, DEQ, ENQ, HistoryEvent, Scheduler

TOK_SEQ_BITS = 16


def make_token(tid: int, seq: int) -> int:
    return ((tid & 0x7FFF) << TOK_SEQ_BITS) | ((seq + 1) & 0xFFFF)


def token_fields(tok: int) -> Tuple[int, int]:
    return (tok >> TOK_SEQ_BITS) & 0x7FFF, (tok & 0xFFFF) - 1


@dataclass
class FifoReport:
    ok: bool
    reason: str = ""
    produced: int = 0
    consumed: int = 0


def producer_body(queue: QueueAlgorithm, ops: int):
    def body(ctx: Ctx, tid: int):
        sent = 0
        while sent < ops:
            tok = make_token(tid, sent)
            yield from ctx.op_begin(ENQ, tok)
            ok = yield from queue.enqueue(ctx, tid, tok)
            yield from ctx.op_end(ok, ok)
            if ok:
                sent += 1
            else:
                yield from ctx.step()
    return body


def consumer_body(queue: QueueAlgorithm, done_flag: Dict[str, bool],
                  sink: List[Tuple[int, int]]):
    """Dequeue until the producers are done AND the queue is drained."""
    def body(ctx: Ctx, tid: int):
        empties_after_done = 0
        while True:
            yield from ctx.op_begin(DEQ, None)
            ok, v = yield from queue.dequeue(ctx, tid)
            yield from ctx.op_end(v if ok else None, ok)
            if ok:
                sink.append((tid, v))
                empties_after_done = 0
            else:
                if done_flag["done"]:
                    empties_after_done += 1
                    if empties_after_done >= 3:
                        return
                yield from ctx.step()
    return body


def run_producer_consumer(queue: QueueAlgorithm, *, producers: int,
                          consumers: int, ops_per_producer: int,
                          policy: str = "random", seed: int = 0,
                          wave_size: int = 8,
                          max_steps: int = 5_000_000) -> Tuple[Scheduler, List[Tuple[int, int]], FifoReport]:
    """Producers enqueue unique tokens; consumers drain.  Returns the
    scheduler (for history/metrics), the consumption log, and the FIFO
    conformance report."""
    mem = AtomicMemory()
    queue.init(mem)
    sched = Scheduler(mem, wave_size=wave_size, policy=policy, seed=seed)
    done = {"done": False}
    sink: List[Tuple[int, int]] = []

    prod_threads = []
    for _ in range(producers):
        prod_threads.append(sched.spawn(producer_body(queue, ops_per_producer)))
    for _ in range(consumers):
        sched.spawn(consumer_body(queue, done, sink))

    # run until producers finish, then mark done and drain
    while sched.step_count < max_steps:
        if all(t.done for t in prod_threads):
            done["done"] = True
        live = sched.runnable()
        if not live:
            break
        th = sched._pick()
        sched._exec(th)
    report = fifo_conformance(sink, producers, ops_per_producer)
    if not all(t.done for t in sched.threads):
        report = FifoReport(False, "run did not complete within step budget",
                            report.produced, report.consumed)
    return sched, sink, report


def fifo_conformance(sink: List[Tuple[int, int]], producers: int,
                     ops_per_producer: int) -> FifoReport:
    """§ IV-b: exactly-once, no out-of-bounds tokens, per-producer monotone
    sequence at each consumer."""
    counts: Dict[int, int] = {}
    per_consumer_last: Dict[Tuple[int, int], int] = {}
    for consumer, tok in sink:
        pid, seq = token_fields(tok)
        if pid >= producers or not (0 <= seq < ops_per_producer):
            return FifoReport(False, f"out-of-thin-air token {tok:#x}",
                              producers * ops_per_producer, len(sink))
        counts[tok] = counts.get(tok, 0) + 1
        if counts[tok] > 1:
            return FifoReport(False, f"token {tok:#x} delivered twice",
                              producers * ops_per_producer, len(sink))
        key = (consumer, pid)
        last = per_consumer_last.get(key, -1)
        if seq <= last:
            return FifoReport(
                False,
                f"consumer {consumer} saw producer {pid} seq {seq} after {last}",
                producers * ops_per_producer, len(sink))
        per_consumer_last[key] = seq
    expect = producers * ops_per_producer
    if len(sink) != expect:
        return FifoReport(False, f"{len(sink)}/{expect} tokens delivered",
                          expect, len(sink))
    return FifoReport(True, "exactly-once, in-order", expect, len(sink))


def run_balanced(queue: QueueAlgorithm, *, threads: int, ops: int,
                 policy: str = "gang", seed: int = 0, wave_size: int = 8,
                 max_steps: int = 5_000_000) -> Scheduler:
    """Paper's balanced kernel: every thread alternates enq/deq."""
    mem = AtomicMemory()
    queue.init(mem)
    sched = Scheduler(mem, wave_size=wave_size, policy=policy, seed=seed)
    for i in range(threads):
        sched.spawn(queue.worker_balanced, ops, (i << 16))
    sched.run(max_steps)
    return sched
