"""mamba2-130m — 24L attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64,
)
