"""Docs cross-reference checker (CI gate).

Validates that the documentation web cannot rot:

1. every ``DESIGN.md § x[.y]`` pointer in the source tree, benchmarks,
   examples, tests, README, and docs/PAPER_MAP.md names a section header
   that actually exists in DESIGN.md;
2. docs/PAPER_MAP.md covers every paper section § II–§ V with its own
   ``## § <n>`` header (the acceptance contract of the paper map);
3. every internal ``§ x.y`` cross-reference *inside* DESIGN.md resolves
   to one of its own headers.

Run from the repo root: ``python tools/docs_check.py`` — exits nonzero
with a list of stale pointers on failure.
"""

from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# e.g. "## § 2 Accelerator mapping" / "### § 2.3 Mesh-level ..."
_HEADER = re.compile(r"^#+\s*§\s*(\d+(?:\.\d+)?)\b", re.M)
# e.g. "DESIGN.md § 4.3" (an optional trailing ".5" would be a subsection)
_POINTER = re.compile(r"DESIGN\.md\s*§\s*(\d+(?:\.\d+)?)")
# bare internal refs inside DESIGN.md: "§ 2.3", "§ 5" — but not "§ II" etc.
_INTERNAL = re.compile(r"§\s*(\d+(?:\.\d+)?)")
# the paper sections PAPER_MAP.md must cover
_PAPER_SECTIONS = ("II", "III", "IV", "V")


def design_headers(design_text: str) -> set:
    return set(_HEADER.findall(design_text))


def check() -> list:
    errors = []
    design_path = os.path.join(REPO, "DESIGN.md")
    with open(design_path) as f:
        design = f.read()
    headers = design_headers(design)
    if not headers:
        return [f"{design_path}: no '§ <n>' headers found"]

    # 1. DESIGN.md § pointers across the repo
    pointer_files = []
    for pat in ("src/**/*.py", "benchmarks/*.py", "examples/*.py",
                "tests/*.py", "tools/*.py", "README.md",
                "docs/PAPER_MAP.md"):
        pointer_files += glob.glob(os.path.join(REPO, pat), recursive=True)
    for path in sorted(set(pointer_files)):
        with open(path) as f:
            text = f.read()
        for m in _POINTER.finditer(text):
            sec = m.group(1)
            if sec not in headers:
                line = text[:m.start()].count("\n") + 1
                errors.append(f"{os.path.relpath(path, REPO)}:{line}: "
                              f"stale pointer DESIGN.md § {sec} "
                              f"(no such header)")

    # 2. PAPER_MAP.md covers paper § II–§ V
    pm_path = os.path.join(REPO, "docs", "PAPER_MAP.md")
    if not os.path.exists(pm_path):
        errors.append("docs/PAPER_MAP.md is missing")
    else:
        with open(pm_path) as f:
            pm = f.read()
        for sec in _PAPER_SECTIONS:
            if not re.search(rf"^##\s*§\s*{sec}\b", pm, re.M):
                errors.append(f"docs/PAPER_MAP.md: no '## § {sec}' section "
                              f"(paper § {sec} uncovered)")

    # 3. DESIGN.md internal cross-references
    for m in _INTERNAL.finditer(design):
        sec = m.group(1)
        if sec not in headers:
            line = design[:m.start()].count("\n") + 1
            errors.append(f"DESIGN.md:{line}: internal reference § {sec} "
                          f"has no matching header")
    return errors


def main() -> int:
    errors = check()
    if errors:
        print(f"docs-check: {len(errors)} stale reference(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print("docs-check: all DESIGN.md § pointers resolve; PAPER_MAP.md "
          "covers paper § II-§ V")
    return 0


if __name__ == "__main__":
    sys.exit(main())
