"""Per-architecture configs (assignment pool) + registry."""
from .base import ArchConfig, SHAPES
from .registry import ARCHS, get_config, list_archs

__all__ = ["ArchConfig", "SHAPES", "ARCHS", "get_config", "list_archs"]
